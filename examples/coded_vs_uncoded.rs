//! Coded baselines in action: real polynomial encode → per-worker gram
//! computation → master-side interpolation decode, verified against the
//! uncoded sum — plus the decode-delay measurement that the paper's
//! timing comparison deliberately excludes (§VI-B "this additional
//! decoding delay is not taken into account").
//!
//! ```bash
//! cargo run --release --example coded_vs_uncoded
//! ```

use std::time::Instant;

use straggler_sched::coded::{PcScheme, PcmmScheme};
use straggler_sched::data::Dataset;
use straggler_sched::delay::{DelayModel, Ec2LikeModel};
use straggler_sched::linalg::{norm2, vec_axpy};
use straggler_sched::report::Table;
use straggler_sched::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let (n, r, d) = (8usize, 2usize, 200usize);
    let ds = Dataset::synthesize(n, d, n * 50, 33);
    let mut rng = Rng::seed_from_u64(1);
    let theta: Vec<f64> = (0..d).map(|_| rng.normal()).collect();

    // ground truth: XᵀXθ = Σ_i X_i X_iᵀ θ
    let mut truth = vec![0.0; d];
    for p in &ds.parts {
        vec_axpy(&mut truth, 1.0, &p.gram_matvec(&theta));
    }

    // ---- PC ----------------------------------------------------------------
    let pc = PcScheme::new(n, r);
    println!(
        "PC  (n = {n}, r = {r}): recovery threshold = {} workers",
        pc.recovery_threshold()
    );
    let responses: Vec<(usize, Vec<f64>)> = (0..pc.recovery_threshold())
        .map(|w| (w, pc.worker_compute(w, &ds.parts, &theta)))
        .collect();
    let t0 = Instant::now();
    let decoded = pc.decode(&responses);
    let pc_decode_us = t0.elapsed().as_micros();
    let mut err = decoded.clone();
    vec_axpy(&mut err, -1.0, &truth);
    println!(
        "  decode error ‖·‖₂/‖truth‖₂ = {:.2e}, decode wall time = {pc_decode_us} µs",
        norm2(&err) / norm2(&truth)
    );

    // ---- PCMM --------------------------------------------------------------
    let pcmm = PcmmScheme::new(n, r);
    println!(
        "PCMM(n = {n}, r = {r}): recovery threshold = {} evaluations",
        pcmm.recovery_threshold()
    );
    let mut responses = Vec::new();
    'outer: for j in 0..r {
        for i in 0..n {
            responses.push(((i, j), pcmm.worker_compute(i, j, &ds.parts, &theta)));
            if responses.len() == pcmm.recovery_threshold() {
                break 'outer;
            }
        }
    }
    let t0 = Instant::now();
    let decoded = pcmm.decode(&responses);
    let pcmm_decode_us = t0.elapsed().as_micros();
    let mut err = decoded.clone();
    vec_axpy(&mut err, -1.0, &truth);
    println!(
        "  decode error ‖·‖₂/‖truth‖₂ = {:.2e}, decode wall time = {pcmm_decode_us} µs",
        norm2(&err) / norm2(&truth)
    );

    // ---- timing comparison (the paper's metric, decode excluded) -----------
    let model = Ec2LikeModel::new(n, 9, 0.2);
    let trials = 30_000;
    let mut rng = Rng::seed_from_u64(5);
    let mut scratch = Vec::new();
    let (mut t_pc, mut t_pcmm) = (0.0, 0.0);
    for _ in 0..trials {
        let s = model.sample(n, r, &mut rng);
        t_pc += pc.completion_time(&s, &mut scratch);
        t_pcmm += pcmm.completion_time(&s, &mut scratch);
    }
    let mut table = Table::new(
        "average completion (ms), EC2-like delays — decode delay excluded per the paper",
        &["scheme", "t̄ (ms)", "decode (µs, measured, excluded)"],
    );
    table.push_row(vec![
        "PC".into(),
        Table::fmt(t_pc / trials as f64),
        pc_decode_us.to_string(),
    ]);
    table.push_row(vec![
        "PCMM".into(),
        Table::fmt(t_pcmm / trials as f64),
        pcmm_decode_us.to_string(),
    ]);
    table.print();
    println!("\nthe uncoded CS/SS path has zero decode cost — run `straggler fig5` for the full comparison.");
    Ok(())
}
