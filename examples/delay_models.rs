//! Tour of the delay substrate: sample every model, print moments and
//! ASCII histograms, and reproduce Fig. 3's headline observation —
//! communication delay dominates computation delay.
//!
//! ```bash
//! cargo run --release --example delay_models
//! ```

use straggler_sched::delay::{
    DelayModel, Ec2LikeModel, ShiftedExponential, TruncatedGaussianModel, WorkerCorrelated,
};
use straggler_sched::metrics::Histogram;
use straggler_sched::report::Table;
use straggler_sched::util::rng::Rng;
use straggler_sched::util::stats::RunningStats;

fn ascii_hist(samples: &[f64], bins: usize, width: usize) -> String {
    let lo = samples.iter().cloned().fold(f64::INFINITY, f64::min);
    let hi = samples.iter().cloned().fold(f64::NEG_INFINITY, f64::max) + 1e-9;
    let mut h = Histogram::new(lo, hi, bins);
    samples.iter().for_each(|&x| h.push(x));
    let max_count = (0..bins).map(|i| h.density(i)).fold(0.0, f64::max);
    let mut out = String::new();
    for i in 0..bins {
        let bar = ((h.density(i) / max_count) * width as f64) as usize;
        out.push_str(&format!(
            "  {:>7.3} ms |{}\n",
            h.center(i),
            "#".repeat(bar)
        ));
    }
    out
}

fn main() {
    let n = 3;
    let models: Vec<Box<dyn DelayModel>> = vec![
        Box::new(TruncatedGaussianModel::scenario1(n)),
        Box::new(TruncatedGaussianModel::scenario2(n, 5)),
        Box::new(ShiftedExponential::new(0.1, 8.0, 0.4, 2.0)),
        Box::new(Ec2LikeModel::new(n, 7, 0.25)),
        Box::new(WorkerCorrelated::new(
            ShiftedExponential::new(0.1, 8.0, 0.4, 2.0),
            0.6,
        )),
    ];

    let mut summary = Table::new(
        "delay models at a glance (worker 0, 20 000 draws)",
        &["model", "comp mean", "comp p95-ish", "comm mean", "comm/comp"],
    );

    for model in &models {
        let mut rng = Rng::seed_from_u64(11);
        let mut comp = RunningStats::new();
        let mut comm = RunningStats::new();
        let mut comp_samples = Vec::new();
        for _ in 0..20_000 {
            let s = model.sample(n, 1, &mut rng);
            comp.push(s.comp(0, 0));
            comm.push(s.comm(0, 0));
            comp_samples.push(s.comp(0, 0));
        }
        summary.push_row(vec![
            model.name(),
            Table::fmt(comp.mean()),
            Table::fmt(comp.mean() + 2.0 * comp.std_dev()),
            Table::fmt(comm.mean()),
            format!("{:.2}x", comm.mean() / comp.mean()),
        ]);
        if model.name().starts_with("ec2-like") {
            println!("EC2-like computation-delay histogram (worker 0) — right-skewed,");
            println!("matching the paper's Fig. 3 measurements:");
            print!("{}", ascii_hist(&comp_samples, 18, 50));
            println!();
        }
    }
    summary.print();
    println!("\nnote the comm/comp ratios ≫ 1 — the paper's Fig. 3 observation that");
    println!("communication, not computation, is the distributed-learning bottleneck.");
}
