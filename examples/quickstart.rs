//! Quickstart: build the paper's schedules, simulate a round, compare
//! schemes, and peek at the lower bound — in under a minute.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use straggler_sched::delay::{DelayModel, TruncatedGaussianModel};
use straggler_sched::harness::{evaluate, EvalPoint};
use straggler_sched::report::Table;
use straggler_sched::scheduler::{CyclicScheduler, Scheduler, StaircaseScheduler};
use straggler_sched::sim::simulate_round;
use straggler_sched::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let (n, r) = (4usize, 3usize);
    let mut rng = Rng::seed_from_u64(0);

    // 1. the paper's TO matrices (Examples 2 and 3, 1-based display)
    let cs = CyclicScheduler.schedule(n, r, &mut rng);
    let ss = StaircaseScheduler.schedule(n, r, &mut rng);
    println!("C_CS (n = {n}, r = {r}):\n{}", cs.to_paper_string());
    println!("C_SS (n = {n}, r = {r}):\n{}", ss.to_paper_string());

    // 2. one simulated round under the paper's scenario-1 delays
    let model = TruncatedGaussianModel::scenario1(n);
    let sample = model.sample(n, r, &mut rng);
    let round = simulate_round(&cs, &sample, n);
    println!(
        "one CS round, k = n = {n}: completed in {:.4} ms; arrival order of tasks: {:?}",
        round.completion_time,
        round.winners.iter().map(|t| t + 1).collect::<Vec<_>>()
    );

    // 3. average completion times across schemes, coupled delay stream
    let point = EvalPoint::new(8, 4, 8, 50_000, 7);
    let model8 = TruncatedGaussianModel::scenario1(8);
    let mut table = Table::new(
        "t̄ (ms): n = 8, r = 4, k = 8, scenario-1 truncated Gaussian",
        &["scheme", "mean", "p95"],
    );
    for e in evaluate(&point, &model8) {
        table.push_row(vec![e.scheme.clone(), Table::fmt(e.mean), Table::fmt(e.p95)]);
    }
    table.print();

    println!("\nnext: `straggler fig4` .. `fig7` regenerate the paper's figures;");
    println!("      `cargo run --release --example train_distributed` runs the full stack.");
    Ok(())
}
