//! End-to-end driver: distributed linear-regression DGD on the full
//! three-layer stack.
//!
//! * L1/L2 — the gram-matvec Pallas kernel inside the jax `task_gram`
//!   entry point, AOT-lowered to `artifacts/e2e__task_gram.hlo.txt`;
//! * runtime — each worker thread owns a PJRT CPU client executing that
//!   artifact (python is not running);
//! * L3 — the socketed master/worker coordinator with the paper's
//!   staircase schedule, EC2-like injected straggling, k-of-n stopping,
//!   and the eq. 61 master update.
//!
//! Trains a d = 512 model on N = 10 240 synthetic samples across
//! n = 10 workers for 300 rounds and logs the loss curve
//! (results/e2e_loss_curve.{csv,json}).
//!
//! ```bash
//! make artifacts && cargo run --release --example train_distributed
//! ```

use straggler_sched::harness::{run_e2e, E2eConfig, Options};

fn main() -> anyhow::Result<()> {
    let use_pjrt = straggler_sched::runtime::default_artifact_dir()
        .join("manifest.json")
        .exists();
    if !use_pjrt {
        eprintln!("artifacts/ not built — falling back to the CPU-oracle backend.");
        eprintln!("run `make artifacts` for the full PJRT path.\n");
    }
    let cfg = E2eConfig {
        use_pjrt,
        ..E2eConfig::default()
    };
    let (n, d, samples, rounds, k, r) = (cfg.n, cfg.d, cfg.n_samples, cfg.rounds, cfg.k, cfg.r);
    println!(
        "training: n = {n} workers, d = {d}, N = {samples}, r = {r}, k = {k}, {rounds} rounds\n"
    );
    let opts = Options::default();
    let (report, curve) = run_e2e(cfg, &opts)?;
    curve.print();
    println!(
        "\nmean round completion: {:.3} ms (p95 across rounds: {:.3} ms)",
        report.mean_completion_ms(),
        {
            let mut v: Vec<f64> = report.rounds.iter().map(|l| l.completion_ms).collect();
            v.sort_by(f64::total_cmp);
            straggler_sched::util::stats::quantile_sorted(&v, 0.95)
        }
    );
    println!("final loss: {:.6}", report.final_loss);

    // convergence sanity: loss at round 10·x must trend down
    let losses: Vec<f64> = report.rounds.iter().filter_map(|l| l.loss).collect();
    anyhow::ensure!(
        losses.last().unwrap() < &(0.5 * losses[0]),
        "training failed to reduce loss"
    );
    println!("convergence check passed: {:.4} → {:.4}", losses[0], losses.last().unwrap());
    Ok(())
}
