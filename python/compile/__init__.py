"""Build-time compile package: L2 jax model + L1 pallas kernels + AOT.

Never imported at runtime — ``make artifacts`` runs ``compile.aot`` once
and the rust binary consumes only ``artifacts/*.hlo.txt`` afterwards.
"""
