"""AOT pipeline: lower every L2 entry point to HLO-text artifacts.

Runs exactly once at build time (``make artifacts``):

    cd python && python -m compile.aot --out ../artifacts

For each (experiment profile × entry point) this lowers the jitted jax
function — Pallas kernels included, in interpret mode — to StableHLO,
converts to an XlaComputation and dumps **HLO text**.  Text, not
``.serialize()``: jax ≥ 0.5 emits HloModuleProto with 64-bit instruction
ids which the xla_extension 0.5.1 under the rust ``xla`` crate rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

A ``manifest.json`` records, for every artifact, the entry-point name,
profile, dims, argument shapes and output shape — the rust runtime
(rust/src/runtime/artifacts.rs) is manifest-driven and never hard-codes
shapes.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import sys

import jax
from jax._src.lib import xla_client as xc

from . import model
from .kernels import gram_matvec as _gm

# Interpret-mode Pallas pays a full-array slice copy per grid step on the
# CPU backend, so AOT artifacts use monolithic blocks (grid = 1) unless
# overridden — a 4.3x kernel speedup at the e2e shape with identical
# numerics (EXPERIMENTS.md §Perf).  Real-TPU lowering would restore the
# 128-wide MXU tiling that the pytest suite keeps exercising.  Applied
# only inside build() so importing this module never perturbs the
# kernels' default (the pytest suite relies on 128).
AOT_BLOCK = int(os.environ.get("STRAGGLER_AOT_BLOCK", "1024"))

# ---------------------------------------------------------------------------
# Experiment profiles.  dims = {d: features, b: samples per partition,
# n: partitions, m: coded matrices produced per encode call}.
#
# Profiles mirror the paper's evaluation points (DESIGN.md §4) plus a small
# quickstart profile for examples/tests.  ``m = 2n`` covers PC/PCMM with
# computation load r = 2 (their minimum); larger r encodes in several calls.
# ---------------------------------------------------------------------------

PROFILES: dict[str, dict[str, int]] = {
    # tiny shapes for unit/integration tests and examples/quickstart.rs
    "quickstart": {"d": 64, "b": 32, "n": 4, "m": 8},
    # Fig. 3 cluster profile: N=900, d=500, n=3
    "fig3": {"d": 500, "b": 300, "n": 3, "m": 6},
    # Fig. 5 cluster profile: N=900, d=400, n=15
    "fig5": {"d": 400, "b": 60, "n": 15, "m": 30},
    # Fig. 7 profile: N=1000, d=800, n=10
    "fig7": {"d": 800, "b": 100, "n": 10, "m": 20},
    # end-to-end training example: N=10240, d=512, n=10
    "e2e": {"d": 512, "b": 1024, "n": 10, "m": 20},
}


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (the interchange format)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_entry(name: str, dims: dict[str, int]) -> tuple[str, list[list[int]]]:
    """Lower one entry point at concrete dims; return (hlo_text, arg shapes)."""
    fn, arg_templates = model.ENTRY_POINTS[name]
    args = model.example_args(arg_templates, dims)
    lowered = jax.jit(fn).lower(*args)
    return to_hlo_text(lowered), [list(a.shape) for a in args]


def build(out_dir: str, profiles: list[str], verbose: bool = True) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    prev_block = _gm.DEFAULT_BLOCK
    _gm.DEFAULT_BLOCK = AOT_BLOCK
    try:
        return _build_inner(out_dir, profiles, verbose)
    finally:
        _gm.DEFAULT_BLOCK = prev_block


def _build_inner(out_dir: str, profiles: list[str], verbose: bool) -> dict:
    manifest: dict = {"format": "hlo-text/v1", "artifacts": {}}
    for prof in profiles:
        dims = PROFILES[prof]
        for entry, (_, arg_templates) in model.ENTRY_POINTS.items():
            key = f"{prof}/{entry}"
            fname = f"{prof}__{entry}.hlo.txt"
            path = os.path.join(out_dir, fname)
            text, arg_shapes = lower_entry(entry, dims)
            with open(path, "w") as f:
                f.write(text)
            digest = hashlib.sha256(text.encode()).hexdigest()[:16]
            manifest["artifacts"][key] = {
                "file": fname,
                "entry": entry,
                "profile": prof,
                "dims": dims,
                "arg_shapes": arg_shapes,
                "arg_names": [t.split(":", 1)[0] for t in arg_templates],
                "dtype": "f32",
                "sha256_16": digest,
            }
            if verbose:
                print(f"  wrote {fname:44s} ({len(text):>8d} chars)")
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    if verbose:
        print(f"manifest: {len(manifest['artifacts'])} artifacts -> {out_dir}/manifest.json")
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifact output directory")
    ap.add_argument(
        "--profiles",
        default=",".join(PROFILES),
        help=f"comma-separated subset of {list(PROFILES)}",
    )
    args = ap.parse_args()
    profiles = [p for p in args.profiles.split(",") if p]
    unknown = [p for p in profiles if p not in PROFILES]
    if unknown:
        sys.exit(f"unknown profiles: {unknown}")
    build(args.out, profiles)


if __name__ == "__main__":
    main()
