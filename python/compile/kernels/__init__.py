"""L1 Pallas kernels (build-time only; lowered into HLO artifacts).

``ref`` holds the pure-jnp oracles; ``gram_matvec`` and ``partial_grad``
hold the tiled Pallas implementations the L2 model calls.
"""

from . import gram_matvec, partial_grad, ref  # noqa: F401
