"""L1 Pallas kernels: tiled gram matrix–vector product  h(X) = X Xᵀ θ.

This is the compute hot-spot of every scheme in the paper: each task a
worker executes — uncoded (CS/SS/RA) on a raw partition, or coded
(PC/PCMM) on an encoded partition — is exactly one gram mat-vec over a
``(d, b)`` matrix (paper eq. 50, Table I).

TPU-shaped structure (DESIGN.md §Hardware-Adaptation):

* pass 1 ``u = Xᵀ θ``: grid over column tiles, each program pulls an
  ``(d, bb)`` block of ``X`` HBM→VMEM plus the full ``θ`` and issues one
  MXU-friendly ``(bb, d) @ (d,)`` contraction;
* pass 2 ``v = X u``: grid over row tiles, ``(dd, b)`` blocks against the
  full ``u``.

Both passes keep the VMEM working set to one block + one vector
(≤ a few hundred KiB for the paper's shapes, see DESIGN.md §7) instead of
the whole ``X``.  Block sizes are chosen as the largest divisor of the
dimension ≤ a target (default 128 = MXU lane width) so that arbitrary
hypothesis-generated shapes run without padding logic; the AOT shapes
used by the rust runtime are multiples of 8 and get full-width tiles.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, so interpret mode is both the correctness path and what the
AOT pipeline lowers into the HLO artifacts (see /opt/xla-example/README).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile target: one MXU tile edge.  Real TPU lowering would want
# (128, 128) f32 / (256, 256) bf16 blocks; interpret mode just needs the
# same structure.  Mutable module global read at call time: the AOT
# pipeline raises it (STRAGGLER_AOT_BLOCK, default 1024) because
# interpret-mode grids pay a full-array slice copy per step on CPU —
# 4.3x on the e2e task shape (EXPERIMENTS.md §Perf) — while the pytest
# suite keeps 128 so the tiled BlockSpec schedule stays exercised.
DEFAULT_BLOCK = 128

# interpret=True is mandatory on this image (CPU PJRT backend).  Kept as a
# module switch so a TPU build only has to flip one constant.
INTERPRET = True


def pick_block(dim: int, target: int | None = None) -> int:
    """Largest divisor of ``dim`` that is ≤ ``target``.

    ``target=None`` reads the module's ``DEFAULT_BLOCK`` at call time so
    the AOT pipeline can widen tiles globally.  Guarantees the grid
    tiles the array exactly, so kernels never read out-of-bounds garbage
    for ragged shapes (hypothesis feeds primes).
    """
    if target is None:
        target = DEFAULT_BLOCK
    if dim <= 0:
        raise ValueError(f"dimension must be positive, got {dim}")
    best = 1
    d = 1
    while d * d <= dim:
        if dim % d == 0:
            for c in (d, dim // d):
                if c <= target and c > best:
                    best = c
        d += 1
    return best


def _matvec_t_kernel(x_ref, theta_ref, o_ref):
    """One column tile of  u = Xᵀ θ:  o[bb] = x[d, bb]ᵀ @ theta[d]."""
    # Contract over the full d axis held in VMEM; the (bb, d) x (d,)
    # product maps onto the MXU as a thin matmul.
    o_ref[...] = x_ref[...].T @ theta_ref[...]


def _matvec_kernel(x_ref, u_ref, o_ref):
    """One row tile of  v = X u:  o[dd] = x[dd, b] @ u[b]."""
    o_ref[...] = x_ref[...] @ u_ref[...]


@functools.partial(jax.jit, static_argnames=("block",))
def matvec_t(x: jnp.ndarray, theta: jnp.ndarray, *, block: int | None = None) -> jnp.ndarray:
    """u = Xᵀ θ via Pallas.  x: (d, b), theta: (d,) → (b,)."""
    d, b = x.shape
    bb = pick_block(b) if block is None else block
    grid = (b // bb,)
    return pl.pallas_call(
        _matvec_t_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((d, bb), lambda i: (0, i)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bb,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((b,), x.dtype),
        interpret=INTERPRET,
    )(x, theta)


@functools.partial(jax.jit, static_argnames=("block",))
def matvec(x: jnp.ndarray, u: jnp.ndarray, *, block: int | None = None) -> jnp.ndarray:
    """v = X u via Pallas.  x: (d, b), u: (b,) → (d,)."""
    d, b = x.shape
    dd = pick_block(d) if block is None else block
    grid = (d // dd,)
    return pl.pallas_call(
        _matvec_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((dd, b), lambda i: (i, 0)),
            pl.BlockSpec((b,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((dd,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((d,), x.dtype),
        interpret=INTERPRET,
    )(x, u)


def gram_matvec(x: jnp.ndarray, theta: jnp.ndarray) -> jnp.ndarray:
    """h(X) = X (Xᵀ θ)  — paper eq. 50, two tiled passes.

    The intermediate ``u`` stays a device value between the two
    pallas_calls, so the whole thing lowers into a single HLO module and
    XLA schedules the two passes back to back with no host round-trip.
    """
    return matvec(x, matvec_t(x, theta))
