"""L1 Pallas kernel: fused per-partition gradient  g = X Xᵀ θ − b.

The fused form saves one HBM round-trip of the ``(d,)`` intermediate
versus composing ``gram_matvec`` with a separate subtraction: the second
pass consumes ``u = Xᵀ θ`` and the precomputed ``b = X y`` tile in the
same program and writes the already-subtracted result.

Used by the ``task_grad`` L2 entry point (the uncoded worker task when
the master wants finished gradient terms rather than raw ``h(X_i)``).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .gram_matvec import INTERPRET, matvec_t, pick_block


def _fused_grad_kernel(x_ref, u_ref, b_ref, o_ref):
    """One row tile:  o[dd] = x[dd, b] @ u[b] − b_vec[dd]."""
    o_ref[...] = x_ref[...] @ u_ref[...] - b_ref[...]


@functools.partial(jax.jit, static_argnames=("block",))
def matvec_sub(
    x: jnp.ndarray, u: jnp.ndarray, b_vec: jnp.ndarray, *, block: int | None = None
) -> jnp.ndarray:
    """v = X u − b_vec via Pallas.  x: (d, b), u: (b,), b_vec: (d,) → (d,)."""
    d, b = x.shape
    dd = pick_block(d) if block is None else block
    grid = (d // dd,)
    return pl.pallas_call(
        _fused_grad_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((dd, b), lambda i: (i, 0)),
            pl.BlockSpec((b,), lambda i: (0,)),
            pl.BlockSpec((dd,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((dd,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((d,), x.dtype),
        interpret=INTERPRET,
    )(x, u, b_vec)


def partial_grad(x: jnp.ndarray, b_vec: jnp.ndarray, theta: jnp.ndarray) -> jnp.ndarray:
    """g = X Xᵀ θ − b_vec  (paper §VI-A, the summand of eq. 48)."""
    return matvec_sub(x, matvec_t(x, theta), b_vec)
