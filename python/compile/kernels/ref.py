"""Pure-jnp oracles for the Pallas kernels (L1 correctness reference).

Every Pallas kernel in this package has an exact, un-tiled counterpart
here.  pytest (python/tests/test_kernels.py) asserts allclose between the
kernel (interpret=True) and these functions across a hypothesis sweep of
shapes and dtypes; the rust integration tests re-check the same numbers
through the AOT artifacts, so the chain

    ref.py  ==  pallas kernel  ==  HLO artifact  ==  rust runtime output

is closed end to end.

Shape conventions follow the paper (§VI-A): a partition ("mini-batch
task") is ``X_i ∈ R^{d×b}`` with ``b = N/n`` samples as *columns*, the
model is ``theta ∈ R^d``, and the per-task computation is the gram
matrix–vector product

    h(X_i) = X_i X_iᵀ theta            (paper eq. 50)

which every scheme (CS, SS, RA, PC, PCMM) executes per task.
"""

from __future__ import annotations

import jax.numpy as jnp


def matvec_t(x: jnp.ndarray, theta: jnp.ndarray) -> jnp.ndarray:
    """u = Xᵀ theta  — first pass of the gram mat-vec.

    x: (d, b), theta: (d,)  →  (b,)
    """
    return x.T @ theta


def matvec(x: jnp.ndarray, u: jnp.ndarray) -> jnp.ndarray:
    """v = X u  — second pass of the gram mat-vec.

    x: (d, b), u: (b,)  →  (d,)
    """
    return x @ u


def gram_matvec(x: jnp.ndarray, theta: jnp.ndarray) -> jnp.ndarray:
    """h(X) = X Xᵀ theta  (paper eq. 50).  x: (d, b), theta: (d,) → (d,)."""
    return x @ (x.T @ theta)


def partial_grad(x: jnp.ndarray, b_vec: jnp.ndarray, theta: jnp.ndarray) -> jnp.ndarray:
    """Per-partition gradient term  g_i = X_i X_iᵀ theta − X_i y_i.

    ``b_vec`` is the precomputed ``X_i y_i`` (constant across iterations,
    paper §VI-A).  x: (d, b), b_vec: (d,), theta: (d,) → (d,).
    """
    return gram_matvec(x, theta) - b_vec


def xy_vec(x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """b_i = X_i y_i.  x: (d, b), y: (b,) → (d,)."""
    return x @ y


def loss(x_parts: jnp.ndarray, y_parts: jnp.ndarray, theta: jnp.ndarray) -> jnp.ndarray:
    """F(theta) = 1/N ‖Xθ − y‖²  (paper eq. 47).

    x_parts: (n, d, b) stacked partitions, y_parts: (n, b) → scalar.
    """
    n, d, b = x_parts.shape
    preds = jnp.einsum("ndb,d->nb", x_parts, theta)
    resid = preds - y_parts
    return jnp.sum(resid * resid) / (n * b)


def encode_parts(x_parts: jnp.ndarray, coeffs: jnp.ndarray) -> jnp.ndarray:
    """Coded-matrix construction for PC/PCMM (paper eqs. 53, 58).

    x_parts: (n, d, b), coeffs: (m, n)  →  (m, d, b) where
    out[j] = Σ_i coeffs[j, i] · x_parts[i].
    """
    return jnp.einsum("mi,idb->mdb", coeffs, x_parts)


def master_update(theta: jnp.ndarray, agg: jnp.ndarray, eta_eff: jnp.ndarray) -> jnp.ndarray:
    """θ_{l+1} = θ_l − η_eff · agg   (paper eqs. 49/61/62 with the
    scheme-specific scale folded into ``eta_eff``)."""
    return theta - eta_eff * agg
