"""L2 JAX model: the distributed linear-regression DGD computation graph.

Paper §VI-A: minimize  F(θ) = 1/N ‖Xθ − y‖²  by distributed gradient
descent.  The dataset is split into n partitions X_i ∈ R^{d×b} (b = N/n);
the per-task worker computation is  h(X_i) = X_i X_iᵀ θ  (eq. 50) and the
master update with computation target k is

    θ_{l+1} = θ_l − η·(2n/(kN)) Σ_{i=1}^{k} (h(X_{p_i}) − X_{p_i} y_{p_i})   (eq. 61)

Every public function here is a *pure* jax function over fixed shapes —
``aot.py`` lowers each one to an HLO-text artifact that the rust runtime
(rust/src/runtime/) loads and executes on the request path.  The gram
mat-vec hot-spot is the L1 Pallas kernel, so it lowers into the same HLO.

Entry points (shapes with d = features, b = samples/partition, n = parts):

    task_gram      (d,b),(d,)            → (d,)     worker task, eq. 50
    task_grad      (d,b),(d,),(d,)       → (d,)     fused h(X_i) − X_i y_i
    xy_vec         (d,b),(b,)            → (d,)     setup-time X_i y_i
    master_update  (d,),(d,),()          → (d,)     eqs. 49/61
    loss           (n,d,b),(n,b),(d,)    → ()       eq. 47
    encode_parts   (n,d,b),(m,n)         → (m,d,b)  PC/PCMM coded matrices
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import gram_matvec as gm
from .kernels import partial_grad as pg


def task_gram(x: jnp.ndarray, theta: jnp.ndarray) -> tuple[jnp.ndarray]:
    """Worker task: h(X_i) = X_i X_iᵀ θ (eq. 50), via the L1 kernel."""
    return (gm.gram_matvec(x, theta),)


def task_grad(x: jnp.ndarray, b_vec: jnp.ndarray, theta: jnp.ndarray) -> tuple[jnp.ndarray]:
    """Worker task, fused gradient form: h(X_i) − X_i y_i."""
    return (pg.partial_grad(x, b_vec, theta),)


def xy_vec(x: jnp.ndarray, y: jnp.ndarray) -> tuple[jnp.ndarray]:
    """Setup-time constant b_i = X_i y_i (computed once by the master)."""
    return (x @ y,)


def master_update(
    theta: jnp.ndarray, agg: jnp.ndarray, eta_eff: jnp.ndarray
) -> tuple[jnp.ndarray]:
    """θ_{l+1} = θ_l − η_eff · agg.

    ``agg`` is Σ (h(X_{p_i}) − X_{p_i} y_{p_i}) over the k received
    distinct tasks; ``eta_eff = η·2n/(kN)`` folds the eq.-61 scale (or
    η·2/N for the coded schemes' eq. 49 — the rust master picks).
    """
    return (theta - eta_eff * agg,)


def loss(x_parts: jnp.ndarray, y_parts: jnp.ndarray, theta: jnp.ndarray) -> tuple[jnp.ndarray]:
    """F(θ) = 1/N ‖Xθ − y‖² over stacked partitions (eq. 47)."""
    n, d, b = x_parts.shape
    preds = jnp.einsum("ndb,d->nb", x_parts, theta)
    resid = preds - y_parts
    return (jnp.sum(resid * resid) / (n * b),)


def encode_parts(x_parts: jnp.ndarray, coeffs: jnp.ndarray) -> tuple[jnp.ndarray]:
    """Coded matrices for PC/PCMM:  out[j] = Σ_i coeffs[j,i]·X_i.

    PC eq. 53 uses structured integer coefficients; PCMM eq. 58 uses
    Lagrange-basis evaluations.  Both are just this einsum — the rust
    ``coded`` module supplies the coefficient matrix.
    """
    return (jnp.einsum("mi,idb->mdb", coeffs, x_parts),)


def grad_autodiff(x_parts: jnp.ndarray, y_parts: jnp.ndarray, theta: jnp.ndarray) -> jnp.ndarray:
    """Full-dataset ∇F(θ) via jax autodiff — test oracle only (eq. 48).

    Not AOT-exported; used by python/tests/test_model.py to confirm that
    summing the n task_grad outputs (scaled 2/N) equals the true gradient.
    """
    return jax.grad(lambda t: loss(x_parts, y_parts, t)[0])(theta)


# ---------------------------------------------------------------------------
# Entry-point registry used by aot.py.  Each spec maps argument names to
# shape templates in terms of (d, b, n, m); dtype is f32 throughout (the
# paper's EC2 experiments are float32 numpy).
# ---------------------------------------------------------------------------

ENTRY_POINTS = {
    "task_gram": (task_gram, ("x:d,b", "theta:d")),
    "task_grad": (task_grad, ("x:d,b", "b_vec:d", "theta:d")),
    "xy_vec": (xy_vec, ("x:d,b", "y:b")),
    "master_update": (master_update, ("theta:d", "agg:d", "eta_eff:")),
    "loss": (loss, ("x_parts:n,d,b", "y_parts:n,b", "theta:d")),
    "encode_parts": (encode_parts, ("x_parts:n,d,b", "coeffs:m,n")),
}


def shape_of(template: str, dims: dict[str, int]) -> tuple[int, ...]:
    """Resolve a template like ``"n,d,b"`` against concrete dims."""
    template = template.split(":", 1)[1] if ":" in template else template
    if not template:
        return ()
    return tuple(dims[axis] for axis in template.split(","))


def example_args(names: tuple[str, ...], dims: dict[str, int]) -> list[jax.ShapeDtypeStruct]:
    """ShapeDtypeStructs for an entry point at concrete dims."""
    return [
        jax.ShapeDtypeStruct(shape_of(t, dims), jnp.float32) for t in names
    ]
