import jax

# Deterministic CPU-only test environment; the whole AOT path targets the
# CPU PJRT backend (interpret-mode Pallas), so tests must match.
jax.config.update("jax_platform_name", "cpu")
jax.config.update("jax_enable_x64", False)
