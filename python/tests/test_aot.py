"""AOT pipeline: HLO-text artifacts + manifest are well-formed."""

from __future__ import annotations

import json
import os
import tempfile

import pytest

from compile import aot, model


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("artifacts"))
    manifest = aot.build(out, ["quickstart"], verbose=False)
    return out, manifest


class TestBuild:
    def test_all_entries_emitted(self, built):
        out, manifest = built
        assert set(manifest["artifacts"]) == {
            f"quickstart/{e}" for e in model.ENTRY_POINTS
        }

    def test_files_exist_and_are_hlo_text(self, built):
        out, manifest = built
        for meta in manifest["artifacts"].values():
            path = os.path.join(out, meta["file"])
            text = open(path).read()
            assert "HloModule" in text, meta["file"]
            assert "ENTRY" in text, meta["file"]

    def test_manifest_roundtrips_from_disk(self, built):
        out, manifest = built
        on_disk = json.load(open(os.path.join(out, "manifest.json")))
        assert on_disk == manifest
        assert on_disk["format"] == "hlo-text/v1"

    def test_arg_shapes_match_profile_dims(self, built):
        _, manifest = built
        dims = aot.PROFILES["quickstart"]
        meta = manifest["artifacts"]["quickstart/task_gram"]
        assert meta["arg_shapes"] == [[dims["d"], dims["b"]], [dims["d"]]]
        meta = manifest["artifacts"]["quickstart/master_update"]
        assert meta["arg_shapes"] == [[dims["d"]], [dims["d"]], []]

    def test_parameter_count_in_hlo(self, built):
        out, manifest = built
        meta = manifest["artifacts"]["quickstart/task_grad"]
        text = open(os.path.join(out, meta["file"])).read()
        # ENTRY computation must declare 3 parameters
        entry = text[text.index("ENTRY"):]
        first_line = entry.splitlines()[0]
        assert first_line.count("parameter") == 0  # params are in body
        assert "parameter(2)" in entry

    def test_deterministic_output(self, built):
        out, manifest = built
        text1, _ = aot.lower_entry("task_gram", aot.PROFILES["quickstart"])
        text2, _ = aot.lower_entry("task_gram", aot.PROFILES["quickstart"])
        assert text1 == text2


class TestProfiles:
    def test_profiles_cover_paper_experiments(self):
        assert {"fig3", "fig5", "fig7", "e2e", "quickstart"} <= set(aot.PROFILES)

    def test_profile_dims_match_paper(self):
        # Fig. 3: N=900, d=500, n=3  →  b = 300
        assert aot.PROFILES["fig3"] == {"d": 500, "b": 300, "n": 3, "m": 6}
        # Fig. 5: N=900, d=400, n=15  →  b = 60
        p5 = aot.PROFILES["fig5"]
        assert p5["d"] == 400 and p5["b"] * p5["n"] == 900
        # Fig. 7: N=1000, d=800, n=10 →  b = 100
        p7 = aot.PROFILES["fig7"]
        assert p7["d"] == 800 and p7["b"] * p7["n"] == 1000

    def test_unknown_profile_rejected(self):
        with pytest.raises(KeyError):
            aot.lower_entry("task_gram", aot.PROFILES["nope"])
