"""L1 correctness: Pallas kernels (interpret=True) vs pure-jnp oracles.

Hypothesis sweeps the shape/dtype space — including ragged primes that
force block size 1 — and asserts allclose against ref.py.  This is the
core correctness signal for the compute hot-spot; the rust integration
tests re-verify the same numerics through the AOT artifacts.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import gram_matvec as gm
from compile.kernels import partial_grad as pg
from compile.kernels import ref

DIMS = st.integers(min_value=1, max_value=97)


def rand(shape, dtype=np.float32, seed=0):
    rng = np.random.default_rng(seed + sum(shape))
    return rng.standard_normal(shape).astype(dtype)


def tol(dtype):
    # bf16 matmuls accumulate in f32 but round outputs; loosen accordingly.
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# pick_block
# ---------------------------------------------------------------------------


class TestPickBlock:
    @given(dim=st.integers(1, 4096), target=st.integers(1, 256))
    @settings(max_examples=200, deadline=None)
    def test_divides_and_bounded(self, dim, target):
        b = gm.pick_block(dim, target)
        assert dim % b == 0
        assert 1 <= b <= target

    @given(dim=st.integers(1, 1024))
    @settings(max_examples=100, deadline=None)
    def test_maximal(self, dim):
        b = gm.pick_block(dim, 128)
        # no larger divisor of dim fits under the target
        for cand in range(b + 1, min(dim, 128) + 1):
            assert dim % cand != 0

    def test_rejects_nonpositive(self):
        with pytest.raises(ValueError):
            gm.pick_block(0)

    def test_explicit_target(self):
        assert gm.pick_block(512, 1024) == 512
        assert gm.pick_block(2048, 1024) == 1024

    def test_exact_power_of_two(self):
        assert gm.pick_block(512) == 128
        assert gm.pick_block(128) == 128
        assert gm.pick_block(100) == 100
        assert gm.pick_block(300) == 100
        assert gm.pick_block(97) == 97  # prime but under target: whole dim
        assert gm.pick_block(131) == 1  # prime above target: degenerate tile


# ---------------------------------------------------------------------------
# matvec_t / matvec / gram_matvec vs oracle
# ---------------------------------------------------------------------------


class TestMatvecT:
    @given(d=DIMS, b=DIMS)
    @settings(max_examples=40, deadline=None)
    def test_matches_ref(self, d, b):
        x, theta = rand((d, b)), rand((d,), seed=1)
        got = gm.matvec_t(jnp.asarray(x), jnp.asarray(theta))
        np.testing.assert_allclose(got, ref.matvec_t(x, theta), **tol(jnp.float32))

    def test_explicit_small(self):
        x = np.array([[1.0, 2.0], [3.0, 4.0]], np.float32)
        theta = np.array([1.0, -1.0], np.float32)
        np.testing.assert_allclose(gm.matvec_t(x, theta), [-2.0, -2.0])

    def test_forced_block_one(self):
        x, theta = rand((13, 7)), rand((13,), seed=2)
        got = gm.matvec_t(jnp.asarray(x), jnp.asarray(theta), block=1)
        np.testing.assert_allclose(got, ref.matvec_t(x, theta), **tol(jnp.float32))


class TestMatvec:
    @given(d=DIMS, b=DIMS)
    @settings(max_examples=40, deadline=None)
    def test_matches_ref(self, d, b):
        x, u = rand((d, b)), rand((b,), seed=3)
        got = gm.matvec(jnp.asarray(x), jnp.asarray(u))
        np.testing.assert_allclose(got, ref.matvec(x, u), **tol(jnp.float32))

    def test_identity(self):
        x = np.eye(5, dtype=np.float32)
        u = rand((5,), seed=4)
        np.testing.assert_allclose(gm.matvec(x, u), u, rtol=1e-6)


class TestGramMatvec:
    @given(d=DIMS, b=DIMS)
    @settings(max_examples=40, deadline=None)
    def test_matches_ref(self, d, b):
        x, theta = rand((d, b)), rand((d,), seed=5)
        got = gm.gram_matvec(jnp.asarray(x), jnp.asarray(theta))
        np.testing.assert_allclose(got, ref.gram_matvec(x, theta), rtol=1e-3, atol=1e-3)

    def test_psd_quadratic_form(self):
        # θᵀ (X Xᵀ θ) = ‖Xᵀθ‖² ≥ 0 — gram operator is PSD.
        x, theta = rand((40, 23), seed=6), rand((40,), seed=7)
        h = np.asarray(gm.gram_matvec(jnp.asarray(x), jnp.asarray(theta)))
        assert float(theta @ h) >= -1e-4

    def test_paper_shapes(self):
        # Fig. 7 profile: d=800, b=100 — the largest AOT shape.
        x, theta = rand((800, 100), seed=8), rand((800,), seed=9)
        got = gm.gram_matvec(jnp.asarray(x), jnp.asarray(theta))
        np.testing.assert_allclose(got, ref.gram_matvec(x, theta), rtol=5e-3, atol=5e-3)

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_dtypes(self, dtype):
        x = jnp.asarray(rand((32, 16), seed=10), dtype=dtype)
        theta = jnp.asarray(rand((32,), seed=11), dtype=dtype)
        got = gm.gram_matvec(x, theta)
        assert got.dtype == dtype
        want = ref.gram_matvec(x.astype(jnp.float32), theta.astype(jnp.float32))
        np.testing.assert_allclose(got.astype(jnp.float32), want, **tol(dtype))


class TestPartialGrad:
    @given(d=DIMS, b=DIMS)
    @settings(max_examples=40, deadline=None)
    def test_matches_ref(self, d, b):
        x, bv, theta = rand((d, b)), rand((d,), seed=12), rand((d,), seed=13)
        got = pg.partial_grad(jnp.asarray(x), jnp.asarray(bv), jnp.asarray(theta))
        np.testing.assert_allclose(got, ref.partial_grad(x, bv, theta), rtol=1e-3, atol=1e-3)

    def test_zero_b_equals_gram(self):
        x, theta = rand((24, 9), seed=14), rand((24,), seed=15)
        g = pg.partial_grad(jnp.asarray(x), jnp.zeros(24, jnp.float32), jnp.asarray(theta))
        h = gm.gram_matvec(jnp.asarray(x), jnp.asarray(theta))
        np.testing.assert_allclose(g, h, rtol=1e-5, atol=1e-5)

    def test_at_optimum_gradient_vanishes(self):
        # If y = Xᵀθ* exactly, then b = X y = X Xᵀ θ* and g(θ*) = 0.
        x = rand((16, 12), seed=16)
        theta_star = rand((16,), seed=17)
        y = x.T @ theta_star
        bv = x @ y
        g = pg.partial_grad(jnp.asarray(x), jnp.asarray(bv), jnp.asarray(theta_star))
        np.testing.assert_allclose(g, np.zeros(16), atol=1e-3)
