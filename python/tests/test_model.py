"""L2 correctness: model entry points vs oracles and jax autodiff."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile import model
from compile.kernels import ref

SMALL = st.integers(min_value=1, max_value=24)


def rand(shape, seed=0):
    rng = np.random.default_rng(seed + 13 * sum(shape))
    return rng.standard_normal(shape).astype(np.float32)


class TestTaskEntryPoints:
    @given(d=SMALL, b=SMALL)
    @settings(max_examples=25, deadline=None)
    def test_task_gram(self, d, b):
        x, theta = rand((d, b)), rand((d,), seed=1)
        (got,) = model.task_gram(jnp.asarray(x), jnp.asarray(theta))
        np.testing.assert_allclose(got, ref.gram_matvec(x, theta), rtol=1e-3, atol=1e-3)

    @given(d=SMALL, b=SMALL)
    @settings(max_examples=25, deadline=None)
    def test_task_grad(self, d, b):
        x, bv, theta = rand((d, b)), rand((d,), seed=2), rand((d,), seed=3)
        (got,) = model.task_grad(jnp.asarray(x), jnp.asarray(bv), jnp.asarray(theta))
        np.testing.assert_allclose(got, ref.partial_grad(x, bv, theta), rtol=1e-3, atol=1e-3)

    def test_xy_vec(self):
        x, y = rand((10, 6)), rand((6,), seed=4)
        (got,) = model.xy_vec(jnp.asarray(x), jnp.asarray(y))
        np.testing.assert_allclose(got, x @ y, rtol=1e-5, atol=1e-5)

    def test_master_update(self):
        theta, agg = rand((8,)), rand((8,), seed=5)
        (got,) = model.master_update(jnp.asarray(theta), jnp.asarray(agg), jnp.float32(0.25))
        np.testing.assert_allclose(got, theta - 0.25 * agg, rtol=1e-6)


class TestGradientConsistency:
    """Summed task gradients must equal the true ∇F — eq. 48 vs autodiff."""

    @given(n=st.integers(2, 6), d=SMALL, b=SMALL)
    @settings(max_examples=15, deadline=None)
    def test_sum_of_task_grads_is_full_gradient(self, n, d, b):
        xs = rand((n, d, b), seed=6)
        ys = rand((n, b), seed=7)
        theta = rand((d,), seed=8)
        total = np.zeros(d, np.float32)
        for i in range(n):
            bv = xs[i] @ ys[i]
            (g,) = model.task_grad(jnp.asarray(xs[i]), jnp.asarray(bv), jnp.asarray(theta))
            total += np.asarray(g)
        # eq. 48: ∇F = 2/N Σ (X_i X_iᵀ θ − X_i y_i),  N = n·b
        want = model.grad_autodiff(jnp.asarray(xs), jnp.asarray(ys), jnp.asarray(theta))
        np.testing.assert_allclose(2.0 / (n * b) * total, want, rtol=2e-3, atol=2e-3)

    def test_gd_step_reduces_loss(self):
        xs, ys = rand((4, 12, 8), seed=9), rand((4, 8), seed=10)
        theta = rand((12,), seed=11)
        (l0,) = model.loss(jnp.asarray(xs), jnp.asarray(ys), jnp.asarray(theta))
        g = model.grad_autodiff(jnp.asarray(xs), jnp.asarray(ys), jnp.asarray(theta))
        (theta1,) = model.master_update(jnp.asarray(theta), g, jnp.float32(0.01))
        (l1,) = model.loss(jnp.asarray(xs), jnp.asarray(ys), theta1)
        assert float(l1) < float(l0)


class TestLoss:
    def test_zero_at_perfect_fit(self):
        xs = rand((3, 6, 5), seed=12)
        theta = rand((6,), seed=13)
        ys = np.einsum("ndb,d->nb", xs, theta)
        (val,) = model.loss(jnp.asarray(xs), jnp.asarray(ys), jnp.asarray(theta))
        assert float(val) < 1e-8

    def test_matches_flat_formula(self):
        xs, ys = rand((3, 6, 5), seed=14), rand((3, 5), seed=15)
        theta = rand((6,), seed=16)
        (val,) = model.loss(jnp.asarray(xs), jnp.asarray(ys), jnp.asarray(theta))
        # flatten to the paper's X ∈ R^{N×d} convention: rows are samples
        xflat = np.concatenate([xs[i].T for i in range(3)], axis=0)
        yflat = np.concatenate([ys[i] for i in range(3)])
        want = np.sum((xflat @ theta - yflat) ** 2) / len(yflat)
        np.testing.assert_allclose(float(val), want, rtol=1e-4)


class TestEncodeParts:
    @given(n=st.integers(1, 5), m=st.integers(1, 7), d=SMALL, b=SMALL)
    @settings(max_examples=20, deadline=None)
    def test_matches_einsum(self, n, m, d, b):
        xs, coeffs = rand((n, d, b), seed=17), rand((m, n), seed=18)
        (got,) = model.encode_parts(jnp.asarray(xs), jnp.asarray(coeffs))
        np.testing.assert_allclose(
            got, ref.encode_parts(xs, coeffs), rtol=1e-4, atol=1e-4
        )

    def test_identity_coeffs_recover_parts(self):
        xs = rand((4, 5, 3), seed=19)
        (got,) = model.encode_parts(jnp.asarray(xs), jnp.eye(4, dtype=np.float32))
        np.testing.assert_allclose(got, xs, rtol=1e-6)

    def test_linearity_in_tasks(self):
        # encoding then gram-matvec == linear combination property used by
        # PC/PCMM *only* through polynomial structure; here we check the
        # encode itself is linear: encode(a·X) = a·encode(X).
        xs, coeffs = rand((3, 4, 2), seed=20), rand((5, 3), seed=21)
        (e1,) = model.encode_parts(jnp.asarray(2.0 * xs), jnp.asarray(coeffs))
        (e2,) = model.encode_parts(jnp.asarray(xs), jnp.asarray(coeffs))
        np.testing.assert_allclose(e1, 2.0 * np.asarray(e2), rtol=1e-5)


class TestShapeRegistry:
    def test_shape_of(self):
        dims = {"d": 4, "b": 3, "n": 2, "m": 5}
        assert model.shape_of("x:d,b", dims) == (4, 3)
        assert model.shape_of("eta:", dims) == ()
        assert model.shape_of("n,d,b", dims) == (2, 4, 3)

    def test_example_args_cover_all_entries(self):
        dims = {"d": 4, "b": 3, "n": 2, "m": 5}
        for name, (_, templates) in model.ENTRY_POINTS.items():
            args = model.example_args(templates, dims)
            assert len(args) == len(templates), name

    @pytest.mark.parametrize("entry", sorted(model.ENTRY_POINTS))
    def test_entries_trace_at_tiny_dims(self, entry):
        import jax

        dims = {"d": 4, "b": 2, "n": 3, "m": 4}
        fn, templates = model.ENTRY_POINTS[entry]
        args = model.example_args(templates, dims)
        jax.jit(fn).lower(*args)  # must trace without error
