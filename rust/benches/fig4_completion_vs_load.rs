//! Figure 4 regeneration bench: t̄ vs computation load r under the
//! paper's truncated-Gaussian scenarios (n = 16, k = n).  Prints the
//! figure's series and times the full sweep.
//!
//! ```bash
//! cargo bench --bench fig4_completion_vs_load
//! ```

use std::time::Instant;

use straggler_sched::harness::{fig4, Options};

fn main() -> anyhow::Result<()> {
    for scenario in [1u8, 2] {
        let opts = Options {
            trials: 20_000,
            seed: 0xF16,
            out_dir: Some("results".into()),
            scenario,
            cluster: false,
        };
        let t0 = Instant::now();
        fig4(&opts)?;
        println!(
            "fig4 scenario {scenario}: regenerated in {:.2} s ({} trials/point, 15 points)\n",
            t0.elapsed().as_secs_f64(),
            opts.trials
        );
    }
    Ok(())
}
