//! Figure 5 regeneration bench: t̄ vs r on the EC2-like substrate
//! (n = 15, d = 400, N = 900, k = n), plus wall-clock for the sweep.
//!
//! ```bash
//! cargo bench --bench fig5_cluster_completion_vs_load
//! ```

use std::time::Instant;

use straggler_sched::harness::{fig5, Options};

fn main() -> anyhow::Result<()> {
    let opts = Options {
        trials: 20_000,
        seed: 0xF16,
        out_dir: Some("results".into()),
        scenario: 1,
        cluster: false,
    };
    let t0 = Instant::now();
    fig5(&opts)?;
    println!(
        "fig5: regenerated in {:.2} s ({} trials/point, 14 points)",
        t0.elapsed().as_secs_f64(),
        opts.trials
    );
    Ok(())
}
