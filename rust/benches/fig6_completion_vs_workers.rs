//! Figure 6 regeneration bench: t̄ vs number of workers n ∈ [10, 15]
//! at r = n, k = n (d = 500, N = 1000).
//!
//! ```bash
//! cargo bench --bench fig6_completion_vs_workers
//! ```

use std::time::Instant;

use straggler_sched::harness::{fig6, Options};

fn main() -> anyhow::Result<()> {
    let opts = Options {
        trials: 20_000,
        seed: 0xF16,
        out_dir: Some("results".into()),
        scenario: 1,
        cluster: false,
    };
    let t0 = Instant::now();
    fig6(&opts)?;
    println!(
        "fig6: regenerated in {:.2} s ({} trials/point, 6 points)",
        t0.elapsed().as_secs_f64(),
        opts.trials
    );
    Ok(())
}
