//! Figure 7 regeneration bench: t̄ vs computation target k ∈ [2, n]
//! for the uncoded schemes + LB (n = 10, r = n, d = 800, N = 1000).
//!
//! ```bash
//! cargo bench --bench fig7_completion_vs_target
//! ```

use std::time::Instant;

use straggler_sched::harness::{fig7, Options};

fn main() -> anyhow::Result<()> {
    let opts = Options {
        trials: 20_000,
        seed: 0xF16,
        out_dir: Some("results".into()),
        scenario: 1,
        cluster: false,
    };
    let t0 = Instant::now();
    fig7(&opts)?;
    println!(
        "fig7: regenerated in {:.2} s ({} trials/point, 9 points)",
        t0.elapsed().as_secs_f64(),
        opts.trials
    );
    Ok(())
}
