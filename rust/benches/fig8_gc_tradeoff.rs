//! Figure 8 regeneration bench: the GC(s) grouped multi-message
//! communication–computation tradeoff (n = 12, r = n, k = n, EC2-like
//! delays + serialized master ingestion), swept through the unified
//! scheme layer.
//!
//! ```bash
//! cargo bench --bench fig8_gc_tradeoff
//! ```

use std::time::Instant;

use straggler_sched::harness::{fig8_gc, Options};

fn main() -> anyhow::Result<()> {
    let opts = Options {
        trials: 20_000,
        seed: 0xF16,
        out_dir: Some("results".into()),
        scenario: 1,
        cluster: false,
    };
    let t0 = Instant::now();
    fig8_gc(&opts)?;
    println!(
        "fig8: regenerated in {:.2} s ({} trials/point, 6 group sizes)",
        t0.elapsed().as_secs_f64(),
        opts.trials
    );
    Ok(())
}
