//! Hot-path micro-benchmarks — the §Perf measurement surface of
//! EXPERIMENTS.md.  Every optimization iteration re-runs this target
//! and diffs the report lines.
//!
//! ```bash
//! cargo bench --bench hot_paths
//! ```

use std::hint::black_box;

use straggler_sched::analysis::{collect_task_times, theorem1_mean};
use straggler_sched::coded::{PcScheme, PcmmScheme};
use straggler_sched::coordinator::Msg;
use straggler_sched::delay::{DelayModel, DelaySample, TruncatedGaussianModel};
use straggler_sched::lb::kth_slot_arrival;
use straggler_sched::linalg::Mat;
use straggler_sched::scheduler::{CyclicScheduler, RandomAssignment, Scheduler, StaircaseScheduler};
use straggler_sched::sim::{completion_time_fast, simulate_round_with, SimScratch};
use straggler_sched::util::benchkit::{bench, group};
use straggler_sched::util::rng::Rng;

fn main() {
    let (n, r) = (16usize, 16usize);
    let model = TruncatedGaussianModel::scenario1(n);
    let mut rng = Rng::seed_from_u64(42);
    let to_cs = CyclicScheduler.schedule(n, r, &mut rng);
    let to_ss = StaircaseScheduler.schedule(n, r, &mut rng);

    group("delay sampling");
    {
        let mut sample = DelaySample::zeros(n, r);
        let mut rng = Rng::seed_from_u64(1);
        bench("truncated_gaussian/sample_round_16x16", || {
            model.sample_into(black_box(&mut sample), &mut rng);
        });
    }

    group("simulation round (paper eq. 1-2 + k-distinct stop)");
    {
        let mut sample = DelaySample::zeros(n, r);
        let mut rng = Rng::seed_from_u64(2);
        model.sample_into(&mut sample, &mut rng);
        let mut scratch = SimScratch::new();
        bench("simulate_round/cs_n16_r16_k16", || {
            black_box(simulate_round_with(&to_cs, &sample, 16, &mut scratch));
        });
        bench("simulate_round/ss_n16_r16_k8", || {
            black_box(simulate_round_with(&to_ss, &sample, 8, &mut scratch));
        });
        let mut fast_scratch: Vec<f64> = Vec::with_capacity(n);
        bench("simulate_round/fast_cs_n16_r16_k16", || {
            black_box(completion_time_fast(&to_cs, &sample, 16, &mut fast_scratch));
        });
        let mut lbs = Vec::with_capacity(n * r);
        bench("lower_bound/kth_slot_arrival_k16", || {
            black_box(kth_slot_arrival(&sample, 16, &mut lbs));
        });
        let pc = PcScheme::new(n, r);
        let pcmm = PcmmScheme::new(n, r);
        bench("coded/pc_completion", || {
            black_box(pc.completion_time(&sample, &mut lbs));
        });
        bench("coded/pcmm_completion", || {
            black_box(pcmm.completion_time(&sample, &mut lbs));
        });
    }

    group("full monte-carlo round (sample + all schemes) — figure inner loop");
    {
        let mut sample = DelaySample::zeros(n, r);
        let mut rng = Rng::seed_from_u64(3);
        let mut fast_scratch: Vec<f64> = Vec::with_capacity(n);
        let mut lbs = Vec::with_capacity(n * r);
        let pc = PcScheme::new(n, r);
        let pcmm = PcmmScheme::new(n, r);
        bench("figure_inner_loop/n16_r16_all_schemes", || {
            model.sample_into(&mut sample, &mut rng);
            black_box(completion_time_fast(&to_cs, &sample, 16, &mut fast_scratch));
            black_box(completion_time_fast(&to_ss, &sample, 16, &mut fast_scratch));
            black_box(pc.completion_time(&sample, &mut lbs));
            black_box(pcmm.completion_time(&sample, &mut lbs));
            black_box(kth_slot_arrival(&sample, 16, &mut lbs));
        });
    }

    group("schedulers");
    {
        let mut rng = Rng::seed_from_u64(4);
        bench("schedule/cs_n16_r16", || {
            black_box(CyclicScheduler.schedule(16, 16, &mut rng));
        });
        bench("schedule/ra_n16_r16", || {
            black_box(RandomAssignment.schedule(16, 16, &mut rng));
        });
    }

    group("analysis (theorem 1, n = 12)");
    {
        let model12 = TruncatedGaussianModel::scenario1(12);
        let samples = collect_task_times(&CyclicScheduler, &model12, 12, 4, 200, 5);
        bench("theorem1_mean/n12_200rounds", || {
            black_box(theorem1_mean(&samples, 9));
        });
    }

    group("protocol codec");
    {
        let msg = Msg::Result {
            round: 7,
            worker_id: 3,
            task: 11,
            comp_us: 1500,
            send_ts_us: 123_456,
            h: vec![1.25f32; 512],
        };
        bench("protocol/encode_result_d512", || {
            black_box(msg.encode());
        });
        let enc = msg.encode();
        bench("protocol/decode_result_d512", || {
            black_box(Msg::decode(&enc).unwrap());
        });
    }

    group("linalg oracle (d = 400, b = 60 — fig5 task shape)");
    {
        let mut rng = Rng::seed_from_u64(6);
        let x = Mat::from_fn(400, 60, |_, _| rng.normal());
        let theta: Vec<f64> = (0..400).map(|_| rng.normal()).collect();
        bench("linalg/gram_matvec_400x60", || {
            black_box(x.gram_matvec(black_box(&theta)));
        });
    }

    group("pjrt runtime (quickstart artifact, d = 64, b = 32)");
    {
        let dir = straggler_sched::runtime::default_artifact_dir();
        if dir.join("manifest.json").exists() {
            let mut rt = straggler_sched::runtime::Runtime::new(dir).expect("runtime");
            let x: Vec<f32> = (0..64 * 32).map(|i| (i % 13) as f32 / 7.0).collect();
            let theta: Vec<f32> = (0..64).map(|i| (i % 7) as f32 / 5.0).collect();
            rt.prepare("quickstart", "task_gram").unwrap();
            bench("runtime/task_gram_execute_64x32", || {
                black_box(rt.task_gram("quickstart", &x, &theta).unwrap());
            });
        } else {
            println!("runtime/task_gram_execute_64x32  SKIPPED (run `make artifacts`)");
        }
    }
}
