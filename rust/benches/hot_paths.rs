//! Hot-path micro-benchmarks — the §Perf measurement surface of
//! EXPERIMENTS.md.  Every optimization iteration re-runs this target
//! and diffs the report lines; a machine-readable copy lands in
//! `BENCH_hot_paths.json` so the perf trajectory is tracked across PRs.
//!
//! ```bash
//! cargo bench --bench hot_paths
//! ```

use std::hint::black_box;

use straggler_sched::analysis::{collect_task_times, theorem1_mean};
use straggler_sched::coordinator::framebuf::{encode_result_into, parse_frame, FrameView};
use straggler_sched::coordinator::reactor::Reactor;
use straggler_sched::coded::{DecodeCache, PcScheme, PcmmScheme};
use straggler_sched::coordinator::{AggregatorRing, Msg, RoundAggregator};
use straggler_sched::delay::{
    DelayBatch, DelayModel, DelaySample, ShiftedExponential, TruncatedGaussianModel,
};
use straggler_sched::lb::kth_slot_arrival;
use straggler_sched::linalg::Mat;
use straggler_sched::scheduler::{
    CyclicScheduler, RandomAssignment, Scheduler, StaircaseScheduler,
};
use straggler_sched::scheme::{RoundView, SchemeEvaluator as _, SchemeId, SchemeRegistry};
use straggler_sched::sim::{
    chunk_rounds, completion_from_arrivals, completion_time_fast, simulate_round_with,
    slot_arrivals_batch, FlatTasks, MonteCarlo, SimScratch, BATCH_ROUNDS,
};
use straggler_sched::util::benchkit::{bench, group, write_json_report, BenchResult};
use straggler_sched::util::rng::Rng;

/// Allocation-counting wrapper around the system allocator: the §Perf
/// zero-alloc claims ("the warmed ingest path allocates nothing") are
/// asserted, not eyeballed — count deltas around a manual loop on the
/// main thread (not inside `bench`, whose sample vector also allocates).
struct CountingAlloc;

static ALLOC_CALLS: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);

unsafe impl std::alloc::GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: std::alloc::Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        std::alloc::System.alloc(layout)
    }
    unsafe fn dealloc(&self, ptr: *mut u8, layout: std::alloc::Layout) {
        std::alloc::System.dealloc(ptr, layout)
    }
    unsafe fn realloc(
        &self,
        ptr: *mut u8,
        layout: std::alloc::Layout,
        new_size: usize,
    ) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        std::alloc::System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn alloc_calls() -> u64 {
    ALLOC_CALLS.load(std::sync::atomic::Ordering::Relaxed)
}

fn main() {
    let (n, r) = (16usize, 16usize);
    let model = TruncatedGaussianModel::scenario1(n);
    let mut rng = Rng::seed_from_u64(42);
    let to_cs = CyclicScheduler.schedule(n, r, &mut rng);
    let to_ss = StaircaseScheduler.schedule(n, r, &mut rng);
    let mut all: Vec<BenchResult> = Vec::new();

    group("delay sampling");
    {
        let mut sample = DelaySample::zeros(n, r);
        let mut rng = Rng::seed_from_u64(1);
        all.push(bench("truncated_gaussian/sample_round_16x16", || {
            model.sample_into(black_box(&mut sample), &mut rng);
        }));
        let mut batch = DelayBatch::zeros(BATCH_ROUNDS, n, r);
        let mut rng = Rng::seed_from_u64(1);
        all.push(bench("truncated_gaussian/sample_batch_256x16x16", || {
            model.sample_batch_into(black_box(&mut batch), &mut rng);
        }));
    }

    group("simulation round (paper eq. 1-2 + k-distinct stop)");
    {
        let mut sample = DelaySample::zeros(n, r);
        let mut rng = Rng::seed_from_u64(2);
        model.sample_into(&mut sample, &mut rng);
        let mut scratch = SimScratch::new();
        all.push(bench("simulate_round/cs_n16_r16_k16", || {
            black_box(simulate_round_with(&to_cs, &sample, 16, &mut scratch));
        }));
        all.push(bench("simulate_round/ss_n16_r16_k8", || {
            black_box(simulate_round_with(&to_ss, &sample, 8, &mut scratch));
        }));
        let mut fast_scratch: Vec<f64> = Vec::with_capacity(n);
        all.push(bench("simulate_round/fast_cs_n16_r16_k16", || {
            black_box(completion_time_fast(&to_cs, &sample, 16, &mut fast_scratch));
        }));
        let mut lbs = Vec::with_capacity(n * r);
        all.push(bench("lower_bound/kth_slot_arrival_k16", || {
            black_box(kth_slot_arrival(&sample, 16, &mut lbs));
        }));
        let pc = PcScheme::new(n, r);
        let pcmm = PcmmScheme::new(n, r);
        all.push(bench("coded/pc_completion", || {
            black_box(pc.completion_time(&sample, &mut lbs));
        }));
        all.push(bench("coded/pcmm_completion", || {
            black_box(pcmm.completion_time(&sample, &mut lbs));
        }));
    }

    group("batched SoA kernels (per 256-round batch)");
    {
        let mut rng = Rng::seed_from_u64(7);
        let batch = model.sample_batch(BATCH_ROUNDS, n, r, &mut rng);
        let mut arrivals: Vec<f64> = Vec::new();
        all.push(bench("batch/slot_arrivals_256x16x16", || {
            slot_arrivals_batch(black_box(&batch), &mut arrivals);
        }));
        slot_arrivals_batch(&batch, &mut arrivals);
        let cs_flat = FlatTasks::new(&to_cs);
        let stride = batch.stride();
        let mut task_times: Vec<f64> = Vec::with_capacity(n);
        all.push(bench("batch/completions_cs_256rounds_k16", || {
            let mut acc = 0.0;
            for b in 0..BATCH_ROUNDS {
                acc += completion_from_arrivals(
                    &cs_flat,
                    &arrivals[b * stride..(b + 1) * stride],
                    16,
                    &mut task_times,
                );
            }
            black_box(acc);
        }));
    }

    group("aggregate merge (uncoded flush path: SoA arena vs per-round alloc, 256 tasks, d = 512)");
    {
        // one GC(16)-shaped round over 256 tasks: 16 block flushes plus
        // a duplicate re-flush of each (a straggler's late copy) — the
        // master-side merge the cluster data plane runs per round
        let (n_t, s, d) = (256usize, 16usize, 512usize);
        let mut rng = Rng::seed_from_u64(11);
        let flushes: Vec<(Vec<usize>, Vec<f64>)> = (0..n_t / s)
            .map(|b| {
                let tasks: Vec<usize> = (b * s..(b + 1) * s).collect();
                let sum: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
                (tasks, sum)
            })
            .collect();
        let mut agg = RoundAggregator::new(n_t, d, s, n_t);
        let reused = bench("aggregate/reused_soa_256tasks_d512", || {
            agg.reset();
            for (tasks, sum) in &flushes {
                black_box(agg.offer(tasks, sum));
                black_box(agg.offer(tasks, sum)); // duplicate drop
            }
            let (w, t) = agg.finish();
            black_box((w.len(), t[0]));
        });
        let fresh = bench("aggregate/fresh_alloc_256tasks_d512", || {
            let mut agg = RoundAggregator::new(n_t, d, s, n_t);
            for (tasks, sum) in &flushes {
                black_box(agg.offer(tasks, sum));
                black_box(agg.offer(tasks, sum));
            }
            let (w, t) = agg.finish();
            black_box((w.len(), t[0]));
        });
        println!(
            "aggregate merge reuse: fresh-alloc {:.2} µs vs reused {:.2} µs  →  {:.2}× \
             (reset must beat rebuild)",
            fresh.mean_ns / 1e3,
            reused.mean_ns / 1e3,
            fresh.mean_ns / reused.mean_ns
        );
        all.push(reused);
        all.push(fresh);
    }

    group("async ring (bounded-staleness pump, S = 4 rounds in flight, 256 tasks, d = 512)");
    {
        // the pipelined master's steady-state round: route every flush
        // of the oldest in-flight round through the S-slot ring, retire
        // it, advance the window.  The ring recycles slot arenas on
        // advance, so the churn must cost the same as one synchronous
        // RoundAggregator reset+merge — not an allocation storm.
        let (n_t, s, d, depth) = (256usize, 16usize, 512usize, 4usize);
        let mut rng = Rng::seed_from_u64(23);
        let flushes: Vec<(Vec<usize>, Vec<f64>)> = (0..n_t / s)
            .map(|b| {
                let tasks: Vec<usize> = (b * s..(b + 1) * s).collect();
                let sum: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
                (tasks, sum)
            })
            .collect();
        let mut ring = AggregatorRing::new(n_t, d, s, n_t, depth);
        let mut round = 0usize;
        let pump = bench("ring/pump_round_s4_256tasks_d512", || {
            for (tasks, sum) in &flushes {
                black_box(ring.offer(round, tasks, sum));
            }
            assert!(ring.oldest_complete());
            let (w, t) = ring.finish_oldest();
            black_box((w.len(), t[0]));
            ring.advance();
            round += 1;
        });
        // a straggler's frame for an already-applied round: the drop
        // path the pipeline takes under fire must be near-free
        let (tasks0, sum0) = &flushes[0];
        let stale = bench("ring/stale_frame_drop_d512", || {
            black_box(ring.offer(0, tasks0, sum0));
        });
        let fresh_ring = bench("ring/fresh_alloc_s4_256tasks_d512", || {
            let mut ring = AggregatorRing::new(n_t, d, s, n_t, depth);
            for (tasks, sum) in &flushes {
                black_box(ring.offer(0, tasks, sum));
            }
            let (w, t) = ring.finish_oldest();
            black_box((w.len(), t[0]));
            ring.advance();
        });
        println!(
            "async ring recycle: fresh-alloc {:.2} µs vs pumped {:.2} µs  →  {:.2}× \
             (advance must beat rebuild); stale drop {:.0} ns",
            fresh_ring.mean_ns / 1e3,
            pump.mean_ns / 1e3,
            fresh_ring.mean_ns / pump.mean_ns,
            stale.mean_ns
        );
        all.push(pump);
        all.push(stale);
        all.push(fresh_ring);
    }

    group("decode cache (PC/PCMM weight reuse at threshold ≥ 32, d = 512)");
    {
        // responder subsets repeat round-over-round, so the cached
        // decode path must collapse the per-round O(m²) solve to a key
        // lookup + one O(m·d) apply.  Data content is irrelevant to the
        // solve cost — fabricated d-length payloads keep setup cheap.
        let d = 512usize;
        let mut rng = Rng::seed_from_u64(13);

        // PC n = 32, r = 2 → threshold m = 31 (k ≥ 32-scale subset)
        let pc = PcScheme::new(32, 2);
        let m_pc = pc.recovery_threshold();
        let pc_resp: Vec<(usize, Vec<f64>)> = (0..m_pc)
            .map(|w| (w, (0..d).map(|_| rng.normal()).collect()))
            .collect();
        let pc_newton = bench("decode/pc_newton_fresh_m31_d512", || {
            black_box(pc.decode_interpolated(black_box(&pc_resp)));
        });
        all.push(bench("decode/pc_weights_fresh_m31_d512", || {
            black_box(pc.decode(black_box(&pc_resp)));
        }));
        let mut pc_cache = DecodeCache::with_default_cap();
        pc.decode_cached(&pc_resp, &mut pc_cache); // warm: every bench call hits
        let pc_hit = bench("decode/pc_cache_hit_m31_d512", || {
            black_box(pc.decode_cached(black_box(&pc_resp), &mut pc_cache));
        });
        println!(
            "decode cache PC m=31: newton {:.2} µs vs cache-hit {:.2} µs  →  {:.1}× \
             (target ≥ 5×)",
            pc_newton.mean_ns / 1e3,
            pc_hit.mean_ns / 1e3,
            pc_newton.mean_ns / pc_hit.mean_ns
        );
        all.push(pc_newton);
        all.push(pc_hit);

        // PCMM n = 32, r = 2 → threshold m = 63 over 64 slots
        let pcmm = PcmmScheme::new(32, 2);
        let m_mm = pcmm.recovery_threshold();
        let pcmm_resp: Vec<((usize, usize), Vec<f64>)> = (0..m_mm)
            .map(|s| ((s / 2, s % 2), (0..d).map(|_| rng.normal()).collect()))
            .collect();
        let mm_newton = bench("decode/pcmm_newton_fresh_m63_d512", || {
            black_box(pcmm.decode_interpolated(black_box(&pcmm_resp)));
        });
        all.push(bench("decode/pcmm_weights_fresh_m63_d512", || {
            black_box(pcmm.decode(black_box(&pcmm_resp)));
        }));
        let mut mm_cache = DecodeCache::with_default_cap();
        pcmm.decode_cached(&pcmm_resp, &mut mm_cache);
        let mm_hit = bench("decode/pcmm_cache_hit_m63_d512", || {
            black_box(pcmm.decode_cached(black_box(&pcmm_resp), &mut mm_cache));
        });
        println!(
            "decode cache PCMM m=63: newton {:.2} µs vs cache-hit {:.2} µs  →  {:.1}× \
             (target ≥ 5×)",
            mm_newton.mean_ns / 1e3,
            mm_hit.mean_ns / 1e3,
            mm_newton.mean_ns / mm_hit.mean_ns
        );
        all.push(mm_newton);
        all.push(mm_hit);
    }

    group("fleet n = 10_000 (chunked arrivals + completion kernel, r = 4, k = 9_000)");
    {
        // the fleet regime the chunked engine targets: one n = 10_000
        // round end-to-end (sample → arrivals → k-th order statistic)
        // must stay in low single-digit milliseconds, with zero
        // allocation after the first chunk
        let (n_f, r_f, k_f) = (10_000usize, 4usize, 9_000usize);
        let chunk = chunk_rounds(n_f, r_f);
        let fleet_model = ShiftedExponential::new(0.05, 4.0, 0.2, 2.0);
        let mut rng = Rng::seed_from_u64(17);
        let mut batch = DelayBatch::zeros(chunk, n_f, r_f);
        let sample_b = bench(&format!("fleet/sample_chunk_{chunk}x10000x4"), || {
            fleet_model.sample_batch_into(black_box(&mut batch), &mut rng);
        });
        let mut arrivals: Vec<f64> = Vec::new();
        let arrive_b = bench(&format!("fleet/slot_arrivals_{chunk}x10000x4"), || {
            slot_arrivals_batch(black_box(&batch), &mut arrivals);
        });
        slot_arrivals_batch(&batch, &mut arrivals);
        let to_fleet = CyclicScheduler.schedule(n_f, r_f, &mut rng);
        let flat = FlatTasks::new(&to_fleet);
        let stride = n_f * r_f;
        let mut task_times: Vec<f64> = Vec::with_capacity(n_f);
        let complete_b = bench(&format!("fleet/completions_{chunk}rounds_k9000"), || {
            let mut acc = 0.0;
            for b in 0..chunk {
                acc += completion_from_arrivals(
                    &flat,
                    &arrivals[b * stride..(b + 1) * stride],
                    k_f,
                    &mut task_times,
                );
            }
            black_box(acc);
        });
        let per_round_us = (sample_b.mean_ns + arrive_b.mean_ns + complete_b.mean_ns)
            / chunk as f64
            / 1e3;
        println!(
            "fleet n=10,000 per-round: sample {:.0} µs + arrivals {:.0} µs + completion \
             {:.0} µs = {per_round_us:.0} µs (target < 3000 µs end-to-end; completion \
             alone < 500 µs)",
            sample_b.mean_ns / chunk as f64 / 1e3,
            arrive_b.mean_ns / chunk as f64 / 1e3,
            complete_b.mean_ns / chunk as f64 / 1e3
        );
        all.push(sample_b);
        all.push(arrive_b);
        all.push(complete_b);
    }

    group("scheme layer: registry dispatch vs direct kernel (per 256-round chunk)");
    {
        // the acceptance bar of the PR-2 refactor: preparing evaluators
        // once per chunk must leave ZERO per-round overhead beyond one
        // virtual call — the completion kernel itself is unchanged
        let mut rng = Rng::seed_from_u64(7);
        let batch = model.sample_batch(BATCH_ROUNDS, n, r, &mut rng);
        let mut arrivals: Vec<f64> = Vec::new();
        slot_arrivals_batch(&batch, &mut arrivals);
        let stride = batch.stride();
        let cs_flat = FlatTasks::new(&to_cs);
        let mut task_times: Vec<f64> = Vec::with_capacity(n);
        let direct = bench("scheme/direct_cs_256rounds_k16", || {
            let mut acc = 0.0;
            for b in 0..BATCH_ROUNDS {
                acc += completion_from_arrivals(
                    &cs_flat,
                    &arrivals[b * stride..(b + 1) * stride],
                    16,
                    &mut task_times,
                );
            }
            black_box(acc);
        });
        let mut rng_sched = Rng::seed_from_u64(0);
        let mut ev = SchemeRegistry::build(SchemeId::Cs).prepare(n, r, 16, &mut rng_sched);
        let registry = bench("scheme/registry_cs_256rounds_k16", || {
            let mut acc = 0.0;
            for b in 0..BATCH_ROUNDS {
                let view = RoundView {
                    arrivals: &arrivals[b * stride..(b + 1) * stride],
                    comp: batch.comp_round(b),
                    comm: batch.comm_round(b),
                };
                acc += ev.completion(&view, &mut rng_sched);
            }
            black_box(acc);
        });
        println!(
            "registry-vs-direct per-round dispatch overhead: {:+.1}% (target ~0%)",
            100.0 * (registry.mean_ns / direct.mean_ns - 1.0)
        );
        let mut ev_gc = SchemeRegistry::build(SchemeId::Gc(4)).prepare(n, r, 16, &mut rng_sched);
        let gc = bench("scheme/registry_gc4_256rounds_k16", || {
            let mut acc = 0.0;
            for b in 0..BATCH_ROUNDS {
                let view = RoundView {
                    arrivals: &arrivals[b * stride..(b + 1) * stride],
                    comp: batch.comp_round(b),
                    comm: batch.comm_round(b),
                };
                acc += ev_gc.completion(&view, &mut rng_sched);
            }
            black_box(acc);
        });
        all.push(direct);
        all.push(registry);
        all.push(gc);
    }

    group("coupled 3-scheme round (CS + SS + RA): scalar vs batched");
    let speedup = {
        // scalar path: sample one round, evaluate all three schemes by
        // re-walking the delays per scheme (the pre-batch engine)
        let mut sample = DelaySample::zeros(n, r);
        let mut rng = Rng::seed_from_u64(3);
        let mut rng_sched = Rng::seed_from_u64(4);
        let mut fast_scratch: Vec<f64> = Vec::with_capacity(n);
        let scalar = bench("coupled3/scalar_per_round", || {
            model.sample_into(&mut sample, &mut rng);
            black_box(completion_time_fast(&to_cs, &sample, 16, &mut fast_scratch));
            black_box(completion_time_fast(&to_ss, &sample, 16, &mut fast_scratch));
            let ra = RandomAssignment.schedule(n, r, &mut rng_sched);
            black_box(completion_time_fast(&ra, &sample, 16, &mut fast_scratch));
        });
        // batched path: one 256-round batch per iteration, arrivals
        // computed once and shared by all three schemes
        let mut batch = DelayBatch::zeros(BATCH_ROUNDS, n, r);
        let mut rng = Rng::seed_from_u64(3);
        let mut rng_sched = Rng::seed_from_u64(4);
        let mut arrivals: Vec<f64> = Vec::new();
        let mut task_times: Vec<f64> = Vec::with_capacity(n);
        let cs_flat = FlatTasks::new(&to_cs);
        let ss_flat = FlatTasks::new(&to_ss);
        let stride = n * r;
        let batched = bench("coupled3/batched_per_256rounds", || {
            model.sample_batch_into(&mut batch, &mut rng);
            slot_arrivals_batch(&batch, &mut arrivals);
            let mut acc = 0.0;
            for b in 0..BATCH_ROUNDS {
                let round = &arrivals[b * stride..(b + 1) * stride];
                acc += completion_from_arrivals(&cs_flat, round, 16, &mut task_times);
                acc += completion_from_arrivals(&ss_flat, round, 16, &mut task_times);
                let ra = RandomAssignment.schedule(n, r, &mut rng_sched);
                let ra_flat = FlatTasks::new(&ra);
                acc += completion_from_arrivals(&ra_flat, round, 16, &mut task_times);
            }
            black_box(acc);
        });
        let scalar_rps = 1e9 / scalar.mean_ns;
        let batched_rps = 1e9 / (batched.mean_ns / BATCH_ROUNDS as f64);
        let speedup = batched_rps / scalar_rps;
        println!(
            "coupled3 rounds/s: scalar {scalar_rps:.0}, batched {batched_rps:.0}  \
             →  {speedup:.2}× (target ≥ 3×)"
        );
        all.push(scalar);
        all.push(batched);
        speedup
    };

    group("full coupled estimator (20k trials, CS+SS+RA, n=r=k=16)");
    {
        let mc = MonteCarlo {
            trials: 20_000,
            seed: 0xBE7C4,
            threads: std::thread::available_parallelism()
                .map(|p| p.get())
                .unwrap_or(1),
        };
        let schemes: Vec<&dyn Scheduler> =
            vec![&CyclicScheduler, &StaircaseScheduler, &RandomAssignment];
        let scalar = bench("estimator/scalar_20k_3schemes", || {
            black_box(mc.estimate_coupled_scalar(&schemes, &model, n, r, 16));
        });
        let batched = bench("estimator/batched_20k_3schemes", || {
            black_box(mc.estimate_coupled(&schemes, &model, n, r, 16));
        });
        println!(
            "estimator rounds/s: scalar {:.0}, batched {:.0}  →  {:.2}×",
            mc.trials as f64 * 1e9 / scalar.mean_ns,
            mc.trials as f64 * 1e9 / batched.mean_ns,
            scalar.mean_ns / batched.mean_ns
        );
        all.push(scalar);
        all.push(batched);
    }

    group("full monte-carlo round (sample + all schemes) — figure inner loop");
    {
        let mut sample = DelaySample::zeros(n, r);
        let mut rng = Rng::seed_from_u64(3);
        let mut fast_scratch: Vec<f64> = Vec::with_capacity(n);
        let mut lbs = Vec::with_capacity(n * r);
        let pc = PcScheme::new(n, r);
        let pcmm = PcmmScheme::new(n, r);
        all.push(bench("figure_inner_loop/n16_r16_all_schemes", || {
            model.sample_into(&mut sample, &mut rng);
            black_box(completion_time_fast(&to_cs, &sample, 16, &mut fast_scratch));
            black_box(completion_time_fast(&to_ss, &sample, 16, &mut fast_scratch));
            black_box(pc.completion_time(&sample, &mut lbs));
            black_box(pcmm.completion_time(&sample, &mut lbs));
            black_box(kth_slot_arrival(&sample, 16, &mut lbs));
        }));
    }

    group("schedulers");
    {
        let mut rng = Rng::seed_from_u64(4);
        all.push(bench("schedule/cs_n16_r16", || {
            black_box(CyclicScheduler.schedule(16, 16, &mut rng));
        }));
        all.push(bench("schedule/ra_n16_r16", || {
            black_box(RandomAssignment.schedule(16, 16, &mut rng));
        }));
    }

    group("analysis (theorem 1, n = 12)");
    {
        let model12 = TruncatedGaussianModel::scenario1(12);
        let samples = collect_task_times(&CyclicScheduler, &model12, 12, 4, 200, 5);
        all.push(bench("theorem1_mean/n12_200rounds", || {
            black_box(theorem1_mean(&samples, 9));
        }));
    }

    group("protocol codec");
    {
        let msg = Msg::Result {
            round: 7,
            version: 7,
            worker_id: 3,
            tasks: vec![11],
            comp_us: 1500,
            send_ts_us: 123_456,
            h: vec![1.25f32; 512],
        };
        all.push(bench("protocol/encode_result_d512", || {
            black_box(msg.encode());
        }));
        let enc = msg.encode();
        all.push(bench("protocol/decode_result_d512", || {
            black_box(Msg::decode(&enc).unwrap());
        }));
    }

    group("protocol v3 wire economy (aggregated GC flush frames, d = 512)");
    {
        // a v3 GC(s) flush ships ONE d-block regardless of s; the PR-2
        // wire shipped s concatenated per-task blocks.  Counter: frame
        // bytes for an s = 4 flush, and the full-round totals at
        // n = r = 16 (64 flush messages vs 256 per-task messages)
        let d = 512usize;
        let s = 4usize;
        let flush = Msg::Result {
            round: 1,
            version: 1,
            worker_id: 0,
            tasks: (8..8 + s as u32).collect(),
            comp_us: 1500,
            send_ts_us: 123_456,
            h: vec![1.25f32; d],
        };
        let v3_frame = 4 + flush.encode().len(); // + length prefix
        let v2_frame = v3_frame + 4 * d * (s - 1); // s blocks, not one
        let per_task = Msg::Result {
            round: 1,
            version: 1,
            worker_id: 0,
            tasks: vec![8],
            comp_us: 1500,
            send_ts_us: 123_456,
            h: vec![1.25f32; d],
        };
        let single_frame = 4 + per_task.encode().len();
        let (n_w, r_w) = (16usize, 16usize);
        let v3_round = n_w * (r_w / s) * v3_frame;
        let v2_round = n_w * (r_w / s) * v2_frame;
        let cs_round = n_w * r_w * single_frame;
        println!(
            "wire/gc{s}_flush_d{d}: v3 {v3_frame} B vs PR-2 {v2_frame} B  \
             →  {:.2}× frame shrink",
            v2_frame as f64 / v3_frame as f64
        );
        println!(
            "wire/full_round_n16_r16: GC({s}) v3 {v3_round} B, GC({s}) PR-2 \
             {v2_round} B, CS per-task {cs_round} B  →  {:.2}× vs PR-2, \
             {:.2}× vs CS",
            v2_round as f64 / v3_round as f64,
            cs_round as f64 / v3_round as f64
        );
        all.push(bench("wire/encode_gc4_aggregated_d512", || {
            black_box(flush.encode());
        }));
        let enc = flush.encode();
        all.push(bench("wire/decode_gc4_aggregated_d512", || {
            black_box(Msg::decode(&enc).unwrap());
        }));
    }

    group("net (reactor data plane: pooled frame codec + poll pump vs thread baseline)");
    {
        use std::io::Write as _;
        use std::net::{TcpListener, TcpStream};
        use std::sync::mpsc;
        use std::time::Duration;

        // --- pooled frame codec, d = 512 (the worker flush / master
        // ingest frame shape).  The pooled path appends into a warmed
        // buffer; the fresh path is PR-7's encode-per-flush.
        let d = 512usize;
        let tasks: Vec<u32> = (8..12).collect();
        let h64: Vec<f64> = (0..d).map(|i| (i % 13) as f64 / 7.0).collect();
        let mut frame: Vec<u8> = Vec::new();
        encode_result_into(&mut frame, 1, 1, 0, &tasks, 1500, 123_456, &h64);
        let a0 = alloc_calls();
        for _ in 0..1_000 {
            frame.clear();
            encode_result_into(&mut frame, 1, 1, 0, &tasks, 1500, 123_456, &h64);
        }
        let encode_allocs = alloc_calls() - a0;
        assert_eq!(
            encode_allocs, 0,
            "warmed pooled encode must be allocation-free, saw {encode_allocs} allocs/1000"
        );
        let pooled = bench("net/encode_result_pooled_d512", || {
            frame.clear();
            encode_result_into(&mut frame, 1, 1, 0, &tasks, 1500, 123_456, &h64);
            black_box(frame.len());
        });
        let owned_msg = Msg::Result {
            round: 1,
            version: 1,
            worker_id: 0,
            tasks: tasks.clone(),
            comp_us: 1500,
            send_ts_us: 123_456,
            h: h64.iter().map(|&v| v as f32).collect(),
        };
        let fresh = bench("net/encode_result_fresh_d512", || {
            black_box(owned_msg.encode());
        });
        println!(
            "net codec encode: fresh-alloc {:.0} ns vs pooled {:.0} ns  →  {:.2}×; \
             pooled path allocs/iter = 0 (asserted)",
            fresh.mean_ns,
            pooled.mean_ns,
            fresh.mean_ns / pooled.mean_ns
        );
        all.push(pooled);
        all.push(fresh);

        // --- zero-copy decode view vs owned decode on the same frame
        let payload = frame[4..].to_vec();
        let a0 = alloc_calls();
        for _ in 0..1_000 {
            match parse_frame(&payload).unwrap() {
                FrameView::Result(r) => {
                    black_box((r.round, r.tasks_len(), r.h_len()));
                }
                FrameView::Other(_) => unreachable!("Result frame"),
            }
        }
        let view_allocs = alloc_calls() - a0;
        assert_eq!(
            view_allocs, 0,
            "zero-copy Result view must not allocate, saw {view_allocs} allocs/1000"
        );
        let view = bench("net/decode_result_view_d512", || {
            match parse_frame(black_box(&payload)).unwrap() {
                FrameView::Result(r) => black_box((r.round, r.h_len())),
                FrameView::Other(_) => unreachable!("Result frame"),
            };
        });
        let owned = bench("net/decode_result_owned_d512", || {
            black_box(Msg::decode(black_box(&payload)).unwrap());
        });
        println!(
            "net codec decode: owned {:.0} ns vs view {:.0} ns  →  {:.2}×; \
             view path allocs/iter = 0 (asserted)",
            owned.mean_ns,
            view.mean_ns,
            owned.mean_ns / view.mean_ns
        );
        all.push(view);
        all.push(owned);

        // --- ingest pump at n = 64 synthetic sockets: 8 pre-queued
        // ~2 KiB Result frames per conn (512 frames total) drained by
        // (a) the poll reactor on one thread and (b) PR-7's 64 blocking
        // reader threads + channel.  Same frames, same loopback sockets.
        let n_conns = 64usize;
        let frames_per_conn = 8usize;
        let total = n_conns * frames_per_conn;
        let listener = TcpListener::bind("127.0.0.1:0").expect("bind");
        let addr = listener.local_addr().unwrap();
        let mut masters: Vec<TcpStream> = Vec::new();
        let mut peers: Vec<TcpStream> = Vec::new();
        for _ in 0..n_conns {
            let c = TcpStream::connect(addr).expect("connect");
            let (s, _) = listener.accept().expect("accept");
            s.set_nodelay(true).unwrap();
            c.set_nodelay(true).unwrap();
            masters.push(s);
            peers.push(c);
        }
        let mut reactor = Reactor::new(masters).expect("reactor");
        let mut pump_iter = || {
            for p in peers.iter_mut() {
                for _ in 0..frames_per_conn {
                    p.write_all(&frame).unwrap();
                }
            }
            let mut got = 0usize;
            while got < total {
                if reactor
                    .poll_frame(Duration::from_secs(5))
                    .expect("reactor pump")
                    .is_some()
                {
                    got += 1;
                }
            }
            black_box(got);
        };
        pump_iter(); // warm every conn's read buffer to frame depth
        let a0 = alloc_calls();
        pump_iter();
        let pump_allocs = alloc_calls() - a0;
        assert_eq!(
            pump_allocs, 0,
            "warmed reactor ingest (512 frames / 64 conns) must be allocation-free, \
             saw {pump_allocs} allocs"
        );
        let reactor_pump = bench("net/reactor_pump_n64_512frames", &mut pump_iter);
        all.push(reactor_pump.clone());

        // thread baseline — spawned AFTER the reactor alloc assertions
        // so its per-frame decode allocations can't pollute the counter
        let listener2 = TcpListener::bind("127.0.0.1:0").expect("bind");
        let addr2 = listener2.local_addr().unwrap();
        let mut peers2: Vec<TcpStream> = Vec::new();
        let (tx, rx) = mpsc::channel::<Msg>();
        for _ in 0..n_conns {
            let c = TcpStream::connect(addr2).expect("connect");
            let (s, _) = listener2.accept().expect("accept");
            s.set_nodelay(true).unwrap();
            c.set_nodelay(true).unwrap();
            peers2.push(c);
            let mut s = s;
            let tx = tx.clone();
            std::thread::spawn(move || loop {
                match Msg::read_from(&mut s) {
                    Ok(m) => {
                        if tx.send(m).is_err() {
                            return;
                        }
                    }
                    Err(_) => return,
                }
            });
        }
        let threads_pump = bench("net/threads_pump_n64_512frames", || {
            for p in peers2.iter_mut() {
                for _ in 0..frames_per_conn {
                    p.write_all(&frame).unwrap();
                }
            }
            let mut got = 0usize;
            while got < total {
                rx.recv_timeout(Duration::from_secs(5)).expect("threads pump");
                got += 1;
            }
            black_box(got);
        });
        all.push(threads_pump.clone());
        println!(
            "net pump n=64 ×512 frames: threads {:.0} µs vs reactor {:.0} µs  →  \
             {:.2}× (acceptance: reactor ≥ thread baseline, i.e. ratio ≥ 1.0)",
            threads_pump.mean_ns / 1e3,
            reactor_pump.mean_ns / 1e3,
            threads_pump.mean_ns / reactor_pump.mean_ns
        );
    }

    group("telemetry (registry hot paths + scrape-hook pump overhead)");
    {
        use std::io::Write as _;
        use std::net::{TcpListener, TcpStream};
        use std::time::Duration;

        use straggler_sched::telemetry::{
            encode_prometheus_into, metrics as tmet, snapshot_into, MetricsServer, Snapshot,
        };

        // --- registry primitives: the per-frame instrument cost the
        // data plane pays.  Zero-alloc is asserted, not eyeballed.
        let a0 = alloc_calls();
        for i in 0..1_000u64 {
            tmet::MASTER_FRAMES_TOTAL.inc();
            tmet::RING_ROUNDS_IN_FLIGHT.set(i as f64);
        }
        let c_allocs = alloc_calls() - a0;
        assert_eq!(
            c_allocs, 0,
            "counter/gauge hot path must be allocation-free, saw {c_allocs} allocs/1000"
        );
        all.push(bench("telemetry/counter_inc", || {
            tmet::MASTER_FRAMES_TOTAL.inc();
        }));

        // histogram record past the exact-mode cap (4096 samples): the
        // estimator sits on the fixed grid, so no heap traffic remains
        for i in 0..6_000 {
            tmet::MASTER_DWELL_US.record((i % 1009) as f64);
        }
        let a0 = alloc_calls();
        for i in 0..1_000 {
            tmet::MASTER_DWELL_US.record((i % 997) as f64);
        }
        let h_allocs = alloc_calls() - a0;
        assert_eq!(
            h_allocs, 0,
            "warm histogram record must be allocation-free, saw {h_allocs} allocs/1000"
        );
        let mut tick = 0u64;
        all.push(bench("telemetry/histogram_record_warm", || {
            tick = tick.wrapping_add(1);
            tmet::MASTER_DWELL_US.record((tick % 997) as f64);
        }));

        // snapshot + Prometheus exposition into reused buffers — the
        // whole-catalog scrape cost
        let mut snap = Snapshot::default();
        let mut body = String::new();
        snapshot_into(&mut snap);
        encode_prometheus_into(&mut body, &snap);
        let a0 = alloc_calls();
        for _ in 0..100 {
            snapshot_into(&mut snap);
            encode_prometheus_into(&mut body, &snap);
        }
        let s_allocs = alloc_calls() - a0;
        assert_eq!(
            s_allocs, 0,
            "warm snapshot_into + encode must reuse buffers, saw {s_allocs} allocs/100"
        );
        all.push(bench("telemetry/snapshot_encode", || {
            snapshot_into(&mut snap);
            encode_prometheus_into(&mut body, &snap);
            black_box(body.len());
        }));

        // --- scrape-hook pump overhead: the net group's 64-conn ingest
        // drain, once plain and once with the idle metrics listener
        // riding the reactor's poll set (the production wiring when
        // `--metrics-addr` is on but nobody is scraping)
        let d = 512usize;
        let tasks: Vec<u32> = (8..12).collect();
        let h64: Vec<f64> = (0..d).map(|i| (i % 13) as f64 / 7.0).collect();
        let mut frame: Vec<u8> = Vec::new();
        encode_result_into(&mut frame, 1, 1, 0, &tasks, 1500, 123_456, &h64);
        let n_conns = 64usize;
        let frames_per_conn = 8usize;
        let total = n_conns * frames_per_conn;
        let listener = TcpListener::bind("127.0.0.1:0").expect("bind");
        let addr = listener.local_addr().unwrap();
        let mut masters: Vec<TcpStream> = Vec::new();
        let mut peers: Vec<TcpStream> = Vec::new();
        for _ in 0..n_conns {
            let c = TcpStream::connect(addr).expect("connect");
            let (s, _) = listener.accept().expect("accept");
            s.set_nodelay(true).unwrap();
            c.set_nodelay(true).unwrap();
            masters.push(s);
            peers.push(c);
        }
        let mut reactor = Reactor::new(masters).expect("reactor");
        let mut plain_iter = || {
            for p in peers.iter_mut() {
                for _ in 0..frames_per_conn {
                    p.write_all(&frame).unwrap();
                }
            }
            let mut got = 0usize;
            while got < total {
                if reactor
                    .poll_frame(Duration::from_secs(5))
                    .expect("plain pump")
                    .is_some()
                {
                    got += 1;
                }
            }
            black_box(got);
        };
        plain_iter(); // warm read buffers to frame depth
        let plain = bench("telemetry/reactor_pump_plain_n64_512frames", &mut plain_iter);
        let mut srv = MetricsServer::bind("127.0.0.1:0").expect("metrics listener");
        let mut hooked_iter = || {
            for p in peers.iter_mut() {
                for _ in 0..frames_per_conn {
                    p.write_all(&frame).unwrap();
                }
            }
            let mut got = 0usize;
            while got < total {
                if reactor
                    .poll_frame_hooked(Duration::from_secs(5), Some(&mut srv))
                    .expect("hooked pump")
                    .is_some()
                {
                    got += 1;
                }
            }
            black_box(got);
        };
        hooked_iter(); // warm the poll set's extra hook slot
        let a0 = alloc_calls();
        hooked_iter();
        let hook_allocs = alloc_calls() - a0;
        assert_eq!(
            hook_allocs, 0,
            "warmed hooked pump (idle scrape listener) must stay allocation-free, \
             saw {hook_allocs} allocs"
        );
        let hooked = bench("telemetry/reactor_pump_hooked_n64_512frames", &mut hooked_iter);
        println!(
            "telemetry pump overhead: plain {:.0} µs vs hooked {:.0} µs  →  {:+.2}% \
             (acceptance gate: ≤ 3% with the idle scrape listener on the poll set)",
            plain.mean_ns / 1e3,
            hooked.mean_ns / 1e3,
            100.0 * (hooked.mean_ns / plain.mean_ns - 1.0)
        );
        all.push(plain);
        all.push(hooked);
    }

    group("policy replan (adaptive subsystem, n = 64) — must stay off the per-task hot path");
    {
        // the adaptive contract: estimator update + re-plan + evaluator
        // rebuild happen once per ROUND boundary, so their combined cost
        // must stay well under 1 ms at fleet scale (n = 64) — otherwise
        // re-planning would eat the very straggler slack it recovers
        use straggler_sched::adaptive::{PolicyEngine, PolicyKind};
        use straggler_sched::scheme::gc::GcEvaluator;

        let (n_f, r_f, k_f, block) = (64usize, 64usize, 48usize, 4usize);
        let mut rng_obs = Rng::seed_from_u64(21);
        let mut engine = PolicyEngine::new(PolicyKind::AdaptiveOrder, n_f, r_f, block);
        let est_update = bench("adaptive/estimator_update_64workers", || {
            for w in 0..n_f {
                engine.observe(w, 0.1 + 0.3 * rng_obs.f64(), 0.5);
            }
        });
        let mut rng_plan = Rng::seed_from_u64(3);
        let mut round = 0usize;
        let order_plan = bench("adaptive/replan_order_n64", || {
            round += 1;
            black_box(engine.plan(round, &mut rng_plan));
        });
        let mut load_engine = PolicyEngine::new(PolicyKind::AdaptiveLoad, n_f, r_f, block);
        for w in 0..n_f {
            load_engine.observe(w, 0.1 + 0.01 * w as f64, 0.5);
        }
        let load_plan = bench("adaptive/replan_load_n64", || {
            round += 1;
            black_box(load_engine.plan(round, &mut rng_plan));
        });
        let base = CyclicScheduler.schedule(n_f, r_f, &mut rng_plan);
        let plan = engine.plan(round + 1, &mut rng_plan);
        let rebuild = bench("adaptive/rebuild_evaluator_n64_r64", || {
            let to = plan.materialize(&base);
            black_box(GcEvaluator::with_sizes(&to, &plan.sizes, k_f));
        });
        let per_round_ns =
            est_update.mean_ns + order_plan.mean_ns.max(load_plan.mean_ns) + rebuild.mean_ns;
        println!(
            "adaptive replan cycle (estimate + plan + rebuild): {:.1} µs/round \
             (target < 1000 µs at n = 64)",
            per_round_ns / 1e3
        );
        all.push(est_update);
        all.push(order_plan);
        all.push(load_plan);
        all.push(rebuild);
    }

    group("trace fit (fleet calibration, n = 64 workers)");
    {
        // the trace subsystem's budget: fitting a whole 64-worker fleet
        // (shifted-exp MLE + truncated-Gaussian moments + KS per
        // channel) must stay under 5 ms so `trace fit` and fitted
        // replay feel instant even on operational traces
        use straggler_sched::trace::{fit_traces, TraceRecorder, TraceStore};
        let mut rec = TraceRecorder::with_fleet("GC(2)", 64);
        let mut rng = Rng::seed_from_u64(0x7124CE);
        for round in 0..128 {
            for w in 0..64usize {
                let base = 1.6 * (1.0 + 0.3 * (w as f64 / 63.0));
                rec.push_flush(
                    round,
                    w,
                    0,
                    2,
                    base * (1.8 + 0.4 * rng.f64()),
                    5.5 * (0.8 + 0.4 * rng.f64()),
                    0.25,
                    2088,
                    false,
                    round as u32,
                );
            }
        }
        let store: TraceStore = rec.into_store();
        let fit = bench("trace/fit_fleet_64workers", || {
            black_box(fit_traces(black_box(&store)).unwrap());
        });
        println!(
            "trace fit at n = 64 ({} events): {:.3} ms/fit (target < 5 ms)",
            store.len(),
            fit.mean_ns / 1e6
        );
        all.push(fit);
        let bin = store.to_binary();
        all.push(bench("trace/encode_binary_8192events", || {
            black_box(store.to_binary());
        }));
        all.push(bench("trace/decode_binary_8192events", || {
            black_box(TraceStore::from_binary(black_box(&bin)).unwrap());
        }));
    }

    group("linalg oracle (d = 400, b = 60 — fig5 task shape)");
    {
        let mut rng = Rng::seed_from_u64(6);
        let x = Mat::from_fn(400, 60, |_, _| rng.normal());
        let theta: Vec<f64> = (0..400).map(|_| rng.normal()).collect();
        all.push(bench("linalg/gram_matvec_400x60", || {
            black_box(x.gram_matvec(black_box(&theta)));
        }));
    }

    group("pjrt runtime (quickstart artifact, d = 64, b = 32)");
    {
        let dir = straggler_sched::runtime::default_artifact_dir();
        if dir.join("manifest.json").exists() {
            match straggler_sched::runtime::Runtime::new(dir) {
                Ok(mut rt) => {
                    let x: Vec<f32> = (0..64 * 32).map(|i| (i % 13) as f32 / 7.0).collect();
                    let theta: Vec<f32> = (0..64).map(|i| (i % 7) as f32 / 5.0).collect();
                    rt.prepare("quickstart", "task_gram").unwrap();
                    all.push(bench("runtime/task_gram_execute_64x32", || {
                        black_box(rt.task_gram("quickstart", &x, &theta).unwrap());
                    }));
                }
                Err(e) => println!("runtime/task_gram_execute_64x32  SKIPPED ({e})"),
            }
        } else {
            println!("runtime/task_gram_execute_64x32  SKIPPED (run `make artifacts`)");
        }
    }

    match write_json_report("BENCH_hot_paths.json", "hot_paths", &all) {
        Ok(()) => println!("\nwrote BENCH_hot_paths.json ({} benchmarks)", all.len()),
        Err(e) => eprintln!("\ncould not write BENCH_hot_paths.json: {e}"),
    }
    // cargo sets a bench binary's CWD to the package root (rust/); also
    // refresh the committed in-tree baseline at the workspace root so
    // the perf trajectory is tracked by git (EXPERIMENTS.md §Perf)
    if std::path::Path::new("../Cargo.toml").exists() {
        match write_json_report("../BENCH_hot_paths.json", "hot_paths", &all) {
            Ok(()) => println!("refreshed workspace baseline ../BENCH_hot_paths.json"),
            Err(e) => eprintln!("could not refresh workspace baseline: {e}"),
        }
    }
    println!("coupled3 batched-vs-scalar speedup: {speedup:.2}× (acceptance gate ≥ 3×)");
}
