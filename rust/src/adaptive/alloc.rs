//! Non-cyclic task-allocation schedulers — the Behrouzi-Far & Soljanin
//! (arXiv:1808.02838) *allocation* axis, orthogonal to flush cadence.
//!
//! The paper's CS/SS fix a cyclic allocation; [18]'s RA randomizes it
//! uniformly.  Behrouzi-Far & Soljanin study the middle ground: how
//! tasks are *grouped onto* workers changes straggler tolerance even
//! with the execution order fixed.  Two variants ship here as
//! [`crate::scheduler::Scheduler`]s, reachable through the
//! `alloc-group` / `alloc-random` policies of [`super::policy`]:
//!
//! * [`GroupAllocation`] — workers are partitioned into `n / r` groups
//!   of `r`; every member of a group holds the *same* `r`-task batch,
//!   staggered cyclically within the group so the group's members start
//!   on different tasks (in-group replication = straggler diversity per
//!   batch, zero diversity across batches — the contrast CS is designed
//!   to avoid, which is exactly why it belongs in the comparison set);
//! * random-batch — every worker draws an independent uniformly random
//!   `r`-subset in random order each round; this is
//!   [`crate::scheduler::RandomAssignment`]'s generalized `r < n` form,
//!   so the policy layer reuses that scheduler rather than duplicating
//!   it here.

use crate::scheduler::{Scheduler, ToMatrix};
use crate::util::rng::Rng;

/// Group allocation: `n / r` disjoint worker groups, each replicating
/// one `r`-task batch with in-group cyclic stagger.  Requires `r | n`
/// (enforced by [`GroupAllocation::applicable`]; `schedule` asserts).
#[derive(Debug, Clone, Copy, Default)]
pub struct GroupAllocation;

impl GroupAllocation {
    /// Group allocation partitions both workers and tasks into `n / r`
    /// blocks, so it needs `r | n`.
    pub fn applicable(n: usize, r: usize) -> bool {
        r >= 1 && r <= n && n % r == 0
    }
}

impl Scheduler for GroupAllocation {
    fn name(&self) -> &'static str {
        "ALLOC-G"
    }

    fn schedule(&self, n: usize, r: usize, _rng: &mut Rng) -> ToMatrix {
        assert!(
            Self::applicable(n, r),
            "group allocation needs r | n (got n = {n}, r = {r})"
        );
        let rows = (0..n)
            .map(|w| {
                let (group, member) = (w / r, w % r);
                // batch `group` = tasks [group·r, (group+1)·r), walked
                // cyclically from an in-group stagger offset
                (0..r).map(|j| group * r + (member + j) % r).collect()
            })
            .collect();
        ToMatrix::new(n, rows)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn group_structure_matches_construction() {
        let mut rng = Rng::seed_from_u64(0);
        let to = GroupAllocation.schedule(6, 3, &mut rng);
        // group 0 = workers 0..3 on tasks {0,1,2}, staggered
        assert_eq!(to.row(0), &[0, 1, 2]);
        assert_eq!(to.row(1), &[1, 2, 0]);
        assert_eq!(to.row(2), &[2, 0, 1]);
        // group 1 = workers 3..6 on tasks {3,4,5}
        assert_eq!(to.row(3), &[3, 4, 5]);
        assert_eq!(to.row(5), &[5, 3, 4]);
        assert!(to.rows_distinct());
        assert!(to.covers_all_tasks());
        // every task replicated exactly r times, all inside its group
        assert!(to.coverage().iter().all(|&c| c == 3));
    }

    #[test]
    fn stagger_puts_each_batch_task_first_somewhere() {
        // in-group diversity: each task of a batch opens exactly one
        // member's row — the straggler-tolerance property of the scheme
        let mut rng = Rng::seed_from_u64(0);
        let to = GroupAllocation.schedule(8, 4, &mut rng);
        for group in 0..2 {
            let mut firsts: Vec<usize> =
                (0..4).map(|m| to.task(group * 4 + m, 0)).collect();
            firsts.sort_unstable();
            assert_eq!(firsts, (group * 4..group * 4 + 4).collect::<Vec<_>>());
        }
    }

    #[test]
    fn applicability_requires_divisibility() {
        assert!(GroupAllocation::applicable(12, 4));
        assert!(GroupAllocation::applicable(6, 6));
        assert!(GroupAllocation::applicable(5, 1));
        assert!(!GroupAllocation::applicable(12, 5));
        assert!(!GroupAllocation::applicable(4, 8));
        assert!(!GroupAllocation::applicable(4, 0));
    }

    #[test]
    #[should_panic(expected = "group allocation needs r | n")]
    fn schedule_rejects_ragged_groups() {
        let mut rng = Rng::seed_from_u64(0);
        GroupAllocation.schedule(7, 3, &mut rng);
    }

    #[test]
    fn full_load_degenerates_to_one_group() {
        let mut rng = Rng::seed_from_u64(0);
        let to = GroupAllocation.schedule(4, 4, &mut rng);
        // one group of everyone = the cyclic matrix
        let cs = crate::scheduler::CyclicScheduler.schedule(4, 4, &mut rng);
        assert_eq!(to.rows(), cs.rows());
    }
}
