//! Streaming per-worker delay estimation.
//!
//! One [`DelayEstimator`] tracks, per worker, the per-task computation
//! delay (EWMA mean/variance + empirical quantiles) and the per-message
//! communication delay (EWMA mean).  EWMA — not a uniform average — is
//! the point: when a worker's service rate *shifts* mid-run (the
//! shifting-straggler scenario of [`super::sim`]), the estimate
//! re-centers within `O(1/α)` observations instead of being anchored to
//! stale history, which is what lets [`super::PolicyEngine`] re-rank
//! workers while the shift is still happening.
//!
//! Feeding is caller-driven and **causal**: the cluster master calls
//! [`DelayEstimator::observe_flush`] per received `Result` frame (the
//! same `comp_us`/receive-timestamp measurements that populate
//! `RoundLog` and `DelayRecorder`), and the Monte-Carlo arm feeds each
//! round's simulated slot delays *after* evaluating the round, censored
//! at the round's completion time (per-slot — a slightly richer view
//! than the master's flush-grouped one; see the censoring note in
//! [`super::sim`]).

use crate::util::stats::{Ewma, StreamingQuantiles};

/// Default EWMA weight: re-centers an estimate within ~15 observations
/// of a rate shift while smoothing per-task noise.
pub const DEFAULT_EWMA_ALPHA: f64 = 0.2;

/// Snapshot of one worker's current delay model.
#[derive(Debug, Clone)]
pub struct WorkerEstimate {
    pub worker: usize,
    /// EWMA per-task computation delay (ms); `NaN` if unobserved.
    pub comp_mean_ms: f64,
    /// EW standard deviation of the per-task computation delay.
    pub comp_std_ms: f64,
    /// EWMA per-message communication delay (ms); `NaN` if unobserved.
    pub comm_mean_ms: f64,
    /// Empirical median of the per-task computation delay.
    pub comp_p50_ms: f64,
    /// Empirical 95th percentile of the per-task computation delay.
    pub comp_p95_ms: f64,
    /// Computation observations folded in so far.
    pub samples: u64,
}

/// Per-worker streaming delay models for an `n`-worker fleet.
#[derive(Debug, Clone)]
pub struct DelayEstimator {
    comp: Vec<Ewma>,
    comm: Vec<Ewma>,
    comp_q: Vec<StreamingQuantiles>,
}

impl DelayEstimator {
    pub fn new(n: usize) -> Self {
        Self::with_alpha(n, DEFAULT_EWMA_ALPHA)
    }

    pub fn with_alpha(n: usize, alpha: f64) -> Self {
        assert!(n >= 1, "need at least one worker");
        Self {
            comp: vec![Ewma::new(alpha); n],
            comm: vec![Ewma::new(alpha); n],
            comp_q: vec![StreamingQuantiles::new(); n],
        }
    }

    pub fn n(&self) -> usize {
        self.comp.len()
    }

    /// Fold in one task's observed delays: `comp_ms` to compute it,
    /// `comm_ms` to deliver the message it rode on.
    pub fn observe(&mut self, worker: usize, comp_ms: f64, comm_ms: f64) {
        self.comp[worker].push(comp_ms);
        self.comp_q[worker].push(comp_ms);
        self.comm[worker].push(comm_ms);
    }

    /// Fold in one flushed result group as measured by the cluster
    /// master: `tasks` tasks computed in `comp_total_ms` (the frame's
    /// `comp_us`), delivered with `comm_ms` of wire delay.  The group's
    /// computation time is attributed evenly across its tasks.
    pub fn observe_flush(&mut self, worker: usize, tasks: usize, comp_total_ms: f64, comm_ms: f64) {
        assert!(tasks >= 1, "a flush delivers at least one task");
        let per_task = comp_total_ms / tasks as f64;
        for _ in 0..tasks {
            self.comp[worker].push(per_task);
            self.comp_q[worker].push(per_task);
        }
        self.comm[worker].push(comm_ms);
    }

    /// Computation observations folded in for `worker`.
    pub fn samples(&self, worker: usize) -> u64 {
        self.comp[worker].count()
    }

    /// EWMA per-task computation delay of `worker` (ms); `NaN` if
    /// unobserved.  The O(1) accessor the per-round policies read —
    /// [`DelayEstimator::estimate`] additionally sorts the quantile
    /// state and is for reports.
    pub fn comp_mean_ms(&self, worker: usize) -> f64 {
        self.comp[worker].mean()
    }

    /// Current snapshot for one worker.
    pub fn estimate(&self, worker: usize) -> WorkerEstimate {
        let q = &self.comp_q[worker];
        let (p50, p95) = if q.count() > 0 {
            let qs = q.quantiles(&[0.5, 0.95]);
            (qs[0], qs[1])
        } else {
            (f64::NAN, f64::NAN)
        };
        WorkerEstimate {
            worker,
            comp_mean_ms: self.comp[worker].mean(),
            comp_std_ms: self.comp[worker].std_dev(),
            comm_mean_ms: self.comm[worker].mean(),
            comp_p50_ms: p50,
            comp_p95_ms: p95,
            samples: self.comp[worker].count(),
        }
    }

    /// Snapshots for the whole fleet, worker order.
    pub fn estimates(&self) -> Vec<WorkerEstimate> {
        (0..self.n()).map(|w| self.estimate(w)).collect()
    }

    /// Workers sorted fastest-first by estimated per-task computation
    /// delay.  Unobserved workers sort last, in index order, so a fresh
    /// estimator yields the identity ranking (round 0 is always the
    /// static plan) and the output is deterministic for any estimator
    /// state — the policy-determinism contract.
    pub fn speed_ranking(&self) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..self.n()).collect();
        idx.sort_by(|&a, &b| {
            let (ma, mb) = (self.score(a), self.score(b));
            ma.total_cmp(&mb).then(a.cmp(&b))
        });
        idx
    }

    /// Ranking score: EWMA per-task computation delay; `+∞` when the
    /// worker has never been observed (ranks behind every observed one;
    /// `total_cmp` keeps `∞` ties resolved by index).
    fn score(&self, worker: usize) -> f64 {
        if self.comp[worker].count() == 0 {
            f64::INFINITY
        } else {
            self.comp[worker].mean()
        }
    }

    /// Workers sorted fastest-first by the empirical `q`-quantile of
    /// their per-task computation delay (the
    /// [`StreamingQuantiles`] state behind `comp_p50/p95`) — the
    /// heavy-tail-robust ranking of the `order@pQQ` policy: a worker
    /// whose *mean* is good but whose tail occasionally stalls a round
    /// ranks behind a steady one, which the EWMA mean cannot see.
    /// Unobserved workers rank last in index order, so the fresh-state
    /// identity and determinism contracts of
    /// [`DelayEstimator::speed_ranking`] carry over.
    pub fn speed_ranking_quantile(&self, q: f64) -> Vec<usize> {
        assert!((0.0..=1.0).contains(&q), "quantile level must be in [0,1]");
        // quantile() re-sorts the observation buffer in exact mode —
        // evaluate it once per worker, never inside the comparator
        let scores: Vec<f64> = (0..self.n())
            .map(|w| {
                if self.comp_q[w].count() == 0 {
                    f64::INFINITY
                } else {
                    self.comp_q[w].quantile(q)
                }
            })
            .collect();
        let mut idx: Vec<usize> = (0..self.n()).collect();
        idx.sort_by(|&a, &b| scores[a].total_cmp(&scores[b]).then(a.cmp(&b)));
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fresh_estimator_ranks_identity() {
        let est = DelayEstimator::new(5);
        assert_eq!(est.speed_ranking(), vec![0, 1, 2, 3, 4]);
        assert!(est.estimate(0).comp_mean_ms.is_nan());
        assert_eq!(est.estimate(0).samples, 0);
    }

    #[test]
    fn ranking_orders_by_observed_means() {
        let mut est = DelayEstimator::new(4);
        for _ in 0..20 {
            est.observe(0, 0.3, 0.5);
            est.observe(1, 0.1, 0.5);
            est.observe(3, 0.2, 0.5);
        }
        // worker 2 unobserved → last; others fastest-first
        assert_eq!(est.speed_ranking(), vec![1, 3, 0, 2]);
    }

    #[test]
    fn ranking_tracks_a_speed_shift() {
        let mut est = DelayEstimator::new(2);
        for _ in 0..50 {
            est.observe(0, 0.1, 0.5);
            est.observe(1, 0.3, 0.5);
        }
        assert_eq!(est.speed_ranking(), vec![0, 1]);
        // worker 0 becomes the straggler; EWMA re-ranks in ~15 obs
        for _ in 0..15 {
            est.observe(0, 0.3, 0.5);
            est.observe(1, 0.1, 0.5);
        }
        assert_eq!(est.speed_ranking(), vec![1, 0]);
    }

    #[test]
    fn flush_attributes_comp_evenly() {
        let mut est = DelayEstimator::new(1);
        est.observe_flush(0, 4, 2.0, 0.7);
        let e = est.estimate(0);
        assert_eq!(e.samples, 4);
        assert!((e.comp_mean_ms - 0.5).abs() < 1e-12);
        assert!((e.comm_mean_ms - 0.7).abs() < 1e-12);
        assert!((e.comp_p50_ms - 0.5).abs() < 1e-12);
    }

    #[test]
    fn quantile_ranking_sees_the_tail_the_mean_hides() {
        let mut est = DelayEstimator::new(2);
        // worker 0: steady 0.3 ms; worker 1: usually 0.1 ms but every
        // 10th task stalls 3 ms — better EWMA mean, far worse p95
        for i in 0..200 {
            est.observe(0, 0.3, 0.5);
            est.observe(1, if i % 10 == 0 { 3.0 } else { 0.1 }, 0.5);
        }
        assert_eq!(est.speed_ranking(), vec![1, 0], "mean prefers the spiky worker");
        assert_eq!(
            est.speed_ranking_quantile(0.95),
            vec![0, 1],
            "p95 prefers the steady worker"
        );
        // low quantiles agree with the typical case again
        assert_eq!(est.speed_ranking_quantile(0.5), vec![1, 0]);
    }

    #[test]
    fn quantile_ranking_fresh_state_is_identity() {
        let est = DelayEstimator::new(4);
        assert_eq!(est.speed_ranking_quantile(0.95), vec![0, 1, 2, 3]);
    }

    #[test]
    fn quantiles_reflect_the_stream() {
        let mut est = DelayEstimator::new(1);
        for i in 0..100 {
            est.observe(0, i as f64, 0.0);
        }
        let e = est.estimate(0);
        assert!((e.comp_p50_ms - 49.5).abs() < 1.0, "p50 {}", e.comp_p50_ms);
        assert!((e.comp_p95_ms - 94.05).abs() < 1.5, "p95 {}", e.comp_p95_ms);
    }
}
