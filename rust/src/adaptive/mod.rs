//! Online adaptive scheduling — per-worker delay estimation and
//! round-by-round re-planning, the fourth pillar next to
//! [`crate::scheme`], the engines ([`crate::sim`]) and the cluster data
//! plane ([`crate::coordinator`]).
//!
//! The paper fixes the computation schedule before the first round, yet
//! its whole premise is that worker delays are random — and on real
//! clusters they *drift* (§VI's EC2 measurements).  Egger, Kas Hanna &
//! Bitar (arXiv:2304.08589) show that adapting each worker's
//! computation load online to its estimated straggling behavior beats
//! any static assignment, and Behrouzi-Far & Soljanin (arXiv:1808.02838)
//! show the task-to-worker *allocation* itself is a live design axis.
//! This module makes every uncoded scheme re-plannable between rounds,
//! on the Monte-Carlo engines and the live cluster alike:
//!
//! * [`estimator`] — streaming per-worker delay models: EWMA
//!   mean/variance ([`crate::util::stats::Ewma`]) plus
//!   empirical quantiles ([`crate::util::stats::StreamingQuantiles`]),
//!   fed from the cluster's measured `Result` timestamps (the same
//!   measurements that populate `RoundLog`/`DelayRecorder`) and from
//!   simulated arrivals in the Monte-Carlo arm — causally: round `t`'s
//!   decisions only see arrivals from rounds `< t`;
//! * [`policy`] — the [`Policy`] decision rules behind a
//!   [`PolicyEngine`]: at each round boundary the engine consumes the
//!   estimator state and emits a fresh [`RoundPlan`] (worker order,
//!   per-worker flush sizes, optional assignment override).  Shipped
//!   policies: `static` (frozen plan, bit-identical to the registry
//!   path), `order` (re-rank the cyclic/staircase worker order by
//!   estimated speed, spreading the currently-fast workers' rows evenly
//!   over task space), `order@pQQ` (the same re-ranking by the
//!   empirical QQ-th-percentile delay — heavy-tailed fleets, where a
//!   good mean can hide a round-stalling tail), `load` (re-split
//!   per-worker flush sizes `s_i` à la GCH on a rank ramp, constrained
//!   to divisors of the canonical block so partial sums stay
//!   mergeable), `load-rate` (re-split proportionally to estimated
//!   *service-rate ratios* instead of ranks — the response is sized by
//!   how much slower a worker actually is), and the Behrouzi-Far &
//!   Soljanin allocation variants `alloc-group` / `alloc-random` as
//!   static allocation policies;
//! * [`alloc`] — the non-cyclic allocation schedulers those variants
//!   build on;
//! * [`sim`] — the sequential multi-round re-planning Monte-Carlo arm
//!   ([`sim::run_policy_rounds`], also reachable as
//!   `MonteCarlo::estimate_policy`) plus the shifting-straggler
//!   scenario ([`sim::ShiftingStraggler`], [`sim::two_tier_model`]) —
//!   worker speeds change mid-run, the exact case static schemes lose.
//!
//! The live-cluster side enters through
//! [`crate::scheme::SchemeRegistry::adaptive_plan`] and
//! `ClusterConfig::policy`: the master re-issues per-round `Assign`
//! frames from the engine's plan (protocol stays v3 — assignment was
//! always per-round; only the plan's *source* changes).
//!
//! Determinism contract: every policy decision is a pure function of
//! `(round, estimator state)` (plus the scheduling RNG for
//! `alloc-random`, which redraws like RA), so a fixed seed + arrival
//! trace reproduces the decision sequence exactly — pinned by
//! `rust/tests/adaptive.rs` via [`sim::PolicyOutcome::decision_digest`].

pub mod alloc;
pub mod estimator;
pub mod policy;
pub mod sim;

pub use alloc::GroupAllocation;
pub use estimator::{DelayEstimator, WorkerEstimate, DEFAULT_EWMA_ALPHA};
pub use policy::{
    snap_divisor, spread_offsets, PolicyEngine, PolicyKind, PolicySpec, RoundPlan, MAX_STALENESS,
};
pub use sim::{
    run_policy_rounds, two_tier_model, PerRound, PolicyOutcome, PolicyRunConfig,
    RoundDelayModel, ShiftingStraggler,
};
