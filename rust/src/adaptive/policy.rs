//! Re-planning policies and the engine that drives them.
//!
//! A policy is a decision rule: given the current
//! [`DelayEstimator`](super::DelayEstimator) state, emit a
//! [`RoundPlan`] for the next round — which base TO-matrix row each
//! worker executes, each worker's flush size, and (for the allocation
//! variants) an outright assignment override.  The
//! [`PolicyEngine`] owns the estimator + policy state and is the one
//! object both execution paths drive: the Monte-Carlo arm
//! ([`super::sim`]) and the cluster master
//! ([`crate::coordinator::run_cluster`]).
//!
//! Decisions are pure functions of `(round, estimator state)` — plus
//! the scheduling RNG for `alloc-random`, which redraws per round
//! exactly like RA — so a fixed seed + arrival trace reproduces the
//! decision sequence bit for bit ([`PolicyEngine::decision_digest`]).

use anyhow::{bail, ensure, Result};

use crate::scheduler::{RandomAssignment, Scheduler, ToMatrix};
use crate::scheme::SchemeId;
use crate::util::rng::Rng;

use super::alloc::GroupAllocation;
use super::estimator::DelayEstimator;

/// Which re-planning rule runs between rounds.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum PolicyKind {
    /// Frozen plan — today's registry path, bit-identical (pinned by
    /// `rust/tests/scheme_registry.rs`).
    Static,
    /// Re-rank the cyclic/staircase worker order by estimated speed:
    /// the `j`-th fastest worker gets base row [`spread_offsets`]`[j]`,
    /// so the currently-fast workers' rows tile task space evenly and
    /// their early slots cover *disjoint* tasks.
    AdaptiveOrder,
    /// Re-split per-worker flush sizes `s_i` à la GCH: the fastest
    /// worker keeps the full canonical block, slower workers ramp down
    /// to 1, every size [`snap_divisor`]-constrained to divide the
    /// canonical block so the master's range merge stays duplicate-safe.
    AdaptiveLoad,
    /// Behrouzi-Far & Soljanin group allocation (static assignment
    /// override; needs `r | n`).
    AllocGroup,
    /// Behrouzi-Far & Soljanin random-batch allocation: an independent
    /// random `r`-subset per worker, redrawn every round.
    AllocRandom,
}

impl PolicyKind {
    /// Parse the CLI/config spelling (case-insensitive):
    /// `static | order | load | alloc-group | alloc-random`.
    pub fn parse(name: &str) -> Result<PolicyKind> {
        Ok(match name.trim().to_lowercase().as_str() {
            "static" => PolicyKind::Static,
            "order" | "adaptive-order" => PolicyKind::AdaptiveOrder,
            "load" | "adaptive-load" => PolicyKind::AdaptiveLoad,
            "alloc-group" | "group" => PolicyKind::AllocGroup,
            "alloc-random" | "random" => PolicyKind::AllocRandom,
            other => bail!(
                "unknown policy {other:?} (static|order|load|alloc-group|alloc-random)"
            ),
        })
    }

    /// Does the policy consume estimator state between rounds?
    pub fn is_adaptive(self) -> bool {
        matches!(self, PolicyKind::AdaptiveOrder | PolicyKind::AdaptiveLoad)
    }

    /// Does the policy change *which tasks a worker holds*?  On the
    /// live cluster this forces full-dataset distribution (like RA) —
    /// `load` keeps assignments fixed and ships rows only.
    pub fn reassigns_rows(self) -> bool {
        matches!(
            self,
            PolicyKind::AdaptiveOrder | PolicyKind::AllocGroup | PolicyKind::AllocRandom
        )
    }

    /// The one policy × scheme × shape gate, shared by the Monte-Carlo
    /// arm ([`super::sim::run_policy_rounds`]) and the registry's
    /// cluster entry (`SchemeRegistry::adaptive_plan`): non-static
    /// policies need a fixed uncoded base plan to re-plan (CS, SS or
    /// GC(s) — GCH is itself a static load layout, RA/alloc-random
    /// re-randomize, and the coded wires fix their own assignment),
    /// `alloc-group` needs `r | n`, and `alloc-random` needs `r = n`
    /// (random batches may leave the k-distinct target uncoverable
    /// otherwise).
    pub fn validate_base(self, scheme: SchemeId, n: usize, r: usize) -> Result<()> {
        if self == PolicyKind::Static {
            return Ok(());
        }
        ensure!(
            matches!(scheme, SchemeId::Cs | SchemeId::Ss | SchemeId::Gc(_)),
            "policy {self} needs a fixed uncoded base plan to re-plan; \
             {scheme} has none — use --policy static, or a CS/SS/GC(s) \
             base (GCH is itself a static load layout: adapt it as \
             --policy load over GC(s))"
        );
        if self == PolicyKind::AllocGroup {
            ensure!(
                GroupAllocation::applicable(n, r),
                "alloc-group needs r | n (got n = {n}, r = {r})"
            );
        }
        if self == PolicyKind::AllocRandom {
            ensure!(
                r == n,
                "alloc-random needs r = n (random batches may leave the \
                 k-distinct target uncoverable otherwise)"
            );
        }
        Ok(())
    }
}

impl std::fmt::Display for PolicyKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            PolicyKind::Static => "static",
            PolicyKind::AdaptiveOrder => "order",
            PolicyKind::AdaptiveLoad => "load",
            PolicyKind::AllocGroup => "alloc-group",
            PolicyKind::AllocRandom => "alloc-random",
        })
    }
}

/// One round's plan, as emitted by [`PolicyEngine::plan`].
#[derive(Debug, Clone, PartialEq)]
pub struct RoundPlan {
    /// `order[w]` = index of the base TO-matrix row worker `w`
    /// executes (identity when the policy does not reorder).
    pub order: Vec<usize>,
    /// Per-worker flush sizes; every entry divides the canonical block.
    pub sizes: Vec<usize>,
    /// Assignment override (allocation policies) — replaces the base
    /// matrix outright; `order` is identity when set.
    pub to: Option<ToMatrix>,
}

impl RoundPlan {
    /// The frozen identity plan at a given shape.
    pub fn identity(n: usize, block: usize) -> Self {
        Self {
            order: (0..n).collect(),
            sizes: vec![block; n],
            to: None,
        }
    }

    /// The concrete TO matrix this plan executes over `base`: the
    /// assignment override when present, else `base`'s rows permuted so
    /// worker `w` runs row `order[w]` — the single materialization
    /// every consumer (MC arm, cluster master, benches) shares.
    pub fn materialize(&self, base: &ToMatrix) -> ToMatrix {
        match &self.to {
            Some(to) => to.clone(),
            None => ToMatrix::new(
                base.n(),
                (0..base.n())
                    .map(|w| base.row(self.order[w]).to_vec())
                    .collect(),
            ),
        }
    }
}

/// Offsets that spread `n` ranked items around the cyclic ring so that
/// every *prefix* of the ranking is (near-)maximally spaced: greedy
/// max–min cyclic distance, ties to the smallest offset.  `[0, n/2,
/// n/4, 3n/4, …]` — bit-reversal order for powers of two.  This is what
/// lets the `j` currently-fastest workers' cyclic rows cover `≈ j·r`
/// *distinct* tasks early instead of overlapping windows.
pub fn spread_offsets(n: usize) -> Vec<usize> {
    assert!(n >= 1);
    let mut offs = Vec::with_capacity(n);
    offs.push(0usize);
    let mut used = vec![false; n];
    used[0] = true;
    for _ in 1..n {
        let (mut best, mut best_d) = (usize::MAX, 0usize);
        for c in 0..n {
            if used[c] {
                continue;
            }
            let d = offs
                .iter()
                .map(|&o| {
                    let fwd = (c + n - o) % n;
                    fwd.min(n - fwd)
                })
                .min()
                .expect("offs nonempty");
            if d > best_d {
                best = c;
                best_d = d;
            }
        }
        offs.push(best);
        used[best] = true;
    }
    offs
}

/// Largest divisor of `block` that is `≤ max(v, 1)` — the mergeability
/// constraint on per-worker flush sizes: a worker flushing at
/// boundaries of a divisor of the canonical block always produces
/// ranges nested inside one canonical block, so the master's
/// duplicate-safe range merge ([`crate::coordinator::aggregate`])
/// keeps working across workers with *different* cadences.
pub fn snap_divisor(block: usize, v: usize) -> usize {
    assert!(block >= 1, "canonical block must be ≥ 1");
    let v = v.clamp(1, block);
    (1..=v).rev().find(|d| block % d == 0).expect("1 divides")
}

/// Policy + estimator state, driven at every round boundary.
pub struct PolicyEngine {
    kind: PolicyKind,
    n: usize,
    r: usize,
    /// Canonical flush block of the base scheme (`s` for GC(s),
    /// `max(s_fast, s_slow)` for GCH, 1 for per-task streaming).
    block: usize,
    pub estimator: DelayEstimator,
    last: Option<RoundPlan>,
    replans: usize,
    digest: u64,
}

impl PolicyEngine {
    pub fn new(kind: PolicyKind, n: usize, r: usize, block: usize) -> Self {
        assert!(n >= 1 && r >= 1 && r <= n, "degenerate fleet shape");
        assert!(block >= 1 && block <= r, "canonical block must satisfy 1 ≤ block ≤ r");
        Self {
            kind,
            n,
            r,
            block,
            estimator: DelayEstimator::new(n),
            last: None,
            replans: 0,
            digest: 0xcbf29ce484222325, // FNV-1a offset basis
        }
    }

    pub fn kind(&self) -> PolicyKind {
        self.kind
    }

    /// Feed one task observation (Monte-Carlo arm).
    pub fn observe(&mut self, worker: usize, comp_ms: f64, comm_ms: f64) {
        self.estimator.observe(worker, comp_ms, comm_ms);
    }

    /// Feed one flushed result group (cluster master).
    pub fn observe_flush(
        &mut self,
        worker: usize,
        tasks: usize,
        comp_total_ms: f64,
        comm_ms: f64,
    ) {
        self.estimator.observe_flush(worker, tasks, comp_total_ms, comm_ms);
    }

    /// Decide round `round`'s plan from the current estimator state.
    /// `rng_sched` is consumed only by `alloc-random` (per-round
    /// redraw, RA-style).
    pub fn plan(&mut self, round: usize, rng_sched: &mut Rng) -> RoundPlan {
        let n = self.n;
        // no evidence yet → the static plan (the estimator-driven
        // policies must not impose an uninformed bias on round 0; the
        // allocation overrides are evidence-free by design)
        let unobserved = self.kind.is_adaptive()
            && (0..n).all(|w| self.estimator.samples(w) == 0);
        let plan = match self.kind {
            _ if unobserved => RoundPlan::identity(n, self.block),
            PolicyKind::Static => RoundPlan::identity(n, self.block),
            PolicyKind::AdaptiveOrder => {
                let ranking = self.estimator.speed_ranking();
                let offsets = spread_offsets(n);
                let mut order = vec![0usize; n];
                for (j, &w) in ranking.iter().enumerate() {
                    order[w] = offsets[j];
                }
                RoundPlan {
                    order,
                    sizes: vec![self.block; n],
                    to: None,
                }
            }
            PolicyKind::AdaptiveLoad => {
                let ranking = self.estimator.speed_ranking();
                let mut sizes = vec![0usize; n];
                for (j, &w) in ranking.iter().enumerate() {
                    // linear ramp block → 1 across the speed ranking,
                    // snapped to divisors of the canonical block
                    let t = if n == 1 { 0.0 } else { j as f64 / (n - 1) as f64 };
                    let raw = self.block as f64 + (1.0 - self.block as f64) * t;
                    sizes[w] = snap_divisor(self.block, raw.round() as usize);
                }
                RoundPlan {
                    order: (0..n).collect(),
                    sizes,
                    to: None,
                }
            }
            PolicyKind::AllocGroup => RoundPlan {
                order: (0..n).collect(),
                sizes: vec![self.block; n],
                to: Some(GroupAllocation.schedule(n, self.r, rng_sched)),
            },
            PolicyKind::AllocRandom => RoundPlan {
                order: (0..n).collect(),
                sizes: vec![self.block; n],
                to: Some(RandomAssignment.schedule(n, self.r, rng_sched)),
            },
        };
        if self.last.as_ref() != Some(&plan) {
            self.replans += 1;
        }
        self.fold_digest(round, &plan);
        self.last = Some(plan.clone());
        plan
    }

    /// How many rounds changed the plan (round 0 counts as the first).
    pub fn replans(&self) -> usize {
        self.replans
    }

    /// FNV-1a fold of every decision so far — the determinism pin:
    /// identical seeds + arrival traces must yield identical digests.
    pub fn decision_digest(&self) -> u64 {
        self.digest
    }

    fn fold_digest(&mut self, round: usize, plan: &RoundPlan) {
        const PRIME: u64 = 0x100000001b3;
        let mut h = self.digest;
        let mut fold = |v: u64| {
            h ^= v;
            h = h.wrapping_mul(PRIME);
        };
        fold(round as u64);
        for &o in &plan.order {
            fold(o as u64);
        }
        for &s in &plan.sizes {
            fold(s as u64);
        }
        if let Some(to) = &plan.to {
            for row in to.rows() {
                for &t in row {
                    fold(t as u64 ^ 0x5A5A);
                }
            }
        }
        self.digest = h;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_spellings_and_display_roundtrip() {
        for (s, want) in [
            ("static", PolicyKind::Static),
            ("ORDER", PolicyKind::AdaptiveOrder),
            ("adaptive-load", PolicyKind::AdaptiveLoad),
            (" alloc-group ", PolicyKind::AllocGroup),
            ("alloc-random", PolicyKind::AllocRandom),
        ] {
            assert_eq!(PolicyKind::parse(s).unwrap(), want, "{s:?}");
        }
        for kind in [
            PolicyKind::Static,
            PolicyKind::AdaptiveOrder,
            PolicyKind::AdaptiveLoad,
            PolicyKind::AllocGroup,
            PolicyKind::AllocRandom,
        ] {
            assert_eq!(PolicyKind::parse(&kind.to_string()).unwrap(), kind);
        }
        assert!(PolicyKind::parse("wat").is_err());
    }

    #[test]
    fn spread_offsets_is_a_spread_permutation() {
        assert_eq!(spread_offsets(8), vec![0, 4, 2, 6, 1, 3, 5, 7]);
        assert_eq!(spread_offsets(1), vec![0]);
        for n in 1..=17 {
            let offs = spread_offsets(n);
            let mut sorted = offs.clone();
            sorted.sort_unstable();
            assert_eq!(sorted, (0..n).collect::<Vec<_>>(), "n={n}");
        }
        // the defining property: early prefixes are maximally spaced —
        // at n = 12 the first four offsets sit pairwise ≥ 3 apart
        // cyclically (after that the gaps necessarily shrink to 1)
        let offs = spread_offsets(12);
        assert_eq!(&offs[..4], &[0, 6, 3, 9]);
        for i in 0..4 {
            for j in 0..i {
                let d = (offs[i] + 12 - offs[j]) % 12;
                assert!(d.min(12 - d) >= 3, "offsets {} and {}", offs[j], offs[i]);
            }
        }
    }

    #[test]
    fn snap_divisor_picks_largest_dividing() {
        assert_eq!(snap_divisor(4, 4), 4);
        assert_eq!(snap_divisor(4, 3), 2);
        assert_eq!(snap_divisor(4, 2), 2);
        assert_eq!(snap_divisor(4, 1), 1);
        assert_eq!(snap_divisor(6, 5), 3);
        assert_eq!(snap_divisor(6, 4), 3);
        assert_eq!(snap_divisor(1, 9), 1);
        assert_eq!(snap_divisor(5, 0), 1, "clamps up to 1");
        assert_eq!(snap_divisor(3, 7), 3, "clamps down to block");
    }

    #[test]
    fn order_policy_spreads_the_fast_prefix() {
        let mut eng = PolicyEngine::new(PolicyKind::AdaptiveOrder, 8, 8, 1);
        let mut rng = Rng::seed_from_u64(0);
        // no observations yet → round 0 is the static identity plan
        let p0 = eng.plan(0, &mut rng);
        assert_eq!(p0, RoundPlan::identity(8, 1));
        // make workers 5 and 6 the fast pair → they get offsets 0 and 4
        for _ in 0..30 {
            for w in 0..8 {
                let ms = if w == 5 || w == 6 { 0.1 } else { 0.4 };
                eng.observe(w, ms, 0.5);
            }
        }
        let p1 = eng.plan(1, &mut rng);
        let d = (p1.order[5] + 8 - p1.order[6]) % 8;
        assert_eq!(d.min(8 - d), 4, "fast pair must sit opposite: {:?}", p1.order);
        assert!(eng.replans() >= 2);
    }

    #[test]
    fn load_policy_sizes_divide_block_and_ramp_by_rank() {
        let mut eng = PolicyEngine::new(PolicyKind::AdaptiveLoad, 6, 6, 4);
        let mut rng = Rng::seed_from_u64(0);
        for _ in 0..30 {
            for w in 0..6 {
                eng.observe(w, 0.1 * (w + 1) as f64, 0.5);
            }
        }
        let p = eng.plan(1, &mut rng);
        assert_eq!(p.order, (0..6).collect::<Vec<_>>(), "load does not reorder");
        assert!(p.sizes.iter().all(|&s| 4 % s == 0), "{:?}", p.sizes);
        // worker 0 is fastest → full block; worker 5 slowest → 1
        assert_eq!(p.sizes[0], 4);
        assert_eq!(p.sizes[5], 1);
        for w in 0..5 {
            assert!(p.sizes[w] >= p.sizes[w + 1], "monotone ramp: {:?}", p.sizes);
        }
    }

    #[test]
    fn unobserved_adaptive_policies_emit_the_static_plan() {
        let mut rng = Rng::seed_from_u64(0);
        for kind in [PolicyKind::AdaptiveOrder, PolicyKind::AdaptiveLoad] {
            let mut eng = PolicyEngine::new(kind, 6, 6, 3);
            assert_eq!(
                eng.plan(0, &mut rng),
                RoundPlan::identity(6, 3),
                "{kind}: round 0 must be static"
            );
        }
    }

    #[test]
    fn materialize_permutes_rows_or_applies_override() {
        let mut rng = Rng::seed_from_u64(0);
        let base = crate::scheduler::CyclicScheduler.schedule(4, 2, &mut rng);
        let plan = RoundPlan {
            order: vec![2, 0, 3, 1],
            sizes: vec![1; 4],
            to: None,
        };
        let to = plan.materialize(&base);
        for w in 0..4 {
            assert_eq!(to.row(w), base.row(plan.order[w]), "worker {w}");
        }
        assert_eq!(RoundPlan::identity(4, 1).materialize(&base).rows(), base.rows());
        let with_override = RoundPlan {
            to: Some(base.clone()),
            ..RoundPlan::identity(4, 1)
        };
        assert_eq!(with_override.materialize(&base).rows(), base.rows());
    }

    #[test]
    fn validate_base_gates_policy_scheme_shapes() {
        use SchemeId::*;
        let v = |p: PolicyKind, s, n, r| p.validate_base(s, n, r).is_ok();
        assert!(v(PolicyKind::Static, Pc, 6, 3), "static allows everything");
        assert!(v(PolicyKind::AdaptiveOrder, Cs, 6, 3));
        assert!(v(PolicyKind::AdaptiveLoad, Gc(2), 6, 4));
        assert!(!v(PolicyKind::AdaptiveOrder, Pc, 6, 3), "coded");
        assert!(!v(PolicyKind::AdaptiveLoad, GcHet(2, 1), 6, 4), "GCH");
        assert!(!v(PolicyKind::AdaptiveOrder, Ra, 6, 6), "randomized");
        assert!(!v(PolicyKind::AllocGroup, Cs, 6, 4), "needs r | n");
        assert!(v(PolicyKind::AllocGroup, Cs, 6, 3));
        assert!(!v(PolicyKind::AllocRandom, Cs, 6, 3), "needs r = n");
        assert!(v(PolicyKind::AllocRandom, Cs, 6, 6));
    }

    #[test]
    fn alloc_policies_override_assignment() {
        let mut rng = Rng::seed_from_u64(1);
        let mut eng = PolicyEngine::new(PolicyKind::AllocGroup, 6, 3, 1);
        let p = eng.plan(0, &mut rng);
        let to = p.to.expect("group allocation overrides");
        assert_eq!(to.row(0), &[0, 1, 2]);
        // deterministic: second round identical, no replan counted
        let p2 = eng.plan(1, &mut rng);
        assert_eq!(p.to, p2.to);
        assert_eq!(eng.replans(), 1);

        let mut eng = PolicyEngine::new(PolicyKind::AllocRandom, 6, 3, 1);
        let a = eng.plan(0, &mut rng).to.unwrap();
        let b = eng.plan(1, &mut rng).to.unwrap();
        assert_ne!(a, b, "random-batch redraws per round");
    }

    #[test]
    fn digest_is_deterministic_and_decision_sensitive() {
        let run = |obs: f64| {
            let mut eng = PolicyEngine::new(PolicyKind::AdaptiveOrder, 4, 4, 1);
            let mut rng = Rng::seed_from_u64(0);
            for round in 0..5 {
                for w in 0..4 {
                    eng.observe(w, if w == 0 { obs } else { 0.4 }, 0.5);
                }
                eng.plan(round, &mut rng);
            }
            eng.decision_digest()
        };
        assert_eq!(run(0.1), run(0.1), "same trace → same digest");
        assert_ne!(run(0.1), run(0.9), "different ranking → different digest");
    }
}
