//! Re-planning policies and the engine that drives them.
//!
//! A policy is a decision rule: given the current
//! [`DelayEstimator`](super::DelayEstimator) state, emit a
//! [`RoundPlan`] for the next round — which base TO-matrix row each
//! worker executes, each worker's flush size, and (for the allocation
//! variants) an outright assignment override.  The
//! [`PolicyEngine`] owns the estimator + policy state and is the one
//! object both execution paths drive: the Monte-Carlo arm
//! ([`super::sim`]) and the cluster master
//! ([`crate::coordinator::run_cluster`]).
//!
//! Decisions are pure functions of `(round, estimator state)` — plus
//! the scheduling RNG for `alloc-random`, which redraws per round
//! exactly like RA — so a fixed seed + arrival trace reproduces the
//! decision sequence bit for bit ([`PolicyEngine::decision_digest`]).

use anyhow::{bail, ensure, Result};

use crate::scheduler::{RandomAssignment, Scheduler, ToMatrix};
use crate::scheme::SchemeId;
use crate::util::fnv::Fnv1a;
use crate::util::rng::Rng;

use super::alloc::GroupAllocation;
use super::estimator::DelayEstimator;

/// Which re-planning rule runs between rounds.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum PolicyKind {
    /// Frozen plan — today's registry path, bit-identical (pinned by
    /// `rust/tests/scheme_registry.rs`).
    Static,
    /// Re-rank the cyclic/staircase worker order by estimated speed:
    /// the `j`-th fastest worker gets base row [`spread_offsets`]`[j]`,
    /// so the currently-fast workers' rows tile task space evenly and
    /// their early slots cover *disjoint* tasks.
    AdaptiveOrder,
    /// [`PolicyKind::AdaptiveOrder`] ranked by the empirical
    /// `q`-quantile of the per-task computation delay instead of the
    /// EWMA mean (`q` stored in percent, e.g. `95` for `order@p95`) —
    /// the heavy-tailed-fleet variant: a worker whose mean looks fast
    /// but whose tail stalls rounds ranks where its tail puts it.
    AdaptiveOrderQuantile(u16),
    /// Re-split per-worker flush sizes `s_i` à la GCH: the fastest
    /// worker keeps the full canonical block, slower workers ramp down
    /// to 1, every size [`snap_divisor`]-constrained to divide the
    /// canonical block so the master's range merge stays duplicate-safe.
    AdaptiveLoad,
    /// Re-split flush sizes **proportional to estimated service
    /// rates** (`1 / mean per-task delay`), replacing
    /// [`PolicyKind::AdaptiveLoad`]'s rank ramp: a worker half as fast
    /// as the fleet's fastest flushes blocks half as large (still
    /// [`snap_divisor`]-constrained, floor 1).  Rank only orders
    /// workers; rate ratios *size* the response to how much slower
    /// they actually are.
    LoadRate,
    /// Behrouzi-Far & Soljanin group allocation (static assignment
    /// override; needs `r | n`).
    AllocGroup,
    /// Behrouzi-Far & Soljanin random-batch allocation: an independent
    /// random `r`-subset per worker, redrawn every round.
    AllocRandom,
}

impl PolicyKind {
    /// Parse the CLI/config spelling (case-insensitive):
    /// `static | order | order@pQQ | load | load-rate | alloc-group |
    /// alloc-random` — `order@p95` ranks by the empirical 95th
    /// percentile (any `QQ ∈ [1, 99]`).
    pub fn parse(name: &str) -> Result<PolicyKind> {
        let lower = name.trim().to_lowercase();
        if let Some(q) = lower
            .strip_prefix("order@p")
            .or_else(|| lower.strip_prefix("adaptive-order@p"))
        {
            let q: u16 = q.parse().map_err(|_| {
                anyhow::anyhow!("bad quantile in {name:?}; want order@pQQ with QQ ∈ [1, 99]")
            })?;
            ensure!(
                (1..=99).contains(&q),
                "order@p quantile must be in [1, 99], got {q}"
            );
            return Ok(PolicyKind::AdaptiveOrderQuantile(q));
        }
        Ok(match lower.as_str() {
            "static" => PolicyKind::Static,
            "order" | "adaptive-order" => PolicyKind::AdaptiveOrder,
            "load" | "adaptive-load" => PolicyKind::AdaptiveLoad,
            "load-rate" | "adaptive-load-rate" | "rate" => PolicyKind::LoadRate,
            "alloc-group" | "group" => PolicyKind::AllocGroup,
            "alloc-random" | "random" => PolicyKind::AllocRandom,
            other => bail!(
                "unknown policy {other:?} \
                 (static|order|order@pQQ|load|load-rate|alloc-group|alloc-random)"
            ),
        })
    }

    /// Does the policy consume estimator state between rounds?
    pub fn is_adaptive(self) -> bool {
        matches!(
            self,
            PolicyKind::AdaptiveOrder
                | PolicyKind::AdaptiveOrderQuantile(_)
                | PolicyKind::AdaptiveLoad
                | PolicyKind::LoadRate
        )
    }

    /// Does the policy change *which tasks a worker holds*?  On the
    /// live cluster this forces full-dataset distribution (like RA) —
    /// the load policies keep assignments fixed and ship rows only.
    pub fn reassigns_rows(self) -> bool {
        matches!(
            self,
            PolicyKind::AdaptiveOrder
                | PolicyKind::AdaptiveOrderQuantile(_)
                | PolicyKind::AllocGroup
                | PolicyKind::AllocRandom
        )
    }

    /// The one policy × scheme × shape gate, shared by the Monte-Carlo
    /// arm ([`super::sim::run_policy_rounds`]) and the registry's
    /// cluster entry (`SchemeRegistry::adaptive_plan`): non-static
    /// policies need a fixed uncoded base plan to re-plan (CS, SS or
    /// GC(s) — GCH is itself a static load layout, RA/alloc-random
    /// re-randomize, and the coded wires fix their own assignment),
    /// `alloc-group` needs `r | n`, and `alloc-random` needs `r = n`
    /// (random batches may leave the k-distinct target uncoverable
    /// otherwise).
    pub fn validate_base(self, scheme: SchemeId, n: usize, r: usize) -> Result<()> {
        if self == PolicyKind::Static {
            return Ok(());
        }
        ensure!(
            matches!(scheme, SchemeId::Cs | SchemeId::Ss | SchemeId::Gc(_)),
            "policy {self} needs a fixed uncoded base plan to re-plan; \
             {scheme} has none — use --policy static, or a CS/SS/GC(s) \
             base (GCH is itself a static load layout: adapt it as \
             --policy load over GC(s))"
        );
        if self == PolicyKind::AllocGroup {
            ensure!(
                GroupAllocation::applicable(n, r),
                "alloc-group needs r | n (got n = {n}, r = {r})"
            );
        }
        if self == PolicyKind::AllocRandom {
            ensure!(
                r == n,
                "alloc-random needs r = n (random batches may leave the \
                 k-distinct target uncoverable otherwise)"
            );
        }
        Ok(())
    }
}

impl std::fmt::Display for PolicyKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PolicyKind::Static => f.write_str("static"),
            PolicyKind::AdaptiveOrder => f.write_str("order"),
            PolicyKind::AdaptiveOrderQuantile(q) => write!(f, "order@p{q}"),
            PolicyKind::AdaptiveLoad => f.write_str("load"),
            PolicyKind::LoadRate => f.write_str("load-rate"),
            PolicyKind::AllocGroup => f.write_str("alloc-group"),
            PolicyKind::AllocRandom => f.write_str("alloc-random"),
        }
    }
}

/// Upper bound on the bounded-staleness window `S`: the master keeps one
/// aggregation arena per in-flight round, so the ring is sized `S`
/// slots — a small constant keeps the stale-gradient bound meaningful
/// (gap ≤ S − 1) and the memory footprint flat.
pub const MAX_STALENESS: usize = 8;

/// A policy *spec*: the re-planning rule plus the bounded-staleness
/// window `S` of the async data plane — the second axis of the policy
/// grammar (`order@p95@s2`, `static@s3`).  `S = 1` is the synchronous
/// path (bit-identical to today's, pinned by test); `S ≥ 2` keeps up to
/// `S` rounds in flight, applying each round's aggregate against a θ at
/// most `S − 1` versions stale (Egger, Kas Hanna & Bitar,
/// arXiv:2304.08589).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct PolicySpec {
    pub kind: PolicyKind,
    /// Bounded-staleness window `S ∈ [1, MAX_STALENESS]`; 1 = sync.
    pub staleness: usize,
}

impl PolicySpec {
    /// The synchronous spec for a bare policy (`S = 1`).
    pub fn sync(kind: PolicyKind) -> Self {
        Self { kind, staleness: 1 }
    }

    /// Parse the CLI/config spelling: any [`PolicyKind`] spelling,
    /// optionally suffixed `@sS` with `S ∈ [1, MAX_STALENESS]` —
    /// `order@s2`, `order@p95@s3`, `static@s2`.  No suffix means `S = 1`
    /// (synchronous).
    pub fn parse(name: &str) -> Result<PolicySpec> {
        let lower = name.trim().to_lowercase();
        let (kind_str, staleness) = match lower.rfind("@s") {
            Some(pos) => {
                let digits = &lower[pos + 2..];
                ensure!(
                    !digits.is_empty() && digits.bytes().all(|b| b.is_ascii_digit()),
                    "bad staleness in {name:?}; want POLICY@sS with \
                     S ∈ [1, {MAX_STALENESS}] (e.g. order@s2, order@p95@s2)"
                );
                let s: usize = digits.parse().map_err(|_| {
                    anyhow::anyhow!("bad staleness in {name:?}; want POLICY@sS")
                })?;
                ensure!(
                    (1..=MAX_STALENESS).contains(&s),
                    "staleness must be in [1, {MAX_STALENESS}], got {s}"
                );
                (&lower[..pos], s)
            }
            None => (lower.as_str(), 1),
        };
        Ok(PolicySpec {
            kind: PolicyKind::parse(kind_str)?,
            staleness,
        })
    }
}

impl std::fmt::Display for PolicySpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        if self.staleness <= 1 {
            write!(f, "{}", self.kind)
        } else {
            write!(f, "{}@s{}", self.kind, self.staleness)
        }
    }
}

/// One round's plan, as emitted by [`PolicyEngine::plan`].
#[derive(Debug, Clone, PartialEq)]
pub struct RoundPlan {
    /// `order[w]` = index of the base TO-matrix row worker `w`
    /// executes (identity when the policy does not reorder).
    pub order: Vec<usize>,
    /// Per-worker flush sizes; every entry divides the canonical block.
    pub sizes: Vec<usize>,
    /// Assignment override (allocation policies) — replaces the base
    /// matrix outright; `order` is identity when set.
    pub to: Option<ToMatrix>,
}

impl RoundPlan {
    /// The frozen identity plan at a given shape.
    pub fn identity(n: usize, block: usize) -> Self {
        Self {
            order: (0..n).collect(),
            sizes: vec![block; n],
            to: None,
        }
    }

    /// The concrete TO matrix this plan executes over `base`: the
    /// assignment override when present, else `base`'s rows permuted so
    /// worker `w` runs row `order[w]` — the single materialization
    /// every consumer (MC arm, cluster master, benches) shares.
    pub fn materialize(&self, base: &ToMatrix) -> ToMatrix {
        match &self.to {
            Some(to) => to.clone(),
            None => ToMatrix::new(
                base.n(),
                (0..base.n())
                    .map(|w| base.row(self.order[w]).to_vec())
                    .collect(),
            ),
        }
    }
}

/// Offsets that spread `n` ranked items around the cyclic ring so that
/// every *prefix* of the ranking is (near-)maximally spaced: greedy
/// max–min cyclic distance, ties to the smallest offset.  `[0, n/2,
/// n/4, 3n/4, …]` — bit-reversal order for powers of two.  This is what
/// lets the `j` currently-fastest workers' cyclic rows cover `≈ j·r`
/// *distinct* tasks early instead of overlapping windows.
pub fn spread_offsets(n: usize) -> Vec<usize> {
    assert!(n >= 1);
    let mut offs = Vec::with_capacity(n);
    offs.push(0usize);
    let mut used = vec![false; n];
    used[0] = true;
    for _ in 1..n {
        let (mut best, mut best_d) = (usize::MAX, 0usize);
        for c in 0..n {
            if used[c] {
                continue;
            }
            let d = offs
                .iter()
                .map(|&o| {
                    let fwd = (c + n - o) % n;
                    fwd.min(n - fwd)
                })
                .min()
                .expect("offs nonempty");
            if d > best_d {
                best = c;
                best_d = d;
            }
        }
        offs.push(best);
        used[best] = true;
    }
    offs
}

/// Largest divisor of `block` that is `≤ max(v, 1)` — the mergeability
/// constraint on per-worker flush sizes: a worker flushing at
/// boundaries of a divisor of the canonical block always produces
/// ranges nested inside one canonical block, so the master's
/// duplicate-safe range merge ([`crate::coordinator::aggregate`])
/// keeps working across workers with *different* cadences.
pub fn snap_divisor(block: usize, v: usize) -> usize {
    assert!(block >= 1, "canonical block must be ≥ 1");
    let v = v.clamp(1, block);
    (1..=v).rev().find(|d| block % d == 0).expect("1 divides")
}

/// Policy + estimator state, driven at every round boundary.
pub struct PolicyEngine {
    kind: PolicyKind,
    n: usize,
    r: usize,
    /// Canonical flush block of the base scheme (`s` for GC(s),
    /// `max(s_fast, s_slow)` for GCH, 1 for per-task streaming).
    block: usize,
    pub estimator: DelayEstimator,
    last: Option<RoundPlan>,
    replans: usize,
    digest: Fnv1a,
}

impl PolicyEngine {
    pub fn new(kind: PolicyKind, n: usize, r: usize, block: usize) -> Self {
        assert!(n >= 1 && r >= 1 && r <= n, "degenerate fleet shape");
        assert!(block >= 1 && block <= r, "canonical block must satisfy 1 ≤ block ≤ r");
        Self {
            kind,
            n,
            r,
            block,
            estimator: DelayEstimator::new(n),
            last: None,
            replans: 0,
            digest: Fnv1a::new(),
        }
    }

    pub fn kind(&self) -> PolicyKind {
        self.kind
    }

    /// Feed one task observation (Monte-Carlo arm).
    pub fn observe(&mut self, worker: usize, comp_ms: f64, comm_ms: f64) {
        self.estimator.observe(worker, comp_ms, comm_ms);
    }

    /// Feed one flushed result group (cluster master).
    pub fn observe_flush(
        &mut self,
        worker: usize,
        tasks: usize,
        comp_total_ms: f64,
        comm_ms: f64,
    ) {
        self.estimator.observe_flush(worker, tasks, comp_total_ms, comm_ms);
    }

    /// Decide round `round`'s plan from the current estimator state.
    /// `rng_sched` is consumed only by `alloc-random` (per-round
    /// redraw, RA-style).
    pub fn plan(&mut self, round: usize, rng_sched: &mut Rng) -> RoundPlan {
        let n = self.n;
        // no evidence yet → the static plan (the estimator-driven
        // policies must not impose an uninformed bias on round 0; the
        // allocation overrides are evidence-free by design)
        let unobserved = self.kind.is_adaptive()
            && (0..n).all(|w| self.estimator.samples(w) == 0);
        let plan = match self.kind {
            _ if unobserved => RoundPlan::identity(n, self.block),
            PolicyKind::Static => RoundPlan::identity(n, self.block),
            PolicyKind::AdaptiveOrder | PolicyKind::AdaptiveOrderQuantile(_) => {
                let ranking = match self.kind {
                    PolicyKind::AdaptiveOrderQuantile(q) => {
                        self.estimator.speed_ranking_quantile(q as f64 / 100.0)
                    }
                    _ => self.estimator.speed_ranking(),
                };
                let offsets = spread_offsets(n);
                let mut order = vec![0usize; n];
                for (j, &w) in ranking.iter().enumerate() {
                    order[w] = offsets[j];
                }
                RoundPlan {
                    order,
                    sizes: vec![self.block; n],
                    to: None,
                }
            }
            PolicyKind::LoadRate => {
                // service-rate-proportional flush sizes: the fastest
                // estimated worker keeps the full canonical block,
                // everyone else scales by their rate ratio (unobserved
                // workers have rate 0 → the floor of 1), snapped to
                // divisors of the block so the master's range merge
                // stays duplicate-safe
                let rate = |w: usize| {
                    let e = &self.estimator;
                    if e.samples(w) == 0 {
                        0.0
                    } else {
                        1.0 / e.comp_mean_ms(w).max(1e-12)
                    }
                };
                let max_rate = (0..n).map(rate).fold(0.0f64, f64::max).max(1e-12);
                let sizes: Vec<usize> = (0..n)
                    .map(|w| {
                        let raw = (self.block as f64 * rate(w) / max_rate).round() as usize;
                        snap_divisor(self.block, raw)
                    })
                    .collect();
                RoundPlan {
                    order: (0..n).collect(),
                    sizes,
                    to: None,
                }
            }
            PolicyKind::AdaptiveLoad => {
                let ranking = self.estimator.speed_ranking();
                let mut sizes = vec![0usize; n];
                for (j, &w) in ranking.iter().enumerate() {
                    // linear ramp block → 1 across the speed ranking,
                    // snapped to divisors of the canonical block
                    let t = if n == 1 { 0.0 } else { j as f64 / (n - 1) as f64 };
                    let raw = self.block as f64 + (1.0 - self.block as f64) * t;
                    sizes[w] = snap_divisor(self.block, raw.round() as usize);
                }
                RoundPlan {
                    order: (0..n).collect(),
                    sizes,
                    to: None,
                }
            }
            PolicyKind::AllocGroup => RoundPlan {
                order: (0..n).collect(),
                sizes: vec![self.block; n],
                to: Some(GroupAllocation.schedule(n, self.r, rng_sched)),
            },
            PolicyKind::AllocRandom => RoundPlan {
                order: (0..n).collect(),
                sizes: vec![self.block; n],
                to: Some(RandomAssignment.schedule(n, self.r, rng_sched)),
            },
        };
        if self.last.as_ref() != Some(&plan) {
            self.replans += 1;
        }
        self.fold_digest(round, &plan);
        self.last = Some(plan.clone());
        plan
    }

    /// How many rounds changed the plan (round 0 counts as the first).
    pub fn replans(&self) -> usize {
        self.replans
    }

    /// FNV-1a fold ([`Fnv1a`]) of every decision so far — the
    /// determinism pin: identical seeds + arrival traces must yield
    /// identical digests.
    pub fn decision_digest(&self) -> u64 {
        self.digest.digest()
    }

    fn fold_digest(&mut self, round: usize, plan: &RoundPlan) {
        let h = &mut self.digest;
        h.fold(round as u64);
        for &o in &plan.order {
            h.fold(o as u64);
        }
        for &s in &plan.sizes {
            h.fold(s as u64);
        }
        if let Some(to) = &plan.to {
            for row in to.rows() {
                for &t in row {
                    h.fold(t as u64 ^ 0x5A5A);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_spellings_and_display_roundtrip() {
        for (s, want) in [
            ("static", PolicyKind::Static),
            ("ORDER", PolicyKind::AdaptiveOrder),
            ("order@p95", PolicyKind::AdaptiveOrderQuantile(95)),
            ("ORDER@P50", PolicyKind::AdaptiveOrderQuantile(50)),
            ("adaptive-load", PolicyKind::AdaptiveLoad),
            ("load-rate", PolicyKind::LoadRate),
            (" alloc-group ", PolicyKind::AllocGroup),
            ("alloc-random", PolicyKind::AllocRandom),
        ] {
            assert_eq!(PolicyKind::parse(s).unwrap(), want, "{s:?}");
        }
        for kind in [
            PolicyKind::Static,
            PolicyKind::AdaptiveOrder,
            PolicyKind::AdaptiveOrderQuantile(95),
            PolicyKind::AdaptiveLoad,
            PolicyKind::LoadRate,
            PolicyKind::AllocGroup,
            PolicyKind::AllocRandom,
        ] {
            assert_eq!(PolicyKind::parse(&kind.to_string()).unwrap(), kind);
        }
        for bad in ["wat", "order@p0", "order@p100", "order@p", "order@pxx"] {
            assert!(PolicyKind::parse(bad).is_err(), "{bad:?}");
        }
    }

    #[test]
    fn policy_spec_parses_the_staleness_axis() {
        for (s, kind, staleness) in [
            ("static", PolicyKind::Static, 1),
            ("order", PolicyKind::AdaptiveOrder, 1),
            ("static@s3", PolicyKind::Static, 3),
            ("ORDER@S2", PolicyKind::AdaptiveOrder, 2),
            ("order@p95@s2", PolicyKind::AdaptiveOrderQuantile(95), 2),
            ("load-rate@s4", PolicyKind::LoadRate, 4),
            ("order@s1", PolicyKind::AdaptiveOrder, 1),
        ] {
            let spec = PolicySpec::parse(s).unwrap();
            assert_eq!(spec.kind, kind, "{s:?}");
            assert_eq!(spec.staleness, staleness, "{s:?}");
        }
        // display round-trips, eliding @s1
        for spec in [
            PolicySpec::sync(PolicyKind::Static),
            PolicySpec { kind: PolicyKind::AdaptiveOrder, staleness: 2 },
            PolicySpec { kind: PolicyKind::AdaptiveOrderQuantile(95), staleness: 3 },
        ] {
            assert_eq!(PolicySpec::parse(&spec.to_string()).unwrap(), spec);
        }
        assert_eq!(PolicySpec::sync(PolicyKind::Static).to_string(), "static");
        assert_eq!(
            PolicySpec { kind: PolicyKind::AdaptiveOrder, staleness: 2 }.to_string(),
            "order@s2"
        );
        for bad in ["order@s", "order@s0", "order@s99", "order@sx", "wat@s2"] {
            assert!(PolicySpec::parse(bad).is_err(), "{bad:?}");
        }
    }

    #[test]
    fn spread_offsets_is_a_spread_permutation() {
        assert_eq!(spread_offsets(8), vec![0, 4, 2, 6, 1, 3, 5, 7]);
        assert_eq!(spread_offsets(1), vec![0]);
        for n in 1..=17 {
            let offs = spread_offsets(n);
            let mut sorted = offs.clone();
            sorted.sort_unstable();
            assert_eq!(sorted, (0..n).collect::<Vec<_>>(), "n={n}");
        }
        // the defining property: early prefixes are maximally spaced —
        // at n = 12 the first four offsets sit pairwise ≥ 3 apart
        // cyclically (after that the gaps necessarily shrink to 1)
        let offs = spread_offsets(12);
        assert_eq!(&offs[..4], &[0, 6, 3, 9]);
        for i in 0..4 {
            for j in 0..i {
                let d = (offs[i] + 12 - offs[j]) % 12;
                assert!(d.min(12 - d) >= 3, "offsets {} and {}", offs[j], offs[i]);
            }
        }
    }

    #[test]
    fn snap_divisor_picks_largest_dividing() {
        assert_eq!(snap_divisor(4, 4), 4);
        assert_eq!(snap_divisor(4, 3), 2);
        assert_eq!(snap_divisor(4, 2), 2);
        assert_eq!(snap_divisor(4, 1), 1);
        assert_eq!(snap_divisor(6, 5), 3);
        assert_eq!(snap_divisor(6, 4), 3);
        assert_eq!(snap_divisor(1, 9), 1);
        assert_eq!(snap_divisor(5, 0), 1, "clamps up to 1");
        assert_eq!(snap_divisor(3, 7), 3, "clamps down to block");
    }

    #[test]
    fn order_policy_spreads_the_fast_prefix() {
        let mut eng = PolicyEngine::new(PolicyKind::AdaptiveOrder, 8, 8, 1);
        let mut rng = Rng::seed_from_u64(0);
        // no observations yet → round 0 is the static identity plan
        let p0 = eng.plan(0, &mut rng);
        assert_eq!(p0, RoundPlan::identity(8, 1));
        // make workers 5 and 6 the fast pair → they get offsets 0 and 4
        for _ in 0..30 {
            for w in 0..8 {
                let ms = if w == 5 || w == 6 { 0.1 } else { 0.4 };
                eng.observe(w, ms, 0.5);
            }
        }
        let p1 = eng.plan(1, &mut rng);
        let d = (p1.order[5] + 8 - p1.order[6]) % 8;
        assert_eq!(d.min(8 - d), 4, "fast pair must sit opposite: {:?}", p1.order);
        assert!(eng.replans() >= 2);
    }

    #[test]
    fn load_policy_sizes_divide_block_and_ramp_by_rank() {
        let mut eng = PolicyEngine::new(PolicyKind::AdaptiveLoad, 6, 6, 4);
        let mut rng = Rng::seed_from_u64(0);
        for _ in 0..30 {
            for w in 0..6 {
                eng.observe(w, 0.1 * (w + 1) as f64, 0.5);
            }
        }
        let p = eng.plan(1, &mut rng);
        assert_eq!(p.order, (0..6).collect::<Vec<_>>(), "load does not reorder");
        assert!(p.sizes.iter().all(|&s| 4 % s == 0), "{:?}", p.sizes);
        // worker 0 is fastest → full block; worker 5 slowest → 1
        assert_eq!(p.sizes[0], 4);
        assert_eq!(p.sizes[5], 1);
        for w in 0..5 {
            assert!(p.sizes[w] >= p.sizes[w + 1], "monotone ramp: {:?}", p.sizes);
        }
    }

    #[test]
    fn unobserved_adaptive_policies_emit_the_static_plan() {
        let mut rng = Rng::seed_from_u64(0);
        for kind in [
            PolicyKind::AdaptiveOrder,
            PolicyKind::AdaptiveOrderQuantile(95),
            PolicyKind::AdaptiveLoad,
            PolicyKind::LoadRate,
        ] {
            let mut eng = PolicyEngine::new(kind, 6, 6, 3);
            assert_eq!(
                eng.plan(0, &mut rng),
                RoundPlan::identity(6, 3),
                "{kind}: round 0 must be static"
            );
        }
    }

    #[test]
    fn order_quantile_ranks_by_the_tail() {
        let mut eng = PolicyEngine::new(PolicyKind::AdaptiveOrderQuantile(95), 2, 2, 1);
        let mut rng = Rng::seed_from_u64(0);
        // worker 0 steady 0.3; worker 1 usually faster but spiky
        for i in 0..100 {
            eng.observe(0, 0.3, 0.5);
            eng.observe(1, if i % 10 == 0 { 3.0 } else { 0.1 }, 0.5);
        }
        let p = eng.plan(1, &mut rng);
        // the steady worker is ranked fastest → offset 0
        assert_eq!(p.order[0], 0, "{:?}", p.order);
        // the plain mean ranking would have flipped it
        let mut mean_eng = PolicyEngine::new(PolicyKind::AdaptiveOrder, 2, 2, 1);
        for i in 0..100 {
            mean_eng.observe(0, 0.3, 0.5);
            mean_eng.observe(1, if i % 10 == 0 { 3.0 } else { 0.1 }, 0.5);
        }
        let pm = mean_eng.plan(1, &mut rng);
        assert_eq!(pm.order[1], 0, "{:?}", pm.order);
    }

    #[test]
    fn load_rate_sizes_follow_service_rate_ratios() {
        // block 4; worker rates 1 : 1/2 : 1/4 : unobserved
        let mut eng = PolicyEngine::new(PolicyKind::LoadRate, 4, 4, 4);
        let mut rng = Rng::seed_from_u64(0);
        for _ in 0..30 {
            eng.observe(0, 0.1, 0.5);
            eng.observe(1, 0.2, 0.5);
            eng.observe(2, 0.4, 0.5);
        }
        let p = eng.plan(1, &mut rng);
        assert_eq!(p.order, (0..4).collect::<Vec<_>>(), "load-rate does not reorder");
        assert_eq!(p.sizes[0], 4, "fastest keeps the full block");
        assert_eq!(p.sizes[1], 2, "half the rate → half the block");
        assert_eq!(p.sizes[2], 1, "quarter rate → 1");
        assert_eq!(p.sizes[3], 1, "unobserved floors at 1");
        assert!(p.sizes.iter().all(|&s| 4 % s == 0));
        // contrast with the rank ramp: `load` at these shapes gives the
        // 2nd-ranked worker a size from its *rank*, not its rate
        let mut ramp = PolicyEngine::new(PolicyKind::AdaptiveLoad, 4, 4, 4);
        for _ in 0..30 {
            ramp.observe(0, 0.1, 0.5);
            ramp.observe(1, 0.11, 0.5); // nearly as fast as worker 0
            ramp.observe(2, 0.4, 0.5);
        }
        let mut rate = PolicyEngine::new(PolicyKind::LoadRate, 4, 4, 4);
        for _ in 0..30 {
            rate.observe(0, 0.1, 0.5);
            rate.observe(1, 0.11, 0.5);
            rate.observe(2, 0.4, 0.5);
        }
        let pr = ramp.plan(1, &mut rng);
        let pv = rate.plan(1, &mut rng);
        assert!(pr.sizes[1] < 4, "rank ramp demotes the near-tied worker");
        assert_eq!(pv.sizes[1], 4, "rate ratio keeps the near-tied worker at full block");
    }

    #[test]
    fn materialize_permutes_rows_or_applies_override() {
        let mut rng = Rng::seed_from_u64(0);
        let base = crate::scheduler::CyclicScheduler.schedule(4, 2, &mut rng);
        let plan = RoundPlan {
            order: vec![2, 0, 3, 1],
            sizes: vec![1; 4],
            to: None,
        };
        let to = plan.materialize(&base);
        for w in 0..4 {
            assert_eq!(to.row(w), base.row(plan.order[w]), "worker {w}");
        }
        assert_eq!(RoundPlan::identity(4, 1).materialize(&base).rows(), base.rows());
        let with_override = RoundPlan {
            to: Some(base.clone()),
            ..RoundPlan::identity(4, 1)
        };
        assert_eq!(with_override.materialize(&base).rows(), base.rows());
    }

    #[test]
    fn validate_base_gates_policy_scheme_shapes() {
        use SchemeId::*;
        let v = |p: PolicyKind, s, n, r| p.validate_base(s, n, r).is_ok();
        assert!(v(PolicyKind::Static, Pc, 6, 3), "static allows everything");
        assert!(v(PolicyKind::AdaptiveOrder, Cs, 6, 3));
        assert!(v(PolicyKind::AdaptiveLoad, Gc(2), 6, 4));
        assert!(!v(PolicyKind::AdaptiveOrder, Pc, 6, 3), "coded");
        assert!(!v(PolicyKind::AdaptiveLoad, GcHet(2, 1), 6, 4), "GCH");
        assert!(!v(PolicyKind::AdaptiveOrder, Ra, 6, 6), "randomized");
        assert!(!v(PolicyKind::AllocGroup, Cs, 6, 4), "needs r | n");
        assert!(v(PolicyKind::AllocGroup, Cs, 6, 3));
        assert!(!v(PolicyKind::AllocRandom, Cs, 6, 3), "needs r = n");
        assert!(v(PolicyKind::AllocRandom, Cs, 6, 6));
    }

    #[test]
    fn alloc_policies_override_assignment() {
        let mut rng = Rng::seed_from_u64(1);
        let mut eng = PolicyEngine::new(PolicyKind::AllocGroup, 6, 3, 1);
        let p = eng.plan(0, &mut rng);
        let to = p.to.expect("group allocation overrides");
        assert_eq!(to.row(0), &[0, 1, 2]);
        // deterministic: second round identical, no replan counted
        let p2 = eng.plan(1, &mut rng);
        assert_eq!(p.to, p2.to);
        assert_eq!(eng.replans(), 1);

        let mut eng = PolicyEngine::new(PolicyKind::AllocRandom, 6, 3, 1);
        let a = eng.plan(0, &mut rng).to.unwrap();
        let b = eng.plan(1, &mut rng).to.unwrap();
        assert_ne!(a, b, "random-batch redraws per round");
    }

    #[test]
    fn digest_is_deterministic_and_decision_sensitive() {
        let run = |obs: f64| {
            let mut eng = PolicyEngine::new(PolicyKind::AdaptiveOrder, 4, 4, 1);
            let mut rng = Rng::seed_from_u64(0);
            for round in 0..5 {
                for w in 0..4 {
                    eng.observe(w, if w == 0 { obs } else { 0.4 }, 0.5);
                }
                eng.plan(round, &mut rng);
            }
            eng.decision_digest()
        };
        assert_eq!(run(0.1), run(0.1), "same trace → same digest");
        assert_ne!(run(0.1), run(0.9), "different ranking → different digest");
    }
}
