//! The multi-round re-planning Monte-Carlo arm, and the
//! shifting-straggler scenario it exists to win.
//!
//! The coupled engines ([`crate::scheme::run_rounds`]) treat rounds as
//! exchangeable — correct for static schemes, where nothing carries
//! across rounds.  An adaptive policy is *sequential by construction*:
//! round `t`'s plan depends on what rounds `< t` revealed about worker
//! speeds.  [`run_policy_rounds`] is therefore a single-stream driver:
//! same chunked [`DelayBatch`] sampling, same shared-arrival pass, same
//! completion kernels as `run_rounds` (the `static` policy is
//! bit-identical to the registry path — pinned in
//! `rust/tests/scheme_registry.rs`), plus a decide → evaluate → observe
//! cycle per round for the adaptive policies.
//!
//! The scenario: [`ShiftingStraggler`] rotates which workers are slow
//! every `shift_every` rounds (over any base model — use
//! [`two_tier_model`] for a crisp fast/slow fleet).  Static schemes
//! must commit to one layout, so whichever layout they pick is wrong
//! after the next shift; the adaptive policies re-estimate and re-plan
//! within `O(1/α)` rounds of each shift (`straggler adaptive` prints
//! the comparison table; EXPERIMENTS.md §Adaptive has the numbers).

use anyhow::{ensure, Result};

use crate::delay::{DelayBatch, DelayModel, DelaySample, TruncatedGaussian, TruncatedGaussianModel};
use crate::scheduler::{CyclicScheduler, Scheduler, StaircaseScheduler, ToMatrix};
use crate::scheme::gc::GcEvaluator;
use crate::scheme::{RoundView, SchemeEvaluator, SchemeId, SchemeRegistry};
use crate::sim::{chunk_rounds, shard_rngs, slot_arrivals_batch, CompletionEstimate, MonteCarlo};
use crate::telemetry::{metrics as tm, SpanRecorder, SpanSummary};
use crate::trace::TraceRecorder;
use crate::util::rng::Rng;
use crate::util::stats::{RunningStats, StreamingQuantiles};

use super::policy::{PolicyEngine, PolicyKind, RoundPlan, MAX_STALENESS};

/// A delay source that may depend on the round index — the hook the
/// shifting-straggler scenario plugs into.  Round-stationary models
/// enter through [`PerRound`].
pub trait RoundDelayModel: Send + Sync {
    fn name(&self) -> String;

    /// Fill all `n × r` slots with round `round`'s delays.  For a fixed
    /// RNG stream the result must be a deterministic function of
    /// `(round, rng state)`.
    fn sample_round_into(&self, round: usize, out: &mut DelaySample, rng: &mut Rng);
}

/// Adapter: any stationary [`DelayModel`] as a [`RoundDelayModel`]
/// (ignores the round index; consumes the identical RNG stream as the
/// model's own batched sampling — the bit-identity contract of
/// [`DelayModel::sample_batch_into`]).
pub struct PerRound<'a>(pub &'a dyn DelayModel);

impl RoundDelayModel for PerRound<'_> {
    fn name(&self) -> String {
        self.0.name()
    }

    fn sample_round_into(&self, _round: usize, out: &mut DelaySample, rng: &mut Rng) {
        self.0.sample_into(out, rng);
    }
}

/// Shifting stragglers: every `shift_every` rounds the fleet's
/// per-worker delay profiles rotate by `rotate` positions, so *which*
/// workers are slow changes mid-run while the fleet's aggregate
/// capacity stays constant — the controlled drift that separates
/// adaptive from static scheduling.
pub struct ShiftingStraggler<'a> {
    base: &'a dyn DelayModel,
    shift_every: usize,
    rotate: usize,
}

impl<'a> ShiftingStraggler<'a> {
    pub fn new(base: &'a dyn DelayModel, shift_every: usize, rotate: usize) -> Self {
        assert!(shift_every >= 1, "shift period must be ≥ 1 round");
        Self {
            base,
            shift_every,
            rotate,
        }
    }

    /// Worker-row rotation in effect at `round`.
    pub fn offset_at(&self, round: usize, n: usize) -> usize {
        (round / self.shift_every * self.rotate) % n
    }
}

impl RoundDelayModel for ShiftingStraggler<'_> {
    fn name(&self) -> String {
        format!(
            "shifting({}, every {} rot {})",
            self.base.name(),
            self.shift_every,
            self.rotate
        )
    }

    fn sample_round_into(&self, round: usize, out: &mut DelaySample, rng: &mut Rng) {
        self.base.sample_into(out, rng);
        let (n, r) = (out.n, out.r);
        let off = self.offset_at(round, n);
        if off > 0 {
            // worker w takes the base model's row (w + off) mod n: the
            // per-worker profiles rotate, the RNG stream does not
            out.comp_mut().rotate_left(off * r);
            out.comm_mut().rotate_left(off * r);
        }
    }
}

/// A crisp two-tier fleet for the scenario: workers `0..n_slow` have
/// their per-task computation mean scaled by `slow_factor`, the rest
/// run at the §VI-C scenario-1 baseline (comp μ 0.1 ms, comm μ 0.5 ms);
/// wrap in [`ShiftingStraggler`] to move the slow block around.
pub fn two_tier_model(n: usize, n_slow: usize, slow_factor: f64) -> TruncatedGaussianModel {
    assert!(n_slow <= n, "slow tier larger than the fleet");
    assert!(slow_factor >= 1.0, "slow factor scales the mean up");
    let comp = (0..n)
        .map(|w| {
            let mu = if w < n_slow { 0.1 * slow_factor } else { 0.1 };
            TruncatedGaussian::symmetric(mu, 0.1, 0.03)
        })
        .collect();
    let comm = (0..n)
        .map(|_| TruncatedGaussian::symmetric(0.5, 0.2, 0.2))
        .collect();
    TruncatedGaussianModel::new(comp, comm, "two-tier")
}

/// One policy run's shape: which scheme's base plan the policy
/// re-plans, at which `(n, r, k)` point, for how many rounds.
#[derive(Debug, Clone, Copy)]
pub struct PolicyRunConfig {
    pub scheme: SchemeId,
    pub policy: PolicyKind,
    pub n: usize,
    pub r: usize,
    pub k: usize,
    pub rounds: usize,
    /// Master-side serialized ingestion cost (ms/message); 0 = the
    /// idealized eq. (1)–(2) dynamics.
    pub ingest_ms: f64,
    pub seed: u64,
    /// Bounded-staleness window `S ∈ [1, MAX_STALENESS]`.  `S = 1` is
    /// the synchronous data plane — bit-identical to the registry path
    /// (pinned in `rust/tests/scheme_registry.rs`).  `S ≥ 2` keeps up
    /// to `S` rounds in flight over shared worker queues: round `t` is
    /// issued the instant round `t − S` *applies* to θ, so a straggler
    /// delays only its own round's contribution (see
    /// [`run_policy_rounds`] for the overlapping-round recurrences).
    pub staleness: usize,
}

/// What a policy run produces.
#[derive(Debug, Clone)]
pub struct PolicyOutcome {
    pub estimate: CompletionEstimate,
    /// Rounds whose plan differed from the previous round's (0 for the
    /// static policy, 1 for a static allocation override).
    pub replans: usize,
    /// FNV fold of every decision — the determinism pin: same seed +
    /// arrival trace ⇒ same digest.
    pub decision_digest: u64,
    /// Round critical-path spans over simulated time (wait-first /
    /// completion / apply; decode is empty — the simulator does not
    /// model master-side decode).  Recorded through a *silent*
    /// [`SpanRecorder`], so simulated milliseconds never leak into the
    /// process-global wall-clock histograms.
    pub spans: SpanSummary,
}

/// Canonical flush block of a scheme's uncoded base plan.
fn scheme_block(id: SchemeId) -> usize {
    match id {
        SchemeId::Gc(s) => s as usize,
        SchemeId::GcHet(a, b) => (a.max(b)) as usize,
        _ => 1,
    }
}

/// The base TO-matrix builder a policy permutes. `None` = the scheme
/// has no fixed uncoded base (randomized or coded) and only `static`
/// applies.
fn base_scheduler(id: SchemeId) -> Option<Box<dyn Scheduler>> {
    match id {
        SchemeId::Cs | SchemeId::Gc(_) => Some(Box::new(CyclicScheduler)),
        SchemeId::Ss => Some(Box::new(StaircaseScheduler)),
        _ => None,
    }
}

/// Run `cfg.rounds` sequential rounds of `scheme` under `policy`,
/// re-planning at every round boundary, and stream per-round completion
/// times into the estimate (and `emit`, when given).
///
/// The `static` policy takes the exact code path of the coupled engines
/// — same `shard_rngs(seed, 0)` streams, same chunked sampling, same
/// kernels — so its estimate is bit-identical to
/// `harness::evaluate` at `threads = 1` for every scheme.  Adaptive
/// policies additionally: ask the [`PolicyEngine`] for a [`RoundPlan`]
/// before each round (rebuilding the evaluator only when the plan
/// changed), and afterwards feed the estimator every slot whose
/// arrival precedes the round's completion time — causal like the live
/// master's feed, though slightly better informed (see the censoring
/// note at the feedback loop).
///
/// A [`TraceRecorder`] in `trace` captures the same **censored** slot
/// view the estimator sees (one per-slot event per delivery the master
/// witnessed before completion) — the simulator-side tap of the trace
/// subsystem ([`crate::trace`]); recording never touches the RNG
/// streams, so a recorded run's estimate is bit-identical to an
/// unrecorded one.
pub fn run_policy_rounds(
    cfg: &PolicyRunConfig,
    model: &dyn RoundDelayModel,
    mut emit: Option<&mut dyn FnMut(usize, f64)>,
    mut trace: Option<&mut TraceRecorder>,
) -> Result<PolicyOutcome> {
    let PolicyRunConfig {
        scheme: scheme_id,
        policy,
        n,
        r,
        k,
        rounds,
        ingest_ms,
        seed,
        staleness,
    } = *cfg;
    ensure!(rounds >= 1, "need at least one round");
    ensure!(
        SchemeRegistry::applicable(scheme_id, n, r, k),
        "{scheme_id} is not applicable at (n = {n}, r = {r}, k = {k}) — paper Table I"
    );
    ensure!(
        !(ingest_ms.is_nan() || ingest_ms < 0.0),
        "ingest cost must be a non-negative ms/message"
    );
    ensure!(
        (1..=MAX_STALENESS).contains(&staleness),
        "staleness must be in [1, {MAX_STALENESS}], got {staleness}"
    );
    if staleness > 1 {
        // the k-async arm: overlapping rounds on shared worker queues.
        // S = 1 deliberately does NOT route through it — the loop below
        // is the synchronous engines' exact code path (same RNG streams,
        // same FP operation order), which the bit-identity pins require.
        return run_policy_rounds_async(cfg, model, emit, trace);
    }

    let (mut rng, mut rng_sched) = shard_rngs(seed, 0);
    let scheme = SchemeRegistry::build(scheme_id);
    // prepare consumes rng_sched exactly like the coupled engines — the
    // static-policy bit-identity contract
    let mut evaluator: Box<dyn SchemeEvaluator> = scheme.prepare(n, r, k, &mut rng_sched);

    policy.validate_base(scheme_id, n, r)?;
    let mut engine: Option<PolicyEngine> = match policy {
        PolicyKind::Static => None,
        _ => Some(PolicyEngine::new(policy, n, r, scheme_block(scheme_id))),
    };
    // the base matrix adaptive plans permute (fixed; drawn outside the
    // round loop so the delay stream is untouched — CS/SS ignore the
    // RNG, so the throwaway stream is inert)
    let base_to: Option<ToMatrix> = engine
        .as_ref()
        .and_then(|_| base_scheduler(scheme_id))
        .map(|s| s.schedule(n, r, &mut Rng::seed_from_u64(0)));

    let mut stats = RunningStats::new();
    let mut quantiles = StreamingQuantiles::new();
    let mut last_plan: Option<RoundPlan> = None;
    // simulated-time spans (µs), summary-only — telemetry is inert on
    // the RNG streams and the completion arithmetic
    let mut spans = SpanRecorder::silent(n, 1);
    let sim_us = |ms: f64| (ms.max(0.0) * 1e3).round() as u64;
    let run_t0 = std::time::Instant::now();

    let stride = n * r;
    // fleet-aware chunk cap — identical round sequence for any chunking
    let cap = chunk_rounds(n, r).min(rounds);
    let mut batch = DelayBatch::zeros(cap, n, r);
    let mut tmp = DelaySample::zeros(n, r);
    let mut arrivals: Vec<f64> = Vec::new();
    let mut done = 0usize;
    while done < rounds {
        let chunk = cap.min(rounds - done);
        if batch.rounds != chunk {
            batch = DelayBatch::zeros(chunk, n, r);
        }
        for b in 0..chunk {
            model.sample_round_into(done + b, &mut tmp, &mut rng);
            batch.copy_round_from_sample(b, &tmp);
        }
        slot_arrivals_batch(&batch, &mut arrivals);
        for b in 0..chunk {
            let round = done + b;
            let mut replanned = false;
            if let Some(engine) = engine.as_mut() {
                let plan_t0 = std::time::Instant::now();
                let plan = engine.plan(round, &mut rng_sched);
                if last_plan.as_ref() != Some(&plan) {
                    let to = plan.materialize(base_to.as_ref().expect("adaptive base plan"));
                    evaluator = Box::new(GcEvaluator::with_sizes(&to, &plan.sizes, k));
                    last_plan = Some(plan);
                    replanned = true;
                }
                tm::SIM_REPLAN_US.record(plan_t0.elapsed().as_secs_f64() * 1e6);
                if replanned {
                    tm::SIM_REPLANS_TOTAL.inc();
                }
            }
            let view = RoundView {
                arrivals: &arrivals[b * stride..(b + 1) * stride],
                comp: batch.comp_round(b),
                comm: batch.comm_round(b),
            };
            let t = if ingest_ms == 0.0 {
                evaluator.completion(&view, &mut rng_sched)
            } else {
                evaluator.completion_ingest(&view, ingest_ms, &mut rng_sched)
            };
            spans.begin(round, 0);
            let (mut first, mut first_w) = (f64::INFINITY, 0usize);
            for (slot, &a) in view.arrivals.iter().enumerate() {
                if a < first {
                    first = a;
                    first_w = slot / r;
                }
            }
            if first <= t {
                spans.frame(round, first_w, sim_us(first));
            }
            spans.complete(round, None, sim_us(t));
            spans.apply(round, sim_us(t));
            tm::SIM_ROUNDS_TOTAL.inc();
            if engine.is_some() || trace.is_some() {
                // causal feedback, censored at the round's completion
                // time.  Censoring uses per-task slot arrivals — a
                // slightly better-informed view than the live master's
                // flush-grouped feed (a partially-filled group's slots
                // count here but never reach a real master); the
                // policies only consume the resulting speed *ranking*,
                // which both views agree on.  The trace recorder eats
                // the identical censored stream, so recorded simulator
                // traces match what a replaying estimator would see.
                for i in 0..n {
                    for j in 0..r {
                        let slot = i * r + j;
                        if view.arrivals[slot] <= t {
                            if let Some(engine) = engine.as_mut() {
                                engine.observe(i, view.comp[slot], view.comm[slot]);
                            }
                            if let Some(rec) = trace.as_deref_mut() {
                                // sync: θ is always current — version
                                // tag = round index, gap 0
                                rec.push_slot(
                                    round,
                                    i,
                                    j,
                                    view.comp[slot],
                                    view.comm[slot],
                                    replanned,
                                    round as u32,
                                );
                            }
                        }
                    }
                }
            }
            stats.push(t);
            quantiles.push(t);
            if let Some(f) = emit.as_mut() {
                (*f)(round, t);
            }
        }
        done += chunk;
    }

    let label = match policy {
        PolicyKind::Static => scheme_id.to_string(),
        _ => format!("{scheme_id}+{policy}"),
    };
    let elapsed = run_t0.elapsed().as_secs_f64();
    if elapsed > 0.0 {
        tm::SIM_ROUNDS_PER_SEC.set(rounds as f64 / elapsed);
    }
    tm::SIM_EST_MEAN_MS.set(stats.mean());
    Ok(PolicyOutcome {
        estimate: CompletionEstimate::from_streams(label, n, r, k, &stats, &quantiles),
        replans: engine.as_ref().map_or(0, |e| e.replans()),
        decision_digest: engine.as_ref().map_or(0, |e| e.decision_digest()),
        spans: spans.summary(),
    })
}

/// The bounded-staleness (`S ≥ 2`) overlapping-rounds kernel behind
/// [`run_policy_rounds`].  Rounds share the worker queues; everything
/// runs on one absolute clock:
///
/// * issue time `a_t = apply_{t−S}` (`0` for `t < S`) — round `t`'s
///   `Assign` goes out the instant round `t − S` applies to θ, which is
///   exactly when the master's `S`-slot aggregation ring recycles a slot
///   ([`crate::coordinator::AggregatorRing`]);
/// * worker start `s_{i,t} = max(a_t, f_{i,t−1})` — a worker picks up
///   round `t` when it is both issued and the worker's queue drained;
/// * absolute slot arrival = `s_{i,t}` + the worker's *local* arrival
///   profile (prefix-comp + comm — the untouched
///   [`slot_arrivals_batch`] values, shifted by
///   [`crate::sim::offset_arrivals`]), so every scheme's completion
///   evaluator runs unchanged over absolute arrivals;
/// * completion `c_t` = the scheme's completion rule over those
///   arrivals;
/// * worker free time `f_{i,t} = min(c_t, s_{i,t} + Σ_j comp_t(i,j))` —
///   the `Stop(t)` broadcast censors remaining work at `c_t`, and
///   communication rides the delivery threads so it never blocks the
///   compute queue;
/// * in-order apply `apply_t = max(c_t, apply_{t−1})` (the ring applies
///   oldest-first), reported per-round metric `d_t = apply_t −
///   apply_{t−1} ≥ 0` — wall-clock per applied round, so means are
///   directly comparable with the synchronous path's per-round
///   durations.
///
/// θ-version tag of round `t`: `v_t = max(0, t − S + 1)` applied rounds
/// at issue → staleness gap `t − v_t ≤ S − 1`, with `S = 1` degenerating
/// to gap 0 (the synchronous tag `v_t = t`).
///
/// Causality: the engine planning round `t` (at issue time `a_t`) has
/// seen censored observations only from rounds `≤ t − S` — later rounds
/// are still in flight — so observations are buffered `S` deep and
/// flushed just before planning (`S = 1` would degenerate to the
/// synchronous loop's feed-after-evaluate order).
///
/// Known approximation (documented in EXPERIMENTS.md §Async): per-round
/// master ingestion serializes *within* a round's messages only;
/// cross-round ingest contention at the master is not modeled.
fn run_policy_rounds_async(
    cfg: &PolicyRunConfig,
    model: &dyn RoundDelayModel,
    mut emit: Option<&mut dyn FnMut(usize, f64)>,
    mut trace: Option<&mut TraceRecorder>,
) -> Result<PolicyOutcome> {
    let PolicyRunConfig {
        scheme: scheme_id,
        policy,
        n,
        r,
        k,
        rounds,
        ingest_ms,
        seed,
        staleness,
    } = *cfg;
    debug_assert!(staleness >= 2, "the sync path handles S = 1");

    let (mut rng, mut rng_sched) = shard_rngs(seed, 0);
    let scheme = SchemeRegistry::build(scheme_id);
    let mut evaluator: Box<dyn SchemeEvaluator> = scheme.prepare(n, r, k, &mut rng_sched);

    policy.validate_base(scheme_id, n, r)?;
    let mut engine: Option<PolicyEngine> = match policy {
        PolicyKind::Static => None,
        _ => Some(PolicyEngine::new(policy, n, r, scheme_block(scheme_id))),
    };
    let base_to: Option<ToMatrix> = engine
        .as_ref()
        .and_then(|_| base_scheduler(scheme_id))
        .map(|s| s.schedule(n, r, &mut Rng::seed_from_u64(0)));

    let mut stats = RunningStats::new();
    let mut quantiles = StreamingQuantiles::new();
    let mut last_plan: Option<RoundPlan> = None;
    let mut spans = SpanRecorder::silent(n, staleness);
    let sim_us = |ms: f64| (ms.max(0.0) * 1e3).round() as u64;
    let run_t0 = std::time::Instant::now();

    let stride = n * r;
    let cap = chunk_rounds(n, r).min(rounds);
    let mut batch = DelayBatch::zeros(cap, n, r);
    let mut tmp = DelaySample::zeros(n, r);
    let mut arrivals: Vec<f64> = Vec::new();
    let mut abs_arrivals: Vec<f64> = vec![0.0; stride];
    let mut starts: Vec<f64> = vec![0.0; n];

    // pipeline state on the absolute clock
    let mut free_at = vec![0.0f64; n]; // f_{i, t−1}
    let mut apply_ring = vec![0.0f64; staleness]; // apply_{t−S..t−1}, mod S
    let mut applied_at = 0.0f64; // apply_{t−1}
    // S-deep causal observation buffer: slot `t % S` holds round `t`'s
    // censored observations until round `t + S` is planned
    let mut obs_buf: Vec<Vec<(usize, f64, f64)>> = vec![Vec::new(); staleness];

    let mut done = 0usize;
    while done < rounds {
        let chunk = cap.min(rounds - done);
        if batch.rounds != chunk {
            batch = DelayBatch::zeros(chunk, n, r);
        }
        // sample all chunk rounds first — the identical consumption
        // order of the synchronous path, so S is delay-stream-inert
        for b in 0..chunk {
            model.sample_round_into(done + b, &mut tmp, &mut rng);
            batch.copy_round_from_sample(b, &tmp);
        }
        slot_arrivals_batch(&batch, &mut arrivals);
        for b in 0..chunk {
            let round = done + b;
            let slot_ix = round % staleness;
            // observation lag: round `round − S` has applied by this
            // round's issue instant — its buffered observations land now
            if let Some(engine) = engine.as_mut() {
                if round >= staleness {
                    for (w, comp, comm) in obs_buf[slot_ix].drain(..) {
                        engine.observe(w, comp, comm);
                    }
                }
            }
            let mut replanned = false;
            if let Some(engine) = engine.as_mut() {
                let plan_t0 = std::time::Instant::now();
                let plan = engine.plan(round, &mut rng_sched);
                if last_plan.as_ref() != Some(&plan) {
                    let to = plan.materialize(base_to.as_ref().expect("adaptive base plan"));
                    evaluator = Box::new(GcEvaluator::with_sizes(&to, &plan.sizes, k));
                    last_plan = Some(plan);
                    replanned = true;
                }
                tm::SIM_REPLAN_US.record(plan_t0.elapsed().as_secs_f64() * 1e6);
                if replanned {
                    tm::SIM_REPLANS_TOTAL.inc();
                }
            }
            // a_t = apply_{t−S}; ring slot t % S still holds it
            let issue = if round >= staleness { apply_ring[slot_ix] } else { 0.0 };
            for (i, s) in starts.iter_mut().enumerate() {
                *s = issue.max(free_at[i]);
            }
            let local = &arrivals[b * stride..(b + 1) * stride];
            crate::sim::offset_arrivals(local, &starts, r, &mut abs_arrivals);
            let view = RoundView {
                arrivals: &abs_arrivals,
                comp: batch.comp_round(b),
                comm: batch.comm_round(b),
            };
            let c = if ingest_ms == 0.0 {
                evaluator.completion(&view, &mut rng_sched)
            } else {
                evaluator.completion_ingest(&view, ingest_ms, &mut rng_sched)
            };
            // free times: finished the queue, or stopped at c_t
            let comp = batch.comp_round(b);
            for i in 0..n {
                let total: f64 = comp[i * r..(i + 1) * r].iter().sum();
                free_at[i] = c.min(starts[i] + total);
            }
            // censored causal feedback (buffered S rounds) + trace tap
            if engine.is_some() || trace.is_some() {
                let version = (round + 1).saturating_sub(staleness) as u32;
                for i in 0..n {
                    for j in 0..r {
                        let slot = i * r + j;
                        if abs_arrivals[slot] <= c {
                            if engine.is_some() {
                                obs_buf[slot_ix].push((i, view.comp[slot], view.comm[slot]));
                            }
                            if let Some(rec) = trace.as_deref_mut() {
                                rec.push_slot(
                                    round,
                                    i,
                                    j,
                                    view.comp[slot],
                                    view.comm[slot],
                                    replanned,
                                    version,
                                );
                            }
                        }
                    }
                }
            }
            spans.begin(round, sim_us(issue));
            let (mut first, mut first_w) = (f64::INFINITY, 0usize);
            for (slot, &a) in abs_arrivals.iter().enumerate() {
                if a < first {
                    first = a;
                    first_w = slot / r;
                }
            }
            if first <= c {
                spans.frame(round, first_w, sim_us(first));
            }
            spans.complete(round, None, sim_us(c));
            tm::SIM_ROUNDS_TOTAL.inc();
            let apply = applied_at.max(c);
            let d = apply - applied_at;
            applied_at = apply;
            apply_ring[slot_ix] = apply;
            spans.apply(round, sim_us(apply));
            stats.push(d);
            quantiles.push(d);
            if let Some(f) = emit.as_mut() {
                (*f)(round, d);
            }
        }
        done += chunk;
    }

    let label = match policy {
        PolicyKind::Static => format!("{scheme_id}@s{staleness}"),
        _ => format!("{scheme_id}+{policy}@s{staleness}"),
    };
    let elapsed = run_t0.elapsed().as_secs_f64();
    if elapsed > 0.0 {
        tm::SIM_ROUNDS_PER_SEC.set(rounds as f64 / elapsed);
    }
    tm::SIM_EST_MEAN_MS.set(stats.mean());
    Ok(PolicyOutcome {
        estimate: CompletionEstimate::from_streams(label, n, r, k, &stats, &quantiles),
        replans: engine.as_ref().map_or(0, |e| e.replans()),
        decision_digest: engine.as_ref().map_or(0, |e| e.decision_digest()),
        spans: spans.summary(),
    })
}

impl MonteCarlo {
    /// The re-planning arm on the Monte-Carlo driver: `trials`
    /// sequential rounds of `scheme` under `policy`.  Adaptation is
    /// causal and therefore single-stream — `threads` is ignored here
    /// (shard 0's RNG streams are used), so estimates are deterministic
    /// in `(trials, seed)` alone.
    #[allow(clippy::too_many_arguments)]
    pub fn estimate_policy(
        &self,
        scheme: SchemeId,
        policy: PolicyKind,
        model: &dyn RoundDelayModel,
        n: usize,
        r: usize,
        k: usize,
        ingest_ms: f64,
    ) -> Result<PolicyOutcome> {
        run_policy_rounds(
            &PolicyRunConfig {
                scheme,
                policy,
                n,
                r,
                k,
                rounds: self.trials,
                ingest_ms,
                seed: self.seed,
                staleness: 1,
            },
            model,
            None,
            None,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shifting_rotation_moves_the_slow_block() {
        let base = two_tier_model(6, 2, 4.0);
        let shifting = ShiftingStraggler::new(&base, 10, 2);
        assert_eq!(shifting.offset_at(0, 6), 0);
        assert_eq!(shifting.offset_at(9, 6), 0);
        assert_eq!(shifting.offset_at(10, 6), 2);
        assert_eq!(shifting.offset_at(35, 6), 0, "wraps mod n");
        // segment 0: workers 0,1 slow; after one shift the block moved
        let mut rng = Rng::seed_from_u64(3);
        let mut s = DelaySample::zeros(6, 4);
        let mut mean_of = |round: usize, w: usize, rng: &mut Rng| {
            let mut acc = 0.0;
            for _ in 0..200 {
                shifting.sample_round_into(round, &mut s, rng);
                acc += s.comp_row(w).iter().sum::<f64>() / 4.0;
            }
            acc / 200.0
        };
        assert!(mean_of(0, 0, &mut rng) > 0.3);
        assert!(mean_of(0, 3, &mut rng) < 0.2);
        // after the shift, base rows rotate left by 2: slow rows 0,1
        // now land on workers 4,5
        assert!(mean_of(10, 4, &mut rng) > 0.3);
        assert!(mean_of(10, 0, &mut rng) < 0.2);
    }

    #[test]
    fn per_round_adapter_matches_model_stream() {
        // PerRound must consume the base model's RNG stream verbatim
        let model = two_tier_model(4, 1, 2.0);
        let mut a = Rng::seed_from_u64(5);
        let mut b = Rng::seed_from_u64(5);
        let mut s1 = DelaySample::zeros(4, 3);
        let mut s2 = DelaySample::zeros(4, 3);
        let adapter = PerRound(&model);
        for round in 0..7 {
            adapter.sample_round_into(round, &mut s1, &mut a);
            model.sample_into(&mut s2, &mut b);
            assert_eq!(s1.comp_flat(), s2.comp_flat(), "round {round}");
            assert_eq!(s1.comm_flat(), s2.comm_flat(), "round {round}");
        }
    }

    #[test]
    fn policy_runs_reject_impossible_combinations() {
        let model = two_tier_model(6, 3, 3.0);
        let run = |scheme, policy, n, r| {
            run_policy_rounds(
                &PolicyRunConfig {
                    scheme,
                    policy,
                    n,
                    r,
                    k: n,
                    rounds: 4,
                    ingest_ms: 0.0,
                    seed: 1,
                    staleness: 1,
                },
                &PerRound(&model),
                None,
                None,
            )
        };
        assert!(run(SchemeId::Pc, PolicyKind::AdaptiveOrder, 6, 3).is_err());
        assert!(run(SchemeId::Lb, PolicyKind::AdaptiveLoad, 6, 3).is_err());
        assert!(
            run(SchemeId::Ra, PolicyKind::AdaptiveOrder, 6, 3).is_err(),
            "RA needs r = n anyway"
        );
        assert!(run(SchemeId::GcHet(2, 1), PolicyKind::AdaptiveLoad, 6, 2).is_err());
        assert!(
            run(SchemeId::Cs, PolicyKind::AllocGroup, 6, 4).is_err(),
            "alloc-group needs r | n"
        );
        // and the valid shapes run
        assert!(run(SchemeId::Gc(2), PolicyKind::AdaptiveLoad, 6, 4).is_ok());
        assert!(run(SchemeId::Ss, PolicyKind::AdaptiveOrder, 6, 3).is_ok());
        assert!(run(SchemeId::Cs, PolicyKind::AllocGroup, 6, 3).is_ok());
        assert!(run(SchemeId::Pcmm, PolicyKind::Static, 6, 3).is_ok());
    }

    #[test]
    fn staleness_bounds_are_enforced() {
        let model = two_tier_model(6, 3, 3.0);
        let run = |staleness| {
            run_policy_rounds(
                &PolicyRunConfig {
                    scheme: SchemeId::Cs,
                    policy: PolicyKind::Static,
                    n: 6,
                    r: 3,
                    k: 6,
                    rounds: 4,
                    ingest_ms: 0.0,
                    seed: 1,
                    staleness,
                },
                &PerRound(&model),
                None,
                None,
            )
        };
        assert!(run(0).is_err(), "S = 0 is meaningless");
        assert!(run(MAX_STALENESS + 1).is_err(), "above the window cap");
        assert!(run(1).is_ok());
        assert!(run(MAX_STALENESS).is_ok());
    }

    #[test]
    fn async_rounds_are_causal_and_labelled() {
        // d_t ≥ 0 always (in-order apply), every round emits exactly
        // once and in order, and the label carries the @sS suffix
        let model = two_tier_model(6, 2, 3.0);
        let mut seen = Vec::new();
        let out = run_policy_rounds(
            &PolicyRunConfig {
                scheme: SchemeId::Gc(2),
                policy: PolicyKind::AdaptiveOrder,
                n: 6,
                r: 4,
                k: 6,
                rounds: 120,
                ingest_ms: 0.0,
                seed: 7,
                staleness: 3,
            },
            &PerRound(&model),
            Some(&mut |round, d| seen.push((round, d))),
            None,
        )
        .unwrap();
        assert_eq!(seen.len(), 120);
        for (ix, &(round, d)) in seen.iter().enumerate() {
            assert_eq!(round, ix, "emitted out of order");
            assert!(d >= 0.0, "negative apply delta at round {round}");
        }
        // total wall-clock = Σ d_t must be positive and finite
        let total: f64 = seen.iter().map(|&(_, d)| d).sum();
        assert!(total.is_finite() && total > 0.0);
        assert_eq!(out.estimate.scheme, "GC(2)+order@s3");
    }

    #[test]
    fn async_pipelining_beats_sync_on_the_same_delay_stream() {
        // monotone coupling: both runs consume the identical delay
        // stream (chunked sampling order is S-inert), and under S ≥ 2
        // every round's issue instant a_t = apply_{t−S} ≤ apply_{t−1} =
        // the sync start — so total applied wall-clock can only shrink.
        // Static policy isolates the pipelining effect from adaptation.
        let base = two_tier_model(8, 2, 4.0);
        let model = ShiftingStraggler::new(&base, 40, 2);
        let run = |staleness| {
            run_policy_rounds(
                &PolicyRunConfig {
                    scheme: SchemeId::Cs,
                    policy: PolicyKind::Static,
                    n: 8,
                    r: 3,
                    k: 8,
                    rounds: 400,
                    ingest_ms: 0.0,
                    seed: 21,
                    staleness,
                },
                &model,
                None,
                None,
            )
            .unwrap()
            .estimate
            .mean
        };
        let sync = run(1);
        let async2 = run(2);
        let async4 = run(4);
        assert!(
            async2 < sync,
            "S=2 ({async2}) should beat sync ({sync}) per applied round"
        );
        assert!(
            async4 <= async2 * 1.05,
            "deeper pipelines don't regress: S=4 {async4} vs S=2 {async2}"
        );
    }

    #[test]
    fn async_static_run_reports_version_gap_bound() {
        // recorded trace versions never lag the round by more than S−1
        use crate::trace::TraceRecorder;
        let model = two_tier_model(6, 2, 3.0);
        let staleness = 3usize;
        let mut rec = TraceRecorder::with_fleet("CS@s3", 6);
        run_policy_rounds(
            &PolicyRunConfig {
                scheme: SchemeId::Cs,
                policy: PolicyKind::Static,
                n: 6,
                r: 4,
                k: 6,
                rounds: 60,
                ingest_ms: 0.0,
                seed: 9,
                staleness,
            },
            &PerRound(&model),
            None,
            Some(&mut rec),
        )
        .unwrap();
        let store = rec.into_store();
        assert!(store.events().len() > 0, "async run recorded no events");
        for ev in store.events() {
            let gap = ev.round as i64 - ev.version as i64;
            assert!(
                (0..staleness as i64).contains(&gap),
                "round {} tagged version {} — gap {gap} outside [0, S)",
                ev.round,
                ev.version
            );
        }
    }

    #[test]
    fn alloc_random_matches_ra_at_full_load() {
        // alloc-random over CS at r = n is RA by another name; their
        // estimates should agree statistically on the same model
        let model = TruncatedGaussianModel::scenario1(6);
        let mc = MonteCarlo {
            trials: 3000,
            seed: 11,
            threads: 1,
        };
        let alloc = mc
            .estimate_policy(
                SchemeId::Cs,
                PolicyKind::AllocRandom,
                &PerRound(&model),
                6,
                6,
                5,
                0.0,
            )
            .unwrap();
        let ra = mc.estimate(&crate::scheduler::RandomAssignment, &model, 6, 6, 5);
        let slack = 4.0 * (alloc.estimate.std_err + ra.std_err);
        assert!(
            (alloc.estimate.mean - ra.mean).abs() < slack,
            "alloc-random {} vs RA {} (slack {slack})",
            alloc.estimate.mean,
            ra.mean
        );
    }
}
