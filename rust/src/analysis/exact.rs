//! Closed-form ground truth for the `r = 1` shifted-exponential case.
//!
//! With `r = 1` and the CS/SS schedules, worker `j` computes task `j`
//! only, so the per-task arrival times `t_j = T⁽¹⁾ + T⁽²⁾` are i.i.d.
//! hypoexponential sums (plus deterministic shifts) and the completion
//! time is the k-th order statistic of n i.i.d. variables:
//!
//! ```text
//! Pr{t_(k) > t} = Σ_{j=0}^{k−1} C(n,j) F(t)ʲ S(t)^{n−j}
//! ```
//!
//! The mean is integrated with adaptive Simpson.  This path provides
//! *true analytic numbers* (independent of the simulator's code) that
//! the test suite compares against Monte-Carlo output — closing the
//! loop that Theorem-1 internal consistency alone cannot.

use crate::delay::exponential::ShiftedExp;
use crate::util::combin::binomial_f64;
use crate::util::math::adaptive_simpson;

/// Survival function of `X + Y` where `X = s₁ + Exp(λ₁)`,
/// `Y = s₂ + Exp(λ₂)` (hypoexponential with a deterministic shift).
pub fn sum_survival(comp: ShiftedExp, comm: ShiftedExp, t: f64) -> f64 {
    let shift = comp.shift + comm.shift;
    if t <= shift {
        return 1.0;
    }
    let u = t - shift;
    let (l1, l2) = (comp.rate, comm.rate);
    if (l1 - l2).abs() < 1e-9 * l1.max(l2) {
        // Erlang-2 limit
        let l = 0.5 * (l1 + l2);
        (1.0 + l * u) * (-l * u).exp()
    } else {
        (l2 * (-l1 * u).exp() - l1 * (-l2 * u).exp()) / (l2 - l1)
    }
}

/// Survival of the k-th order statistic of `n` i.i.d. variables with
/// elementwise survival `s`.
pub fn order_stat_survival(n: usize, k: usize, s: f64) -> f64 {
    debug_assert!((0.0..=1.0 + 1e-12).contains(&s));
    let f = 1.0 - s;
    let mut total = 0.0;
    for j in 0..k {
        total += binomial_f64(n as u64, j as u64) * f.powi(j as i32) * s.powi((n - j) as i32);
    }
    total.clamp(0.0, 1.0)
}

/// Exact `t̄(r=1, k)` for i.i.d. shifted-exponential comp/comm delays.
pub fn mean_completion_r1_exp(n: usize, k: usize, comp: ShiftedExp, comm: ShiftedExp) -> f64 {
    assert!(k >= 1 && k <= n);
    let shift = comp.shift + comm.shift;
    // upper integration limit: far into the exponential tail
    let tail = 60.0 / comp.rate.min(comm.rate);
    let sf = |t: f64| order_stat_survival(n, k, sum_survival(comp, comm, t));
    shift + adaptive_simpson(&sf, shift, shift + tail, 1e-10)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::delay::ShiftedExponential;
    use crate::scheduler::CyclicScheduler;
    use crate::sim::MonteCarlo;

    #[test]
    fn sum_survival_is_valid_tail() {
        let c1 = ShiftedExp::new(0.1, 2.0);
        let c2 = ShiftedExp::new(0.2, 5.0);
        assert_eq!(sum_survival(c1, c2, 0.0), 1.0);
        assert_eq!(sum_survival(c1, c2, 0.3), 1.0);
        let mut last = 1.0;
        for i in 1..200 {
            let t = 0.3 + i as f64 * 0.05;
            let s = sum_survival(c1, c2, t);
            assert!(s <= last + 1e-12, "survival must be non-increasing");
            assert!((0.0..=1.0).contains(&s));
            last = s;
        }
        assert!(last < 1e-6);
    }

    #[test]
    fn sum_survival_equal_rates_is_erlang() {
        let c = ShiftedExp::new(0.0, 3.0);
        // Erlang-2: S(t) = (1 + λt)e^{−λt}
        let t = 0.7;
        let want = (1.0 + 3.0 * t) * (-3.0 * t as f64).exp();
        assert!((sum_survival(c, c, t) - want).abs() < 1e-9);
    }

    #[test]
    fn sum_mean_from_survival_integral() {
        // E[X+Y] = shifts + 1/λ₁ + 1/λ₂ must equal ∫ S dt
        let c1 = ShiftedExp::new(0.1, 2.0);
        let c2 = ShiftedExp::new(0.05, 4.0);
        let integral = adaptive_simpson(&|t| sum_survival(c1, c2, t), 0.0, 40.0, 1e-11);
        let want = 0.15 + 0.5 + 0.25;
        assert!((integral - want).abs() < 1e-7, "{integral} vs {want}");
    }

    #[test]
    fn order_stat_survival_boundaries() {
        // k = 1: survival of the minimum = sⁿ
        assert!((order_stat_survival(5, 1, 0.8) - 0.8f64.powi(5)).abs() < 1e-12);
        // k = n: survival of the maximum = 1 − (1−s)ⁿ
        assert!((order_stat_survival(5, 5, 0.8) - (1.0 - 0.2f64.powi(5))).abs() < 1e-12);
        // degenerate s
        assert_eq!(order_stat_survival(4, 2, 1.0), 1.0);
        assert_eq!(order_stat_survival(4, 2, 0.0), 0.0);
    }

    #[test]
    fn analytic_matches_monte_carlo() {
        // the headline cross-check: true analytic t̄ vs the simulator
        let comp = ShiftedExp::new(0.1, 5.0);
        let comm = ShiftedExp::new(0.3, 2.0);
        let model = ShiftedExponential { comp, comm };
        let mc = MonteCarlo::new(150_000, 99);
        for (n, k) in [(4, 1), (4, 3), (8, 8), (10, 6)] {
            let exact = mean_completion_r1_exp(n, k, comp, comm);
            let est = mc.estimate(&CyclicScheduler, &model, n, 1, k);
            assert!(
                (exact - est.mean).abs() < 5.0 * est.std_err + 1e-4,
                "n={n} k={k}: exact {exact} vs MC {} ± {}",
                est.mean,
                est.std_err
            );
        }
    }

    #[test]
    fn mean_increasing_in_k_decreasing_in_n() {
        let comp = ShiftedExp::new(0.1, 5.0);
        let comm = ShiftedExp::new(0.3, 2.0);
        let m1 = mean_completion_r1_exp(8, 2, comp, comm);
        let m2 = mean_completion_r1_exp(8, 5, comp, comm);
        let m3 = mean_completion_r1_exp(8, 8, comp, comm);
        assert!(m1 < m2 && m2 < m3);
        // fixed k, more workers → k-th order stat shrinks
        let w8 = mean_completion_r1_exp(8, 4, comp, comm);
        let w12 = mean_completion_r1_exp(12, 4, comp, comm);
        assert!(w12 < w8);
    }
}
