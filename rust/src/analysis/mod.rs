//! Analytic completion-time evaluation — Theorem 1 (paper §III).
//!
//! Theorem 1 expresses the completion-time tail for *any* TO matrix via
//! inclusion–exclusion over task subsets:
//!
//! ```text
//! Pr{t_C(r,k) > t} = Σ_{i=n−k+1}^{n} (−1)^{n−k+i+1} C(i−1, n−k)
//!                      Σ_{|S|=i} Pr{ t_j > t  ∀ j ∈ S }          (7)
//! ```
//!
//! Integrating (8) and using `∫₀^∞ Pr{min_{j∈S} t_j > t} dt =
//! E[min_{j∈S} t_j]` turns the average completion time into a signed sum
//! of **expected subset minima** of the per-task arrival times `t_j`:
//!
//! ```text
//! t̄_C(r,k) = Σ_i (−1)^{n−k+i+1} C(i−1, n−k) Σ_{|S|=i} E[ min_{j∈S} t_j ]
//! ```
//!
//! [`theorem1_mean`] evaluates that sum *exactly under the empirical
//! measure* of a set of Monte-Carlo draws of `(t_1, …, t_n)`.  Because
//! Theorem 1 holds for any distribution — including the empirical one —
//! the result must agree with the direct estimator
//! [`empirical_mean`] up to floating-point error, for every TO matrix
//! and delay model.  This is the strongest possible cross-validation of
//! the simulator and is enforced by tests and proptests.
//!
//! [`exact`] additionally provides closed-form survival functions for
//! the `r = 1` shifted-exponential case (hypoexponential sums), so the
//! whole pipeline is checked against *true* analytic numbers, not just
//! internal consistency.

pub mod exact;

use crate::util::combin::binomial_f64;
use crate::util::rng::Rng;

/// Per-round first-arrival times `t_j` for each task (rows = rounds).
pub struct TaskTimeSamples {
    pub n: usize,
    /// flattened rounds × n
    times: Vec<f64>,
}

impl TaskTimeSamples {
    pub fn new(n: usize) -> Self {
        Self {
            n,
            times: Vec::new(),
        }
    }

    pub fn push_round(&mut self, t: &[f64]) {
        assert_eq!(t.len(), self.n);
        self.times.extend_from_slice(t);
    }

    pub fn rounds(&self) -> usize {
        self.times.len() / self.n
    }

    pub fn round(&self, idx: usize) -> &[f64] {
        &self.times[idx * self.n..(idx + 1) * self.n]
    }
}

/// The Theorem-1 sign/coefficient `(−1)^{n−k+i+1} C(i−1, n−k)` for the
/// size-`i` subset layer (from eq. 16).
pub fn theorem1_coefficient(n: usize, k: usize, i: usize) -> f64 {
    debug_assert!(i >= n - k + 1 && i <= n);
    let sign = if (n - k + i + 1) % 2 == 0 { 1.0 } else { -1.0 };
    sign * binomial_f64((i - 1) as u64, (n - k) as u64)
}

/// Evaluate Theorem 1 under the empirical measure of `samples`:
/// `t̄_C(r,k)` as the signed sum of expected subset minima.
///
/// Complexity `O(rounds · 2ⁿ)` using an in-place subset-minimum DP over
/// bitmasks (each mask extends a smaller mask by its lowest set bit), so
/// practical for `n ≤ 20`; the engine asserts `n ≤ 24` to keep memory
/// bounded.
pub fn theorem1_mean(samples: &TaskTimeSamples, k: usize) -> f64 {
    let n = samples.n;
    assert!(n <= 24, "Theorem-1 evaluator is exponential in n; n ≤ 24");
    assert!(k >= 1 && k <= n);
    let rounds = samples.rounds();
    assert!(rounds > 0, "no samples");

    let full = 1usize << n;
    // accumulate E[min over S] per mask
    let mut acc = vec![0.0f64; full];
    let mut min_s = vec![0.0f64; full];
    for round in 0..rounds {
        let t = samples.round(round);
        // DP: min over mask = min(t[lowest bit], min over rest)
        for mask in 1..full {
            let low = mask.trailing_zeros() as usize;
            let rest = mask & (mask - 1);
            let m = if rest == 0 {
                t[low]
            } else {
                t[low].min(min_s[rest])
            };
            min_s[mask] = m;
        }
        for mask in 1..full {
            acc[mask] += min_s[mask];
        }
    }
    let inv_rounds = 1.0 / rounds as f64;

    // signed layer sums
    let mut total = 0.0;
    for mask in 1..full {
        let i = mask.count_ones() as usize;
        if i >= n - k + 1 {
            total += theorem1_coefficient(n, k, i) * acc[mask] * inv_rounds;
        }
    }
    total
}

/// Direct estimator: mean of the k-th smallest *distinct-task* arrival
/// time per round — i.e. the k-th order statistic of `(t_1, …, t_n)`
/// (the completion time, since `t_j` are per-task first arrivals).
pub fn empirical_mean(samples: &TaskTimeSamples, k: usize) -> f64 {
    let n = samples.n;
    assert!(k >= 1 && k <= n);
    let rounds = samples.rounds();
    let mut scratch = vec![0.0f64; n];
    let mut sum = 0.0;
    for round in 0..rounds {
        scratch.copy_from_slice(samples.round(round));
        scratch.sort_unstable_by(f64::total_cmp);
        sum += scratch[k - 1];
    }
    sum / rounds as f64
}

/// Collect per-task arrival-time samples for a (scheduler, model) pair.
pub fn collect_task_times(
    scheduler: &dyn crate::scheduler::Scheduler,
    model: &dyn crate::delay::DelayModel,
    n: usize,
    r: usize,
    rounds: usize,
    seed: u64,
) -> TaskTimeSamples {
    
    let mut rng = Rng::seed_from_u64(seed);
    let mut rng_sched = Rng::seed_from_u64(seed ^ 0x5C4ED);
    let mut out = TaskTimeSamples::new(n);
    let mut sample = crate::delay::DelaySample::zeros(n, r);
    let fixed = if scheduler.is_randomized() {
        None
    } else {
        Some(scheduler.schedule(n, r, &mut rng_sched))
    };
    for _ in 0..rounds {
        model.sample_into(&mut sample, &mut rng);
        let to = match &fixed {
            Some(to) => to.clone(),
            None => scheduler.schedule(n, r, &mut rng_sched),
        };
        let t = crate::sim::task_arrival_times(&to, &sample);
        out.push_round(&t);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::delay::{ShiftedExponential, TruncatedGaussianModel};
    use crate::scheduler::{CyclicScheduler, RandomAssignment, StaircaseScheduler};

    #[test]
    fn coefficient_matches_eq_16() {
        // n = 4, k = 3 → n−k = 1; layers i = 2, 3, 4
        // i=2: (−1)^{1+2+1} C(1,1) = +1 ; i=3: (−1)^{1+3+1} C(2,1) = −2
        // i=4: (−1)^{1+4+1} C(3,1) = +3
        assert_eq!(theorem1_coefficient(4, 3, 2), 1.0);
        assert_eq!(theorem1_coefficient(4, 3, 3), -2.0);
        assert_eq!(theorem1_coefficient(4, 3, 4), 3.0);
        // k = n → alternating ±1·C(i−1, 0)
        assert_eq!(theorem1_coefficient(5, 5, 1), 1.0);
        assert_eq!(theorem1_coefficient(5, 5, 2), -1.0);
        assert_eq!(theorem1_coefficient(5, 5, 5), 1.0);
    }

    #[test]
    fn max_min_identity_for_k_equals_n() {
        // for k = n Theorem 1 reduces to the classic
        // E[max] = Σ (−1)^{|S|+1} E[min over S] identity
        let mut s = TaskTimeSamples::new(3);
        s.push_round(&[1.0, 2.0, 5.0]);
        s.push_round(&[4.0, 1.0, 3.0]);
        let got = theorem1_mean(&s, 3);
        let want = (5.0 + 4.0) / 2.0;
        assert!((got - want).abs() < 1e-12, "{got} vs {want}");
    }

    #[test]
    fn theorem1_equals_order_statistic_on_fixed_samples() {
        // the identity holds under the empirical measure for every k
        let mut s = TaskTimeSamples::new(5);
        s.push_round(&[0.3, 1.2, 0.7, 2.0, 0.9]);
        s.push_round(&[1.1, 0.2, 3.0, 0.4, 0.8]);
        s.push_round(&[2.2, 2.1, 0.1, 0.6, 1.4]);
        for k in 1..=5 {
            let t1 = theorem1_mean(&s, k);
            let emp = empirical_mean(&s, k);
            assert!(
                (t1 - emp).abs() < 1e-9,
                "k={k}: theorem1 {t1} vs empirical {emp}"
            );
        }
    }

    #[test]
    fn theorem1_validates_simulator_cs() {
        let model = TruncatedGaussianModel::scenario1(6);
        let samples = collect_task_times(&CyclicScheduler, &model, 6, 3, 400, 21);
        for k in [1, 3, 6] {
            let t1 = theorem1_mean(&samples, k);
            let emp = empirical_mean(&samples, k);
            assert!(
                (t1 - emp).abs() < 1e-8,
                "k={k}: {t1} vs {emp}"
            );
        }
    }

    #[test]
    fn theorem1_validates_simulator_ss_and_ra() {
        let model = ShiftedExponential::new(0.05, 4.0, 0.2, 2.0);
        for sched in [
            &StaircaseScheduler as &dyn crate::scheduler::Scheduler,
            &RandomAssignment,
        ] {
            let samples = collect_task_times(sched, &model, 5, 5, 300, 33);
            for k in 2..=5 {
                let t1 = theorem1_mean(&samples, k);
                let emp = empirical_mean(&samples, k);
                assert!((t1 - emp).abs() < 1e-8, "{} k={k}", sched.name());
            }
        }
    }

    #[test]
    #[should_panic(expected = "exponential in n")]
    fn refuses_large_n() {
        let s = TaskTimeSamples::new(30);
        theorem1_mean(&s, 2);
    }
}
