//! LRU cache of [`DecodeWeights`] keyed by the responding subset.
//!
//! The PC/PCMM decode weights depend only on *which* workers (or
//! worker-slots) the master heard from — not on the round's data.
//! Stragglers recur, so responder subsets repeat round-over-round and
//! the fresh `O(m²)` weight build amortizes to a key lookup.  The cache
//! is a bounded LRU (small linear-scan `Vec`; keys are short sorted id
//! lists and the bound is tens of entries, so a hash map would cost
//! more than it saves) with hit/miss/eviction counters surfaced through
//! `ClusterReport` and trace replay.
//!
//! Keys must be **canonical** (sorted ascending) so the same subset
//! hits regardless of arrival order — `PcScheme::decode_cached` /
//! `PcmmScheme::decode_cached` canonicalize before lookup.

use super::poly::DecodeWeights;

/// Hit/miss/eviction counters for one cache (cheap to copy around).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct DecodeCacheStats {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
}

impl DecodeCacheStats {
    pub fn lookups(&self) -> u64 {
        self.hits + self.misses
    }

    /// Fraction of lookups served from cache (0.0 when never used).
    pub fn hit_rate(&self) -> f64 {
        let lookups = self.lookups();
        if lookups == 0 {
            0.0
        } else {
            self.hits as f64 / lookups as f64
        }
    }

    /// Fold another cache's counters in (per-run totals across schemes).
    pub fn merge(&mut self, other: &DecodeCacheStats) {
        self.hits += other.hits;
        self.misses += other.misses;
        self.evictions += other.evictions;
    }
}

/// Bounded LRU from canonical responder-subset keys to decode weights.
#[derive(Debug, Clone)]
pub struct DecodeCache {
    cap: usize,
    /// LRU order: least-recently-used first, most-recent last.
    entries: Vec<(Vec<usize>, DecodeWeights)>,
    stats: DecodeCacheStats,
}

impl DecodeCache {
    /// Default bound: generous for the paper's fleet sizes (an n-worker
    /// PC run has at most `C(n, 2c−1)` subsets but in practice a
    /// handful of straggler patterns dominate), tiny in memory (one
    /// `m`-length weight vector per entry).
    pub const DEFAULT_CAP: usize = 64;

    pub fn new(cap: usize) -> Self {
        assert!(cap >= 1, "cache bound must be ≥ 1");
        Self {
            cap,
            entries: Vec::with_capacity(cap.min(Self::DEFAULT_CAP)),
            stats: DecodeCacheStats::default(),
        }
    }

    pub fn with_default_cap() -> Self {
        Self::new(Self::DEFAULT_CAP)
    }

    /// Weights for `key` (a canonical, ascending responder id list):
    /// cache hit refreshes recency; miss builds via `build`, evicting
    /// the least-recently-used entry at the bound.
    pub fn weights_for(
        &mut self,
        key: &[usize],
        build: impl FnOnce() -> DecodeWeights,
    ) -> &DecodeWeights {
        if let Some(pos) = self.entries.iter().position(|(k, _)| k.as_slice() == key) {
            self.stats.hits += 1;
            let entry = self.entries.remove(pos);
            self.entries.push(entry);
        } else {
            self.stats.misses += 1;
            if self.entries.len() == self.cap {
                self.entries.remove(0);
                self.stats.evictions += 1;
            }
            self.entries.push((key.to_vec(), build()));
        }
        &self.entries.last().expect("just inserted or refreshed").1
    }

    pub fn stats(&self) -> DecodeCacheStats {
        self.stats
    }

    /// Entries currently held.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn cap(&self) -> usize {
        self.cap
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn weights_of(key: &[usize]) -> DecodeWeights {
        // distinct fake points derived from the key — enough to tell
        // entries apart
        let xs: Vec<f64> = key.iter().map(|&k| 1.0 + k as f64).collect();
        DecodeWeights::build(&xs, &[0.0])
    }

    #[test]
    fn hits_and_misses_are_counted() {
        let mut c = DecodeCache::new(4);
        c.weights_for(&[0, 1, 2], || weights_of(&[0, 1, 2]));
        c.weights_for(&[0, 1, 3], || weights_of(&[0, 1, 3]));
        c.weights_for(&[0, 1, 2], || panic!("must hit, not rebuild"));
        let s = c.stats();
        assert_eq!((s.hits, s.misses, s.evictions), (1, 2, 0));
        assert_eq!(s.lookups(), 3);
        assert!((s.hit_rate() - 1.0 / 3.0).abs() < 1e-12);
        assert_eq!(c.len(), 2);
    }

    #[test]
    fn cached_weights_equal_fresh_build() {
        let mut c = DecodeCache::new(2);
        let key = [1usize, 4, 6];
        let fresh = weights_of(&key);
        let first = c.weights_for(&key, || weights_of(&key)).weights().to_vec();
        let hit = c.weights_for(&key, || panic!("hit expected")).weights().to_vec();
        assert_eq!(first, fresh.weights());
        assert_eq!(hit, fresh.weights());
    }

    #[test]
    fn evicts_least_recently_used_at_bound() {
        let mut c = DecodeCache::new(2);
        c.weights_for(&[0], || weights_of(&[0]));
        c.weights_for(&[1], || weights_of(&[1]));
        // touch [0] so [1] becomes LRU
        c.weights_for(&[0], || panic!("hit expected"));
        c.weights_for(&[2], || weights_of(&[2])); // evicts [1]
        assert_eq!(c.stats().evictions, 1);
        // [1] gone (rebuild), [0] still resident (hit)
        let mut rebuilt = false;
        c.weights_for(&[1], || {
            rebuilt = true;
            weights_of(&[1])
        });
        assert!(rebuilt, "LRU entry [1] should have been evicted");
        // reinserting [1] at the bound evicts the now-LRU [0]
        assert_eq!(c.stats().evictions, 2);
    }

    #[test]
    fn hit_rate_zero_when_unused() {
        let c = DecodeCache::with_default_cap();
        assert_eq!(c.stats().hit_rate(), 0.0);
        assert!(c.is_empty());
        assert_eq!(c.cap(), DecodeCache::DEFAULT_CAP);
    }

    #[test]
    fn merge_folds_counters() {
        let mut a = DecodeCacheStats {
            hits: 3,
            misses: 2,
            evictions: 1,
        };
        let b = DecodeCacheStats {
            hits: 1,
            misses: 4,
            evictions: 0,
        };
        a.merge(&b);
        assert_eq!(
            a,
            DecodeCacheStats {
                hits: 4,
                misses: 6,
                evictions: 1
            }
        );
    }
}
