//! Coded-computation baselines (paper §VI-B): **PC** — polynomially
//! coded regression [13] — and **PCMM** — polynomially coded
//! multi-message [17].
//!
//! Unlike most reproductions, these are *real* implementations, not just
//! timing formulas: [`poly`] provides the vector Newton interpolation
//! the master actually runs, [`pc`]/[`pcmm`] build the true encoding
//! coefficient matrices (eqs. 53, 58), and tests verify that encoding →
//! per-worker gram computation → interpolation → reconstruction
//! reproduces `XᵀXθ` exactly.  The timing side (completion criteria of
//! Table I) consumes the same [`crate::delay::DelaySample`]s as the
//! uncoded engine, so comparisons are coupled sample-by-sample.
//!
//! Per the paper, the master-side encode/decode *delay* is excluded from
//! the completion-time metric (it would only worsen the coded schemes);
//! the harness measures it separately and reports it alongside.

//! Decode hot path: reconstruction is *linear* in the received
//! evaluations, so both schemes apply precomputed per-subset
//! [`poly::DecodeWeights`] (canonical responder order), and [`cache`]
//! bounds an LRU of those weights keyed by the responding subset —
//! repeated straggler patterns decode with zero solve work.

pub mod cache;
pub mod pc;
pub mod pcmm;
pub mod poly;

pub use cache::{DecodeCache, DecodeCacheStats};
pub use pc::PcScheme;
pub use pcmm::PcmmScheme;
