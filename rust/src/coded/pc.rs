//! Polynomially coded (PC) regression — Li et al. [13], paper §VI-B.
//!
//! Construction (generalizing Example 4): split the `n` tasks into
//! `c = ⌈n/r⌉` *positions* with stride `r`: position `u` holds tasks
//! `{u·r, …, u·r + r − 1}` … equivalently, worker `i`'s `j`-th coded
//! matrix mixes the tasks `{j, j + r, j + 2r, …}` (the `j`-th member of
//! every group) with Lagrange-basis weights evaluated at `x = i`:
//!
//! ```text
//! X̃_{i,j} = Σ_{u=0}^{c−1} ℓ_u(x_i) · X_{j + u·r}
//! ```
//!
//! Worker `i` computes `Σ_j X̃_{i,j} X̃_{i,j}ᵀ θ = φ(x_i)` — a single
//! degree-`2(c−1)` vector polynomial — and sends the **sum** in one
//! message.  The master interpolates `φ` from any `2c − 1` workers and
//! reconstructs `XᵀXθ = Σ_u φ(node_u)`.
//!
//! Timing (Table I): one message per worker, computation delay = sum of
//! `r` per-task delays ⇒ completion = `(2⌈n/r⌉ − 1)`-th order statistic
//! of `t_i = Σ_j T⁽¹⁾_{i,j} + T⁽²⁾_i` (eqs. 51–52).

use crate::delay::DelaySample;
use crate::linalg::{vec_axpy, Mat};

use super::cache::DecodeCache;
use super::poly::{lagrange_basis, DecodeWeights, NewtonPoly};

/// The PC scheme for `n` tasks/workers at computation load `r ≥ 2`.
#[derive(Debug, Clone)]
pub struct PcScheme {
    pub n: usize,
    pub r: usize,
    /// number of groups `c = ⌈n/r⌉`; polynomial degree is `2(c−1)`
    pub groups: usize,
    /// interpolation nodes (one per group position)
    nodes: Vec<f64>,
    /// evaluation point of worker `i`
    points: Vec<f64>,
}

impl PcScheme {
    pub fn new(n: usize, r: usize) -> Self {
        assert!(r >= 2, "PC requires computation load r ≥ 2 (paper Table I)");
        assert!(r <= n, "load cannot exceed task count");
        let groups = n.div_ceil(r);
        // nodes 1..c and worker points 1..n, as in the paper's examples
        let nodes = (1..=groups).map(|u| u as f64).collect();
        let points = (1..=n).map(|i| i as f64).collect();
        Self {
            n,
            r,
            groups,
            nodes,
            points,
        }
    }

    /// Workers the master must hear from (paper: `2⌈n/r⌉ − 1`).
    pub fn recovery_threshold(&self) -> usize {
        2 * self.groups - 1
    }

    /// Encoding coefficients of worker `i`: `r × n` matrix `A` with
    /// `X̃_{i,j} = Σ_m A[j][m] X_m`.
    pub fn encode_coeffs(&self, worker: usize) -> Vec<Vec<f64>> {
        assert!(worker < self.n);
        let x = self.points[worker];
        let mut rows = vec![vec![0.0; self.n]; self.r];
        for (j, row) in rows.iter_mut().enumerate() {
            for u in 0..self.groups {
                let task = j + u * self.r;
                if task < self.n {
                    row[task] = lagrange_basis(&self.nodes, u, x);
                }
            }
        }
        rows
    }

    /// Worker `i`'s full computation on real data: encode its `r`
    /// matrices, gram-matvec each against `theta`, sum (one message).
    pub fn worker_compute(&self, worker: usize, parts: &[Mat], theta: &[f64]) -> Vec<f64> {
        assert_eq!(parts.len(), self.n, "need all n partitions to encode");
        let coeffs = self.encode_coeffs(worker);
        let d = parts[0].rows;
        let mut total = vec![0.0; d];
        for row in &coeffs {
            let coded = Mat::linear_combination(row, parts);
            vec_axpy(&mut total, 1.0, &coded.gram_matvec(theta));
        }
        total
    }

    /// Master decode: from `(worker, value)` pairs (≥ threshold),
    /// reconstruct `XᵀXθ = Σ_u φ(node_u)`.
    ///
    /// The reconstruction is linear in the received evaluations, so
    /// decode applies precomputed [`DecodeWeights`] for the responding
    /// subset (canonicalized to ascending worker order first, making
    /// the result a pure function of *which* workers responded — not
    /// of arrival order).  Bit-identical to [`Self::decode_cached`] by
    /// construction.
    pub fn decode(&self, responses: &[(usize, Vec<f64>)]) -> Vec<f64> {
        self.decode_with(responses, None)
    }

    /// [`Self::decode`] through an LRU of per-subset weights: repeated
    /// straggler patterns skip the `O(m²·c)` weight build entirely.
    pub fn decode_cached(
        &self,
        responses: &[(usize, Vec<f64>)],
        cache: &mut DecodeCache,
    ) -> Vec<f64> {
        self.decode_with(responses, Some(cache))
    }

    fn decode_with(
        &self,
        responses: &[(usize, Vec<f64>)],
        cache: Option<&mut DecodeCache>,
    ) -> Vec<f64> {
        assert!(
            responses.len() >= self.recovery_threshold(),
            "PC needs {} responses, got {}",
            self.recovery_threshold(),
            responses.len()
        );
        let take = self.recovery_threshold();
        // canonical subset order: ascending worker id
        let mut order: Vec<usize> = (0..take).collect();
        order.sort_unstable_by_key(|&i| responses[i].0);
        let key: Vec<usize> = order.iter().map(|&i| responses[i].0).collect();
        let ys: Vec<&[f64]> = order.iter().map(|&i| responses[i].1.as_slice()).collect();
        match cache {
            Some(c) => c.weights_for(&key, || self.decode_weights(&key)).apply(&ys),
            None => self.decode_weights(&key).apply(&ys),
        }
    }

    /// Decode weights for a canonical (ascending) responding worker
    /// subset — the cacheable, data-independent part of [`Self::decode`].
    pub fn decode_weights(&self, workers: &[usize]) -> DecodeWeights {
        let xs: Vec<f64> = workers.iter().map(|&w| self.points[w]).collect();
        DecodeWeights::build(&xs, &self.nodes)
    }

    /// Reference decode via Newton divided-difference interpolation —
    /// the original `O(m²·d)` per-round path, kept as the numerical
    /// cross-check and the "fresh solve" bench baseline.
    pub fn decode_interpolated(&self, responses: &[(usize, Vec<f64>)]) -> Vec<f64> {
        assert!(
            responses.len() >= self.recovery_threshold(),
            "PC needs {} responses, got {}",
            self.recovery_threshold(),
            responses.len()
        );
        let take = self.recovery_threshold();
        let xs: Vec<f64> = responses[..take]
            .iter()
            .map(|&(w, _)| self.points[w])
            .collect();
        let ys: Vec<Vec<f64>> = responses[..take].iter().map(|(_, v)| v.clone()).collect();
        let phi = NewtonPoly::interpolate(&xs, &ys);
        phi.eval_sum(&self.nodes)
    }

    /// Completion time of one delay realization (eqs. 51–52): worker `i`
    /// finishes at `Σ_{j<r} comp(i,j) + comm(i, r−1)` (all `r` tasks,
    /// one message), and the round completes at the threshold-th order
    /// statistic across workers.
    pub fn completion_time(&self, sample: &DelaySample, scratch: &mut Vec<f64>) -> f64 {
        assert_eq!(sample.n, self.n);
        assert_eq!(sample.r, self.r);
        scratch.clear();
        for i in 0..self.n {
            let comp: f64 = sample.comp_row(i).iter().sum();
            // single message: use the last slot's comm delay (the draw
            // is exchangeable across slots, so any fixed slot works)
            let t = comp + sample.comm(i, self.r - 1);
            scratch.push(t);
        }
        let k = self.recovery_threshold();
        let (_, kth, _) = scratch.select_nth_unstable_by(k - 1, |a, b| a.total_cmp(b));
        *kth
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_parts(n: usize, d: usize, b: usize, rng: &mut Rng) -> Vec<Mat> {
        (0..n)
            .map(|_| Mat::from_fn(d, b, |_, _| rng.normal()))
            .collect()
    }

    fn uncoded_sum(parts: &[Mat], theta: &[f64]) -> Vec<f64> {
        let mut total = vec![0.0; parts[0].rows];
        for p in parts {
            vec_axpy(&mut total, 1.0, &p.gram_matvec(theta));
        }
        total
    }

    #[test]
    fn example_4_coefficients() {
        // paper Example 4: n = 4, r = 2 →
        //   X̃_{i,1} = −(i−2)X_1 + (i−1)X_3,  X̃_{i,2} = −(i−2)X_2 + (i−1)X_4
        let pc = PcScheme::new(4, 2);
        assert_eq!(pc.groups, 2);
        assert_eq!(pc.recovery_threshold(), 3);
        for i in 0..4 {
            let a = pc.encode_coeffs(i);
            let x = (i + 1) as f64;
            // 0-based tasks: X_1→0, X_3→2 in coded matrix j=0
            assert!((a[0][0] - (2.0 - x)).abs() < 1e-12, "worker {i}");
            assert!((a[0][2] - (x - 1.0)).abs() < 1e-12);
            assert_eq!(a[0][1], 0.0);
            assert_eq!(a[0][3], 0.0);
            // X_2→1, X_4→3 in coded matrix j=1
            assert!((a[1][1] - (2.0 - x)).abs() < 1e-12);
            assert!((a[1][3] - (x - 1.0)).abs() < 1e-12);
        }
    }

    #[test]
    fn decode_reconstructs_gram_sum_exactly() {
        let mut rng = Rng::seed_from_u64(12);
        for (n, r) in [(4usize, 2usize), (6, 2), (6, 3), (9, 3), (8, 4)] {
            let pc = PcScheme::new(n, r);
            let (d, b) = (10, 5);
            let parts = random_parts(n, d, b, &mut rng);
            let theta: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
            // any `threshold` workers suffice — pick a scattered subset
            let mut resp = Vec::new();
            for w in (0..n).rev() {
                if resp.len() < pc.recovery_threshold() {
                    resp.push((w, pc.worker_compute(w, &parts, &theta)));
                }
            }
            let got = pc.decode(&resp);
            let want = uncoded_sum(&parts, &theta);
            for lane in 0..d {
                assert!(
                    (got[lane] - want[lane]).abs() < 1e-6 * (1.0 + want[lane].abs()),
                    "n={n} r={r} lane {lane}: {} vs {}",
                    got[lane],
                    want[lane]
                );
            }
        }
    }

    #[test]
    fn non_divisible_n_padded_groups_decode() {
        // n = 5, r = 2 → c = 3 groups, last group ragged
        let mut rng = Rng::seed_from_u64(7);
        let pc = PcScheme::new(5, 2);
        assert_eq!(pc.recovery_threshold(), 5);
        let parts = random_parts(5, 6, 3, &mut rng);
        let theta: Vec<f64> = (0..6).map(|_| rng.normal()).collect();
        let resp: Vec<_> = (0..5)
            .map(|w| (w, pc.worker_compute(w, &parts, &theta)))
            .collect();
        let got = pc.decode(&resp);
        let want = uncoded_sum(&parts, &theta);
        for lane in 0..6 {
            assert!((got[lane] - want[lane]).abs() < 1e-6 * (1.0 + want[lane].abs()));
        }
    }

    #[test]
    fn completion_uses_threshold_order_stat() {
        let pc = PcScheme::new(4, 2);
        // comp rows sum: w0: 3, w1: 1, w2: 9, w3: 5; comm(last): 1 each
        let s = DelaySample::from_rows(
            vec![
                vec![1.0, 2.0],
                vec![0.5, 0.5],
                vec![4.0, 5.0],
                vec![2.0, 3.0],
            ],
            vec![vec![9.0, 1.0]; 4],
        );
        // worker finish times: 4, 2, 10, 6 → 3rd smallest = 6
        let mut scratch = Vec::new();
        assert_eq!(pc.completion_time(&s, &mut scratch), 6.0);
    }

    #[test]
    fn full_load_needs_single_worker_group() {
        // r = n → c = 1, threshold 1: fastest worker alone completes
        let pc = PcScheme::new(4, 4);
        assert_eq!(pc.recovery_threshold(), 1);
        let mut rng = Rng::seed_from_u64(3);
        let parts = random_parts(4, 5, 2, &mut rng);
        let theta: Vec<f64> = (0..5).map(|_| rng.normal()).collect();
        let got = pc.decode(&[(2, pc.worker_compute(2, &parts, &theta))]);
        let want = uncoded_sum(&parts, &theta);
        for lane in 0..5 {
            assert!((got[lane] - want[lane]).abs() < 1e-8 * (1.0 + want[lane].abs()));
        }
    }

    #[test]
    #[should_panic(expected = "r ≥ 2")]
    fn rejects_r1() {
        PcScheme::new(4, 1);
    }

    #[test]
    #[should_panic(expected = "needs")]
    fn decode_rejects_too_few() {
        let pc = PcScheme::new(6, 2);
        pc.decode(&[(0, vec![0.0])]);
    }

    #[test]
    fn weight_decode_matches_newton_reference() {
        let mut rng = Rng::seed_from_u64(17);
        for (n, r) in [(4usize, 2usize), (6, 3), (8, 4)] {
            let pc = PcScheme::new(n, r);
            let parts = random_parts(n, 7, 4, &mut rng);
            let theta: Vec<f64> = (0..7).map(|_| rng.normal()).collect();
            let resp: Vec<_> = (0..pc.recovery_threshold())
                .map(|w| (w, pc.worker_compute(w, &parts, &theta)))
                .collect();
            let (fast, reference) = (pc.decode(&resp), pc.decode_interpolated(&resp));
            for lane in 0..7 {
                assert!(
                    (fast[lane] - reference[lane]).abs() < 1e-9 * (1.0 + reference[lane].abs()),
                    "n={n} r={r} lane {lane}: {} vs {}",
                    fast[lane],
                    reference[lane]
                );
            }
        }
    }

    #[test]
    fn cached_decode_bit_identical_across_arrival_orders() {
        use crate::coded::DecodeCache;
        let mut rng = Rng::seed_from_u64(23);
        let pc = PcScheme::new(6, 3); // threshold 3
        let parts = random_parts(6, 8, 4, &mut rng);
        let theta: Vec<f64> = (0..8).map(|_| rng.normal()).collect();
        let computed: Vec<Vec<f64>> = (0..6)
            .map(|w| pc.worker_compute(w, &parts, &theta))
            .collect();
        let mut cache = DecodeCache::with_default_cap();
        // the same subset {1, 3, 5} in three arrival orders: fresh and
        // cached decodes must all be bit-identical (canonical order)
        let mut want: Option<Vec<f64>> = None;
        for order in [[5usize, 1, 3], [3, 5, 1], [1, 3, 5]] {
            let resp: Vec<_> = order.iter().map(|&w| (w, computed[w].clone())).collect();
            let fresh = pc.decode(&resp);
            let cached = pc.decode_cached(&resp, &mut cache);
            assert_eq!(fresh, cached, "cached ≠ fresh for order {order:?}");
            if let Some(w) = &want {
                assert_eq!(w, &fresh, "arrival order {order:?} changed the decode");
            }
            want = Some(fresh);
        }
        let stats = cache.stats();
        assert_eq!((stats.hits, stats.misses), (2, 1));
    }
}
