//! Polynomially coded multi-message (PCMM) — Ozfatura, Gündüz & Ulukus
//! [17], paper §VI-B.
//!
//! PCMM keeps PC's polynomial structure but lets workers stream partial
//! results: worker `i` stores `r` coded matrices, each a Lagrange
//! combination of **all** `n` partitions evaluated at its own point
//! `β_{i,j}` (eq. 58):
//!
//! ```text
//! X̂_{i,j} = Σ_{m=1}^{n} X_m · ℓ_m(β_{i,j})        nodes = {1, …, n}
//! ```
//!
//! Each gram mat-vec `X̂X̂ᵀθ` is one evaluation of the degree-`2(n−1)`
//! polynomial `ψ(x)` (eq. 59), computed *sequentially* and sent
//! *immediately* — so the master can harvest evaluations from slow
//! workers too.  It interpolates `ψ` from any `2n − 1` evaluations and
//! reconstructs `XᵀXθ = Σ_{u=1}^{n} ψ(u)` (eq. 60).
//!
//! Timing (eqs. 56–57): slot arrivals are identical in law to the
//! uncoded engine's; completion is the `(2n−1)`-th order statistic over
//! **all** `n·r` slot arrivals (no distinctness requirement — every
//! evaluation point is fresh information).
//!
//! `β` points: the paper only requires distinct reals; we use Chebyshev
//! points on `[1, n]` for interpolation stability at degree `2n − 2`
//! (DESIGN.md §5 notes this choice; it does not affect timing).

use crate::delay::DelaySample;
use crate::linalg::Mat;

use super::cache::DecodeCache;
use super::poly::{chebyshev_points, lagrange_basis, DecodeWeights, NewtonPoly};

/// The PCMM scheme for `n` tasks/workers at computation load `r ≥ 2`.
#[derive(Debug, Clone)]
pub struct PcmmScheme {
    pub n: usize,
    pub r: usize,
    /// Lagrange nodes (1..n) used both for encoding and reconstruction.
    nodes: Vec<f64>,
    /// β_{i,j}: evaluation point of worker i's j-th coded matrix.
    betas: Vec<Vec<f64>>,
}

impl PcmmScheme {
    pub fn new(n: usize, r: usize) -> Self {
        assert!(r >= 2, "PCMM requires computation load r ≥ 2 (paper Table I)");
        assert!(r <= n, "load cannot exceed task count");
        assert!(
            n * r >= 2 * n - 1,
            "need n·r ≥ 2n−1 evaluation slots to ever decode"
        );
        let nodes: Vec<f64> = (1..=n).map(|u| u as f64).collect();
        let flat = chebyshev_points(n * r, 1.0, n as f64);
        let betas = (0..n).map(|i| flat[i * r..(i + 1) * r].to_vec()).collect();
        Self { n, r, nodes, betas }
    }

    /// Evaluations the master must collect (paper: `2n − 1`).
    pub fn recovery_threshold(&self) -> usize {
        2 * self.n - 1
    }

    /// β point of worker `i`'s `j`-th computation.
    pub fn beta(&self, worker: usize, slot: usize) -> f64 {
        self.betas[worker][slot]
    }

    /// Encoding coefficients of worker `i`'s `j`-th coded matrix over
    /// the `n` partitions (eq. 58).
    pub fn encode_coeffs(&self, worker: usize, slot: usize) -> Vec<f64> {
        let x = self.beta(worker, slot);
        (0..self.n)
            .map(|m| lagrange_basis(&self.nodes, m, x))
            .collect()
    }

    /// Worker `i`'s `j`-th computation on real data: one evaluation
    /// `ψ(β_{i,j})`, sent to the master immediately.
    pub fn worker_compute(
        &self,
        worker: usize,
        slot: usize,
        parts: &[Mat],
        theta: &[f64],
    ) -> Vec<f64> {
        assert_eq!(parts.len(), self.n, "need all n partitions to encode");
        let coded = Mat::linear_combination(&self.encode_coeffs(worker, slot), parts);
        coded.gram_matvec(theta)
    }

    /// Master decode from `((worker, slot), value)` pairs.
    ///
    /// Linear-weight reconstruction over the responding slot subset,
    /// canonicalized to ascending global slot id `worker·r + slot` —
    /// the result depends only on *which* evaluations arrived, not on
    /// their order.  Bit-identical to [`Self::decode_cached`].
    pub fn decode(&self, responses: &[((usize, usize), Vec<f64>)]) -> Vec<f64> {
        self.decode_with(responses, None)
    }

    /// [`Self::decode`] through an LRU of per-subset weights (keys are
    /// global slot ids): repeated straggler patterns skip the weight
    /// build.
    pub fn decode_cached(
        &self,
        responses: &[((usize, usize), Vec<f64>)],
        cache: &mut DecodeCache,
    ) -> Vec<f64> {
        self.decode_with(responses, Some(cache))
    }

    fn decode_with(
        &self,
        responses: &[((usize, usize), Vec<f64>)],
        cache: Option<&mut DecodeCache>,
    ) -> Vec<f64> {
        assert!(
            responses.len() >= self.recovery_threshold(),
            "PCMM needs {} evaluations, got {}",
            self.recovery_threshold(),
            responses.len()
        );
        let take = self.recovery_threshold();
        // canonical subset order: ascending global slot id
        let mut order: Vec<usize> = (0..take).collect();
        order.sort_unstable_by_key(|&i| {
            let (w, j) = responses[i].0;
            w * self.r + j
        });
        let key: Vec<usize> = order
            .iter()
            .map(|&i| {
                let (w, j) = responses[i].0;
                w * self.r + j
            })
            .collect();
        let ys: Vec<&[f64]> = order.iter().map(|&i| responses[i].1.as_slice()).collect();
        match cache {
            Some(c) => c.weights_for(&key, || self.decode_weights(&key)).apply(&ys),
            None => self.decode_weights(&key).apply(&ys),
        }
    }

    /// Decode weights for a canonical (ascending) global-slot-id
    /// subset — the cacheable, data-independent part of decode.
    pub fn decode_weights(&self, slots: &[usize]) -> DecodeWeights {
        let xs: Vec<f64> = slots
            .iter()
            .map(|&s| self.beta(s / self.r, s % self.r))
            .collect();
        DecodeWeights::build(&xs, &self.nodes)
    }

    /// Reference decode via Newton divided-difference interpolation —
    /// the original per-round path, kept as the numerical cross-check
    /// and the "fresh solve" bench baseline.
    pub fn decode_interpolated(&self, responses: &[((usize, usize), Vec<f64>)]) -> Vec<f64> {
        assert!(
            responses.len() >= self.recovery_threshold(),
            "PCMM needs {} evaluations, got {}",
            self.recovery_threshold(),
            responses.len()
        );
        let take = self.recovery_threshold();
        let xs: Vec<f64> = responses[..take]
            .iter()
            .map(|&((i, j), _)| self.beta(i, j))
            .collect();
        let ys: Vec<Vec<f64>> = responses[..take].iter().map(|(_, v)| v.clone()).collect();
        let psi = NewtonPoly::interpolate(&xs, &ys);
        psi.eval_sum(&self.nodes)
    }

    /// Completion time of one delay realization (eqs. 56–57): the
    /// `(2n−1)`-th smallest slot arrival among all `n·r` slots.
    pub fn completion_time(&self, sample: &DelaySample, scratch: &mut Vec<f64>) -> f64 {
        assert_eq!(sample.n, self.n);
        assert_eq!(sample.r, self.r);
        scratch.clear();
        for i in 0..self.n {
            let comp = sample.comp_row(i);
            let comm = sample.comm_row(i);
            let mut prefix = 0.0;
            for j in 0..self.r {
                prefix += comp[j];
                scratch.push(prefix + comm[j]);
            }
        }
        let k = self.recovery_threshold();
        let (_, kth, _) = scratch.select_nth_unstable_by(k - 1, |a, b| a.total_cmp(b));
        *kth
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::vec_axpy;
    use crate::util::rng::Rng;

    fn random_parts(n: usize, d: usize, b: usize, rng: &mut Rng) -> Vec<Mat> {
        (0..n)
            .map(|_| Mat::from_fn(d, b, |_, _| rng.normal()))
            .collect()
    }

    fn uncoded_sum(parts: &[Mat], theta: &[f64]) -> Vec<f64> {
        let mut total = vec![0.0; parts[0].rows];
        for p in parts {
            vec_axpy(&mut total, 1.0, &p.gram_matvec(theta));
        }
        total
    }

    #[test]
    fn betas_are_distinct_across_all_slots() {
        let s = PcmmScheme::new(6, 3);
        let mut all: Vec<f64> = (0..6)
            .flat_map(|i| (0..3).map(move |j| (i, j)))
            .map(|(i, j)| s.beta(i, j))
            .collect();
        all.sort_by(f64::total_cmp);
        all.dedup_by(|a, b| (*a - *b).abs() < 1e-12);
        assert_eq!(all.len(), 18);
    }

    #[test]
    fn encoding_at_node_m_recovers_partition_m() {
        // if β happens to hit node m, the coded matrix is exactly X_m;
        // we verify the basis property instead (β are off-node): the
        // coefficients sum to 1 (partition of unity for Lagrange bases)
        let s = PcmmScheme::new(5, 2);
        for i in 0..5 {
            for j in 0..2 {
                let c = s.encode_coeffs(i, j);
                let total: f64 = c.iter().sum();
                assert!((total - 1.0).abs() < 1e-9, "worker {i} slot {j}");
            }
        }
    }

    #[test]
    fn decode_reconstructs_gram_sum() {
        let mut rng = Rng::seed_from_u64(21);
        for (n, r) in [(3usize, 2usize), (4, 2), (5, 3)] {
            let s = PcmmScheme::new(n, r);
            let (d, b) = (8, 4);
            let parts = random_parts(n, d, b, &mut rng);
            let theta: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
            // gather evaluations in arbitrary (worker, slot) order
            let mut resp = Vec::new();
            'outer: for j in 0..r {
                for i in 0..n {
                    resp.push(((i, j), s.worker_compute(i, j, &parts, &theta)));
                    if resp.len() == s.recovery_threshold() {
                        break 'outer;
                    }
                }
            }
            let got = s.decode(&resp);
            let want = uncoded_sum(&parts, &theta);
            for lane in 0..d {
                assert!(
                    (got[lane] - want[lane]).abs() < 1e-4 * (1.0 + want[lane].abs()),
                    "n={n} r={r} lane {lane}: {} vs {}",
                    got[lane],
                    want[lane]
                );
            }
        }
    }

    #[test]
    fn completion_is_2n_minus_1_slot_order_stat() {
        let s = PcmmScheme::new(2, 2);
        // arrivals: w0: 1+10=11, 3+1=4 ; w1: 4+1=5, 5+1=6  → sorted 4,5,6,11
        let sample = DelaySample::from_rows(
            vec![vec![1.0, 2.0], vec![4.0, 1.0]],
            vec![vec![10.0, 1.0], vec![1.0, 1.0]],
        );
        let mut scratch = Vec::new();
        // threshold = 3 → 6.0
        assert_eq!(s.completion_time(&sample, &mut scratch), 6.0);
    }

    #[test]
    fn pcmm_profits_from_partial_work_vs_pc() {
        // with heterogeneous workers, PCMM's multi-message harvest should
        // (on average) beat PC at the same load — the paper's Fig. 4/5
        // observation
        use crate::delay::{DelayModel, TruncatedGaussianModel};
        // at r = 2 the two schemes are nearly tied (threshold 2⌈n/r⌉−1
        // vs 2n−1 balance out); from r = 4 PCMM's partial-work harvest
        // wins clearly — exactly the paper's Fig. 4 shape
        let n = 8;
        let r = 4;
        let model = TruncatedGaussianModel::scenario2(n, 3);
        let pc = crate::coded::PcScheme::new(n, r);
        let pcmm = PcmmScheme::new(n, r);
        let mut rng = Rng::seed_from_u64(5);
        let (mut tot_pc, mut tot_pcmm) = (0.0, 0.0);
        let mut scratch = Vec::new();
        for _ in 0..4000 {
            let s = model.sample(n, r, &mut rng);
            tot_pc += pc.completion_time(&s, &mut scratch);
            tot_pcmm += pcmm.completion_time(&s, &mut scratch);
        }
        assert!(
            tot_pcmm < tot_pc,
            "PCMM {} should beat PC {}",
            tot_pcmm / 4000.0,
            tot_pc / 4000.0
        );
    }

    #[test]
    #[should_panic(expected = "r ≥ 2")]
    fn rejects_r1() {
        PcmmScheme::new(4, 1);
    }

    #[test]
    #[should_panic(expected = "needs")]
    fn decode_rejects_too_few() {
        let s = PcmmScheme::new(3, 2);
        s.decode(&[((0, 0), vec![1.0])]);
    }

    #[test]
    fn weight_decode_matches_newton_reference() {
        let mut rng = Rng::seed_from_u64(19);
        for (n, r) in [(3usize, 2usize), (4, 2), (5, 3)] {
            let s = PcmmScheme::new(n, r);
            let parts = random_parts(n, 6, 3, &mut rng);
            let theta: Vec<f64> = (0..6).map(|_| rng.normal()).collect();
            let mut resp = Vec::new();
            'outer: for j in 0..r {
                for i in 0..n {
                    resp.push(((i, j), s.worker_compute(i, j, &parts, &theta)));
                    if resp.len() == s.recovery_threshold() {
                        break 'outer;
                    }
                }
            }
            let (fast, reference) = (s.decode(&resp), s.decode_interpolated(&resp));
            for lane in 0..6 {
                assert!(
                    (fast[lane] - reference[lane]).abs() < 1e-7 * (1.0 + reference[lane].abs()),
                    "n={n} r={r} lane {lane}: {} vs {}",
                    fast[lane],
                    reference[lane]
                );
            }
        }
    }

    #[test]
    fn cached_decode_bit_identical_across_arrival_orders() {
        use crate::coded::DecodeCache;
        let mut rng = Rng::seed_from_u64(29);
        let s = PcmmScheme::new(3, 2); // threshold 5 of 6 slots
        let parts = random_parts(3, 5, 3, &mut rng);
        let theta: Vec<f64> = (0..5).map(|_| rng.normal()).collect();
        let slots: Vec<(usize, usize)> = (0..3).flat_map(|i| (0..2).map(move |j| (i, j))).collect();
        let computed: Vec<Vec<f64>> = slots
            .iter()
            .map(|&(i, j)| s.worker_compute(i, j, &parts, &theta))
            .collect();
        let mut cache = DecodeCache::with_default_cap();
        // same 5-slot subset (drop slot index 3) in two arrival orders
        let mut want: Option<Vec<f64>> = None;
        for order in [[0usize, 1, 2, 4, 5], [5, 2, 0, 4, 1]] {
            let resp: Vec<_> = order
                .iter()
                .map(|&si| (slots[si], computed[si].clone()))
                .collect();
            let fresh = s.decode(&resp);
            let cached = s.decode_cached(&resp, &mut cache);
            assert_eq!(fresh, cached, "cached ≠ fresh for order {order:?}");
            if let Some(w) = &want {
                assert_eq!(w, &fresh, "arrival order {order:?} changed the decode");
            }
            want = Some(fresh);
        }
        let stats = cache.stats();
        assert_eq!((stats.hits, stats.misses), (1, 1));
    }
}
