//! Vector-valued polynomial interpolation — the decode machinery shared
//! by the PC and PCMM baselines.
//!
//! Both schemes make each worker evaluate a (vector-valued) polynomial
//! `φ(x) ∈ R^d` at a known point; the master interpolates `φ` from
//! enough evaluations and then evaluates it at the reconstruction
//! points.  We use the **Newton form with divided differences**, applied
//! element-wise over the `d` vector lanes: `O(m²·d)` to build, `O(m·d)`
//! per evaluation, and numerically far better behaved than solving the
//! Vandermonde system.

/// Newton-form interpolant of a vector-valued polynomial from samples
/// `(x_i, y_i ∈ R^d)` at pairwise-distinct nodes.
#[derive(Debug, Clone)]
pub struct NewtonPoly {
    nodes: Vec<f64>,
    /// divided-difference coefficients, one `d`-vector per order
    coeffs: Vec<Vec<f64>>,
    dim: usize,
}

impl NewtonPoly {
    /// Build from `m` samples; interpolates the unique polynomial of
    /// degree ≤ m−1 through them.
    pub fn interpolate(xs: &[f64], ys: &[Vec<f64>]) -> Self {
        assert_eq!(xs.len(), ys.len(), "node/value count mismatch");
        assert!(!xs.is_empty(), "need at least one sample");
        let dim = ys[0].len();
        for y in ys {
            assert_eq!(y.len(), dim, "ragged sample vectors");
        }
        for (i, &a) in xs.iter().enumerate() {
            for &b in &xs[..i] {
                assert!(
                    (a - b).abs() > 1e-12 * (1.0 + a.abs().max(b.abs())),
                    "interpolation nodes must be distinct (got {a} ≈ {b})"
                );
            }
        }
        // divided differences, classic in-place backward sweep: after
        // pass `order`, table[i] = f[x_{i−order}, …, x_i], so at the end
        // table[j] is the Newton coefficient f[x_0, …, x_j].
        let m = xs.len();
        let mut table: Vec<Vec<f64>> = ys.to_vec();
        for order in 1..m {
            for i in (order..m).rev() {
                let denom = xs[i] - xs[i - order];
                for lane in 0..dim {
                    table[i][lane] = (table[i][lane] - table[i - 1][lane]) / denom;
                }
            }
        }
        Self {
            nodes: xs.to_vec(),
            coeffs: table,
            dim,
        }
    }

    pub fn degree_bound(&self) -> usize {
        self.coeffs.len() - 1
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Evaluate at `x` via the vector Horner/Newton scheme.
    pub fn eval(&self, x: f64) -> Vec<f64> {
        let m = self.coeffs.len();
        let mut acc = self.coeffs[m - 1].clone();
        for i in (0..m - 1).rev() {
            let w = x - self.nodes[i];
            for lane in 0..self.dim {
                acc[lane] = acc[lane] * w + self.coeffs[i][lane];
            }
        }
        acc
    }

    /// Evaluate at several points and sum the results — the master's
    /// reconstruction step `Σ_u φ(u)` in both PC and PCMM.
    pub fn eval_sum(&self, points: &[f64]) -> Vec<f64> {
        let mut total = vec![0.0; self.dim];
        for &x in points {
            let v = self.eval(x);
            for (t, vi) in total.iter_mut().zip(v) {
                *t += vi;
            }
        }
        total
    }
}

/// Precomputed linear decode weights for one responder subset.
///
/// The master's reconstruction `Σ_u φ(node_u)` is *linear* in the
/// received evaluations: with responder points `x_0 … x_{m−1}` and
/// reconstruction nodes `u_0 … u_{c−1}`,
///
/// ```text
/// Σ_u φ(u)  =  Σ_i w_i · y_i,      w_i = Σ_u ℓ_i(u)
/// ```
///
/// where `ℓ_i` is the Lagrange basis over the responder points.  The
/// weights depend only on *which* workers responded — not on the data —
/// so they are the natural unit to cache across rounds (stragglers
/// recur, subsets repeat).  Build is `O(c·m²)` independent of the
/// vector dimension `d`; [`Self::apply`] is `O(m·d)`, versus the
/// `O(m²·d)` divided-difference solve of [`NewtonPoly`] per round.
///
/// Numerics: the product-form basis evaluates `ℓ_i(u)` exactly as
/// [`lagrange_basis`] does (Kronecker delta when a node coincides with
/// a responder point), and the mirror-validated error is at or below
/// the Newton path's on every PC/PCMM shape the repo tests.
#[derive(Debug, Clone)]
pub struct DecodeWeights {
    weights: Vec<f64>,
}

impl DecodeWeights {
    /// Build the weights for responder points `xs` and reconstruction
    /// nodes `recon`.  `xs` must be pairwise distinct.
    pub fn build(xs: &[f64], recon: &[f64]) -> Self {
        assert!(!xs.is_empty(), "need at least one responder point");
        for (i, &a) in xs.iter().enumerate() {
            for &b in &xs[..i] {
                assert!(
                    (a - b).abs() > 1e-12 * (1.0 + a.abs().max(b.abs())),
                    "responder points must be distinct (got {a} ≈ {b})"
                );
            }
        }
        let weights = (0..xs.len())
            .map(|i| recon.iter().map(|&u| lagrange_basis(xs, i, u)).sum())
            .collect();
        Self { weights }
    }

    /// Number of responder evaluations the weights combine.
    pub fn len(&self) -> usize {
        self.weights.len()
    }

    pub fn is_empty(&self) -> bool {
        self.weights.is_empty()
    }

    /// The raw weight vector (bench/inspection).
    pub fn weights(&self) -> &[f64] {
        &self.weights
    }

    /// Combine the responder evaluations: `out = Σ_i w_i · ys_i`.
    /// `ys` must match the build order of the responder points.
    pub fn apply(&self, ys: &[&[f64]]) -> Vec<f64> {
        assert_eq!(ys.len(), self.weights.len(), "evaluation count mismatch");
        let dim = ys[0].len();
        let mut out = vec![0.0f64; dim];
        self.apply_into(ys, &mut out);
        out
    }

    /// [`Self::apply`] into a caller-provided, correctly-sized buffer
    /// (the master's hot path — no per-round allocation).
    pub fn apply_into(&self, ys: &[&[f64]], out: &mut [f64]) {
        assert_eq!(ys.len(), self.weights.len(), "evaluation count mismatch");
        out.fill(0.0);
        for (&w, y) in self.weights.iter().zip(ys) {
            assert_eq!(y.len(), out.len(), "ragged evaluation vectors");
            for (o, &v) in out.iter_mut().zip(y.iter()) {
                *o += w * v;
            }
        }
    }
}

/// Scalar Lagrange basis polynomial `ℓ_u(x)` over the given nodes:
/// `Π_{m ≠ u} (x − node_m) / (node_u − node_m)`.
pub fn lagrange_basis(nodes: &[f64], u: usize, x: f64) -> f64 {
    let mut acc = 1.0;
    for (m, &node) in nodes.iter().enumerate() {
        if m != u {
            acc *= (x - node) / (nodes[u] - node);
        }
    }
    acc
}

/// Chebyshev points of the second kind mapped to `[lo, hi]` — the
/// evaluation points PCMM workers use (`β_{i,j}`), chosen for
/// interpolation stability at the paper's degrees (2n − 2).
pub fn chebyshev_points(count: usize, lo: f64, hi: f64) -> Vec<f64> {
    assert!(count >= 1);
    if count == 1 {
        return vec![0.5 * (lo + hi)];
    }
    (0..count)
        .map(|j| {
            let t = (j as f64 * std::f64::consts::PI / (count - 1) as f64).cos();
            0.5 * (lo + hi) + 0.5 * (hi - lo) * t
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn interpolates_scalar_quadratic_exactly() {
        // y = 2x² − 3x + 1 through 3 points
        let f = |x: f64| vec![2.0 * x * x - 3.0 * x + 1.0];
        let xs = [0.0, 1.0, 3.0];
        let ys: Vec<Vec<f64>> = xs.iter().map(|&x| f(x)).collect();
        let p = NewtonPoly::interpolate(&xs, &ys);
        for x in [-2.0, 0.5, 2.0, 10.0] {
            assert!((p.eval(x)[0] - f(x)[0]).abs() < 1e-9, "x={x}");
        }
    }

    #[test]
    fn interpolates_vector_polys_lanewise() {
        // lanes: [x², x + 1]
        let f = |x: f64| vec![x * x, x + 1.0];
        let xs = [1.0, 2.0, 4.0];
        let ys: Vec<Vec<f64>> = xs.iter().map(|&x| f(x)).collect();
        let p = NewtonPoly::interpolate(&xs, &ys);
        let v = p.eval(3.0);
        assert!((v[0] - 9.0).abs() < 1e-9);
        assert!((v[1] - 4.0).abs() < 1e-9);
    }

    #[test]
    fn random_poly_roundtrip() {
        let mut rng = Rng::seed_from_u64(5);
        for degree in [0usize, 1, 3, 6, 10] {
            let dim = 4;
            // random coefficients
            let coef: Vec<Vec<f64>> = (0..=degree)
                .map(|_| (0..dim).map(|_| rng.range_f64(-2.0, 2.0)).collect())
                .collect();
            let eval = |x: f64| -> Vec<f64> {
                (0..dim)
                    .map(|l| {
                        coef.iter()
                            .rev()
                            .fold(0.0, |acc, c| acc * x + c[l])
                    })
                    .collect()
            };
            let xs = chebyshev_points(degree + 1, -1.0, 2.0);
            let ys: Vec<Vec<f64>> = xs.iter().map(|&x| eval(x)).collect();
            let p = NewtonPoly::interpolate(&xs, &ys);
            for _ in 0..10 {
                let x = rng.range_f64(-1.0, 2.0);
                let (got, want) = (p.eval(x), eval(x));
                for l in 0..dim {
                    assert!(
                        (got[l] - want[l]).abs() < 1e-7 * (1.0 + want[l].abs()),
                        "deg {degree} lane {l}: {} vs {}",
                        got[l],
                        want[l]
                    );
                }
            }
        }
    }

    #[test]
    fn eval_sum_matches_individual_sums() {
        let xs = [0.0, 1.0, 2.0];
        let ys = vec![vec![1.0], vec![2.0], vec![5.0]]; // x² + 1
        let p = NewtonPoly::interpolate(&xs, &ys);
        let total = p.eval_sum(&[1.0, 2.0, 3.0])[0];
        assert!((total - (2.0 + 5.0 + 10.0)).abs() < 1e-9);
    }

    #[test]
    fn lagrange_basis_partition_of_unity() {
        let nodes = [1.0, 2.0, 3.0, 4.0];
        for x in [0.3, 1.5, 3.9] {
            let total: f64 = (0..4).map(|u| lagrange_basis(&nodes, u, x)).sum();
            assert!((total - 1.0).abs() < 1e-10, "x={x}");
        }
        // kronecker at the nodes
        for (u, &xu) in nodes.iter().enumerate() {
            for v in 0..4 {
                let want = if u == v { 1.0 } else { 0.0 };
                assert!((lagrange_basis(&nodes, v, xu) - want).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn chebyshev_points_distinct_and_bounded() {
        let pts = chebyshev_points(9, 1.0, 15.0);
        assert_eq!(pts.len(), 9);
        for &p in &pts {
            assert!((1.0..=15.0).contains(&p));
        }
        let mut sorted = pts.clone();
        sorted.sort_by(f64::total_cmp);
        sorted.dedup_by(|a, b| (*a - *b).abs() < 1e-12);
        assert_eq!(sorted.len(), 9);
    }

    #[test]
    #[should_panic(expected = "distinct")]
    fn rejects_duplicate_nodes() {
        NewtonPoly::interpolate(&[1.0, 1.0], &[vec![0.0], vec![1.0]]);
    }

    #[test]
    fn decode_weights_match_newton_reconstruction() {
        // Σ_u φ(u) computed two ways: divided-difference interpolation
        // + eval_sum, versus the precomputed linear weights
        let mut rng = Rng::seed_from_u64(9);
        for (m, recon) in [(3usize, vec![1.0, 2.0]), (5, vec![1.0, 2.0, 3.0]), (7, vec![2.5])] {
            let dim = 6;
            let coef: Vec<Vec<f64>> = (0..m)
                .map(|_| (0..dim).map(|_| rng.range_f64(-2.0, 2.0)).collect())
                .collect();
            let eval = |x: f64| -> Vec<f64> {
                (0..dim)
                    .map(|l| coef.iter().rev().fold(0.0, |acc, c| acc * x + c[l]))
                    .collect()
            };
            let xs: Vec<f64> = (0..m).map(|i| 1.0 + i as f64).collect();
            let ys: Vec<Vec<f64>> = xs.iter().map(|&x| eval(x)).collect();
            let want = NewtonPoly::interpolate(&xs, &ys).eval_sum(&recon);
            let w = DecodeWeights::build(&xs, &recon);
            let refs: Vec<&[f64]> = ys.iter().map(|y| y.as_slice()).collect();
            let got = w.apply(&refs);
            for l in 0..dim {
                assert!(
                    (got[l] - want[l]).abs() < 1e-9 * (1.0 + want[l].abs()),
                    "m={m} lane {l}: weights {} vs newton {}",
                    got[l],
                    want[l]
                );
            }
        }
    }

    #[test]
    fn decode_weights_kronecker_when_node_is_a_responder_point() {
        // reconstruction node coincides with a responder point: the
        // product form collapses ℓ_i to the Kronecker delta, so the
        // weight contribution is exactly 1.0 on that responder
        let xs = [1.0, 2.0, 3.0];
        let w = DecodeWeights::build(&xs, &[2.0]);
        assert_eq!(w.weights(), &[0.0, 1.0, 0.0]);
    }

    #[test]
    fn apply_into_reuses_buffer_and_matches_apply() {
        let xs = [1.0, 2.0, 4.0];
        let w = DecodeWeights::build(&xs, &[1.5, 3.0]);
        let ys = [vec![1.0, -2.0], vec![0.5, 4.0], vec![-3.0, 0.25]];
        let refs: Vec<&[f64]> = ys.iter().map(|y| y.as_slice()).collect();
        let fresh = w.apply(&refs);
        let mut buf = vec![9.0; 2]; // stale garbage must be overwritten
        w.apply_into(&refs, &mut buf);
        assert_eq!(fresh, buf);
    }

    #[test]
    #[should_panic(expected = "distinct")]
    fn decode_weights_reject_duplicate_points() {
        DecodeWeights::build(&[2.0, 2.0], &[1.0]);
    }
}
