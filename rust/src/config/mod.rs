//! Config-driven experiments: describe a sweep in JSON, run it with
//! `straggler run --config exp.json`.
//!
//! Example:
//!
//! ```json
//! {
//!   "name": "my-sweep",
//!   "n": 12,
//!   "rs": [2, 4, 8, 12],
//!   "ks": [12],
//!   "trials": 10000,
//!   "seed": 7,
//!   "ingest_ms": 0.0,
//!   "schemes": ["CS", "SS", "RA", "PC", "PCMM", "LB", "GC(2)"],
//!   "model": {"kind": "ec2_like", "seed": 3, "hetero": 0.2}
//! }
//! ```
//!
//! Scheme names are parsed by [`SchemeRegistry::parse`] — the same
//! grammar the CLI's `--schemes` flag uses, including the grouped
//! multi-message `GC(s)` family.  A scheme inapplicable at *every*
//! sweep point is rejected up front; one applicable at only some
//! points (e.g. PC with mixed `ks`) renders `NaN` cells at the
//! excluded points.
//!
//! Model kinds: `scenario1`, `scenario2 {seed}`, `ec2_like {seed,
//! hetero}`, `shifted_exp {comp_shift, comp_rate, comm_shift,
//! comm_rate}`, `truncated_gaussian {comp: {...}, comm: {...}}` —
//! the same space as [`crate::delay::DelayModelKind`].
//!
//! An optional `"policy"` field (`static | order | order@pQQ | load |
//! load-rate | alloc-group | alloc-random`) switches the sweep onto
//! the sequential re-planning arm of [`crate::adaptive`]; non-static
//! policies require CS/SS/GC(s) bases.  An optional `"staleness"` key
//! (or the `@sS` policy suffix, e.g. `"order@s2"`) pipelines `S ∈
//! [1, 8]` rounds in flight — the bounded-staleness k-async arm; any
//! `S > 1` routes the sweep through the sequential arm even under the
//! static policy.

use anyhow::{anyhow, bail, Context, Result};

use crate::adaptive::{
    run_policy_rounds, PerRound, PolicyKind, PolicyRunConfig, PolicySpec, MAX_STALENESS,
};
use crate::delay::{DelayModelKind, TruncatedGaussian};
use crate::harness::{evaluate, EvalPoint};
use crate::report::Table;
use crate::scheme::{SchemeId, SchemeRegistry};
use crate::util::json::Json;

/// A declarative experiment sweep.
#[derive(Debug, Clone)]
pub struct Experiment {
    pub name: String,
    pub n: usize,
    pub rs: Vec<usize>,
    pub ks: Vec<usize>,
    pub trials: usize,
    pub seed: u64,
    pub ingest_ms: f64,
    pub schemes: Vec<SchemeId>,
    /// Round-boundary re-planning policy (`"policy"` field, default
    /// `static`; grammar `static | order | order@pQQ | load |
    /// load-rate | alloc-group | alloc-random`).  Non-static sweeps
    /// run the sequential re-planning arm of [`crate::adaptive`] per
    /// point instead of the coupled batch evaluator — every scheme
    /// still sees the identical delay stream.
    pub policy: PolicyKind,
    /// Bounded-staleness window (`"staleness"` key or the `@sS` policy
    /// suffix; default 1 = synchronous).  `S > 1` runs every point
    /// through the sequential arm with `S` rounds in flight.
    pub staleness: usize,
    pub model: DelayModelKind,
}

impl Experiment {
    pub fn from_json_str(text: &str) -> Result<Self> {
        let root = Json::parse(text).map_err(|e| anyhow!("config parse error: {e}"))?;
        Self::from_json(&root)
    }

    pub fn from_file(path: &std::path::Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {}", path.display()))?;
        Self::from_json_str(&text)
    }

    pub fn from_json(root: &Json) -> Result<Self> {
        let usize_field = |key: &str, default: Option<usize>| -> Result<usize> {
            match root.get(key) {
                Some(v) => v.as_usize().ok_or_else(|| anyhow!("`{key}` must be an integer")),
                None => default.ok_or_else(|| anyhow!("config missing `{key}`")),
            }
        };
        let n = usize_field("n", None)?;
        let list_field = |key: &str, default: Vec<usize>| -> Result<Vec<usize>> {
            match root.get(key) {
                None => Ok(default),
                Some(Json::Arr(items)) => items
                    .iter()
                    .map(|v| v.as_usize().ok_or_else(|| anyhow!("`{key}` entries must be ints")))
                    .collect(),
                Some(v) => v
                    .as_usize()
                    .map(|u| vec![u])
                    .ok_or_else(|| anyhow!("`{key}` must be int or int array")),
            }
        };
        let rs = list_field("rs", vec![n])?;
        let ks = list_field("ks", vec![n])?;
        for &r in &rs {
            if r < 1 || r > n {
                bail!("r = {r} out of range [1, {n}]");
            }
        }
        for &k in &ks {
            if k < 1 || k > n {
                bail!("k = {k} out of range [1, {n}]");
            }
        }
        let schemes = match root.get("schemes") {
            // the default set filters silently per point — figure-sweep
            // semantics (RA only shows up at r = n, etc.)
            None => SchemeRegistry::default_schemes(),
            Some(Json::Arr(items)) => {
                let ids = items
                    .iter()
                    .map(|v| SchemeRegistry::parse(v.as_str().unwrap_or("")))
                    .collect::<Result<Vec<_>>>()?;
                // an explicitly listed scheme inapplicable at *every*
                // sweep point is a config error, not a table of NaNs;
                // partial applicability (e.g. PC only at the k = n
                // points) renders NaN cells at the excluded points
                for &s in &ids {
                    let somewhere = rs
                        .iter()
                        .any(|&r| ks.iter().any(|&k| SchemeRegistry::applicable(s, n, r, k)));
                    if !somewhere {
                        bail!(
                            "scheme {s} is not applicable at any (r, k) point of this \
                             sweep — paper Table I (PC/PCMM need r ≥ 2 and k = n; RA \
                             needs r = n; GC(s) needs s ≤ r)"
                        );
                    }
                }
                ids
            }
            Some(_) => bail!("`schemes` must be an array of scheme names"),
        };
        let (policy, policy_staleness) = match root.get("policy") {
            None => (PolicyKind::Static, 1),
            Some(v) => {
                let name = v
                    .as_str()
                    .ok_or_else(|| anyhow!("`policy` must be a string"))?;
                let spec = PolicySpec::parse(name)?;
                let p = spec.kind;
                if p != PolicyKind::Static {
                    // the shared policy × scheme gate, with sweep
                    // semantics: a scheme the policy cannot re-plan at
                    // ANY (r) point is a config error up front; partial
                    // applicability renders NaN cells at run time
                    for &s in &schemes {
                        if rs.iter().all(|&r| p.validate_base(s, n, r).is_err()) {
                            let err = p.validate_base(s, n, rs[0]).expect_err("all err");
                            bail!(
                                "policy {p} cannot re-plan scheme {s} at any sweep \
                                 point: {err}"
                            );
                        }
                    }
                }
                (p, spec.staleness)
            }
        };
        let staleness = {
            // the `@sS` policy suffix and the standalone `"staleness"`
            // key are the same axis; the suffix wins when both appear
            let key = usize_field("staleness", Some(1))?;
            let s = if policy_staleness > 1 { policy_staleness } else { key };
            if !(1..=MAX_STALENESS).contains(&s) {
                bail!("`staleness` must be in [1, {MAX_STALENESS}] rounds in flight, got {s}");
            }
            s
        };
        Ok(Self {
            name: root
                .get("name")
                .and_then(Json::as_str)
                .unwrap_or("experiment")
                .to_string(),
            n,
            rs,
            ks,
            trials: usize_field("trials", Some(10_000))?,
            seed: root
                .get("seed")
                .map(|v| v.as_f64().unwrap_or(0.0) as u64)
                .unwrap_or(0xF16),
            ingest_ms: {
                let v = match root.get("ingest_ms") {
                    None => 0.0,
                    Some(j) => j
                        .as_f64()
                        .ok_or_else(|| anyhow!("`ingest_ms` must be a number"))?,
                };
                if v.is_nan() || v < 0.0 {
                    bail!("`ingest_ms` must be a non-negative ms/message cost, got {v}");
                }
                v
            },
            schemes,
            policy,
            staleness,
            model: parse_model(
                root.get("model")
                    .ok_or_else(|| anyhow!("config missing `model`"))?,
            )?,
        })
    }

    /// Run the sweep; one row per (r, k) point.
    pub fn run(&self) -> Table {
        let model = self.model.build(self.n);
        let mut headers = vec!["r".to_string(), "k".to_string()];
        headers.extend(self.schemes.iter().map(|s| s.to_string()));
        let mut table = Table::new(
            &format!(
                "{}: n = {}, {} trials, model = {}{}",
                self.name,
                self.n,
                self.trials,
                model.name(),
                match (self.policy == PolicyKind::Static, self.staleness) {
                    (true, 1) => String::new(),
                    (true, s) => format!(", S = {s}"),
                    (false, 1) => format!(", policy = {}", self.policy),
                    (false, s) => format!(", policy = {}, S = {s}", self.policy),
                }
            ),
            &headers.iter().map(String::as_str).collect::<Vec<_>>(),
        );
        for &r in &self.rs {
            for &k in &self.ks {
                let mut row = vec![r.to_string(), k.to_string()];
                if self.policy == PolicyKind::Static && self.staleness == 1 {
                    let point = EvalPoint::new(self.n, r, k, self.trials, self.seed)
                        .with_schemes(&self.schemes)
                        .with_ingest(self.ingest_ms);
                    let est = evaluate(&point, model.as_ref());
                    for s in &self.schemes {
                        let mean = est
                            .iter()
                            .find(|e| e.scheme == s.to_string())
                            .map(|e| e.mean)
                            .unwrap_or(f64::NAN);
                        row.push(Table::fmt(mean));
                    }
                } else {
                    // the sequential arm (re-planning and/or S > 1
                    // rounds in flight), one run per scheme; identical
                    // seeds couple the delay streams
                    for &s in &self.schemes {
                        let mean = run_policy_rounds(
                            &PolicyRunConfig {
                                scheme: s,
                                policy: self.policy,
                                n: self.n,
                                r,
                                k,
                                rounds: self.trials,
                                ingest_ms: self.ingest_ms,
                                seed: self.seed,
                                staleness: self.staleness,
                            },
                            &PerRound(model.as_ref()),
                            None,
                            None,
                        )
                        .map(|o| o.estimate.mean)
                        .unwrap_or(f64::NAN);
                        row.push(Table::fmt(mean));
                    }
                }
                table.push_row(row);
            }
        }
        table
    }
}

fn parse_model(v: &Json) -> Result<DelayModelKind> {
    let kind = v
        .get("kind")
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow!("model needs a `kind`"))?;
    let f = |key: &str, default: Option<f64>| -> Result<f64> {
        match v.get(key) {
            Some(x) => x.as_f64().ok_or_else(|| anyhow!("`{key}` must be a number")),
            None => default.ok_or_else(|| anyhow!("model missing `{key}`")),
        }
    };
    Ok(match kind {
        "scenario1" => DelayModelKind::TruncatedGaussianScenario1,
        "scenario2" => DelayModelKind::TruncatedGaussianScenario2 {
            seed: f("seed", Some(0.0))? as u64,
        },
        "ec2_like" => DelayModelKind::Ec2Like {
            seed: f("seed", Some(0.0))? as u64,
            hetero: f("hetero", Some(0.2))?,
        },
        "shifted_exp" => DelayModelKind::ShiftedExponential {
            comp_shift: f("comp_shift", None)?,
            comp_rate: f("comp_rate", None)?,
            comm_shift: f("comm_shift", None)?,
            comm_rate: f("comm_rate", None)?,
        },
        "truncated_gaussian" => {
            let tg = |key: &str| -> Result<TruncatedGaussian> {
                let o = v.get(key).ok_or_else(|| anyhow!("model missing `{key}`"))?;
                let g = |k2: &str| -> Result<f64> {
                    o.get(k2)
                        .and_then(Json::as_f64)
                        .ok_or_else(|| anyhow!("`{key}.{k2}` must be a number"))
                };
                Ok(TruncatedGaussian {
                    mu: g("mu")?,
                    sigma: g("sigma")?,
                    a: g("a")?,
                    b: o.get("b").and_then(Json::as_f64).unwrap_or(g("a")?),
                })
            };
            DelayModelKind::TruncatedGaussian {
                comp: tg("comp")?,
                comm: tg("comm")?,
            }
        }
        other => bail!("unknown model kind {other:?}"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    const GOOD: &str = r#"{
        "name": "t",
        "n": 6,
        "rs": [2, 6],
        "ks": [4, 6],
        "trials": 400,
        "seed": 3,
        "schemes": ["CS", "SS", "LB"],
        "model": {"kind": "scenario1"}
    }"#;

    #[test]
    fn parses_and_runs() {
        let exp = Experiment::from_json_str(GOOD).unwrap();
        assert_eq!(exp.n, 6);
        assert_eq!(exp.rs, vec![2, 6]);
        assert_eq!(exp.schemes.len(), 3);
        let table = exp.run();
        assert_eq!(table.rows.len(), 4); // 2 rs × 2 ks
        assert_eq!(table.headers, vec!["r", "k", "CS", "SS", "LB"]);
        // every cell parses as a positive number
        for row in &table.rows {
            for cell in &row[2..] {
                assert!(cell.parse::<f64>().unwrap() > 0.0);
            }
        }
    }

    #[test]
    fn scalar_r_and_defaults() {
        let exp = Experiment::from_json_str(
            r#"{"n": 4, "rs": 2, "model": {"kind": "ec2_like", "seed": 1}}"#,
        )
        .unwrap();
        assert_eq!(exp.rs, vec![2]);
        assert_eq!(exp.ks, vec![4]);
        assert_eq!(exp.trials, 10_000);
        assert_eq!(exp.schemes.len(), 6);
    }

    #[test]
    fn full_model_specification() {
        let exp = Experiment::from_json_str(
            r#"{"n": 4, "model": {"kind": "truncated_gaussian",
                 "comp": {"mu": 0.1, "sigma": 0.1, "a": 0.03},
                 "comm": {"mu": 0.5, "sigma": 0.2, "a": 0.2}}}"#,
        )
        .unwrap();
        match exp.model {
            DelayModelKind::TruncatedGaussian { comp, .. } => {
                assert!((comp.mu - 0.1).abs() < 1e-12);
                assert_eq!(comp.b, comp.a); // symmetric default
            }
            _ => panic!("wrong kind"),
        }
    }

    #[test]
    fn gc_schemes_parse_and_run_in_config() {
        let exp = Experiment::from_json_str(
            r#"{"n": 6, "trials": 300, "schemes": ["CS", "GC(2)", "gc3", "GCH(3,1)"],
                "ingest_ms": 0.1, "model": {"kind": "scenario1"}}"#,
        )
        .unwrap();
        assert_eq!(
            exp.schemes,
            vec![
                SchemeId::Cs,
                SchemeId::Gc(2),
                SchemeId::Gc(3),
                SchemeId::GcHet(3, 1)
            ]
        );
        let table = exp.run();
        assert_eq!(table.headers, vec!["r", "k", "CS", "GC(2)", "GC(3)", "GCH(3,1)"]);
        for cell in &table.rows[0][2..] {
            assert!(cell.parse::<f64>().unwrap() > 0.0);
        }
    }

    #[test]
    fn policy_field_runs_the_replanning_arm() {
        let exp = Experiment::from_json_str(
            r#"{"n": 6, "trials": 200, "schemes": ["CS", "GC(2)"],
                "policy": "order", "ingest_ms": 0.05,
                "model": {"kind": "scenario2", "seed": 4}}"#,
        )
        .unwrap();
        assert_eq!(exp.policy, PolicyKind::AdaptiveOrder);
        let table = exp.run();
        assert!(table.title.contains("policy = order"));
        for cell in &table.rows[0][2..] {
            assert!(cell.parse::<f64>().unwrap() > 0.0);
        }
        // default remains static
        let exp = Experiment::from_json_str(
            r#"{"n": 4, "model": {"kind": "scenario1"}}"#,
        )
        .unwrap();
        assert_eq!(exp.policy, PolicyKind::Static);
        assert_eq!(exp.staleness, 1, "default is the synchronous protocol");
    }

    #[test]
    fn staleness_key_and_policy_suffix_agree() {
        // standalone key: static policy still routes through the
        // sequential arm when S > 1
        let exp = Experiment::from_json_str(
            r#"{"n": 6, "rs": [2], "trials": 150, "schemes": ["CS"],
                "staleness": 2, "model": {"kind": "scenario1"}}"#,
        )
        .unwrap();
        assert_eq!(exp.staleness, 2);
        let table = exp.run();
        assert!(table.title.contains("S = 2"), "{}", table.title);
        assert!(table.rows[0][2].parse::<f64>().unwrap() > 0.0);
        // `@sS` suffix on the policy spells the same axis
        let exp = Experiment::from_json_str(
            r#"{"n": 6, "rs": [2], "schemes": ["CS"], "policy": "order@s3",
                "model": {"kind": "scenario1"}}"#,
        )
        .unwrap();
        assert_eq!(exp.policy, PolicyKind::AdaptiveOrder);
        assert_eq!(exp.staleness, 3, "suffix carries the window");
    }

    #[test]
    fn rejects_bad_configs() {
        for bad in [
            r#"{"rs": [2], "model": {"kind": "scenario1"}}"#, // no n
            r#"{"n": 4, "rs": [9], "model": {"kind": "scenario1"}}"#, // r > n
            r#"{"n": 4, "ks": [0], "model": {"kind": "scenario1"}}"#, // k < 1
            r#"{"n": 4}"#,                                    // no model
            r#"{"n": 4, "model": {"kind": "wat"}}"#,          // bad kind
            r#"{"n": 4, "schemes": ["XX"], "model": {"kind": "scenario1"}}"#,
            r#"{"n": 4, "schemes": ["GC(0)"], "model": {"kind": "scenario1"}}"#,
            r#"{"n": 4, "ingest_ms": -0.1, "model": {"kind": "scenario1"}}"#,
            // wrong-typed ingest_ms must error, not coerce to 0
            r#"{"n": 4, "ingest_ms": "0.2", "model": {"kind": "scenario1"}}"#,
            // GC(4) needs s ≤ r but the sweep only visits r = 2
            r#"{"n": 4, "rs": [2], "schemes": ["GC(4)"], "model": {"kind": "scenario1"}}"#,
            // RA needs r = n, never reached by this sweep
            r#"{"n": 4, "rs": [1, 2], "schemes": ["RA"], "model": {"kind": "scenario1"}}"#,
            // unknown policy spelling
            r#"{"n": 4, "policy": "wat", "model": {"kind": "scenario1"}}"#,
            // re-planning policies need an uncoded fixed base
            r#"{"n": 4, "schemes": ["PC"], "policy": "order", "model": {"kind": "scenario1"}}"#,
            r#"{"n": 4, "schemes": ["GCH(2,1)"], "policy": "load",
                "model": {"kind": "scenario1"}}"#,
            // staleness window is bounded: S ∈ [1, MAX_STALENESS]
            r#"{"n": 4, "staleness": 0, "model": {"kind": "scenario1"}}"#,
            r#"{"n": 4, "staleness": 99, "model": {"kind": "scenario1"}}"#,
        ] {
            assert!(Experiment::from_json_str(bad).is_err(), "{bad}");
        }
    }
}
