//! Duplicate-safe master-side aggregation of partial-sum result blocks
//! (protocol v3, uncoded `DistinctTasks` schemes).
//!
//! A v3 `Result` frame carries one aggregated block `Σ_t h(X_t)` over a
//! contiguous task range — the range is the block's *id*.  Because the
//! sum is irreversible, the master can only combine blocks whose ranges
//! are **disjoint**; this module is the state machine that decides, per
//! incoming range, whether it is fresh information, a duplicate, or an
//! unusable partial overlap — and guarantees a late straggler's
//! duplicate flush can never double-count a task into θ.
//!
//! Structure: task space `[0, n)` is partitioned into canonical blocks
//! of `s` tasks (`s` = the scheme's flush group; the last block is
//! ragged when `s ∤ n`).  Workers flush at canonical boundaries
//! (`Assign.align`), so **every received range lies inside exactly one
//! canonical block** — cross-worker merging reduces to interval
//! bookkeeping per block, never across blocks.  Within a block the
//! rules are:
//!
//! * disjoint from everything accepted → accept;
//! * fully covered by accepted ranges → duplicate, drop (the
//!   duplicate-safety guarantee);
//! * partial overlap → accept only if the incoming range is strictly
//!   longer than the accepted ranges it intersects (replacing them —
//!   coverage grows strictly, so acceptance is monotone); otherwise
//!   drop it as stranded.
//!
//! Liveness: with the cyclic assignment the registry pairs GC(s) with,
//! worker `i`'s row decomposes into a head suffix and a tail prefix of
//! the *same* canonical block plus full middle blocks (for `r = n`), so
//! any single worker that finishes its row completes every block — the
//! round can always terminate, exactly like CS.  For `r < n` every task
//! is still covered by `r` workers at `r` different alignments; the
//! stranded-overlap case only delays (never prevents) the `k`-distinct
//! rule in the paper's regimes.
//!
//! Layout: accepted sums live in one flat slot arena (`sums`, a single
//! `d`-strided `Vec<f64>`) with a free-list for recycled slots; the
//! per-block interval lists hold only `{start, len, slot}` metadata.
//! The aggregator is built once per run and [`RoundAggregator::reset`]
//! between rounds — no per-flush or per-round `Vec` churn: slot copies
//! are `copy_from_slice` into preallocated storage and the `finish`
//! outputs are reused buffers.  This mirrors the structure-of-arrays
//! audit `sim/batch.rs` did for delay sampling.
//!
//! Determinism: [`RoundAggregator::finish`] emits winners and the
//! gradient partial-sum in **canonical task order** (blocks ascending,
//! ranges ascending within a block), independent of arrival order —
//! the property `rust/tests/partial_sum.rs` pins (bit-identical θ
//! across `s` and arrival orders on exactly-representable values).
//! The arena layout keeps the accumulation arithmetic (one `vec_axpy`
//! per accepted range, canonical order) identical to the
//! per-range-`Vec` implementation it replaced, so θ is bit-identical.

use crate::linalg::vec_axpy;

/// Verdict on one offered result block.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Offer {
    /// Fresh coverage: `new_distinct` tasks newly counted (a
    /// strict-growth replacement reports the net gain).
    Accepted { new_distinct: usize },
    /// Every task of the range was already covered — dropped whole.
    Duplicate,
    /// Partial overlap with no strict coverage growth — dropped whole
    /// (its sum cannot be split).
    Stranded,
    /// Not a contiguous in-bounds range inside one canonical block.
    Malformed,
}

/// An accepted range: `[start, start + len)`; its `d`-length sum lives
/// in arena slot `slot` of the owning aggregator.
struct RangeMeta {
    start: usize,
    len: usize,
    slot: usize,
}

/// Aggregation state for the uncoded `DistinctTasks` rule: one list of
/// accepted, pairwise-disjoint ranges per canonical block, sums in a
/// flat slot arena.  Built once per run, [`Self::reset`] per round.
pub struct RoundAggregator {
    n: usize,
    d: usize,
    s: usize,
    k: usize,
    /// interval metadata per canonical block, reused across rounds
    blocks: Vec<Vec<RangeMeta>>,
    /// blocks holding ≥ 1 accepted range this round (sparse reset/scan)
    touched: Vec<usize>,
    /// flat `d`-strided sum arena; slot `i` is `sums[i·d .. (i+1)·d]`
    sums: Vec<f64>,
    /// recycled arena slots
    free: Vec<usize>,
    distinct: usize,
    /// reused `finish` outputs
    winners: Vec<usize>,
    total: Vec<f64>,
}

impl RoundAggregator {
    /// `n` tasks, `d`-dimensional blocks, flush group `s`, target `k`.
    pub fn new(n: usize, d: usize, s: usize, k: usize) -> Self {
        assert!(n >= 1 && d >= 1, "degenerate round shape");
        assert!(s >= 1, "flush group must be ≥ 1");
        assert!(k >= 1 && k <= n, "need 1 ≤ k ≤ n");
        Self {
            n,
            d,
            s,
            k,
            blocks: (0..n.div_ceil(s)).map(|_| Vec::new()).collect(),
            touched: Vec::new(),
            sums: Vec::new(),
            free: Vec::new(),
            distinct: 0,
            winners: Vec::new(),
            total: vec![0.0; d],
        }
    }

    /// Clear round state for reuse, keeping every allocation (interval
    /// lists, arena, free-list, output buffers) warm for the next round.
    pub fn reset(&mut self) {
        while let Some(b) = self.touched.pop() {
            for r in self.blocks[b].drain(..) {
                self.free.push(r.slot);
            }
        }
        self.distinct = 0;
    }

    /// Copy `sum` into a (recycled or fresh) arena slot.
    fn alloc_slot(&mut self, sum: &[f64]) -> usize {
        let slot = self.free.pop().unwrap_or_else(|| {
            let next = self.sums.len() / self.d;
            self.sums.resize(self.sums.len() + self.d, 0.0);
            next
        });
        self.sums[slot * self.d..(slot + 1) * self.d].copy_from_slice(sum);
        slot
    }

    /// Offer one received block: a contiguous ascending task range and
    /// its aggregated `d`-length sum.
    pub fn offer(&mut self, tasks: &[usize], sum: &[f64]) -> Offer {
        if tasks.is_empty() || sum.len() != self.d {
            return Offer::Malformed;
        }
        let (start, len) = (tasks[0], tasks.len());
        if start + len > self.n || tasks.windows(2).any(|w| w[1] != w[0] + 1) {
            return Offer::Malformed;
        }
        if (start / self.s) != ((start + len - 1) / self.s) {
            return Offer::Malformed; // straddles a canonical boundary
        }
        let block = start / self.s;
        let end = start + len;
        // `inter` measures the covered part of the incoming range (for
        // duplicate detection); `dropped_len` is the *full* length of
        // every accepted range it touches — those are what a
        // replacement would evict whole, so strict coverage growth
        // requires `len > dropped_len`, not merely `len > inter`
        let (mut inter, mut dropped_len) = (0usize, 0usize);
        for r in self.blocks[block].iter() {
            let ov = end.min(r.start + r.len).saturating_sub(start.max(r.start));
            if ov > 0 {
                inter += ov;
                dropped_len += r.len;
            }
        }
        if inter == len {
            return Offer::Duplicate;
        }
        if inter == 0 {
            if self.blocks[block].is_empty() {
                self.touched.push(block);
            }
            let slot = self.alloc_slot(sum);
            self.blocks[block].push(RangeMeta { start, len, slot });
            self.distinct += len;
            return Offer::Accepted { new_distinct: len };
        }
        // partial overlap: replace the intersecting ranges only if the
        // swap strictly grows coverage (monotone acceptance); evicted
        // slots return to the free-list before the incoming claims one
        if len > dropped_len {
            {
                let Self { blocks, free, .. } = self;
                blocks[block].retain(|r| {
                    let keep = r.start + r.len <= start || r.start >= end;
                    if !keep {
                        free.push(r.slot);
                    }
                    keep
                });
            }
            let slot = self.alloc_slot(sum);
            self.blocks[block].push(RangeMeta { start, len, slot });
            let gained = len - dropped_len;
            self.distinct += gained;
            Offer::Accepted {
                new_distinct: gained,
            }
        } else {
            Offer::Stranded
        }
    }

    /// Distinct tasks covered so far.
    pub fn distinct(&self) -> usize {
        self.distinct
    }

    /// Has the `k`-distinct completion rule fired?
    pub fn complete(&self) -> bool {
        self.distinct >= self.k
    }

    /// Emit the winners (canonical task order) and the gradient
    /// partial-sum `Σ_{t ∈ winners} h(X_t)`, accumulated in canonical
    /// order so the result is independent of arrival order.  The
    /// returned slices borrow reused internal buffers — copy out what
    /// must outlive the next `reset`/`finish`.
    pub fn finish(&mut self) -> (&[usize], &[f64]) {
        self.winners.clear();
        self.total.clear();
        self.total.resize(self.d, 0.0);
        self.touched.sort_unstable();
        let Self {
            blocks,
            touched,
            sums,
            winners,
            total,
            d,
            ..
        } = self;
        for &b in touched.iter() {
            let ranges = &mut blocks[b];
            ranges.sort_unstable_by_key(|r| r.start);
            for r in ranges.iter() {
                winners.extend(r.start..r.start + r.len);
                vec_axpy(total, 1.0, &sums[r.slot * *d..(r.slot + 1) * *d]);
            }
        }
        (winners, total)
    }
}

/// Verdict on a frame offered to the [`AggregatorRing`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RingOffer {
    /// The frame belongs to an in-flight round; the inner verdict is
    /// the round's own duplicate-safe range bookkeeping.
    InFlight(Offer),
    /// The frame's round already applied to θ (it lags the ring's base
    /// — e.g. a straggler's flush from round `t` landing after round
    /// `t + S` applied).  Dropped whole: θ is immutable history.
    Stale,
    /// The frame claims a round the master has not issued yet — only a
    /// corrupt or hostile worker can produce it.  Dropped whole.
    Future,
}

/// `S` independent [`RoundAggregator`]s behind one round-indexed
/// window — the master-side state of the bounded-staleness pipeline.
///
/// Round `t` occupies slot `t % S` while `t ∈ [base, base + S)`;
/// `base` is the oldest unapplied round.  Application is strictly
/// **in order**: only the oldest round can be finished and popped,
/// which is what keeps θ a linear history (version tags count applied
/// rounds) even though frames land out of order.  Popping recycles the
/// slot (a [`RoundAggregator::reset`], zero steady-state allocation —
/// the PR-6 arena survives intact S-fold) and is the exact instant the
/// master may issue round `base + S`.
///
/// Synchronous operation is the `S = 1` degenerate case: one slot,
/// `offer` → `complete` → `finish_oldest` → `advance`, identical to
/// driving a bare [`RoundAggregator`].
pub struct AggregatorRing {
    slots: Vec<RoundAggregator>,
    staleness: usize,
    base: usize,
}

impl AggregatorRing {
    /// Ring of `staleness` aggregators, each shaped `(n, d, s, k)` like
    /// [`RoundAggregator::new`].
    pub fn new(n: usize, d: usize, s: usize, k: usize, staleness: usize) -> Self {
        assert!(staleness >= 1, "need at least one round in flight");
        Self {
            slots: (0..staleness).map(|_| RoundAggregator::new(n, d, s, k)).collect(),
            staleness,
            base: 0,
        }
    }

    /// Oldest unapplied round — also the θ-version tag (number of
    /// applied rounds) of any `Assign` issued right now.
    pub fn base_round(&self) -> usize {
        self.base
    }

    pub fn staleness(&self) -> usize {
        self.staleness
    }

    /// Is `round` currently in flight (`base ≤ round < base + S`)?
    pub fn in_flight(&self, round: usize) -> bool {
        (self.base..self.base + self.staleness).contains(&round)
    }

    /// Route one received block to its round.  Frames outside the
    /// window are dropped whole — a late duplicate from an applied
    /// round can never reach an aggregator, so it can never corrupt θ.
    pub fn offer(&mut self, round: usize, tasks: &[usize], sum: &[f64]) -> RingOffer {
        if round < self.base {
            return RingOffer::Stale;
        }
        if round >= self.base + self.staleness {
            return RingOffer::Future;
        }
        RingOffer::InFlight(self.slots[round % self.staleness].offer(tasks, sum))
    }

    /// Distinct tasks covered by an in-flight round (`None` outside the
    /// window).
    pub fn distinct(&self, round: usize) -> Option<usize> {
        self.in_flight(round)
            .then(|| self.slots[round % self.staleness].distinct())
    }

    /// Has the *oldest* round's `k`-distinct rule fired?  Only the
    /// oldest is ever eligible — in-order application.
    pub fn oldest_complete(&self) -> bool {
        self.slots[self.base % self.staleness].complete()
    }

    /// Winners + partial-sum of the oldest round (canonical order, same
    /// reused buffers as [`RoundAggregator::finish`]).  Call
    /// [`Self::advance`] after applying to θ.
    pub fn finish_oldest(&mut self) -> (&[usize], &[f64]) {
        let ix = self.base % self.staleness;
        self.slots[ix].finish()
    }

    /// Retire the oldest round: recycle its slot for round
    /// `base + S` and move the window forward.  The caller may issue
    /// the next round's `Assign` the moment this returns.
    pub fn advance(&mut self) {
        let ix = self.base % self.staleness;
        self.slots[ix].reset();
        self.base += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sum_of(tasks: &[usize], d: usize) -> Vec<f64> {
        // task t contributes the vector [t+1, t+1, …] — integer-exact
        (0..d)
            .map(|_| tasks.iter().map(|&t| (t + 1) as f64).sum())
            .collect()
    }

    fn offer_range(agg: &mut RoundAggregator, lo: usize, hi: usize, d: usize) -> Offer {
        let tasks: Vec<usize> = (lo..hi).collect();
        agg.offer(&tasks, &sum_of(&tasks, d))
    }

    #[test]
    fn singleton_groups_reproduce_k_distinct_dedup() {
        let mut agg = RoundAggregator::new(4, 2, 1, 3);
        assert_eq!(offer_range(&mut agg, 1, 2, 2), Offer::Accepted { new_distinct: 1 });
        assert_eq!(offer_range(&mut agg, 1, 2, 2), Offer::Duplicate);
        assert_eq!(offer_range(&mut agg, 3, 4, 2), Offer::Accepted { new_distinct: 1 });
        assert!(!agg.complete());
        assert_eq!(offer_range(&mut agg, 0, 1, 2), Offer::Accepted { new_distinct: 1 });
        assert!(agg.complete());
        let (winners, total) = agg.finish();
        assert_eq!(winners, vec![0, 1, 3]);
        assert_eq!(total, vec![6.0, 6.0]); // 1 + 2 + 4
    }

    #[test]
    fn complementary_suffix_and_prefix_tile_a_block() {
        // block [0, 3): suffix {1, 2} then prefix {0}
        let mut agg = RoundAggregator::new(6, 1, 3, 6);
        assert_eq!(offer_range(&mut agg, 1, 3, 1), Offer::Accepted { new_distinct: 2 });
        assert_eq!(offer_range(&mut agg, 0, 1, 1), Offer::Accepted { new_distinct: 1 });
        // full block now duplicates the tiled pair
        assert_eq!(offer_range(&mut agg, 0, 3, 1), Offer::Duplicate);
        assert_eq!(agg.distinct(), 3);
    }

    #[test]
    fn partial_overlap_is_stranded_unless_strictly_longer() {
        let mut agg = RoundAggregator::new(4, 1, 4, 4);
        assert_eq!(offer_range(&mut agg, 1, 3, 1), Offer::Accepted { new_distinct: 2 });
        // {2, 3} overlaps {1, 2} and is not longer → stranded whole
        assert_eq!(offer_range(&mut agg, 2, 4, 1), Offer::Stranded);
        assert_eq!(agg.distinct(), 2);
        // the full block is strictly longer → replaces the pair
        assert_eq!(offer_range(&mut agg, 0, 4, 1), Offer::Accepted { new_distinct: 2 });
        assert_eq!(agg.distinct(), 4);
        let (winners, total) = agg.finish();
        assert_eq!(winners, vec![0, 1, 2, 3]);
        assert_eq!(total, vec![10.0]); // the replacement's own sum, once
    }

    #[test]
    fn replacement_never_double_counts() {
        // accept {0}, then the longer {0, 1, 2} replaces it: coverage
        // goes 1 → 3 and the finish sum holds each task exactly once
        let mut agg = RoundAggregator::new(3, 1, 3, 3);
        assert_eq!(offer_range(&mut agg, 0, 1, 1), Offer::Accepted { new_distinct: 1 });
        assert_eq!(offer_range(&mut agg, 0, 3, 1), Offer::Accepted { new_distinct: 2 });
        let (winners, total) = agg.finish();
        assert_eq!(winners, vec![0, 1, 2]);
        assert_eq!(total, vec![6.0]);
    }

    #[test]
    fn replacement_that_would_shrink_coverage_is_stranded() {
        // accepted {0,1} and {3,4} (coverage 4); incoming {1,2,3} is
        // longer than its *intersection* (2) but would evict 4 covered
        // tasks for 3 — it must be stranded, not swapped in
        let mut agg = RoundAggregator::new(5, 1, 5, 5);
        assert_eq!(offer_range(&mut agg, 0, 2, 1), Offer::Accepted { new_distinct: 2 });
        assert_eq!(offer_range(&mut agg, 3, 5, 1), Offer::Accepted { new_distinct: 2 });
        assert_eq!(offer_range(&mut agg, 1, 4, 1), Offer::Stranded);
        assert_eq!(agg.distinct(), 4);
        // the exact gap filler is still welcome
        assert_eq!(offer_range(&mut agg, 2, 3, 1), Offer::Accepted { new_distinct: 1 });
        let (winners, total) = agg.finish();
        assert_eq!(winners, vec![0, 1, 2, 3, 4]);
        assert_eq!(total, vec![15.0]);
    }

    #[test]
    fn rejects_malformed_ranges() {
        let mut agg = RoundAggregator::new(8, 2, 2, 8);
        assert_eq!(agg.offer(&[], &[0.0, 0.0]), Offer::Malformed);
        assert_eq!(agg.offer(&[1], &[0.0]), Offer::Malformed); // wrong d
        assert_eq!(agg.offer(&[3, 5], &[0.0, 0.0]), Offer::Malformed); // gap
        assert_eq!(agg.offer(&[7, 8], &[0.0, 0.0]), Offer::Malformed); // oob
        assert_eq!(agg.offer(&[1, 2], &[0.0, 0.0]), Offer::Malformed); // straddle
        assert_eq!(agg.offer(&[2, 3], &[0.0, 0.0]), Offer::Accepted { new_distinct: 2 });
    }

    #[test]
    fn ragged_last_block_accepts_short_range() {
        // n = 5, s = 2 → blocks [0,2) [2,4) [4,5)
        let mut agg = RoundAggregator::new(5, 1, 2, 5);
        assert_eq!(offer_range(&mut agg, 4, 5, 1), Offer::Accepted { new_distinct: 1 });
        assert_eq!(offer_range(&mut agg, 0, 2, 1), Offer::Accepted { new_distinct: 2 });
        assert_eq!(offer_range(&mut agg, 2, 4, 1), Offer::Accepted { new_distinct: 2 });
        assert!(agg.complete());
        let (winners, total) = agg.finish();
        assert_eq!(winners, vec![0, 1, 2, 3, 4]);
        assert_eq!(total, vec![15.0]);
    }

    #[test]
    fn reset_reuses_state_identically_to_a_fresh_aggregator() {
        // round 1 exercises accept / duplicate / replace, then reset;
        // round 2 on the reused aggregator must match a fresh one
        // bit-for-bit (recycled arena slots, warm buffers and all)
        let mut reused = RoundAggregator::new(6, 3, 3, 6);
        assert_eq!(offer_range(&mut reused, 0, 2, 3), Offer::Accepted { new_distinct: 2 });
        assert_eq!(offer_range(&mut reused, 3, 6, 3), Offer::Accepted { new_distinct: 3 });
        assert_eq!(offer_range(&mut reused, 0, 3, 3), Offer::Accepted { new_distinct: 1 });
        let _ = reused.finish();
        reused.reset();
        assert_eq!(reused.distinct(), 0);
        assert!(!reused.complete());

        let mut fresh = RoundAggregator::new(6, 3, 3, 6);
        let offers = [(4usize, 6usize), (4, 6), (1, 3), (0, 3), (3, 6)];
        for &(lo, hi) in &offers {
            assert_eq!(
                offer_range(&mut reused, lo, hi, 3),
                offer_range(&mut fresh, lo, hi, 3),
                "offer [{lo}, {hi}) verdicts diverged after reset"
            );
        }
        let (w1, t1) = reused.finish();
        let (w2, t2) = fresh.finish();
        assert_eq!(w1, w2);
        assert_eq!(t1, t2);
    }

    #[test]
    fn finish_is_idempotent_on_reused_buffers() {
        let mut agg = RoundAggregator::new(4, 2, 2, 4);
        offer_range(&mut agg, 2, 4, 2);
        offer_range(&mut agg, 0, 2, 2);
        let (w1, t1) = {
            let (w, t) = agg.finish();
            (w.to_vec(), t.to_vec())
        };
        let (w2, t2) = agg.finish();
        assert_eq!(w1, w2);
        assert_eq!(t1, t2);
        assert_eq!(w1, vec![0, 1, 2, 3]);
    }

    fn ring_offer(ring: &mut AggregatorRing, round: usize, lo: usize, hi: usize, d: usize) -> RingOffer {
        let tasks: Vec<usize> = (lo..hi).collect();
        ring.offer(round, &tasks, &sum_of(&tasks, d))
    }

    #[test]
    fn ring_routes_frames_by_round_within_the_window() {
        let mut ring = AggregatorRing::new(3, 1, 1, 3, 2);
        assert_eq!(ring.base_round(), 0);
        assert!(ring.in_flight(0) && ring.in_flight(1) && !ring.in_flight(2));
        // interleaved frames for both in-flight rounds
        assert_eq!(
            ring_offer(&mut ring, 0, 0, 1, 1),
            RingOffer::InFlight(Offer::Accepted { new_distinct: 1 })
        );
        assert_eq!(
            ring_offer(&mut ring, 1, 2, 3, 1),
            RingOffer::InFlight(Offer::Accepted { new_distinct: 1 })
        );
        assert_eq!(ring_offer(&mut ring, 2, 0, 1, 1), RingOffer::Future);
        assert_eq!(ring.distinct(0), Some(1));
        assert_eq!(ring.distinct(1), Some(1));
        assert_eq!(ring.distinct(2), None);
        // fill + apply round 0; round 2 opens the moment it retires
        ring_offer(&mut ring, 0, 1, 2, 1);
        ring_offer(&mut ring, 0, 2, 3, 1);
        assert!(ring.oldest_complete());
        let (winners, total) = ring.finish_oldest();
        assert_eq!(winners, vec![0, 1, 2]);
        assert_eq!(total, vec![6.0]);
        ring.advance();
        assert_eq!(ring.base_round(), 1);
        assert!(ring.in_flight(2));
        assert_eq!(
            ring_offer(&mut ring, 2, 0, 1, 1),
            RingOffer::InFlight(Offer::Accepted { new_distinct: 1 })
        );
        // round 1's earlier frame survived round 0's retirement
        assert_eq!(ring.distinct(1), Some(1));
    }

    #[test]
    fn late_frames_from_applied_rounds_never_corrupt_theta() {
        // the issue-12 edge case: a duplicate/censored frame from round
        // t arrives after round t + S applied — it must be dropped
        // whole, and every later round's θ contribution must be
        // bit-identical to a run where the late frame never arrived
        let mut ring = AggregatorRing::new(2, 1, 1, 2, 2);
        for round in 0..2usize {
            ring_offer(&mut ring, round, 0, 1, 1);
            ring_offer(&mut ring, round, 1, 2, 1);
            assert!(ring.oldest_complete());
            let _ = ring.finish_oldest();
            ring.advance();
        }
        assert_eq!(ring.base_round(), 2);
        // round 0 retired two advances ago (= t + S applied)
        assert_eq!(ring_offer(&mut ring, 0, 0, 1, 1), RingOffer::Stale);
        assert_eq!(ring_offer(&mut ring, 1, 0, 2, 1), RingOffer::Stale);
        // the in-flight rounds saw nothing: distinct counts untouched
        assert_eq!(ring.distinct(2), Some(0));
        assert_eq!(ring.distinct(3), Some(0));
        ring_offer(&mut ring, 2, 0, 2, 1);
        assert!(ring.oldest_complete());
        let (winners, total) = ring.finish_oldest();
        assert_eq!(winners, vec![0, 1]);
        assert_eq!(total, vec![3.0], "late stale frames leaked into θ");
    }

    #[test]
    fn ring_version_gap_never_exceeds_staleness_minus_one() {
        // hand-rolled proptest: drive rings of every S ∈ [1, 4] with a
        // deterministic pseudo-random frame schedule; at every instant
        // any issuable round `t ∈ [base, base + S)` is tagged with
        // version = base, so the staleness gap t − base ≤ S − 1 must
        // hold, and the window never outruns in-order application
        for staleness in 1..=4usize {
            let (n, d, k) = (3usize, 1usize, 3usize);
            let mut ring = AggregatorRing::new(n, d, 1, k, staleness);
            let mut state = 0x9E3779B97F4A7C15u64 ^ staleness as u64;
            let mut next = move || {
                state ^= state << 13;
                state ^= state >> 7;
                state ^= state << 17;
                state
            };
            let mut applied = 0usize;
            while applied < 30 {
                let round = ring.base_round() + (next() as usize % staleness);
                // the version a master would stamp on this frame's round
                let version = ring.base_round();
                assert!(round - version <= staleness - 1, "gap bound violated");
                let lo = next() as usize % n;
                let tasks = [lo];
                let _ = ring.offer(round, &tasks, &sum_of(&tasks, d));
                // frames beyond the window are always refused
                assert_eq!(
                    ring.offer(ring.base_round() + staleness, &tasks, &sum_of(&tasks, d)),
                    RingOffer::Future
                );
                while ring.oldest_complete() {
                    let _ = ring.finish_oldest();
                    ring.advance();
                    applied += 1;
                }
            }
            assert!(ring.base_round() >= 30 / staleness.max(1));
        }
    }
}
