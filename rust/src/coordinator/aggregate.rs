//! Duplicate-safe master-side aggregation of partial-sum result blocks
//! (protocol v3, uncoded `DistinctTasks` schemes).
//!
//! A v3 `Result` frame carries one aggregated block `Σ_t h(X_t)` over a
//! contiguous task range — the range is the block's *id*.  Because the
//! sum is irreversible, the master can only combine blocks whose ranges
//! are **disjoint**; this module is the state machine that decides, per
//! incoming range, whether it is fresh information, a duplicate, or an
//! unusable partial overlap — and guarantees a late straggler's
//! duplicate flush can never double-count a task into θ.
//!
//! Structure: task space `[0, n)` is partitioned into canonical blocks
//! of `s` tasks (`s` = the scheme's flush group; the last block is
//! ragged when `s ∤ n`).  Workers flush at canonical boundaries
//! (`Assign.align`), so **every received range lies inside exactly one
//! canonical block** — cross-worker merging reduces to interval
//! bookkeeping per block, never across blocks.  Within a block the
//! rules are:
//!
//! * disjoint from everything accepted → accept;
//! * fully covered by accepted ranges → duplicate, drop (the
//!   duplicate-safety guarantee);
//! * partial overlap → accept only if the incoming range is strictly
//!   longer than the accepted ranges it intersects (replacing them —
//!   coverage grows strictly, so acceptance is monotone); otherwise
//!   drop it as stranded.
//!
//! Liveness: with the cyclic assignment the registry pairs GC(s) with,
//! worker `i`'s row decomposes into a head suffix and a tail prefix of
//! the *same* canonical block plus full middle blocks (for `r = n`), so
//! any single worker that finishes its row completes every block — the
//! round can always terminate, exactly like CS.  For `r < n` every task
//! is still covered by `r` workers at `r` different alignments; the
//! stranded-overlap case only delays (never prevents) the `k`-distinct
//! rule in the paper's regimes.
//!
//! Layout: accepted sums live in one flat slot arena (`sums`, a single
//! `d`-strided `Vec<f64>`) with a free-list for recycled slots; the
//! per-block interval lists hold only `{start, len, slot}` metadata.
//! The aggregator is built once per run and [`RoundAggregator::reset`]
//! between rounds — no per-flush or per-round `Vec` churn: slot copies
//! are `copy_from_slice` into preallocated storage and the `finish`
//! outputs are reused buffers.  This mirrors the structure-of-arrays
//! audit `sim/batch.rs` did for delay sampling.
//!
//! Determinism: [`RoundAggregator::finish`] emits winners and the
//! gradient partial-sum in **canonical task order** (blocks ascending,
//! ranges ascending within a block), independent of arrival order —
//! the property `rust/tests/partial_sum.rs` pins (bit-identical θ
//! across `s` and arrival orders on exactly-representable values).
//! The arena layout keeps the accumulation arithmetic (one `vec_axpy`
//! per accepted range, canonical order) identical to the
//! per-range-`Vec` implementation it replaced, so θ is bit-identical.

use crate::linalg::vec_axpy;

/// Verdict on one offered result block.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Offer {
    /// Fresh coverage: `new_distinct` tasks newly counted (a
    /// strict-growth replacement reports the net gain).
    Accepted { new_distinct: usize },
    /// Every task of the range was already covered — dropped whole.
    Duplicate,
    /// Partial overlap with no strict coverage growth — dropped whole
    /// (its sum cannot be split).
    Stranded,
    /// Not a contiguous in-bounds range inside one canonical block.
    Malformed,
}

/// An accepted range: `[start, start + len)`; its `d`-length sum lives
/// in arena slot `slot` of the owning aggregator.
struct RangeMeta {
    start: usize,
    len: usize,
    slot: usize,
}

/// Aggregation state for the uncoded `DistinctTasks` rule: one list of
/// accepted, pairwise-disjoint ranges per canonical block, sums in a
/// flat slot arena.  Built once per run, [`Self::reset`] per round.
pub struct RoundAggregator {
    n: usize,
    d: usize,
    s: usize,
    k: usize,
    /// interval metadata per canonical block, reused across rounds
    blocks: Vec<Vec<RangeMeta>>,
    /// blocks holding ≥ 1 accepted range this round (sparse reset/scan)
    touched: Vec<usize>,
    /// flat `d`-strided sum arena; slot `i` is `sums[i·d .. (i+1)·d]`
    sums: Vec<f64>,
    /// recycled arena slots
    free: Vec<usize>,
    distinct: usize,
    /// reused `finish` outputs
    winners: Vec<usize>,
    total: Vec<f64>,
}

impl RoundAggregator {
    /// `n` tasks, `d`-dimensional blocks, flush group `s`, target `k`.
    pub fn new(n: usize, d: usize, s: usize, k: usize) -> Self {
        assert!(n >= 1 && d >= 1, "degenerate round shape");
        assert!(s >= 1, "flush group must be ≥ 1");
        assert!(k >= 1 && k <= n, "need 1 ≤ k ≤ n");
        Self {
            n,
            d,
            s,
            k,
            blocks: (0..n.div_ceil(s)).map(|_| Vec::new()).collect(),
            touched: Vec::new(),
            sums: Vec::new(),
            free: Vec::new(),
            distinct: 0,
            winners: Vec::new(),
            total: vec![0.0; d],
        }
    }

    /// Clear round state for reuse, keeping every allocation (interval
    /// lists, arena, free-list, output buffers) warm for the next round.
    pub fn reset(&mut self) {
        while let Some(b) = self.touched.pop() {
            for r in self.blocks[b].drain(..) {
                self.free.push(r.slot);
            }
        }
        self.distinct = 0;
    }

    /// Copy `sum` into a (recycled or fresh) arena slot.
    fn alloc_slot(&mut self, sum: &[f64]) -> usize {
        let slot = self.free.pop().unwrap_or_else(|| {
            let next = self.sums.len() / self.d;
            self.sums.resize(self.sums.len() + self.d, 0.0);
            next
        });
        self.sums[slot * self.d..(slot + 1) * self.d].copy_from_slice(sum);
        slot
    }

    /// Offer one received block: a contiguous ascending task range and
    /// its aggregated `d`-length sum.
    pub fn offer(&mut self, tasks: &[usize], sum: &[f64]) -> Offer {
        if tasks.is_empty() || sum.len() != self.d {
            return Offer::Malformed;
        }
        let (start, len) = (tasks[0], tasks.len());
        if start + len > self.n || tasks.windows(2).any(|w| w[1] != w[0] + 1) {
            return Offer::Malformed;
        }
        if (start / self.s) != ((start + len - 1) / self.s) {
            return Offer::Malformed; // straddles a canonical boundary
        }
        let block = start / self.s;
        let end = start + len;
        // `inter` measures the covered part of the incoming range (for
        // duplicate detection); `dropped_len` is the *full* length of
        // every accepted range it touches — those are what a
        // replacement would evict whole, so strict coverage growth
        // requires `len > dropped_len`, not merely `len > inter`
        let (mut inter, mut dropped_len) = (0usize, 0usize);
        for r in self.blocks[block].iter() {
            let ov = end.min(r.start + r.len).saturating_sub(start.max(r.start));
            if ov > 0 {
                inter += ov;
                dropped_len += r.len;
            }
        }
        if inter == len {
            return Offer::Duplicate;
        }
        if inter == 0 {
            if self.blocks[block].is_empty() {
                self.touched.push(block);
            }
            let slot = self.alloc_slot(sum);
            self.blocks[block].push(RangeMeta { start, len, slot });
            self.distinct += len;
            return Offer::Accepted { new_distinct: len };
        }
        // partial overlap: replace the intersecting ranges only if the
        // swap strictly grows coverage (monotone acceptance); evicted
        // slots return to the free-list before the incoming claims one
        if len > dropped_len {
            {
                let Self { blocks, free, .. } = self;
                blocks[block].retain(|r| {
                    let keep = r.start + r.len <= start || r.start >= end;
                    if !keep {
                        free.push(r.slot);
                    }
                    keep
                });
            }
            let slot = self.alloc_slot(sum);
            self.blocks[block].push(RangeMeta { start, len, slot });
            let gained = len - dropped_len;
            self.distinct += gained;
            Offer::Accepted {
                new_distinct: gained,
            }
        } else {
            Offer::Stranded
        }
    }

    /// Distinct tasks covered so far.
    pub fn distinct(&self) -> usize {
        self.distinct
    }

    /// Has the `k`-distinct completion rule fired?
    pub fn complete(&self) -> bool {
        self.distinct >= self.k
    }

    /// Emit the winners (canonical task order) and the gradient
    /// partial-sum `Σ_{t ∈ winners} h(X_t)`, accumulated in canonical
    /// order so the result is independent of arrival order.  The
    /// returned slices borrow reused internal buffers — copy out what
    /// must outlive the next `reset`/`finish`.
    pub fn finish(&mut self) -> (&[usize], &[f64]) {
        self.winners.clear();
        self.total.clear();
        self.total.resize(self.d, 0.0);
        self.touched.sort_unstable();
        let Self {
            blocks,
            touched,
            sums,
            winners,
            total,
            d,
            ..
        } = self;
        for &b in touched.iter() {
            let ranges = &mut blocks[b];
            ranges.sort_unstable_by_key(|r| r.start);
            for r in ranges.iter() {
                winners.extend(r.start..r.start + r.len);
                vec_axpy(total, 1.0, &sums[r.slot * *d..(r.slot + 1) * *d]);
            }
        }
        (winners, total)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sum_of(tasks: &[usize], d: usize) -> Vec<f64> {
        // task t contributes the vector [t+1, t+1, …] — integer-exact
        (0..d)
            .map(|_| tasks.iter().map(|&t| (t + 1) as f64).sum())
            .collect()
    }

    fn offer_range(agg: &mut RoundAggregator, lo: usize, hi: usize, d: usize) -> Offer {
        let tasks: Vec<usize> = (lo..hi).collect();
        agg.offer(&tasks, &sum_of(&tasks, d))
    }

    #[test]
    fn singleton_groups_reproduce_k_distinct_dedup() {
        let mut agg = RoundAggregator::new(4, 2, 1, 3);
        assert_eq!(offer_range(&mut agg, 1, 2, 2), Offer::Accepted { new_distinct: 1 });
        assert_eq!(offer_range(&mut agg, 1, 2, 2), Offer::Duplicate);
        assert_eq!(offer_range(&mut agg, 3, 4, 2), Offer::Accepted { new_distinct: 1 });
        assert!(!agg.complete());
        assert_eq!(offer_range(&mut agg, 0, 1, 2), Offer::Accepted { new_distinct: 1 });
        assert!(agg.complete());
        let (winners, total) = agg.finish();
        assert_eq!(winners, vec![0, 1, 3]);
        assert_eq!(total, vec![6.0, 6.0]); // 1 + 2 + 4
    }

    #[test]
    fn complementary_suffix_and_prefix_tile_a_block() {
        // block [0, 3): suffix {1, 2} then prefix {0}
        let mut agg = RoundAggregator::new(6, 1, 3, 6);
        assert_eq!(offer_range(&mut agg, 1, 3, 1), Offer::Accepted { new_distinct: 2 });
        assert_eq!(offer_range(&mut agg, 0, 1, 1), Offer::Accepted { new_distinct: 1 });
        // full block now duplicates the tiled pair
        assert_eq!(offer_range(&mut agg, 0, 3, 1), Offer::Duplicate);
        assert_eq!(agg.distinct(), 3);
    }

    #[test]
    fn partial_overlap_is_stranded_unless_strictly_longer() {
        let mut agg = RoundAggregator::new(4, 1, 4, 4);
        assert_eq!(offer_range(&mut agg, 1, 3, 1), Offer::Accepted { new_distinct: 2 });
        // {2, 3} overlaps {1, 2} and is not longer → stranded whole
        assert_eq!(offer_range(&mut agg, 2, 4, 1), Offer::Stranded);
        assert_eq!(agg.distinct(), 2);
        // the full block is strictly longer → replaces the pair
        assert_eq!(offer_range(&mut agg, 0, 4, 1), Offer::Accepted { new_distinct: 2 });
        assert_eq!(agg.distinct(), 4);
        let (winners, total) = agg.finish();
        assert_eq!(winners, vec![0, 1, 2, 3]);
        assert_eq!(total, vec![10.0]); // the replacement's own sum, once
    }

    #[test]
    fn replacement_never_double_counts() {
        // accept {0}, then the longer {0, 1, 2} replaces it: coverage
        // goes 1 → 3 and the finish sum holds each task exactly once
        let mut agg = RoundAggregator::new(3, 1, 3, 3);
        assert_eq!(offer_range(&mut agg, 0, 1, 1), Offer::Accepted { new_distinct: 1 });
        assert_eq!(offer_range(&mut agg, 0, 3, 1), Offer::Accepted { new_distinct: 2 });
        let (winners, total) = agg.finish();
        assert_eq!(winners, vec![0, 1, 2]);
        assert_eq!(total, vec![6.0]);
    }

    #[test]
    fn replacement_that_would_shrink_coverage_is_stranded() {
        // accepted {0,1} and {3,4} (coverage 4); incoming {1,2,3} is
        // longer than its *intersection* (2) but would evict 4 covered
        // tasks for 3 — it must be stranded, not swapped in
        let mut agg = RoundAggregator::new(5, 1, 5, 5);
        assert_eq!(offer_range(&mut agg, 0, 2, 1), Offer::Accepted { new_distinct: 2 });
        assert_eq!(offer_range(&mut agg, 3, 5, 1), Offer::Accepted { new_distinct: 2 });
        assert_eq!(offer_range(&mut agg, 1, 4, 1), Offer::Stranded);
        assert_eq!(agg.distinct(), 4);
        // the exact gap filler is still welcome
        assert_eq!(offer_range(&mut agg, 2, 3, 1), Offer::Accepted { new_distinct: 1 });
        let (winners, total) = agg.finish();
        assert_eq!(winners, vec![0, 1, 2, 3, 4]);
        assert_eq!(total, vec![15.0]);
    }

    #[test]
    fn rejects_malformed_ranges() {
        let mut agg = RoundAggregator::new(8, 2, 2, 8);
        assert_eq!(agg.offer(&[], &[0.0, 0.0]), Offer::Malformed);
        assert_eq!(agg.offer(&[1], &[0.0]), Offer::Malformed); // wrong d
        assert_eq!(agg.offer(&[3, 5], &[0.0, 0.0]), Offer::Malformed); // gap
        assert_eq!(agg.offer(&[7, 8], &[0.0, 0.0]), Offer::Malformed); // oob
        assert_eq!(agg.offer(&[1, 2], &[0.0, 0.0]), Offer::Malformed); // straddle
        assert_eq!(agg.offer(&[2, 3], &[0.0, 0.0]), Offer::Accepted { new_distinct: 2 });
    }

    #[test]
    fn ragged_last_block_accepts_short_range() {
        // n = 5, s = 2 → blocks [0,2) [2,4) [4,5)
        let mut agg = RoundAggregator::new(5, 1, 2, 5);
        assert_eq!(offer_range(&mut agg, 4, 5, 1), Offer::Accepted { new_distinct: 1 });
        assert_eq!(offer_range(&mut agg, 0, 2, 1), Offer::Accepted { new_distinct: 2 });
        assert_eq!(offer_range(&mut agg, 2, 4, 1), Offer::Accepted { new_distinct: 2 });
        assert!(agg.complete());
        let (winners, total) = agg.finish();
        assert_eq!(winners, vec![0, 1, 2, 3, 4]);
        assert_eq!(total, vec![15.0]);
    }

    #[test]
    fn reset_reuses_state_identically_to_a_fresh_aggregator() {
        // round 1 exercises accept / duplicate / replace, then reset;
        // round 2 on the reused aggregator must match a fresh one
        // bit-for-bit (recycled arena slots, warm buffers and all)
        let mut reused = RoundAggregator::new(6, 3, 3, 6);
        assert_eq!(offer_range(&mut reused, 0, 2, 3), Offer::Accepted { new_distinct: 2 });
        assert_eq!(offer_range(&mut reused, 3, 6, 3), Offer::Accepted { new_distinct: 3 });
        assert_eq!(offer_range(&mut reused, 0, 3, 3), Offer::Accepted { new_distinct: 1 });
        let _ = reused.finish();
        reused.reset();
        assert_eq!(reused.distinct(), 0);
        assert!(!reused.complete());

        let mut fresh = RoundAggregator::new(6, 3, 3, 6);
        let offers = [(4usize, 6usize), (4, 6), (1, 3), (0, 3), (3, 6)];
        for &(lo, hi) in &offers {
            assert_eq!(
                offer_range(&mut reused, lo, hi, 3),
                offer_range(&mut fresh, lo, hi, 3),
                "offer [{lo}, {hi}) verdicts diverged after reset"
            );
        }
        let (w1, t1) = reused.finish();
        let (w2, t2) = fresh.finish();
        assert_eq!(w1, w2);
        assert_eq!(t1, t2);
    }

    #[test]
    fn finish_is_idempotent_on_reused_buffers() {
        let mut agg = RoundAggregator::new(4, 2, 2, 4);
        offer_range(&mut agg, 2, 4, 2);
        offer_range(&mut agg, 0, 2, 2);
        let (w1, t1) = {
            let (w, t) = agg.finish();
            (w.to_vec(), t.to_vec())
        };
        let (w2, t2) = agg.finish();
        assert_eq!(w1, w2);
        assert_eq!(t1, t2);
        assert_eq!(w1, vec![0, 1, 2, 3]);
    }
}
