//! Pooled, zero-copy frame plumbing for the cluster data plane.
//!
//! The blocking master reads one frame per `Msg::read_frame` call with
//! a fresh payload `Vec` each time, and every `Result` decode allocates
//! its `tasks`/`h` vectors even though the master immediately folds
//! them into the aggregator and drops them.  At fleet scale that
//! per-frame churn *is* the master-side ingest term the paper's
//! completion time is gated on.  This module is the allocation-free
//! replacement, shared by the poll reactor
//! ([`crate::coordinator::reactor`]) and the worker's send path:
//!
//! * [`FrameBuf`] — a growable scratch buffer a non-blocking socket is
//!   drained into; complete length-prefixed frames are yielded in place
//!   as borrows ([`Frame`]), partial frames simply stay buffered until
//!   the next readiness event.  Each OS read is stamped, so every
//!   yielded frame knows the wall-clock instant its last byte arrived —
//!   the numerator of the master *dwell time* metric (arrival →
//!   processing) reported in `ClusterReport.ingest`.
//! * [`parse_frame`] / [`ResultRef`] — a zero-copy view of the hot
//!   `Result` frame: header fields decoded by value, the `tasks`/`h`
//!   arrays left as byte borrows to be copied straight into caller
//!   scratch (`read_tasks_into`/`read_h64_into`).  Cold control frames
//!   fall back to the owned [`Msg`] decode.
//! * [`FramePool`] + [`encode_result_into`]/[`encode_assign_into`] —
//!   recycled encode buffers and framed (length-prefixed) encoders for
//!   the two per-round hot frames, byte-identical to
//!   `Msg::encode` + prefix (pinned by tests below).
//!
//! Protocol v5 wire bytes match `Msg::encode` exactly — this is
//! purely a different way of producing and consuming the same frames.
//! The one genuinely new trick is [`patch_result_send_ts`]: the
//! worker's delivery thread back-patches the `send_ts_us` field of an
//! already-encoded `Result` frame in place, so the send stamp is taken
//! at the moment the frame actually heads for the socket rather than
//! at encode time (which is what the separate `enqueue_us` field now
//! records).

use std::collections::VecDeque;
use std::io::{self, Read};

use anyhow::{bail, Result};

use super::protocol::{put_u32, put_u64, Msg, MAX_FRAME};

/// Per-read target: large enough that a GC flush frame (d ≲ 8k floats)
/// lands in one or two reads, small enough that an idle connection
/// costs nothing.
const READ_CHUNK: usize = 64 * 1024;

/// A complete frame borrowed out of a [`FrameBuf`].
pub struct Frame<'a> {
    /// the payload (tag + fields), without the length prefix
    pub payload: &'a [u8],
    /// total wire size: 4-byte prefix + payload
    pub wire_len: usize,
    /// µs timestamp (shared process clock) of the OS read that
    /// completed this frame — when its last byte actually arrived
    pub recv_us: u64,
}

/// Incremental frame assembly buffer for one connection.
///
/// `fill_from` appends whatever the socket has ready; `next_frame`
/// yields complete frames in place.  Compaction (shifting the live
/// region back to offset 0) happens only when the spare tail runs out,
/// so steady-state operation is memmove-light and allocation-free once
/// the buffer has grown to the connection's frame size.
#[derive(Default)]
pub struct FrameBuf {
    buf: Vec<u8>,
    start: usize,
    end: usize,
    /// absolute stream offset of `start` (bytes consumed so far)
    abs_consumed: u64,
    /// fill marks `(absolute_end_offset, ts_us)`: the ts of the read
    /// that brought the stream up to that offset.  Frames map their end
    /// offset to the first covering mark — exact arrival times even
    /// when frames sit buffered behind one another.
    marks: VecDeque<(u64, u64)>,
}

impl FrameBuf {
    pub fn new() -> Self {
        Self::default()
    }

    /// Bytes currently buffered (complete or partial frames).
    pub fn pending_bytes(&self) -> usize {
        self.end - self.start
    }

    /// Drop all buffered state (pool reuse).
    pub fn reset(&mut self) {
        self.start = 0;
        self.end = 0;
        self.abs_consumed = 0;
        self.marks.clear();
    }

    /// One `read` from `r` into spare capacity, stamped `now_us`.
    /// Returns `Ok(0)` on EOF; `WouldBlock` propagates as `Err` (the
    /// reactor's cue that the socket is drained).
    pub fn fill_from(&mut self, r: &mut impl Read, now_us: u64) -> io::Result<usize> {
        if self.start == self.end {
            self.start = 0;
            self.end = 0;
        }
        if self.buf.len() - self.end < READ_CHUNK {
            if self.start > 0 {
                self.buf.copy_within(self.start..self.end, 0);
                self.end -= self.start;
                self.start = 0;
            }
            if self.buf.len() - self.end < READ_CHUNK {
                self.buf.resize(self.end + READ_CHUNK, 0);
            }
        }
        let n = r.read(&mut self.buf[self.end..])?;
        if n > 0 {
            self.end += n;
            let abs_end = self.abs_consumed + (self.end - self.start) as u64;
            match self.marks.back_mut() {
                // coalesce reads from the same instant
                Some(m) if m.1 == now_us => m.0 = abs_end,
                _ => self.marks.push_back((abs_end, now_us)),
            }
        }
        Ok(n)
    }

    /// Is a complete frame buffered?  Non-consuming peek — the
    /// reactor's fairness scan checks every connection before
    /// borrowing one frame out.  Errors on a corrupt (oversized)
    /// length prefix, like [`FrameBuf::next_frame`].
    pub fn has_frame(&self) -> Result<bool> {
        let avail = self.end - self.start;
        if avail < 4 {
            return Ok(false);
        }
        let len = u32::from_le_bytes(self.buf[self.start..self.start + 4].try_into().unwrap());
        if len > MAX_FRAME {
            bail!("oversized frame {len}");
        }
        Ok(avail >= 4 + len as usize)
    }

    /// Yield the next complete frame, if one is fully buffered.
    /// Errors only on a corrupt (oversized) length prefix — the
    /// connection is unrecoverable past that point.
    pub fn next_frame(&mut self) -> Result<Option<Frame<'_>>> {
        let avail = self.end - self.start;
        if avail < 4 {
            return Ok(None);
        }
        let len = u32::from_le_bytes(self.buf[self.start..self.start + 4].try_into().unwrap());
        if len > MAX_FRAME {
            bail!("oversized frame {len}");
        }
        let len = len as usize;
        if avail < 4 + len {
            return Ok(None);
        }
        let frame_end_abs = self.abs_consumed + (4 + len) as u64;
        while self.marks.front().is_some_and(|m| m.0 < frame_end_abs) {
            self.marks.pop_front();
        }
        let recv_us = self.marks.front().map_or(0, |m| m.1);
        let s = self.start + 4;
        self.start += 4 + len;
        self.abs_consumed += (4 + len) as u64;
        Ok(Some(Frame {
            payload: &self.buf[s..s + len],
            wire_len: 4 + len,
            recv_us,
        }))
    }
}

/// Zero-copy view of a `Result` frame: header by value, arrays as byte
/// borrows to be copied straight into caller scratch.
pub struct ResultRef<'a> {
    pub round: u32,
    pub version: u32,
    pub worker_id: u32,
    pub comp_us: u64,
    /// v5 latency anatomy: worker-clock stamps (first task start,
    /// compute end, flush encode) — see `Msg::Result` in protocol.rs.
    pub comp_start_us: u64,
    pub comp_end_us: u64,
    pub enqueue_us: u64,
    pub send_ts_us: u64,
    tasks: &'a [u8],
    h: &'a [u8],
}

impl ResultRef<'_> {
    pub fn tasks_len(&self) -> usize {
        self.tasks.len() / 4
    }

    pub fn h_len(&self) -> usize {
        self.h.len() / 4
    }

    /// Copy the task ids into `out` (cleared first).
    pub fn read_tasks_into(&self, out: &mut Vec<usize>) {
        out.clear();
        out.extend(
            self.tasks
                .chunks_exact(4)
                .map(|c| u32::from_le_bytes(c.try_into().unwrap()) as usize),
        );
    }

    /// Copy the aggregated partial-sum block into `out` as f64
    /// (cleared first) — the master aggregates in f64.
    pub fn read_h64_into(&self, out: &mut Vec<f64>) {
        out.clear();
        out.extend(
            self.h
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes(c.try_into().unwrap()) as f64),
        );
    }
}

/// A parsed frame: the hot `Result` path stays zero-copy, everything
/// else takes the owned [`Msg`] decode (control frames are rare).
pub enum FrameView<'a> {
    Result(ResultRef<'a>),
    Other(Msg),
}

/// Parse a frame payload.  Field layout and validation (truncation,
/// lying array lengths, trailing bytes) match [`Msg::decode`] exactly.
pub fn parse_frame(payload: &[u8]) -> Result<FrameView<'_>> {
    if payload.first() != Some(&Msg::TAG_RESULT) {
        return Ok(FrameView::Other(Msg::decode(payload)?));
    }
    let mut pos = 1usize;
    let round = u32_at(payload, &mut pos)?;
    let version = u32_at(payload, &mut pos)?;
    let worker_id = u32_at(payload, &mut pos)?;
    let tasks_len = u32_at(payload, &mut pos)? as usize;
    let tasks = take(payload, &mut pos, tasks_len.saturating_mul(4))?;
    let comp_us = u64_at(payload, &mut pos)?;
    let comp_start_us = u64_at(payload, &mut pos)?;
    let comp_end_us = u64_at(payload, &mut pos)?;
    let enqueue_us = u64_at(payload, &mut pos)?;
    let send_ts_us = u64_at(payload, &mut pos)?;
    let h_len = u32_at(payload, &mut pos)? as usize;
    let h = take(payload, &mut pos, h_len.saturating_mul(4))?;
    if pos != payload.len() {
        bail!("trailing bytes in frame (tag {})", Msg::TAG_RESULT);
    }
    Ok(FrameView::Result(ResultRef {
        round,
        version,
        worker_id,
        comp_us,
        comp_start_us,
        comp_end_us,
        enqueue_us,
        send_ts_us,
        tasks,
        h,
    }))
}

fn take<'a>(payload: &'a [u8], pos: &mut usize, n: usize) -> Result<&'a [u8]> {
    if payload.len() - *pos < n {
        bail!("frame truncated at byte {}", *pos);
    }
    let s = &payload[*pos..*pos + n];
    *pos += n;
    Ok(s)
}

fn u32_at(payload: &[u8], pos: &mut usize) -> Result<u32> {
    Ok(u32::from_le_bytes(take(payload, pos, 4)?.try_into().unwrap()))
}

fn u64_at(payload: &[u8], pos: &mut usize) -> Result<u64> {
    Ok(u64::from_le_bytes(take(payload, pos, 8)?.try_into().unwrap()))
}

/// Recycled encode buffers: `get` a cleared `Vec<u8>`, `put` it back
/// after the bytes hit the socket.  Bounded so a burst can't pin
/// memory forever.
#[derive(Default)]
pub struct FramePool {
    free: Vec<Vec<u8>>,
}

impl FramePool {
    const MAX_POOLED: usize = 64;

    pub fn new() -> Self {
        Self::default()
    }

    pub fn get(&mut self) -> Vec<u8> {
        match self.free.pop() {
            Some(mut b) => {
                b.clear();
                b
            }
            None => Vec::new(),
        }
    }

    pub fn put(&mut self, buf: Vec<u8>) {
        if self.free.len() < Self::MAX_POOLED {
            self.free.push(buf);
        }
    }

    pub fn pooled(&self) -> usize {
        self.free.len()
    }
}

/// Append a framed (length-prefixed) `Result` to `out`, converting the
/// f64 running sum to the wire's f32 in place — byte-identical to
/// `Msg::Result{..}.encode()` behind a prefix, with zero intermediate
/// allocation.
#[allow(clippy::too_many_arguments)]
pub fn encode_result_into(
    out: &mut Vec<u8>,
    round: u32,
    version: u32,
    worker_id: u32,
    tasks: &[u32],
    comp_us: u64,
    comp_start_us: u64,
    comp_end_us: u64,
    enqueue_us: u64,
    send_ts_us: u64,
    h_sum: &[f64],
) {
    let payload_len = 1 + 3 * 4 + (4 + 4 * tasks.len()) + 5 * 8 + (4 + 4 * h_sum.len());
    out.reserve(4 + payload_len);
    put_u32(out, payload_len as u32);
    out.push(Msg::TAG_RESULT);
    put_u32(out, round);
    put_u32(out, version);
    put_u32(out, worker_id);
    put_u32(out, tasks.len() as u32);
    for &t in tasks {
        put_u32(out, t);
    }
    put_u64(out, comp_us);
    put_u64(out, comp_start_us);
    put_u64(out, comp_end_us);
    put_u64(out, enqueue_us);
    put_u64(out, send_ts_us);
    put_u32(out, h_sum.len() as u32);
    for &v in h_sum {
        out.extend_from_slice(&(v as f32).to_le_bytes());
    }
}

/// Byte offset of `send_ts_us` inside a framed `Result`:
/// `len(4) tag(1) round(4) version(4) worker(4) tasks_len(4)
/// tasks(4·n) comp(8) comp_start(8) comp_end(8) enqueue(8)` → 53+4n.
fn result_send_ts_offset(frame: &[u8]) -> usize {
    debug_assert!(frame.len() >= 21 && frame[4] == Msg::TAG_RESULT);
    let n = u32::from_le_bytes(frame[17..21].try_into().unwrap()) as usize;
    53 + 4 * n
}

/// Back-patch `send_ts_us` in an already-encoded framed `Result` —
/// the delivery thread stamps the frame at the instant it picks it up
/// for the socket, *before* any injected wire delay, so
/// `recv_us - send_ts_us` measures the full network phase.
pub fn patch_result_send_ts(frame: &mut [u8], send_ts_us: u64) {
    let at = result_send_ts_offset(frame);
    frame[at..at + 8].copy_from_slice(&send_ts_us.to_le_bytes());
}

/// Append a framed `Assign` to `out`.  Cluster mode always uses the
/// identity task↔batch map (no Remark-3 reshuffle), so the task list is
/// written twice — once as `tasks`, once as `batches` — exactly as the
/// master's `Msg::Assign { batches: tasks.clone(), .. }` did.
#[allow(clippy::too_many_arguments)]
pub fn encode_assign_into(
    out: &mut Vec<u8>,
    round: u32,
    version: u32,
    theta: &[f32],
    tasks: &[u32],
    group: u32,
    issue_us: u64,
    align: bool,
) {
    let payload_len = 1 + 2 * 4 + (4 + 4 * theta.len()) + 2 * (4 + 4 * tasks.len()) + 4 + 8 + 1;
    out.reserve(4 + payload_len);
    put_u32(out, payload_len as u32);
    out.push(Msg::TAG_ASSIGN);
    put_u32(out, round);
    put_u32(out, version);
    put_u32(out, theta.len() as u32);
    for &v in theta {
        out.extend_from_slice(&v.to_le_bytes());
    }
    for _ in 0..2 {
        put_u32(out, tasks.len() as u32);
        for &t in tasks {
            put_u32(out, t);
        }
    }
    put_u32(out, group);
    put_u64(out, issue_us);
    // align stays the FINAL Assign field (see protocol.rs)
    out.push(u8::from(align));
}

/// Append any message framed (prefix + payload) to `out` — the cold
/// path for control frames (Stop/Shutdown/Welcome), sharing the pooled
/// buffer discipline of the hot encoders.
pub fn encode_msg_framed(out: &mut Vec<u8>, msg: &Msg) {
    let at = out.len();
    out.extend_from_slice(&[0u8; 4]); // prefix backpatched below
    msg.encode_into(out);
    let payload_len = (out.len() - at - 4) as u32;
    out[at..at + 4].copy_from_slice(&payload_len.to_le_bytes());
}

#[cfg(test)]
mod tests {
    use super::*;

    fn framed(msg: &Msg) -> Vec<u8> {
        let payload = msg.encode();
        let mut wire = (payload.len() as u32).to_le_bytes().to_vec();
        wire.extend_from_slice(&payload);
        wire
    }

    fn sample_result() -> Msg {
        Msg::Result {
            round: 13,
            version: 11,
            worker_id: 2,
            tasks: vec![3, 4, 9],
            comp_us: 1234,
            comp_start_us: 990_000,
            comp_end_us: 991_234,
            enqueue_us: 995_000,
            send_ts_us: 999_999,
            h: vec![1.0, -2.5, f32::MAX],
        }
    }

    #[test]
    fn encode_result_into_is_byte_identical_to_msg_encode() {
        let mut out = Vec::new();
        encode_result_into(
            &mut out,
            13,
            11,
            2,
            &[3, 4, 9],
            1234,
            990_000,
            991_234,
            995_000,
            999_999,
            // f64 inputs that round-trip exactly through f32
            &[1.0, -2.5, f32::MAX as f64],
        );
        assert_eq!(out, framed(&sample_result()));
    }

    #[test]
    fn patch_result_send_ts_rewrites_only_the_send_stamp() {
        let mut out = Vec::new();
        encode_result_into(
            &mut out,
            13,
            11,
            2,
            &[3, 4, 9],
            1234,
            990_000,
            991_234,
            995_000,
            0, // placeholder the delivery thread overwrites
            &[1.0, -2.5, f32::MAX as f64],
        );
        patch_result_send_ts(&mut out, 999_999);
        assert_eq!(out, framed(&sample_result()));
        // idempotent re-patch, and the empty-tasks offset path
        patch_result_send_ts(&mut out, 999_999);
        assert_eq!(out, framed(&sample_result()));
        let mut empty = Vec::new();
        encode_result_into(&mut empty, 1, 1, 0, &[], 5, 6, 7, 8, 0, &[]);
        patch_result_send_ts(&mut empty, 77);
        match parse_frame(&empty[4..]).unwrap() {
            FrameView::Result(r) => assert_eq!(r.send_ts_us, 77),
            FrameView::Other(_) => panic!("Result frame must take the zero-copy path"),
        }
    }

    #[test]
    fn encode_assign_into_is_byte_identical_to_msg_encode() {
        for align in [false, true] {
            let tasks = vec![7u32, 0, 3, 4];
            let theta = vec![0.5f32, -1.5, 3.25];
            let msg = Msg::Assign {
                round: 12,
                version: 10,
                theta: theta.clone(),
                tasks: tasks.clone(),
                batches: tasks.clone(),
                group: 2,
                issue_us: 4_242_000,
                align,
            };
            let mut out = Vec::new();
            encode_assign_into(&mut out, 12, 10, &theta, &tasks, 2, 4_242_000, align);
            assert_eq!(out, framed(&msg), "align = {align}");
        }
    }

    #[test]
    fn encode_msg_framed_matches_write_to() {
        for msg in [
            Msg::Stop { round: 7 },
            Msg::Shutdown,
            Msg::Welcome {
                proto: 4,
                worker_id: 3,
                profile: "fig5".into(),
            },
        ] {
            let mut out = Vec::new();
            encode_msg_framed(&mut out, &msg);
            assert_eq!(out, framed(&msg));
        }
    }

    #[test]
    fn parse_frame_result_view_matches_owned_decode() {
        let payload = sample_result().encode();
        match parse_frame(&payload).unwrap() {
            FrameView::Result(r) => {
                assert_eq!((r.round, r.version, r.worker_id), (13, 11, 2));
                assert_eq!((r.comp_us, r.send_ts_us), (1234, 999_999));
                assert_eq!(
                    (r.comp_start_us, r.comp_end_us, r.enqueue_us),
                    (990_000, 991_234, 995_000)
                );
                assert_eq!((r.tasks_len(), r.h_len()), (3, 3));
                let mut tasks = vec![99usize]; // read_*_into must clear
                r.read_tasks_into(&mut tasks);
                assert_eq!(tasks, vec![3, 4, 9]);
                let mut h = vec![0.0f64];
                r.read_h64_into(&mut h);
                assert_eq!(h, vec![1.0, -2.5, f32::MAX as f64]);
            }
            FrameView::Other(_) => panic!("Result frame must take the zero-copy path"),
        }
    }

    #[test]
    fn parse_frame_other_falls_back_to_msg_decode() {
        let payload = Msg::Stop { round: 3 }.encode();
        match parse_frame(&payload).unwrap() {
            FrameView::Other(Msg::Stop { round }) => assert_eq!(round, 3),
            _ => panic!("Stop must fall back to the owned decode"),
        }
    }

    #[test]
    fn parse_frame_rejects_everything_msg_decode_rejects() {
        let enc = sample_result().encode();
        for cut in 1..enc.len() {
            assert!(parse_frame(&enc[..cut]).is_err(), "cut at {cut}");
        }
        let mut trailing = enc.clone();
        trailing.push(0);
        assert!(parse_frame(&trailing).is_err());
        // lying tasks length: claims more u32s than the frame holds
        let mut lying = vec![Msg::TAG_RESULT];
        lying.extend_from_slice(&1u32.to_le_bytes()); // round
        lying.extend_from_slice(&1u32.to_le_bytes()); // version
        lying.extend_from_slice(&0u32.to_le_bytes()); // worker_id
        lying.extend_from_slice(&1_000_000u32.to_le_bytes()); // tasks len lie
        assert!(parse_frame(&lying).is_err());
        assert!(parse_frame(&[99]).is_err()); // unknown tag → Msg::decode error
    }

    /// `Read` that doles the wire out `chunk` bytes at a time — frame
    /// boundaries land everywhere, including inside the length prefix.
    struct Chunked<'a> {
        data: &'a [u8],
        pos: usize,
        chunk: usize,
    }

    impl Read for Chunked<'_> {
        fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
            let n = self.chunk.min(self.data.len() - self.pos).min(buf.len());
            buf[..n].copy_from_slice(&self.data[self.pos..self.pos + n]);
            self.pos += n;
            Ok(n)
        }
    }

    #[test]
    fn framebuf_reassembles_frames_across_any_split() {
        let msgs = vec![
            sample_result(),
            Msg::Stop { round: 13 },
            Msg::Result {
                round: 14,
                version: 12,
                worker_id: 0,
                tasks: vec![1],
                comp_us: 5,
                comp_start_us: 1,
                comp_end_us: 6,
                enqueue_us: 6,
                send_ts_us: 6,
                h: vec![0.25; 32],
            },
            Msg::Shutdown,
        ];
        let mut wire = Vec::new();
        for m in &msgs {
            encode_msg_framed(&mut wire, m);
        }
        for chunk in [1usize, 2, 3, 5, 7, 11, 64, wire.len()] {
            let mut r = Chunked {
                data: &wire,
                pos: 0,
                chunk,
            };
            let mut fb = FrameBuf::new();
            let mut got = Vec::new();
            let mut wire_total = 0usize;
            loop {
                while let Some(f) = fb.next_frame().unwrap() {
                    wire_total += f.wire_len;
                    got.push(Msg::decode(f.payload).unwrap());
                }
                if fb.fill_from(&mut r, 0).unwrap() == 0 {
                    break;
                }
            }
            assert_eq!(got, msgs, "chunk = {chunk}");
            assert_eq!(wire_total, wire.len(), "chunk = {chunk}");
            assert_eq!(fb.pending_bytes(), 0, "chunk = {chunk}");
        }
    }

    #[test]
    fn framebuf_rejects_oversized_prefix() {
        let mut fb = FrameBuf::new();
        let bogus = (MAX_FRAME + 1).to_le_bytes();
        let mut r = Chunked {
            data: &bogus,
            pos: 0,
            chunk: 4,
        };
        fb.fill_from(&mut r, 0).unwrap();
        assert!(fb.next_frame().is_err());
    }

    #[test]
    fn fill_marks_give_exact_per_frame_arrival_times() {
        // frame A arrives whole in the first read (ts 100); frame B is
        // split across reads and completes in the second (ts 200); a
        // third read (ts 300) brings frame C.  Buffered frames must
        // report the read that *completed* them, not the consume time.
        let a = {
            let mut v = Vec::new();
            encode_msg_framed(&mut v, &Msg::Stop { round: 1 });
            v
        };
        let b = {
            let mut v = Vec::new();
            encode_msg_framed(&mut v, &Msg::Stop { round: 2 });
            v
        };
        let c = {
            let mut v = Vec::new();
            encode_msg_framed(&mut v, &Msg::Shutdown);
            v
        };
        let mut fb = FrameBuf::new();
        let fill = |fb: &mut FrameBuf, bytes: &[u8], ts: u64| {
            let mut r = Chunked {
                data: bytes,
                pos: 0,
                chunk: bytes.len().max(1),
            };
            fb.fill_from(&mut r, ts).unwrap();
        };
        let split = b.len() / 2;
        fill(&mut fb, &a, 100);
        fill(&mut fb, &b[..split], 100);
        fill(&mut fb, &b[split..], 200);
        fill(&mut fb, &c, 300);
        let ts_a = fb.next_frame().unwrap().unwrap().recv_us;
        let ts_b = fb.next_frame().unwrap().unwrap().recv_us;
        let ts_c = fb.next_frame().unwrap().unwrap().recv_us;
        assert_eq!((ts_a, ts_b, ts_c), (100, 200, 300));
        assert!(fb.next_frame().unwrap().is_none());
    }

    #[test]
    fn frame_pool_recycles_buffers() {
        let mut pool = FramePool::new();
        let mut b = pool.get();
        b.extend_from_slice(&[1, 2, 3]);
        let cap = b.capacity();
        pool.put(b);
        assert_eq!(pool.pooled(), 1);
        let b2 = pool.get();
        assert!(b2.is_empty(), "pooled buffers come back cleared");
        assert_eq!(b2.capacity(), cap, "capacity survives the round trip");
        assert_eq!(pool.pooled(), 0);
    }
}
