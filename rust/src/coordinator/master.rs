//! Cluster master (leader): schedules, distributes, collects, stops,
//! updates — the paper's §II protocol over real sockets.
//!
//! Since protocol v3 the master speaks every scheme natively
//! ([`crate::scheme::WirePlan`]):
//!
//! * **uncoded** (CS/SS/RA/GC(s)) — workers stream aggregated
//!   partial-sum blocks; the master merges them duplicate-safe by task
//!   range ([`super::aggregate::RoundAggregator`]) and applies the
//!   eq. 61 update from the merged sum — a GC(s) flush costs one
//!   `d`-vector on the wire instead of `s`;
//! * **coded** (PC/PCMM) — the master encodes each worker's matrices
//!   with [`crate::coded`] at load time, collects polynomial
//!   evaluations, and at the recovery threshold *decodes* the exact
//!   full gradient and steps θ (eq. 49) — Messages-rule rounds are no
//!   longer timing-only.
//!
//! On the uncoded plane the per-round `Assign` plan can come from an
//! adaptive [`PolicyEngine`] instead of the frozen registry plan
//! ([`ClusterConfig::policy`]): the engine eats the same measured
//! `comp_us`/receive-timestamp stream the `RoundLog` is built from and
//! re-emits worker order / per-worker flush sizes / assignment between
//! rounds.  Protocol stays v3 — assignment was always per-round; only
//! the plan's source changes.

use std::cell::RefCell;
use std::collections::{HashMap, HashSet};
use std::io::Write as _;
use std::net::{TcpListener, TcpStream};
use std::rc::Rc;
use std::sync::mpsc;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use super::aggregate::{AggregatorRing, Offer, RingOffer, RoundAggregator};
use super::framebuf::{encode_assign_into, encode_msg_framed, parse_frame, FramePool, FrameView};
use super::protocol::Msg;
use super::reactor::Reactor;
use super::{now_us, TaskDelaySampler};
use crate::adaptive::{GroupAllocation, PolicyEngine, PolicyKind, WorkerEstimate, MAX_STALENESS};
use crate::coded::{DecodeCache, DecodeCacheStats, PcScheme, PcmmScheme};
use crate::data::Dataset;
use crate::delay::DelayModelKind;
use crate::gd::{coded_update, UncodedMaster};
use crate::linalg::{vec_axpy, Mat};
use crate::metrics::DelayRecorder;
use crate::scheduler::Scheduler as _;
use crate::scheme::{ClusterPlan, CompletionRule, WirePlan};
use crate::telemetry::flight::Phase;
use crate::telemetry::{
    metrics as tm, snapshot_into, AnomalyDetector, ClockSync, FlightRecorder, MetricsConfig,
    MetricsLog, MetricsServer, Snapshot, SpanRecorder, SpanSummary,
};
use crate::trace::{TraceRecorder, TraceStore};
use crate::util::poll::PollHook;
use crate::util::signal;
use crate::util::rng::Rng;
use crate::util::stats::{RunningStats, StreamingQuantiles};

/// How the master talks to its worker sockets.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum IoMode {
    /// Thread-per-worker blocking readers feeding an mpsc channel — the
    /// pre-reactor data plane, kept as the bit-identity cross-check
    /// (`tests/reactor_parity.rs`).
    Threads,
    /// One poll-driven event loop over non-blocking sockets with pooled
    /// frame buffers and a zero-copy `Result` parse
    /// ([`super::reactor`], [`super::framebuf`]).
    #[default]
    Reactor,
}

impl IoMode {
    /// Parse the CLI spelling (`train --io threads|reactor`).
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "threads" => Ok(IoMode::Threads),
            "reactor" => Ok(IoMode::Reactor),
            other => bail!("unknown io mode {other:?} (expected threads|reactor)"),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            IoMode::Threads => "threads",
            IoMode::Reactor => "reactor",
        }
    }
}

impl std::fmt::Display for IoMode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Cluster configuration.
pub struct ClusterConfig {
    pub n: usize,
    pub r: usize,
    pub k: usize,
    pub eta: f64,
    pub rounds: usize,
    /// artifact profile the workers execute (`task_gram` entry)
    pub profile: String,
    /// how the scheme executes on the wire — scheduler, flush group,
    /// completion rule and payload semantics, built by
    /// [`crate::scheme::SchemeRegistry::cluster_plan`] (or
    /// [`crate::scheme::SchemeRegistry::adaptive_plan`] when a policy
    /// re-plans it)
    pub plan: ClusterPlan,
    /// round-boundary re-planning policy ([`crate::adaptive`]):
    /// `static` freezes the plan (the pre-adaptive behavior), the
    /// others consume measured per-worker delays and re-issue each
    /// round's `Assign` frames from a fresh [`crate::adaptive::RoundPlan`]
    pub policy: PolicyKind,
    /// bounded-staleness window `S ∈ [1, MAX_STALENESS]`.  `S = 1` is
    /// the strictly synchronous §II protocol (collect → step θ →
    /// re-assign).  `S ≥ 2` keeps up to `S` rounds in flight on the
    /// uncoded `DistinctTasks` plane: frames route through an
    /// [`super::aggregate::AggregatorRing`], θ applies strictly in
    /// round order, and round `t + S` is issued (with its v4 θ-version
    /// tag) the instant round `t` applies — a straggler delays its own
    /// round's application, not the fleet's assignment pipeline.
    pub staleness: usize,
    pub dataset: Dataset,
    /// injected straggling; `None` measures bare-metal delays
    pub inject: Option<DelayModelKind>,
    pub seed: u64,
    /// worker compute engine
    pub use_pjrt: bool,
    pub artifact_dir: Option<std::path::PathBuf>,
    /// record loss every this many rounds (loss is O(N·d))
    pub loss_every: usize,
    /// listen address; `None` binds an ephemeral localhost port
    pub listen: Option<String>,
    /// spawn the n workers in-process (false = wait for external
    /// `straggler worker --connect` processes — real multi-process mode)
    pub spawn_workers: bool,
    /// master-side socket I/O: the poll reactor (default) or the
    /// thread-per-worker blocking path (bit-identity cross-check)
    pub io: IoMode,
    /// telemetry wiring: Prometheus scrape listener + per-round JSONL
    /// metrics log ([`crate::telemetry`]).  Off by default; provably
    /// inert on the data path (`tests/reactor_parity.rs` pins θ
    /// bit-identical with telemetry on vs off).
    pub metrics: MetricsConfig,
}

/// Per-round record.
#[derive(Debug, Clone)]
pub struct RoundLog {
    pub round: usize,
    /// wall-clock ms from round start to completion (k distinct tasks,
    /// or the threshold-th message under a `Messages` rule)
    pub completion_ms: f64,
    /// the distinct winners held at completion — task ids in canonical
    /// order for the uncoded wire; evaluation keys (worker ids for PC,
    /// global slot ids for PCMM) in arrival order for the coded wires
    pub winners: Vec<usize>,
    /// total task results received (incl. duplicates)
    pub results_seen: usize,
    /// result messages received — the GC(s) communication saving in
    /// message count
    pub messages_seen: usize,
    /// total wire bytes of the received result frames (length prefix +
    /// payload) — the GC(s) payload saving: one aggregated block per
    /// flush, so bytes/round shrink ≈ s× vs per-task blocks
    pub wire_bytes: usize,
    /// did the policy change the plan for this round? (always false
    /// under the `static` policy; the first planned round counts)
    pub replanned: bool,
    pub loss: Option<f64>,
}

/// Master-side ingest health for the run: per-frame *dwell time* — µs
/// from a `Result` frame being ready at the master (last byte read off
/// the socket, or handed to the channel in `IoMode::Threads`) to the
/// round loop actually processing it.  Dwell is the master-side queueing
/// term the cross-round ingest-contention approximation in
/// EXPERIMENTS.md §Async could previously only estimate: a p99 that
/// grows with n means the master itself is the straggler.
#[derive(Debug, Clone, Default)]
pub struct IngestReport {
    /// frames measured (every frame the data plane handed the loop,
    /// including non-Result and later-dropped ones)
    pub frames: usize,
    pub dwell_p50_us: f64,
    pub dwell_p90_us: f64,
    pub dwell_p99_us: f64,
    pub dwell_mean_us: f64,
    pub dwell_max_us: f64,
}

/// Streaming dwell accumulator behind [`IngestReport`]: exact order
/// statistics up to `StreamingQuantiles::EXACT_CAP` frames, O(1) grid
/// past it — safe to leave on for million-frame runs.
struct IngestStats {
    q: StreamingQuantiles,
    s: RunningStats,
}

impl IngestStats {
    fn new() -> Self {
        Self {
            q: StreamingQuantiles::new(),
            s: RunningStats::new(),
        }
    }

    fn push(&mut self, dwell_us: u64) {
        let v = dwell_us as f64;
        self.q.push(v);
        self.s.push(v);
    }

    fn report(&self) -> IngestReport {
        if self.s.count() == 0 {
            return IngestReport::default();
        }
        let qs = self.q.quantiles(&[0.5, 0.9, 0.99]);
        IngestReport {
            frames: self.s.count() as usize,
            dwell_p50_us: qs[0],
            dwell_p90_us: qs[1],
            dwell_p99_us: qs[2],
            dwell_mean_us: self.s.mean(),
            dwell_max_us: self.s.max(),
        }
    }
}

/// Whole-run report.
pub struct ClusterReport {
    pub rounds: Vec<RoundLog>,
    /// per-worker measured delays (ms) — feeds Fig. 3 + empirical replay
    pub recorders: Vec<DelayRecorder>,
    /// the canonical per-event delay trace ([`crate::trace`]): one
    /// event per received `Result` frame (real socket timings, frame
    /// bytes, flush sizes) — save with `train --record PATH`, then
    /// `straggler trace fit` / `sim --from-trace` close the
    /// record → fit → replay loop
    pub trace: TraceStore,
    /// the policy engine's final per-worker delay estimates (empty
    /// under the `static` policy) — the estimator state the last
    /// round's plan was derived from
    pub worker_estimates: Vec<WorkerEstimate>,
    pub final_theta: Vec<f64>,
    pub final_loss: f64,
    /// decode-weight cache counters for the run (`None` on uncoded
    /// wires) — stragglers recur, so the hit rate is the fraction of
    /// rounds that decoded without any Lagrange solve work
    pub decode_cache: Option<DecodeCacheStats>,
    /// per-frame master dwell-time percentiles (ready → processed)
    pub ingest: IngestReport,
    /// round critical-path phases, per-worker straggler attribution and
    /// wasted-work ledger ([`crate::telemetry::span`])
    pub spans: SpanSummary,
}

impl ClusterReport {
    pub fn mean_completion_ms(&self) -> f64 {
        let s: f64 = self.rounds.iter().map(|r| r.completion_ms).sum();
        s / self.rounds.len().max(1) as f64
    }

    pub fn mean_wire_bytes(&self) -> f64 {
        let s: usize = self.rounds.iter().map(|r| r.wire_bytes).sum();
        s as f64 / self.rounds.len().max(1) as f64
    }
}

/// Which coded construction the master encodes/decodes with.
enum Coded {
    Pc(PcScheme),
    Pcmm(PcmmScheme),
}

/// Reused per-frame decode scratch: a `Result`'s task range and
/// aggregated block land here instead of fresh vectors — the
/// allocation-free half of the zero-copy ingest path.
#[derive(Default)]
struct ResultScratch {
    tasks: Vec<usize>,
    h64: Vec<f64>,
}

/// Header of one received `Result` frame (arrays live in the scratch).
struct ResultMeta {
    round: u32,
    version: u32,
    worker_id: u32,
    comp_us: u64,
    /// worker-clock stamp: first task of the flush began computing —
    /// the `t1` of the NTP-style exchange (`comp_end_us` also rides
    /// the wire but the phase decomposition derives compute from
    /// `comp_us`, so it is not carried past the parse)
    comp_start_us: u64,
    /// worker-clock stamp: flush handed to the delivery path
    enqueue_us: u64,
    /// worker-clock stamp: delivery thread started writing the frame
    send_ts_us: u64,
    /// wire size (length prefix + payload)
    frame_len: usize,
    /// µs the frame became ready at the master — arrival of its last
    /// byte (reactor) or the channel hand-off (threads)
    recv_us: u64,
    /// µs the frame waited between ready and the loop processing it
    dwell_us: u64,
}

/// The master's socket I/O behind one interface, so both round loops
/// are word-for-word identical across [`IoMode`]s — which is what makes
/// the reactor bit-identity cross-check meaningful.
enum DataPlane {
    Threads {
        streams: Vec<TcpStream>,
        rx: mpsc::Receiver<(Msg, usize, u64)>,
        pool: FramePool,
    },
    Reactor(Reactor),
}

impl DataPlane {
    /// Wrap the handshaken streams.  `Threads` spawns the per-worker
    /// blocking readers here (workers stay silent until their first
    /// `Assign`, so post-LoadData spawn loses nothing); `Reactor`
    /// flips the sockets non-blocking.
    fn new(io: IoMode, streams: Vec<TcpStream>) -> Result<Self> {
        match io {
            IoMode::Threads => {
                let (tx, rx) = mpsc::channel::<(Msg, usize, u64)>();
                for (id, stream) in streams.iter().enumerate() {
                    let mut rd = stream.try_clone()?;
                    let tx = tx.clone();
                    std::thread::Builder::new()
                        .name(format!("master-recv{id}"))
                        .spawn(move || loop {
                            match Msg::read_frame(&mut rd) {
                                Ok((msg, len)) => {
                                    // stamp the hand-off: dwell = how
                                    // long the frame waits in the
                                    // channel before the loop takes it
                                    if tx.send((msg, len, now_us())).is_err() {
                                        return;
                                    }
                                }
                                Err(_) => return,
                            }
                        })?;
                }
                Ok(DataPlane::Threads {
                    streams,
                    rx,
                    pool: FramePool::new(),
                })
            }
            IoMode::Reactor => Ok(DataPlane::Reactor(Reactor::new(streams)?)),
        }
    }

    /// A cleared, pooled buffer to encode the next outbound frame into.
    fn take_buf(&mut self) -> Vec<u8> {
        match self {
            DataPlane::Threads { pool, .. } => pool.get(),
            DataPlane::Reactor(r) => r.take_send_buf(),
        }
    }

    /// Send one framed message to one worker.
    fn send_frame(&mut self, id: usize, frame: Vec<u8>) -> Result<()> {
        match self {
            DataPlane::Threads { streams, pool, .. } => {
                let mut w = &streams[id];
                w.write_all(&frame)?;
                w.flush()?;
                pool.put(frame);
                Ok(())
            }
            DataPlane::Reactor(r) => {
                r.send_frame(id, frame);
                Ok(())
            }
        }
    }

    /// Send one framed message to every worker (Assign/Stop fan-out);
    /// the reactor shares a single buffer across all write queues.
    fn broadcast_frame(&mut self, frame: Vec<u8>) -> Result<()> {
        match self {
            DataPlane::Threads { streams, pool, .. } => {
                for stream in streams.iter() {
                    let mut w = stream;
                    w.write_all(&frame)?;
                    w.flush()?;
                }
                pool.put(frame);
                Ok(())
            }
            DataPlane::Reactor(r) => {
                r.broadcast_frame(frame);
                Ok(())
            }
        }
    }

    /// Next `Result` frame into `scratch`: `Ok(Some)` on a Result,
    /// `Ok(None)` on any other frame (the caller's loop just
    /// continues), `Err` on timeout (with `timeout_ctx`) or a dead
    /// fleet.  Every frame's dwell time is pushed into `ingest`.
    fn recv_result(
        &mut self,
        timeout: Duration,
        timeout_ctx: &'static str,
        scratch: &mut ResultScratch,
        ingest: &mut IngestStats,
        srv: Option<&mut MetricsServer>,
    ) -> Result<Option<ResultMeta>> {
        match self {
            DataPlane::Threads { rx, .. } => {
                // with a scrape listener live, slice the blocking wait
                // into short chunks and pump the listener between them —
                // frame order stays the channel's FIFO either way
                let (msg, frame_len, ready_us) = match srv {
                    None => rx.recv_timeout(timeout).context(timeout_ctx)?,
                    Some(srv) => {
                        let deadline = std::time::Instant::now() + timeout;
                        loop {
                            srv.pump(0);
                            let left =
                                deadline.saturating_duration_since(std::time::Instant::now());
                            anyhow::ensure!(!left.is_zero(), "{timeout_ctx}");
                            match rx.recv_timeout(left.min(Duration::from_millis(50))) {
                                Ok(v) => break v,
                                Err(mpsc::RecvTimeoutError::Timeout) => continue,
                                Err(e) => return Err(e).context(timeout_ctx),
                            }
                        }
                    }
                };
                let dwell = now_us().saturating_sub(ready_us);
                ingest.push(dwell);
                tm::MASTER_DWELL_US.record(dwell as f64);
                tm::MASTER_FRAMES_TOTAL.inc();
                let Msg::Result {
                    round,
                    version,
                    worker_id,
                    tasks,
                    comp_us,
                    comp_start_us,
                    enqueue_us,
                    send_ts_us,
                    h,
                    ..
                } = msg
                else {
                    return Ok(None);
                };
                scratch.tasks.clear();
                scratch.tasks.extend(tasks.iter().map(|&t| t as usize));
                scratch.h64.clear();
                scratch.h64.extend(h.iter().map(|&v| v as f64));
                Ok(Some(ResultMeta {
                    round,
                    version,
                    worker_id,
                    comp_us,
                    comp_start_us,
                    enqueue_us,
                    send_ts_us,
                    frame_len,
                    recv_us: now_us(),
                    dwell_us: dwell,
                }))
            }
            DataPlane::Reactor(r) => {
                let hook = srv.map(|s| s as &mut dyn PollHook);
                let Some((_, frame)) = r.poll_frame_hooked(timeout, hook)? else {
                    bail!("{timeout_ctx}");
                };
                let dwell = now_us().saturating_sub(frame.recv_us);
                ingest.push(dwell);
                tm::MASTER_DWELL_US.record(dwell as f64);
                tm::MASTER_FRAMES_TOTAL.inc();
                match parse_frame(frame.payload)? {
                    FrameView::Result(res) => {
                        res.read_tasks_into(&mut scratch.tasks);
                        res.read_h64_into(&mut scratch.h64);
                        Ok(Some(ResultMeta {
                            round: res.round,
                            version: res.version,
                            worker_id: res.worker_id,
                            comp_us: res.comp_us,
                            comp_start_us: res.comp_start_us,
                            enqueue_us: res.enqueue_us,
                            send_ts_us: res.send_ts_us,
                            frame_len: frame.wire_len,
                            recv_us: frame.recv_us,
                            dwell_us: dwell,
                        }))
                    }
                    FrameView::Other(_) => Ok(None),
                }
            }
        }
    }

    /// Best-effort teardown: Shutdown to every worker, flush, close.
    fn shutdown(&mut self) {
        let mut frame = self.take_buf();
        encode_msg_framed(&mut frame, &Msg::Shutdown);
        match self {
            DataPlane::Threads { streams, .. } => {
                for stream in streams.iter() {
                    let mut w = stream;
                    let _ = w.write_all(&frame);
                    let _ = w.flush();
                    let _ = stream.shutdown(std::net::Shutdown::Both);
                }
            }
            DataPlane::Reactor(r) => {
                r.broadcast_frame(frame);
                r.shutdown(Duration::from_secs(2));
            }
        }
    }
}

/// The v5 latency anatomy of one ingested `Result` frame, shared
/// word-for-word by both round loops: feed the frame's four-stamp
/// exchange to the worker's clock estimator, decompose the frame's
/// life into compute → worker-queue → network → master-dwell (worker
/// stamps mapped onto the master clock), attribute the phases per
/// worker, and run the anomaly watchdog over them.  Pure observation —
/// consumes no RNG, reorders nothing; θ-inertness is pinned by
/// `tests/reactor_parity.rs`.
///
/// Returns `(comp_ms, comm_ms, queue_ms)` — compute, *measured*
/// network (clock-mapped send → arrival), and worker-queue — for the
/// recorders, the trace tap and the policy estimator downstream.
fn observe_frame_anatomy(
    fr: &ResultMeta,
    issue_us: Option<u64>,
    round: usize,
    clocks: &mut [ClockSync],
    spans: &mut SpanRecorder,
    anomaly: &mut AnomalyDetector,
    flight: &Rc<RefCell<FlightRecorder>>,
) -> (f64, f64, f64) {
    let w = fr.worker_id as usize;
    // NTP-style exchange: Assign issue (master) → first compute start
    // (worker) → delivery send (worker) → frame arrival (master).  The
    // min-RTT filter inside ClockSync keeps only the tight pings, so
    // later flushes of a group (whose t1 − t0 span inflates apparent
    // RTT) are rejected automatically.
    if let Some(t0) = issue_us {
        if clocks[w].observe(t0, fr.comp_start_us, fr.send_ts_us, fr.recv_us) {
            tm::CLOCK_OFFSET_US.set(clocks[w].offset_us());
        }
    }
    let comp_ms = fr.comp_us as f64 / 1e3;
    // queue: flush enqueue → wire send, both worker-clock — a pure
    // duration, no offset mapping needed
    let queue_ms = fr.send_ts_us.saturating_sub(fr.enqueue_us) as f64 / 1e3;
    // network: worker send stamp mapped onto the master clock → frame
    // arrival at the master — the *measured* comm delay
    let send_at_master = clocks[w].map_to_master(fr.send_ts_us);
    let comm_ms = fr.recv_us.saturating_sub(send_at_master) as f64 / 1e3;
    let dwell_ms = fr.dwell_us as f64 / 1e3;
    spans.phases(w, comp_ms, queue_ms, comm_ms, dwell_ms);
    let mut fl = flight.borrow_mut();
    fl.record(
        fr.recv_us,
        "phase",
        round as i64,
        w as i64,
        [comp_ms, queue_ms, comm_ms, dwell_ms],
    );
    for (phase, ms) in Phase::ALL.into_iter().zip([comp_ms, queue_ms, comm_ms, dwell_ms]) {
        if let Some(a) = anomaly.observe(w, phase, ms) {
            tm::ANOMALY_TOTAL.inc();
            // the automatic flight dump: the anomaly lands in the ring
            // next to the phase events that caused it, ready for
            // `/debug/flight`
            fl.record(
                fr.recv_us,
                "anomaly",
                round as i64,
                w as i64,
                [a.phase as usize as f64, a.observed_ms, a.fleet_median_ms, anomaly.factor()],
            );
            eprintln!(
                "telemetry: worker {w} {} phase anomalous at round {round}: \
                 {:.3} ms vs fleet median {:.3} ms (factor {})",
                a.phase.name(),
                a.observed_ms,
                a.fleet_median_ms,
                anomaly.factor()
            );
        }
    }
    (comp_ms, comm_ms, queue_ms)
}

/// Run a full cluster experiment: spawns `n` in-process workers over
/// localhost TCP, executes `rounds` DGD rounds, returns the report.
pub fn run_cluster(cfg: ClusterConfig) -> Result<ClusterReport> {
    let ClusterConfig {
        n,
        r,
        k,
        eta,
        rounds,
        profile,
        plan,
        policy,
        staleness,
        dataset,
        inject,
        seed,
        use_pjrt,
        artifact_dir,
        loss_every,
        listen,
        spawn_workers,
        io,
        metrics,
    } = cfg;
    let ClusterPlan {
        scheduler,
        group,
        groups,
        rule,
        wire,
    } = plan;
    anyhow::ensure!(dataset.n == n, "dataset partitions must equal n");
    anyhow::ensure!(k >= 1 && k <= n, "need 1 ≤ k ≤ n");
    anyhow::ensure!(r >= 1 && r <= n, "need 1 ≤ r ≤ n");
    anyhow::ensure!(group >= 1 && group <= r, "need 1 ≤ group ≤ r");
    // per-worker flush sizes (GCH / the `load` policy): every cadence
    // must divide the canonical block so each worker's aligned ranges
    // nest inside one block of the master's duplicate-safe merge
    let base_sizes: Vec<usize> = groups.unwrap_or_else(|| vec![group; n]);
    anyhow::ensure!(base_sizes.len() == n, "need one flush size per worker");
    anyhow::ensure!(
        base_sizes.iter().all(|&s| s >= 1 && group % s == 0),
        "per-worker flush sizes must divide the canonical block {group}: {base_sizes:?}"
    );
    anyhow::ensure!(
        (1..=MAX_STALENESS).contains(&staleness),
        "need 1 ≤ staleness ≤ {MAX_STALENESS} (got {staleness})"
    );
    if staleness > 1 {
        // the pipeline applies per-range partial sums out of round
        // order; coded decodes and Messages timing rounds are
        // whole-round constructs with no duplicate-safe merge to lean
        // on, so they stay synchronous
        anyhow::ensure!(
            matches!(wire, WirePlan::Uncoded { .. }) && rule == CompletionRule::DistinctTasks,
            "staleness {staleness} pipelines the uncoded k-distinct data plane only"
        );
    }
    if policy != PolicyKind::Static {
        anyhow::ensure!(
            matches!(wire, WirePlan::Uncoded { .. }),
            "policy {policy} drives the uncoded data plane only"
        );
        anyhow::ensure!(
            !scheduler.is_randomized(),
            "policy {policy} has nothing fixed to re-plan over a randomized scheduler"
        );
        if policy == PolicyKind::AllocGroup {
            anyhow::ensure!(
                GroupAllocation::applicable(n, r),
                "alloc-group needs r | n (got n = {n}, r = {r})"
            );
        }
        if policy == PolicyKind::AllocRandom {
            anyhow::ensure!(
                r == n,
                "alloc-random needs r = n (random batches may leave the \
                 k-distinct target uncoverable otherwise)"
            );
        }
    }
    let mut engine = (policy != PolicyKind::Static)
        .then(|| PolicyEngine::new(policy, n, r, group));
    if let CompletionRule::Messages { threshold } = rule {
        // aligned flushing can split a worker's row into up to two
        // extra frames (misaligned head block + the mod-n wrap break)
        // beyond the ⌈r/sᵢ⌉ of plain grouped flushing
        let extra = match wire {
            WirePlan::Uncoded { align: true } => 2,
            _ => 0,
        };
        let max_messages: usize = base_sizes.iter().map(|&s| r.div_ceil(s) + extra).sum();
        anyhow::ensure!(
            threshold >= 1 && threshold <= max_messages,
            "message threshold {threshold} unreachable: at most {max_messages} messages/round"
        );
    }
    let coded = match wire {
        WirePlan::Uncoded { align } => {
            // alignment is what keeps every flushed range inside one
            // canonical block, which both the duplicate-safe θ merge
            // (DistinctTasks) and the message accounting of timing
            // rounds (Messages) rely on — unaligned multi-task ranges
            // would be dropped as out-of-plan and stall the round
            anyhow::ensure!(
                align || base_sizes.iter().all(|&s| s == 1),
                "grouped uncoded flushes must be aligned \
                 (WirePlan::Uncoded {{ align: true }}) for duplicate-safe \
                 range aggregation"
            );
            None
        }
        WirePlan::Pc => {
            let pc = PcScheme::new(n, r);
            let want = CompletionRule::Messages {
                threshold: pc.recovery_threshold(),
            };
            anyhow::ensure!(
                rule == want && group == r && base_sizes.iter().all(|&s| s == r),
                "PC wire needs group = r and the Messages rule at its recovery threshold"
            );
            Some(Coded::Pc(pc))
        }
        WirePlan::Pcmm => {
            let pcmm = PcmmScheme::new(n, r);
            let want = CompletionRule::Messages {
                threshold: pcmm.recovery_threshold(),
            };
            anyhow::ensure!(
                rule == want && group == 1 && base_sizes.iter().all(|&s| s == 1),
                "PCMM wire needs group = 1 and the Messages rule at its recovery threshold"
            );
            Some(Coded::Pcmm(pcmm))
        }
    };
    let align = matches!(wire, WirePlan::Uncoded { align: true });

    let listener = match &listen {
        Some(addr) => TcpListener::bind(addr.as_str())
            .with_context(|| format!("bind master listener on {addr}"))?,
        None => TcpListener::bind("127.0.0.1:0").context("bind master listener")?,
    };
    let addr = listener.local_addr()?;
    if !spawn_workers {
        println!("master listening on {addr}; waiting for {n} external workers …");
    }

    // ---- spawn in-process workers (unless external mode) -------------------
    let mut worker_joins = Vec::with_capacity(n);
    for w in 0..if spawn_workers { n } else { 0 } {
        let injected = inject.as_ref().map(|kind| {
            TaskDelaySampler::new(kind.build(n), n, w, seed ^ 0xD37A_u64 ^ (w as u64) << 17)
        });
        let opts = super::worker::WorkerOptions {
            backend: if use_pjrt {
                super::worker::Backend::Pjrt
            } else {
                super::worker::Backend::CpuOracle
            },
            injected,
            artifact_dir: artifact_dir.clone(),
        };
        worker_joins.push(
            std::thread::Builder::new()
                .name(format!("worker{w}"))
                .spawn(move || super::worker::run_worker(addr, opts))?,
        );
    }

    // ---- accept + handshake ------------------------------------------------
    // sockets stay blocking through handshake + data distribution; the
    // chosen data plane (reactor or reader threads) takes over after.
    // The Welcome→Hello exchange doubles as the clock-sync seed ping
    // (v5): the worker's Hello stamp lies between the master's write
    // and read stamps, so every worker clock has a bounded-error
    // mapping before any round traffic flows.
    let mut streams: Vec<TcpStream> = Vec::with_capacity(n);
    let mut clocks: Vec<ClockSync> = vec![ClockSync::new(); n];
    for id in 0..n {
        let (stream, _) = listener.accept().context("accepting worker")?;
        stream.set_nodelay(true)?;
        let t0_us = now_us();
        Msg::Welcome {
            proto: super::protocol::PROTO_VERSION,
            worker_id: id as u32,
            profile: profile.clone(),
        }
        .write_to(&mut &stream)?;
        let (hello, _) = Msg::read_frame(&mut &stream)
            .with_context(|| format!("reading Hello from worker {id}"))?;
        let t3_us = now_us();
        match hello {
            Msg::Hello { worker_id, ts_us } => {
                anyhow::ensure!(
                    worker_id as usize == id,
                    "worker {id} answered the handshake as worker {worker_id}"
                );
                clocks[id].seed_handshake(t0_us, ts_us, t3_us);
            }
            other => bail!("expected Hello from worker {id}, got {other:?}"),
        }
        streams.push(stream);
    }

    // ---- data distribution --------------------------------------------------
    // uncoded, fixed schedulers: ship only the batches in the worker's
    // TO row; randomized (RA) and row-reassigning policies (order /
    // alloc-*): ship everything, since next round's assignment is
    // unknown at load time; coded: encode each worker's matrices here
    // (the worker grams them obliviously — coding is invisible below
    // the master).  `load` keeps assignments fixed and ships rows only.
    let mut rng_sched = Rng::seed_from_u64(seed ^ 0x5C4ED);
    let ship_all = scheduler.is_randomized() || policy.reassigns_rows();
    let fixed_to = if coded.is_none() && !scheduler.is_randomized() {
        Some(scheduler.schedule(n, r, &mut rng_sched))
    } else {
        None
    };
    for (id, stream) in streams.iter().enumerate() {
        let batches: Vec<(u32, Vec<f32>)> = match &coded {
            Some(Coded::Pc(pc)) => pc
                .encode_coeffs(id)
                .iter()
                .enumerate()
                .map(|(j, row)| {
                    (
                        (id * r + j) as u32,
                        Mat::linear_combination(row, &dataset.parts).to_f32(),
                    )
                })
                .collect(),
            Some(Coded::Pcmm(pcmm)) => (0..r)
                .map(|j| {
                    (
                        (id * r + j) as u32,
                        Mat::linear_combination(&pcmm.encode_coeffs(id, j), &dataset.parts)
                            .to_f32(),
                    )
                })
                .collect(),
            None => match &fixed_to {
                Some(to) if !ship_all => to
                    .row(id)
                    .iter()
                    .map(|&b| (b as u32, dataset.parts[b].to_f32()))
                    .collect(),
                _ => (0..n).map(|b| (b as u32, dataset.parts[b].to_f32())).collect(),
            },
        };
        Msg::LoadData {
            d: dataset.d as u32,
            b: dataset.b as u32,
            batches,
        }
        .write_to(&mut &*stream)?;
    }

    // data distributed — hand the sockets to the configured data plane
    let mut plane = DataPlane::new(io, streams)?;

    // ---- telemetry -----------------------------------------------------------
    // the scrape listener shares the data plane's poll loop (reactor) or
    // is pumped between chunked channel waits (threads); the JSONL log
    // gets one registry snapshot per applied round
    // the flight recorder rides an Rc between the round loops and the
    // scrape listener (both live on this thread); the anomaly watchdog
    // feeds it and `straggler_anomaly_total`
    let flight = Rc::new(RefCell::new(FlightRecorder::new(metrics.flight_depth)));
    let mut anomaly = AnomalyDetector::new(n, metrics.anomaly_factor);
    let mut srv = match metrics.addr.as_deref() {
        Some(addr) => {
            let mut s = MetricsServer::bind(addr)?;
            s.set_flight(flight.clone());
            println!("telemetry: serving /metrics on http://{}", s.addr());
            Some(s)
        }
        None => None,
    };
    let mut mlog = metrics.log.as_deref().map(MetricsLog::create).transpose()?;
    let mut msnap = Snapshot::default();
    let mut spans = SpanRecorder::new(n, staleness);
    // Ctrl-C lands between rounds: the latch is polled at each round
    // loop's top, so an interrupted run still tears down gracefully —
    // workers get Shutdown frames and the metrics log its final
    // fsynced snapshot
    signal::install_sigint_latch();

    // ---- round loop ----------------------------------------------------------
    let mut master = UncodedMaster::new(&dataset, eta, k);
    // coded decode target: Xᵀy = Σ_i X_i y_i, precomputed once (eq. 49)
    let xty_total: Option<Vec<f64>> = coded.as_ref().map(|_| {
        let mut total = vec![0.0; dataset.d];
        for xy in &master.xy {
            vec_axpy(&mut total, 1.0, xy);
        }
        total
    });
    let mut rng = Rng::seed_from_u64(seed);
    let mut recorders = vec![DelayRecorder::default(); n];
    // the trace tap: one event per received Result frame.  The
    // registry id is not in scope here — the plan is — so the scheme
    // label is reconstructed from the wire + flush layout
    let trace_label = match wire {
        WirePlan::Pc => "PC".to_string(),
        WirePlan::Pcmm => "PCMM".to_string(),
        WirePlan::Uncoded { .. } => {
            if base_sizes.iter().any(|&s| s != group) {
                format!("GCH/g{group}")
            } else if group > 1 {
                format!("GC({group})")
            } else {
                scheduler.name().to_string()
            }
        }
    };
    let mut trace_rec = TraceRecorder::with_fleet(trace_label, n);
    let mut trace_msgs = vec![0usize; n];
    let mut logs = Vec::with_capacity(rounds);
    let d = dataset.d;
    // per-run hot-path state, persistent across rounds: the uncoded
    // aggregator keeps its slot arena warm (`reset` per round), the
    // coded wires keep an LRU of per-subset decode weights.  The
    // pipelined pump (S ≥ 2) carries its own S-slot ring instead.
    let mut agg = if coded.is_none() && staleness == 1 {
        Some(RoundAggregator::new(n, d, group, k))
    } else {
        None
    };
    let mut decode_cache = coded.as_ref().map(|_| DecodeCache::with_default_cap());
    // reused per-frame/per-fanout scratch (both loops): the steady-state
    // ingest and Assign paths allocate nothing once these are warm
    let mut scratch = ResultScratch::default();
    let mut ingest = IngestStats::new();
    let mut theta32: Vec<f32> = Vec::new();
    let mut tasks_u32: Vec<u32> = Vec::new();

    // ---- bounded-staleness pump (S ≥ 2) ------------------------------------
    // Up to S rounds in flight: round t's Assign goes out the moment
    // round t − S applies, carrying the θ-version tag `base` (= applied
    // rounds, so round − version ≤ S − 1 always).  Frames route through
    // the AggregatorRing and θ advances strictly in round order — a
    // straggler delays its own round's application, never the fleet's
    // assignment pipeline.  Stop is also in order: the worker's stop
    // watermark censors every round ≤ the stopped one, so Stop{t} only
    // goes out when t applies; younger complete-but-unapplied rounds
    // keep draining harmlessly into their ring slots.
    if staleness > 1 {
        let mut ring = AggregatorRing::new(n, d, group, k, staleness);
        // per-round in-flight bookkeeping, indexed `round % S`
        struct InFlight {
            t0_us: u64,
            /// master-clock stamp taken *before* the round's Assign
            /// fan-out — the `t0` of every clock-sync exchange this
            /// round's Result frames complete (t0_us above stays where
            /// it always was, after the fan-out, so completion_ms is
            /// untouched by the v5 extension)
            issue_us: u64,
            results_seen: usize,
            messages_seen: usize,
            wire_bytes: usize,
            replanned: bool,
        }
        let mut meta: Vec<Option<InFlight>> = (0..staleness).map(|_| None).collect();
        // trace bookkeeping must survive a round's retirement (stale
        // frames are still real fleet measurements): flush indices are
        // keyed by (round, worker), replanned flags by round
        let mut flush_idx: HashMap<(usize, usize), usize> = HashMap::new();
        let mut replanned_by_round = vec![false; rounds];
        let mut issued = 0usize;
        while logs.len() < rounds {
            if signal::interrupted() {
                eprintln!(
                    "master: interrupted at {} applied rounds — shutting down gracefully",
                    logs.len()
                );
                break;
            }
            // top up the issue window
            while issued < rounds && issued < ring.base_round() + staleness {
                let round = issued;
                let decision = engine.as_mut().map(|e| {
                    let before = e.replans();
                    let plan = e.plan(round, &mut rng_sched);
                    (plan, e.replans() != before)
                });
                let replanned = decision.as_ref().is_some_and(|(_, changed)| *changed);
                replanned_by_round[round] = replanned;
                let sizes: &[usize] = decision
                    .as_ref()
                    .map_or(&base_sizes, |(plan, _)| &plan.sizes);
                // uncoded wire only (validated above), so a TO matrix
                // always exists — same sources as the synchronous loop
                let to = match &decision {
                    Some((plan, _)) => {
                        plan.materialize(fixed_to.as_ref().expect("policy base plan"))
                    }
                    None => match &fixed_to {
                        Some(to) => to.clone(),
                        None => scheduler.schedule(n, r, &mut rng_sched),
                    },
                };
                theta32.clear();
                theta32.extend(master.theta.iter().map(|&v| v as f32));
                let version = ring.base_round() as u32;
                let issue_us = now_us();
                for id in 0..n {
                    tasks_u32.clear();
                    tasks_u32.extend(to.row(id).iter().map(|&t| t as u32));
                    let mut buf = plane.take_buf();
                    encode_assign_into(
                        &mut buf,
                        round as u32,
                        version,
                        &theta32,
                        &tasks_u32,
                        sizes[id] as u32,
                        issue_us,
                        align && sizes[id] > 1,
                    );
                    plane.send_frame(id, buf)?;
                }
                let t0_us = now_us();
                spans.begin(round, t0_us);
                meta[round % staleness] = Some(InFlight {
                    t0_us,
                    issue_us,
                    results_seen: 0,
                    messages_seen: 0,
                    wire_bytes: 0,
                    replanned,
                });
                issued += 1;
            }
            tm::RING_ROUNDS_IN_FLIGHT.set((issued - ring.base_round()) as f64);

            // one frame off the data plane
            let Some(fr) = plane.recv_result(
                Duration::from_secs(60),
                "master timed out waiting for results (pipelined pump)",
                &mut scratch,
                &mut ingest,
                srv.as_mut(),
            )?
            else {
                continue;
            };
            let worker_id = fr.worker_id;
            let rr = fr.round as usize;
            if scratch.h64.len() != d
                || scratch.tasks.is_empty()
                || worker_id as usize >= n
                || rr >= rounds
            {
                tm::MASTER_FRAMES_MALFORMED_TOTAL.inc();
                eprintln!(
                    "master: dropping malformed result from worker {worker_id} \
                     ({} tasks, {} h values, d = {d}, round {rr})",
                    scratch.tasks.len(),
                    scratch.h64.len()
                );
                continue;
            }
            let distinct_before = ring.distinct(rr);
            let in_window = match ring.offer(rr, &scratch.tasks, &scratch.h64) {
                RingOffer::Future => {
                    spans.wasted_future();
                    eprintln!(
                        "master: dropping result for unissued round {rr} from \
                         worker {worker_id}"
                    );
                    continue;
                }
                RingOffer::InFlight(Offer::Malformed) => {
                    tm::MASTER_FRAMES_MALFORMED_TOTAL.inc();
                    eprintln!(
                        "master: dropping out-of-plan range {:?} from \
                         worker {worker_id}",
                        scratch.tasks
                    );
                    continue;
                }
                RingOffer::InFlight(verdict) => {
                    match verdict {
                        Offer::Duplicate => spans.wasted_duplicate(scratch.tasks.len() as u64),
                        Offer::Stranded => spans.wasted_stranded(scratch.tasks.len() as u64),
                        Offer::Accepted { .. } | Offer::Malformed => {}
                    }
                    true
                }
                // a straggler's flush from an already-applied round:
                // useless to θ (the ring dropped it whole), but a real
                // measurement — it still feeds the recorders, the trace
                // and the estimator below
                RingOffer::Stale => {
                    spans.wasted_stale();
                    false
                }
            };
            spans.frame(rr, worker_id as usize, fr.recv_us);
            if in_window {
                // the frame that pushes its round across the k-distinct
                // target is the critical-path delivery; frames landing
                // after the crossing (round complete, not yet applied)
                // are wasted work
                match (distinct_before, ring.distinct(rr)) {
                    (Some(b), Some(a)) if b < k && a >= k => {
                        spans.complete(rr, Some(worker_id as usize), fr.recv_us);
                    }
                    (Some(b), _) if b >= k => spans.wasted_post_completion(),
                    _ => {}
                }
            }
            // a stale round's InFlight slot already belongs to a newer
            // round (or is gone) — its frames still yield phases, but
            // without an issue stamp they feed no clock exchange
            let issue_us = if in_window {
                meta[rr % staleness].as_ref().map(|m| m.issue_us)
            } else {
                None
            };
            let (comp_ms, comm_ms, queue_ms) = observe_frame_anatomy(
                &fr,
                issue_us,
                rr,
                &mut clocks,
                &mut spans,
                &mut anomaly,
                &flight,
            );
            recorders[worker_id as usize].record_comp(comp_ms);
            recorders[worker_id as usize].record_comm(comm_ms);
            let slot = flush_idx.entry((rr, worker_id as usize)).or_insert(0);
            let msg_idx = *slot;
            *slot += 1;
            trace_rec.push_flush(
                rr,
                worker_id as usize,
                msg_idx,
                scratch.tasks.len(),
                comp_ms,
                comm_ms,
                queue_ms,
                fr.frame_len,
                replanned_by_round[rr],
                fr.version, // the worker's echo of its Assign's θ-version
            );
            if let Some(e) = engine.as_mut() {
                e.observe_flush(worker_id as usize, scratch.tasks.len(), comp_ms, comm_ms);
            }
            if in_window {
                if let Some(m) = meta[rr % staleness].as_mut() {
                    m.messages_seen += 1;
                    m.results_seen += scratch.tasks.len();
                    m.wire_bytes += fr.frame_len;
                }
            }

            // apply every round this frame completed, strictly in order
            while ring.oldest_complete() {
                let applied = ring.base_round();
                let mut buf = plane.take_buf();
                encode_msg_framed(
                    &mut buf,
                    &Msg::Stop {
                        round: applied as u32,
                    },
                );
                plane.broadcast_frame(buf)?;
                let winners: Vec<usize> = {
                    let (winners, h_sum) = ring.finish_oldest();
                    master.apply_aggregate(
                        winners,
                        h_sum,
                        n,
                        dataset.padded_samples(),
                        &mut rng,
                    );
                    winners.to_vec()
                };
                let apply_us = now_us();
                spans.apply(applied, apply_us);
                let m = meta[applied % staleness].take().expect("in-flight meta");
                let loss = if loss_every > 0 && (applied + 1) % loss_every == 0 {
                    Some(dataset.loss(&master.theta))
                } else {
                    None
                };
                logs.push(RoundLog {
                    round: applied,
                    // from issue to θ-application — for non-oldest
                    // rounds this includes the in-order head-of-line
                    // wait, which is the honest pipeline latency
                    completion_ms: (apply_us - m.t0_us) as f64 / 1e3,
                    winners,
                    results_seen: m.results_seen,
                    messages_seen: m.messages_seen,
                    wire_bytes: m.wire_bytes,
                    replanned: m.replanned,
                    loss,
                });
                ring.advance();
                tm::RING_ROUNDS_IN_FLIGHT.set((issued - ring.base_round()) as f64);
                if let Some(ml) = mlog.as_mut() {
                    snapshot_into(&mut msnap);
                    ml.append(&msnap, apply_us)?;
                }
            }
        }
    }

    // S = 1: the synchronous §II loop, bit-identical to the
    // pre-pipelining master (the pump above fills `logs` otherwise)
    let sync_rounds = if staleness > 1 { 0 } else { rounds };
    for round in 0..sync_rounds {
        if signal::interrupted() {
            eprintln!(
                "master: interrupted at {round} applied rounds — shutting down gracefully"
            );
            break;
        }
        // ---- the policy's round-boundary re-plan ---------------------------
        // protocol stays v3: assignment was always per-round; only the
        // plan's *source* changes (frozen vs engine-emitted)
        let decision = engine.as_mut().map(|e| {
            let before = e.replans();
            let plan = e.plan(round, &mut rng_sched);
            (plan, e.replans() != before)
        });
        let replanned = decision.as_ref().is_some_and(|(_, changed)| *changed);
        let sizes: &[usize] = decision
            .as_ref()
            .map_or(&base_sizes, |(plan, _)| &plan.sizes);
        let to = if coded.is_none() {
            Some(match &decision {
                // allocation override, or order/load permuting the
                // fixed base plan's rows — one shared materialization
                Some((plan, _)) => {
                    plan.materialize(fixed_to.as_ref().expect("policy base plan"))
                }
                None => match &fixed_to {
                    Some(to) => to.clone(),
                    None => scheduler.schedule(n, r, &mut rng_sched),
                },
            })
        } else {
            None
        };
        theta32.clear();
        theta32.extend(master.theta.iter().map(|&v| v as f32));
        let round_tag = round as u32;
        let t0_us = now_us();
        spans.begin(round, t0_us);
        for id in 0..n {
            // uncoded: the worker's TO row (identity task↔batch map in
            // cluster mode — no Remark-3 reshuffle, it would force data
            // re-distribution); coded: the worker's fixed global slots
            tasks_u32.clear();
            match &to {
                Some(to) => tasks_u32.extend(to.row(id).iter().map(|&t| t as u32)),
                None => tasks_u32.extend((id * r..(id + 1) * r).map(|s| s as u32)),
            }
            let mut buf = plane.take_buf();
            encode_assign_into(
                &mut buf,
                round_tag,
                // synchronous: every prior round has applied, so the
                // θ-version (applied-round count) equals the round tag
                round_tag,
                &theta32,
                &tasks_u32,
                sizes[id] as u32,
                // t0_us is stamped before the fan-out, so it is the
                // exchange's t0 for every worker's first flush
                t0_us,
                align && sizes[id] > 1,
            );
            plane.send_frame(id, buf)?;
        }

        // collect until the completion rule fires: k distinct tasks
        // (uncoded, duplicate-safe range merge) or the threshold-th
        // evaluation (coded)
        if let Some(a) = agg.as_mut() {
            a.reset();
        }
        let mut responses: Vec<(usize, Vec<f64>)> = Vec::new();
        let mut seen_keys: HashSet<usize> = HashSet::new();
        trace_msgs.fill(0);
        let mut results_seen = 0usize;
        let mut messages_seen = 0usize;
        let mut wire_bytes = 0usize;
        let completion_ms;
        loop {
            let Some(fr) = plane.recv_result(
                Duration::from_secs(60),
                "master timed out waiting for results",
                &mut scratch,
                &mut ingest,
                srv.as_mut(),
            )?
            else {
                continue;
            };
            let worker_id = fr.worker_id;
            if fr.round != round_tag {
                spans.wasted_post_completion();
                continue; // stale result from a stopped round
            }
            // v3 invariant: one aggregated d-length block per message
            if scratch.h64.len() != d || scratch.tasks.is_empty() || worker_id as usize >= n {
                tm::MASTER_FRAMES_MALFORMED_TOTAL.inc();
                eprintln!(
                    "master: dropping malformed result from worker {worker_id} \
                     ({} tasks, {} h values, d = {d})",
                    scratch.tasks.len(),
                    scratch.h64.len()
                );
                continue;
            }
            let recv_us = fr.recv_us;
            let complete = match (&coded, agg.as_mut()) {
                (None, Some(agg)) => {
                    match agg.offer(&scratch.tasks, &scratch.h64) {
                        Offer::Malformed => {
                            tm::MASTER_FRAMES_MALFORMED_TOTAL.inc();
                            eprintln!(
                                "master: dropping out-of-plan range {:?} \
                                 from worker {worker_id}",
                                scratch.tasks
                            );
                            continue;
                        }
                        // duplicates and stranded overlaps still count
                        // as received traffic (results_seen includes
                        // duplicates, as in §II) — they just cannot
                        // reach θ
                        Offer::Accepted { .. } => {}
                        Offer::Duplicate => spans.wasted_duplicate(scratch.tasks.len() as u64),
                        Offer::Stranded => spans.wasted_stranded(scratch.tasks.len() as u64),
                    }
                    tm::AGGREGATOR_TASKS_DISTINCT.set(agg.distinct() as f64);
                    match rule {
                        CompletionRule::DistinctTasks => agg.complete(),
                        CompletionRule::Messages { threshold } => {
                            messages_seen + 1 == threshold
                        }
                    }
                }
                (Some(c), _) => {
                    let key = match c {
                        // PC: one flush per worker, keyed by worker
                        Coded::Pc(_) => {
                            if scratch.tasks.len() != r {
                                tm::MASTER_FRAMES_MALFORMED_TOTAL.inc();
                                eprintln!(
                                    "master: dropping partial PC flush from \
                                     worker {worker_id}"
                                );
                                continue;
                            }
                            worker_id as usize
                        }
                        // PCMM: one evaluation per message, keyed by
                        // the global slot id
                        Coded::Pcmm(_) => {
                            let slot = scratch.tasks[0];
                            if scratch.tasks.len() != 1 || slot / r != worker_id as usize {
                                tm::MASTER_FRAMES_MALFORMED_TOTAL.inc();
                                eprintln!(
                                    "master: dropping malformed PCMM evaluation \
                                     {:?} from worker {worker_id}",
                                    scratch.tasks
                                );
                                continue;
                            }
                            slot
                        }
                    };
                    // a duplicate evaluation adds nothing to the decode
                    // but is still received traffic — it must reach the
                    // messages/wire-bytes accounting below, like uncoded
                    // duplicates
                    if seen_keys.insert(key) {
                        responses.push((key, scratch.h64.clone()));
                    }
                    match rule {
                        CompletionRule::Messages { threshold } => {
                            responses.len() == threshold
                        }
                        CompletionRule::DistinctTasks => unreachable!("validated above"),
                    }
                }
                (None, None) => unreachable!("uncoded wire always has an aggregator"),
            };
            spans.frame(round, worker_id as usize, recv_us);
            messages_seen += 1;
            results_seen += scratch.tasks.len();
            wire_bytes += fr.frame_len;
            let (comp_ms, comm_ms, queue_ms) = observe_frame_anatomy(
                &fr,
                Some(t0_us),
                round,
                &mut clocks,
                &mut spans,
                &mut anomaly,
                &flight,
            );
            recorders[worker_id as usize].record_comp(comp_ms);
            recorders[worker_id as usize].record_comm(comm_ms);
            // duplicates and stranded overlaps are real fleet
            // measurements — the trace records every well-formed frame,
            // exactly what the recorders and the estimator see
            let msg_idx = trace_msgs[worker_id as usize];
            trace_msgs[worker_id as usize] += 1;
            trace_rec.push_flush(
                round,
                worker_id as usize,
                msg_idx,
                scratch.tasks.len(),
                comp_ms,
                comm_ms,
                queue_ms,
                fr.frame_len,
                replanned,
                round as u32, // sync: θ-version == round, gap 0
            );
            if let Some(e) = engine.as_mut() {
                // the estimator eats the same measurements RoundLog and
                // the recorders are built from — causal by construction
                // (these results precede the next round's plan)
                e.observe_flush(worker_id as usize, scratch.tasks.len(), comp_ms, comm_ms);
            }
            if complete {
                spans.complete(round, Some(worker_id as usize), recv_us);
                completion_ms = (recv_us - t0_us) as f64 / 1e3;
                break;
            }
        }

        // acknowledgement: stop all workers for this round (paper §II)
        let mut buf = plane.take_buf();
        encode_msg_framed(&mut buf, &Msg::Stop { round: round_tag });
        plane.broadcast_frame(buf)?;

        // ---- the scheme's master update ------------------------------------
        let winners: Vec<usize> = match &coded {
            None => {
                let (winners, h_sum) = agg.as_mut().expect("uncoded aggregator").finish();
                if rule == CompletionRule::DistinctTasks {
                    master.apply_aggregate(winners, h_sum, n, dataset.padded_samples(), &mut rng);
                }
                // an uncoded Messages rule (hand-built configs only) is
                // a pure timing round: θ stays frozen
                winners.to_vec()
            }
            Some(c) => {
                // decode input is key-shaped per construction; the
                // update and winner bookkeeping are shared
                let cache = decode_cache.as_mut().expect("coded decode cache");
                spans.decode_start(round, now_us());
                let xxt = match c {
                    Coded::Pc(pc) => {
                        pc.decode_cached(&responses[..pc.recovery_threshold()], cache)
                    }
                    Coded::Pcmm(pcmm) => {
                        let take = pcmm.recovery_threshold();
                        let pairs: Vec<((usize, usize), Vec<f64>)> = responses[..take]
                            .iter()
                            .map(|(key, v)| ((key / r, key % r), v.clone()))
                            .collect();
                        pcmm.decode_cached(&pairs, cache)
                    }
                };
                spans.decode_end(round, now_us());
                coded_update(
                    &mut master.theta,
                    &xxt,
                    xty_total.as_ref().expect("coded xty"),
                    eta,
                    dataset.padded_samples(),
                );
                responses.iter().map(|(key, _)| *key).collect()
            }
        };
        let apply_us = now_us();
        spans.apply(round, apply_us);
        let loss = if loss_every > 0 && (round + 1) % loss_every == 0 {
            Some(dataset.loss(&master.theta))
        } else {
            None
        };
        logs.push(RoundLog {
            round,
            completion_ms,
            winners,
            results_seen,
            messages_seen,
            wire_bytes,
            replanned,
            loss,
        });
        if let Some(ml) = mlog.as_mut() {
            snapshot_into(&mut msnap);
            ml.append(&msnap, apply_us)?;
        }
    }

    // ---- teardown -----------------------------------------------------------
    // fold run-scoped caches into the process-global registry, then give
    // the scrape listener one last service pass and the JSONL log a
    // final snapshot so end-of-run counters are observable
    if let Some(st) = decode_cache.as_ref().map(|c| c.stats()) {
        tm::DECODE_CACHE_HITS_TOTAL.add(st.hits);
        tm::DECODE_CACHE_MISSES_TOTAL.add(st.misses);
        tm::DECODE_CACHE_EVICTIONS_TOTAL.add(st.evictions);
    }
    if let DataPlane::Threads { pool, .. } = &plane {
        tm::MASTER_FRAME_POOL_BUFFERS.set(pool.pooled() as f64);
    }
    if let Some(s) = srv.as_mut() {
        s.pump(0);
    }
    // final snapshot + flush + fsync: whether the run finished or a
    // SIGINT broke the round loop, the JSONL log ends durable and
    // parseable at the last applied round
    if let Some(ml) = mlog.as_mut() {
        snapshot_into(&mut msnap);
        ml.finalize(&msnap, now_us())?;
    }
    plane.shutdown();
    for j in worker_joins {
        match j.join() {
            Ok(Ok(())) => {}
            Ok(Err(e)) => eprintln!("worker exited with error: {e:#}"),
            Err(_) => eprintln!("worker thread panicked"),
        }
    }

    let final_loss = dataset.loss(&master.theta);
    Ok(ClusterReport {
        rounds: logs,
        recorders,
        trace: trace_rec.into_store(),
        worker_estimates: engine
            .as_ref()
            .map(|e| e.estimator.estimates())
            .unwrap_or_default(),
        final_theta: master.theta,
        final_loss,
        decode_cache: decode_cache.as_ref().map(|c| c.stats()),
        ingest: ingest.report(),
        spans: spans.summary(),
    })
}

// `impl Write for &TcpStream` is used via `&mut &stream`; keep a local
// assertion that the pattern stays valid if the protocol changes.
#[allow(dead_code)]
fn _assert_stream_write(stream: &TcpStream) {
    let _ = (&mut &*stream).flush();
}
