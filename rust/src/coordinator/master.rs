//! Cluster master (leader): schedules, distributes, collects, stops,
//! updates — the paper's §II protocol over real sockets.

use std::collections::HashSet;
use std::io::Write as _;
use std::net::{TcpListener, TcpStream};
use std::sync::mpsc;
use std::time::Duration;

use anyhow::{Context, Result};

use super::protocol::Msg;
use super::{now_us, TaskDelaySampler};
use crate::data::Dataset;
use crate::delay::DelayModelKind;
use crate::gd::UncodedMaster;
use crate::metrics::DelayRecorder;
use crate::scheduler::Scheduler;
use crate::scheme::CompletionRule;
use crate::util::rng::Rng;

/// Cluster configuration.
pub struct ClusterConfig {
    pub n: usize,
    pub r: usize,
    pub k: usize,
    pub eta: f64,
    pub rounds: usize,
    /// artifact profile the workers execute (`task_gram` entry)
    pub profile: String,
    pub scheduler: Box<dyn Scheduler>,
    pub dataset: Dataset,
    /// injected straggling; `None` measures bare-metal delays
    pub inject: Option<DelayModelKind>,
    pub seed: u64,
    /// worker compute engine
    pub use_pjrt: bool,
    pub artifact_dir: Option<std::path::PathBuf>,
    /// record loss every this many rounds (loss is O(N·d))
    pub loss_every: usize,
    /// listen address; `None` binds an ephemeral localhost port
    pub listen: Option<String>,
    /// spawn the n workers in-process (false = wait for external
    /// `straggler worker --connect` processes — real multi-process mode)
    pub spawn_workers: bool,
    /// workers flush one result message per `group` completed tasks
    /// (1 = the paper's immediate streaming; `s` executes GC(s), `r`
    /// executes PC's one-message-per-worker — see
    /// [`crate::scheme::SchemeRegistry::cluster_plan`])
    pub group: usize,
    /// round-completion rule the master enforces.  `DistinctTasks`
    /// (uncoded §II: stop at `k` distinct results, apply the DGD
    /// update) or `Messages { threshold }` (coded order-statistic
    /// timing: stop at the threshold-th received message; θ is left
    /// untouched — the polynomial decode lives in [`crate::coded`])
    pub rule: CompletionRule,
}

/// Per-round record.
#[derive(Debug, Clone)]
pub struct RoundLog {
    pub round: usize,
    /// wall-clock ms from round start to completion (k-th distinct
    /// result, or the threshold-th message under a `Messages` rule)
    pub completion_ms: f64,
    /// the distinct tasks held at completion, in arrival order (`k` of
    /// them under `DistinctTasks`; possibly fewer under `Messages`)
    pub winners: Vec<usize>,
    /// total task results received (incl. duplicates)
    pub results_seen: usize,
    /// result messages received — `results_seen / group` up to the
    /// stop-ack tail; the GC(s) communication saving shows up here
    pub messages_seen: usize,
    pub loss: Option<f64>,
}

/// Whole-run report.
pub struct ClusterReport {
    pub rounds: Vec<RoundLog>,
    /// per-worker measured delays (ms) — feeds Fig. 3 + empirical replay
    pub recorders: Vec<DelayRecorder>,
    pub final_theta: Vec<f64>,
    pub final_loss: f64,
}

impl ClusterReport {
    pub fn mean_completion_ms(&self) -> f64 {
        let s: f64 = self.rounds.iter().map(|r| r.completion_ms).sum();
        s / self.rounds.len().max(1) as f64
    }
}

/// Run a full cluster experiment: spawns `n` in-process workers over
/// localhost TCP, executes `rounds` DGD rounds, returns the report.
pub fn run_cluster(cfg: ClusterConfig) -> Result<ClusterReport> {
    let ClusterConfig {
        n,
        r,
        k,
        eta,
        rounds,
        profile,
        scheduler,
        dataset,
        inject,
        seed,
        use_pjrt,
        artifact_dir,
        loss_every,
        listen,
        spawn_workers,
        group,
        rule,
    } = cfg;
    anyhow::ensure!(dataset.n == n, "dataset partitions must equal n");
    anyhow::ensure!(k >= 1 && k <= n, "need 1 ≤ k ≤ n");
    anyhow::ensure!(r >= 1 && r <= n, "need 1 ≤ r ≤ n");
    anyhow::ensure!(group >= 1 && group <= r, "need 1 ≤ group ≤ r");
    if let CompletionRule::Messages { threshold } = rule {
        let max_messages = n * r.div_ceil(group);
        anyhow::ensure!(
            threshold >= 1 && threshold <= max_messages,
            "message threshold {threshold} unreachable: at most {max_messages} messages/round"
        );
    }

    let listener = match &listen {
        Some(addr) => TcpListener::bind(addr.as_str())
            .with_context(|| format!("bind master listener on {addr}"))?,
        None => TcpListener::bind("127.0.0.1:0").context("bind master listener")?,
    };
    let addr = listener.local_addr()?;
    if !spawn_workers {
        println!("master listening on {addr}; waiting for {n} external workers …");
    }

    // ---- spawn in-process workers (unless external mode) -------------------
    let mut worker_joins = Vec::with_capacity(n);
    for w in 0..if spawn_workers { n } else { 0 } {
        let injected = inject.as_ref().map(|kind| {
            TaskDelaySampler::new(kind.build(n), n, w, seed ^ 0xD37A_u64 ^ (w as u64) << 17)
        });
        let opts = super::worker::WorkerOptions {
            backend: if use_pjrt {
                super::worker::Backend::Pjrt
            } else {
                super::worker::Backend::CpuOracle
            },
            injected,
            artifact_dir: artifact_dir.clone(),
        };
        worker_joins.push(
            std::thread::Builder::new()
                .name(format!("worker{w}"))
                .spawn(move || super::worker::run_worker(addr, opts))?,
        );
    }

    // ---- accept + handshake ------------------------------------------------
    let mut streams: Vec<TcpStream> = Vec::with_capacity(n);
    let (res_tx, res_rx) = mpsc::channel::<Msg>();
    for id in 0..n {
        let (stream, _) = listener.accept().context("accepting worker")?;
        stream.set_nodelay(true)?;
        Msg::Welcome {
            proto: super::protocol::PROTO_VERSION,
            worker_id: id as u32,
            profile: profile.clone(),
        }
        .write_to(&mut &stream)?;
        // receiver thread: forward Results to the master channel
        let mut rd = stream.try_clone()?;
        let tx = res_tx.clone();
        std::thread::Builder::new()
            .name(format!("master-recv{id}"))
            .spawn(move || loop {
                match Msg::read_from(&mut rd) {
                    Ok(msg) => {
                        if tx.send(msg).is_err() {
                            return;
                        }
                    }
                    Err(_) => return,
                }
            })?;
        streams.push(stream);
    }

    // ---- data distribution --------------------------------------------------
    // fixed schedulers: ship only the batches in the worker's TO row;
    // randomized (RA): ship everything.
    let mut rng_sched = Rng::seed_from_u64(seed ^ 0x5C4ED);
    let fixed_to = if scheduler.is_randomized() {
        None
    } else {
        Some(scheduler.schedule(n, r, &mut rng_sched))
    };
    for (id, stream) in streams.iter().enumerate() {
        let needed: Vec<usize> = match &fixed_to {
            Some(to) => to.row(id).to_vec(),
            None => (0..n).collect(),
        };
        let batches: Vec<(u32, Vec<f32>)> = needed
            .iter()
            .map(|&b| (b as u32, dataset.parts[b].to_f32()))
            .collect();
        Msg::LoadData {
            d: dataset.d as u32,
            b: dataset.b as u32,
            batches,
        }
        .write_to(&mut &*stream)?;
    }

    // ---- round loop ----------------------------------------------------------
    let mut master = UncodedMaster::new(&dataset, eta, k);
    let mut rng = Rng::seed_from_u64(seed);
    let mut recorders = vec![DelayRecorder::default(); n];
    let mut logs = Vec::with_capacity(rounds);

    for round in 0..rounds {
        let to = match &fixed_to {
            Some(to) => to.clone(),
            None => scheduler.schedule(n, r, &mut rng_sched),
        };
        let theta32: Vec<f32> = master.theta.iter().map(|&v| v as f32).collect();
        let round_tag = round as u32;
        let t0_us = now_us();
        for (id, stream) in streams.iter().enumerate() {
            let row = to.row(id);
            Msg::Assign {
                round: round_tag,
                theta: theta32.clone(),
                tasks: row.iter().map(|&t| t as u32).collect(),
                // identity mapping in cluster mode (no Remark-3
                // reshuffle — it would force data re-distribution)
                batches: row.iter().map(|&t| t as u32).collect(),
                group: group as u32,
            }
            .write_to(&mut &*stream)?;
        }

        // collect until the completion rule fires: k distinct task
        // results (uncoded), or the threshold-th message (coded timing)
        let mut seen = HashSet::with_capacity(k);
        let mut received: Vec<(usize, Vec<f64>)> = Vec::with_capacity(k);
        let mut results_seen = 0usize;
        let mut messages_seen = 0usize;
        let d = dataset.d;
        let completion_ms;
        loop {
            let msg = res_rx
                .recv_timeout(Duration::from_secs(60))
                .context("master timed out waiting for results")?;
            let Msg::Result {
                round: rr,
                worker_id,
                tasks,
                comp_us,
                send_ts_us,
                h,
            } = msg
            else {
                continue;
            };
            if rr != round_tag {
                continue; // stale result from a stopped round
            }
            if h.len() != tasks.len() * d {
                eprintln!(
                    "master: dropping malformed result from worker {worker_id} \
                     ({} tasks, {} h values, d = {d})",
                    tasks.len(),
                    h.len()
                );
                continue;
            }
            let recv_us = now_us();
            messages_seen += 1;
            results_seen += tasks.len();
            recorders[worker_id as usize].record_comp(comp_us as f64 / 1e3);
            recorders[worker_id as usize]
                .record_comm((recv_us.saturating_sub(send_ts_us)) as f64 / 1e3);
            let mut complete = false;
            for (i, &task) in tasks.iter().enumerate() {
                if seen.insert(task) {
                    received.push((
                        task as usize,
                        h[i * d..(i + 1) * d].iter().map(|&v| v as f64).collect(),
                    ));
                    if rule == CompletionRule::DistinctTasks && received.len() == k {
                        // remaining tasks of this message are beyond the
                        // target; the whole group arrived at recv time
                        complete = true;
                        break;
                    }
                }
            }
            if let CompletionRule::Messages { threshold } = rule {
                complete = messages_seen == threshold;
            }
            if complete {
                completion_ms = (recv_us - t0_us) as f64 / 1e3;
                break;
            }
        }

        // acknowledgement: stop all workers for this round (paper §II)
        for stream in &streams {
            Msg::Stop { round: round_tag }.write_to(&mut &*stream)?;
        }

        let winners: Vec<usize> = received.iter().map(|(t, _)| *t).collect();
        if rule == CompletionRule::DistinctTasks {
            master.apply_round(&received, n, dataset.padded_samples(), &mut rng);
        }
        // Messages-rule rounds are timing rounds: θ stays frozen (the
        // uncoded h blocks cannot stand in for a polynomial decode)
        let loss = if loss_every > 0 && (round + 1) % loss_every == 0 {
            Some(dataset.loss(&master.theta))
        } else {
            None
        };
        logs.push(RoundLog {
            round,
            completion_ms,
            winners,
            results_seen,
            messages_seen,
            loss,
        });
    }

    // ---- teardown -----------------------------------------------------------
    for stream in &streams {
        let _ = Msg::Shutdown.write_to(&mut &*stream);
        let _ = stream.shutdown(std::net::Shutdown::Both);
    }
    for j in worker_joins {
        match j.join() {
            Ok(Ok(())) => {}
            Ok(Err(e)) => eprintln!("worker exited with error: {e:#}"),
            Err(_) => eprintln!("worker thread panicked"),
        }
    }

    let final_loss = dataset.loss(&master.theta);
    Ok(ClusterReport {
        rounds: logs,
        recorders,
        final_theta: master.theta,
        final_loss,
    })
}

// `impl Write for &TcpStream` is used via `&mut &stream`; keep a local
// assertion that the pattern stays valid if the protocol changes.
#[allow(dead_code)]
fn _assert_stream_write(stream: &TcpStream) {
    let _ = (&mut &*stream).flush();
}
