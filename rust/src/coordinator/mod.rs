//! Master/worker cluster — the testbed that substitutes for the paper's
//! Amazon EC2 deployment (DESIGN.md §2).
//!
//! Real sockets (localhost TCP), real framed protocol, real compute
//! (PJRT on the AOT artifacts, or the f64 CPU oracle for tests), real
//! streaming semantics: workers compute their assigned tasks
//! *sequentially* and ship every result the moment it is ready; the
//! master stops the round by acknowledgement as soon as it holds `k`
//! distinct results (paper §II).  Communication delays are modeled by
//! delaying *delivery* (not the worker's next computation) so eq. (1)'s
//! overlap semantics hold: `t_{i,C(i,j)} = Σ_{m≤j} T⁽¹⁾ + T⁽²⁾_j`.
//!
//! Because the paper's t2.micro delays (ms-scale, comm ≫ comp) cannot
//! arise naturally between threads of one process, workers accept an
//! **injected delay sampler** driven by the same [`crate::delay`] models
//! the Monte-Carlo engine uses; with injection disabled you measure the
//! machine's true microsecond-scale delays instead (that mode feeds the
//! Fig.-3-style histograms).

pub mod aggregate;
pub mod framebuf;
pub mod master;
pub mod protocol;
pub mod reactor;
pub mod worker;

pub use aggregate::{AggregatorRing, Offer, RingOffer, RoundAggregator};
pub use master::{run_cluster, ClusterConfig, ClusterReport, IngestReport, IoMode, RoundLog};
pub use protocol::Msg;
pub use worker::{run_worker, Backend, WorkerOptions};

use std::sync::OnceLock;
use std::time::Instant;

use crate::delay::{DelayModel, DelaySample};
use crate::util::rng::Rng;

/// Shared process clock: µs since the first call.  Master and in-proc
/// workers share it, so one-way delays are directly measurable (the
/// paper's MPI testbed has the same property within an instance; across
/// instances it relies on EC2's clock sync — see DESIGN.md §2).
pub fn now_us() -> u64 {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    let epoch = EPOCH.get_or_init(Instant::now);
    epoch.elapsed().as_micros() as u64
}

/// Per-task delay sampler used by workers to inject straggling, adapted
/// from any round-level [`DelayModel`] (draws 1×1 rounds).
pub struct TaskDelaySampler {
    model: Box<dyn DelayModel>,
    rng: Rng,
    buf: DelaySample,
    /// which worker's marginal to draw (heterogeneous models)
    worker: usize,
    n_model: usize,
}

impl TaskDelaySampler {
    pub fn new(model: Box<dyn DelayModel>, n_model: usize, worker: usize, seed: u64) -> Self {
        Self {
            model,
            rng: Rng::seed_from_u64(seed ^ (worker as u64).wrapping_mul(0x9E37_79B9)),
            buf: DelaySample::zeros(n_model, 1),
            worker,
            n_model,
        }
    }

    /// Draw `(comp_ms, comm_ms)` for one task at this worker.
    pub fn next(&mut self) -> (f64, f64) {
        debug_assert!(self.worker < self.n_model);
        self.model.sample_into(&mut self.buf, &mut self.rng);
        (self.buf.comp(self.worker, 0), self.buf.comm(self.worker, 0))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::delay::TruncatedGaussianModel;

    #[test]
    fn clock_is_monotone() {
        let a = now_us();
        let b = now_us();
        assert!(b >= a);
    }

    #[test]
    fn sampler_draws_worker_marginal() {
        // scenario-2 workers have different means; sampler for worker w
        // must track worker w's marginal
        let n = 6;
        let model = TruncatedGaussianModel::scenario2(n, 5);
        let want = model.comp[3].mu;
        let mut s = TaskDelaySampler::new(Box::new(model), n, 3, 1);
        let mut acc = 0.0;
        let trials = 5000;
        for _ in 0..trials {
            acc += s.next().0;
        }
        let got = acc / trials as f64;
        assert!((got - want).abs() < 0.01, "{got} vs {want}");
    }
}
