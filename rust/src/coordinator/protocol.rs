//! Wire protocol of the master/worker cluster (the EC2 substitute).
//!
//! Length-prefixed binary frames over TCP: `u32-LE length` + payload,
//! payload = `u8 tag` + little-endian fields.  Hand-rolled (no serde in
//! the offline build, DESIGN.md §5) with exhaustive encode/decode tests.
//!
//! Message flow, mirroring paper §II exactly:
//!
//! ```text
//! master → worker:  Welcome, LoadData (once), Assign (per round),
//!                   Stop (ack — paper's "acknowledgement message"),
//!                   Shutdown
//! worker → master:  Result (one per flushed task *group*; group
//!                   size 1 is the paper's immediate streaming, larger
//!                   groups are the GC(s) grouped-flush schemes — see
//!                   `crate::scheme::ClusterPlan`)
//! ```
//!
//! Since protocol v3 a `Result` frame is **scheme-native**: it carries
//! one *aggregated* `d`-length partial-sum block — `Σ_t h(X_t)` over
//! the flushed tasks — instead of the flushed tasks' concatenated
//! per-task blocks, so a GC(s) flush costs the same wire bytes as a
//! single-task message (the `s×` payload saving the scheme promises).
//! The task ids still travel with the frame; they are the block's
//! *range id*, which the master's duplicate-safe aggregation keys on
//! (see `crate::coordinator::aggregate`).  For the coded schemes the
//! aggregated block **is** the scheme's message: PC's per-worker sum
//! `φ(x_i)` and PCMM's per-slot evaluation `ψ(β_{i,j})`, which the
//! master decodes with [`crate::coded`] instead of treating as raw
//! task gradients.

use std::io::{Read, Write};

use anyhow::{bail, Context, Result};

/// Maximum accepted frame: guards against corrupt length prefixes.
pub const MAX_FRAME: u32 = 256 * 1024 * 1024;

/// Wire-protocol version, bumped on every incompatible frame change
/// (v2: grouped `Result` frames + `Assign.group`, PR 2; v3: aggregated
/// partial-sum `Result` blocks + `Assign.align`, PR 3; v4: per-frame
/// θ-version tags on `Assign`/`Result` for the bounded-staleness async
/// data plane; v5: latency anatomy — `Assign.issue_us` master issue
/// stamp, `Result` worker-local compute-start/compute-end/enqueue
/// stamps, and the worker → master `Hello` handshake ping that seeds
/// the per-worker clock-offset estimator).  Sent in `Welcome` so a
/// version-skewed worker fails the handshake with a clear message
/// instead of mis-decoding result frames.
pub const PROTO_VERSION: u32 = 5;

/// Protocol messages.
#[derive(Debug, Clone, PartialEq)]
pub enum Msg {
    /// master → worker on accept: protocol version, your id and the
    /// artifact profile.
    Welcome {
        proto: u32,
        worker_id: u32,
        profile: String,
    },
    /// master → worker once: the data batches this worker will hold.
    /// Each entry is `(batch_id, x ∈ R^{d×b} row-major, y·X ∈ R^d)`.
    LoadData {
        d: u32,
        b: u32,
        batches: Vec<(u32, Vec<f32>)>,
    },
    /// master → worker, one per round: parameters + ordered task list
    /// (the worker's TO-matrix row; `batches[j]` is the batch index the
    /// `j`-th task maps to under the master's current task↔batch map).
    /// `group` is the flush size: send one `Result` per `group`
    /// completed tasks (1 = immediate streaming).  With `align` set the
    /// worker instead flushes at task-space boundaries (after task `t`
    /// with `(t+1) % group == 0`, and whenever the next task is not
    /// `t + 1`), so every flushed range lies inside one canonical
    /// `group`-sized block and the master's duplicate-safe range
    /// aggregation can merge blocks across workers.
    /// `version` (v4) tags the θ snapshot this round computes against:
    /// the number of rounds the master had *applied* when it issued the
    /// frame.  Synchronous masters send `version == round` (staleness
    /// gap 0); a bounded-staleness pipeline sends `round − version ≤
    /// S − 1`.  `issue_us` (v5) is the master-clock stamp taken when
    /// the round's fan-out started — the `t0` of the clock-offset
    /// exchange ([`crate::telemetry::clock`]).
    Assign {
        round: u32,
        version: u32,
        theta: Vec<f32>,
        tasks: Vec<u32>,
        batches: Vec<u32>,
        group: u32,
        issue_us: u64,
        align: bool,
    },
    /// worker → master after each flushed group: **one aggregated
    /// `d`-length block** `Σ_t h(X_t)` over the group's tasks (protocol
    /// v3 — per-task blocks no longer travel), plus the worker-measured
    /// computation time of the whole group and the send timestamp (µs
    /// on the shared process clock) so the master can measure comm
    /// delay.  `tasks` is the range id the master aggregates by.
    /// `version` (v4) echoes the `Assign.version` the worker computed
    /// against, so the master's aggregation ring can verify a landing
    /// frame's θ lineage without a round→version side table.
    ///
    /// The v5 timing block — all four stamps on the *worker's* local
    /// monotonic clock, mapped onto the master clock by
    /// [`crate::telemetry::clock::ClockSync`]:
    /// `comp_start_us` when the group's first task started computing,
    /// `comp_end_us` when its last task finished, `enqueue_us` when the
    /// flush was handed to the send path, and `send_ts_us` when the
    /// sender thread picked it up — so a frame's latency decomposes
    /// into compute → worker-queue → network → master-dwell.
    Result {
        round: u32,
        version: u32,
        worker_id: u32,
        tasks: Vec<u32>,
        comp_us: u64,
        comp_start_us: u64,
        comp_end_us: u64,
        enqueue_us: u64,
        send_ts_us: u64,
        h: Vec<f32>,
    },
    /// master → worker: round complete, abandon remaining tasks
    /// (the paper's acknowledgement).
    Stop { round: u32 },
    /// master → worker: tear down.
    Shutdown,
    /// worker → master immediately after validating `Welcome` (v5):
    /// the handshake ping.  `ts_us` is the worker's local monotonic
    /// clock at send time; the master brackets the exchange with its
    /// own stamps around the `Welcome` write / `Hello` read to seed the
    /// per-worker clock-offset estimator before any round traffic.
    Hello { worker_id: u32, ts_us: u64 },
}

impl Msg {
    pub(crate) const TAG_WELCOME: u8 = 1;
    pub(crate) const TAG_LOAD: u8 = 2;
    pub(crate) const TAG_ASSIGN: u8 = 3;
    pub(crate) const TAG_RESULT: u8 = 4;
    pub(crate) const TAG_STOP: u8 = 5;
    pub(crate) const TAG_SHUTDOWN: u8 = 6;
    pub(crate) const TAG_HELLO: u8 = 7;

    /// Serialize into a payload (without the length prefix).
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(64);
        self.encode_into(&mut out);
        out
    }

    /// Serialize into a caller-owned buffer (appended, without the
    /// length prefix) — the allocation-free spelling of [`Msg::encode`]
    /// for pooled send paths ([`crate::coordinator::framebuf`]).
    pub fn encode_into(&self, out: &mut Vec<u8>) {
        match self {
            Msg::Welcome {
                proto,
                worker_id,
                profile,
            } => {
                out.push(Self::TAG_WELCOME);
                put_u32(&mut out, *proto);
                put_u32(&mut out, *worker_id);
                put_bytes(&mut out, profile.as_bytes());
            }
            Msg::LoadData { d, b, batches } => {
                out.push(Self::TAG_LOAD);
                put_u32(&mut out, *d);
                put_u32(&mut out, *b);
                put_u32(&mut out, batches.len() as u32);
                for (id, x) in batches {
                    put_u32(&mut out, *id);
                    put_f32s(&mut out, x);
                }
            }
            Msg::Assign {
                round,
                version,
                theta,
                tasks,
                batches,
                group,
                issue_us,
                align,
            } => {
                out.push(Self::TAG_ASSIGN);
                put_u32(&mut out, *round);
                put_u32(&mut out, *version);
                put_f32s(&mut out, theta);
                put_u32s(&mut out, tasks);
                put_u32s(&mut out, batches);
                put_u32(&mut out, *group);
                put_u64(&mut out, *issue_us);
                // align stays the FINAL Assign field across protocol
                // bumps — rejects_bad_align_byte pokes the last byte
                out.push(u8::from(*align));
            }
            Msg::Result {
                round,
                version,
                worker_id,
                tasks,
                comp_us,
                comp_start_us,
                comp_end_us,
                enqueue_us,
                send_ts_us,
                h,
            } => {
                out.push(Self::TAG_RESULT);
                put_u32(&mut out, *round);
                put_u32(&mut out, *version);
                put_u32(&mut out, *worker_id);
                put_u32s(&mut out, tasks);
                put_u64(&mut out, *comp_us);
                put_u64(&mut out, *comp_start_us);
                put_u64(&mut out, *comp_end_us);
                put_u64(&mut out, *enqueue_us);
                put_u64(&mut out, *send_ts_us);
                put_f32s(&mut out, h);
            }
            Msg::Stop { round } => {
                out.push(Self::TAG_STOP);
                put_u32(&mut out, *round);
            }
            Msg::Shutdown => out.push(Self::TAG_SHUTDOWN),
            Msg::Hello { worker_id, ts_us } => {
                out.push(Self::TAG_HELLO);
                put_u32(&mut out, *worker_id);
                put_u64(&mut out, *ts_us);
            }
        }
    }

    /// Deserialize a payload.
    pub fn decode(buf: &[u8]) -> Result<Msg> {
        let mut c = Cursor { buf, pos: 0 };
        let tag = c.u8()?;
        let msg = match tag {
            Self::TAG_WELCOME => Msg::Welcome {
                proto: c.u32()?,
                worker_id: c.u32()?,
                profile: String::from_utf8(c.bytes()?.to_vec()).context("profile utf8")?,
            },
            Self::TAG_LOAD => {
                let d = c.u32()?;
                let b = c.u32()?;
                let count = c.u32()? as usize;
                let mut batches = Vec::with_capacity(count);
                for _ in 0..count {
                    let id = c.u32()?;
                    batches.push((id, c.f32s()?));
                }
                Msg::LoadData { d, b, batches }
            }
            Self::TAG_ASSIGN => Msg::Assign {
                round: c.u32()?,
                version: c.u32()?,
                theta: c.f32s()?,
                tasks: c.u32s()?,
                batches: c.u32s()?,
                group: c.u32()?,
                issue_us: c.u64()?,
                align: match c.u8()? {
                    0 => false,
                    1 => true,
                    b => bail!("bad align byte {b} in Assign frame"),
                },
            },
            Self::TAG_RESULT => Msg::Result {
                round: c.u32()?,
                version: c.u32()?,
                worker_id: c.u32()?,
                tasks: c.u32s()?,
                comp_us: c.u64()?,
                comp_start_us: c.u64()?,
                comp_end_us: c.u64()?,
                enqueue_us: c.u64()?,
                send_ts_us: c.u64()?,
                h: c.f32s()?,
            },
            Self::TAG_STOP => Msg::Stop { round: c.u32()? },
            Self::TAG_SHUTDOWN => Msg::Shutdown,
            Self::TAG_HELLO => Msg::Hello {
                worker_id: c.u32()?,
                ts_us: c.u64()?,
            },
            t => bail!("unknown message tag {t}"),
        };
        if c.pos != buf.len() {
            bail!("trailing bytes in frame (tag {tag})");
        }
        Ok(msg)
    }

    /// Write as a framed message.
    pub fn write_to(&self, w: &mut impl Write) -> Result<()> {
        let payload = self.encode();
        anyhow::ensure!(payload.len() as u32 <= MAX_FRAME, "frame too large");
        w.write_all(&(payload.len() as u32).to_le_bytes())?;
        w.write_all(&payload)?;
        w.flush()?;
        Ok(())
    }

    /// Read one framed message (blocking).
    pub fn read_from(r: &mut impl Read) -> Result<Msg> {
        Ok(Self::read_frame(r)?.0)
    }

    /// Read one framed message plus its total wire size (length prefix
    /// + payload) — feeds the master's per-round wire-bytes accounting.
    pub fn read_frame(r: &mut impl Read) -> Result<(Msg, usize)> {
        let mut len4 = [0u8; 4];
        r.read_exact(&mut len4).context("reading frame length")?;
        let len = u32::from_le_bytes(len4);
        anyhow::ensure!(len <= MAX_FRAME, "oversized frame {len}");
        let mut payload = vec![0u8; len as usize];
        r.read_exact(&mut payload).context("reading frame body")?;
        Ok((Msg::decode(&payload)?, 4 + len as usize))
    }
}

// ---- little-endian put/get helpers ----------------------------------------

pub(crate) fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

pub(crate) fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_bytes(out: &mut Vec<u8>, b: &[u8]) {
    put_u32(out, b.len() as u32);
    out.extend_from_slice(b);
}

fn put_u32s(out: &mut Vec<u8>, xs: &[u32]) {
    put_u32(out, xs.len() as u32);
    for &x in xs {
        put_u32(out, x);
    }
}

fn put_f32s(out: &mut Vec<u8>, xs: &[f32]) {
    put_u32(out, xs.len() as u32);
    for &x in xs {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.buf.len() {
            bail!("frame truncated at byte {}", self.pos);
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn bytes(&mut self) -> Result<&'a [u8]> {
        let len = self.u32()? as usize;
        self.take(len)
    }

    fn u32s(&mut self) -> Result<Vec<u32>> {
        let len = self.u32()? as usize;
        anyhow::ensure!(len * 4 <= self.buf.len() - self.pos, "u32 array overruns frame");
        (0..len).map(|_| self.u32()).collect()
    }

    fn f32s(&mut self) -> Result<Vec<f32>> {
        let len = self.u32()? as usize;
        anyhow::ensure!(len * 4 <= self.buf.len() - self.pos, "f32 array overruns frame");
        let mut v = Vec::with_capacity(len);
        for _ in 0..len {
            v.push(f32::from_le_bytes(self.take(4)?.try_into().unwrap()));
        }
        Ok(v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(msg: Msg) {
        let enc = msg.encode();
        let dec = Msg::decode(&enc).expect("decode");
        assert_eq!(dec, msg);
    }

    #[test]
    fn all_messages_roundtrip() {
        roundtrip(Msg::Welcome {
            proto: PROTO_VERSION,
            worker_id: 7,
            profile: "fig5".into(),
        });
        roundtrip(Msg::LoadData {
            d: 3,
            b: 2,
            batches: vec![(0, vec![1.0, -2.0, 3.5, 0.0, 9.25, -0.5]), (4, vec![0.0; 6])],
        });
        roundtrip(Msg::Assign {
            round: 12,
            version: 12,
            theta: vec![0.5, -1.5],
            tasks: vec![3, 1, 0],
            batches: vec![3, 1, 0],
            group: 2,
            issue_us: 42_000,
            align: false,
        });
        // async issue: round 13 against the θ of applied round 11 (S=3)
        roundtrip(Msg::Assign {
            round: 13,
            version: 11,
            theta: vec![],
            tasks: vec![0, 1, 2, 3],
            batches: vec![0, 1, 2, 3],
            group: 2,
            issue_us: u64::MAX,
            align: true,
        });
        roundtrip(Msg::Result {
            round: 12,
            version: 12,
            worker_id: 2,
            tasks: vec![3],
            comp_us: 1234,
            comp_start_us: 998_000,
            comp_end_us: 999_234,
            enqueue_us: 999_500,
            send_ts_us: 999_999,
            h: vec![f32::MIN, f32::MAX, 0.0],
        });
        // grouped flush: two tasks, one aggregated d = 2 sum block (v3),
        // echoing a stale θ-version tag (v4)
        roundtrip(Msg::Result {
            round: 13,
            version: 11,
            worker_id: 0,
            tasks: vec![1, 2],
            comp_us: 2048,
            comp_start_us: 0,
            comp_end_us: 0,
            enqueue_us: 0,
            send_ts_us: 1_000_001,
            h: vec![4.0, 6.0],
        });
        roundtrip(Msg::Stop { round: 12 });
        roundtrip(Msg::Shutdown);
        roundtrip(Msg::Hello {
            worker_id: 3,
            ts_us: 17_000_000,
        });
    }

    #[test]
    fn framed_stream_roundtrip() {
        let msgs = vec![
            Msg::Welcome {
                proto: PROTO_VERSION,
                worker_id: 0,
                profile: "quickstart".into(),
            },
            Msg::Stop { round: 3 },
            Msg::Shutdown,
        ];
        let mut wire = Vec::new();
        for m in &msgs {
            m.write_to(&mut wire).unwrap();
        }
        let mut r = &wire[..];
        for m in &msgs {
            assert_eq!(&Msg::read_from(&mut r).unwrap(), m);
        }
        // stream exhausted
        assert!(Msg::read_from(&mut r).is_err());
    }

    #[test]
    fn rejects_unknown_tag() {
        assert!(Msg::decode(&[99]).is_err());
    }

    #[test]
    fn rejects_bad_align_byte() {
        let mut enc = Msg::Assign {
            round: 1,
            version: 1,
            theta: vec![],
            tasks: vec![0],
            batches: vec![0],
            group: 1,
            issue_us: 9,
            align: false,
        }
        .encode();
        *enc.last_mut().unwrap() = 7; // align byte is the final field
        assert!(Msg::decode(&enc).is_err());
    }

    #[test]
    fn rejects_trailing_bytes() {
        let mut enc = Msg::Stop { round: 1 }.encode();
        enc.push(0);
        assert!(Msg::decode(&enc).is_err());
    }

    #[test]
    fn rejects_truncation_everywhere() {
        let enc = Msg::Result {
            round: 1,
            version: 1,
            worker_id: 2,
            tasks: vec![3, 7],
            comp_us: 4,
            comp_start_us: 10,
            comp_end_us: 14,
            enqueue_us: 15,
            send_ts_us: 16,
            h: vec![1.0, 2.0],
        }
        .encode();
        for cut in 1..enc.len() {
            assert!(Msg::decode(&enc[..cut]).is_err(), "cut at {cut} should fail");
        }
    }

    #[test]
    fn rejects_oversized_frame_header() {
        let bogus = (MAX_FRAME + 1).to_le_bytes();
        let mut stream: Vec<u8> = bogus.to_vec();
        stream.extend_from_slice(&[0u8; 16]);
        let mut r = &stream[..];
        assert!(Msg::read_from(&mut r).is_err());
    }

    #[test]
    fn rejects_lying_array_length() {
        // Assign with a u32s length claiming more than the frame holds
        let mut enc = vec![3u8]; // TAG_ASSIGN
        enc.extend_from_slice(&1u32.to_le_bytes()); // round
        enc.extend_from_slice(&1u32.to_le_bytes()); // version (v4)
        enc.extend_from_slice(&0u32.to_le_bytes()); // theta len 0
        enc.extend_from_slice(&1_000_000u32.to_le_bytes()); // tasks len lie
        assert!(Msg::decode(&enc).is_err());
    }
}
