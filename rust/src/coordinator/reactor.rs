//! Poll-driven event loop for the master's worker connections
//! (`IoMode::Reactor`).
//!
//! One thread, one `poll(2)` call over every worker socket
//! ([`crate::util::poll`]), replacing the thread-per-worker blocking
//! readers: each connection owns a [`FrameBuf`] that non-blocking reads
//! drain into, complete frames are yielded round-robin (no fast worker
//! can starve a slow one's buffered frames), and outbound frames ride a
//! per-connection write queue flushed with vectored writes — an
//! `Assign`/`Stop` fan-out shares one reference-counted buffer across
//! all n queues instead of n clones.
//!
//! The reactor is deliberately *not* a thread: it lives on the master's
//! round loop, so completions flow into `RoundAggregator`/
//! `AggregatorRing` with no channel hop and no lock.  θ-updates happen
//! between `poll_frame` calls; while the master computes, the kernel
//! keeps buffering — the per-frame cost of that dwell is exactly what
//! `ClusterReport.ingest` measures.
//!
//! Disconnect semantics: a dead connection is marked closed and its
//! queued writes are dropped (the fleet keeps going, as with a dead
//! receiver thread in `IoMode::Threads`); only when *every* connection
//! is gone does `poll_frame` error out instead of letting the master
//! sit out its 60 s timeout.

use std::collections::VecDeque;
use std::io::{self, IoSlice, Write};
use std::net::TcpStream;
use std::rc::Rc;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use super::framebuf::{Frame, FrameBuf};
use super::now_us;
use crate::telemetry::metrics as tm;
use crate::util::poll::{poll_fds, PollFd, PollHook, POLLIN, POLLOUT};
use std::os::unix::io::AsRawFd;

/// Max buffers per vectored write burst.
const MAX_IOV: usize = 16;
/// Max recycled send buffers kept around.
const MAX_POOLED: usize = 64;

struct Conn {
    stream: TcpStream,
    rbuf: FrameBuf,
    /// outbound queue: `(frame bytes, written offset)`; broadcast
    /// frames share one `Rc` across all queues
    wq: VecDeque<(Rc<Vec<u8>>, usize)>,
    open: bool,
}

impl Conn {
    fn pending_write_bytes(&self) -> usize {
        self.wq.iter().map(|(b, off)| b.len() - off).sum()
    }
}

/// The master-side event loop over all worker connections.
pub struct Reactor {
    conns: Vec<Conn>,
    /// reused poll set + its pollfd→conn index map
    pollfds: Vec<PollFd>,
    poll_map: Vec<usize>,
    /// round-robin cursor for the buffered-frame drain
    scan: usize,
    /// recycled send buffers (drained queue entries whose `Rc` we held
    /// the last reference to)
    send_pool: Vec<Vec<u8>>,
}

impl Reactor {
    /// Take ownership of the handshaken (blocking) streams and switch
    /// them to non-blocking.
    pub fn new(streams: Vec<TcpStream>) -> Result<Self> {
        for s in &streams {
            s.set_nonblocking(true).context("set_nonblocking")?;
        }
        let conns = streams
            .into_iter()
            .map(|stream| Conn {
                stream,
                rbuf: FrameBuf::new(),
                wq: VecDeque::new(),
                open: true,
            })
            .collect();
        Ok(Self {
            conns,
            pollfds: Vec::new(),
            poll_map: Vec::new(),
            scan: 0,
            send_pool: Vec::new(),
        })
    }

    pub fn n_conns(&self) -> usize {
        self.conns.len()
    }

    pub fn is_open(&self, id: usize) -> bool {
        self.conns[id].open
    }

    /// Outbound bytes still queued (all connections) — backpressure
    /// visibility for tests and benches.
    pub fn pending_write_bytes(&self) -> usize {
        self.conns.iter().map(Conn::pending_write_bytes).sum()
    }

    /// A cleared send buffer from the recycle pool (returns to the pool
    /// by itself once the frame is fully written and the last queue
    /// reference drops).
    pub fn take_send_buf(&mut self) -> Vec<u8> {
        match self.send_pool.pop() {
            Some(mut b) => {
                b.clear();
                b
            }
            None => Vec::new(),
        }
    }

    /// Queue one framed message to one worker; flushes opportunistically
    /// (a send to a closed connection is silently dropped).
    pub fn send_frame(&mut self, id: usize, frame: Vec<u8>) {
        let rc = Rc::new(frame);
        self.enqueue(id, rc);
    }

    /// Queue one framed message to every open worker, sharing a single
    /// buffer across all queues (the Assign/Stop fan-out path).
    pub fn broadcast_frame(&mut self, frame: Vec<u8>) {
        let rc = Rc::new(frame);
        for id in 0..self.conns.len() {
            self.enqueue(id, Rc::clone(&rc));
        }
        // sole owner already (every conn closed): recycle immediately
        if let Ok(buf) = Rc::try_unwrap(rc) {
            self.recycle(buf);
        }
    }

    fn enqueue(&mut self, id: usize, rc: Rc<Vec<u8>>) {
        if !self.conns[id].open || rc.is_empty() {
            return;
        }
        self.conns[id].wq.push_back((rc, 0));
        Self::flush_conn(&mut self.conns[id], &mut self.send_pool);
    }

    fn recycle(&mut self, buf: Vec<u8>) {
        if self.send_pool.len() < MAX_POOLED {
            self.send_pool.push(buf);
            tm::REACTOR_SEND_POOL_BUFFERS.set(self.send_pool.len() as f64);
        }
    }

    /// Drive the write queue of one connection until empty or
    /// `WouldBlock`.  Errors close the connection (queued writes
    /// dropped) — the read side will surface the disconnect.
    fn flush_conn(c: &mut Conn, pool: &mut Vec<Vec<u8>>) {
        if !c.open {
            c.wq.clear();
            return;
        }
        while !c.wq.is_empty() {
            // scope the IoSlice borrows of the queue to the write call,
            // so the queue can be advanced from the result below
            let res = {
                let mut iov = [IoSlice::new(&[]); MAX_IOV];
                let mut k = 0;
                for (buf, off) in c.wq.iter() {
                    if k == MAX_IOV {
                        break;
                    }
                    iov[k] = IoSlice::new(&buf[*off..]);
                    k += 1;
                }
                c.stream.write_vectored(&iov[..k])
            };
            match res {
                Ok(0) => {
                    c.open = false;
                    c.wq.clear();
                    return;
                }
                Ok(mut n) => {
                    tm::REACTOR_WRITEV_BATCHES_TOTAL.inc();
                    while n > 0 {
                        let (buf, off) = c.wq.front_mut().expect("bytes written ⇒ queue nonempty");
                        let rem = buf.len() - *off;
                        if n < rem {
                            *off += n;
                            break;
                        }
                        n -= rem;
                        let (rc, _) = c.wq.pop_front().unwrap();
                        tm::REACTOR_WRITEV_FRAMES_TOTAL.inc();
                        if let Ok(owned) = Rc::try_unwrap(rc) {
                            if pool.len() < MAX_POOLED {
                                pool.push(owned);
                                tm::REACTOR_SEND_POOL_BUFFERS.set(pool.len() as f64);
                            }
                        }
                    }
                }
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => return,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(_) => {
                    c.open = false;
                    c.wq.clear();
                    return;
                }
            }
        }
    }

    /// Drain one connection's socket into its frame buffer until
    /// `WouldBlock`/EOF.  Any hard error closes the connection.
    fn fill_conn(c: &mut Conn) {
        loop {
            match c.rbuf.fill_from(&mut c.stream, now_us()) {
                Ok(0) => {
                    c.open = false;
                    return;
                }
                Ok(_) => {}
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => return,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
                Err(_) => {
                    c.open = false;
                    return;
                }
            }
        }
    }

    /// Yield the next complete frame from any connection, waiting up to
    /// `timeout`.  `Ok(None)` = timeout.  Buffered frames drain
    /// round-robin before the reactor goes back to the kernel; pending
    /// writes are flushed as their sockets turn writable.  Errors when
    /// every connection is closed with nothing left buffered, or on a
    /// corrupt frame stream.
    pub fn poll_frame(&mut self, timeout: Duration) -> Result<Option<(usize, Frame<'_>)>> {
        self.poll_frame_hooked(timeout, None)
    }

    /// [`Reactor::poll_frame`] with an optional [`PollHook`] riding the
    /// same kernel poll set — the telemetry scrape listener's fds join
    /// each `poll(2)` call after the worker sockets and are serviced
    /// after them, so frame delivery order (and thus θ) is untouched.
    pub fn poll_frame_hooked(
        &mut self,
        timeout: Duration,
        mut hook: Option<&mut dyn PollHook>,
    ) -> Result<Option<(usize, Frame<'_>)>> {
        let deadline = Instant::now() + timeout;
        loop {
            // 1. fairness scan over already-buffered frames
            let n = self.conns.len();
            let mut found = None;
            for off in 0..n {
                let i = (self.scan + off) % n;
                if self.conns[i]
                    .rbuf
                    .has_frame()
                    .with_context(|| format!("worker {i} frame stream corrupt"))?
                {
                    found = Some(i);
                    break;
                }
            }
            if let Some(i) = found {
                self.scan = (i + 1) % n;
                tm::REACTOR_PUMP_FRAMES_TOTAL.inc();
                let frame = self.conns[i].rbuf.next_frame()?.expect("peeked above");
                return Ok(Some((i, frame)));
            }

            // 2. back to the kernel for readiness
            let now = Instant::now();
            if now >= deadline {
                return Ok(None);
            }
            self.pollfds.clear();
            self.poll_map.clear();
            for (i, c) in self.conns.iter().enumerate() {
                if !c.open {
                    continue;
                }
                let mut events = POLLIN;
                if !c.wq.is_empty() {
                    events |= POLLOUT;
                }
                self.pollfds.push(PollFd::new(c.stream.as_raw_fd(), events));
                self.poll_map.push(i);
            }
            if self.pollfds.is_empty() {
                bail!("all worker connections closed");
            }
            // hook fds ride behind the worker sockets in the same set
            let base = self.pollfds.len();
            if let Some(h) = hook.as_deref_mut() {
                h.register(&mut self.pollfds);
            }
            let wait_ms = ((deadline - now).as_millis().min(i32::MAX as u128) as i32).max(1);
            tm::REACTOR_PUMP_POLLS_TOTAL.inc();
            poll_fds(&mut self.pollfds, wait_ms).context("poll on worker sockets")?;
            for p in 0..base {
                let pfd = self.pollfds[p];
                let i = self.poll_map[p];
                if pfd.writable() {
                    Self::flush_conn(&mut self.conns[i], &mut self.send_pool);
                }
                // readable, or error/hangup: read it out — a hangup
                // with buffered data still delivers the data first,
                // then EOF closes the connection
                if pfd.readable() || pfd.failed() {
                    Self::fill_conn(&mut self.conns[i]);
                }
            }
            if let Some(h) = hook.as_deref_mut() {
                h.service(&self.pollfds[base..]);
            }
        }
    }

    /// Best-effort teardown: flush queued writes for up to `deadline`,
    /// then shut both socket directions down.
    pub fn shutdown(&mut self, deadline: Duration) {
        let until = Instant::now() + deadline;
        while self.pending_write_bytes() > 0 && Instant::now() < until {
            self.pollfds.clear();
            self.poll_map.clear();
            for (i, c) in self.conns.iter().enumerate() {
                if c.open && !c.wq.is_empty() {
                    self.pollfds.push(PollFd::new(c.stream.as_raw_fd(), POLLOUT));
                    self.poll_map.push(i);
                }
            }
            if self.pollfds.is_empty() {
                break;
            }
            if poll_fds(&mut self.pollfds, 50).is_err() {
                break;
            }
            for p in 0..self.pollfds.len() {
                if self.pollfds[p].writable() || self.pollfds[p].failed() {
                    let i = self.poll_map[p];
                    Self::flush_conn(&mut self.conns[i], &mut self.send_pool);
                }
            }
        }
        for c in &self.conns {
            let _ = c.stream.shutdown(std::net::Shutdown::Both);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::framebuf::encode_msg_framed;
    use crate::coordinator::protocol::Msg;
    use std::io::Read;
    use std::net::TcpListener;

    /// A reactor over `n` localhost connections plus the peer ends.
    fn rig(n: usize) -> (Reactor, Vec<TcpStream>) {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let mut masters = Vec::new();
        let mut peers = Vec::new();
        for _ in 0..n {
            let peer = TcpStream::connect(addr).unwrap();
            peer.set_nodelay(true).unwrap();
            let (m, _) = listener.accept().unwrap();
            m.set_nodelay(true).unwrap();
            masters.push(m);
            peers.push(peer);
        }
        (Reactor::new(masters).unwrap(), peers)
    }

    fn framed(msg: &Msg) -> Vec<u8> {
        let mut v = Vec::new();
        encode_msg_framed(&mut v, msg);
        v
    }

    fn result_msg(round: u32, worker: u32, d: usize) -> Msg {
        Msg::Result {
            round,
            version: round,
            worker_id: worker,
            tasks: vec![worker],
            comp_us: 1,
            send_ts_us: 0,
            h: vec![worker as f32; d],
        }
    }

    #[test]
    fn partial_frame_stays_buffered_until_complete() {
        let (mut reactor, mut peers) = rig(1);
        let wire = framed(&result_msg(0, 0, 64));
        let split = wire.len() / 2;
        peers[0].write_all(&wire[..split]).unwrap();
        peers[0].flush().unwrap();
        // half a frame: the reactor must time out, not yield garbage
        assert!(reactor
            .poll_frame(Duration::from_millis(100))
            .unwrap()
            .is_none());
        peers[0].write_all(&wire[split..]).unwrap();
        peers[0].flush().unwrap();
        let (conn, frame) = reactor
            .poll_frame(Duration::from_secs(2))
            .unwrap()
            .expect("completed frame");
        assert_eq!(conn, 0);
        assert_eq!(frame.wire_len, wire.len());
        assert_eq!(Msg::decode(frame.payload).unwrap(), result_msg(0, 0, 64));
        assert!(frame.recv_us > 0, "arrival timestamp stamped");
    }

    #[test]
    fn burst_from_one_worker_does_not_lose_the_others() {
        let (mut reactor, mut peers) = rig(3);
        // worker 2 bursts three frames; 0 and 1 send one each — every
        // frame from every connection must come through exactly once
        // (exact interleaving depends on arrival timing; delivery and
        // per-connection order are the guarantees)
        for _ in 0..3 {
            peers[2].write_all(&framed(&result_msg(0, 2, 8))).unwrap();
        }
        peers[0].write_all(&framed(&result_msg(0, 0, 8))).unwrap();
        peers[1].write_all(&framed(&result_msg(0, 1, 8))).unwrap();
        for p in &mut peers {
            p.flush().unwrap();
        }
        let mut seen = Vec::new();
        for _ in 0..5 {
            let (conn, _) = reactor
                .poll_frame(Duration::from_secs(2))
                .unwrap()
                .expect("frame");
            seen.push(conn);
        }
        seen.sort_unstable();
        assert_eq!(seen, vec![0, 1, 2, 2, 2]);
        // and nothing invented beyond the five sent
        assert!(reactor
            .poll_frame(Duration::from_millis(50))
            .unwrap()
            .is_none());
    }

    #[test]
    fn slow_writer_backpressure_queues_then_drains_in_order() {
        let (mut reactor, mut peers) = rig(1);
        // a frame large enough that a few of them overrun the combined
        // kernel socket buffers while the peer refuses to read
        let big = framed(&Msg::LoadData {
            d: 4,
            b: 4,
            batches: vec![(0, vec![1.5f32; 64 * 1024])],
        });
        let mut wire_expect = Vec::new();
        let mut sent = 0usize;
        while reactor.pending_write_bytes() == 0 && sent < 64 {
            reactor.send_frame(0, big.clone());
            wire_expect.extend_from_slice(&big);
            sent += 1;
        }
        assert!(
            reactor.pending_write_bytes() > 0,
            "an undrained peer must eventually push the queue into backpressure"
        );
        assert!(reactor.is_open(0), "backpressure is not an error");
        // drain on a thread while the reactor pumps its write queue
        let mut peer = peers.remove(0);
        let total = wire_expect.len();
        let drainer = std::thread::spawn(move || {
            let mut got = vec![0u8; total];
            peer.read_exact(&mut got).unwrap();
            got
        });
        let deadline = Instant::now() + Duration::from_secs(10);
        while reactor.pending_write_bytes() > 0 && Instant::now() < deadline {
            // no inbound traffic: poll_frame times out, flushing writes
            let _ = reactor.poll_frame(Duration::from_millis(20)).unwrap();
        }
        assert_eq!(reactor.pending_write_bytes(), 0, "queue fully drained");
        let got = drainer.join().unwrap();
        assert_eq!(got, wire_expect, "byte stream intact and in order");
    }

    #[test]
    fn broadcast_shares_one_buffer_and_skips_closed_conns() {
        let (mut reactor, mut peers) = rig(2);
        drop(peers.remove(0)); // worker 0 is gone
        // deliver worker 0's EOF so the reactor marks it closed (FIN
        // delivery is fast on loopback but not instantaneous)
        let deadline = Instant::now() + Duration::from_secs(5);
        while reactor.is_open(0) && Instant::now() < deadline {
            let _ = reactor.poll_frame(Duration::from_millis(50)).unwrap();
        }
        assert!(!reactor.is_open(0));
        assert!(reactor.is_open(1));
        let stop = framed(&Msg::Stop { round: 3 });
        reactor.broadcast_frame(stop.clone());
        let mut got = vec![0u8; stop.len()];
        peers[0].read_exact(&mut got).unwrap(); // peers[0] is worker 1
        assert_eq!(got, stop);
    }

    #[test]
    fn mid_round_disconnect_keeps_the_fleet_going() {
        let (mut reactor, mut peers) = rig(2);
        peers[0].write_all(&framed(&result_msg(0, 0, 8))).unwrap();
        peers[0].flush().unwrap();
        let (conn, _) = reactor
            .poll_frame(Duration::from_secs(2))
            .unwrap()
            .expect("frame from worker 0");
        assert_eq!(conn, 0);
        drop(peers.remove(0)); // worker 0 dies mid-round
        peers[0].write_all(&framed(&result_msg(0, 1, 8))).unwrap();
        peers[0].flush().unwrap();
        let (conn, _) = reactor
            .poll_frame(Duration::from_secs(2))
            .unwrap()
            .expect("surviving worker still heard");
        assert_eq!(conn, 1);
        // the dead connection is noticed within a few polls
        let deadline = Instant::now() + Duration::from_secs(5);
        while reactor.is_open(0) && Instant::now() < deadline {
            let _ = reactor.poll_frame(Duration::from_millis(50)).unwrap();
        }
        assert!(!reactor.is_open(0));
        // sending to the dead connection is a silent drop, not a panic
        reactor.send_frame(0, framed(&Msg::Stop { round: 0 }));
        // once the whole fleet is gone, waiting errors out instead of
        // burning the full master timeout
        drop(peers);
        let deadline = Instant::now() + Duration::from_secs(5);
        loop {
            match reactor.poll_frame(Duration::from_millis(100)) {
                Ok(Some(_)) => continue, // drain whatever was in flight
                Ok(None) => {
                    assert!(
                        Instant::now() < deadline,
                        "all-closed fleet must surface an error promptly"
                    );
                }
                Err(e) => {
                    assert!(e.to_string().contains("all worker connections closed"));
                    break;
                }
            }
        }
    }

    #[test]
    fn eof_with_buffered_frames_delivers_them_first() {
        let (mut reactor, mut peers) = rig(1);
        let m1 = framed(&result_msg(0, 0, 4));
        let m2 = framed(&result_msg(1, 0, 4));
        peers[0].write_all(&m1).unwrap();
        peers[0].write_all(&m2).unwrap();
        peers[0].flush().unwrap();
        drop(peers); // hangup right behind the data
        let mut got = Vec::new();
        loop {
            match reactor.poll_frame(Duration::from_millis(200)) {
                Ok(Some((_, f))) => got.push(Msg::decode(f.payload).unwrap()),
                Ok(None) => continue,
                Err(_) => break, // all closed, after the data drained
            }
        }
        assert_eq!(got, vec![result_msg(0, 0, 4), result_msg(1, 0, 4)]);
    }

    #[test]
    fn send_buffers_recycle_through_the_pool() {
        let (mut reactor, mut peers) = rig(1);
        let stop = framed(&Msg::Stop { round: 1 });
        for round in 0..8u32 {
            let mut buf = reactor.take_send_buf();
            encode_msg_framed(&mut buf, &Msg::Stop { round });
            reactor.send_frame(0, buf);
            let mut got = vec![0u8; stop.len()];
            peers[0].read_exact(&mut got).unwrap();
        }
        assert!(
            !reactor.send_pool.is_empty(),
            "fully-written sole-owner buffers must come back to the pool"
        );
    }
}
