//! Worker node: sequential task execution with immediate streaming of
//! results (paper §II).
//!
//! Thread layout per worker:
//!
//! * **reader** — drains the master connection, publishing the latest
//!   `Stop` round into an atomic and forwarding `Assign`/`LoadData`
//!   through a channel (so a Stop is seen *between tasks*, matching the
//!   paper's "receives the acknowledgement … and stops computations");
//! * **compute loop** (this thread) — runs tasks in TO-matrix order,
//!   buffering finished results and **flushing one message per
//!   `group` completed tasks** (`group = 1` is the paper's immediate
//!   streaming; larger groups execute the GC(s) schemes of
//!   `crate::scheme::gc` — the flushed message carries **one
//!   aggregated `d`-length partial sum** over the group, protocol v3,
//!   and rides the flush task's comm delay, matching the simulator's
//!   flush-slot arrival model).  Under `Assign.align` the flush points
//!   move to task-space boundaries so every flushed range lies inside
//!   one canonical block of the master's duplicate-safe aggregation
//!   (`crate::coordinator::aggregate`);
//! * **delivery threads** — each flushed message is handed to a
//!   short-lived sender that sleeps out the injected communication
//!   delay before writing the frame, so comm delays overlap the
//!   worker's subsequent computations exactly as in eq. (1).

use std::collections::HashMap;
use std::io::Write as _;
use std::net::TcpStream;
use std::sync::atomic::{AtomicI64, AtomicU32, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{Context, Result};

use super::framebuf::{encode_result_into, patch_result_send_ts, FramePool};
use super::protocol::Msg;
use super::{now_us, TaskDelaySampler};
use crate::linalg::Mat;
use crate::runtime::Runtime;
use crate::telemetry::metrics as tm;

/// Which engine computes `h(X) = X Xᵀ θ` on the worker.
pub enum Backend {
    /// PJRT executing the AOT artifact (`<profile>/task_gram`) — the
    /// production path; python is *not* involved (HLO was lowered at
    /// build time).
    Pjrt,
    /// f64 CPU oracle (`linalg::Mat`), for artifact-less test runs.
    CpuOracle,
}

/// Worker-side options.
pub struct WorkerOptions {
    pub backend: Backend,
    /// injected per-task (comp, comm) delays; `None` = measure reality
    pub injected: Option<TaskDelaySampler>,
    /// artifact directory override (defaults to $STRAGGLER_ARTIFACTS)
    pub artifact_dir: Option<std::path::PathBuf>,
}

enum Work {
    Load {
        d: u32,
        batches: Vec<(u32, Vec<f32>)>,
    },
    Assign {
        round: u32,
        version: u32,
        theta: Vec<f32>,
        tasks: Vec<u32>,
        batches: Vec<u32>,
        group: u32,
        align: bool,
    },
    Shutdown,
}

/// Run one worker until the master sends `Shutdown`.
pub fn run_worker(addr: std::net::SocketAddr, mut opts: WorkerOptions) -> Result<()> {
    let stream = TcpStream::connect(addr).context("worker connect")?;
    stream.set_nodelay(true)?;
    let mut reader = stream.try_clone()?;
    let writer = Arc::new(Mutex::new(stream));

    // handshake (incl. protocol-version check — a skewed peer must
    // fail here, not mis-decode grouped result frames later)
    let (worker_id, profile) = match Msg::read_from(&mut reader)? {
        Msg::Welcome {
            proto,
            worker_id,
            profile,
        } => {
            anyhow::ensure!(
                proto == super::protocol::PROTO_VERSION,
                "protocol version mismatch: master speaks v{proto}, \
                 this worker speaks v{}",
                super::protocol::PROTO_VERSION
            );
            (worker_id, profile)
        }
        other => anyhow::bail!("expected Welcome, got {other:?}"),
    };

    // v5 handshake ping: echo a worker-clock stamp right back so the
    // master can seed this worker's clock-offset estimate from the
    // Welcome→Hello round trip (telemetry/clock.rs) before any round
    // traffic flows.
    Msg::Hello {
        worker_id,
        ts_us: now_us(),
    }
    .write_to(&mut *writer.lock().expect("writer poisoned"))?;

    // latest acknowledged round (-1 = none): Stop(r) means "round r done"
    let stopped_round = Arc::new(AtomicI64::new(-1));
    let inflight = Arc::new(AtomicU32::new(0));
    let (work_tx, work_rx) = mpsc::channel::<Work>();

    // reader thread: route control messages
    {
        let stopped = Arc::clone(&stopped_round);
        let tx = work_tx.clone();
        std::thread::Builder::new()
            .name(format!("worker{worker_id}-reader"))
            .spawn(move || loop {
                match Msg::read_from(&mut reader) {
                    Ok(Msg::LoadData { d, b: _, batches }) => {
                        let _ = tx.send(Work::Load { d, batches });
                    }
                    Ok(Msg::Assign {
                        round,
                        version,
                        theta,
                        tasks,
                        batches,
                        group,
                        align,
                    }) => {
                        let _ = tx.send(Work::Assign {
                            round,
                            version,
                            theta,
                            tasks,
                            batches,
                            group,
                            align,
                        });
                    }
                    Ok(Msg::Stop { round }) => {
                        stopped.fetch_max(round as i64, Ordering::SeqCst);
                    }
                    Ok(Msg::Shutdown) | Err(_) => {
                        let _ = tx.send(Work::Shutdown);
                        return;
                    }
                    Ok(other) => {
                        eprintln!("worker {worker_id}: unexpected {other:?}");
                    }
                }
            })?;
    }

    // Send-side scratch, reused across flushes and rounds: the frame
    // bytes come from a pool shared with the delivery threads (each
    // thread returns its buffer after the write), and the group
    // accumulators keep their capacity between flushes — steady state
    // allocates nothing on the result path.
    let send_pool = Arc::new(Mutex::new(FramePool::new()));
    let mut buf_tasks: Vec<u32> = Vec::new();
    let mut buf_sum: Vec<f64> = Vec::new();

    // compute state
    #[allow(unused_assignments)]
    let mut dim = 0usize;
    let mut oracle_parts: HashMap<u32, Mat> = HashMap::new();
    let mut pjrt_parts: HashMap<u32, Vec<f32>> = HashMap::new();
    let mut runtime: Option<Runtime> = None;

    loop {
        let work = work_rx.recv().context("worker channel closed")?;
        match work {
            Work::Shutdown => return Ok(()),
            Work::Load { d, batches } => {
                dim = d as usize;
                match opts.backend {
                    Backend::CpuOracle => {
                        for (id, x) in batches {
                            let b = x.len() / dim;
                            oracle_parts.insert(
                                id,
                                Mat::from_fn(dim, b, |i, j| x[i * b + j] as f64),
                            );
                        }
                    }
                    Backend::Pjrt => {
                        if runtime.is_none() {
                            let dir = opts
                                .artifact_dir
                                .clone()
                                .unwrap_or_else(crate::runtime::default_artifact_dir);
                            runtime = Some(Runtime::new(dir)?);
                        }
                        // upload each partition to the device once —
                        // X is round-invariant, so the per-task hot
                        // path only ships θ (§Perf)
                        let rt = runtime.as_mut().unwrap();
                        let meta = rt.manifest().get(&profile, "task_gram")?.clone();
                        let shape = meta.arg_shapes[0].clone();
                        for (id, x) in batches {
                            rt.upload(&format!("x{id}"), &x, &shape)?;
                            pjrt_parts.insert(id, x);
                        }
                    }
                }
            }
            // A queued Assign simply waits until the current round's
            // tasks drain (or its Stop lands) — the worker-queue
            // semantics of the bounded-staleness pipeline: the master
            // may push up to S assignments ahead, and `s_{i,t} =
            // max(issue, free)` falls out of this sequential loop.
            Work::Assign {
                round,
                version,
                theta,
                tasks,
                batches,
                group,
                align,
            } => {
                let group = (group.max(1) as usize).min(tasks.len().max(1));
                // grouped-flush buffers (GC(s)); group = 1 flushes every
                // task, i.e. the paper's immediate streaming.  The
                // buffer holds one f64 running sum, not per-task blocks
                // — protocol v3 ships the aggregate only.
                buf_tasks.clear();
                buf_sum.clear();
                let mut buf_comp_us: u64 = 0;
                // v5 group timing: worker-clock stamp of the first
                // task's start and the last task's end, shipped on the
                // flushed frame so the master can decompose latency
                let mut buf_comp_start_us: u64 = 0;
                let mut buf_comp_end_us: u64 = 0;
                for (slot, (&task, &batch)) in tasks.iter().zip(&batches).enumerate() {
                    // paper: stop as soon as the ack for *this* round
                    // lands; a partially filled group is abandoned with
                    // the round (its results are no longer needed)
                    if stopped_round.load(Ordering::SeqCst) >= round as i64 {
                        break;
                    }
                    // --- computation phase (eq. 1 first term) ---
                    let t0 = now_us();
                    if buf_tasks.is_empty() {
                        buf_comp_start_us = t0;
                    }
                    let (inj_comp_ms, inj_comm_ms) = match opts.injected.as_mut() {
                        Some(s) => s.next(),
                        None => (0.0, 0.0),
                    };
                    if inj_comp_ms > 0.0 {
                        spin_sleep(Duration::from_secs_f64(inj_comp_ms / 1e3));
                    }
                    let h: Vec<f64> = match opts.backend {
                        Backend::CpuOracle => {
                            let part = oracle_parts
                                .get(&batch)
                                .with_context(|| format!("batch {batch} not loaded"))?;
                            let theta64: Vec<f64> =
                                theta.iter().map(|&v| v as f64).collect();
                            part.gram_matvec(&theta64)
                        }
                        Backend::Pjrt => {
                            let rt = runtime.as_mut().expect("runtime initialized on load");
                            anyhow::ensure!(
                                pjrt_parts.contains_key(&batch),
                                "batch {batch} not loaded"
                            );
                            rt.task_gram_resident(&profile, &format!("x{batch}"), &theta)?
                                .into_iter()
                                .map(f64::from)
                                .collect()
                        }
                    };
                    let t1 = now_us();
                    buf_comp_us += t1 - t0;
                    buf_comp_end_us = t1;
                    buf_tasks.push(task);
                    if buf_sum.is_empty() {
                        buf_sum.extend_from_slice(&h);
                    } else {
                        crate::linalg::vec_axpy(&mut buf_sum, 1.0, &h);
                    }

                    // --- communication phase (eq. 1 second term) ---
                    // flush one message per `group` finished tasks (plus
                    // the row's ragged tail) — or, in aligned mode, at
                    // canonical task-space boundaries and contiguity
                    // breaks, so every flushed range sits inside one
                    // canonical block.  Delivery is delayed on a
                    // separate thread riding the *flush* task's comm
                    // delay, so the next computation starts immediately
                    // — the simulator's flush-slot arrival model
                    let last_slot = slot + 1 == tasks.len();
                    let flush = if align {
                        last_slot
                            || (task as usize + 1) % group == 0
                            || tasks[slot + 1] != task.wrapping_add(1)
                    } else {
                        last_slot || buf_tasks.len() == group
                    };
                    if !flush {
                        continue;
                    }
                    // Encode the framed Result directly into a pooled
                    // buffer (length prefix + payload in one shot, f64
                    // sum narrowed to f32 inline); the version field
                    // echoes the θ-version the computation used, so
                    // the master can audit a frame's lineage without
                    // a round→version side table (protocol v4).
                    let mut frame = send_pool.lock().expect("pool poisoned").get();
                    // enqueue stamp = encode time; send_ts is a
                    // placeholder the delivery thread back-patches the
                    // instant the frame heads for the socket, so the
                    // gap between them is the worker-queue phase and
                    // `recv - send` is the full network phase
                    // (including any injected comm delay).
                    encode_result_into(
                        &mut frame,
                        round,
                        version,
                        worker_id,
                        &buf_tasks,
                        buf_comp_us,
                        buf_comp_start_us,
                        buf_comp_end_us,
                        now_us(),
                        0,
                        &buf_sum,
                    );
                    tm::WORKER_COMPUTE_US_TOTAL.add(buf_comp_us);
                    tm::WORKER_FRAMES_SENT_TOTAL.inc();
                    buf_tasks.clear();
                    buf_sum.clear();
                    buf_comp_us = 0;
                    let writer = Arc::clone(&writer);
                    let pool = Arc::clone(&send_pool);
                    let inflight2 = Arc::clone(&inflight);
                    inflight.fetch_add(1, Ordering::SeqCst);
                    std::thread::Builder::new()
                        .name(format!("worker{worker_id}-send"))
                        .spawn(move || {
                            patch_result_send_ts(&mut frame, now_us());
                            if inj_comm_ms > 0.0 {
                                spin_sleep(Duration::from_secs_f64(inj_comm_ms / 1e3));
                            }
                            let send_t0 = now_us();
                            let mut w = writer.lock().expect("writer poisoned");
                            let _ = w.write_all(&frame);
                            let _ = w.flush();
                            drop(w);
                            tm::WORKER_FLUSH_SEND_US_TOTAL.add(now_us() - send_t0);
                            pool.lock().expect("pool poisoned").put(frame);
                            inflight2.fetch_sub(1, Ordering::SeqCst);
                        })?;
                }
            }
        }
    }
}

/// Hybrid sleep: OS sleep for the bulk, spin for the tail — delays are
/// fractions of a millisecond in the paper's scenarios, far below the
/// scheduler's wakeup granularity.
fn spin_sleep(d: Duration) {
    let start = std::time::Instant::now();
    if d > Duration::from_micros(300) {
        std::thread::sleep(d - Duration::from_micros(200));
    }
    while start.elapsed() < d {
        std::hint::spin_loop();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spin_sleep_is_accurate_to_tens_of_us() {
        for ms in [0.1f64, 0.5, 2.0] {
            let d = Duration::from_secs_f64(ms / 1e3);
            let t0 = std::time::Instant::now();
            spin_sleep(d);
            let elapsed = t0.elapsed();
            assert!(elapsed >= d, "slept too little");
            assert!(
                elapsed < d + Duration::from_micros(900),
                "{ms} ms sleep overshot: {elapsed:?}"
            );
        }
    }
}
