//! Synthetic linear-regression dataset — paper §VI-C.
//!
//! `X ∈ R^{N×d}` with i.i.d. `N(0,1)` entries, labels
//! `y_i = (X_i + Z)ᵀ U` with noise `Z ~ N(0, 0.01)` elementwise and a
//! ground-truth `U ~ U(0,1)^d`.  The dataset is split into `n`
//! partitions `X_i ∈ R^{d×b}` (samples as columns, `b = ⌈N/n⌉`,
//! zero-padded when `n ∤ N` exactly as the paper does for Fig. 6).

use crate::linalg::Mat;
use crate::util::rng::Rng;

/// A partitioned regression dataset.
#[derive(Debug, Clone)]
pub struct Dataset {
    /// number of partitions (= tasks = workers)
    pub n: usize,
    /// feature dimension
    pub d: usize,
    /// samples per partition (after padding)
    pub b: usize,
    /// true sample count before padding
    pub n_samples: usize,
    /// partitions `X_i ∈ R^{d×b}`
    pub parts: Vec<Mat>,
    /// labels per partition, `y_i ∈ R^b`
    pub labels: Vec<Vec<f64>>,
    /// ground-truth weight vector `U` (for oracle error tracking)
    pub truth: Vec<f64>,
}

impl Dataset {
    /// Generate per the paper's recipe.
    pub fn synthesize(n: usize, d: usize, n_samples: usize, seed: u64) -> Self {
        assert!(n >= 1 && d >= 1 && n_samples >= n, "degenerate dataset shape");
        let mut rng = Rng::seed_from_u64(seed);
        let b = n_samples.div_ceil(n);
        let truth: Vec<f64> = (0..d).map(|_| rng.f64()).collect(); // U(0,1)

        let mut parts = Vec::with_capacity(n);
        let mut labels = Vec::with_capacity(n);
        let mut produced = 0usize;
        for _ in 0..n {
            let mut x = Mat::zeros(d, b);
            let mut y = vec![0.0; b];
            for col in 0..b {
                if produced >= n_samples {
                    break; // zero-padded tail (paper Fig. 6 note)
                }
                produced += 1;
                // sample x_col ~ N(0,1)^d ; y = (x + z)ᵀ U
                let mut dot = 0.0;
                for row in 0..d {
                    let v = rng.normal();
                    x[(row, col)] = v;
                    let z = 0.1 * rng.normal(); // N(0, 0.01)
                    dot += (v + z) * truth[row];
                }
                y[col] = dot;
            }
            parts.push(x);
            labels.push(y);
        }
        Self {
            n,
            d,
            b,
            n_samples,
            parts,
            labels,
            truth,
        }
    }

    /// Total (padded) sample count `N = n·b`.
    pub fn padded_samples(&self) -> usize {
        self.n * self.b
    }

    /// Precomputed per-partition constants `b_i = X_i y_i` (computed
    /// once by the master, paper §VI-A).
    pub fn xy_vectors(&self) -> Vec<Vec<f64>> {
        self.parts
            .iter()
            .zip(&self.labels)
            .map(|(x, y)| x.matvec(y))
            .collect()
    }

    /// Loss `F(θ) = 1/N ‖Xθ − y‖²` (eq. 47) over the padded dataset.
    pub fn loss(&self, theta: &[f64]) -> f64 {
        let mut total = 0.0;
        for (x, y) in self.parts.iter().zip(&self.labels) {
            let preds = x.matvec_t(theta);
            for (p, yi) in preds.iter().zip(y) {
                total += (p - yi) * (p - yi);
            }
        }
        total / self.padded_samples() as f64
    }

    /// Full gradient `∇F(θ) = 2/N Σ (X_i X_iᵀ θ − X_i y_i)` (eq. 48).
    pub fn full_gradient(&self, theta: &[f64]) -> Vec<f64> {
        let mut g = vec![0.0; self.d];
        for (x, y) in self.parts.iter().zip(&self.labels) {
            let h = x.gram_matvec(theta);
            let xy = x.matvec(y);
            for i in 0..self.d {
                g[i] += h[i] - xy[i];
            }
        }
        let scale = 2.0 / self.padded_samples() as f64;
        g.iter_mut().for_each(|v| *v *= scale);
        g
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_padding() {
        // Fig. 6 setting: N = 1000, n = 11 → b = ⌈1000/11⌉ = 91, padded
        let ds = Dataset::synthesize(11, 20, 1000, 1);
        assert_eq!(ds.b, 91);
        assert_eq!(ds.padded_samples(), 1001);
        assert_eq!(ds.parts.len(), 11);
        assert_eq!(ds.parts[0].rows, 20);
        assert_eq!(ds.parts[0].cols, 91);
        // last partition's final column is padding (all zeros)
        let last = &ds.parts[10];
        let zeros = (0..20).all(|row| last[(row, 90)] == 0.0);
        assert!(zeros, "tail must be zero-padded");
        assert_eq!(ds.labels[10][90], 0.0);
    }

    #[test]
    fn deterministic_in_seed() {
        let a = Dataset::synthesize(4, 8, 64, 7);
        let b = Dataset::synthesize(4, 8, 64, 7);
        assert_eq!(a.parts[2].data(), b.parts[2].data());
        let c = Dataset::synthesize(4, 8, 64, 8);
        assert_ne!(a.parts[2].data(), c.parts[2].data());
    }

    #[test]
    fn labels_follow_truth_up_to_noise() {
        let ds = Dataset::synthesize(2, 30, 200, 3);
        // loss at the truth should be near the noise floor:
        // E[((x+z)ᵀU − xᵀU)²] = E[(zᵀU)²] = 0.01·‖U‖²
        let noise_floor = 0.01 * ds.truth.iter().map(|u| u * u).sum::<f64>();
        let at_truth = ds.loss(&ds.truth);
        assert!(
            at_truth < 3.0 * noise_floor + 0.05,
            "loss at truth {at_truth} vs floor {noise_floor}"
        );
        // and far below the loss at zero
        assert!(at_truth < 0.2 * ds.loss(&vec![0.0; 30]));
    }

    #[test]
    fn full_gradient_matches_finite_differences() {
        let ds = Dataset::synthesize(3, 6, 30, 5);
        let theta: Vec<f64> = (0..6).map(|i| 0.1 * i as f64).collect();
        let g = ds.full_gradient(&theta);
        let eps = 1e-5;
        for i in 0..6 {
            let mut tp = theta.clone();
            let mut tm = theta.clone();
            tp[i] += eps;
            tm[i] -= eps;
            let fd = (ds.loss(&tp) - ds.loss(&tm)) / (2.0 * eps);
            assert!(
                (g[i] - fd).abs() < 1e-5 * (1.0 + fd.abs()),
                "coord {i}: {} vs {fd}",
                g[i]
            );
        }
    }

    #[test]
    fn gradient_near_zero_at_least_squares_solution() {
        // gradient descent long enough should reach tiny gradient
        let ds = Dataset::synthesize(2, 5, 100, 9);
        let mut theta = vec![0.0; 5];
        for _ in 0..4000 {
            let g = ds.full_gradient(&theta);
            for (t, gi) in theta.iter_mut().zip(&g) {
                *t -= 0.05 * gi;
            }
        }
        let g = ds.full_gradient(&theta);
        assert!(crate::linalg::norm2(&g) < 1e-6);
        // and theta is close to truth (noise-limited)
        let err: f64 = theta
            .iter()
            .zip(&ds.truth)
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f64>()
            .sqrt();
        assert!(err < 0.5, "recovered θ far from truth: {err}");
    }

    #[test]
    fn xy_vectors_match_direct() {
        let ds = Dataset::synthesize(3, 4, 12, 11);
        let xy = ds.xy_vectors();
        for i in 0..3 {
            assert_eq!(xy[i], ds.parts[i].matvec(&ds.labels[i]));
        }
    }
}
