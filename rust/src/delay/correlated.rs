//! Worker-correlated delay wrapper.
//!
//! The paper's statistical model (§II) explicitly allows the delays of
//! different tasks *at the same worker* to be dependent (joint CDF
//! `F_{i,[n]}`), while workers stay independent.  This wrapper induces
//! exactly that: per round and worker it draws a log-normal slowdown
//! multiplier `Z_i = exp(σ·G)` (mean-normalized) applied to every slot
//! of that worker — the classic "machine is busy this round" effect.
//! With `sigma = 0` it degenerates to the inner model (tested).

use crate::util::rng::Rng;


use super::{DelayBatch, DelayModel, DelaySample};

/// Wraps any [`DelayModel`] with a per-(round, worker) multiplicative
/// log-normal slowdown of log-std `sigma`, normalized to mean 1 so the
/// marginal means of the inner model are preserved.
pub struct WorkerCorrelated<M> {
    pub inner: M,
    pub sigma: f64,
    /// Apply the multiplier to communication delays too (a busy host
    /// slows its NIC as well); default true.
    pub affect_comm: bool,
}

impl<M: DelayModel> WorkerCorrelated<M> {
    pub fn new(inner: M, sigma: f64) -> Self {
        assert!(sigma >= 0.0, "sigma must be non-negative");
        Self {
            inner,
            sigma,
            affect_comm: true,
        }
    }

    fn multiplier(&self, rng: &mut Rng) -> f64 {
        if self.sigma == 0.0 {
            return 1.0;
        }
        // Box–Muller standard normal
        let u1: f64 = rng.f64().max(1e-300);
        let u2 = rng.f64();
        let g = (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
        // E[exp(σG)] = exp(σ²/2); divide it out to keep mean 1
        (self.sigma * g - self.sigma * self.sigma / 2.0).exp()
    }
}

impl<M: DelayModel> DelayModel for WorkerCorrelated<M> {
    fn name(&self) -> String {
        format!("correlated(σ={})/{}", self.sigma, self.inner.name())
    }

    fn sample_into(&self, out: &mut DelaySample, rng: &mut Rng) {
        self.inner.sample_into(out, rng);
        let (n, r) = (out.n, out.r);
        for i in 0..n {
            let z = self.multiplier(rng);
            if z == 1.0 {
                continue;
            }
            for j in 0..r {
                out.comp_mut()[i * r + j] *= z;
            }
            if self.affect_comm {
                for j in 0..r {
                    out.comm_mut()[i * r + j] *= z;
                }
            }
        }
    }

    /// Batched sampling.  The per-(round, worker) multiplier draws must
    /// interleave with the inner model's stream exactly as in sequential
    /// sampling (bit-identity contract), so rounds stay sequential here;
    /// the batch win is hoisting the inner virtual dispatch result into
    /// one scratch sample and writing scaled rows straight into the
    /// batch's contiguous storage.
    fn sample_batch_into(&self, out: &mut DelayBatch, rng: &mut Rng) {
        let (n, r) = (out.n, out.r);
        let mut tmp = DelaySample::zeros(n, r);
        for b in 0..out.rounds {
            self.inner.sample_into(&mut tmp, rng);
            let (comp, comm) = out.round_mut(b);
            comp.copy_from_slice(tmp.comp_flat());
            comm.copy_from_slice(tmp.comm_flat());
            for i in 0..n {
                let z = self.multiplier(rng);
                if z == 1.0 {
                    continue;
                }
                for v in &mut comp[i * r..(i + 1) * r] {
                    *v *= z;
                }
                if self.affect_comm {
                    for v in &mut comm[i * r..(i + 1) * r] {
                        *v *= z;
                    }
                }
            }
        }
    }

    fn mean_comp(&self, worker: usize) -> Option<f64> {
        // multiplier is mean-1, so marginal means are unchanged
        self.inner.mean_comp(worker)
    }

    fn mean_comm(&self, worker: usize) -> Option<f64> {
        self.inner.mean_comm(worker)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::delay::ShiftedExponential;
    use crate::util::stats::RunningStats;
    

    fn rng() -> Rng {
        Rng::seed_from_u64(0xC088)
    }

    #[test]
    fn sigma_zero_is_identity_in_distribution() {
        let inner = ShiftedExponential::new(0.1, 5.0, 0.2, 3.0);
        let wrapped = WorkerCorrelated::new(ShiftedExponential::new(0.1, 5.0, 0.2, 3.0), 0.0);
        let mut r1 = Rng::seed_from_u64(5);
        let mut r2 = Rng::seed_from_u64(5);
        let a = inner.sample(3, 2, &mut r1);
        let b = wrapped.sample(3, 2, &mut r2);
        for i in 0..3 {
            for j in 0..2 {
                assert_eq!(a.comp(i, j), b.comp(i, j));
                assert_eq!(a.comm(i, j), b.comm(i, j));
            }
        }
    }

    #[test]
    fn preserves_marginal_mean() {
        let wrapped = WorkerCorrelated::new(ShiftedExponential::new(0.0, 2.0, 0.0, 2.0), 0.5);
        let mut r = rng();
        let mut acc = RunningStats::new();
        for _ in 0..100_000 {
            acc.push(wrapped.sample(1, 1, &mut r).comp(0, 0));
        }
        let want = 0.5; // 1/rate
        assert!(
            (acc.mean() - want).abs() < 0.02,
            "mean drifted: {}",
            acc.mean()
        );
    }

    #[test]
    fn induces_positive_within_worker_correlation() {
        let wrapped = WorkerCorrelated::new(ShiftedExponential::new(0.0, 2.0, 0.0, 2.0), 0.8);
        let mut r = rng();
        // correlation between slot 0 and slot 1 comp delays of worker 0
        let (mut sx, mut sy, mut sxy, mut sxx, mut syy) = (0.0, 0.0, 0.0, 0.0, 0.0);
        let n = 50_000;
        for _ in 0..n {
            let s = wrapped.sample(1, 2, &mut r);
            let (x, y) = (s.comp(0, 0), s.comp(0, 1));
            sx += x;
            sy += y;
            sxy += x * y;
            sxx += x * x;
            syy += y * y;
        }
        let nf = n as f64;
        let cov = sxy / nf - (sx / nf) * (sy / nf);
        let vx = sxx / nf - (sx / nf) * (sx / nf);
        let vy = syy / nf - (sy / nf) * (sy / nf);
        let rho = cov / (vx * vy).sqrt();
        assert!(rho > 0.2, "expected strong positive correlation, got {rho}");
    }

    #[test]
    fn workers_remain_independent() {
        let wrapped = WorkerCorrelated::new(ShiftedExponential::new(0.0, 2.0, 0.0, 2.0), 0.8);
        let mut r = rng();
        let (mut sx, mut sy, mut sxy, mut sxx, mut syy) = (0.0, 0.0, 0.0, 0.0, 0.0);
        let n = 50_000;
        for _ in 0..n {
            let s = wrapped.sample(2, 1, &mut r);
            let (x, y) = (s.comp(0, 0), s.comp(1, 0));
            sx += x;
            sy += y;
            sxy += x * y;
            sxx += x * x;
            syy += y * y;
        }
        let nf = n as f64;
        let cov = sxy / nf - (sx / nf) * (sy / nf);
        let vx = sxx / nf - (sx / nf) * (sx / nf);
        let vy = syy / nf - (sy / nf) * (sy / nf);
        let rho = cov / (vx * vy).sqrt();
        assert!(rho.abs() < 0.05, "workers should be independent, got {rho}");
    }
}
