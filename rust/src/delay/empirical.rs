//! Empirical (trace-driven) delay models, including the **EC2-like**
//! generator that substitutes for the paper's Amazon EC2 testbed.
//!
//! The paper measured per-task computation and communication delays of
//! `t2.micro` workers over 500 DGD iterations (Fig. 3) and found:
//!
//! * computation delays ≈ 1–5 ms, unimodal, mildly right-skewed;
//! * communication delays ≈ 2–11 ms — **much larger than computation**
//!   and more dispersed;
//! * workers are *not highly skewed* relative to each other (no
//!   persistent stragglers), but transient slowdowns occur.
//!
//! [`Ec2LikeModel`] reproduces exactly those features: per-worker base
//! delays drawn from a gamma-shaped distribution (right-skewed, strictly
//! positive), mild worker heterogeneity, and a small-probability
//! transient-straggle multiplier (the "non-persistent straggler" of the
//! paper's introduction).  [`EmpiricalModel`] replays arbitrary
//! measured traces (e.g. recorded by the [`crate::coordinator`] cluster)
//! by bootstrap resampling.

use crate::util::rng::Rng;



use super::{DelayBatch, DelayModel, DelaySample};

/// A bag of measured delays (ms) that can be resampled.
#[derive(Debug, Clone)]
pub struct Trace {
    pub samples: Vec<f64>,
}

impl Trace {
    pub fn new(samples: Vec<f64>) -> Self {
        assert!(!samples.is_empty(), "empty trace");
        assert!(
            samples.iter().all(|&s| s.is_finite() && s >= 0.0),
            "trace must contain finite non-negative delays"
        );
        Self { samples }
    }

    pub fn resample(&self, rng: &mut Rng) -> f64 {
        self.samples[rng.below(self.samples.len())]
    }

    pub fn mean(&self) -> f64 {
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }
}

/// Replays per-worker measured traces by bootstrap resampling — this is
/// how recorded cluster delays (Fig. 3 runs) feed back into the fast
/// Monte-Carlo engine.
#[derive(Debug, Clone)]
pub struct EmpiricalModel {
    pub comp: Vec<Trace>,
    pub comm: Vec<Trace>,
}

impl EmpiricalModel {
    pub fn new(comp: Vec<Trace>, comm: Vec<Trace>) -> Self {
        assert_eq!(comp.len(), comm.len(), "per-worker trace counts differ");
        assert!(!comp.is_empty(), "need at least one worker");
        Self { comp, comm }
    }
}

impl DelayModel for EmpiricalModel {
    fn name(&self) -> String {
        format!("empirical/{}-workers", self.comp.len())
    }

    fn sample_into(&self, out: &mut DelaySample, rng: &mut Rng) {
        let (n, r) = (out.n, out.r);
        assert!(n <= self.comp.len(), "trace set smaller than n");
        for i in 0..n {
            for j in 0..r {
                out.comp_mut()[i * r + j] = self.comp[i].resample(rng);
                out.comm_mut()[i * r + j] = self.comm[i].resample(rng);
            }
        }
    }

    /// Batched bootstrap resampling: same `(comp, comm)`-interleaved
    /// draw order per slot as [`EmpiricalModel::sample_into`]
    /// (bit-identity contract), with the per-worker trace borrows and
    /// the shape check hoisted out of the round loop.
    fn sample_batch_into(&self, out: &mut DelayBatch, rng: &mut Rng) {
        let (n, r) = (out.n, out.r);
        assert!(n <= self.comp.len(), "trace set smaller than n");
        let traces: Vec<(&Trace, &Trace)> =
            (0..n).map(|i| (&self.comp[i], &self.comm[i])).collect();
        for b in 0..out.rounds {
            let (comp, comm) = out.round_mut(b);
            for (i, &(tc, tm)) in traces.iter().enumerate() {
                let base = i * r;
                for j in 0..r {
                    comp[base + j] = tc.resample(rng);
                    comm[base + j] = tm.resample(rng);
                }
            }
        }
    }

    fn mean_comp(&self, worker: usize) -> Option<f64> {
        self.comp.get(worker).map(Trace::mean)
    }

    fn mean_comm(&self, worker: usize) -> Option<f64> {
        self.comm.get(worker).map(Trace::mean)
    }
}

/// Marsaglia–Tsang gamma sampler (shape ≥ 1 fast path; shape < 1 via the
/// boost trick).  Local helper — `rand_distr::Gamma` exists, but the
/// empirical generator wants a deterministic, dependency-thin pipeline
/// whose numerics the tests can assert directly.
fn sample_gamma(shape: f64, scale: f64, rng: &mut Rng) -> f64 {
    assert!(shape > 0.0 && scale > 0.0);
    if shape < 1.0 {
        // Γ(a) = Γ(a+1) · U^{1/a}
        let u: f64 = rng.f64().max(1e-300);
        return sample_gamma(shape + 1.0, scale, rng) * u.powf(1.0 / shape);
    }
    let d = shape - 1.0 / 3.0;
    let c = 1.0 / (9.0 * d).sqrt();
    loop {
        // standard normal via Box–Muller (self-contained)
        let u1: f64 = rng.f64().max(1e-300);
        let u2 = rng.f64();
        let x = (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
        let v = 1.0 + c * x;
        if v <= 0.0 {
            continue;
        }
        let v3 = v * v * v;
        let u: f64 = rng.f64().max(1e-300);
        if u.ln() < 0.5 * x * x + d - d * v3 + d * v3.ln() {
            return d * v3 * scale;
        }
    }
}

/// EC2-like synthetic delay generator (the testbed substitute).
///
/// Per worker `i`:
/// * computation delay  `T⁽¹⁾ = base1_i · Gamma(k₁, 1)/k₁ · S`
/// * communication delay `T⁽²⁾ = base2_i · Gamma(k₂, 1)/k₂ · S`
///
/// with gamma shapes `k₁ = 12` (tight, mildly skewed compute) and
/// `k₂ = 10` (moderately dispersed network — Fig. 3's comm spread), worker base delays spread
/// by the `hetero` factor around 1.6 ms / 5.5 ms (Fig. 3 centers), and
/// `S` a transient straggle multiplier: with prob. 5 % the whole *round*
/// of a worker is slowed 1.5–2.5× (non-persistent straggling — the slot
/// delays of one worker in one round are correlated, which the paper's
/// model explicitly allows).
#[derive(Debug, Clone)]
pub struct Ec2LikeModel {
    base_comp: Vec<f64>,
    base_comm: Vec<f64>,
    straggle_prob: f64,
    straggle_lo: f64,
    straggle_hi: f64,
}

impl Ec2LikeModel {
    /// `hetero ∈ [0, 1)`: relative spread of per-worker base speeds
    /// (0 = identical workers; paper's Fig. 3 suggests ≈ 0.15–0.3).
    pub fn new(n: usize, seed: u64, hetero: f64) -> Self {
        
        assert!((0.0..1.0).contains(&hetero), "hetero must be in [0,1)");
        let mut rng = Rng::seed_from_u64(seed ^ 0xEC2_EC2);
        // Fig. 3 centers: computation ≈ 1.6 ms, communication ≈ 5.5 ms
        let base_comp = (0..n)
            .map(|_| 1.6 * (1.0 + hetero * (rng.f64() * 2.0 - 1.0)))
            .collect();
        let base_comm = (0..n)
            .map(|_| 5.5 * (1.0 + hetero * (rng.f64() * 2.0 - 1.0)))
            .collect();
        Self {
            base_comp,
            base_comm,
            straggle_prob: 0.05,
            straggle_lo: 1.5,
            straggle_hi: 2.5,
        }
    }

    pub fn n_workers(&self) -> usize {
        self.base_comp.len()
    }
}

impl DelayModel for Ec2LikeModel {
    fn name(&self) -> String {
        format!("ec2-like/{}-workers", self.n_workers())
    }

    fn sample_into(&self, out: &mut DelaySample, rng: &mut Rng) {
        let (n, r) = (out.n, out.r);
        assert!(n <= self.n_workers(), "model built for fewer workers");
        const K_COMP: f64 = 12.0;
        const K_COMM: f64 = 10.0;
        for i in 0..n {
            // transient per-round straggle multiplier (correlates the
            // slots of this worker within the round)
            let s = if rng.f64() < self.straggle_prob {
                self.straggle_lo + rng.f64() * (self.straggle_hi - self.straggle_lo)
            } else {
                1.0
            };
            for j in 0..r {
                out.comp_mut()[i * r + j] =
                    self.base_comp[i] * sample_gamma(K_COMP, 1.0 / K_COMP, rng) * s;
                out.comm_mut()[i * r + j] =
                    self.base_comm[i] * sample_gamma(K_COMM, 1.0 / K_COMM, rng) * s;
            }
        }
    }

    /// Batched sampling: identical draw order to
    /// [`Ec2LikeModel::sample_into`] — per worker one straggle draw,
    /// then `(comp, comm)` gamma pairs per slot — with base delays
    /// hoisted and writes going into contiguous round slices.
    fn sample_batch_into(&self, out: &mut DelayBatch, rng: &mut Rng) {
        let (n, r) = (out.n, out.r);
        assert!(n <= self.n_workers(), "model built for fewer workers");
        const K_COMP: f64 = 12.0;
        const K_COMM: f64 = 10.0;
        for b in 0..out.rounds {
            let (comp, comm) = out.round_mut(b);
            for i in 0..n {
                let s = if rng.f64() < self.straggle_prob {
                    self.straggle_lo + rng.f64() * (self.straggle_hi - self.straggle_lo)
                } else {
                    1.0
                };
                let (base_comp, base_comm) = (self.base_comp[i], self.base_comm[i]);
                let base = i * r;
                for j in 0..r {
                    comp[base + j] = base_comp * sample_gamma(K_COMP, 1.0 / K_COMP, rng) * s;
                    comm[base + j] = base_comm * sample_gamma(K_COMM, 1.0 / K_COMM, rng) * s;
                }
            }
        }
    }

    fn mean_comp(&self, worker: usize) -> Option<f64> {
        // E[S] = 1·0.95 + 3·0.05 (mean multiplier 3 on straggle rounds)
        let es = 1.0 - self.straggle_prob
            + self.straggle_prob * 0.5 * (self.straggle_lo + self.straggle_hi);
        self.base_comp.get(worker).map(|b| b * es)
    }

    fn mean_comm(&self, worker: usize) -> Option<f64> {
        let es = 1.0 - self.straggle_prob
            + self.straggle_prob * 0.5 * (self.straggle_lo + self.straggle_hi);
        self.base_comm.get(worker).map(|b| b * es)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::RunningStats;
    

    fn rng() -> Rng {
        Rng::seed_from_u64(0xDEADBEE)
    }

    #[test]
    fn gamma_moments() {
        let mut r = rng();
        for (shape, scale) in [(0.7, 2.0), (4.0, 0.5), (12.0, 1.0 / 12.0)] {
            let mut acc = RunningStats::new();
            for _ in 0..200_000 {
                acc.push(sample_gamma(shape, scale, &mut r));
            }
            let want_mean = shape * scale;
            let want_var = shape * scale * scale;
            assert!(
                (acc.mean() - want_mean).abs() < 6.0 * acc.std_err() + 1e-3,
                "mean for shape {shape}: {} vs {want_mean}",
                acc.mean()
            );
            assert!(
                (acc.variance() - want_var).abs() / want_var < 0.05,
                "var for shape {shape}"
            );
        }
    }

    #[test]
    fn trace_resample_stays_in_support() {
        let t = Trace::new(vec![1.0, 2.0, 3.0]);
        let mut r = rng();
        for _ in 0..1000 {
            let x = t.resample(&mut r);
            assert!(x == 1.0 || x == 2.0 || x == 3.0);
        }
        assert!((t.mean() - 2.0).abs() < 1e-12);
    }

    #[test]
    #[should_panic(expected = "empty trace")]
    fn trace_rejects_empty() {
        Trace::new(vec![]);
    }

    #[test]
    fn ec2_comm_dominates_comp() {
        // Fig. 3's headline observation: communication is the bottleneck
        let m = Ec2LikeModel::new(3, 7, 0.2);
        let mut r = rng();
        let mut comp = RunningStats::new();
        let mut comm = RunningStats::new();
        for _ in 0..5_000 {
            let s = m.sample(3, 1, &mut r);
            for i in 0..3 {
                comp.push(s.comp(i, 0));
                comm.push(s.comm(i, 0));
            }
        }
        assert!(
            comm.mean() > 2.0 * comp.mean(),
            "comm {} should dominate comp {}",
            comm.mean(),
            comp.mean()
        );
        // Fig. 3 ranges: comp ∈ ~[1,5] ms, comm ∈ ~[2,11] ms
        assert!(comp.mean() > 1.0 && comp.mean() < 3.0, "{}", comp.mean());
        assert!(comm.mean() > 4.0 && comm.mean() < 8.0, "{}", comm.mean());
    }

    #[test]
    fn ec2_right_skewed() {
        let m = Ec2LikeModel::new(1, 11, 0.0);
        let mut r = rng();
        let mut xs: Vec<f64> = Vec::new();
        for _ in 0..20_000 {
            xs.push(m.sample(1, 1, &mut r).comm(0, 0));
        }
        xs.sort_by(f64::total_cmp);
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let median = xs[xs.len() / 2];
        assert!(mean > median, "right skew: mean {mean} > median {median}");
    }

    #[test]
    fn ec2_deterministic_in_seed() {
        let a = Ec2LikeModel::new(5, 42, 0.3);
        let b = Ec2LikeModel::new(5, 42, 0.3);
        assert_eq!(a.base_comp, b.base_comp);
        assert_eq!(a.base_comm, b.base_comm);
        let c = Ec2LikeModel::new(5, 43, 0.3);
        assert_ne!(a.base_comp, c.base_comp);
    }

    #[test]
    fn ec2_mean_estimate_close_to_analytic() {
        let m = Ec2LikeModel::new(2, 5, 0.0);
        let mut r = rng();
        let mut acc = RunningStats::new();
        for _ in 0..50_000 {
            acc.push(m.sample(2, 1, &mut r).comp(0, 0));
        }
        let want = m.mean_comp(0).unwrap();
        assert!(
            (acc.mean() - want).abs() / want < 0.03,
            "{} vs {want}",
            acc.mean()
        );
    }
}
