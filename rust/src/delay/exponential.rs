//! Shifted-exponential delay model.
//!
//! The workhorse model of the coded-computation literature (Lee et al.
//! [3] and most follow-ups model worker latency as `shift + Exp(rate)`),
//! included both as an ablation and because the r = 1 case admits a
//! *closed-form* completion-time CDF (hypoexponential sums) that the
//! [`crate::analysis`] module uses to validate the Monte-Carlo engine
//! against exact numbers.

use crate::util::rng::Rng;



use super::{DelayBatch, DelayModel, DelaySample};

/// `T = shift + Exp(rate)`; rate in 1/ms, shift in ms.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ShiftedExp {
    pub shift: f64,
    pub rate: f64,
}

impl ShiftedExp {
    pub fn new(shift: f64, rate: f64) -> Self {
        assert!(rate > 0.0, "rate must be positive");
        assert!(shift >= 0.0, "negative shift would allow negative delays");
        Self { shift, rate }
    }

    pub fn sample(&self, rng: &mut Rng) -> f64 {
        // inverse CDF; 1−U ∈ (0,1] avoids ln(0)
        let u = rng.f64();
        self.shift - (1.0 - u).max(1e-300).ln() / self.rate
    }

    pub fn mean(&self) -> f64 {
        self.shift + 1.0 / self.rate
    }

    /// Survival function `Pr{T > t}`.
    pub fn sf(&self, t: f64) -> f64 {
        if t <= self.shift {
            1.0
        } else {
            (-(t - self.shift) * self.rate).exp()
        }
    }
}

/// All workers share `comp` and `comm` shifted exponentials, i.i.d.
/// across slots.
#[derive(Debug, Clone)]
pub struct ShiftedExponential {
    pub comp: ShiftedExp,
    pub comm: ShiftedExp,
}

impl ShiftedExponential {
    pub fn new(comp_shift: f64, comp_rate: f64, comm_shift: f64, comm_rate: f64) -> Self {
        Self {
            comp: ShiftedExp::new(comp_shift, comp_rate),
            comm: ShiftedExp::new(comm_shift, comm_rate),
        }
    }
}

impl DelayModel for ShiftedExponential {
    fn name(&self) -> String {
        format!(
            "shifted-exp/comp({:.3}+Exp({:.3}))/comm({:.3}+Exp({:.3}))",
            self.comp.shift, self.comp.rate, self.comm.shift, self.comm.rate
        )
    }

    fn sample_into(&self, out: &mut DelaySample, rng: &mut Rng) {
        let total = out.n * out.r;
        for idx in 0..total {
            out.comp_mut()[idx] = self.comp.sample(rng);
        }
        for idx in 0..total {
            out.comm_mut()[idx] = self.comm.sample(rng);
        }
    }

    /// Batched sampling: per round, all computation delays then all
    /// communication delays — the same order as
    /// [`ShiftedExponential::sample_into`] (bit-identity contract) —
    /// with shift/rate hoisted into registers and the inverse-CDF
    /// transform inlined over each round's contiguous slice.
    fn sample_batch_into(&self, out: &mut DelayBatch, rng: &mut Rng) {
        let (comp_shift, comp_rate) = (self.comp.shift, self.comp.rate);
        let (comm_shift, comm_rate) = (self.comm.shift, self.comm.rate);
        for b in 0..out.rounds {
            let (comp, comm) = out.round_mut(b);
            for v in comp.iter_mut() {
                // identical expression to ShiftedExp::sample
                let u = rng.f64();
                *v = comp_shift - (1.0 - u).max(1e-300).ln() / comp_rate;
            }
            for v in comm.iter_mut() {
                let u = rng.f64();
                *v = comm_shift - (1.0 - u).max(1e-300).ln() / comm_rate;
            }
        }
    }

    fn mean_comp(&self, _worker: usize) -> Option<f64> {
        Some(self.comp.mean())
    }

    fn mean_comm(&self, _worker: usize) -> Option<f64> {
        Some(self.comm.mean())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::RunningStats;
    

    #[test]
    fn sample_mean_matches_analytic() {
        let d = ShiftedExp::new(0.2, 4.0);
        let mut rng = Rng::seed_from_u64(17);
        let mut acc = RunningStats::new();
        for _ in 0..200_000 {
            acc.push(d.sample(&mut rng));
        }
        assert!((acc.mean() - d.mean()).abs() < 5.0 * acc.std_err());
    }

    #[test]
    fn samples_at_least_shift() {
        let d = ShiftedExp::new(0.5, 1.0);
        let mut rng = Rng::seed_from_u64(3);
        for _ in 0..10_000 {
            assert!(d.sample(&mut rng) >= 0.5);
        }
    }

    #[test]
    fn survival_function() {
        let d = ShiftedExp::new(1.0, 2.0);
        assert_eq!(d.sf(0.5), 1.0);
        assert_eq!(d.sf(1.0), 1.0);
        assert!((d.sf(2.0) - (-2.0f64).exp()).abs() < 1e-15);
    }

    #[test]
    #[should_panic(expected = "rate must be positive")]
    fn rejects_bad_rate() {
        ShiftedExp::new(0.0, 0.0);
    }

    #[test]
    fn empirical_sf_matches() {
        let d = ShiftedExp::new(0.1, 3.0);
        let mut rng = Rng::seed_from_u64(99);
        let t = 0.45;
        let n = 100_000;
        let over = (0..n).filter(|_| d.sample(&mut rng) > t).count();
        let emp = over as f64 / n as f64;
        assert!((emp - d.sf(t)).abs() < 0.01, "{emp} vs {}", d.sf(t));
    }
}
