//! Shifted-exponential delay model.
//!
//! The workhorse model of the coded-computation literature (Lee et al.
//! [3] and most follow-ups model worker latency as `shift + Exp(rate)`),
//! included both as an ablation and because the r = 1 case admits a
//! *closed-form* completion-time CDF (hypoexponential sums) that the
//! [`crate::analysis`] module uses to validate the Monte-Carlo engine
//! against exact numbers.

use crate::util::rng::Rng;



use super::{DelayBatch, DelayModel, DelaySample};

/// `T = shift + Exp(rate)`; rate in 1/ms, shift in ms.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ShiftedExp {
    pub shift: f64,
    pub rate: f64,
}

impl ShiftedExp {
    pub fn new(shift: f64, rate: f64) -> Self {
        assert!(rate > 0.0, "rate must be positive");
        assert!(shift >= 0.0, "negative shift would allow negative delays");
        Self { shift, rate }
    }

    pub fn sample(&self, rng: &mut Rng) -> f64 {
        // inverse CDF; 1−U ∈ (0,1] avoids ln(0)
        let u = rng.f64();
        self.shift - (1.0 - u).max(1e-300).ln() / self.rate
    }

    pub fn mean(&self) -> f64 {
        self.shift + 1.0 / self.rate
    }

    /// Survival function `Pr{T > t}`.
    pub fn sf(&self, t: f64) -> f64 {
        if t <= self.shift {
            1.0
        } else {
            (-(t - self.shift) * self.rate).exp()
        }
    }
}

/// All workers share `comp` and `comm` shifted exponentials, i.i.d.
/// across slots.
#[derive(Debug, Clone)]
pub struct ShiftedExponential {
    pub comp: ShiftedExp,
    pub comm: ShiftedExp,
}

impl ShiftedExponential {
    pub fn new(comp_shift: f64, comp_rate: f64, comm_shift: f64, comm_rate: f64) -> Self {
        Self {
            comp: ShiftedExp::new(comp_shift, comp_rate),
            comm: ShiftedExp::new(comm_shift, comm_rate),
        }
    }
}

impl DelayModel for ShiftedExponential {
    fn name(&self) -> String {
        format!(
            "shifted-exp/comp({:.3}+Exp({:.3}))/comm({:.3}+Exp({:.3}))",
            self.comp.shift, self.comp.rate, self.comm.shift, self.comm.rate
        )
    }

    fn sample_into(&self, out: &mut DelaySample, rng: &mut Rng) {
        let total = out.n * out.r;
        for idx in 0..total {
            out.comp_mut()[idx] = self.comp.sample(rng);
        }
        for idx in 0..total {
            out.comm_mut()[idx] = self.comm.sample(rng);
        }
    }

    /// Batched sampling: per round, all computation delays then all
    /// communication delays — the same order as
    /// [`ShiftedExponential::sample_into`] (bit-identity contract) —
    /// with shift/rate hoisted into registers and the inverse-CDF
    /// transform inlined over each round's contiguous slice.
    fn sample_batch_into(&self, out: &mut DelayBatch, rng: &mut Rng) {
        let (comp_shift, comp_rate) = (self.comp.shift, self.comp.rate);
        let (comm_shift, comm_rate) = (self.comm.shift, self.comm.rate);
        for b in 0..out.rounds {
            let (comp, comm) = out.round_mut(b);
            for v in comp.iter_mut() {
                // identical expression to ShiftedExp::sample
                let u = rng.f64();
                *v = comp_shift - (1.0 - u).max(1e-300).ln() / comp_rate;
            }
            for v in comm.iter_mut() {
                let u = rng.f64();
                *v = comm_shift - (1.0 - u).max(1e-300).ln() / comm_rate;
            }
        }
    }

    fn mean_comp(&self, _worker: usize) -> Option<f64> {
        Some(self.comp.mean())
    }

    fn mean_comm(&self, _worker: usize) -> Option<f64> {
        Some(self.comm.mean())
    }
}

/// Per-worker shifted-exponential delays, i.i.d. across a worker's
/// slots — the parametric fleet model the trace subsystem's fitting
/// layer emits ([`crate::trace::FleetFit::shifted_exp_model`]): worker
/// `i` draws computation delays from `comp[i]` and communication
/// delays from `comm[i]`.
#[derive(Debug, Clone)]
pub struct PerWorkerShiftedExp {
    pub comp: Vec<ShiftedExp>,
    pub comm: Vec<ShiftedExp>,
    label: String,
}

impl PerWorkerShiftedExp {
    pub fn new(comp: Vec<ShiftedExp>, comm: Vec<ShiftedExp>, label: &str) -> Self {
        assert_eq!(comp.len(), comm.len(), "per-worker param counts differ");
        assert!(!comp.is_empty(), "need at least one worker");
        Self {
            comp,
            comm,
            label: label.to_string(),
        }
    }

    pub fn n_workers(&self) -> usize {
        self.comp.len()
    }
}

impl DelayModel for PerWorkerShiftedExp {
    fn name(&self) -> String {
        format!("{}/{}-workers", self.label, self.n_workers())
    }

    fn sample_into(&self, out: &mut DelaySample, rng: &mut Rng) {
        let (n, r) = (out.n, out.r);
        assert!(n <= self.n_workers(), "model built for fewer workers");
        for i in 0..n {
            let (dc, dm) = (self.comp[i], self.comm[i]);
            for j in 0..r {
                out.comp_mut()[i * r + j] = dc.sample(rng);
                out.comm_mut()[i * r + j] = dm.sample(rng);
            }
        }
    }

    /// Batched sampling: identical `(comp, comm)`-interleaved draw
    /// order per slot as [`PerWorkerShiftedExp::sample_into`] (the
    /// bit-identity contract), writing into contiguous round slices.
    fn sample_batch_into(&self, out: &mut DelayBatch, rng: &mut Rng) {
        let (n, r) = (out.n, out.r);
        assert!(n <= self.n_workers(), "model built for fewer workers");
        let params: Vec<(ShiftedExp, ShiftedExp)> =
            (0..n).map(|i| (self.comp[i], self.comm[i])).collect();
        for b in 0..out.rounds {
            let (comp, comm) = out.round_mut(b);
            for (i, &(dc, dm)) in params.iter().enumerate() {
                let base = i * r;
                for j in 0..r {
                    comp[base + j] = dc.sample(rng);
                    comm[base + j] = dm.sample(rng);
                }
            }
        }
    }

    fn mean_comp(&self, worker: usize) -> Option<f64> {
        self.comp.get(worker).map(ShiftedExp::mean)
    }

    fn mean_comm(&self, worker: usize) -> Option<f64> {
        self.comm.get(worker).map(ShiftedExp::mean)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::RunningStats;


    #[test]
    fn sample_mean_matches_analytic() {
        let d = ShiftedExp::new(0.2, 4.0);
        let mut rng = Rng::seed_from_u64(17);
        let mut acc = RunningStats::new();
        for _ in 0..200_000 {
            acc.push(d.sample(&mut rng));
        }
        assert!((acc.mean() - d.mean()).abs() < 5.0 * acc.std_err());
    }

    #[test]
    fn samples_at_least_shift() {
        let d = ShiftedExp::new(0.5, 1.0);
        let mut rng = Rng::seed_from_u64(3);
        for _ in 0..10_000 {
            assert!(d.sample(&mut rng) >= 0.5);
        }
    }

    #[test]
    fn survival_function() {
        let d = ShiftedExp::new(1.0, 2.0);
        assert_eq!(d.sf(0.5), 1.0);
        assert_eq!(d.sf(1.0), 1.0);
        assert!((d.sf(2.0) - (-2.0f64).exp()).abs() < 1e-15);
    }

    #[test]
    #[should_panic(expected = "rate must be positive")]
    fn rejects_bad_rate() {
        ShiftedExp::new(0.0, 0.0);
    }

    #[test]
    fn per_worker_model_respects_parameters() {
        let m = PerWorkerShiftedExp::new(
            vec![ShiftedExp::new(0.1, 10.0), ShiftedExp::new(0.4, 2.0)],
            vec![ShiftedExp::new(0.3, 5.0), ShiftedExp::new(0.3, 5.0)],
            "fitted/shifted-exp",
        );
        assert!(m.name().contains("fitted/shifted-exp"));
        assert_eq!(m.mean_comp(0), Some(0.2));
        assert_eq!(m.mean_comp(1), Some(0.9));
        let mut rng = Rng::seed_from_u64(5);
        let mut acc = RunningStats::new();
        for _ in 0..5000 {
            let s = m.sample(2, 2, &mut rng);
            assert!(s.comp(0, 0) >= 0.1 && s.comp(1, 1) >= 0.4, "shift floors");
            acc.push(s.comp(1, 0));
        }
        assert!((acc.mean() - 0.9).abs() < 6.0 * acc.std_err());
    }

    #[test]
    fn per_worker_batch_matches_sequential() {
        let m = PerWorkerShiftedExp::new(
            vec![ShiftedExp::new(0.1, 4.0); 3],
            vec![ShiftedExp::new(0.2, 3.0); 3],
            "fitted/shifted-exp",
        );
        let (rounds, n, r) = (5usize, 3usize, 2usize);
        let mut rng_a = Rng::seed_from_u64(0xFEED);
        let mut rng_b = Rng::seed_from_u64(0xFEED);
        let batch = m.sample_batch(rounds, n, r, &mut rng_a);
        let mut tmp = DelaySample::zeros(n, r);
        for b in 0..rounds {
            m.sample_into(&mut tmp, &mut rng_b);
            assert_eq!(batch.comp_round(b), tmp.comp_flat(), "b={b}");
            assert_eq!(batch.comm_round(b), tmp.comm_flat(), "b={b}");
        }
    }

    #[test]
    fn empirical_sf_matches() {
        let d = ShiftedExp::new(0.1, 3.0);
        let mut rng = Rng::seed_from_u64(99);
        let t = 0.45;
        let n = 100_000;
        let over = (0..n).filter(|_| d.sample(&mut rng) > t).count();
        let emp = over as f64 / n as f64;
        assert!((emp - d.sf(t)).abs() < 0.01, "{emp} vs {}", d.sf(t));
    }
}
