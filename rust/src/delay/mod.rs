//! Stochastic delay substrate (paper §II, §VI-C).
//!
//! A [`DelayModel`] produces, per round, a matrix of **per-slot** delays:
//! `comp[i][j]` is the computation delay of the `j`-th *computation slot*
//! of worker `i` and `comm[i][j]` the communication delay of shipping
//! that slot's result to the master.  Sampling per slot rather than per
//! `(worker, task)` pair is faithful to the paper: delay statistics do
//! not depend on which task occupies the slot (Remark 6 — equal task
//! size/complexity), and delays across workers are independent (§II).
//! Correlation between slots *of the same worker* — explicitly allowed
//! by the paper's model — is provided by [`correlated::WorkerCorrelated`].
//!
//! All delays are milliseconds.  The paper's `αEβ` notation means
//! `α·10⁻ᵝ` seconds, so its §VI-C scenario constants convert as
//! `1E4 → 0.1 ms`, `5E4 → 0.5 ms`, `3E5 → 0.03 ms`.

pub mod correlated;
pub mod empirical;
pub mod exponential;
pub mod scaled;
pub mod truncated_gaussian;

pub use correlated::WorkerCorrelated;
pub use empirical::{Ec2LikeModel, EmpiricalModel, Trace};
pub use exponential::{PerWorkerShiftedExp, ShiftedExponential};
pub use scaled::Scaled;
pub use truncated_gaussian::{TruncatedGaussian, TruncatedGaussianModel};

use crate::util::rng::Rng;


/// One round's worth of per-slot delays for `n` workers × `r` slots.
///
/// Flat row-major storage: slot `(i, j)` lives at `i * r + j`.
#[derive(Debug, Clone)]
pub struct DelaySample {
    pub n: usize,
    pub r: usize,
    comp: Vec<f64>,
    comm: Vec<f64>,
}

impl DelaySample {
    pub fn zeros(n: usize, r: usize) -> Self {
        Self {
            n,
            r,
            comp: vec![0.0; n * r],
            comm: vec![0.0; n * r],
        }
    }

    /// Build from explicit matrices (tests, deterministic scenarios).
    pub fn from_rows(comp: Vec<Vec<f64>>, comm: Vec<Vec<f64>>) -> Self {
        let n = comp.len();
        assert_eq!(n, comm.len(), "comp/comm worker counts differ");
        let r = comp.first().map_or(0, Vec::len);
        let mut flat_comp = Vec::with_capacity(n * r);
        let mut flat_comm = Vec::with_capacity(n * r);
        for (c1, c2) in comp.iter().zip(&comm) {
            assert_eq!(c1.len(), r, "ragged comp row");
            assert_eq!(c2.len(), r, "ragged comm row");
            flat_comp.extend_from_slice(c1);
            flat_comm.extend_from_slice(c2);
        }
        Self {
            n,
            r,
            comp: flat_comp,
            comm: flat_comm,
        }
    }

    #[inline]
    pub fn comp(&self, worker: usize, slot: usize) -> f64 {
        self.comp[worker * self.r + slot]
    }

    #[inline]
    pub fn comm(&self, worker: usize, slot: usize) -> f64 {
        self.comm[worker * self.r + slot]
    }

    #[inline]
    pub fn comp_row(&self, worker: usize) -> &[f64] {
        &self.comp[worker * self.r..(worker + 1) * self.r]
    }

    #[inline]
    pub fn comm_row(&self, worker: usize) -> &[f64] {
        &self.comm[worker * self.r..(worker + 1) * self.r]
    }

    #[inline]
    pub fn comp_mut(&mut self) -> &mut [f64] {
        &mut self.comp
    }

    #[inline]
    pub fn comm_mut(&mut self) -> &mut [f64] {
        &mut self.comm
    }

    /// All `n × r` computation delays, row-major.
    #[inline]
    pub fn comp_flat(&self) -> &[f64] {
        &self.comp
    }

    /// All `n × r` communication delays, row-major.
    #[inline]
    pub fn comm_flat(&self) -> &[f64] {
        &self.comm
    }

    /// Arrival time at the master of worker `i`'s `j`-th slot (eq. 1/46):
    /// prefix sum of its computation delays plus that slot's comm delay.
    pub fn slot_arrival(&self, worker: usize, slot: usize) -> f64 {
        let row = self.comp_row(worker);
        let prefix: f64 = row[..=slot].iter().sum();
        prefix + self.comm(worker, slot)
    }
}

/// A batch of `rounds` independent delay realizations in
/// structure-of-arrays form — the unit of work of the batched
/// Monte-Carlo engine (`sim::batch`).
///
/// Flat round-major storage: round `b`'s slot `(i, j)` lives at
/// `b·n·r + i·r + j` in both `comp` and `comm`, so one round is a
/// single contiguous `n·r` slice and a whole batch is two contiguous
/// allocations regardless of `rounds` — no per-round `Vec`s, no
/// pointer chasing in the completion kernel.
#[derive(Debug, Clone)]
pub struct DelayBatch {
    pub rounds: usize,
    pub n: usize,
    pub r: usize,
    comp: Vec<f64>,
    comm: Vec<f64>,
}

impl DelayBatch {
    pub fn zeros(rounds: usize, n: usize, r: usize) -> Self {
        Self {
            rounds,
            n,
            r,
            comp: vec![0.0; rounds * n * r],
            comm: vec![0.0; rounds * n * r],
        }
    }

    /// Slots per round (`n · r`).
    #[inline]
    pub fn stride(&self) -> usize {
        self.n * self.r
    }

    /// Round `b`'s computation delays (`n · r` contiguous values).
    #[inline]
    pub fn comp_round(&self, b: usize) -> &[f64] {
        let s = self.stride();
        &self.comp[b * s..(b + 1) * s]
    }

    /// Round `b`'s communication delays.
    #[inline]
    pub fn comm_round(&self, b: usize) -> &[f64] {
        let s = self.stride();
        &self.comm[b * s..(b + 1) * s]
    }

    /// Mutable views of round `b`'s computation and communication delays.
    #[inline]
    pub fn round_mut(&mut self, b: usize) -> (&mut [f64], &mut [f64]) {
        let s = self.stride();
        (
            &mut self.comp[b * s..(b + 1) * s],
            &mut self.comm[b * s..(b + 1) * s],
        )
    }

    /// The whole batch's computation delays (round-major).
    #[inline]
    pub fn comp_flat(&self) -> &[f64] {
        &self.comp
    }

    /// The whole batch's communication delays (round-major).
    #[inline]
    pub fn comm_flat(&self) -> &[f64] {
        &self.comm
    }

    #[inline]
    pub fn comp_flat_mut(&mut self) -> &mut [f64] {
        &mut self.comp
    }

    #[inline]
    pub fn comm_flat_mut(&mut self) -> &mut [f64] {
        &mut self.comm
    }

    /// Copy one round in from a [`DelaySample`] (the per-round fallback
    /// bridge of [`DelayModel::sample_batch_into`]).
    pub fn copy_round_from_sample(&mut self, b: usize, sample: &DelaySample) {
        assert_eq!(sample.n, self.n, "sample shaped for different n");
        assert_eq!(sample.r, self.r, "sample shaped for different r");
        let (comp, comm) = self.round_mut(b);
        comp.copy_from_slice(&sample.comp);
        comm.copy_from_slice(&sample.comm);
    }

    /// Materialize round `b` as an owned [`DelaySample`] (tests and
    /// slow paths; the hot kernels read the slices directly).
    pub fn round_sample(&self, b: usize) -> DelaySample {
        DelaySample {
            n: self.n,
            r: self.r,
            comp: self.comp_round(b).to_vec(),
            comm: self.comm_round(b).to_vec(),
        }
    }
}

/// A source of per-round delay samples.
///
/// `sample_into` must fill **all** `n × r` slots.  Models are `Send +
/// Sync` so Monte-Carlo sweeps can shard rounds across threads (each
/// thread owns its RNG).
pub trait DelayModel: Send + Sync {
    /// Human-readable name used in reports.
    fn name(&self) -> String;

    /// Fill `out` (already shaped `n × r`) with fresh delays.
    fn sample_into(&self, out: &mut DelaySample, rng: &mut Rng);

    /// Convenience allocating wrapper.
    fn sample(&self, n: usize, r: usize, rng: &mut Rng) -> DelaySample {
        let mut out = DelaySample::zeros(n, r);
        self.sample_into(&mut out, rng);
        out
    }

    /// Fill **all** `rounds × n × r` slots of a [`DelayBatch`].
    ///
    /// Contract (property-tested per model in
    /// `rust/tests/batch_engine.rs`): the produced delays and the RNG
    /// stream consumed must be **bit-identical** to `out.rounds`
    /// sequential [`DelayModel::sample_into`] calls on a sample of the
    /// same shape.  This is what lets the batched Monte-Carlo engine
    /// reproduce the scalar engine exactly for a fixed
    /// `(trials, threads, seed)` triple while chunking rounds freely.
    ///
    /// The default falls back to exactly that sequential loop; models
    /// override it to hoist virtual dispatch and per-distribution
    /// constants out of the round loop and write straight into the
    /// batch's contiguous storage.
    fn sample_batch_into(&self, out: &mut DelayBatch, rng: &mut Rng) {
        let mut tmp = DelaySample::zeros(out.n, out.r);
        for b in 0..out.rounds {
            self.sample_into(&mut tmp, rng);
            out.copy_round_from_sample(b, &tmp);
        }
    }

    /// Convenience allocating wrapper around
    /// [`DelayModel::sample_batch_into`].
    fn sample_batch(&self, rounds: usize, n: usize, r: usize, rng: &mut Rng) -> DelayBatch {
        let mut out = DelayBatch::zeros(rounds, n, r);
        self.sample_batch_into(&mut out, rng);
        out
    }

    /// Mean computation delay of one slot at `worker` (for reports and
    /// roofline sanity checks); `None` if unknown analytically.
    fn mean_comp(&self, _worker: usize) -> Option<f64> {
        None
    }

    /// Mean communication delay of one slot at `worker`.
    fn mean_comm(&self, _worker: usize) -> Option<f64> {
        None
    }
}

/// Config-serializable delay-model description; the harness builds the
/// trait object from this (single source of truth for CLI + configs).
#[derive(Debug, Clone)]
pub enum DelayModelKind {
    /// Paper §VI-C scenario 1: homogeneous truncated Gaussians.
    TruncatedGaussianScenario1,
    /// Paper §VI-C scenario 2: heterogeneous (permuted means).
    TruncatedGaussianScenario2 { seed: u64 },
    /// Explicit truncated-Gaussian parameters, shared by all workers.
    TruncatedGaussian {
        comp: TruncatedGaussian,
        comm: TruncatedGaussian,
    },
    /// Shifted exponential comp/comm (rate per ms).
    ShiftedExponential {
        comp_shift: f64,
        comp_rate: f64,
        comm_shift: f64,
        comm_rate: f64,
    },
    /// EC2-like empirical traces (the paper's testbed substitute).
    Ec2Like { seed: u64, hetero: f64 },
    /// Deterministic per-slot delays — every slot takes exactly
    /// `comp_ms`/`comm_ms`, except the optional `straggler`, whose
    /// delays are scaled by `factor`.  Consumes no randomness, so
    /// latency-anatomy tests can assert recovered phase splits against
    /// exact ground truth.
    Fixed {
        comp_ms: f64,
        comm_ms: f64,
        straggler: Option<usize>,
        factor: f64,
    },
}

impl DelayModelKind {
    /// Materialize the model for `n` workers.
    pub fn build(&self, n: usize) -> Box<dyn DelayModel> {
        match self {
            DelayModelKind::TruncatedGaussianScenario1 => {
                Box::new(TruncatedGaussianModel::scenario1(n))
            }
            DelayModelKind::TruncatedGaussianScenario2 { seed } => {
                Box::new(TruncatedGaussianModel::scenario2(n, *seed))
            }
            DelayModelKind::TruncatedGaussian { comp, comm } => Box::new(
                TruncatedGaussianModel::homogeneous(n, comp.clone(), comm.clone()),
            ),
            DelayModelKind::ShiftedExponential {
                comp_shift,
                comp_rate,
                comm_shift,
                comm_rate,
            } => Box::new(ShiftedExponential::new(
                *comp_shift,
                *comp_rate,
                *comm_shift,
                *comm_rate,
            )),
            DelayModelKind::Ec2Like { seed, hetero } => {
                Box::new(Ec2LikeModel::new(n, *seed, *hetero))
            }
            DelayModelKind::Fixed {
                comp_ms,
                comm_ms,
                straggler,
                factor,
            } => Box::new(FixedModel::new(*comp_ms, *comm_ms, *straggler, *factor)),
        }
    }
}

/// Deterministic delay model: constant per-slot delays with one
/// optional straggler scaled by `factor`.  Draws nothing from the RNG
/// (the batch bit-identity contract holds vacuously), which makes it
/// the ground truth for latency-anatomy and anomaly-detector tests —
/// the recovered compute/comm split can be asserted within a tolerance
/// instead of a distributional bound.
#[derive(Debug, Clone)]
pub struct FixedModel {
    comp_ms: f64,
    comm_ms: f64,
    straggler: Option<usize>,
    factor: f64,
}

impl FixedModel {
    pub fn new(comp_ms: f64, comm_ms: f64, straggler: Option<usize>, factor: f64) -> Self {
        assert!(comp_ms.is_finite() && comp_ms >= 0.0, "comp_ms must be finite and ≥ 0");
        assert!(comm_ms.is_finite() && comm_ms >= 0.0, "comm_ms must be finite and ≥ 0");
        assert!(factor.is_finite() && factor > 0.0, "factor must be finite and > 0");
        Self {
            comp_ms,
            comm_ms,
            straggler,
            factor,
        }
    }

    #[inline]
    fn scale(&self, worker: usize) -> f64 {
        if self.straggler == Some(worker) {
            self.factor
        } else {
            1.0
        }
    }
}

impl DelayModel for FixedModel {
    fn name(&self) -> String {
        match self.straggler {
            Some(w) => format!(
                "fixed(comp={}ms, comm={}ms, straggler={w}×{})",
                self.comp_ms, self.comm_ms, self.factor
            ),
            None => format!("fixed(comp={}ms, comm={}ms)", self.comp_ms, self.comm_ms),
        }
    }

    fn sample_into(&self, out: &mut DelaySample, rng: &mut Rng) {
        let _ = rng; // deterministic: consumes no randomness
        let (n, r) = (out.n, out.r);
        for i in 0..n {
            let s = self.scale(i);
            let (comp, comm) = (self.comp_ms * s, self.comm_ms * s);
            out.comp_mut()[i * r..(i + 1) * r].fill(comp);
            out.comm_mut()[i * r..(i + 1) * r].fill(comm);
        }
    }

    fn mean_comp(&self, worker: usize) -> Option<f64> {
        Some(self.comp_ms * self.scale(worker))
    }

    fn mean_comm(&self, worker: usize) -> Option<f64> {
        Some(self.comm_ms * self.scale(worker))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sample_layout_roundtrip() {
        let s = DelaySample::from_rows(
            vec![vec![1.0, 2.0], vec![3.0, 4.0]],
            vec![vec![0.1, 0.2], vec![0.3, 0.4]],
        );
        assert_eq!(s.n, 2);
        assert_eq!(s.r, 2);
        assert_eq!(s.comp(0, 1), 2.0);
        assert_eq!(s.comm(1, 0), 0.3);
        assert_eq!(s.comp_row(1), &[3.0, 4.0]);
    }

    #[test]
    fn slot_arrival_is_prefix_sum_plus_comm() {
        // eq. (1): t_{i,C(i,j)} = Σ_{m≤j} T⁽¹⁾ + T⁽²⁾_j
        let s = DelaySample::from_rows(
            vec![vec![1.0, 2.0, 4.0]],
            vec![vec![10.0, 10.0, 10.0]],
        );
        assert_eq!(s.slot_arrival(0, 0), 11.0);
        assert_eq!(s.slot_arrival(0, 1), 13.0);
        assert_eq!(s.slot_arrival(0, 2), 17.0);
    }

    #[test]
    #[should_panic(expected = "ragged")]
    fn from_rows_rejects_ragged() {
        DelaySample::from_rows(vec![vec![1.0], vec![1.0, 2.0]], vec![vec![0.0], vec![0.0, 0.0]]);
    }

    #[test]
    fn kind_builds_all_variants() {
        let kinds = [
            DelayModelKind::TruncatedGaussianScenario1,
            DelayModelKind::TruncatedGaussianScenario2 { seed: 7 },
            DelayModelKind::ShiftedExponential {
                comp_shift: 0.1,
                comp_rate: 10.0,
                comm_shift: 0.3,
                comm_rate: 5.0,
            },
            DelayModelKind::Ec2Like { seed: 1, hetero: 0.3 },
        ];
        for kind in kinds {
            let m = kind.build(4);
            let mut rng = Rng::seed_from_u64(0);
            let s = m.sample(4, 3, &mut rng);
            for i in 0..4 {
                for j in 0..3 {
                    assert!(s.comp(i, j) > 0.0, "{}", m.name());
                    assert!(s.comm(i, j) > 0.0, "{}", m.name());
                }
            }
        }
    }

    #[test]
    fn fixed_model_is_deterministic_with_one_straggler() {
        let kind = DelayModelKind::Fixed {
            comp_ms: 2.0,
            comm_ms: 0.5,
            straggler: Some(1),
            factor: 8.0,
        };
        let m = kind.build(3);
        assert!(m.name().contains("straggler=1×8"), "{}", m.name());
        let mut rng = Rng::seed_from_u64(0);
        let before = rng.next_u64();
        let mut rng = Rng::seed_from_u64(0);
        let s = m.sample(3, 2, &mut rng);
        // consumes no randomness at all
        assert_eq!(rng.next_u64(), before);
        for j in 0..2 {
            assert_eq!(s.comp(0, j), 2.0);
            assert_eq!(s.comm(0, j), 0.5);
            assert_eq!(s.comp(1, j), 16.0);
            assert_eq!(s.comm(1, j), 4.0);
            assert_eq!(s.comp(2, j), 2.0);
        }
        assert_eq!(m.mean_comp(1), Some(16.0));
        assert_eq!(m.mean_comm(0), Some(0.5));
    }

    #[test]
    fn batch_layout_roundtrip() {
        let mut batch = DelayBatch::zeros(3, 2, 2);
        assert_eq!(batch.stride(), 4);
        let s = DelaySample::from_rows(
            vec![vec![1.0, 2.0], vec![3.0, 4.0]],
            vec![vec![0.1, 0.2], vec![0.3, 0.4]],
        );
        batch.copy_round_from_sample(1, &s);
        assert_eq!(batch.comp_round(0), &[0.0; 4]);
        assert_eq!(batch.comp_round(1), &[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(batch.comm_round(1), &[0.1, 0.2, 0.3, 0.4]);
        let back = batch.round_sample(1);
        assert_eq!(back.comp(1, 0), 3.0);
        assert_eq!(back.comm(0, 1), 0.2);
    }

    #[test]
    fn default_batch_fallback_matches_sequential_sampling() {
        // the trait-default path must satisfy the bit-identity contract
        let kinds = [
            DelayModelKind::TruncatedGaussianScenario1,
            DelayModelKind::Ec2Like { seed: 9, hetero: 0.2 },
        ];
        for kind in kinds {
            let m = kind.build(5);
            let (rounds, n, r) = (7usize, 5usize, 3usize);
            let mut rng_a = Rng::seed_from_u64(0xBA7C4);
            let mut rng_b = Rng::seed_from_u64(0xBA7C4);
            let mut batch = DelayBatch::zeros(rounds, n, r);
            // route through the *default* implementation explicitly
            struct ForceDefault<'m>(&'m dyn DelayModel);
            impl DelayModel for ForceDefault<'_> {
                fn name(&self) -> String {
                    self.0.name()
                }
                fn sample_into(&self, out: &mut DelaySample, rng: &mut Rng) {
                    self.0.sample_into(out, rng);
                }
            }
            ForceDefault(m.as_ref()).sample_batch_into(&mut batch, &mut rng_a);
            let mut tmp = DelaySample::zeros(n, r);
            for b in 0..rounds {
                m.sample_into(&mut tmp, &mut rng_b);
                assert_eq!(batch.comp_round(b), tmp.comp_flat(), "{} b={b}", m.name());
                assert_eq!(batch.comm_round(b), tmp.comm_flat(), "{} b={b}", m.name());
            }
            assert_eq!(rng_a.next_u64(), rng_b.next_u64(), "RNG streams diverged");
        }
    }

    #[test]
    fn kind_names_are_informative() {
        let kind = DelayModelKind::Ec2Like { seed: 42, hetero: 0.25 };
        let m = kind.build(3);
        assert!(m.name().contains("ec2-like"));
        let k2 = DelayModelKind::TruncatedGaussianScenario1.build(4);
        assert!(k2.name().contains("scenario1"));
    }
}
