//! Task-size scaling wrapper.
//!
//! The paper's Fig. 6 sweeps the number of workers `n` at fixed dataset
//! size `N`, so the per-task workload `b = N/n` — and with it the
//! computation delay — *shrinks* as workers are added, while the
//! communication delay (one `d`-vector per message) stays constant.
//! [`Scaled`] applies exactly that: multiply the inner model's
//! computation delays by `comp_scale` (= `(N/n) / (N/n₀)` relative to
//! the calibration point `n₀`) and optionally the communication delays
//! by `comm_scale`.

use super::{DelayBatch, DelayModel, DelaySample};
use crate::util::rng::Rng;

/// Multiplicatively scale an inner model's delays.
pub struct Scaled<M> {
    pub inner: M,
    pub comp_scale: f64,
    pub comm_scale: f64,
}

impl<M: DelayModel> Scaled<M> {
    pub fn new(inner: M, comp_scale: f64, comm_scale: f64) -> Self {
        assert!(comp_scale > 0.0 && comm_scale > 0.0, "scales must be positive");
        Self {
            inner,
            comp_scale,
            comm_scale,
        }
    }

    /// Scaling for a Fig.-6-style sweep: workload per task is `N/n`,
    /// model calibrated at `n0` workers.
    pub fn for_worker_count(inner: M, n: usize, n0: usize) -> Self {
        Self::new(inner, n0 as f64 / n as f64, 1.0)
    }
}

impl<M: DelayModel> DelayModel for Scaled<M> {
    fn name(&self) -> String {
        format!(
            "scaled(comp×{:.3}, comm×{:.3})/{}",
            self.comp_scale,
            self.comm_scale,
            self.inner.name()
        )
    }

    fn sample_into(&self, out: &mut DelaySample, rng: &mut Rng) {
        self.inner.sample_into(out, rng);
        if self.comp_scale != 1.0 {
            for v in out.comp_mut() {
                *v *= self.comp_scale;
            }
        }
        if self.comm_scale != 1.0 {
            for v in out.comm_mut() {
                *v *= self.comm_scale;
            }
        }
    }

    /// Batched sampling: delegate the whole batch to the inner model,
    /// then scale the flat arrays in one pass.  Scaling consumes no
    /// randomness and multiplies each slot by the same factor as the
    /// per-round path, so the result is bit-identical to sequential
    /// `sample_into` calls whenever the inner model's batch path is.
    fn sample_batch_into(&self, out: &mut DelayBatch, rng: &mut Rng) {
        self.inner.sample_batch_into(out, rng);
        if self.comp_scale != 1.0 {
            for v in out.comp_flat_mut() {
                *v *= self.comp_scale;
            }
        }
        if self.comm_scale != 1.0 {
            for v in out.comm_flat_mut() {
                *v *= self.comm_scale;
            }
        }
    }

    fn mean_comp(&self, worker: usize) -> Option<f64> {
        self.inner.mean_comp(worker).map(|m| m * self.comp_scale)
    }

    fn mean_comm(&self, worker: usize) -> Option<f64> {
        self.inner.mean_comm(worker).map(|m| m * self.comm_scale)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::delay::ShiftedExponential;

    #[test]
    fn scales_comp_only_by_default_factory() {
        let inner = ShiftedExponential::new(0.5, 2.0, 0.3, 3.0);
        let s = Scaled::for_worker_count(inner, 10, 15);
        assert!((s.comp_scale - 1.5).abs() < 1e-12);
        assert_eq!(s.comm_scale, 1.0);
        assert!((s.mean_comp(0).unwrap() - 1.5 * 1.0).abs() < 1e-12);
        assert!((s.mean_comm(0).unwrap() - (0.3 + 1.0 / 3.0)).abs() < 1e-12);
    }

    #[test]
    fn sampled_delays_are_scaled() {
        let inner = ShiftedExponential::new(1.0, 1e9, 2.0, 1e9); // ≈ deterministic
        let s = Scaled::new(inner, 3.0, 0.5);
        let mut rng = Rng::seed_from_u64(1);
        let d = s.sample(2, 2, &mut rng);
        for i in 0..2 {
            for j in 0..2 {
                assert!((d.comp(i, j) - 3.0).abs() < 1e-6);
                assert!((d.comm(i, j) - 1.0).abs() < 1e-6);
            }
        }
    }

    #[test]
    #[should_panic(expected = "positive")]
    fn rejects_zero_scale() {
        Scaled::new(ShiftedExponential::new(0.1, 1.0, 0.1, 1.0), 0.0, 1.0);
    }
}
