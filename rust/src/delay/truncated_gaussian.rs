//! Truncated-Gaussian delay model — the paper's primary statistical
//! model (eq. 66, Fig. 3 shows it fits the measured EC2 delays well).
//!
//! A delay is `T ~ N(μ, σ²)` conditioned on `T ∈ [μ − a, μ + b]`.  The
//! paper uses symmetric truncation `a = b` in §VI-C; we support the
//! general asymmetric form of eq. (66).  Sampling is exact inverse-CDF:
//!
//! `T = μ + σ·Φ⁻¹( Φ(−a/σ) + U·(Φ(b/σ) − Φ(−a/σ)) )`,  U ~ U(0,1).

use crate::util::rng::Rng;



use super::{DelayBatch, DelayModel, DelaySample};
use crate::util::math::{normal_cdf, normal_pdf, normal_quantile};

/// Parameters of one truncated Gaussian (all in ms).
#[derive(Debug, Clone, PartialEq)]
pub struct TruncatedGaussian {
    pub mu: f64,
    pub sigma: f64,
    /// Lower truncation offset: support starts at `mu - a`.
    pub a: f64,
    /// Upper truncation offset: support ends at `mu + b`.
    pub b: f64,
}

/// Sampling-ready truncated Gaussian with the inverse-CDF constants
/// (`Φ(α)`, mass) precomputed — the Monte-Carlo hot path.  Rebuilding
/// these per draw costs ~2 `erfc` evaluations per delay; hoisting them
/// plus the no-refinement quantile cut 16×16 round sampling ~5×
/// (EXPERIMENTS.md §Perf).
#[derive(Debug, Clone)]
pub struct PreparedTruncatedGaussian {
    mu: f64,
    sigma: f64,
    lo: f64,
    hi: f64,
    p_lo: f64,
    mass: f64,
}

impl PreparedTruncatedGaussian {
    pub fn new(d: &TruncatedGaussian) -> Self {
        Self {
            mu: d.mu,
            sigma: d.sigma,
            lo: d.lo(),
            hi: d.hi(),
            p_lo: normal_cdf(-d.a / d.sigma),
            mass: d.mass(),
        }
    }

    /// Inverse-CDF draw via the fast (no-refinement) normal quantile —
    /// Acklam's 1.15e-9 relative accuracy is far below MC noise.
    #[inline]
    pub fn sample(&self, rng: &mut Rng) -> f64 {
        let p = self.p_lo + rng.f64() * self.mass;
        let z = crate::util::math::normal_quantile_fast(p.clamp(1e-16, 1.0 - 1e-16));
        (self.mu + self.sigma * z).clamp(self.lo, self.hi)
    }
}

impl TruncatedGaussian {
    pub fn symmetric(mu: f64, sigma: f64, a: f64) -> Self {
        Self { mu, sigma, a, b: a }
    }

    /// Precompute the inverse-CDF constants for repeated sampling.
    pub fn prepared(&self) -> PreparedTruncatedGaussian {
        PreparedTruncatedGaussian::new(self)
    }

    /// Lower support bound `μ − a`.
    pub fn lo(&self) -> f64 {
        self.mu - self.a
    }

    /// Upper support bound `μ + b`.
    pub fn hi(&self) -> f64 {
        self.mu + self.b
    }

    fn alpha(&self) -> f64 {
        -self.a / self.sigma
    }

    fn beta(&self) -> f64 {
        self.b / self.sigma
    }

    /// Normalizing mass `Φ(b/σ) − Φ(−a/σ)` (denominator of eq. 66a).
    pub fn mass(&self) -> f64 {
        normal_cdf(self.beta()) - normal_cdf(self.alpha())
    }

    /// PDF (paper eq. 66a).
    pub fn pdf(&self, t: f64) -> f64 {
        if t < self.lo() || t > self.hi() {
            return 0.0;
        }
        normal_pdf((t - self.mu) / self.sigma) / (self.sigma * self.mass())
    }

    /// CDF.
    pub fn cdf(&self, t: f64) -> f64 {
        if t <= self.lo() {
            return 0.0;
        }
        if t >= self.hi() {
            return 1.0;
        }
        (normal_cdf((t - self.mu) / self.sigma) - normal_cdf(self.alpha())) / self.mass()
    }

    /// Exact mean of the truncated distribution:
    /// `μ + σ (φ(α) − φ(β)) / mass`.
    pub fn mean(&self) -> f64 {
        let (al, be) = (self.alpha(), self.beta());
        self.mu + self.sigma * (normal_pdf(al) - normal_pdf(be)) / self.mass()
    }

    /// Inverse-CDF draw.
    pub fn sample(&self, rng: &mut Rng) -> f64 {
        let u = rng.f64();
        let p_lo = normal_cdf(self.alpha());
        let p = p_lo + u * self.mass();
        // clamp: quantile is ±inf at the endpoints; the support bound is
        // the correct limit value.
        let z = normal_quantile(p.clamp(1e-16, 1.0 - 1e-16));
        (self.mu + self.sigma * z).clamp(self.lo(), self.hi())
    }
}

/// Per-worker truncated-Gaussian delays for computation and
/// communication, i.i.d. across a worker's slots (the paper's §VI-C
/// simplification `f_{i,[n]} = Π f_{i,j}`).
#[derive(Debug, Clone)]
pub struct TruncatedGaussianModel {
    pub comp: Vec<TruncatedGaussian>,
    pub comm: Vec<TruncatedGaussian>,
    /// sampling-ready forms, built once (§Perf: hot-path constants)
    prepared_comp: Vec<PreparedTruncatedGaussian>,
    prepared_comm: Vec<PreparedTruncatedGaussian>,
    label: String,
}

impl TruncatedGaussianModel {
    pub fn new(comp: Vec<TruncatedGaussian>, comm: Vec<TruncatedGaussian>, label: &str) -> Self {
        assert_eq!(comp.len(), comm.len(), "per-worker param counts differ");
        assert!(!comp.is_empty(), "need at least one worker");
        let prepared_comp = comp.iter().map(TruncatedGaussian::prepared).collect();
        let prepared_comm = comm.iter().map(TruncatedGaussian::prepared).collect();
        Self {
            comp,
            comm,
            prepared_comp,
            prepared_comm,
            label: label.to_string(),
        }
    }

    /// All workers share the same comp/comm distributions.
    pub fn homogeneous(n: usize, comp: TruncatedGaussian, comm: TruncatedGaussian) -> Self {
        Self::new(
            vec![comp; n],
            vec![comm; n],
            "truncated-gaussian/homogeneous",
        )
    }

    /// Paper §VI-C **Scenario 1**: μ⁽¹⁾ = 1E4 s = 0.1 ms, μ⁽²⁾ = 5E4 s
    /// = 0.5 ms for every worker; a⁽¹⁾ = 0.03 ms, σ⁽¹⁾ = 0.1 ms,
    /// a⁽²⁾ = σ⁽²⁾ = 0.2 ms.
    pub fn scenario1(n: usize) -> Self {
        let comp = TruncatedGaussian::symmetric(0.1, 0.1, 0.03);
        let comm = TruncatedGaussian::symmetric(0.5, 0.2, 0.2);
        let mut m = Self::homogeneous(n, comp, comm);
        m.label = "truncated-gaussian/scenario1".into();
        m
    }

    /// Paper §VI-C **Scenario 2**: heterogeneous means.
    /// `{μ_i⁽¹⁾} = perm{(2+i)/3 · 0.1 ms : i ∈ [n]}` and
    /// `{μ_i⁽²⁾} = perm{0.5, 0.55, …, (9+n)/2 · 0.1 ms}`; widths as in
    /// scenario 1.
    pub fn scenario2(n: usize, seed: u64) -> Self {
        
        
        let mut rng = Rng::seed_from_u64(seed);
        let mut mu1: Vec<f64> = (1..=n).map(|i| (2.0 + i as f64) / 3.0 * 0.1).collect();
        let mut mu2: Vec<f64> = (1..=n).map(|i| (9.0 + i as f64) / 2.0 * 0.1).collect();
        rng.shuffle(&mut mu1);
        rng.shuffle(&mut mu2);
        let comp = mu1
            .into_iter()
            .map(|mu| TruncatedGaussian::symmetric(mu, 0.1, 0.03))
            .collect();
        let comm = mu2
            .into_iter()
            .map(|mu| TruncatedGaussian::symmetric(mu, 0.2, 0.2))
            .collect();
        Self::new(comp, comm, "truncated-gaussian/scenario2")
    }
}

impl DelayModel for TruncatedGaussianModel {
    fn name(&self) -> String {
        self.label.clone()
    }

    fn sample_into(&self, out: &mut DelaySample, rng: &mut Rng) {
        let (n, r) = (out.n, out.r);
        assert!(
            n <= self.comp.len(),
            "model built for {} workers, asked for {n}",
            self.comp.len()
        );
        for i in 0..n {
            let dc = &self.prepared_comp[i];
            let dm = &self.prepared_comm[i];
            for j in 0..r {
                out.comp_mut()[i * r + j] = dc.sample(rng);
                out.comm_mut()[i * r + j] = dm.sample(rng);
            }
        }
    }

    /// Batched sampling: same `(comp, comm)`-interleaved draw order as
    /// [`TruncatedGaussianModel::sample_into`] (the bit-identity
    /// contract), with the virtual dispatch, shape checks and prepared
    /// inverse-CDF constants hoisted out of the round loop and writes
    /// going straight into the batch's contiguous per-round slices.
    fn sample_batch_into(&self, out: &mut DelayBatch, rng: &mut Rng) {
        let (n, r) = (out.n, out.r);
        assert!(
            n <= self.comp.len(),
            "model built for {} workers, asked for {n}",
            self.comp.len()
        );
        let prepared: Vec<(&PreparedTruncatedGaussian, &PreparedTruncatedGaussian)> = (0..n)
            .map(|i| (&self.prepared_comp[i], &self.prepared_comm[i]))
            .collect();
        for b in 0..out.rounds {
            let (comp, comm) = out.round_mut(b);
            for (i, &(dc, dm)) in prepared.iter().enumerate() {
                let base = i * r;
                for j in 0..r {
                    comp[base + j] = dc.sample(rng);
                    comm[base + j] = dm.sample(rng);
                }
            }
        }
    }

    fn mean_comp(&self, worker: usize) -> Option<f64> {
        self.comp.get(worker).map(TruncatedGaussian::mean)
    }

    fn mean_comm(&self, worker: usize) -> Option<f64> {
        self.comm.get(worker).map(TruncatedGaussian::mean)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    

    fn rng() -> Rng {
        Rng::seed_from_u64(0xC0FFEE)
    }

    #[test]
    fn pdf_integrates_to_one() {
        let d = TruncatedGaussian::symmetric(0.5, 0.2, 0.2);
        let integral =
            crate::util::math::adaptive_simpson(&|t| d.pdf(t), d.lo() - 0.1, d.hi() + 0.1, 1e-10);
        assert!((integral - 1.0).abs() < 1e-8, "{integral}");
    }

    #[test]
    fn cdf_matches_pdf_integral() {
        let d = TruncatedGaussian::symmetric(0.1, 0.1, 0.03);
        for t in [0.08, 0.1, 0.12] {
            let num = crate::util::math::adaptive_simpson(&|x| d.pdf(x), d.lo(), t, 1e-10);
            assert!((d.cdf(t) - num).abs() < 1e-7, "t={t}");
        }
    }

    #[test]
    fn samples_respect_support() {
        let d = TruncatedGaussian::symmetric(0.5, 0.2, 0.2);
        let mut r = rng();
        for _ in 0..20_000 {
            let x = d.sample(&mut r);
            assert!(x >= d.lo() - 1e-12 && x <= d.hi() + 1e-12, "{x}");
        }
    }

    #[test]
    fn sample_mean_matches_analytic_mean() {
        // symmetric truncation: mean == μ; also test asymmetric
        let sym = TruncatedGaussian::symmetric(0.5, 0.2, 0.2);
        assert!((sym.mean() - 0.5).abs() < 1e-12);

        let asym = TruncatedGaussian {
            mu: 1.0,
            sigma: 0.5,
            a: 0.25,
            b: 1.0,
        };
        let mut r = rng();
        let mut acc = crate::util::stats::RunningStats::new();
        for _ in 0..200_000 {
            acc.push(asym.sample(&mut r));
        }
        assert!(
            (acc.mean() - asym.mean()).abs() < 4.0 * acc.std_err() + 1e-4,
            "MC {} vs analytic {}",
            acc.mean(),
            asym.mean()
        );
    }

    #[test]
    fn empirical_cdf_matches_analytic() {
        let d = TruncatedGaussian::symmetric(0.1, 0.1, 0.03);
        let mut r = rng();
        let n = 100_000;
        let mut below = 0u32;
        let t = 0.095;
        for _ in 0..n {
            if d.sample(&mut r) <= t {
                below += 1;
            }
        }
        let emp = below as f64 / n as f64;
        assert!((emp - d.cdf(t)).abs() < 0.01, "emp {emp} vs {}", d.cdf(t));
    }

    #[test]
    fn scenario1_means_match_paper() {
        let m = TruncatedGaussianModel::scenario1(16);
        // μ⁽¹⁾ = 0.1 ms, μ⁽²⁾ = 0.5 ms (symmetric truncation keeps mean)
        assert!((m.mean_comp(0).unwrap() - 0.1).abs() < 1e-12);
        assert!((m.mean_comm(0).unwrap() - 0.5).abs() < 1e-12);
        // communication dominates computation (paper Fig. 3 observation)
        assert!(m.mean_comm(3).unwrap() > m.mean_comp(3).unwrap());
    }

    #[test]
    fn scenario2_is_permutation_of_ladder() {
        let m = TruncatedGaussianModel::scenario2(8, 3);
        let mut mus: Vec<f64> = m.comp.iter().map(|d| d.mu).collect();
        mus.sort_by(f64::total_cmp);
        let want: Vec<f64> = (1..=8).map(|i| (2.0 + i as f64) / 3.0 * 0.1).collect();
        for (a, b) in mus.iter().zip(&want) {
            assert!((a - b).abs() < 1e-12);
        }
        // deterministic in seed
        let m2 = TruncatedGaussianModel::scenario2(8, 3);
        for (a, b) in m.comp.iter().zip(&m2.comp) {
            assert_eq!(a.mu, b.mu);
        }
    }

    #[test]
    fn model_fills_every_slot() {
        let m = TruncatedGaussianModel::scenario1(4);
        let mut r = rng();
        let s = m.sample(4, 3, &mut r);
        for i in 0..4 {
            for j in 0..3 {
                assert!(s.comp(i, j) >= 0.07 - 1e-9 && s.comp(i, j) <= 0.13 + 1e-9);
                assert!(s.comm(i, j) >= 0.3 - 1e-9 && s.comm(i, j) <= 0.7 + 1e-9);
            }
        }
    }
}
