//! Distributed gradient descent logic — the master-side update rules of
//! paper §VI (Table I) shared by the simulator-backed and cluster-backed
//! training paths.
//!
//! * uncoded (CS/SS/RA), target `k`:
//!   `θ ← θ − η·(2n)/(kN) Σ_{i=1}^{k} (h(X_{p_i}) − X_{p_i} y_{p_i})`  (eq. 61)
//! * coded (PC/PCMM), always full gradient:
//!   `θ ← θ − η·(2/N) (XᵀXθ − Xᵀy)`                                   (eq. 49)
//!
//! Also implements the Remark-3 bias guard: tracking per-task completion
//! frequencies and (optionally) re-shuffling the task↔batch mapping
//! every `reshuffle_every` rounds.

pub mod precomputed;

pub use precomputed::{PrecomputedGram, PrecomputedMaster};

use crate::data::Dataset;
use crate::linalg::vec_axpy;
use crate::util::rng::Rng;

/// Master-side DGD state for the uncoded schemes.
#[derive(Debug, Clone)]
pub struct UncodedMaster {
    pub theta: Vec<f64>,
    pub eta: f64,
    pub k: usize,
    /// `b_i = X_i y_i`, precomputed once (paper §VI-A).
    pub xy: Vec<Vec<f64>>,
    /// per-**batch** completion counts (Remark-3 bias tracking: the
    /// SGD bias lives in which *data* gets used, and the reshuffle
    /// remaps tasks to batches precisely to even these out)
    pub task_counts: Vec<u64>,
    /// optional task↔batch permutation re-randomization period
    pub reshuffle_every: Option<usize>,
    /// current task→batch mapping
    pub mapping: Vec<usize>,
    rounds: usize,
}

impl UncodedMaster {
    pub fn new(ds: &Dataset, eta: f64, k: usize) -> Self {
        assert!(k >= 1 && k <= ds.n, "target must satisfy 1 ≤ k ≤ n");
        Self {
            theta: vec![0.0; ds.d],
            eta,
            k,
            xy: ds.xy_vectors(),
            task_counts: vec![0; ds.n],
            reshuffle_every: None,
            mapping: (0..ds.n).collect(),
            rounds: 0,
        }
    }

    pub fn with_reshuffle(mut self, every: usize) -> Self {
        assert!(every >= 1);
        self.reshuffle_every = Some(every);
        self
    }

    /// Batch index computed by task `t` under the current mapping.
    pub fn batch_of(&self, task: usize) -> usize {
        self.mapping[task]
    }

    /// Apply one round given the `k` received `(task, h(X_batch))`
    /// pairs, where `h = X Xᵀ θ` (eq. 50).  Returns the new θ.
    ///
    /// `n_padded` is the padded sample count `N` of eq. 61.
    pub fn apply_round(
        &mut self,
        received: &[(usize, Vec<f64>)],
        n_tasks: usize,
        n_padded: usize,
        rng: &mut Rng,
    ) -> &[f64] {
        assert_eq!(received.len(), self.k, "master must apply exactly k results");
        let d = self.theta.len();
        let mut agg = vec![0.0; d];
        for (task, h) in received {
            let batch = self.mapping[*task];
            self.task_counts[batch] += 1;
            vec_axpy(&mut agg, 1.0, h);
            vec_axpy(&mut agg, -1.0, &self.xy[batch]);
        }
        self.step(agg, received.len(), n_tasks, n_padded, rng)
    }

    /// Apply one round from an already-aggregated partial sum
    /// `h_sum = Σ_{t ∈ winners} h(X_t)` — the protocol-v3 cluster path,
    /// where per-task blocks never reach the master
    /// ([`crate::coordinator::aggregate`]).  `winners` may exceed `k`
    /// when an aligned GC(s) block straddles the target: eq. 61's
    /// `k` becomes the actual winner count `m` (still an unbiased
    /// partial-gradient step, Remark 2).
    pub fn apply_aggregate(
        &mut self,
        winners: &[usize],
        h_sum: &[f64],
        n_tasks: usize,
        n_padded: usize,
        rng: &mut Rng,
    ) -> &[f64] {
        assert!(!winners.is_empty(), "master must apply ≥ 1 results");
        assert_eq!(h_sum.len(), self.theta.len());
        let mut agg = h_sum.to_vec();
        for &task in winners {
            let batch = self.mapping[task];
            self.task_counts[batch] += 1;
            vec_axpy(&mut agg, -1.0, &self.xy[batch]);
        }
        self.step(agg, winners.len(), n_tasks, n_padded, rng)
    }

    /// Shared eq.-61 step: `θ ← θ − η·2n/(mN) · agg` with `m` received
    /// results, plus the Remark-3 reshuffle bookkeeping.
    fn step(
        &mut self,
        agg: Vec<f64>,
        m: usize,
        n_tasks: usize,
        n_padded: usize,
        rng: &mut Rng,
    ) -> &[f64] {
        let scale = self.eta * 2.0 * n_tasks as f64 / (m as f64 * n_padded as f64);
        vec_axpy(&mut self.theta, -scale, &agg);

        self.rounds += 1;
        if let Some(every) = self.reshuffle_every {
            if self.rounds % every == 0 {
                rng.shuffle(&mut self.mapping);
            }
        }
        &self.theta
    }

    /// Empirical bias diagnostic (Remark 3): max/min per-batch usage
    /// frequency ratio; 1.0 = perfectly uniform SGD sampling.
    pub fn selection_skew(&self) -> f64 {
        let max = *self.task_counts.iter().max().unwrap_or(&0);
        let min = *self.task_counts.iter().min().unwrap_or(&0);
        if min == 0 {
            f64::INFINITY
        } else {
            max as f64 / min as f64
        }
    }
}

/// Master update for the coded schemes (eq. 49): takes the exact
/// `XᵀXθ` reconstruction and the precomputed `Xᵀy`.
pub fn coded_update(theta: &mut [f64], xxt_theta: &[f64], xty: &[f64], eta: f64, n_padded: usize) {
    let scale = eta * 2.0 / n_padded as f64;
    for i in 0..theta.len() {
        theta[i] -= scale * (xxt_theta[i] - xty[i]);
    }
}

/// Simulator-backed DGD driver: runs `rounds` iterations of the uncoded
/// scheme with CPU-oracle numerics (the cluster-backed equivalent lives
/// in [`crate::coordinator`]; both share this module's update rules).
pub struct SimulatedTraining<'a> {
    pub ds: &'a Dataset,
    pub master: UncodedMaster,
    pub rng: Rng,
}

impl<'a> SimulatedTraining<'a> {
    pub fn new(ds: &'a Dataset, eta: f64, k: usize, seed: u64) -> Self {
        Self {
            ds,
            master: UncodedMaster::new(ds, eta, k),
            rng: Rng::seed_from_u64(seed),
        }
    }

    /// Run one round: the winners (first k distinct tasks) are supplied
    /// by the completion-time simulator; this computes their gram
    /// mat-vecs with the CPU oracle and applies eq. 61.
    pub fn apply_winners(&mut self, winners: &[usize]) -> f64 {
        let received: Vec<(usize, Vec<f64>)> = winners
            .iter()
            .map(|&t| {
                let batch = self.master.batch_of(t);
                (t, self.ds.parts[batch].gram_matvec(&self.master.theta))
            })
            .collect();
        self.master.apply_round(
            &received,
            self.ds.n,
            self.ds.padded_samples(),
            &mut self.rng,
        );
        self.ds.loss(&self.master.theta)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::delay::{DelayModel, TruncatedGaussianModel};
    use crate::scheduler::{CyclicScheduler, Scheduler};

    #[test]
    fn k_equals_n_round_is_exact_gd_step() {
        // with k = n, eq. 61 reduces to eq. 62 = a full GD step
        let ds = Dataset::synthesize(4, 6, 32, 2);
        let mut m = UncodedMaster::new(&ds, 0.05, 4);
        let mut rng = Rng::seed_from_u64(0);
        let theta0 = m.theta.clone();
        let received: Vec<(usize, Vec<f64>)> = (0..4)
            .map(|t| (t, ds.parts[t].gram_matvec(&theta0)))
            .collect();
        m.apply_round(&received, ds.n, ds.padded_samples(), &mut rng);
        // oracle step
        let g = ds.full_gradient(&theta0);
        for i in 0..6 {
            let want = theta0[i] - 0.05 * g[i];
            assert!((m.theta[i] - want).abs() < 1e-12, "coord {i}");
        }
    }

    #[test]
    fn partial_k_step_is_unbiased_direction_on_average() {
        // averaged over many random k-subsets, the eq.-61 step equals
        // the full-gradient step (that's the Remark-2 SGD argument)
        let ds = Dataset::synthesize(6, 5, 60, 3);
        let theta0: Vec<f64> = (0..5).map(|i| 0.2 * i as f64).collect();
        let full_g = ds.full_gradient(&theta0);
        let k = 2;
        let mut rng = Rng::seed_from_u64(9);
        let mut avg = vec![0.0; 5];
        let trials = 20_000;
        for _ in 0..trials {
            // random k-subset of tasks
            let mut tasks: Vec<usize> = (0..6).collect();
            rng.shuffle(&mut tasks);
            let mut m = UncodedMaster::new(&ds, 1.0, k);
            m.theta = theta0.clone();
            let received: Vec<(usize, Vec<f64>)> = tasks[..k]
                .iter()
                .map(|&t| (t, ds.parts[t].gram_matvec(&theta0)))
                .collect();
            m.apply_round(&received, ds.n, ds.padded_samples(), &mut rng);
            for i in 0..5 {
                avg[i] += (theta0[i] - m.theta[i]) / trials as f64; // = η·ĝ_i
            }
        }
        for i in 0..5 {
            assert!(
                (avg[i] - full_g[i]).abs() < 0.02 * (1.0 + full_g[i].abs()),
                "coord {i}: {} vs {}",
                avg[i],
                full_g[i]
            );
        }
    }

    #[test]
    fn coded_update_matches_uncoded_full_step() {
        let ds = Dataset::synthesize(3, 4, 18, 4);
        let theta0: Vec<f64> = (0..4).map(|i| 0.3 - 0.1 * i as f64).collect();
        // coded: XᵀXθ = Σ gram_i(θ), Xᵀy = Σ X_i y_i
        let mut xxt = vec![0.0; 4];
        let mut xty = vec![0.0; 4];
        for i in 0..3 {
            vec_axpy(&mut xxt, 1.0, &ds.parts[i].gram_matvec(&theta0));
            vec_axpy(&mut xty, 1.0, &ds.parts[i].matvec(&ds.labels[i]));
        }
        let mut theta_coded = theta0.clone();
        coded_update(&mut theta_coded, &xxt, &xty, 0.05, ds.padded_samples());

        let g = ds.full_gradient(&theta0);
        for i in 0..4 {
            let want = theta0[i] - 0.05 * g[i];
            assert!((theta_coded[i] - want).abs() < 1e-12);
        }
    }

    #[test]
    fn training_converges_full_target() {
        let ds = Dataset::synthesize(5, 8, 100, 6);
        let model = TruncatedGaussianModel::scenario1(5);
        let mut rng = Rng::seed_from_u64(1);
        let to = CyclicScheduler.schedule(5, 2, &mut rng);
        let mut training = SimulatedTraining::new(&ds, 0.05, 5, 11);
        let l0 = ds.loss(&training.master.theta);
        let mut last = l0;
        for _ in 0..300 {
            let sample = model.sample(5, 2, &mut rng);
            let round = crate::sim::simulate_round(&to, &sample, 5);
            last = training.apply_winners(&round.winners);
        }
        assert!(
            last < 0.05 * l0,
            "loss should drop ≥ 20×: {l0} → {last}"
        );
    }

    #[test]
    fn training_converges_partial_target_k_lt_n() {
        // Remark 2: SGD with k < n still converges (noisier)
        let ds = Dataset::synthesize(6, 8, 120, 7);
        let model = TruncatedGaussianModel::scenario1(6);
        let mut rng = Rng::seed_from_u64(2);
        let to = CyclicScheduler.schedule(6, 3, &mut rng);
        let mut training = SimulatedTraining::new(&ds, 0.03, 3, 13);
        let l0 = ds.loss(&training.master.theta);
        let mut last = l0;
        for _ in 0..600 {
            let sample = model.sample(6, 3, &mut rng);
            let round = crate::sim::simulate_round(&to, &sample, 3);
            last = training.apply_winners(&round.winners);
        }
        assert!(last < 0.1 * l0, "partial-k training: {l0} → {last}");
        // bias diagnostic exists and is finite after enough rounds
        assert!(training.master.selection_skew().is_finite());
    }

    #[test]
    fn reshuffle_changes_mapping_deterministically() {
        let ds = Dataset::synthesize(8, 4, 64, 8);
        let mut m = UncodedMaster::new(&ds, 0.01, 8).with_reshuffle(1);
        let mut rng = Rng::seed_from_u64(3);
        let before = m.mapping.clone();
        let theta0 = m.theta.clone();
        let received: Vec<(usize, Vec<f64>)> = (0..8)
            .map(|t| (t, ds.parts[t].gram_matvec(&theta0)))
            .collect();
        m.apply_round(&received, ds.n, ds.padded_samples(), &mut rng);
        assert_ne!(m.mapping, before, "mapping must re-randomize");
        let mut sorted = m.mapping.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..8).collect::<Vec<_>>());
    }

    #[test]
    fn aggregate_path_matches_per_task_path() {
        // the v3 cluster feeds apply_aggregate; it must take the same
        // eq.-61 step as the per-task path up to summation order
        let ds = Dataset::synthesize(5, 6, 40, 12);
        let theta0: Vec<f64> = (0..6).map(|i| 0.1 * i as f64 - 0.2).collect();
        let winners = [1usize, 2, 4];
        let received: Vec<(usize, Vec<f64>)> = winners
            .iter()
            .map(|&t| (t, ds.parts[t].gram_matvec(&theta0)))
            .collect();
        let mut per_task = UncodedMaster::new(&ds, 0.05, 3);
        per_task.theta = theta0.clone();
        let mut rng = Rng::seed_from_u64(0);
        per_task.apply_round(&received, ds.n, ds.padded_samples(), &mut rng);

        let mut h_sum = vec![0.0; ds.d];
        for (_, h) in &received {
            vec_axpy(&mut h_sum, 1.0, h);
        }
        let mut agg = UncodedMaster::new(&ds, 0.05, 3);
        agg.theta = theta0.clone();
        let mut rng = Rng::seed_from_u64(0);
        agg.apply_aggregate(&winners, &h_sum, ds.n, ds.padded_samples(), &mut rng);
        for i in 0..ds.d {
            assert!(
                (per_task.theta[i] - agg.theta[i]).abs() < 1e-12,
                "coord {i}: {} vs {}",
                per_task.theta[i],
                agg.theta[i]
            );
        }
        assert_eq!(per_task.task_counts, agg.task_counts);
    }

    #[test]
    fn aggregate_scales_by_actual_winner_count() {
        // m = 4 winners with k = 3 configured: the step must scale by
        // m (the straddled-block overshoot case), i.e. equal a k = 4
        // per-task round
        let ds = Dataset::synthesize(6, 4, 36, 3);
        let theta0 = vec![0.3; 4];
        let winners = [0usize, 2, 3, 5];
        let received: Vec<(usize, Vec<f64>)> = winners
            .iter()
            .map(|&t| (t, ds.parts[t].gram_matvec(&theta0)))
            .collect();
        let mut want = UncodedMaster::new(&ds, 0.05, 4);
        want.theta = theta0.clone();
        let mut rng = Rng::seed_from_u64(1);
        want.apply_round(&received, ds.n, ds.padded_samples(), &mut rng);

        let mut h_sum = vec![0.0; ds.d];
        for (_, h) in &received {
            vec_axpy(&mut h_sum, 1.0, h);
        }
        let mut got = UncodedMaster::new(&ds, 0.05, 3); // k = 3 configured
        got.theta = theta0.clone();
        let mut rng = Rng::seed_from_u64(1);
        got.apply_aggregate(&winners, &h_sum, ds.n, ds.padded_samples(), &mut rng);
        for i in 0..ds.d {
            assert!((want.theta[i] - got.theta[i]).abs() < 1e-12, "coord {i}");
        }
    }

    #[test]
    #[should_panic(expected = "exactly k results")]
    fn apply_rejects_wrong_count() {
        let ds = Dataset::synthesize(4, 3, 16, 1);
        let mut m = UncodedMaster::new(&ds, 0.01, 3);
        let mut rng = Rng::seed_from_u64(0);
        m.apply_round(&[(0, vec![0.0; 3])], 4, 16, &mut rng);
    }
}
