//! Remark-7 variant: distributed matrix–vector DGD over the
//! precomputed gram matrix.
//!
//! The paper's alternative formulation: besides `Xᵀy`, the master
//! computes `W ≜ XᵀX ∈ R^{d×d}` **once**, after which each iteration
//! only needs the distributed matrix–vector product `Wθ_l` and the
//! update (eq. 65)
//!
//! ```text
//! θ_{l+1} = θ_l − η·(2/N)(W θ_l − Xᵀy)
//! ```
//!
//! Tasks become row-blocks of `W`: task `i` computes `W_i θ ∈ R^{d/n}`.
//! The same TO matrices (CS/SS/RA) schedule these tasks unchanged — the
//! completion-time analysis is formulation-agnostic — so this module
//! only supplies the *compute* side: block partitioning, per-task
//! matvec, and the assembling master.  For `k < n` the master reuses
//! the **stale** block values from previous iterations (the natural
//! partial-update semantics here, since unlike eq. 61 the blocks are
//! disjoint coordinates of `Wθ`, not i.i.d. gradient summands).

use crate::data::Dataset;
use crate::linalg::Mat;

/// Precomputed-gram workload: `W = XᵀX`, `Xᵀy`, and a row-block split.
pub struct PrecomputedGram {
    /// the gram matrix `W` (d × d)
    pub w: Mat,
    /// `Xᵀy`
    pub xty: Vec<f64>,
    /// padded sample count `N` of eq. 65
    pub n_padded: usize,
    /// `blocks[i] = (row_start, row_end)` of task i
    pub blocks: Vec<(usize, usize)>,
}

impl PrecomputedGram {
    /// One-time master-side setup (the paper's "computes W once at the
    /// beginning of the learning task").
    pub fn from_dataset(ds: &Dataset, n_blocks: usize) -> Self {
        assert!(n_blocks >= 1 && n_blocks <= ds.d, "need 1 ≤ blocks ≤ d");
        let d = ds.d;
        // W = Σ_i X_i X_iᵀ, built column-by-column via gram mat-vecs of
        // the basis vectors (O(d)·gram cost; setup path, not hot)
        let mut w = Mat::zeros(d, d);
        let mut e = vec![0.0; d];
        for col in 0..d {
            e[col] = 1.0;
            for part in &ds.parts {
                let h = part.gram_matvec(&e);
                for row in 0..d {
                    w[(row, col)] += h[row];
                }
            }
            e[col] = 0.0;
        }
        let mut xty = vec![0.0; d];
        for (x, y) in ds.parts.iter().zip(&ds.labels) {
            let xy = x.matvec(y);
            for i in 0..d {
                xty[i] += xy[i];
            }
        }
        // near-even row blocks
        let base = d / n_blocks;
        let extra = d % n_blocks;
        let mut blocks = Vec::with_capacity(n_blocks);
        let mut start = 0;
        for i in 0..n_blocks {
            let len = base + usize::from(i < extra);
            blocks.push((start, start + len));
            start += len;
        }
        Self {
            w,
            xty,
            n_padded: ds.padded_samples(),
            blocks,
        }
    }

    pub fn n_blocks(&self) -> usize {
        self.blocks.len()
    }

    /// Worker task `i`: the row-block matvec `W_i θ`.
    pub fn task(&self, block: usize, theta: &[f64]) -> Vec<f64> {
        let (lo, hi) = self.blocks[block];
        (lo..hi)
            .map(|row| crate::linalg::dot(self.w.row(row), theta))
            .collect()
    }
}

/// Master state for the Remark-7 update: keeps the latest known value
/// of every `Wθ` block so `k < n` rounds can proceed with stale blocks.
pub struct PrecomputedMaster {
    pub theta: Vec<f64>,
    pub eta: f64,
    /// cached `Wθ` blocks (stale entries persist across rounds)
    wtheta: Vec<f64>,
    /// rounds since each block was refreshed (staleness diagnostic)
    pub block_age: Vec<u32>,
}

impl PrecomputedMaster {
    pub fn new(d: usize, n_blocks: usize, eta: f64) -> Self {
        Self {
            theta: vec![0.0; d],
            eta,
            wtheta: vec![0.0; d],
            block_age: vec![0; n_blocks],
        }
    }

    /// Apply one round: `fresh` holds `(block_index, W_i θ)` results for
    /// the k received blocks; remaining blocks use their cached value
    /// (exact when k = n; stale-coordinate GD otherwise).
    pub fn apply_round(&mut self, grams: &PrecomputedGram, fresh: &[(usize, Vec<f64>)]) -> &[f64] {
        for age in &mut self.block_age {
            *age += 1;
        }
        for (block, values) in fresh {
            let (lo, hi) = grams.blocks[*block];
            assert_eq!(values.len(), hi - lo, "block {block} shape mismatch");
            self.wtheta[lo..hi].copy_from_slice(values);
            self.block_age[*block] = 0;
        }
        // eq. 65: θ ← θ − η·2/N (Wθ − Xᵀy)
        let scale = self.eta * 2.0 / grams.n_padded as f64;
        for i in 0..self.theta.len() {
            self.theta[i] -= scale * (self.wtheta[i] - grams.xty[i]);
        }
        &self.theta
    }

    pub fn max_staleness(&self) -> u32 {
        self.block_age.iter().copied().max().unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::vec_axpy;
    use crate::util::rng::Rng;

    fn setup(n: usize, d: usize, samples: usize) -> (Dataset, PrecomputedGram) {
        let ds = Dataset::synthesize(n, d, samples, 17);
        let grams = PrecomputedGram::from_dataset(&ds, n);
        (ds, grams)
    }

    #[test]
    fn w_theta_matches_gram_sum() {
        // assembled blocks of Wθ must equal Σ_i X_i X_iᵀ θ exactly
        let (ds, grams) = setup(4, 10, 40);
        let mut rng = Rng::seed_from_u64(3);
        let theta: Vec<f64> = (0..10).map(|_| rng.normal()).collect();
        let mut want = vec![0.0; 10];
        for p in &ds.parts {
            vec_axpy(&mut want, 1.0, &p.gram_matvec(&theta));
        }
        let mut got = Vec::new();
        for b in 0..grams.n_blocks() {
            got.extend(grams.task(b, &theta));
        }
        for i in 0..10 {
            assert!(
                (got[i] - want[i]).abs() < 1e-9 * (1.0 + want[i].abs()),
                "row {i}: {} vs {}",
                got[i],
                want[i]
            );
        }
    }

    #[test]
    fn blocks_partition_rows() {
        let (_, grams) = setup(3, 11, 33); // 11 rows over 3 blocks: 4,4,3
        assert_eq!(grams.blocks, vec![(0, 4), (4, 8), (8, 11)]);
        let covered: usize = grams.blocks.iter().map(|(a, b)| b - a).sum();
        assert_eq!(covered, 11);
    }

    #[test]
    fn full_target_round_equals_eq65_exactly() {
        // with k = n the Remark-7 update must equal the direct eq. 65
        // step — and hence the eq. 62 full-gradient step
        let (ds, grams) = setup(5, 8, 50);
        let mut m = PrecomputedMaster::new(8, 5, 0.05);
        let mut rng = Rng::seed_from_u64(9);
        m.theta = (0..8).map(|_| rng.normal() * 0.1).collect();
        let theta0 = m.theta.clone();
        let fresh: Vec<(usize, Vec<f64>)> = (0..5).map(|b| (b, grams.task(b, &theta0))).collect();
        m.apply_round(&grams, &fresh);
        let g = ds.full_gradient(&theta0);
        for i in 0..8 {
            let want = theta0[i] - 0.05 * g[i];
            assert!((m.theta[i] - want).abs() < 1e-9, "coord {i}");
        }
        assert_eq!(m.max_staleness(), 0);
    }

    #[test]
    fn converges_with_full_target() {
        let (ds, grams) = setup(4, 12, 64);
        let mut m = PrecomputedMaster::new(12, 4, 0.04);
        let l0 = ds.loss(&m.theta);
        for _ in 0..800 {
            let theta = m.theta.clone();
            let fresh: Vec<(usize, Vec<f64>)> =
                (0..4).map(|b| (b, grams.task(b, &theta))).collect();
            m.apply_round(&grams, &fresh);
        }
        let l1 = ds.loss(&m.theta);
        assert!(l1 < 0.05 * l0, "{l0} → {l1}");
    }

    #[test]
    fn converges_with_stale_blocks_k_lt_n() {
        // k = 2 of 4 blocks refreshed per round (rotating), rest stale:
        // stale-coordinate GD still converges at a reduced rate
        let (ds, grams) = setup(4, 12, 64);
        let mut m = PrecomputedMaster::new(12, 4, 0.02);
        let l0 = ds.loss(&m.theta);
        for round in 0..2500 {
            let theta = m.theta.clone();
            let b0 = (2 * round) % 4;
            let fresh: Vec<(usize, Vec<f64>)> = [b0, (b0 + 1) % 4]
                .iter()
                .map(|&b| (b, grams.task(b, &theta)))
                .collect();
            m.apply_round(&grams, &fresh);
        }
        assert!(m.max_staleness() <= 2, "rotation bounds staleness");
        let l1 = ds.loss(&m.theta);
        assert!(l1 < 0.1 * l0, "stale-block training: {l0} → {l1}");
    }

    #[test]
    #[should_panic(expected = "shape mismatch")]
    fn rejects_wrong_block_shape() {
        let (_, grams) = setup(3, 9, 27);
        let mut m = PrecomputedMaster::new(9, 3, 0.01);
        m.apply_round(&grams, &[(0, vec![0.0])]);
    }
}
