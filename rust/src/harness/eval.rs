//! Coupled multi-scheme evaluator: CS, SS, RA, PC, PCMM and LB against
//! the *identical* delay stream — the engine behind every figure.
//!
//! Per round one `DelaySample` is drawn; every scheme's completion time
//! is computed from it (uncoded via the §II dynamics, PC/PCMM via their
//! Table-I criteria, LB as the k-th slot order statistic).  This is the
//! paper's fairness discipline ("for fairness we use the same dataset
//! for all the schemes") applied to the randomness itself, and it makes
//! ordering assertions (LB ≤ CS, …) hold per realization, not just in
//! expectation.

use crate::coded::{PcScheme, PcmmScheme};
use crate::delay::{DelayModel, DelaySample};
use crate::lb;
use crate::scheduler::{
    CyclicScheduler, RandomAssignment, Scheduler, SchemeId, StaircaseScheduler,
};
use crate::sim::{completion_time_fast, CompletionEstimate};
use crate::util::rng::Rng;
use crate::util::stats::{quantile_sorted, RunningStats};

/// Evaluation request for one `(n, r, k)` point.
#[derive(Debug, Clone)]
pub struct EvalPoint {
    pub n: usize,
    pub r: usize,
    pub k: usize,
    pub trials: usize,
    pub seed: u64,
    pub schemes: Vec<SchemeId>,
    pub threads: usize,
    /// Master-side per-message ingestion cost (ms).  `0` gives the
    /// paper's idealized eq. (1)–(2) dynamics (used for Fig. 4's pure
    /// statistical model).  A positive value models the serialized
    /// receive loop of the paper's Python/MPI master on the EC2
    /// testbed: messages queue at the master and each costs
    /// `ingest_ms` to process.  This is what makes multi-message
    /// schemes (PCMM's `2n − 1` receptions) pay for their extra
    /// communication — the effect the paper invokes to explain PCMM's
    /// growth with `n` in Fig. 6 ("the increase in the number of
    /// communications required by a factor of two").
    pub ingest_ms: f64,
}

impl EvalPoint {
    pub fn new(n: usize, r: usize, k: usize, trials: usize, seed: u64) -> Self {
        Self {
            n,
            r,
            k,
            trials,
            seed,
            schemes: vec![SchemeId::Cs, SchemeId::Ss, SchemeId::Ra, SchemeId::Pc, SchemeId::Pcmm, SchemeId::Lb],
            threads: std::thread::available_parallelism()
                .map(|p| p.get())
                .unwrap_or(1),
            ingest_ms: 0.0,
        }
    }

    pub fn with_schemes(mut self, schemes: &[SchemeId]) -> Self {
        self.schemes = schemes.to_vec();
        self
    }

    pub fn with_ingest(mut self, ingest_ms: f64) -> Self {
        assert!(ingest_ms >= 0.0);
        self.ingest_ms = ingest_ms;
        self
    }

    /// Schemes actually evaluable at this point (PC/PCMM need r ≥ 2 and
    /// k = n; RA needs r = n).
    pub fn applicable(&self) -> Vec<SchemeId> {
        self.schemes
            .iter()
            .copied()
            .filter(|s| match s {
                SchemeId::Pc | SchemeId::Pcmm => self.r >= 2 && self.k == self.n,
                SchemeId::Ra => self.r == self.n,
                _ => true,
            })
            .collect()
    }
}

/// Run the coupled evaluation; one estimate per applicable scheme, in
/// the order of [`EvalPoint::applicable`].
pub fn evaluate(point: &EvalPoint, model: &dyn DelayModel) -> Vec<CompletionEstimate> {
    let schemes = point.applicable();
    assert!(!schemes.is_empty(), "no applicable schemes at this point");
    let threads = point.threads.clamp(1, point.trials.max(1));
    let shard_sizes: Vec<usize> = (0..threads)
        .map(|t| point.trials / threads + usize::from(t < point.trials % threads))
        .collect();

    let mut per_scheme: Vec<Vec<f64>> = vec![Vec::with_capacity(point.trials); schemes.len()];
    std::thread::scope(|scope| {
        let handles: Vec<_> = shard_sizes
            .iter()
            .enumerate()
            .map(|(shard, &rounds)| {
                let schemes = &schemes;
                scope.spawn(move || shard_eval(point, model, schemes, rounds, shard as u64))
            })
            .collect();
        for h in handles {
            for (dst, src) in per_scheme.iter_mut().zip(h.join().expect("eval shard")) {
                dst.extend(src);
            }
        }
    });

    schemes
        .iter()
        .zip(per_scheme)
        .map(|(id, mut values)| {
            let mut acc = RunningStats::new();
            values.iter().for_each(|&v| acc.push(v));
            values.sort_unstable_by(f64::total_cmp);
            CompletionEstimate {
                scheme: id.to_string(),
                n: point.n,
                r: point.r,
                k: point.k,
                trials: values.len(),
                mean: acc.mean(),
                std_err: acc.std_err(),
                std_dev: acc.std_dev(),
                min: acc.min(),
                max: acc.max(),
                p50: quantile_sorted(&values, 0.5),
                p95: quantile_sorted(&values, 0.95),
            }
        })
        .collect()
}

fn shard_eval(
    point: &EvalPoint,
    model: &dyn DelayModel,
    schemes: &[SchemeId],
    rounds: usize,
    shard: u64,
) -> Vec<Vec<f64>> {
    let (n, r, k) = (point.n, point.r, point.k);
    let base = point.seed ^ 0x9E3779B97F4A7C15u64.wrapping_mul(shard + 1);
    let mut rng = Rng::seed_from_u64(base);
    let mut rng_sched = Rng::seed_from_u64(base ^ 0x5C4ED);

    let mut sample = DelaySample::zeros(n, r);
    let mut scratch: Vec<f64> = Vec::with_capacity(n);
    let mut lb_scratch: Vec<f64> = Vec::with_capacity(n * r);

    // prebuilt fixed schedules and coded schemes
    let cs = CyclicScheduler.schedule(n, r, &mut rng_sched);
    let ss = StaircaseScheduler.schedule(n, r, &mut rng_sched);
    let pc = if r >= 2 { Some(PcScheme::new(n, r)) } else { None };
    let pcmm = if r >= 2 { Some(PcmmScheme::new(n, r)) } else { None };

    let s = point.ingest_ms;
    let mut arrivals: Vec<(f64, usize)> = Vec::with_capacity(n * r);
    let mut out: Vec<Vec<f64>> = vec![Vec::with_capacity(rounds); schemes.len()];
    for _ in 0..rounds {
        model.sample_into(&mut sample, &mut rng);
        for (idx, scheme) in schemes.iter().enumerate() {
            let t = if s == 0.0 {
                // idealized eq. (1)–(2) dynamics
                match scheme {
                    SchemeId::Cs => completion_time_fast(&cs, &sample, k, &mut scratch),
                    SchemeId::Ss => completion_time_fast(&ss, &sample, k, &mut scratch),
                    SchemeId::Ra => {
                        let to = RandomAssignment.schedule(n, r, &mut rng_sched);
                        completion_time_fast(&to, &sample, k, &mut scratch)
                    }
                    SchemeId::Pc => pc
                        .as_ref()
                        .expect("PC applicable")
                        .completion_time(&sample, &mut lb_scratch),
                    SchemeId::Pcmm => pcmm
                        .as_ref()
                        .expect("PCMM applicable")
                        .completion_time(&sample, &mut lb_scratch),
                    SchemeId::Lb => lb::kth_slot_arrival(&sample, k, &mut lb_scratch),
                }
            } else {
                // testbed model: serialized master ingestion queue
                match scheme {
                    SchemeId::Cs => ingest_uncoded(&cs, &sample, k, s, &mut arrivals),
                    SchemeId::Ss => ingest_uncoded(&ss, &sample, k, s, &mut arrivals),
                    SchemeId::Ra => {
                        let to = RandomAssignment.schedule(n, r, &mut rng_sched);
                        ingest_uncoded(&to, &sample, k, s, &mut arrivals)
                    }
                    SchemeId::Pc => {
                        let pc = pc.as_ref().expect("PC applicable");
                        arrivals.clear();
                        for i in 0..n {
                            let comp: f64 = sample.comp_row(i).iter().sum();
                            arrivals.push((comp + sample.comm(i, r - 1), 0));
                        }
                        ingest_count(&mut arrivals, pc.recovery_threshold(), s)
                    }
                    SchemeId::Pcmm => {
                        let pcmm = pcmm.as_ref().expect("PCMM applicable");
                        slot_arrivals(&sample, &mut arrivals);
                        ingest_count(&mut arrivals, pcmm.recovery_threshold(), s)
                    }
                    SchemeId::Lb => {
                        // genie master ingests only the k useful messages
                        slot_arrivals(&sample, &mut arrivals);
                        ingest_count(&mut arrivals, k, s)
                    }
                }
            };
            out[idx].push(t);
        }
    }
    out
}

/// All n·r slot arrival times (task tag unused).
fn slot_arrivals(sample: &DelaySample, arrivals: &mut Vec<(f64, usize)>) {
    arrivals.clear();
    for i in 0..sample.n {
        let comp = sample.comp_row(i);
        let comm = sample.comm_row(i);
        let mut prefix = 0.0;
        for j in 0..sample.r {
            prefix += comp[j];
            arrivals.push((prefix + comm[j], 0));
        }
    }
}

/// Completion under a serialized ingestion queue, stopping at the
/// `count`-th processed message.  For LB the queue only sees the useful
/// messages, so sort first and sweep the earliest `count`.
fn ingest_count(arrivals: &mut [(f64, usize)], count: usize, s: f64) -> f64 {
    arrivals.sort_unstable_by(|a, b| a.0.total_cmp(&b.0));
    let mut busy = 0.0f64;
    for (idx, &(t, _)) in arrivals.iter().enumerate() {
        busy = busy.max(t) + s;
        if idx + 1 == count {
            return busy;
        }
    }
    unreachable!("count exceeds message stream")
}

/// Uncoded completion with ingestion: the master processes *every*
/// arriving message (duplicates included) in arrival order; the round
/// ends when the k-th distinct task finishes ingestion.
fn ingest_uncoded(
    to: &crate::scheduler::ToMatrix,
    sample: &DelaySample,
    k: usize,
    s: f64,
    arrivals: &mut Vec<(f64, usize)>,
) -> f64 {
    let (n, r) = (to.n(), to.r());
    arrivals.clear();
    for i in 0..n {
        let comp = sample.comp_row(i);
        let comm = sample.comm_row(i);
        let row = to.row(i);
        let mut prefix = 0.0;
        for j in 0..r {
            prefix += comp[j];
            arrivals.push((prefix + comm[j], row[j]));
        }
    }
    arrivals.sort_unstable_by(|a, b| a.0.total_cmp(&b.0));
    let mut busy = 0.0f64;
    let mut seen = vec![false; n];
    let mut distinct = 0usize;
    for &(t, task) in arrivals.iter() {
        busy = busy.max(t) + s;
        if !seen[task] {
            seen[task] = true;
            distinct += 1;
            if distinct == k {
                return busy;
            }
        }
    }
    panic!("TO matrix covers fewer than k distinct tasks");
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::delay::TruncatedGaussianModel;

    #[test]
    fn applicability_rules() {
        let p = EvalPoint::new(8, 1, 8, 10, 0);
        let a = p.applicable();
        assert!(!a.contains(&SchemeId::Pc), "PC needs r ≥ 2");
        assert!(!a.contains(&SchemeId::Ra), "RA needs r = n");
        assert!(a.contains(&SchemeId::Cs) && a.contains(&SchemeId::Lb));

        let p = EvalPoint::new(8, 8, 8, 10, 0);
        let a = p.applicable();
        assert!(a.contains(&SchemeId::Ra) && a.contains(&SchemeId::Pc));

        // partial target: coded schemes are k = n only (paper Fig. 7)
        let p = EvalPoint::new(8, 8, 5, 10, 0);
        assert!(!p.applicable().contains(&SchemeId::Pc));
        assert!(p.applicable().contains(&SchemeId::Ra));
    }

    #[test]
    fn lb_below_all_schemes_per_estimate() {
        let model = TruncatedGaussianModel::scenario1(8);
        let point = EvalPoint::new(8, 4, 8, 3000, 3);
        let est = evaluate(&point, &model);
        let schemes = point.applicable();
        let lb_mean = est[schemes.iter().position(|s| *s == SchemeId::Lb).unwrap()].mean;
        for (id, e) in schemes.iter().zip(&est) {
            assert!(
                lb_mean <= e.mean + 1e-9,
                "LB {lb_mean} above {id} {}",
                e.mean
            );
        }
    }

    #[test]
    fn deterministic_across_runs() {
        let model = TruncatedGaussianModel::scenario1(6);
        let point = EvalPoint::new(6, 3, 6, 500, 9);
        let a = evaluate(&point, &model);
        let b = evaluate(&point, &model);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.mean, y.mean, "{}", x.scheme);
        }
    }

    #[test]
    fn full_load_ordering_matches_paper() {
        // Fig. 5 r = n shape: CS/SS < RA and LB below everything
        let model = TruncatedGaussianModel::scenario1(10);
        let point = EvalPoint::new(10, 10, 10, 4000, 17);
        let est = evaluate(&point, &model);
        let by = |id: SchemeId| {
            est.iter()
                .find(|e| e.scheme == id.to_string())
                .map(|e| e.mean)
                .unwrap()
        };
        assert!(by(SchemeId::Cs) < by(SchemeId::Ra));
        assert!(by(SchemeId::Ss) < by(SchemeId::Ra));
        assert!(by(SchemeId::Lb) <= by(SchemeId::Ss));
    }
}
