//! Coupled multi-scheme evaluator: CS, SS, RA, PC, PCMM and LB against
//! the *identical* delay stream — the engine behind every figure.
//!
//! Per chunk of rounds one [`DelayBatch`] is drawn and every slot's
//! arrival time is computed **once** ([`slot_arrivals_batch`]); every
//! scheme's completion time is then derived from that shared array
//! (uncoded via the §II dynamics, PCMM and LB directly as order
//! statistics of the arrivals, PC from the per-worker comp/comm rows)
//! without re-reading the delay stream per scheme.  This is the paper's
//! fairness discipline ("for fairness we use the same dataset for all
//! the schemes") applied to the randomness itself, and it makes
//! ordering assertions (LB ≤ CS, …) hold per realization, not just in
//! expectation.
//!
//! Shards run on the persistent [`WorkerPool`] with RNG streams from
//! [`shard_rngs`] — the same shard-seeding invariant as the plain
//! Monte-Carlo engine, so harness estimates can never decouple from
//! `MonteCarlo` estimates for structural reasons.  Trial statistics
//! stream into `RunningStats` + `StreamingQuantiles`, keeping memory
//! O(schemes) at any trial count.

use crate::coded::{PcScheme, PcmmScheme};
use crate::delay::{DelayBatch, DelayModel};
use crate::scheduler::{
    CyclicScheduler, RandomAssignment, Scheduler, SchemeId, StaircaseScheduler,
};
use crate::sim::{
    completion_from_arrivals, kth_arrival_from_arrivals, shard_layout, shard_rngs,
    slot_arrivals_batch, CompletionEstimate, FlatTasks, WorkerPool, BATCH_ROUNDS,
};
use crate::util::stats::{RunningStats, StreamingQuantiles};

/// Evaluation request for one `(n, r, k)` point.
#[derive(Debug, Clone)]
pub struct EvalPoint {
    pub n: usize,
    pub r: usize,
    pub k: usize,
    pub trials: usize,
    pub seed: u64,
    pub schemes: Vec<SchemeId>,
    /// Number of deterministic shards (RNG streams).  OS concurrency is
    /// clamped to `available_parallelism` by the persistent pool.
    pub threads: usize,
    /// Master-side per-message ingestion cost (ms).  `0` gives the
    /// paper's idealized eq. (1)–(2) dynamics (used for Fig. 4's pure
    /// statistical model).  A positive value models the serialized
    /// receive loop of the paper's Python/MPI master on the EC2
    /// testbed: messages queue at the master and each costs
    /// `ingest_ms` to process.  This is what makes multi-message
    /// schemes (PCMM's `2n − 1` receptions) pay for their extra
    /// communication — the effect the paper invokes to explain PCMM's
    /// growth with `n` in Fig. 6 ("the increase in the number of
    /// communications required by a factor of two").
    pub ingest_ms: f64,
}

impl EvalPoint {
    pub fn new(n: usize, r: usize, k: usize, trials: usize, seed: u64) -> Self {
        Self {
            n,
            r,
            k,
            trials,
            seed,
            schemes: vec![
                SchemeId::Cs,
                SchemeId::Ss,
                SchemeId::Ra,
                SchemeId::Pc,
                SchemeId::Pcmm,
                SchemeId::Lb,
            ],
            threads: std::thread::available_parallelism()
                .map(|p| p.get())
                .unwrap_or(1),
            ingest_ms: 0.0,
        }
    }

    pub fn with_schemes(mut self, schemes: &[SchemeId]) -> Self {
        self.schemes = schemes.to_vec();
        self
    }

    pub fn with_ingest(mut self, ingest_ms: f64) -> Self {
        assert!(ingest_ms >= 0.0);
        self.ingest_ms = ingest_ms;
        self
    }

    /// Schemes actually evaluable at this point (PC/PCMM need r ≥ 2 and
    /// k = n; RA needs r = n).
    pub fn applicable(&self) -> Vec<SchemeId> {
        self.schemes
            .iter()
            .copied()
            .filter(|s| match s {
                SchemeId::Pc | SchemeId::Pcmm => self.r >= 2 && self.k == self.n,
                SchemeId::Ra => self.r == self.n,
                _ => true,
            })
            .collect()
    }
}

/// Run the coupled evaluation; one estimate per applicable scheme, in
/// the order of [`EvalPoint::applicable`].
pub fn evaluate(point: &EvalPoint, model: &dyn DelayModel) -> Vec<CompletionEstimate> {
    let schemes = point.applicable();
    assert!(!schemes.is_empty(), "no applicable schemes at this point");
    let shard_sizes = shard_layout(point.trials, point.threads);

    let schemes_ref = &schemes;
    let jobs: Vec<_> = shard_sizes
        .into_iter()
        .enumerate()
        .map(|(shard, rounds)| {
            move || shard_eval(point, model, schemes_ref, rounds, shard as u64)
        })
        .collect();
    let per_shard = WorkerPool::global().scope_run(jobs);

    let mut merged: Vec<(RunningStats, StreamingQuantiles)> =
        vec![(RunningStats::new(), StreamingQuantiles::new()); schemes.len()];
    for shard_acc in per_shard {
        for (dst, src) in merged.iter_mut().zip(shard_acc) {
            dst.0.merge(&src.0);
            dst.1.merge(&src.1);
        }
    }

    schemes
        .iter()
        .zip(merged)
        .map(|(id, (stats, quantiles))| {
            CompletionEstimate::from_streams(
                id.to_string(),
                point.n,
                point.r,
                point.k,
                &stats,
                &quantiles,
            )
        })
        .collect()
}

fn shard_eval(
    point: &EvalPoint,
    model: &dyn DelayModel,
    schemes: &[SchemeId],
    rounds: usize,
    shard: u64,
) -> Vec<(RunningStats, StreamingQuantiles)> {
    let (n, r, k) = (point.n, point.r, point.k);
    let (mut rng, mut rng_sched) = shard_rngs(point.seed, shard);

    // prebuilt fixed schedules (flattened once) and coded schemes
    let cs = FlatTasks::new(&CyclicScheduler.schedule(n, r, &mut rng_sched));
    let ss = FlatTasks::new(&StaircaseScheduler.schedule(n, r, &mut rng_sched));
    let pc = if r >= 2 { Some(PcScheme::new(n, r)) } else { None };
    let pcmm = if r >= 2 { Some(PcmmScheme::new(n, r)) } else { None };

    let s = point.ingest_ms;
    let stride = n * r;
    let mut acc: Vec<(RunningStats, StreamingQuantiles)> =
        vec![(RunningStats::new(), StreamingQuantiles::new()); schemes.len()];

    let mut batch = DelayBatch::zeros(BATCH_ROUNDS.min(rounds.max(1)), n, r);
    let mut arrivals: Vec<f64> = Vec::new();
    let mut task_times: Vec<f64> = Vec::with_capacity(n);
    let mut scratch: Vec<f64> = Vec::with_capacity(stride);
    let mut pairs: Vec<(f64, usize)> = Vec::with_capacity(stride);
    // per-draw scratch for RA's fresh matrices, refilled in place
    let mut ra_flat: Option<FlatTasks> = None;

    let mut done = 0usize;
    while done < rounds {
        let chunk = BATCH_ROUNDS.min(rounds - done);
        if batch.rounds != chunk {
            batch = DelayBatch::zeros(chunk, n, r);
        }
        model.sample_batch_into(&mut batch, &mut rng);
        slot_arrivals_batch(&batch, &mut arrivals);
        for b in 0..chunk {
            let round_arrivals = &arrivals[b * stride..(b + 1) * stride];
            let comp = batch.comp_round(b);
            let comm = batch.comm_round(b);
            for (idx, scheme) in schemes.iter().enumerate() {
                let t = if s == 0.0 {
                    // idealized eq. (1)–(2) dynamics, all from the
                    // shared arrival array
                    match scheme {
                        SchemeId::Cs => {
                            completion_from_arrivals(&cs, round_arrivals, k, &mut task_times)
                        }
                        SchemeId::Ss => {
                            completion_from_arrivals(&ss, round_arrivals, k, &mut task_times)
                        }
                        SchemeId::Ra => {
                            let to = RandomAssignment.schedule(n, r, &mut rng_sched);
                            let flat = FlatTasks::refill_or_init(&mut ra_flat, &to);
                            completion_from_arrivals(flat, round_arrivals, k, &mut task_times)
                        }
                        SchemeId::Pc => pc_completion(
                            comp,
                            comm,
                            n,
                            r,
                            pc.as_ref().expect("PC applicable").recovery_threshold(),
                            &mut scratch,
                        ),
                        SchemeId::Pcmm => kth_arrival_from_arrivals(
                            round_arrivals,
                            pcmm.as_ref().expect("PCMM applicable").recovery_threshold(),
                            &mut scratch,
                        ),
                        SchemeId::Lb => {
                            kth_arrival_from_arrivals(round_arrivals, k, &mut scratch)
                        }
                    }
                } else {
                    // testbed model: serialized master ingestion queue
                    match scheme {
                        SchemeId::Cs => {
                            ingest_uncoded(&cs, round_arrivals, k, s, &mut pairs)
                        }
                        SchemeId::Ss => {
                            ingest_uncoded(&ss, round_arrivals, k, s, &mut pairs)
                        }
                        SchemeId::Ra => {
                            let to = RandomAssignment.schedule(n, r, &mut rng_sched);
                            let flat = FlatTasks::refill_or_init(&mut ra_flat, &to);
                            ingest_uncoded(flat, round_arrivals, k, s, &mut pairs)
                        }
                        SchemeId::Pc => {
                            let pc = pc.as_ref().expect("PC applicable");
                            pairs.clear();
                            for i in 0..n {
                                let comp_sum: f64 = comp[i * r..(i + 1) * r].iter().sum();
                                pairs.push((comp_sum + comm[i * r + r - 1], 0));
                            }
                            ingest_count(&mut pairs, pc.recovery_threshold(), s)
                        }
                        SchemeId::Pcmm => {
                            let pcmm = pcmm.as_ref().expect("PCMM applicable");
                            pairs.clear();
                            pairs.extend(round_arrivals.iter().map(|&t| (t, 0)));
                            ingest_count(&mut pairs, pcmm.recovery_threshold(), s)
                        }
                        SchemeId::Lb => {
                            // genie master ingests only the k useful messages
                            pairs.clear();
                            pairs.extend(round_arrivals.iter().map(|&t| (t, 0)));
                            ingest_count(&mut pairs, k, s)
                        }
                    }
                };
                acc[idx].0.push(t);
                acc[idx].1.push(t);
            }
        }
        done += chunk;
    }
    acc
}

/// PC completion (eqs. 51–52) from one round's comp/comm rows: worker
/// `i` finishes at `Σ_{j<r} comp(i,j) + comm(i, r−1)` (all `r` tasks,
/// one message); the round completes at the threshold-th order
/// statistic across workers.  Mirrors `PcScheme::completion_time` on
/// the batch's flat storage.
fn pc_completion(
    comp: &[f64],
    comm: &[f64],
    n: usize,
    r: usize,
    threshold: usize,
    scratch: &mut Vec<f64>,
) -> f64 {
    scratch.clear();
    for i in 0..n {
        let comp_sum: f64 = comp[i * r..(i + 1) * r].iter().sum();
        scratch.push(comp_sum + comm[i * r + r - 1]);
    }
    let (_, kth, _) = scratch.select_nth_unstable_by(threshold - 1, |a, b| a.total_cmp(b));
    *kth
}

/// Completion under a serialized ingestion queue, stopping at the
/// `count`-th processed message.  For LB the queue only sees the useful
/// messages, so sort first and sweep the earliest `count`.
fn ingest_count(arrivals: &mut [(f64, usize)], count: usize, s: f64) -> f64 {
    arrivals.sort_unstable_by(|a, b| a.0.total_cmp(&b.0));
    let mut busy = 0.0f64;
    for (idx, &(t, _)) in arrivals.iter().enumerate() {
        busy = busy.max(t) + s;
        if idx + 1 == count {
            return busy;
        }
    }
    unreachable!("count exceeds message stream")
}

/// Uncoded completion with ingestion: the master processes *every*
/// arriving message (duplicates included) in arrival order; the round
/// ends when the k-th distinct task finishes ingestion.  Message
/// arrival times come from the shared per-round arrival array; the TO
/// matrix only supplies the task tags.
fn ingest_uncoded(
    tasks: &FlatTasks,
    round_arrivals: &[f64],
    k: usize,
    s: f64,
    pairs: &mut Vec<(f64, usize)>,
) -> f64 {
    let n = tasks.n();
    pairs.clear();
    pairs.extend(
        round_arrivals
            .iter()
            .zip(tasks.tasks())
            .map(|(&t, &task)| (t, task)),
    );
    pairs.sort_unstable_by(|a, b| a.0.total_cmp(&b.0));
    let mut busy = 0.0f64;
    let mut seen = vec![false; n];
    let mut distinct = 0usize;
    for &(t, task) in pairs.iter() {
        busy = busy.max(t) + s;
        if !seen[task] {
            seen[task] = true;
            distinct += 1;
            if distinct == k {
                return busy;
            }
        }
    }
    panic!("TO matrix covers fewer than k distinct tasks");
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::delay::TruncatedGaussianModel;

    #[test]
    fn applicability_rules() {
        let p = EvalPoint::new(8, 1, 8, 10, 0);
        let a = p.applicable();
        assert!(!a.contains(&SchemeId::Pc), "PC needs r ≥ 2");
        assert!(!a.contains(&SchemeId::Ra), "RA needs r = n");
        assert!(a.contains(&SchemeId::Cs) && a.contains(&SchemeId::Lb));

        let p = EvalPoint::new(8, 8, 8, 10, 0);
        let a = p.applicable();
        assert!(a.contains(&SchemeId::Ra) && a.contains(&SchemeId::Pc));

        // partial target: coded schemes are k = n only (paper Fig. 7)
        let p = EvalPoint::new(8, 8, 5, 10, 0);
        assert!(!p.applicable().contains(&SchemeId::Pc));
        assert!(p.applicable().contains(&SchemeId::Ra));
    }

    #[test]
    fn lb_below_all_schemes_per_estimate() {
        let model = TruncatedGaussianModel::scenario1(8);
        let point = EvalPoint::new(8, 4, 8, 3000, 3);
        let est = evaluate(&point, &model);
        let schemes = point.applicable();
        let lb_mean = est[schemes.iter().position(|s| *s == SchemeId::Lb).unwrap()].mean;
        for (id, e) in schemes.iter().zip(&est) {
            assert!(
                lb_mean <= e.mean + 1e-9,
                "LB {lb_mean} above {id} {}",
                e.mean
            );
        }
    }

    #[test]
    fn deterministic_across_runs() {
        let model = TruncatedGaussianModel::scenario1(6);
        let point = EvalPoint::new(6, 3, 6, 500, 9);
        let a = evaluate(&point, &model);
        let b = evaluate(&point, &model);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.mean, y.mean, "{}", x.scheme);
        }
    }

    #[test]
    fn harness_couples_to_plain_monte_carlo_engine() {
        // shard-seeding invariant across code paths: the harness and
        // `MonteCarlo` must see bit-identical delay streams for the
        // same (trials, threads, seed), so a CS-only evaluation agrees
        // exactly, not just statistically
        use crate::sim::MonteCarlo;
        let model = TruncatedGaussianModel::scenario1(7);
        let mut point = EvalPoint::new(7, 3, 7, 2000, 31).with_schemes(&[SchemeId::Cs]);
        point.threads = 4;
        let harness = evaluate(&point, &model).remove(0);
        let mc = MonteCarlo {
            trials: 2000,
            seed: 31,
            threads: 4,
        };
        let plain = mc.estimate(&CyclicScheduler, &model, 7, 3, 7);
        assert_eq!(harness.mean.to_bits(), plain.mean.to_bits());
        assert_eq!(harness.p95.to_bits(), plain.p95.to_bits());
    }

    #[test]
    fn pc_completion_matches_coded_module_kernel() {
        // the harness's slice-based PC kernel must stay bit-identical
        // to PcScheme::completion_time, or figure PC curves silently
        // drift from the coded module's ground truth
        use crate::delay::{DelayModel, TruncatedGaussianModel};
        let (n, r) = (9usize, 4usize);
        let model = TruncatedGaussianModel::scenario2(n, 8);
        let mut rng = crate::util::rng::Rng::seed_from_u64(2);
        let pc = PcScheme::new(n, r);
        let mut coded_scratch: Vec<f64> = Vec::new();
        let mut flat_scratch: Vec<f64> = Vec::new();
        for _ in 0..64 {
            let sample = model.sample(n, r, &mut rng);
            let coded = pc.completion_time(&sample, &mut coded_scratch);
            let flat = pc_completion(
                sample.comp_flat(),
                sample.comm_flat(),
                n,
                r,
                pc.recovery_threshold(),
                &mut flat_scratch,
            );
            assert_eq!(coded.to_bits(), flat.to_bits());
        }
    }

    #[test]
    fn full_load_ordering_matches_paper() {
        // Fig. 5 r = n shape: CS/SS < RA and LB below everything
        let model = TruncatedGaussianModel::scenario1(10);
        let point = EvalPoint::new(10, 10, 10, 4000, 17);
        let est = evaluate(&point, &model);
        let by = |id: SchemeId| {
            est.iter()
                .find(|e| e.scheme == id.to_string())
                .map(|e| e.mean)
                .unwrap()
        };
        assert!(by(SchemeId::Cs) < by(SchemeId::Ra));
        assert!(by(SchemeId::Ss) < by(SchemeId::Ra));
        assert!(by(SchemeId::Lb) <= by(SchemeId::Ss));
    }
}
