//! Coupled multi-scheme evaluator: every scheme the registry knows
//! (CS, SS, RA, PC, PCMM, LB, GC(s), …) against the *identical* delay
//! stream — the engine behind every figure.
//!
//! Per chunk of rounds one `DelayBatch` is drawn and every slot's
//! arrival time is computed **once**; every scheme's completion time is
//! then derived from that shared array by its registry-built evaluator
//! ([`crate::scheme`]) without re-reading the delay stream per scheme.
//! This is the paper's fairness discipline ("for fairness we use the
//! same dataset for all the schemes") applied to the randomness itself,
//! and it makes ordering assertions (LB ≤ CS, …) hold per realization,
//! not just in expectation.
//!
//! Shards run on the persistent [`WorkerPool`] with RNG streams from
//! [`shard_rngs`] — the same shard-seeding invariant as the plain
//! Monte-Carlo engine, and since PR 2 literally the same chunk loop
//! ([`crate::scheme::run_rounds`]), so harness estimates can never
//! decouple from `MonteCarlo` estimates for structural reasons.  Trial
//! statistics stream into `RunningStats` + `StreamingQuantiles`,
//! keeping memory O(schemes) at any trial count.

use crate::delay::DelayModel;
use crate::scheme::{run_rounds, SchemeId, SchemeRegistry};
use crate::sim::{shard_layout, shard_rngs, CompletionEstimate, WorkerPool};
use crate::util::stats::{RunningStats, StreamingQuantiles};

/// Evaluation request for one `(n, r, k)` point.
#[derive(Debug, Clone)]
pub struct EvalPoint {
    pub n: usize,
    pub r: usize,
    pub k: usize,
    pub trials: usize,
    pub seed: u64,
    pub schemes: Vec<SchemeId>,
    /// Number of deterministic shards (RNG streams).  OS concurrency is
    /// clamped to `available_parallelism` by the persistent pool.
    pub threads: usize,
    /// Master-side per-message ingestion cost (ms).  `0` gives the
    /// paper's idealized eq. (1)–(2) dynamics (used for Fig. 4's pure
    /// statistical model).  A positive value models the serialized
    /// receive loop of the paper's Python/MPI master on the EC2
    /// testbed: messages queue at the master and each costs
    /// `ingest_ms` to process.  This is what makes multi-message
    /// schemes (PCMM's `2n − 1` receptions) pay for their extra
    /// communication — the effect the paper invokes to explain PCMM's
    /// growth with `n` in Fig. 6 — and what grouped flushing (GC(s))
    /// trades computation lateness against.
    pub ingest_ms: f64,
}

impl EvalPoint {
    pub fn new(n: usize, r: usize, k: usize, trials: usize, seed: u64) -> Self {
        Self {
            n,
            r,
            k,
            trials,
            seed,
            schemes: SchemeRegistry::default_schemes(),
            threads: std::thread::available_parallelism()
                .map(|p| p.get())
                .unwrap_or(1),
            ingest_ms: 0.0,
        }
    }

    pub fn with_schemes(mut self, schemes: &[SchemeId]) -> Self {
        self.schemes = schemes.to_vec();
        self
    }

    pub fn with_ingest(mut self, ingest_ms: f64) -> Self {
        assert!(ingest_ms >= 0.0);
        self.ingest_ms = ingest_ms;
        self
    }

    /// Schemes actually evaluable at this point, per the registry's
    /// paper-Table-I rules (PC/PCMM need r ≥ 2 and k = n; RA needs
    /// r = n; GC(s) needs s ≤ r).
    pub fn applicable(&self) -> Vec<SchemeId> {
        self.schemes
            .iter()
            .copied()
            .filter(|&s| SchemeRegistry::applicable(s, self.n, self.r, self.k))
            .collect()
    }
}

/// Run the coupled evaluation; one estimate per applicable scheme, in
/// the order of [`EvalPoint::applicable`].
pub fn evaluate(point: &EvalPoint, model: &dyn DelayModel) -> Vec<CompletionEstimate> {
    let schemes = point.applicable();
    assert!(!schemes.is_empty(), "no applicable schemes at this point");
    let shard_sizes = shard_layout(point.trials, point.threads);

    let schemes_ref = &schemes;
    let jobs: Vec<_> = shard_sizes
        .into_iter()
        .enumerate()
        .map(|(shard, rounds)| {
            move || shard_eval(point, model, schemes_ref, rounds, shard as u64)
        })
        .collect();
    let per_shard = WorkerPool::global().scope_run(jobs);

    let mut merged: Vec<(RunningStats, StreamingQuantiles)> =
        vec![(RunningStats::new(), StreamingQuantiles::new()); schemes.len()];
    for shard_acc in per_shard {
        for (dst, src) in merged.iter_mut().zip(shard_acc) {
            dst.0.merge(&src.0);
            dst.1.merge(&src.1);
        }
    }

    schemes
        .iter()
        .zip(merged)
        .map(|(id, (stats, quantiles))| {
            CompletionEstimate::from_streams(
                id.to_string(),
                point.n,
                point.r,
                point.k,
                &stats,
                &quantiles,
            )
        })
        .collect()
}

/// One shard: prepare every scheme's evaluator once (consuming the
/// scheduling RNG in scheme order — the bit-identity contract), then
/// drive the shared chunk loop.
fn shard_eval(
    point: &EvalPoint,
    model: &dyn DelayModel,
    schemes: &[SchemeId],
    rounds: usize,
    shard: u64,
) -> Vec<(RunningStats, StreamingQuantiles)> {
    let (n, r, k) = (point.n, point.r, point.k);
    let (mut rng, mut rng_sched) = shard_rngs(point.seed, shard);

    let mut evaluators: Vec<_> = schemes
        .iter()
        .map(|&id| SchemeRegistry::build(id).prepare(n, r, k, &mut rng_sched))
        .collect();

    let mut acc: Vec<(RunningStats, StreamingQuantiles)> =
        vec![(RunningStats::new(), StreamingQuantiles::new()); schemes.len()];
    run_rounds(
        &mut evaluators,
        model,
        n,
        r,
        rounds,
        point.ingest_ms,
        &mut rng,
        &mut rng_sched,
        &mut |idx, t| {
            acc[idx].0.push(t);
            acc[idx].1.push(t);
        },
    );
    acc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::delay::TruncatedGaussianModel;

    #[test]
    fn applicability_rules() {
        let p = EvalPoint::new(8, 1, 8, 10, 0);
        let a = p.applicable();
        assert!(!a.contains(&SchemeId::Pc), "PC needs r ≥ 2");
        assert!(!a.contains(&SchemeId::Ra), "RA needs r = n");
        assert!(a.contains(&SchemeId::Cs) && a.contains(&SchemeId::Lb));

        let p = EvalPoint::new(8, 8, 8, 10, 0);
        let a = p.applicable();
        assert!(a.contains(&SchemeId::Ra) && a.contains(&SchemeId::Pc));

        // partial target: coded schemes are k = n only (paper Fig. 7)
        let p = EvalPoint::new(8, 8, 5, 10, 0);
        assert!(!p.applicable().contains(&SchemeId::Pc));
        assert!(p.applicable().contains(&SchemeId::Ra));

        // GC groups are bounded by the row length
        let p = EvalPoint::new(8, 4, 8, 10, 0)
            .with_schemes(&[SchemeId::Gc(4), SchemeId::Gc(5)]);
        assert_eq!(p.applicable(), vec![SchemeId::Gc(4)]);
    }

    #[test]
    fn lb_below_all_schemes_per_estimate() {
        let model = TruncatedGaussianModel::scenario1(8);
        let point = EvalPoint::new(8, 4, 8, 3000, 3);
        let est = evaluate(&point, &model);
        let schemes = point.applicable();
        let lb_mean = est[schemes.iter().position(|s| *s == SchemeId::Lb).unwrap()].mean;
        for (id, e) in schemes.iter().zip(&est) {
            assert!(
                lb_mean <= e.mean + 1e-9,
                "LB {lb_mean} above {id} {}",
                e.mean
            );
        }
    }

    #[test]
    fn deterministic_across_runs() {
        let model = TruncatedGaussianModel::scenario1(6);
        let point = EvalPoint::new(6, 3, 6, 500, 9);
        let a = evaluate(&point, &model);
        let b = evaluate(&point, &model);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.mean, y.mean, "{}", x.scheme);
        }
    }

    #[test]
    fn harness_couples_to_plain_monte_carlo_engine() {
        // shard-seeding invariant across code paths: the harness and
        // `MonteCarlo` must see bit-identical delay streams for the
        // same (trials, threads, seed), so a CS-only evaluation agrees
        // exactly, not just statistically
        use crate::scheduler::CyclicScheduler;
        use crate::sim::MonteCarlo;
        let model = TruncatedGaussianModel::scenario1(7);
        let mut point = EvalPoint::new(7, 3, 7, 2000, 31).with_schemes(&[SchemeId::Cs]);
        point.threads = 4;
        let harness = evaluate(&point, &model).remove(0);
        let mc = MonteCarlo {
            trials: 2000,
            seed: 31,
            threads: 4,
        };
        let plain = mc.estimate(&CyclicScheduler, &model, 7, 3, 7);
        assert_eq!(harness.mean.to_bits(), plain.mean.to_bits());
        assert_eq!(harness.p95.to_bits(), plain.p95.to_bits());
    }

    #[test]
    fn full_load_ordering_matches_paper() {
        // Fig. 5 r = n shape: CS/SS < RA and LB below everything
        let model = TruncatedGaussianModel::scenario1(10);
        let point = EvalPoint::new(10, 10, 10, 4000, 17);
        let est = evaluate(&point, &model);
        let by = |id: SchemeId| {
            est.iter()
                .find(|e| e.scheme == id.to_string())
                .map(|e| e.mean)
                .unwrap()
        };
        assert!(by(SchemeId::Cs) < by(SchemeId::Ra));
        assert!(by(SchemeId::Ss) < by(SchemeId::Ra));
        assert!(by(SchemeId::Lb) <= by(SchemeId::Ss));
    }
}
