//! Experiment harness: regenerates every table and figure of the
//! paper's evaluation section (§VI) — see DESIGN.md §4 for the index.
//!
//! Each `figN` function sweeps the paper's parameter grid with the
//! coupled evaluator ([`eval`]), prints the series the paper plots, and
//! writes `results/figN.{csv,json}`.  Absolute numbers depend on the
//! delay substrate (we simulate the EC2 testbed — DESIGN.md §2); the
//! assertions in `rust/tests/figures_smoke.rs` pin the *shape*: who
//! wins, roughly by how much, where the crossovers fall.

pub mod eval;

pub use eval::{evaluate, EvalPoint};


use anyhow::Result;

use crate::adaptive::{
    run_policy_rounds, two_tier_model, PolicyKind, PolicyRunConfig, ShiftingStraggler,
};
use crate::coordinator::{run_cluster, ClusterConfig, ClusterReport, IoMode};
use crate::data::Dataset;
use crate::delay::{DelayModel, DelayModelKind, Ec2LikeModel, TruncatedGaussianModel};
use crate::metrics::{fit_truncated_gaussian, Histogram};
use crate::report::Table;
use crate::scheduler::SchemeId;
use crate::scheme::SchemeRegistry;
use crate::sim::CompletionEstimate;
use crate::telemetry::MetricsConfig;

/// Common harness options.
#[derive(Debug, Clone)]
pub struct Options {
    pub trials: usize,
    pub seed: u64,
    pub out_dir: Option<std::path::PathBuf>,
    /// Fig. 4 scenario (1 or 2)
    pub scenario: u8,
    /// run the real cluster (sockets + compute) instead of / alongside
    /// the fast Monte-Carlo path where applicable
    pub cluster: bool,
}

impl Default for Options {
    fn default() -> Self {
        Self {
            trials: 20_000,
            seed: 0xF16,
            out_dir: Some("results".into()),
            scenario: 1,
            cluster: false,
        }
    }
}

impl Options {
    /// Write a table to the configured output dir (no-op when `--no-out`).
    pub fn write(&self, table: &Table, name: &str) -> Result<()> {
        if let Some(dir) = &self.out_dir {
            let paths = table.write(dir, name)?;
            for p in paths {
                println!("  wrote {}", p.display());
            }
        }
        Ok(())
    }
}

fn mean_of(estimates: &[CompletionEstimate], id: SchemeId) -> f64 {
    estimates
        .iter()
        .find(|e| e.scheme == id.to_string())
        .map(|e| e.mean)
        .unwrap_or(f64::NAN)
}

/// Master-side per-message ingestion cost used by the EC2-testbed
/// figures (5–7): models the serialized Python/MPI receive loop of the
/// paper's master (DESIGN.md §2).  Fig. 4 — the paper's *numerical*
/// (pure statistical-model) experiment — uses 0.
pub const EC2_INGEST_MS: f64 = 0.15;

/// Shared sweep body for Figs. 4 and 5: t̄ vs computation load r.
fn sweep_r(
    n: usize,
    model: &dyn DelayModel,
    opts: &Options,
    ingest_ms: f64,
) -> (Table, Vec<(usize, Vec<CompletionEstimate>)>) {
    let mut table = Table::new(
        &format!("average completion time (ms) vs computation load, n = {n}, k = n"),
        &["r", "CS", "SS", "PC", "PCMM", "LB"],
    );
    let mut raw = Vec::new();
    for r in 2..=n {
        let point = EvalPoint::new(n, r, n, opts.trials, opts.seed).with_ingest(ingest_ms);
        let est = evaluate(&point, model);
        table.push_row(vec![
            r.to_string(),
            Table::fmt(mean_of(&est, SchemeId::Cs)),
            Table::fmt(mean_of(&est, SchemeId::Ss)),
            Table::fmt(mean_of(&est, SchemeId::Pc)),
            Table::fmt(mean_of(&est, SchemeId::Pcmm)),
            Table::fmt(mean_of(&est, SchemeId::Lb)),
        ]);
        raw.push((r, est));
    }
    (table, raw)
}

/// Append the paper's RA comparison note (r = n point).
fn ra_note(n: usize, raw: &[(usize, Vec<CompletionEstimate>)]) -> String {
    let last = &raw.last().expect("nonempty sweep").1;
    let ra = mean_of(last, SchemeId::Ra);
    let ss = mean_of(last, SchemeId::Ss);
    let cs = mean_of(last, SchemeId::Cs);
    format!(
        "r = n = {n}: RA {} ms; SS {} ms ({:.2}% reduction); CS {} ms ({:.2}% reduction)",
        Table::fmt(ra),
        Table::fmt(ss),
        100.0 * (1.0 - ss / ra),
        Table::fmt(cs),
        100.0 * (1.0 - cs / ra),
    )
}

/// **Fig. 4** — truncated-Gaussian delays (eq. 66), n = 16, k = n,
/// scenarios 1 (homogeneous) and 2 (heterogeneous means).
pub fn fig4(opts: &Options) -> Result<Table> {
    let n = 16;
    let model: Box<dyn DelayModel> = match opts.scenario {
        1 => Box::new(TruncatedGaussianModel::scenario1(n)),
        2 => Box::new(TruncatedGaussianModel::scenario2(n, opts.seed)),
        s => anyhow::bail!("fig4 scenario must be 1 or 2, got {s}"),
    };
    let (mut table, raw) = sweep_r(n, model.as_ref(), opts, 0.0);
    table.title = format!(
        "Fig. 4 (scenario {}): t̄ (ms) vs r — truncated Gaussian, n = 16, k = n",
        opts.scenario
    );
    table.print();
    println!("  {}", ra_note(n, &raw));
    opts.write(&table, &format!("fig4_scenario{}", opts.scenario))?;
    Ok(table)
}

/// **Fig. 5** — the EC2 experiment: n = 15, d = 400, N = 900, k = n.
/// Delay substrate: the EC2-like model (DESIGN.md §2); optionally a
/// real-cluster spot check at r ∈ {2, n} with `--cluster`.
pub fn fig5(opts: &Options) -> Result<Table> {
    let n = 15;
    let model = Ec2LikeModel::new(n, opts.seed ^ 0xEC2, 0.2);
    let (mut table, raw) = sweep_r(n, &model, opts, EC2_INGEST_MS);
    table.title = "Fig. 5: t̄ (ms) vs r — EC2-like cluster, n = 15, d = 400, N = 900, k = n".into();
    table.print();
    println!("  {}", ra_note(n, &raw));
    opts.write(&table, "fig5")?;

    if opts.cluster {
        let spot = fig5_cluster_spotcheck(opts)?;
        spot.print();
        opts.write(&spot, "fig5_cluster_spotcheck")?;
    }
    Ok(table)
}

/// Real-cluster spot check for Fig. 5: run the socketed coordinator at
/// a few r values and report measured completion times next to the
/// Monte-Carlo numbers (they should agree to within scheduling noise).
fn fig5_cluster_spotcheck(opts: &Options) -> Result<Table> {
    let n = 15;
    let rounds = 150.min(opts.trials);
    let mut table = Table::new(
        "Fig. 5 cluster spot check: measured t̄ (ms), real sockets + compute",
        &["r", "CS (cluster)", "SS (cluster)"],
    );
    for r in [2usize, 8, n] {
        let mut row = vec![r.to_string()];
        for id in [SchemeId::Cs, SchemeId::Ss] {
            let report = run_cluster(ClusterConfig {
                n,
                r,
                k: n,
                eta: 0.01,
                rounds,
                profile: "fig5".into(),
                plan: SchemeRegistry::cluster_plan(id, n, r, n)?,
                policy: PolicyKind::Static,
                staleness: 1,
                dataset: Dataset::synthesize(n, 400, 900, opts.seed),
                inject: Some(DelayModelKind::Ec2Like {
                    seed: opts.seed ^ 0xEC2,
                    hetero: 0.2,
                }),
                seed: opts.seed,
                use_pjrt: false,
                artifact_dir: None,
                loss_every: 0,
                listen: None,
                spawn_workers: true,
                io: IoMode::default(),
                metrics: MetricsConfig::default(),
            })?;
            row.push(Table::fmt(report.mean_completion_ms()));
        }
        table.push_row(row);
    }
    Ok(table)
}

/// **Fig. 6** — t̄ vs number of workers n ∈ [10, 15], r = n, k = n
/// (d = 500, N = 1000, zero-padded when n ∤ N).
pub fn fig6(opts: &Options) -> Result<Table> {
    let mut table = Table::new(
        "Fig. 6: t̄ (ms) vs n — r = n, k = n, d = 500, N = 1000",
        &["n", "CS", "SS", "RA", "PC", "PCMM", "LB"],
    );
    for n in 10..=15 {
        // same base cluster hardware across n (model built for the
        // largest n; smaller sweeps use its first n workers), with the
        // per-task computation delay scaled by the workload b = N/n —
        // fewer workers means bigger mini-batches (paper Fig. 6 setup);
        // communication delay stays constant (one d-vector per message)
        let model = crate::delay::Scaled::for_worker_count(
            Ec2LikeModel::new(15, opts.seed ^ 0xEC2, 0.2),
            n,
            15,
        );
        let point = EvalPoint::new(n, n, n, opts.trials, opts.seed).with_ingest(EC2_INGEST_MS);
        let est = evaluate(&point, &model);
        table.push_row(vec![
            n.to_string(),
            Table::fmt(mean_of(&est, SchemeId::Cs)),
            Table::fmt(mean_of(&est, SchemeId::Ss)),
            Table::fmt(mean_of(&est, SchemeId::Ra)),
            Table::fmt(mean_of(&est, SchemeId::Pc)),
            Table::fmt(mean_of(&est, SchemeId::Pcmm)),
            Table::fmt(mean_of(&est, SchemeId::Lb)),
        ]);
    }
    table.print();
    opts.write(&table, "fig6")?;
    Ok(table)
}

/// **Fig. 7** — t̄ vs computation target k ∈ [2, n], n = 10, r = n
/// (uncoded schemes + LB only; PC/PCMM are k = n by construction).
pub fn fig7(opts: &Options) -> Result<Table> {
    let n = 10;
    let model = Ec2LikeModel::new(n, opts.seed ^ 0xEC2, 0.2);
    let mut table = Table::new(
        "Fig. 7: t̄ (ms) vs k — n = 10, r = n, d = 800, N = 1000",
        &["k", "CS", "SS", "RA", "LB"],
    );
    for k in 2..=n {
        let point = EvalPoint::new(n, n, k, opts.trials, opts.seed)
            .with_ingest(EC2_INGEST_MS)
            .with_schemes(&[SchemeId::Cs, SchemeId::Ss, SchemeId::Ra, SchemeId::Lb]);
        let est = evaluate(&point, &model);
        table.push_row(vec![
            k.to_string(),
            Table::fmt(mean_of(&est, SchemeId::Cs)),
            Table::fmt(mean_of(&est, SchemeId::Ss)),
            Table::fmt(mean_of(&est, SchemeId::Ra)),
            Table::fmt(mean_of(&est, SchemeId::Lb)),
        ]);
    }
    table.print();
    opts.write(&table, "fig7")?;
    Ok(table)
}

/// **Fig. 8** (beyond the paper) — the GC(s) communication–computation
/// tradeoff: grouped multi-message cyclic schedules (one partial-sum
/// message per `s` completed tasks, arXiv:2004.04948-style) against
/// CS (≡ GC(1)) and the genie bound, under the testbed
/// master-ingestion model.  Larger `s` delays deliveries to the flush
/// slot but cuts the master's message load `s×` — the sweep shows
/// where each effect wins.  The first scheme to ship end-to-end
/// through the unified scheme layer ([`crate::scheme`]).
pub fn fig8_gc(opts: &Options) -> Result<Table> {
    let n = 12;
    let r = n;
    let model = Ec2LikeModel::new(n, opts.seed ^ 0xEC2, 0.2);
    let mut table = Table::new(
        &format!(
            "Fig. 8: t̄ (ms) vs GC group size s — n = {n}, r = n, k = n, \
             EC2-like, ingest {EC2_INGEST_MS} ms/message"
        ),
        &["s", "GC(s)", "CS", "LB", "GC/CS", "messages/round"],
    );
    // one coupled pass: every group size plus CS and LB share the
    // identical delay stream, so the whole sweep is a single evaluate
    const GROUPS: [usize; 6] = [1, 2, 3, 4, 6, 12];
    let mut schemes: Vec<SchemeId> = GROUPS.iter().map(|&s| SchemeId::Gc(s as u32)).collect();
    schemes.push(SchemeId::Cs);
    schemes.push(SchemeId::Lb);
    let point = EvalPoint::new(n, r, n, opts.trials, opts.seed)
        .with_ingest(EC2_INGEST_MS)
        .with_schemes(&schemes);
    let est = evaluate(&point, &model);
    let (cs, lb) = (mean_of(&est, SchemeId::Cs), mean_of(&est, SchemeId::Lb));
    for s in GROUPS {
        let g = mean_of(&est, SchemeId::Gc(s as u32));
        table.push_row(vec![
            s.to_string(),
            Table::fmt(g),
            Table::fmt(cs),
            Table::fmt(lb),
            format!("{:.3}", g / cs),
            (n * r.div_ceil(s)).to_string(),
        ]);
    }
    table.print();
    opts.write(&table, "fig8_gc")?;

    if opts.cluster {
        let spot = fig8_cluster_spotcheck(opts)?;
        spot.print();
        opts.write(&spot, "fig8_cluster_spotcheck")?;
    }
    Ok(table)
}

/// Real-cluster spot check for Fig. 8: execute GC(s) rounds on the
/// socketed coordinator through the registry's [`ClusterPlan`] and
/// report measured completion + message counts next to GC(1) ≡ CS.
///
/// [`ClusterPlan`]: crate::scheme::ClusterPlan
fn fig8_cluster_spotcheck(opts: &Options) -> Result<Table> {
    let n = 6;
    let rounds = 100.min(opts.trials.max(1));
    let mut table = Table::new(
        "Fig. 8 cluster spot check: measured GC(s), real sockets + compute",
        &[
            "s",
            "mean t (ms)",
            "avg messages/round",
            "avg results/round",
            "avg wire KiB/round",
        ],
    );
    for s in [1usize, 2, 3] {
        let report = run_cluster(ClusterConfig {
            n,
            r: n,
            k: n,
            eta: 0.01,
            rounds,
            profile: "fig8".into(),
            plan: SchemeRegistry::cluster_plan(SchemeId::Gc(s as u32), n, n, n)?,
            policy: PolicyKind::Static,
            staleness: 1,
            dataset: Dataset::synthesize(n, 64, n * 16, opts.seed),
            inject: Some(DelayModelKind::Ec2Like {
                seed: opts.seed ^ 0xEC2,
                hetero: 0.2,
            }),
            seed: opts.seed,
            use_pjrt: false,
            artifact_dir: None,
            loss_every: 0,
            listen: None,
            spawn_workers: true,
            io: IoMode::default(),
            metrics: MetricsConfig::default(),
        })?;
        let rounds_f = report.rounds.len().max(1) as f64;
        let msgs: usize = report.rounds.iter().map(|l| l.messages_seen).sum();
        let results: usize = report.rounds.iter().map(|l| l.results_seen).sum();
        table.push_row(vec![
            s.to_string(),
            Table::fmt(report.mean_completion_ms()),
            format!("{:.1}", msgs as f64 / rounds_f),
            format!("{:.1}", results as f64 / rounds_f),
            format!("{:.2}", report.mean_wire_bytes() / 1024.0),
        ]);
    }
    Ok(table)
}

/// **Adaptive** (beyond the paper) — the shifting-straggler comparison
/// of EXPERIMENTS.md §Adaptive: a two-tier fleet (half the workers 3×
/// slower) whose slow block rotates every 250 rounds, evaluated at the
/// scarce-coverage point `n = 12, r = 4, k = n` with a 0.05 ms/message
/// master.  Static schemes must commit to one layout and are wrong
/// after every shift; the `order` and `load` policies re-estimate and
/// re-plan.  The `@sS` rows pipeline `S` rounds in flight (bounded
/// staleness, EXPERIMENTS.md §Async): the slow tier's long rounds
/// overlap instead of serializing, so k-async rows beat the best
/// synchronous static row even before any re-planning.  Every run
/// shares the identical delay stream (the policy engines only consume
/// the scheduling RNG), so the deltas are variance-reduced.
pub fn adaptive_shift_table(opts: &Options) -> Result<Table> {
    let (n, r, k) = (12usize, 4usize, 12usize);
    let (ingest_ms, shift_every, rotate) = (0.05, 250usize, 5usize);
    let (n_slow, slow_factor) = (6usize, 3.0);
    let rounds = opts.trials.clamp(500, 20_000);
    let base = two_tier_model(n, n_slow, slow_factor);
    let model = ShiftingStraggler::new(&base, shift_every, rotate);

    let runs: Vec<(SchemeId, PolicyKind, usize)> = vec![
        (SchemeId::Cs, PolicyKind::Static, 1),
        (SchemeId::Gc(4), PolicyKind::Static, 1),
        (SchemeId::GcHet(4, 1), PolicyKind::Static, 1),
        (SchemeId::Gc(4), PolicyKind::AdaptiveOrder, 1),
        (SchemeId::Gc(4), PolicyKind::AdaptiveLoad, 1),
        // the k-async rows: S rounds in flight on the same stream —
        // staleness hides the slow tier behind the pipeline
        (SchemeId::Cs, PolicyKind::Static, 2),
        (SchemeId::Gc(4), PolicyKind::AdaptiveOrder, 2),
        (SchemeId::Gc(4), PolicyKind::AdaptiveOrder, 3),
    ];
    let mut table = Table::new(
        &format!(
            "Adaptive: shifting stragglers (two-tier ×{slow_factor}, {n_slow}/{n} slow, \
             shift every {shift_every} rot {rotate}) — n = {n}, r = {r}, k = {k}, \
             ingest {ingest_ms} ms, {rounds} rounds"
        ),
        &["scheme", "policy", "mean", "std_err", "p95", "replans", "vs best static"],
    );
    let mut outcomes = Vec::new();
    for &(scheme, policy, staleness) in &runs {
        let out = run_policy_rounds(
            &PolicyRunConfig {
                scheme,
                policy,
                n,
                r,
                k,
                rounds,
                ingest_ms,
                seed: opts.seed,
                staleness,
            },
            &model,
            None,
            None,
        )?;
        outcomes.push((scheme, policy, staleness, out));
    }
    // the baseline the async rows must beat: best SYNCHRONOUS static
    let best_static = outcomes
        .iter()
        .filter(|(_, p, s, _)| *p == PolicyKind::Static && *s == 1)
        .map(|(_, _, _, o)| o.estimate.mean)
        .fold(f64::INFINITY, f64::min);
    for (scheme, policy, staleness, out) in &outcomes {
        table.push_row(vec![
            scheme.to_string(),
            if *staleness > 1 {
                format!("{policy}@s{staleness}")
            } else {
                policy.to_string()
            },
            Table::fmt(out.estimate.mean),
            Table::fmt(out.estimate.std_err),
            Table::fmt(out.estimate.p95),
            out.replans.to_string(),
            format!("{:+.1}%", 100.0 * (out.estimate.mean / best_static - 1.0)),
        ]);
    }
    table.print();
    opts.write(&table, "adaptive_shift")?;
    Ok(table)
}

/// **Fig. 3** — histograms of per-task computation and communication
/// delays of the first three workers, measured on the *real* cluster
/// (sockets + compute) with EC2-like injection, plus truncated-Gaussian
/// moment fits (the paper's overlay).  Returns (summary, histogram)
/// tables.
pub fn fig3(opts: &Options) -> Result<(Table, Table)> {
    let n = 3;
    let rounds = opts.trials.clamp(50, 500);
    let report = run_cluster(ClusterConfig {
        n,
        r: 1,
        k: n,
        eta: 0.01,
        rounds,
        profile: "fig3".into(),
        plan: SchemeRegistry::cluster_plan(SchemeId::Cs, n, 1, n)?,
        policy: PolicyKind::Static,
        staleness: 1,
        dataset: Dataset::synthesize(n, 500, 900, opts.seed),
        inject: Some(DelayModelKind::Ec2Like {
            seed: opts.seed ^ 0xF163,
            hetero: 0.25,
        }),
        seed: opts.seed,
        use_pjrt: opts.cluster,
        artifact_dir: None,
        loss_every: 0,
        listen: None,
        spawn_workers: true,
        io: IoMode::default(),
        metrics: MetricsConfig::default(),
    })?;

    let mut summary = Table::new(
        &format!("Fig. 3 summary: measured delays over {rounds} rounds (ms)"),
        &[
            "worker",
            "comp mean",
            "comp fit μ",
            "comp fit σ",
            "comm mean",
            "comm fit μ",
            "comm fit σ",
        ],
    );
    let mut hist = Table::new(
        "Fig. 3 histograms: per-worker delay densities",
        &["worker", "kind", "bin_center_ms", "density", "fit_pdf"],
    );
    for (w, rec) in report.recorders.iter().enumerate() {
        let comp_fit = fit_truncated_gaussian(&rec.comp);
        let comm_fit = fit_truncated_gaussian(&rec.comm);
        summary.push_row(vec![
            w.to_string(),
            Table::fmt(rec.comp_stats().mean()),
            Table::fmt(comp_fit.mu),
            Table::fmt(comp_fit.sigma),
            Table::fmt(rec.comm_stats().mean()),
            Table::fmt(comm_fit.mu),
            Table::fmt(comm_fit.sigma),
        ]);
        for (kind, samples, fit) in [
            ("comp", &rec.comp, &comp_fit),
            ("comm", &rec.comm, &comm_fit),
        ] {
            let lo = samples.iter().cloned().fold(f64::INFINITY, f64::min);
            let hi = samples.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
            let mut h = Histogram::new(lo, (hi - lo).max(1e-9) + lo + 1e-9, 24);
            samples.iter().for_each(|&x| h.push(x));
            for bin in 0..h.bins() {
                hist.push_row(vec![
                    w.to_string(),
                    kind.to_string(),
                    Table::fmt(h.center(bin)),
                    Table::fmt(h.density(bin)),
                    Table::fmt(fit.pdf(h.center(bin))),
                ]);
            }
        }
    }
    summary.print();
    opts.write(&summary, "fig3_summary")?;
    opts.write(&hist, "fig3_histograms")?;
    Ok((summary, hist))
}

/// **Table I** — characteristics of the schemes (descriptive; printed
/// from code so the implementation stays self-documenting).
pub fn table1(opts: &Options) -> Result<Table> {
    let mut t = Table::new(
        "Table I: scheme characteristics at DGD iteration l",
        &["scheme", "load r", "target", "completion criteria", "worker sends", "master update"],
    );
    t.push_row(vec![
        "CS / SS".into(),
        "1 ≤ r ≤ n".into(),
        "1 ≤ k ≤ n".into(),
        "k distinct computations".into(),
        "each h(X_C(i,j)) immediately".into(),
        "θ − η·2n/(kN) Σ (h(X_pi) − X_pi y_pi)".into(),
    ]);
    t.push_row(vec![
        "RA".into(),
        "r = n".into(),
        "1 ≤ k ≤ n".into(),
        "k distinct computations".into(),
        "each h(X_C(i,j)) immediately".into(),
        "θ − η·2n/(kN) Σ (h(X_pi) − X_pi y_pi)".into(),
    ]);
    t.push_row(vec![
        "PC".into(),
        "r ≥ 2".into(),
        "k = n".into(),
        "2⌈n/r⌉ − 1 computations".into(),
        "Σ_j h(X̃_i,j) once".into(),
        "interpolate φ; θ − η·2/N (XᵀXθ − Xᵀy)".into(),
    ]);
    t.push_row(vec![
        "PCMM".into(),
        "r ≥ 2".into(),
        "k = n".into(),
        "2n − 1 computations".into(),
        "each h(X̂_i,j) immediately".into(),
        "interpolate ψ; θ − η·2/N (XᵀXθ − Xᵀy)".into(),
    ]);
    t.print();
    opts.write(&t, "table1")?;
    Ok(t)
}

/// End-to-end distributed training on the real cluster — the e2e driver
/// behind `examples/train_distributed.rs` (kept in the library so tests
/// and the CLI share it).  The scheme is registry-dispatched
/// ([`SchemeRegistry::cluster_plan`]): uncoded schemes run the eq. 61
/// partial-gradient update, GC(s) additionally aggregates partial sums
/// on the wire, and PC/PCMM decode the full gradient on the master.
pub struct E2eConfig {
    pub n: usize,
    pub d: usize,
    pub n_samples: usize,
    pub r: usize,
    pub k: usize,
    pub rounds: usize,
    pub eta: f64,
    /// the scheme to execute (`CS | SS | RA | GC(s) | GCH(a,b) | PC |
    /// PCMM`) — resolved through the registry, no hardcoded scheduler
    pub scheme: SchemeId,
    /// round-boundary re-planning policy (`static | order | order@pQQ
    /// | load | load-rate | alloc-group | alloc-random`)
    pub policy: PolicyKind,
    /// bounded-staleness window: keep up to `S` rounds in flight with
    /// θ-version-tagged frames (`S = 1` = synchronous; `S ≥ 2` needs an
    /// uncoded scheme — see [`ClusterConfig::staleness`])
    pub staleness: usize,
    pub profile: String,
    pub use_pjrt: bool,
    pub seed: u64,
    /// bind address for the master (`None` = ephemeral localhost)
    pub listen: Option<String>,
    /// spawn in-process workers (false = wait for external
    /// `straggler worker --connect` processes)
    pub spawn_workers: bool,
    /// master data plane: poll-driven reactor (default) or the legacy
    /// thread-per-worker blocking receivers (kept as a bit-identity
    /// cross-check — see [`IoMode`])
    pub io: IoMode,
    /// live telemetry export: Prometheus scrape address and/or JSONL
    /// snapshot log (default: disabled — see [`MetricsConfig`])
    pub metrics: MetricsConfig,
}

impl Default for E2eConfig {
    fn default() -> Self {
        // matches the `e2e` AOT profile: d = 512, b = 1024, n = 10
        Self {
            n: 10,
            d: 512,
            n_samples: 10_240,
            r: 4,
            k: 8,
            rounds: 300,
            eta: 0.05,
            scheme: SchemeId::Ss,
            policy: PolicyKind::Static,
            staleness: 1,
            profile: "e2e".into(),
            use_pjrt: true,
            seed: 2024,
            listen: None,
            spawn_workers: true,
            io: IoMode::default(),
            metrics: MetricsConfig::default(),
        }
    }
}

pub fn run_e2e(cfg: E2eConfig, opts: &Options) -> Result<(ClusterReport, Table)> {
    let dataset = Dataset::synthesize(cfg.n, cfg.d, cfg.n_samples, cfg.seed);
    let plan = SchemeRegistry::adaptive_plan(cfg.scheme, cfg.policy, cfg.n, cfg.r, cfg.k)?;
    let report = run_cluster(ClusterConfig {
        n: cfg.n,
        r: cfg.r,
        k: cfg.k,
        eta: cfg.eta,
        rounds: cfg.rounds,
        profile: cfg.profile.clone(),
        plan,
        policy: cfg.policy,
        staleness: cfg.staleness,
        dataset,
        inject: Some(DelayModelKind::Ec2Like {
            seed: cfg.seed ^ 0xEC2,
            hetero: 0.25,
        }),
        seed: cfg.seed,
        use_pjrt: cfg.use_pjrt,
        artifact_dir: None,
        loss_every: 10,
        listen: cfg.listen.clone(),
        spawn_workers: cfg.spawn_workers,
        io: cfg.io,
        metrics: cfg.metrics.clone(),
    })?;
    let mut curve = Table::new(
        &format!(
            "e2e training: n = {}, d = {}, N = {}, r = {}, k = {} ({} scheme, {} policy{})",
            cfg.n,
            cfg.d,
            cfg.n_samples,
            cfg.r,
            cfg.k,
            cfg.scheme,
            cfg.policy,
            if cfg.staleness > 1 {
                format!(", S = {}", cfg.staleness)
            } else {
                String::new()
            }
        ),
        &["round", "loss", "completion_ms"],
    );
    for log in &report.rounds {
        if let Some(loss) = log.loss {
            curve.push_row(vec![
                log.round.to_string(),
                format!("{loss:.6}"),
                Table::fmt(log.completion_ms),
            ]);
        }
    }
    opts.write(&curve, "e2e_loss_curve")?;
    opts.write(&report.spans.phase_table(), "e2e_round_phases")?;
    opts.write(
        &report.spans.attribution_table(),
        "e2e_straggler_attribution",
    )?;
    Ok((report, curve))
}

/// **Ablations** — design-choice experiments beyond the paper's figures
/// (DESIGN.md calls these out):
///
/// 1. master ingestion cost sweep — how the serialized receive loop
///    penalizes multi-message schemes (the Fig.-6 PCMM mechanism);
/// 2. within-worker delay correlation sweep — robustness of the CS/SS
///    advantage when one slow worker stays slow for a whole round;
/// 3. searched schedules vs CS/SS — how much headroom the paper's
///    hand-designed orders leave (numeric attack on eq. 6);
/// 4. Remark-3 bias: per-task selection skew with k < n, with and
///    without periodic task↔batch reshuffling.
pub fn ablations(opts: &Options) -> Result<Vec<Table>> {
    use crate::scheduler::Scheduler as _;
    let mut tables = Vec::new();

    // ---- 1. ingestion-cost sweep -------------------------------------------
    let n = 12;
    let model = Ec2LikeModel::new(n, opts.seed ^ 0xEC2, 0.2);
    let mut t1 = Table::new(
        "ablation 1: master ingest cost (ms/message) vs scheme means (n = 12, r = 4, k = n)",
        &["ingest_ms", "SS", "PCMM", "PCMM/SS"],
    );
    for ingest in [0.0, 0.05, 0.15, 0.3, 0.5] {
        let point = EvalPoint::new(n, 4, n, opts.trials / 2, opts.seed)
            .with_ingest(ingest)
            .with_schemes(&[SchemeId::Ss, SchemeId::Pcmm]);
        let est = evaluate(&point, &model);
        let ss = mean_of(&est, SchemeId::Ss);
        let pcmm = mean_of(&est, SchemeId::Pcmm);
        t1.push_row(vec![
            format!("{ingest:.2}"),
            Table::fmt(ss),
            Table::fmt(pcmm),
            format!("{:.3}", pcmm / ss),
        ]);
    }
    t1.print();
    opts.write(&t1, "ablation_ingest")?;
    tables.push(t1);

    // ---- 2. correlation sweep ----------------------------------------------
    let mut t2 = Table::new(
        "ablation 2: within-worker delay correlation σ vs CS/LB gap (n = 10, r = 5, k = n)",
        &["sigma", "CS", "SS", "LB", "CS/LB"],
    );
    for sigma in [0.0, 0.3, 0.6, 0.9] {
        let model = crate::delay::WorkerCorrelated::new(
            crate::delay::ShiftedExponential::new(0.08, 8.0, 0.4, 3.0),
            sigma,
        );
        let point = EvalPoint::new(10, 5, 10, opts.trials / 2, opts.seed).with_schemes(&[
            SchemeId::Cs,
            SchemeId::Ss,
            SchemeId::Lb,
        ]);
        let est = evaluate(&point, &model);
        let (cs, ss, lb) = (
            mean_of(&est, SchemeId::Cs),
            mean_of(&est, SchemeId::Ss),
            mean_of(&est, SchemeId::Lb),
        );
        t2.push_row(vec![
            format!("{sigma:.1}"),
            Table::fmt(cs),
            Table::fmt(ss),
            Table::fmt(lb),
            format!("{:.3}", cs / lb),
        ]);
    }
    t2.print();
    opts.write(&t2, "ablation_correlation")?;
    tables.push(t2);

    // ---- 3. searched schedules ----------------------------------------------
    let mut t3 = Table::new(
        "ablation 3: local-search TO matrices vs CS/SS (scenario-2 heterogeneous, k = n, fresh-sample eval)",
        &["n", "r", "CS", "SS", "searched", "gain vs best designed"],
    );
    for (n, r) in [(5usize, 2usize), (6, 3), (8, 2)] {
        let model = TruncatedGaussianModel::scenario2(n, opts.seed);
        let out = crate::scheduler::search(
            &model,
            n,
            r,
            n,
            &crate::scheduler::SearchConfig {
                crn_rounds: 250,
                max_sweeps: 4,
                restarts: 2,
                seed: opts.seed,
            },
        );
        // fresh-sample evaluation of all three matrices
        let mut rng = crate::util::rng::Rng::seed_from_u64(opts.seed ^ 0xFE);
        let cs = crate::scheduler::CyclicScheduler.schedule(n, r, &mut rng);
        let ss = crate::scheduler::StaircaseScheduler.schedule(n, r, &mut rng);
        let mut scratch = crate::sim::SimScratch::new();
        let trials = (opts.trials / 2).max(2000);
        let (mut a, mut b, mut c) = (0.0, 0.0, 0.0);
        for _ in 0..trials {
            let s = model.sample(n, r, &mut rng);
            a += crate::sim::simulate_round_with(&cs, &s, n, &mut scratch).completion_time;
            b += crate::sim::simulate_round_with(&ss, &s, n, &mut scratch).completion_time;
            c += crate::sim::simulate_round_with(&out.matrix, &s, n, &mut scratch).completion_time;
        }
        let (a, b, c) = (a / trials as f64, b / trials as f64, c / trials as f64);
        t3.push_row(vec![
            n.to_string(),
            r.to_string(),
            Table::fmt(a),
            Table::fmt(b),
            Table::fmt(c),
            format!("{:.2}%", 100.0 * (1.0 - c / a.min(b))),
        ]);
    }
    t3.print();
    opts.write(&t3, "ablation_search")?;
    tables.push(t3);

    // ---- 4. Remark-3 selection bias ------------------------------------------
    let mut t4 = Table::new(
        "ablation 4: Remark-3 task-selection skew over 2000 rounds (n = 8, r = 2, k = 3, scenario-2)",
        &["reshuffle", "max/min task frequency", "loss after 2000 rounds"],
    );
    for reshuffle in [false, true] {
        let ds = crate::data::Dataset::synthesize(8, 12, 8 * 10, opts.seed);
        let model = TruncatedGaussianModel::scenario2(8, opts.seed ^ 5);
        let mut rng = crate::util::rng::Rng::seed_from_u64(opts.seed);
        let to = crate::scheduler::CyclicScheduler.schedule(8, 2, &mut rng);
        let mut training = crate::gd::SimulatedTraining::new(&ds, 0.02, 3, opts.seed);
        if reshuffle {
            training.master = training.master.clone().with_reshuffle(25);
        }
        let mut last = f64::NAN;
        for _ in 0..2000 {
            let s = model.sample(8, 2, &mut rng);
            let round = crate::sim::simulate_round(&to, &s, 3);
            last = training.apply_winners(&round.winners);
        }
        t4.push_row(vec![
            reshuffle.to_string(),
            format!("{:.2}", training.master.selection_skew()),
            format!("{last:.5}"),
        ]);
    }
    t4.print();
    opts.write(&t4, "ablation_remark3_bias")?;
    tables.push(t4);

    Ok(tables)
}
