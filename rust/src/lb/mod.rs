//! Lower bound on the minimum average completion time — paper §V.
//!
//! A genie that knows the delay realization `T` (eq. 42) in advance can
//! schedule so that the first `k` results received are distinct, making
//! the completion time exactly the k-th smallest **slot arrival time**
//! `t̂_{T,(k)}` among all `n·r` slots (eq. 46 and the argument below it).
//! Averaging over realizations (Monte Carlo, as in the paper — the
//! order-statistic distribution is "analytically elusive") yields
//! `t̄_LB(r,k) ≤ t̄*(r,k)`.
//!
//! [`lower_bound`] computes the bound; its constructive counterpart
//! [`crate::scheduler::oracle_schedule`] is tested to *achieve* it
//! realization-by-realization.

use crate::util::rng::Rng;


use crate::delay::{DelayModel, DelaySample};
use crate::scheme::{run_rounds, SchemeId, SchemeRegistry};
use crate::sim::CompletionEstimate;
use crate::util::stats::{RunningStats, StreamingQuantiles};

/// k-th smallest slot-arrival time of one realization (`t̂_{T,(k)}`).
///
/// Uses `select_nth_unstable` — O(n·r) average, no full sort — because
/// this sits inside the Monte-Carlo hot loop.
pub fn kth_slot_arrival(sample: &DelaySample, k: usize, scratch: &mut Vec<f64>) -> f64 {
    let (n, r) = (sample.n, sample.r);
    assert!(k >= 1 && k <= n * r, "need 1 ≤ k ≤ n·r slots");
    scratch.clear();
    for i in 0..n {
        let comp = sample.comp_row(i);
        let comm = sample.comm_row(i);
        let mut prefix = 0.0;
        for j in 0..r {
            prefix += comp[j];
            scratch.push(prefix + comm[j]);
        }
    }
    let (_, kth, _) = scratch.select_nth_unstable_by(k - 1, |a, b| a.total_cmp(b));
    *kth
}

/// Monte-Carlo estimate of `t̄_LB(r, k)` (eq. 44), on the batched
/// engine: the registry's genie scheme driven through the shared
/// [`run_rounds`] chunk loop — delays sampled in `DelayBatch` chunks,
/// slot arrivals computed once per chunk, the k-th order statistic
/// streaming into `RunningStats` + `StreamingQuantiles` (memory O(1)
/// in `trials`).  The delay stream and per-round values are
/// bit-identical to the pre-registry per-round loop for a fixed seed
/// (pinned by `batched_lower_bound_matches_scalar_reference` below).
pub fn lower_bound(
    model: &dyn DelayModel,
    n: usize,
    r: usize,
    k: usize,
    trials: usize,
    seed: u64,
) -> CompletionEstimate {
    assert!(trials > 0, "need at least one trial");
    assert!(k <= n, "computation target exceeds task count");
    assert!(k >= 1 && k <= n * r, "not enough slots to ever reach the target");
    let mut rng = Rng::seed_from_u64(seed);
    // the genie consumes no scheduling randomness; this stream exists
    // only to satisfy the shared driver's signature
    let mut rng_sched = Rng::seed_from_u64(seed ^ 0x1B);
    let mut evaluators =
        vec![SchemeRegistry::build(SchemeId::Lb).prepare(n, r, k, &mut rng_sched)];
    let mut stats = RunningStats::new();
    let mut quantiles = StreamingQuantiles::new();
    run_rounds(
        &mut evaluators,
        model,
        n,
        r,
        trials,
        0.0,
        &mut rng,
        &mut rng_sched,
        &mut |_, t| {
            stats.push(t);
            quantiles.push(t);
        },
    );
    CompletionEstimate::from_streams("LB".into(), n, r, k, &stats, &quantiles)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::delay::{DelayModel, ShiftedExponential, TruncatedGaussianModel};
    use crate::scheduler::{CyclicScheduler, Scheduler, StaircaseScheduler};
    use crate::sim::MonteCarlo;

    #[test]
    fn kth_arrival_on_fixture() {
        let s = DelaySample::from_rows(
            vec![vec![1.0, 2.0], vec![4.0, 1.0]],
            vec![vec![10.0, 1.0], vec![1.0, 1.0]],
        );
        // slot arrivals: 11, 4, 5, 6 → sorted 4, 5, 6, 11
        let mut scratch = Vec::new();
        assert_eq!(kth_slot_arrival(&s, 1, &mut scratch), 4.0);
        assert_eq!(kth_slot_arrival(&s, 2, &mut scratch), 5.0);
        assert_eq!(kth_slot_arrival(&s, 4, &mut scratch), 11.0);
    }

    #[test]
    fn lb_below_every_scheme() {
        // eq. 45: the bound must sit below CS and SS for all (r, k)
        let model = TruncatedGaussianModel::scenario1(8);
        let mc = MonteCarlo::new(4000, 5);
        for r in [1, 2, 4, 8] {
            for k in [1, 4, 8] {
                let lb = lower_bound(&model, 8, r, k, 4000, 5);
                for sched in [
                    &CyclicScheduler as &dyn Scheduler,
                    &StaircaseScheduler,
                ] {
                    let est = mc.estimate(sched, &model, 8, r, k);
                    assert!(
                        lb.mean <= est.mean + 3.0 * (lb.std_err + est.std_err),
                        "r={r} k={k} {}: LB {} vs {}",
                        sched.name(),
                        lb.mean,
                        est.mean
                    );
                }
            }
        }
    }

    #[test]
    fn batched_lower_bound_matches_scalar_reference() {
        // per-round values (and hence the mean) must reproduce the old
        // sample-per-round loop bit-for-bit for a fixed seed
        let model = TruncatedGaussianModel::scenario2(6, 2);
        let (n, r, k, trials, seed) = (6usize, 3usize, 4usize, 700usize, 13u64);
        let est = lower_bound(&model, n, r, k, trials, seed);
        let mut rng = Rng::seed_from_u64(seed);
        let mut sample = DelaySample::zeros(n, r);
        let mut scratch = Vec::new();
        let mut acc = crate::util::stats::RunningStats::new();
        for _ in 0..trials {
            model.sample_into(&mut sample, &mut rng);
            acc.push(kth_slot_arrival(&sample, k, &mut scratch));
        }
        assert_eq!(est.trials, trials);
        assert_eq!(est.mean.to_bits(), acc.mean().to_bits());
        assert_eq!(est.min.to_bits(), acc.min().to_bits());
        assert_eq!(est.max.to_bits(), acc.max().to_bits());
    }

    #[test]
    fn lb_tight_at_r1_k1_single_worker() {
        // with n = r = k = 1 the genie has no freedom: LB == CS exactly
        let model = ShiftedExponential::new(0.2, 3.0, 0.1, 4.0);
        let lb = lower_bound(&model, 1, 1, 1, 50_000, 9);
        let mc = MonteCarlo::new(50_000, 9).single_threaded();
        let cs = mc.estimate(&CyclicScheduler, &model, 1, 1, 1);
        assert!((lb.mean - cs.mean).abs() < 4.0 * (lb.std_err + cs.std_err));
    }

    #[test]
    fn lb_per_realization_dominance() {
        // t̂_{T,(k)} ≤ t_C(T, r, k) realization by realization, any C
        let model = ShiftedExponential::new(0.1, 2.0, 0.2, 3.0);
        let mut rng = Rng::seed_from_u64(31);
        let to = {
            let mut r2 = Rng::seed_from_u64(0);
            StaircaseScheduler.schedule(7, 3, &mut r2)
        };
        let mut scratch = Vec::new();
        for _ in 0..300 {
            let s = model.sample(7, 3, &mut rng);
            for k in 1..=7usize {
                if k > 7 * 3 {
                    continue;
                }
                let lb = kth_slot_arrival(&s, k, &mut scratch);
                let sim = crate::sim::simulate_round(&to, &s, k);
                assert!(
                    lb <= sim.completion_time + 1e-12,
                    "k={k}: {lb} > {}",
                    sim.completion_time
                );
            }
        }
    }

    #[test]
    fn oracle_achieves_the_bound() {
        let model = ShiftedExponential::new(0.1, 2.0, 0.2, 3.0);
        let mut rng = Rng::seed_from_u64(8);
        let mut scratch = Vec::new();
        for _ in 0..100 {
            let s = model.sample(5, 4, &mut rng);
            for k in 1..=5 {
                let want = kth_slot_arrival(&s, k, &mut scratch);
                let to = crate::scheduler::oracle_schedule(&s, k);
                let got = crate::sim::simulate_round(&to, &s, k).completion_time;
                assert!((want - got).abs() < 1e-12, "k={k}");
            }
        }
    }
}
