//! # straggler-sched
//!
//! Production reproduction of *"Computation Scheduling for Distributed
//! Machine Learning with Straggling Workers"* (M. Mohammadi Amiri and
//! D. Gündüz, IEEE Transactions on Signal Processing, 2019).
//!
//! A master distributes `n` mini-batch gradient tasks over `n` workers.
//! Each worker receives up to `r` tasks (the **computation load**)
//! together with an execution order — jointly a **task-ordering (TO)
//! matrix** `C ∈ [n]^{n×r}` — computes them *sequentially*, and streams
//! each result to the master the moment it finishes.  A round completes
//! when the master holds `k` **distinct** results (the **computation
//! target**).  Computation and communication delays are random; the goal
//! is to pick `C` minimizing the average completion time.
//!
//! The crate provides, as first-class subsystems:
//!
//! * [`scheduler`] — TO-matrix construction: the paper's **cyclic (CS)**
//!   and **staircase (SS)** schedules, the **random-assignment (RA)**
//!   baseline, and the genie **oracle** schedule behind the lower bound;
//! * [`scheme`] — the unified scheme-execution layer: a `Scheme` trait
//!   (assignment + execution order + completion rule) with prepared
//!   per-chunk evaluators, a `SchemeRegistry` owning applicability and
//!   parsing, and the grouped multi-message **GC(s)** family; every
//!   batched engine and the live cluster dispatch through it;
//! * [`delay`] — the stochastic delay substrate (truncated Gaussian of
//!   paper eq. 66, shifted exponential, empirical EC2-like traces,
//!   worker-correlated wrappers);
//! * [`sim`] — a Monte-Carlo completion-time engine implementing the
//!   arrival dynamics of paper eqs. (1)–(2);
//! * [`analysis`] — an exact evaluator of Theorem 1's
//!   inclusion–exclusion formula, used to cross-validate the simulator;
//! * [`lb`] — the order-statistic lower bound of §V;
//! * [`coded`] — the coded baselines **PC** and **PCMM** with *real*
//!   polynomial encoding/decoding (not just timing models);
//! * [`data`] / [`gd`] — the distributed linear-regression workload of
//!   §VI (dataset synthesis, DGD update rules);
//! * [`runtime`] — a PJRT executor that loads the AOT-compiled JAX/Pallas
//!   artifacts (`artifacts/*.hlo.txt`) and runs them on the hot path;
//! * [`coordinator`] — a threaded TCP master/worker cluster (the EC2
//!   testbed substitute) doing real compute over a real wire protocol;
//! * [`adaptive`] — online per-worker delay estimation (EWMA +
//!   streaming quantiles) and round-by-round re-planning policies that
//!   re-rank the worker order (by EWMA mean or empirical p95),
//!   re-split per-worker flush sizes (rank ramp or service-rate
//!   proportional), or swap the task allocation — on the Monte-Carlo
//!   engines and the live cluster alike;
//! * [`trace`] — the record → fit → replay loop: a canonical delay
//!   trace format (JSONL + binary) captured from the live cluster and
//!   the simulator, per-worker model fitting with KS diagnostics, and
//!   bit-reproducible offline replay of the scheme × policy matrix
//!   against measured delays — the calibrated digital twin of a fleet;
//! * [`telemetry`] — the observability spine: a zero-steady-state-
//!   allocation metrics registry, per-round critical-path spans with
//!   straggler attribution and wasted-work accounting, and a
//!   Prometheus/JSONL exporter served from the reactor's poll loop;
//! * [`harness`] / [`report`] / [`metrics`] — experiment sweeps that
//!   regenerate every table and figure of the paper's evaluation.
//!
//! Conventions: worker indices `i ∈ [0, n)`, task indices `j ∈ [0, n)`
//! (the paper is 1-based), all delays and times are **milliseconds** as
//! `f64`.  The paper's `αEβ` notation means `α·10⁻ᵝ` **seconds**, so
//! e.g. `1E4 = 0.1 ms`.

pub mod adaptive;
pub mod analysis;
pub mod coded;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod gd;
pub mod delay;
pub mod lb;
pub mod linalg;
pub mod harness;
pub mod metrics;
pub mod report;
pub mod runtime;
pub mod scheduler;
pub mod scheme;
pub mod sim;
pub mod telemetry;
pub mod trace;
pub mod util;

pub use scheduler::ToMatrix;
