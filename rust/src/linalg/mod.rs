//! Dense f64 linear algebra substrate.
//!
//! The coded baselines (polynomial encode/decode), the CPU-oracle
//! gradient checks, and the master's bookkeeping need small dense
//! matrix/vector ops.  This is intentionally simple row-major code —
//! the *hot* numeric path runs through the PJRT runtime on the AOT
//! artifacts; this module is the control-plane math and test oracle.

/// Row-major dense matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    data: Vec<f64>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    pub fn from_rows(rows: Vec<Vec<f64>>) -> Self {
        let r = rows.len();
        assert!(r > 0, "empty matrix");
        let c = rows[0].len();
        let mut data = Vec::with_capacity(r * c);
        for row in &rows {
            assert_eq!(row.len(), c, "ragged rows");
            data.extend_from_slice(row);
        }
        Self {
            rows: r,
            cols: c,
            data,
        }
    }

    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f64) -> Self {
        let mut m = Self::zeros(rows, cols);
        for i in 0..rows {
            for j in 0..cols {
                m[(i, j)] = f(i, j);
            }
        }
        m
    }

    pub fn identity(n: usize) -> Self {
        Self::from_fn(n, n, |i, j| if i == j { 1.0 } else { 0.0 })
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn data(&self) -> &[f64] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f64] {
        &mut self.data
    }

    /// `y = A x`.
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.cols, "matvec dimension mismatch");
        (0..self.rows)
            .map(|i| dot(self.row(i), x))
            .collect()
    }

    /// `y = Aᵀ x`.
    pub fn matvec_t(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.rows, "matvec_t dimension mismatch");
        let mut y = vec![0.0; self.cols];
        for i in 0..self.rows {
            let xi = x[i];
            if xi == 0.0 {
                continue;
            }
            for (j, &a) in self.row(i).iter().enumerate() {
                y[j] += a * xi;
            }
        }
        y
    }

    /// `h = A Aᵀ x` — the paper's per-task computation (eq. 50) with
    /// `A = X_i ∈ R^{d×b}`.
    pub fn gram_matvec(&self, x: &[f64]) -> Vec<f64> {
        self.matvec(&self.matvec_t(x))
    }

    /// `self += alpha · other`.
    pub fn axpy(&mut self, alpha: f64, other: &Mat) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += alpha * b;
        }
    }

    pub fn scale(&mut self, alpha: f64) {
        for a in &mut self.data {
            *a *= alpha;
        }
    }

    /// Linear combination of matrices: `Σ coeffs[i] · mats[i]`.
    pub fn linear_combination(coeffs: &[f64], mats: &[Mat]) -> Mat {
        assert_eq!(coeffs.len(), mats.len());
        assert!(!mats.is_empty());
        let mut out = Mat::zeros(mats[0].rows, mats[0].cols);
        for (&c, m) in coeffs.iter().zip(mats) {
            if c != 0.0 {
                out.axpy(c, m);
            }
        }
        out
    }

    /// Cast to f32 (runtime buffers are f32, matching the artifacts).
    pub fn to_f32(&self) -> Vec<f32> {
        self.data.iter().map(|&x| x as f32).collect()
    }
}

impl std::ops::Index<(usize, usize)> for Mat {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Mat {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        &mut self.data[i * self.cols + j]
    }
}

/// Dot product.
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

/// `a += alpha · b` for vectors.
pub fn vec_axpy(a: &mut [f64], alpha: f64, b: &[f64]) {
    assert_eq!(a.len(), b.len());
    for (x, y) in a.iter_mut().zip(b) {
        *x += alpha * y;
    }
}

/// Euclidean norm.
pub fn norm2(a: &[f64]) -> f64 {
    dot(a, a).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matvec_against_hand_computed() {
        let a = Mat::from_rows(vec![vec![1.0, 2.0], vec![3.0, 4.0], vec![5.0, 6.0]]);
        assert_eq!(a.matvec(&[1.0, -1.0]), vec![-1.0, -1.0, -1.0]);
        assert_eq!(a.matvec_t(&[1.0, 0.0, 1.0]), vec![6.0, 8.0]);
    }

    #[test]
    fn gram_is_matvec_composition() {
        let a = Mat::from_fn(5, 3, |i, j| ((i * 7 + j * 3) % 11) as f64 - 5.0);
        let x = [0.5, -1.0, 2.0, 0.0, 1.5];
        let got = a.gram_matvec(&x);
        let manual = a.matvec(&a.matvec_t(&x));
        assert_eq!(got, manual);
        // PSD: xᵀ A Aᵀ x ≥ 0
        assert!(dot(&x, &got) >= -1e-12);
    }

    #[test]
    fn identity_gram_is_identity() {
        let i4 = Mat::identity(4);
        let x = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(i4.gram_matvec(&x), x.to_vec());
    }

    #[test]
    fn linear_combination_matches_elementwise() {
        let a = Mat::from_rows(vec![vec![1.0, 0.0], vec![0.0, 1.0]]);
        let b = Mat::from_rows(vec![vec![0.0, 2.0], vec![2.0, 0.0]]);
        let c = Mat::linear_combination(&[3.0, 0.5], &[a, b]);
        assert_eq!(c[(0, 0)], 3.0);
        assert_eq!(c[(0, 1)], 1.0);
        assert_eq!(c[(1, 0)], 1.0);
        assert_eq!(c[(1, 1)], 3.0);
    }

    #[test]
    fn axpy_scale_roundtrip() {
        let mut a = Mat::identity(3);
        let b = Mat::identity(3);
        a.axpy(2.0, &b);
        a.scale(1.0 / 3.0);
        assert!((a[(0, 0)] - 1.0).abs() < 1e-15);
        assert_eq!(a[(0, 1)], 0.0);
    }

    #[test]
    #[should_panic(expected = "dimension mismatch")]
    fn matvec_rejects_bad_shape() {
        Mat::zeros(2, 3).matvec(&[1.0, 2.0]);
    }

    #[test]
    fn vector_helpers() {
        let mut a = vec![1.0, 2.0];
        vec_axpy(&mut a, 0.5, &[2.0, 4.0]);
        assert_eq!(a, vec![2.0, 4.0]);
        assert!((norm2(&[3.0, 4.0]) - 5.0).abs() < 1e-15);
    }
}
