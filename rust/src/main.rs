//! `straggler` — CLI for the straggler-scheduling reproduction.
//!
//! ```text
//! straggler table1                              # Table I
//! straggler fig3  [--trials 500] [--cluster]    # delay histograms (real cluster)
//! straggler fig4  [--scenario 1|2] [--trials N] # t̄ vs r, truncated Gaussian
//! straggler fig5  [--trials N] [--cluster]      # t̄ vs r, EC2-like (+ spot check)
//! straggler fig6  [--trials N]                  # t̄ vs n
//! straggler fig7  [--trials N]                  # t̄ vs k
//! straggler fig8  [--trials N] [--cluster]      # GC(s) tradeoff sweep
//! straggler sim   --n 16 --r 4 --k 16 [--model scenario1|scenario2|ec2|exp]
//!                 [--schemes CS,SS,GC2,GCH(4,1),LB] [--ingest 0.15]
//!                 [--staleness S]               # k-async: S rounds in flight
//!                 [--policy order [--shift 250 --rotate 5]]  # re-planning arm
//!                 [--record t.jsonl]            # censored-slot trace capture
//!                 [--from-trace t.jsonl [--replay empirical|tg|exp|corr]]
//! straggler train --scheme CS|SS|RA|GC(s)|GCH(a,b)|PC|PCMM
//!                 [--policy static|order|order@p95|load|load-rate|alloc-group|alloc-random]
//!                 [--staleness S]               # pipelined master (uncoded)
//!                 [--io reactor|threads]        # master data plane
//!                 [--metrics-addr 127.0.0.1:9464]  # live Prometheus /metrics
//!                 [--metrics-log m.jsonl]       # per-round snapshot log
//!                 [--flight-depth 256]          # anomaly flight-recorder ring
//!                 [--anomaly-factor 4.0]        # phase-EWMA vs fleet-median trip
//!                 [--rounds 300] [--k 8] [--no-pjrt] [--record t.jsonl]
//! straggler trace record --out-trace t.jsonl [--cluster]  # record → fit → replay
//! straggler trace fit    --trace t.jsonl        # per-worker fits + KS + tiers
//! straggler trace replay --trace t.jsonl        # scheme × policy matrix + digest
//! straggler trace report --trace t.jsonl [--k K] [--json]  # span/attribution
//! straggler adaptive [--trials N]               # shifting-straggler table
//! straggler all   [--trials N]                  # every figure + table
//! ```
//!
//! All figure commands write `results/<name>.{csv,json}` (override with
//! `--out DIR`, suppress with `--no-out`).

use anyhow::{bail, Result};

use straggler_sched::adaptive::{
    run_policy_rounds, PerRound, PolicyKind, PolicyRunConfig, PolicySpec, RoundDelayModel,
    ShiftingStraggler, MAX_STALENESS,
};
use straggler_sched::delay::{
    DelayModel, Ec2LikeModel, ShiftedExponential, TruncatedGaussianModel,
};
use straggler_sched::harness::{self, EvalPoint, Options};
use straggler_sched::report::Table;
use straggler_sched::scheme::{SchemeId, SchemeRegistry};
use straggler_sched::telemetry::{spans_from_trace, MetricsConfig};
use straggler_sched::trace::{
    fit_traces, replay, ReplayConfig, ReplaySource, TraceRecorder, TraceStore,
};
use straggler_sched::util::cli::Args;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn options(args: &Args) -> Result<Options> {
    let mut opts = Options {
        trials: args.usize_or("trials", 20_000)?,
        seed: args.u64_or("seed", 0xF16)?,
        scenario: args.usize_or("scenario", 1)? as u8,
        cluster: args.flag("cluster"),
        ..Options::default()
    };
    if args.flag("no-out") {
        opts.out_dir = None;
    } else {
        opts.out_dir = Some(args.str_or("out", "results").into());
    }
    Ok(opts)
}

fn build_model(name: &str, n: usize, seed: u64) -> Result<Box<dyn DelayModel>> {
    Ok(match name {
        "scenario1" => Box::new(TruncatedGaussianModel::scenario1(n)),
        "scenario2" => Box::new(TruncatedGaussianModel::scenario2(n, seed)),
        "ec2" => Box::new(Ec2LikeModel::new(n, seed, 0.2)),
        "exp" => Box::new(ShiftedExponential::new(0.05, 10.0, 0.3, 3.0)),
        other => bail!("unknown delay model {other:?} (scenario1|scenario2|ec2|exp)"),
    })
}

/// Recording length shared by every trace-capture path: an explicit
/// `--rounds` wins, else an explicit `--trials`, else the path's small
/// default — a fit needs a few hundred rounds, not the 20k-trial
/// estimation default (`n·r` events per round add up fast).
fn record_rounds(args: &Args, opts: &Options, default: usize) -> Result<usize> {
    match args.str_opt("rounds") {
        Some(_) => args.usize_or("rounds", default),
        None if args.str_opt("trials").is_some() => Ok(opts.trials),
        None => Ok(default),
    }
}

/// Parse a comma-separated policy list (`static,order,load`) through
/// [`PolicyKind::parse`].
fn parse_policies(list: &str) -> Result<Vec<PolicyKind>> {
    list.split(',')
        .map(|p| PolicyKind::parse(p).map_err(|e| anyhow::anyhow!("policy {p:?}: {e}")))
        .collect()
}

/// Shared body of `straggler trace replay` and `sim --from-trace`:
/// build the replay config from flags, run the scheme × policy matrix
/// against the trace's delays, print the table + determinism digest.
fn run_trace_replay(args: &Args, opts: &Options, store: &TraceStore, name: &str) -> Result<()> {
    let n = store.n_workers();
    if n == 0 {
        bail!("trace {name} holds no events");
    }
    let trials = if args.str_opt("trials").is_none() {
        5_000
    } else {
        opts.trials
    };
    let mut cfg = ReplayConfig::matrix(n, trials, opts.seed);
    cfg.r = args.usize_or("r", n)?;
    cfg.k = args.usize_or("k", n)?;
    cfg.ingest_ms = args.f64_or("ingest", 0.0)?;
    if cfg.ingest_ms.is_nan() || cfg.ingest_ms < 0.0 {
        bail!("--ingest must be a non-negative ms/message cost, got {}", cfg.ingest_ms);
    }
    if let Some(list) = args.str_opt("schemes") {
        cfg.schemes = SchemeRegistry::parse_list(&list)?;
    } else {
        cfg.schemes = straggler_sched::trace::default_matrix_schemes(n, cfg.r, cfg.k);
    }
    if let Some(list) = args.str_opt("policies") {
        cfg.policies = parse_policies(&list)?;
    }
    if let Some(source) = args.str_opt("replay") {
        cfg.source = ReplaySource::parse(&source)
            .map_err(|e| anyhow::anyhow!("--replay {source:?}: {e}"))?;
    }
    let out = replay(store, &cfg)?;
    let mut t = Table::new(
        &format!(
            "trace replay ({}): n = {n}, r = {}, k = {}, {} rounds/cell, \
             ingest {} ms — {} events from {name}",
            out.model_name,
            cfg.r,
            cfg.k,
            cfg.trials,
            cfg.ingest_ms,
            store.len()
        ),
        &["scheme", "policy", "mean", "std_err", "p95", "replans"],
    );
    for cell in &out.cells {
        t.push_row(vec![
            cell.scheme.to_string(),
            cell.policy.to_string(),
            Table::fmt(cell.estimate.mean),
            Table::fmt(cell.estimate.std_err),
            Table::fmt(cell.estimate.p95),
            cell.replans.to_string(),
        ]);
    }
    t.print();
    for (scheme, policy, reason) in &out.skipped {
        println!("  skipped {scheme} × {policy}: {reason}");
    }
    for d in &out.decode_cache {
        println!(
            "  decode cache {}: {:.1}% hit rate over {} rounds ({} hits / {} misses / {} evictions)",
            d.scheme,
            100.0 * d.stats.hit_rate(),
            d.rounds,
            d.stats.hits,
            d.stats.misses,
            d.stats.evictions
        );
    }
    println!("  completion digest: {:016x} (pinned-seed determinism handle)", out.digest);
    opts.write(&t, "trace_replay")?;
    Ok(())
}

/// `straggler trace record|fit|replay` — the record → fit → replay loop
/// of the trace subsystem (EXPERIMENTS.md §Traces).
fn run_trace(args: &Args, opts: &Options) -> Result<()> {
    let action = args.action.clone().unwrap_or_default();
    match action.as_str() {
        "record" => {
            let out_path = args
                .str_opt("out-trace")
                .ok_or_else(|| anyhow::anyhow!("`trace record` needs --out-trace FILE"))?;
            let path = std::path::PathBuf::from(&out_path);
            let store = if args.flag("cluster") {
                // real sockets + compute; the master's trace tap records
                // every Result frame
                if args.str_opt("model").is_some() {
                    bail!(
                        "--model shapes the *simulated* recorder; the cluster records \
                         real measured delays (drop --model or drop --cluster)"
                    );
                }
                let scheme_name = args.str_or("scheme", "GC(2)");
                let scheme = SchemeRegistry::parse(&scheme_name)?;
                let policy = PolicyKind::parse(&args.str_or("policy", "static"))?;
                let n = args.usize_or("n", 6)?;
                let cfg = harness::E2eConfig {
                    n,
                    d: args.usize_or("d", 64)?,
                    n_samples: args.usize_or("samples", n * 16)?,
                    r: args.usize_or("r", 4)?,
                    k: args.usize_or("k", n)?,
                    rounds: record_rounds(args, opts, 150)?,
                    eta: 0.01,
                    scheme,
                    policy,
                    staleness: args.usize_in("staleness", 1, 1, MAX_STALENESS)?,
                    profile: "trace".into(),
                    use_pjrt: false,
                    seed: opts.seed,
                    listen: None,
                    spawn_workers: true,
                    io: straggler_sched::coordinator::IoMode::default(),
                    metrics: MetricsConfig::default(),
                };
                let quiet = Options {
                    out_dir: None,
                    ..opts.clone()
                };
                let (report, _) = harness::run_e2e(cfg, &quiet)?;
                report.trace
            } else {
                // simulated: censored slots from the single-stream arm
                let n = args.usize_or("n", 8)?;
                let r = args.usize_or("r", 4)?;
                let k = args.usize_or("k", n)?;
                let rounds = record_rounds(args, opts, 200)?;
                let scheme_name = args.str_or("scheme", "GC(2)");
                let scheme = SchemeRegistry::parse(&scheme_name)?;
                let policy = PolicyKind::parse(&args.str_or("policy", "static"))?;
                let model_name = args.str_or("model", "ec2");
                let model = build_model(&model_name, n, opts.seed)?;
                let mut rec = TraceRecorder::with_fleet(scheme.to_string(), n);
                let out = run_policy_rounds(
                    &PolicyRunConfig {
                        scheme,
                        policy,
                        n,
                        r,
                        k,
                        rounds,
                        ingest_ms: 0.0,
                        seed: opts.seed,
                        // --staleness > 1 records a pipelined run, so
                        // the trace carries non-trivial θ-version tags
                        staleness: args.usize_in("staleness", 1, 1, MAX_STALENESS)?,
                    },
                    &PerRound(model.as_ref()),
                    None,
                    Some(&mut rec),
                )?;
                println!(
                    "  recorded {} censored-slot events over {rounds} rounds \
                     (mean completion {:.3} ms)",
                    rec.len(),
                    out.estimate.mean
                );
                rec.into_store()
            };
            store.save(&path)?;
            println!(
                "  wrote {} ({} events, {} workers, {} rounds, schemes {:?})",
                path.display(),
                store.len(),
                store.n_workers(),
                store.rounds(),
                store.schemes()
            );
        }
        "fit" => {
            let path = args
                .str_opt("trace")
                .ok_or_else(|| anyhow::anyhow!("`trace fit` needs --trace FILE"))?;
            let store = TraceStore::load(std::path::Path::new(&path))?;
            let fit = fit_traces(&store)?;
            let mut t = Table::new(
                &format!(
                    "trace fit: {} events, {} workers, {} rounds from {path}",
                    store.len(),
                    fit.n(),
                    store.rounds()
                ),
                &[
                    "worker", "ch", "samples", "mean", "exp shift", "exp rate", "exp KS",
                    "tg μ", "tg σ", "tg KS", "best", "tier",
                ],
            );
            for w in &fit.workers {
                let tier = if fit.tier_of[w.worker] == 0 { "fast" } else { "slow" };
                for (ch, c) in [("comp", &w.comp), ("comm", &w.comm)] {
                    t.push_row(vec![
                        w.worker.to_string(),
                        ch.into(),
                        c.samples.to_string(),
                        Table::fmt(c.mean_ms),
                        Table::fmt(c.exp.dist.shift),
                        Table::fmt(c.exp.dist.rate),
                        format!("{:.4}", c.exp.ks),
                        Table::fmt(c.tg.dist.mu),
                        Table::fmt(c.tg.dist.sigma),
                        format!("{:.4}", c.tg.ks),
                        c.best().to_string(),
                        tier.into(),
                    ]);
                }
            }
            t.print();
            if let (Some(fast), Some(slow)) = (fit.tier_mean_ms(0), fit.tier_mean_ms(1)) {
                println!(
                    "  tiers: {} fast (mean {:.3} ms/task) vs {} slow (mean {:.3} ms/task, \
                     {:.2}× slower)",
                    fit.fast_workers().len(),
                    fast,
                    fit.slow_workers().len(),
                    slow,
                    slow / fast
                );
            } else {
                println!("  tiers: fleet is effectively homogeneous (single tier)");
            }
            println!(
                "  correlated slowdown: fleet-mean σ̂ = {:.3} (per-worker: {}) — \
                 replay it with --replay corr",
                fit.mean_sigma(),
                fit.sigma
                    .iter()
                    .map(|s| format!("{s:.2}"))
                    .collect::<Vec<_>>()
                    .join(" ")
            );
            opts.write(&t, "trace_fit")?;
        }
        "replay" => {
            let path = args
                .str_opt("trace")
                .ok_or_else(|| anyhow::anyhow!("`trace replay` needs --trace FILE"))?;
            let store = TraceStore::load(std::path::Path::new(&path))?;
            run_trace_replay(args, opts, &store, &path)?;
        }
        "report" => {
            // offline attribution: reconstruct per-round critical-path
            // spans from a recorded trace — who delivered the k-th
            // distinct result, which phase dominated, what was wasted
            let path = args
                .str_opt("trace")
                .ok_or_else(|| anyhow::anyhow!("`trace report` needs --trace FILE"))?;
            let store = TraceStore::load(std::path::Path::new(&path))?;
            let k = args.usize_or("k", store.n_workers())?;
            let spans = spans_from_trace(&store, k)?;
            if args.flag("json") {
                // machine path: the same SpanSummary JSON the telemetry
                // exporter serves — one compact object on stdout, no tables
                println!("{}", spans.to_json().to_string_compact());
                return Ok(());
            }
            println!(
                "trace report: {} events over {} reconstructed rounds from {path} (k = {k})",
                store.len(),
                spans.rounds
            );
            let phases = spans.phase_table();
            phases.print();
            let attribution = spans.attribution_table();
            attribution.print();
            if spans.wasted.total_frames() > 0 {
                spans.wasted_table().print();
            }
            opts.write(&phases, "trace_report_phases")?;
            opts.write(&attribution, "trace_report_attribution")?;
        }
        other => bail!(
            "unknown trace action {other:?} — spell it \
             `straggler trace record|fit|replay|report` \
             (record: --out-trace FILE [--cluster] [--scheme S] [--rounds N]; \
             fit/replay/report: --trace FILE)"
        ),
    }
    Ok(())
}

fn run() -> Result<()> {
    let args = Args::from_env()?;
    let sub = args.subcommand.clone().unwrap_or_else(|| "help".into());
    if let Some(action) = args.action.as_ref().filter(|_| sub != "trace") {
        bail!("unexpected positional argument {action:?} after `{sub}`");
    }
    match sub.as_str() {
        "table1" => {
            let opts = options(&args)?;
            harness::table1(&opts)?;
        }
        "fig3" => {
            let mut opts = options(&args)?;
            if args.str_opt("trials").is_none() {
                opts.trials = 500; // paper: 500 iterations
            }
            harness::fig3(&opts)?;
        }
        "fig4" => {
            let opts = options(&args)?;
            harness::fig4(&opts)?;
        }
        "fig5" => {
            let opts = options(&args)?;
            harness::fig5(&opts)?;
        }
        "fig6" => {
            let opts = options(&args)?;
            harness::fig6(&opts)?;
        }
        "fig7" => {
            let opts = options(&args)?;
            harness::fig7(&opts)?;
        }
        "fig8" => {
            let opts = options(&args)?;
            harness::fig8_gc(&opts)?;
        }
        "all" => {
            let mut opts = options(&args)?;
            harness::table1(&opts)?;
            harness::fig4(&Options {
                scenario: 1,
                ..opts.clone()
            })?;
            harness::fig4(&Options {
                scenario: 2,
                ..opts.clone()
            })?;
            harness::fig5(&opts)?;
            harness::fig6(&opts)?;
            harness::fig7(&opts)?;
            harness::fig8_gc(&opts)?;
            harness::adaptive_shift_table(&opts)?;
            opts.trials = 500;
            harness::fig3(&opts)?;
        }
        "sim" => {
            let opts = options(&args)?;
            if let Some(path) = args.str_opt("from-trace") {
                // measured-delay replay: the fleet comes from the trace
                // (record → fit → replay, EXPERIMENTS.md §Traces)
                if args.str_opt("model").is_some() || args.str_opt("n").is_some() {
                    bail!(
                        "--from-trace replays the trace's own fleet; drop --model/--n \
                         (shape the matrix with --r/--k/--schemes/--policies/--replay \
                         empirical|tg|exp|corr instead)"
                    );
                }
                let store = TraceStore::load(std::path::Path::new(&path))?;
                run_trace_replay(&args, &opts, &store, &path)?;
                let unknown = args.unknown_keys();
                if !unknown.is_empty() {
                    bail!("unknown arguments: {}", unknown.join(", "));
                }
                return Ok(());
            }
            let n = args.usize_or("n", 16)?;
            let r = args.usize_or("r", 4)?;
            let k = args.usize_or("k", n)?;
            let model_name = args.str_or("model", "scenario1");
            let model = build_model(&model_name, n, opts.seed)?;
            let schemes = match args.str_opt("schemes") {
                None => SchemeRegistry::default_schemes(),
                Some(list) => {
                    // paren-aware split: GCH(4,1) keeps its inner comma
                    let ids = SchemeRegistry::parse_list(&list)?;
                    // explicitly named schemes must be runnable here —
                    // the default set filters silently (figure-sweep
                    // semantics), an explicit request must not
                    for &id in &ids {
                        if !SchemeRegistry::applicable(id, n, r, k) {
                            bail!(
                                "{id} is not applicable at (n = {n}, r = {r}, k = {k}) — \
                                 paper Table I (PC/PCMM need r ≥ 2 and k = n; RA needs \
                                 r = n; GC(s) needs s ≤ r; GCH(a,b) needs a,b ≤ r)"
                            );
                        }
                    }
                    ids
                }
            };
            let ingest = args.f64_or("ingest", 0.0)?;
            if ingest.is_nan() || ingest < 0.0 {
                bail!("--ingest must be a non-negative ms/message cost, got {ingest}");
            }
            let staleness = args.usize_in("staleness", 1, 1, MAX_STALENESS)?;
            if let Some(rec_path) = args.str_opt("record") {
                // censored-slot trace emission: a single-stream run of
                // ONE scheme, recorded through the simulator tap
                let scheme = match args.str_opt("schemes") {
                    None => SchemeId::Cs,
                    Some(_) if schemes.len() == 1 => schemes[0],
                    Some(list) => bail!(
                        "--record captures one scheme's trace at a time; \
                         got --schemes {list:?} (pick one)"
                    ),
                };
                let policy = match args.str_opt("policy") {
                    None => PolicyKind::Static,
                    Some(p) => PolicyKind::parse(&p)
                        .map_err(|e| anyhow::anyhow!("--policy {p:?}: {e}"))?,
                };
                let rounds = record_rounds(&args, &opts, 500)?;
                let mut rec = TraceRecorder::with_fleet(scheme.to_string(), n);
                let out = run_policy_rounds(
                    &PolicyRunConfig {
                        scheme,
                        policy,
                        n,
                        r,
                        k,
                        rounds,
                        ingest_ms: ingest,
                        seed: opts.seed,
                        staleness,
                    },
                    &PerRound(model.as_ref()),
                    None,
                    Some(&mut rec),
                )?;
                let store = rec.into_store();
                let path = std::path::PathBuf::from(&rec_path);
                store.save(&path)?;
                println!(
                    "  {scheme} under {policy}: mean completion {:.3} ms over {rounds} rounds",
                    out.estimate.mean
                );
                println!(
                    "  wrote {} ({} censored-slot events) — next: \
                     `straggler trace fit --trace {}` or `sim --from-trace {}`",
                    path.display(),
                    store.len(),
                    path.display(),
                    path.display()
                );
                let unknown = args.unknown_keys();
                if !unknown.is_empty() {
                    bail!("unknown arguments: {}", unknown.join(", "));
                }
                return Ok(());
            }
            if let Some(pname) = args.str_opt("policy") {
                // re-planning arm: every scheme runs twice on the same
                // delay stream — frozen (static) and under the policy.
                // `--policy order@s2` and `--policy order --staleness 2`
                // both pipeline S rounds in flight
                let spec = PolicySpec::parse(&pname).map_err(|e| {
                    anyhow::anyhow!("--policy {pname:?}: {e}")
                })?;
                let policy = spec.kind;
                let staleness = if spec.staleness > 1 {
                    spec.staleness
                } else {
                    staleness
                };
                let shift = args.usize_or("shift", 0)?;
                let rotate = args.usize_or("rotate", n / 2)?;
                let bases: Vec<SchemeId> = if args.str_opt("schemes").is_some() {
                    schemes.clone()
                } else {
                    // policy-mode default: CS plus a grouped base the
                    // load policy can re-split
                    let s = r.min(4) as u32;
                    if s > 1 {
                        vec![SchemeId::Cs, SchemeId::Gc(s)]
                    } else {
                        vec![SchemeId::Cs]
                    }
                };
                let shifting;
                let per_round;
                let round_model: &dyn RoundDelayModel = if shift > 0 {
                    shifting = ShiftingStraggler::new(model.as_ref(), shift, rotate);
                    &shifting
                } else {
                    per_round = PerRound(model.as_ref());
                    &per_round
                };
                let mut t = Table::new(
                    &format!(
                        "re-planning: n = {n}, r = {r}, k = {k}, model = {model_name}\
                         {}{}, ingest {ingest} ms, {} rounds",
                        if shift > 0 {
                            format!(" (shift every {shift} rot {rotate})")
                        } else {
                            String::new()
                        },
                        if staleness > 1 {
                            format!(", S = {staleness}")
                        } else {
                            String::new()
                        },
                        opts.trials
                    ),
                    &["scheme", "static", &policy.to_string(), "delta", "replans"],
                );
                for &scheme in &bases {
                    let run = |p: PolicyKind| {
                        run_policy_rounds(
                            &PolicyRunConfig {
                                scheme,
                                policy: p,
                                n,
                                r,
                                k,
                                rounds: opts.trials,
                                ingest_ms: ingest,
                                seed: opts.seed,
                                staleness,
                            },
                            round_model,
                            None,
                            None,
                        )
                    };
                    let frozen = run(PolicyKind::Static)?;
                    let adaptive = run(policy)?;
                    t.push_row(vec![
                        scheme.to_string(),
                        Table::fmt(frozen.estimate.mean),
                        Table::fmt(adaptive.estimate.mean),
                        format!(
                            "{:+.2}%",
                            100.0 * (adaptive.estimate.mean / frozen.estimate.mean - 1.0)
                        ),
                        adaptive.replans.to_string(),
                    ]);
                }
                t.print();
                let unknown = args.unknown_keys();
                if !unknown.is_empty() {
                    bail!("unknown arguments: {}", unknown.join(", "));
                }
                return Ok(());
            }
            if staleness > 1 {
                // k-async arm: every scheme runs twice on the same
                // delay stream — synchronous (S = 1) and with S rounds
                // in flight (EXPERIMENTS.md §Async).  The async column
                // reports per-round θ-application *increments*, so the
                // two columns are directly comparable wall-clock rates
                let mut t = Table::new(
                    &format!(
                        "k-async: n = {n}, r = {r}, k = {k}, model = {model_name}, \
                         S = {staleness}, ingest {ingest} ms, {} rounds",
                        opts.trials
                    ),
                    &["scheme", "sync", "async", "delta", "label"],
                );
                for &scheme in &schemes {
                    let run = |s: usize| {
                        run_policy_rounds(
                            &PolicyRunConfig {
                                scheme,
                                policy: PolicyKind::Static,
                                n,
                                r,
                                k,
                                rounds: opts.trials,
                                ingest_ms: ingest,
                                seed: opts.seed,
                                staleness: s,
                            },
                            &PerRound(model.as_ref()),
                            None,
                            None,
                        )
                    };
                    let sync = run(1)?;
                    let pipe = run(staleness)?;
                    t.push_row(vec![
                        scheme.to_string(),
                        Table::fmt(sync.estimate.mean),
                        Table::fmt(pipe.estimate.mean),
                        format!(
                            "{:+.2}%",
                            100.0 * (pipe.estimate.mean / sync.estimate.mean - 1.0)
                        ),
                        pipe.estimate.scheme.clone(),
                    ]);
                }
                t.print();
                let unknown = args.unknown_keys();
                if !unknown.is_empty() {
                    bail!("unknown arguments: {}", unknown.join(", "));
                }
                return Ok(());
            }
            let point = EvalPoint::new(n, r, k, opts.trials, opts.seed)
                .with_schemes(&schemes)
                .with_ingest(ingest);
            let est = harness::evaluate(&point, model.as_ref());
            let mut t = Table::new(
                &format!(
                    "t̄ (ms): n = {n}, r = {r}, k = {k}, model = {model_name}, {} trials",
                    opts.trials
                ),
                &["scheme", "mean", "std_err", "p50", "p95", "min", "max"],
            );
            for e in &est {
                t.push_row(vec![
                    e.scheme.clone(),
                    Table::fmt(e.mean),
                    Table::fmt(e.std_err),
                    Table::fmt(e.p50),
                    Table::fmt(e.p95),
                    Table::fmt(e.min),
                    Table::fmt(e.max),
                ]);
            }
            t.print();
            let lb = est.iter().find(|e| e.scheme == SchemeId::Lb.to_string());
            let ss = est.iter().find(|e| e.scheme == SchemeId::Ss.to_string());
            if let (Some(lb), Some(ss)) = (lb, ss) {
                println!("  SS-to-LB gap: {:.2}%", 100.0 * (ss.mean / lb.mean - 1.0));
            }
        }
        "run" => {
            let opts = options(&args)?;
            let path = args
                .str_opt("config")
                .ok_or_else(|| anyhow::anyhow!("`run` needs --config FILE"))?;
            let exp = straggler_sched::config::Experiment::from_file(std::path::Path::new(&path))?;
            let table = exp.run();
            table.print();
            if let Some(dir) = &opts.out_dir {
                for p in table.write(dir, &exp.name)? {
                    println!("  wrote {}", p.display());
                }
            }
        }
        "ablations" => {
            let opts = options(&args)?;
            harness::ablations(&opts)?;
        }
        "worker" => {
            // external worker process: `straggler worker --connect HOST:PORT
            // [--oracle] [--inject scenario1|scenario2|ec2|fixed] [--n N --id I]`
            let connect = args
                .str_opt("connect")
                .ok_or_else(|| anyhow::anyhow!("`worker` needs --connect HOST:PORT"))?;
            let addr: std::net::SocketAddr = connect
                .parse()
                .map_err(|e| anyhow::anyhow!("bad --connect address {connect:?}: {e}"))?;
            let inject = match args.str_opt("inject") {
                None => None,
                Some(name) => {
                    let n = args.usize_or("n", 4)?;
                    let id = args.usize_or("id", 0)?;
                    let seed = args.u64_or("seed", 0xF16)?;
                    let kind = match name.as_str() {
                        "scenario1" => {
                            straggler_sched::delay::DelayModelKind::TruncatedGaussianScenario1
                        }
                        "scenario2" => {
                            straggler_sched::delay::DelayModelKind::TruncatedGaussianScenario2 {
                                seed,
                            }
                        }
                        "ec2" => straggler_sched::delay::DelayModelKind::Ec2Like {
                            seed,
                            hetero: 0.2,
                        },
                        "fixed" => {
                            // deterministic constants for the latency-anatomy
                            // e2e: known ground truth per phase, one optional
                            // straggler slowed by --factor
                            let straggler = match args.str_opt("straggler") {
                                None => None,
                                Some(s) => Some(s.parse::<usize>().map_err(|e| {
                                    anyhow::anyhow!("bad --straggler {s:?}: {e}")
                                })?),
                            };
                            straggler_sched::delay::DelayModelKind::Fixed {
                                comp_ms: args.f64_or("comp-ms", 2.0)?,
                                comm_ms: args.f64_or("comm-ms", 0.5)?,
                                straggler,
                                factor: args.f64_or("factor", 4.0)?,
                            }
                        }
                        other => bail!("unknown --inject model {other:?}"),
                    };
                    Some(straggler_sched::coordinator::TaskDelaySampler::new(
                        kind.build(n),
                        n,
                        id,
                        seed,
                    ))
                }
            };
            let opts = straggler_sched::coordinator::WorkerOptions {
                backend: if args.flag("oracle") {
                    straggler_sched::coordinator::Backend::CpuOracle
                } else {
                    straggler_sched::coordinator::Backend::Pjrt
                },
                injected: inject,
                artifact_dir: args.str_opt("artifacts").map(Into::into),
            };
            println!("worker connecting to {addr} …");
            straggler_sched::coordinator::run_worker(addr, opts)?;
            println!("worker done");
        }
        "train" => {
            let opts = options(&args)?;
            let scheme_name = args.str_or("scheme", "SS");
            let scheme = SchemeRegistry::parse(&scheme_name).map_err(|e| {
                anyhow::anyhow!(
                    "--scheme {scheme_name:?}: {e}. Spellings: CS, SS, RA, PC, PCMM, \
                     GC(s) or GCs with s ≥ 1 (e.g. --scheme \"GC(2)\" or --scheme GC2), \
                     GCH(a,b) with per-worker flush sizes (e.g. --scheme \"GCH(4,1)\")"
                )
            })?;
            let policy_name = args.str_or("policy", "static");
            let spec = PolicySpec::parse(&policy_name)
                .map_err(|e| anyhow::anyhow!("--policy {policy_name:?}: {e}"))?;
            let staleness = if spec.staleness > 1 {
                spec.staleness
            } else {
                args.usize_in("staleness", 1, 1, MAX_STALENESS)?
            };
            let policy = spec.kind;
            let cfg = harness::E2eConfig {
                n: args.usize_or("n", 10)?,
                d: args.usize_or("d", 512)?,
                n_samples: args.usize_or("samples", 10_240)?,
                r: args.usize_or("r", 4)?,
                k: args.usize_or("k", 8)?,
                rounds: args.usize_or("rounds", 300)?,
                eta: args.f64_or("eta", 0.05)?,
                scheme,
                policy,
                staleness,
                profile: args.str_or("profile", "e2e"),
                use_pjrt: !args.flag("no-pjrt"),
                seed: args.u64_or("data-seed", 2024)?,
                listen: args.str_opt("listen"),
                spawn_workers: !args.flag("external"),
                io: straggler_sched::coordinator::IoMode::parse(&args.str_or("io", "reactor"))?,
                metrics: MetricsConfig {
                    addr: args.str_opt("metrics-addr"),
                    log: args.str_opt("metrics-log"),
                    flight_depth: args.usize_or(
                        "flight-depth",
                        straggler_sched::telemetry::flight::DEFAULT_FLIGHT_DEPTH,
                    )?,
                    anomaly_factor: args.f64_or(
                        "anomaly-factor",
                        straggler_sched::telemetry::flight::DEFAULT_ANOMALY_FACTOR,
                    )?,
                },
            };
            let io = cfg.io;
            let (report, curve) = harness::run_e2e(cfg, &opts)?;
            curve.print();
            println!(
                "  mean completion {:.3} ms over {} rounds; final loss {:.6}; \
                 avg wire {:.1} KiB/round",
                report.mean_completion_ms(),
                report.rounds.len(),
                report.final_loss,
                report.mean_wire_bytes() / 1024.0
            );
            if report.ingest.frames > 0 {
                println!(
                    "  {io} data plane: {} frames, master dwell p50 {:.1} µs  \
                     p99 {:.1} µs  max {:.1} µs",
                    report.ingest.frames,
                    report.ingest.dwell_p50_us,
                    report.ingest.dwell_p99_us,
                    report.ingest.dwell_max_us
                );
            }
            if let Some(stats) = &report.decode_cache {
                println!(
                    "  decode cache: {:.1}% hit rate ({} hits / {} misses / {} evictions)",
                    100.0 * stats.hit_rate(),
                    stats.hits,
                    stats.misses,
                    stats.evictions
                );
            }
            if report.spans.rounds > 0 {
                report.spans.phase_table().print();
                report.spans.attribution_table().print();
                if report.spans.wasted.total_frames() > 0 {
                    report.spans.wasted_table().print();
                }
            }
            if let Some(rec_path) = args.str_opt("record") {
                // the master's per-Result-frame trace (real socket
                // timings) — feeds `trace fit` / `sim --from-trace`
                let path = std::path::PathBuf::from(&rec_path);
                report.trace.save(&path)?;
                println!(
                    "  wrote {} ({} measured events) — next: \
                     `straggler trace fit --trace {}`",
                    path.display(),
                    report.trace.len(),
                    path.display()
                );
            }
            if !report.worker_estimates.is_empty() {
                let replans = report.rounds.iter().filter(|l| l.replanned).count();
                println!(
                    "  policy {policy}: {replans} replanned rounds; \
                     estimated per-task comp (ms):"
                );
                for e in &report.worker_estimates {
                    println!(
                        "    worker {:2}: mean {:.3}  p95 {:.3}  ({} samples)",
                        e.worker, e.comp_mean_ms, e.comp_p95_ms, e.samples
                    );
                }
            }
        }
        "adaptive" => {
            let opts = options(&args)?;
            harness::adaptive_shift_table(&opts)?;
        }
        "trace" => {
            let opts = options(&args)?;
            run_trace(&args, &opts)?;
        }
        _ => {
            print!("{HELP}");
        }
    }
    let unknown = args.unknown_keys();
    if !unknown.is_empty() {
        bail!("unknown arguments: {}", unknown.join(", "));
    }
    Ok(())
}

const HELP: &str = r#"straggler — computation scheduling with straggling workers (TSP 2019)

subcommands:
  table1            print/emit Table I (scheme characteristics)
  fig3              measured delay histograms on the real cluster
  fig4              t̄ vs computation load r (truncated Gaussian, --scenario 1|2)
  fig5              t̄ vs r (EC2-like; --cluster adds a real-cluster spot check)
  fig6              t̄ vs number of workers n
  fig7              t̄ vs computation target k
  fig8              GC(s) grouped multi-message tradeoff sweep
                    (--cluster adds a real-cluster spot check)
  sim               one (n, r, k) point (--model ..., --ingest MS,
                    --schemes CS,SS,RA,PC,PCMM,LB,GC(s),GCH(a,b));
                    --staleness S runs the bounded-staleness k-async
                    arm instead: each scheme synchronous vs with S
                    rounds in flight on the same delay stream (S = 1
                    is synchronous; S ≤ 8);
                    with --policy P it instead runs the sequential
                    re-planning arm, each scheme frozen vs under P
                    (--shift R rotates the worker delay profiles every
                    R rounds by --rotate positions — the
                    shifting-straggler scenario; P@sS, e.g. order@s2,
                    combines re-planning with S rounds in flight);
                    --record FILE captures one scheme's censored-slot
                    delay trace (--rounds N, default 500; add
                    --staleness S for θ-version-tagged async traces);
                    --from-trace FILE replays a recorded
                    trace instead of a --model (the fleet size comes
                    from the trace; --replay empirical|tg|exp|corr
                    picks bootstrap vs fitted vs correlated-slowdown
                    substrates, --policies static,order,load shapes
                    the matrix)
  run               run a JSON-described sweep: --config exp.json
                    (optional "policy" field runs the re-planning arm)
  ablations         design-choice studies (ingest, correlation, searched
                    schedules, Remark-3 bias)
  adaptive          the shifting-straggler comparison table: static
                    CS/GC/GCH vs the order/load policies on the same
                    delay stream (EXPERIMENTS.md §Adaptive)
  train             end-to-end distributed DGD over PJRT workers,
                    scheme-dispatched via the registry:
                    --scheme CS|SS|RA|GC(s)|GCH(a,b)|PC|PCMM
                    (default SS; GC(s) spells as "GC(2)" or GC2 and
                    aggregates one partial-sum block per flush;
                    GCH(a,b) ramps per-worker flush sizes, snapped to
                    divisors of max(a,b) on the cluster; PC/PCMM decode
                    the coded gradient on the master, k = n required)
                    --policy static|order|order@p95|load|load-rate|
                    alloc-group|alloc-random re-plans the assignment
                    between rounds from measured per-worker delays
                    (uncoded schemes only); --staleness S (or the
                    @sS policy suffix) keeps S rounds in flight on
                    the pipelined master (uncoded k-distinct wire
                    only, protocol v4 θ-version tags); --record FILE
                    saves the master's measured delay trace
                    (--listen ADDR --external for multi-process mode);
                    --io reactor|threads picks the master data plane:
                    the poll-driven zero-copy reactor (default) or the
                    legacy thread-per-worker receivers (bit-identical
                    cross-check path); --metrics-addr HOST:PORT serves
                    live Prometheus text on /metrics from the master's
                    own poll loop (no extra thread; telemetry is inert —
                    θ is bit-identical with it on or off), plus
                    /healthz (uptime + round gauge), /catalog (metric
                    catalog JSON) and /debug/flight (the anomaly
                    flight-recorder ring as JSON);
                    --metrics-log FILE appends one registry snapshot
                    per round as JSONL (final snapshot flushed + fsynced
                    on shutdown, Ctrl-C included); protocol v5 frames
                    carry worker-local timestamps, so each Result
                    decomposes into compute / worker-queue / network /
                    master-dwell phases on the master clock (NTP-style
                    per-worker offset estimation off the Assign→Result
                    exchange); --flight-depth N bounds the flight ring
                    (default 256) and --anomaly-factor F trips the
                    anomaly detector when a worker's phase EWMA exceeds
                    F × the fleet median (default 4.0); after the run
                    the master prints per-round phase spans (wait-first
                    / collect / decode / apply), straggler attribution
                    (who delivered the k-th distinct result, with
                    measured per-phase means) and a wasted-work table
  trace             the record → fit → replay loop (digital-twin
                    calibration, EXPERIMENTS.md §Traces):
                    trace record --out-trace FILE [--cluster]
                      captures a delay trace — simulated censored slots
                      by default (--scheme/--policy/--model/--n/--r/--k/
                      --rounds), real master-measured Result frames
                      with --cluster;
                    trace fit --trace FILE
                      per-worker shifted-exp MLE + truncated-Gaussian
                      moment fits, KS goodness-of-fit, fast/slow tiers,
                      per-worker correlated-slowdown σ̂;
                    trace replay --trace FILE
                      runs the scheme × policy matrix on the traced
                      fleet (--replay empirical|tg|exp|corr, --schemes,
                      --policies, --trials, --ingest) and prints the
                      pinned-seed completion digest;
                    trace report --trace FILE [--k K] [--json]
                      offline observability: reconstructs per-round
                      critical-path spans from the recorded arrivals
                      (completion = K-th distinct task, default K = n)
                      and prints phase, straggler-attribution and
                      wasted-work tables; --json emits the same
                      SpanSummary object the telemetry exporter serves
  worker            external worker process: --connect HOST:PORT
                    [--oracle] [--inject scenario1|scenario2|ec2|fixed
                    --n N --id I] (fixed: deterministic --comp-ms,
                    --comm-ms, optional --straggler W slowed ×--factor —
                    the latency-anatomy ground-truth injection)
  all               regenerate every table and figure

common flags: --trials N  --seed S  --out DIR  --no-out  --cluster
scheme grammar (sim/run/train): CS SS RA PC PCMM LB GC(s)|GCs GCH(a,b)
  — case-insensitive; malformed spellings fail with the expected form
policy grammar (sim/run/train): static order order@pQQ load load-rate
  alloc-group alloc-random
  — order/load re-plan from EWMA delay estimates; order@pQQ ranks by
  the empirical QQ-th percentile (heavy-tailed fleets, e.g. order@p95);
  load-rate sizes flushes by estimated service-rate ratios instead of
  the rank ramp; alloc-* are the Behrouzi-Far & Soljanin allocation
  variants (alloc-group needs r | n)
staleness axis: append @sS to any policy (order@s2, order@p95@s2) or
  pass --staleness S to keep S ∈ [1, 8] rounds in flight — bounded
  staleness: θ-version gap ≤ S − 1, S = 1 is the synchronous protocol
trace files: versioned JSONL (default) or compact binary (.bin), one
  event per delivered message — see EXPERIMENTS.md §Traces
"#;
