//! Measurement plumbing: histograms (Fig. 3), delay-trace recorders,
//! and distribution fitting (the truncated-Gaussian overlay of Fig. 3).



use crate::delay::TruncatedGaussian;
use crate::util::stats::RunningStats;

/// Fixed-bin histogram over `[lo, hi)`; under/overflow are clamped into
/// the edge bins so mass is never silently dropped.
#[derive(Debug, Clone)]
pub struct Histogram {
    pub lo: f64,
    pub hi: f64,
    pub counts: Vec<u64>,
    pub total: u64,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, bins: usize) -> Self {
        assert!(hi > lo, "empty support");
        assert!(bins >= 1, "need at least one bin");
        Self {
            lo,
            hi,
            counts: vec![0; bins],
            total: 0,
        }
    }

    pub fn bins(&self) -> usize {
        self.counts.len()
    }

    pub fn bin_width(&self) -> f64 {
        (self.hi - self.lo) / self.bins() as f64
    }

    pub fn push(&mut self, x: f64) {
        let idx = ((x - self.lo) / self.bin_width()).floor();
        let idx = (idx.max(0.0) as usize).min(self.bins() - 1);
        self.counts[idx] += 1;
        self.total += 1;
    }

    /// Bin center of bin `i`.
    pub fn center(&self, i: usize) -> f64 {
        self.lo + (i as f64 + 0.5) * self.bin_width()
    }

    /// Empirical density at bin `i` (normalized so Σ density·width = 1).
    pub fn density(&self, i: usize) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        self.counts[i] as f64 / (self.total as f64 * self.bin_width())
    }
}

/// Per-worker delay recorder used by the cluster coordinator: feeds both
/// Fig. 3 histograms and the empirical replay model.
#[derive(Debug, Clone, Default)]
pub struct DelayRecorder {
    pub comp: Vec<f64>,
    pub comm: Vec<f64>,
}

impl DelayRecorder {
    pub fn record_comp(&mut self, ms: f64) {
        self.comp.push(ms);
    }

    pub fn record_comm(&mut self, ms: f64) {
        self.comm.push(ms);
    }

    pub fn comp_stats(&self) -> RunningStats {
        let mut s = RunningStats::new();
        self.comp.iter().for_each(|&x| s.push(x));
        s
    }

    pub fn comm_stats(&self) -> RunningStats {
        let mut s = RunningStats::new();
        self.comm.iter().for_each(|&x| s.push(x));
        s
    }
}

/// Moment-fit a truncated Gaussian to samples, as the paper does for
/// Fig. 3's overlay: center at the sample mean, width at the sample
/// std-dev, support at the observed extremes (±(max−min)/2 around μ).
pub fn fit_truncated_gaussian(samples: &[f64]) -> TruncatedGaussian {
    assert!(samples.len() >= 2, "need ≥ 2 samples to fit");
    let mut acc = RunningStats::new();
    samples.iter().for_each(|&x| acc.push(x));
    let mu = acc.mean();
    let sigma = acc.std_dev().max(1e-12);
    let a = (mu - acc.min()).max(1e-12);
    let b = (acc.max() - mu).max(1e-12);
    TruncatedGaussian { mu, sigma, a, b }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_bins_and_density() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        for x in [0.5, 1.5, 1.7, 9.9, -5.0, 25.0] {
            h.push(x);
        }
        assert_eq!(h.total, 6);
        assert_eq!(h.counts[0], 2); // 0.5 and clamped −5.0
        assert_eq!(h.counts[1], 2);
        assert_eq!(h.counts[9], 2); // 9.9 and clamped 25.0
        // densities integrate to 1
        let integral: f64 = (0..10).map(|i| h.density(i) * h.bin_width()).sum();
        assert!((integral - 1.0).abs() < 1e-12);
        assert!((h.center(0) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn fit_recovers_gaussian_moments() {
        use crate::util::rng::Rng;
        let d = TruncatedGaussian::symmetric(5.0, 1.0, 3.0);
        let mut rng = Rng::seed_from_u64(2);
        let samples: Vec<f64> = (0..50_000).map(|_| d.sample(&mut rng)).collect();
        let fit = fit_truncated_gaussian(&samples);
        assert!((fit.mu - 5.0).abs() < 0.05, "mu {}", fit.mu);
        // truncation at ±3σ barely changes σ
        assert!((fit.sigma - 1.0).abs() < 0.05, "sigma {}", fit.sigma);
    }

    #[test]
    fn recorder_stats() {
        let mut r = DelayRecorder::default();
        r.record_comp(1.0);
        r.record_comp(3.0);
        r.record_comm(10.0);
        assert_eq!(r.comp_stats().count(), 2);
        assert!((r.comp_stats().mean() - 2.0).abs() < 1e-12);
        assert_eq!(r.comm_stats().count(), 1);
    }

    #[test]
    #[should_panic(expected = "need ≥ 2 samples")]
    fn fit_rejects_tiny_input() {
        fit_truncated_gaussian(&[1.0]);
    }
}
