//! Report emission: aligned console tables, CSV, and JSON result files
//! under `results/` — every figure harness writes all three so the
//! paper's plots can be regenerated with any plotting tool.

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::util::json::Json;

/// A rectangular table with a title; the common output of every
/// experiment harness.
#[derive(Debug, Clone)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn push_row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells);
    }

    /// Format a float with sensible experiment precision.
    pub fn fmt(x: f64) -> String {
        if x.is_nan() {
            "-".into()
        } else if x == 0.0 {
            "0".into()
        } else if x.abs() >= 100.0 {
            format!("{x:.1}")
        } else {
            format!("{x:.4}")
        }
    }

    /// Aligned plain-text rendering.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(String::len).collect();
        for row in &self.rows {
            for (w, cell) in widths.iter_mut().zip(row) {
                *w = (*w).max(cell.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("── {} ──\n", self.title));
        let line = |cells: &[String], widths: &[usize]| -> String {
            let mut s = String::from("  ");
            for (cell, w) in cells.iter().zip(widths) {
                s.push_str(&format!("{cell:>w$}  ", w = w));
            }
            s.trim_end().to_string() + "\n"
        };
        out.push_str(&line(&self.headers, &widths));
        let total: usize = widths.iter().sum::<usize>() + 2 * widths.len() + 2;
        out.push_str(&format!("  {}\n", "-".repeat(total.saturating_sub(2))));
        for row in &self.rows {
            out.push_str(&line(row, &widths));
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }

    /// CSV rendering (header row + data rows, minimal quoting).
    pub fn to_csv(&self) -> String {
        let esc = |s: &str| -> String {
            if s.contains(',') || s.contains('"') || s.contains('\n') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = String::new();
        out.push_str(
            &self
                .headers
                .iter()
                .map(|h| esc(h))
                .collect::<Vec<_>>()
                .join(","),
        );
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }

    /// JSON rendering: `{title, headers, rows: [[...]]}`.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("title", Json::Str(self.title.clone())),
            (
                "headers",
                Json::Arr(self.headers.iter().cloned().map(Json::Str).collect()),
            ),
            (
                "rows",
                Json::Arr(
                    self.rows
                        .iter()
                        .map(|r| Json::Arr(r.iter().cloned().map(Json::Str).collect()))
                        .collect(),
                ),
            ),
        ])
    }

    /// Write `<out>/<name>.csv` and `<out>/<name>.json`; returns paths.
    pub fn write(&self, out_dir: impl AsRef<Path>, name: &str) -> Result<Vec<PathBuf>> {
        let dir = out_dir.as_ref();
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating results dir {}", dir.display()))?;
        let csv = dir.join(format!("{name}.csv"));
        std::fs::write(&csv, self.to_csv())?;
        let json = dir.join(format!("{name}.json"));
        std::fs::write(&json, self.to_json().to_string_pretty())?;
        Ok(vec![csv, json])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Table {
        let mut t = Table::new("fig-test", &["r", "CS", "SS"]);
        t.push_row(vec!["2".into(), Table::fmt(0.86), Table::fmt(0.6923)]);
        t.push_row(vec!["16".into(), Table::fmt(123.456), Table::fmt(f64::NAN)]);
        t
    }

    #[test]
    fn render_aligns_columns() {
        let r = sample().render();
        assert!(r.contains("fig-test"));
        assert!(r.contains("0.8600"));
        assert!(r.contains("123.5")); // ≥100 → 1 decimal
        assert!(r.contains('-')); // NaN cell
    }

    #[test]
    fn csv_roundtrips_through_commas() {
        let mut t = Table::new("t", &["a", "b"]);
        t.push_row(vec!["x,y".into(), "q\"q".into()]);
        let csv = t.to_csv();
        assert!(csv.contains("\"x,y\""));
        assert!(csv.contains("\"q\"\"q\""));
    }

    #[test]
    fn json_parses_back() {
        let j = sample().to_json();
        let text = j.to_string_pretty();
        let back = Json::parse(&text).unwrap();
        assert_eq!(back.get("title").unwrap().as_str(), Some("fig-test"));
        assert_eq!(back.get("rows").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    fn writes_files() {
        let dir = std::env::temp_dir().join(format!("straggler-report-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let paths = sample().write(&dir, "fig_test").unwrap();
        assert_eq!(paths.len(), 2);
        for p in paths {
            assert!(p.exists());
        }
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn rejects_ragged_rows() {
        let mut t = Table::new("t", &["a"]);
        t.push_row(vec!["1".into(), "2".into()]);
    }
}
