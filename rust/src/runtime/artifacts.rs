//! AOT artifact manifest — the contract between `python/compile/aot.py`
//! and the rust runtime.
//!
//! `make artifacts` writes `artifacts/manifest.json` describing every
//! lowered HLO module (entry point, profile, argument shapes, dims).
//! The runtime is manifest-driven: it never hard-codes shapes, so adding
//! a profile on the python side requires no rust change.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;

/// Metadata of one artifact (one HLO-text module).
#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    /// manifest key, `"<profile>/<entry>"`
    pub key: String,
    /// file name within the artifact directory
    pub file: String,
    pub entry: String,
    pub profile: String,
    /// named dims (d, b, n, m) the module was lowered at
    pub dims: HashMap<String, usize>,
    /// per-argument shapes, in call order
    pub arg_shapes: Vec<Vec<usize>>,
    pub arg_names: Vec<String>,
}

impl ArtifactMeta {
    /// Element count of argument `idx`.
    pub fn arg_len(&self, idx: usize) -> usize {
        self.arg_shapes[idx].iter().product::<usize>().max(1)
    }

    pub fn dim(&self, name: &str) -> Option<usize> {
        self.dims.get(name).copied()
    }
}

/// Parsed manifest plus the directory it lives in.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: HashMap<String, ArtifactMeta>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts` first)", path.display()))?;
        let root = Json::parse(&text).map_err(|e| anyhow!("parsing {}: {e}", path.display()))?;
        let format = root
            .get("format")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("manifest missing `format`"))?;
        if format != "hlo-text/v1" {
            bail!("unsupported manifest format {format:?}");
        }
        let mut artifacts = HashMap::new();
        let entries = root
            .get("artifacts")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow!("manifest missing `artifacts` object"))?;
        for (key, meta) in entries {
            let get_str = |field: &str| -> Result<String> {
                meta.get(field)
                    .and_then(Json::as_str)
                    .map(str::to_string)
                    .ok_or_else(|| anyhow!("artifact {key}: missing `{field}`"))
            };
            let arg_shapes = meta
                .get("arg_shapes")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("artifact {key}: missing `arg_shapes`"))?
                .iter()
                .map(|shape| {
                    shape
                        .as_arr()
                        .ok_or_else(|| anyhow!("artifact {key}: bad shape"))?
                        .iter()
                        .map(|d| d.as_usize().ok_or_else(|| anyhow!("bad dim")))
                        .collect::<Result<Vec<usize>>>()
                })
                .collect::<Result<Vec<_>>>()?;
            let arg_names = meta
                .get("arg_names")
                .and_then(Json::as_arr)
                .map(|names| {
                    names
                        .iter()
                        .filter_map(Json::as_str)
                        .map(str::to_string)
                        .collect()
                })
                .unwrap_or_default();
            let dims = meta
                .get("dims")
                .and_then(Json::as_obj)
                .map(|pairs| {
                    pairs
                        .iter()
                        .filter_map(|(k, v)| v.as_usize().map(|u| (k.clone(), u)))
                        .collect()
                })
                .unwrap_or_default();
            artifacts.insert(
                key.clone(),
                ArtifactMeta {
                    key: key.clone(),
                    file: get_str("file")?,
                    entry: get_str("entry")?,
                    profile: get_str("profile")?,
                    dims,
                    arg_shapes,
                    arg_names,
                },
            );
        }
        Ok(Self { dir, artifacts })
    }

    /// Look up `"<profile>/<entry>"`.
    pub fn get(&self, profile: &str, entry: &str) -> Result<&ArtifactMeta> {
        let key = format!("{profile}/{entry}");
        self.artifacts
            .get(&key)
            .ok_or_else(|| anyhow!("artifact {key} not in manifest ({} present)", self.artifacts.len()))
    }

    /// Absolute path of an artifact's HLO text.
    pub fn path_of(&self, meta: &ArtifactMeta) -> PathBuf {
        self.dir.join(&meta.file)
    }

    /// All profiles present.
    pub fn profiles(&self) -> Vec<String> {
        let mut p: Vec<String> = self
            .artifacts
            .values()
            .map(|m| m.profile.clone())
            .collect();
        p.sort();
        p.dedup();
        p
    }
}

/// Default artifact directory: `$STRAGGLER_ARTIFACTS` or `./artifacts`.
pub fn default_artifact_dir() -> PathBuf {
    std::env::var_os("STRAGGLER_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_manifest(dir: &Path, body: &str) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(dir.join("manifest.json"), body).unwrap();
    }

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("straggler-manifest-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn loads_well_formed_manifest() {
        let dir = tmpdir("ok");
        write_manifest(
            &dir,
            r#"{
              "format": "hlo-text/v1",
              "artifacts": {
                "quickstart/task_gram": {
                  "file": "quickstart__task_gram.hlo.txt",
                  "entry": "task_gram",
                  "profile": "quickstart",
                  "dims": {"d": 64, "b": 32, "n": 4, "m": 8},
                  "arg_shapes": [[64, 32], [64]],
                  "arg_names": ["x", "theta"]
                }
              }
            }"#,
        );
        let m = Manifest::load(&dir).unwrap();
        let a = m.get("quickstart", "task_gram").unwrap();
        assert_eq!(a.arg_shapes, vec![vec![64, 32], vec![64]]);
        assert_eq!(a.arg_len(0), 2048);
        assert_eq!(a.arg_len(1), 64);
        assert_eq!(a.dim("d"), Some(64));
        assert_eq!(a.arg_names, vec!["x", "theta"]);
        assert_eq!(m.profiles(), vec!["quickstart"]);
        assert!(m.path_of(a).ends_with("quickstart__task_gram.hlo.txt"));
    }

    #[test]
    fn scalar_args_have_len_one() {
        let dir = tmpdir("scalar");
        write_manifest(
            &dir,
            r#"{"format": "hlo-text/v1", "artifacts": {
                "p/master_update": {
                  "file": "f.hlo.txt", "entry": "master_update", "profile": "p",
                  "dims": {}, "arg_shapes": [[8], [8], []], "arg_names": ["theta","agg","eta"]
                }}}"#,
        );
        let m = Manifest::load(&dir).unwrap();
        let a = m.get("p", "master_update").unwrap();
        assert_eq!(a.arg_len(2), 1);
    }

    #[test]
    fn missing_manifest_is_helpful_error() {
        let err = Manifest::load(tmpdir("missing")).unwrap_err();
        assert!(err.to_string().contains("make artifacts"), "{err}");
    }

    #[test]
    fn rejects_wrong_format() {
        let dir = tmpdir("fmt");
        write_manifest(&dir, r#"{"format": "hlo-bin/v9", "artifacts": {}}"#);
        assert!(Manifest::load(&dir).is_err());
    }

    #[test]
    fn unknown_key_error_lists_count() {
        let dir = tmpdir("unknown");
        write_manifest(&dir, r#"{"format": "hlo-text/v1", "artifacts": {}}"#);
        let m = Manifest::load(&dir).unwrap();
        let err = m.get("nope", "task_gram").unwrap_err();
        assert!(err.to_string().contains("nope/task_gram"));
    }
}
