//! PJRT runtime — loads the AOT-compiled JAX/Pallas artifacts and runs
//! them on the request path.  Python never executes at runtime: the
//! interchange is HLO **text** (see `python/compile/aot.py` and
//! /opt/xla-example/README.md for why text, not serialized protos).
//!
//! One [`Runtime`] owns a PJRT CPU client plus a cache of compiled
//! executables keyed by `"<profile>/<entry>"`.  PJRT handles are not
//! `Send`, so each coordinator worker thread owns its own `Runtime`
//! (compilation of these small modules is a few ms, amortized once at
//! cluster start — measured in EXPERIMENTS.md §Perf).
//!
//! **Build gating:** the PJRT execution path needs the `xla` (xla-rs)
//! bindings, which are not vendored in this offline tree.  The real
//! implementation compiles only with `--features pjrt`; the default
//! build substitutes an API-compatible stub whose constructor returns an
//! error, so callers (the coordinator's `Backend::Pjrt`, benches, tests)
//! compile unchanged and fall back or skip at runtime.

pub mod artifacts;

pub use artifacts::{default_artifact_dir, ArtifactMeta, Manifest};

#[cfg(feature = "pjrt")]
mod backend {
    use super::{default_artifact_dir, Manifest};
    use std::collections::HashMap;
    use std::path::Path;

    use anyhow::{anyhow, Context, Result};

    /// A loaded PJRT CPU runtime bound to one artifact directory.
    pub struct Runtime {
        client: xla::PjRtClient,
        manifest: Manifest,
        cache: HashMap<String, xla::PjRtLoadedExecutable>,
        /// resident device buffers for round-invariant operands (worker
        /// data partitions): uploading X once instead of per task removed
        /// a 2 MB host copy from every e2e task execution — §Perf
        buffers: HashMap<String, xla::PjRtBuffer>,
    }

    impl Runtime {
        /// Create a CPU PJRT client and load the manifest from `dir`.
        pub fn new(dir: impl AsRef<Path>) -> Result<Self> {
            let manifest = Manifest::load(dir)?;
            let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
            Ok(Self {
                client,
                manifest,
                cache: HashMap::new(),
                buffers: HashMap::new(),
            })
        }

        /// Artifact directory from `$STRAGGLER_ARTIFACTS` / `./artifacts`.
        pub fn from_default_dir() -> Result<Self> {
            Self::new(default_artifact_dir())
        }

        pub fn manifest(&self) -> &Manifest {
            &self.manifest
        }

        pub fn platform_name(&self) -> String {
            self.client.platform_name()
        }

        /// Compile (or fetch cached) executable for `profile/entry`.
        pub fn prepare(&mut self, profile: &str, entry: &str) -> Result<()> {
            let key = format!("{profile}/{entry}");
            if self.cache.contains_key(&key) {
                return Ok(());
            }
            let meta = self.manifest.get(profile, entry)?.clone();
            let path = self.manifest.path_of(&meta);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
            )
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compiling {key}"))?;
            self.cache.insert(key, exe);
            Ok(())
        }

        /// Execute `profile/entry` on f32 buffers (shapes validated against
        /// the manifest) and return the flat f32 output.
        ///
        /// The AOT pipeline lowers with `return_tuple=True`, so every module
        /// returns a 1-tuple; this unwraps it.
        pub fn execute(&mut self, profile: &str, entry: &str, args: &[&[f32]]) -> Result<Vec<f32>> {
            self.prepare(profile, entry)?;
            let meta = self.manifest.get(profile, entry)?.clone();
            anyhow::ensure!(
                args.len() == meta.arg_shapes.len(),
                "{}/{entry}: expected {} args, got {}",
                profile,
                meta.arg_shapes.len(),
                args.len()
            );
            let mut literals = Vec::with_capacity(args.len());
            for (idx, (arg, shape)) in args.iter().zip(&meta.arg_shapes).enumerate() {
                anyhow::ensure!(
                    arg.len() == meta.arg_len(idx),
                    "{}/{entry}: arg {idx} ({}) has {} elements, manifest says {:?}",
                    profile,
                    meta.arg_names.get(idx).map(String::as_str).unwrap_or("?"),
                    arg.len(),
                    shape
                );
                let lit = if shape.is_empty() {
                    xla::Literal::from(arg[0])
                } else {
                    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
                    xla::Literal::vec1(arg)
                        .reshape(&dims)
                        .with_context(|| format!("reshaping arg {idx} to {shape:?}"))?
                };
                literals.push(lit);
            }
            let key = format!("{profile}/{entry}");
            let exe = self.cache.get(&key).expect("prepared above");
            let result = exe
                .execute::<xla::Literal>(&literals)
                .with_context(|| format!("executing {key}"))?[0][0]
                .to_literal_sync()?;
            let out = result
                .to_tuple1()
                .with_context(|| format!("{key}: unwrapping 1-tuple output"))?;
            Ok(out.to_vec::<f32>()?)
        }

        /// Convenience: the paper's worker task `h(X) = X Xᵀ θ` (eq. 50).
        pub fn task_gram(&mut self, profile: &str, x: &[f32], theta: &[f32]) -> Result<Vec<f32>> {
            self.execute(profile, "task_gram", &[x, theta])
        }

        /// Upload a round-invariant operand to the device once, keyed.
        pub fn upload(&mut self, key: &str, data: &[f32], shape: &[usize]) -> Result<()> {
            if self.buffers.contains_key(key) {
                return Ok(());
            }
            let buf = self
                .client
                .buffer_from_host_buffer::<f32>(data, shape, None)
                .with_context(|| format!("uploading buffer {key}"))?;
            self.buffers.insert(key.to_string(), buf);
            Ok(())
        }

        pub fn has_buffer(&self, key: &str) -> bool {
            self.buffers.contains_key(key)
        }

        /// `h(X) = X Xᵀ θ` with `X` resident on-device (uploaded via
        /// [`Runtime::upload`]); only the small `θ` is copied per call.
        pub fn task_gram_resident(
            &mut self,
            profile: &str,
            x_key: &str,
            theta: &[f32],
        ) -> Result<Vec<f32>> {
            self.prepare(profile, "task_gram")?;
            let meta = self.manifest.get(profile, "task_gram")?;
            anyhow::ensure!(
                theta.len() == meta.arg_len(1),
                "theta has {} elements, manifest says {:?}",
                theta.len(),
                meta.arg_shapes[1]
            );
            let theta_shape = meta.arg_shapes[1].clone();
            let theta_buf = self
                .client
                .buffer_from_host_buffer::<f32>(theta, &theta_shape, None)?;
            let x_buf = self
                .buffers
                .get(x_key)
                .ok_or_else(|| anyhow!("no resident buffer {x_key}; call upload() first"))?;
            let key = format!("{profile}/task_gram");
            let exe = self.cache.get(&key).expect("prepared above");
            let result = exe
                .execute_b::<&xla::PjRtBuffer>(&[x_buf, &theta_buf])
                .with_context(|| format!("executing {key} (resident)"))?[0][0]
                .to_literal_sync()?;
            let out = result.to_tuple1()?;
            Ok(out.to_vec::<f32>()?)
        }

        /// Master update `θ ← θ − η_eff · agg`.
        pub fn master_update(
            &mut self,
            profile: &str,
            theta: &[f32],
            agg: &[f32],
            eta_eff: f32,
        ) -> Result<Vec<f32>> {
            self.execute(profile, "master_update", &[theta, agg, &[eta_eff]])
        }

        /// Loss over stacked partitions (eq. 47); returns the scalar.
        pub fn loss(
            &mut self,
            profile: &str,
            x_parts: &[f32],
            y_parts: &[f32],
            theta: &[f32],
        ) -> Result<f32> {
            let v = self.execute(profile, "loss", &[x_parts, y_parts, theta])?;
            Ok(v[0])
        }
    }
}

#[cfg(not(feature = "pjrt"))]
mod backend {
    use super::{default_artifact_dir, Manifest};
    use std::path::Path;

    use anyhow::{bail, Result};

    const DISABLED: &str = "straggler-sched was built without the `pjrt` feature; \
         rebuild with `--features pjrt` in an environment providing the xla-rs \
         bindings, or use the CPU-oracle backend (`--oracle`)";

    /// API-compatible stand-in for the PJRT runtime.  [`Runtime::new`]
    /// always fails with an explanatory error, so no instance can exist;
    /// the methods are present only so callers compile unchanged.
    pub struct Runtime {
        manifest: Manifest,
    }

    impl Runtime {
        pub fn new(dir: impl AsRef<Path>) -> Result<Self> {
            // validate the manifest anyway so error messages stay useful
            let _ = Manifest::load(dir)?;
            bail!("{DISABLED}")
        }

        pub fn from_default_dir() -> Result<Self> {
            Self::new(default_artifact_dir())
        }

        pub fn manifest(&self) -> &Manifest {
            &self.manifest
        }

        pub fn platform_name(&self) -> String {
            "stub (pjrt feature disabled)".into()
        }

        pub fn prepare(&mut self, _profile: &str, _entry: &str) -> Result<()> {
            bail!("{DISABLED}")
        }

        pub fn execute(
            &mut self,
            _profile: &str,
            _entry: &str,
            _args: &[&[f32]],
        ) -> Result<Vec<f32>> {
            bail!("{DISABLED}")
        }

        pub fn task_gram(
            &mut self,
            _profile: &str,
            _x: &[f32],
            _theta: &[f32],
        ) -> Result<Vec<f32>> {
            bail!("{DISABLED}")
        }

        pub fn upload(&mut self, _key: &str, _data: &[f32], _shape: &[usize]) -> Result<()> {
            bail!("{DISABLED}")
        }

        pub fn has_buffer(&self, _key: &str) -> bool {
            false
        }

        pub fn task_gram_resident(
            &mut self,
            _profile: &str,
            _x_key: &str,
            _theta: &[f32],
        ) -> Result<Vec<f32>> {
            bail!("{DISABLED}")
        }

        pub fn master_update(
            &mut self,
            _profile: &str,
            _theta: &[f32],
            _agg: &[f32],
            _eta_eff: f32,
        ) -> Result<Vec<f32>> {
            bail!("{DISABLED}")
        }

        pub fn loss(
            &mut self,
            _profile: &str,
            _x_parts: &[f32],
            _y_parts: &[f32],
            _theta: &[f32],
        ) -> Result<f32> {
            bail!("{DISABLED}")
        }
    }
}

pub use backend::Runtime;

#[cfg(all(test, feature = "pjrt"))]
mod tests {
    //! These compile-and-run the real AOT artifacts; they are skipped
    //! (not failed) when `artifacts/` hasn't been built so that pure
    //! rust iterations stay fast.  `make test` always builds artifacts
    //! first, so CI exercises them.

    use super::*;

    fn runtime() -> Option<Runtime> {
        let dir = default_artifact_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping runtime test: no artifacts at {}", dir.display());
            return None;
        }
        Some(Runtime::new(dir).expect("runtime construction"))
    }

    #[test]
    fn loads_and_reports_platform() {
        let Some(rt) = runtime() else { return };
        assert_eq!(rt.platform_name().to_lowercase(), "cpu");
        assert!(rt.manifest().profiles().contains(&"quickstart".to_string()));
    }

    #[test]
    fn task_gram_matches_cpu_oracle() {
        let Some(mut rt) = runtime() else { return };
        let meta = rt.manifest().get("quickstart", "task_gram").unwrap().clone();
        let (d, b) = (meta.dim("d").unwrap(), meta.dim("b").unwrap());
        // deterministic pseudo-data
        let x: Vec<f32> = (0..d * b).map(|i| ((i * 37 % 23) as f32 - 11.0) / 7.0).collect();
        let theta: Vec<f32> = (0..d).map(|i| ((i * 13 % 17) as f32 - 8.0) / 5.0).collect();
        let got = rt.task_gram("quickstart", &x, &theta).unwrap();
        assert_eq!(got.len(), d);
        // oracle: X (Xᵀ θ) in f64
        let xm = crate::linalg::Mat::from_fn(d, b, |i, j| x[i * b + j] as f64);
        let wanted = xm.gram_matvec(&theta.iter().map(|&v| v as f64).collect::<Vec<_>>());
        for i in 0..d {
            let w = wanted[i] as f32;
            assert!(
                (got[i] - w).abs() <= 1e-3 * (1.0 + w.abs()),
                "lane {i}: {} vs {w}",
                got[i]
            );
        }
    }

    #[test]
    fn master_update_is_exact() {
        let Some(mut rt) = runtime() else { return };
        let meta = rt.manifest().get("quickstart", "master_update").unwrap().clone();
        let d = meta.dim("d").unwrap();
        let theta: Vec<f32> = (0..d).map(|i| i as f32 / 10.0).collect();
        let agg: Vec<f32> = (0..d).map(|i| (d - i) as f32).collect();
        let got = rt.master_update("quickstart", &theta, &agg, 0.5).unwrap();
        for i in 0..d {
            let want = theta[i] - 0.5 * agg[i];
            assert!((got[i] - want).abs() < 1e-6, "lane {i}");
        }
    }

    #[test]
    fn wrong_arg_count_is_error() {
        let Some(mut rt) = runtime() else { return };
        let err = rt.execute("quickstart", "task_gram", &[&[0.0]]).unwrap_err();
        assert!(err.to_string().contains("expected 2 args"), "{err}");
    }

    #[test]
    fn wrong_arg_len_is_error() {
        let Some(mut rt) = runtime() else { return };
        let err = rt
            .execute("quickstart", "task_gram", &[&[0.0f32; 3], &[0.0f32; 3]])
            .unwrap_err();
        assert!(err.to_string().contains("elements"), "{err}");
    }

}

#[cfg(all(test, not(feature = "pjrt")))]
mod stub_tests {
    use super::*;

    #[test]
    fn stub_runtime_fails_with_actionable_error() {
        let dir = default_artifact_dir();
        let err = match Runtime::new(&dir) {
            Err(e) => e,
            Ok(_) => panic!("stub Runtime must never construct"),
        };
        let msg = err.to_string();
        // either the manifest is missing (no artifacts built) or the
        // feature gate fires; both must point the user somewhere useful
        assert!(
            msg.contains("pjrt") || msg.contains("make artifacts"),
            "unhelpful error: {msg}"
        );
    }
}
