//! Cyclic scheduling (CS) — paper §IV-A.
//!
//! `C_CS(i, j) = g(i + j − 1)` (eq. 21): worker `i` starts at task `i`
//! and walks forward cyclically.  Every task therefore occupies a
//! *different* slot position at each of the `r` workers that hold it —
//! position `j` at exactly one worker for each `j ∈ [r]` — which is the
//! structural property that makes partial computations useful: some
//! worker always has any given task early in its queue.

use crate::util::rng::Rng;

use super::{wrap, Scheduler, ToMatrix};

#[derive(Debug, Clone, Copy, Default)]
pub struct CyclicScheduler;

impl Scheduler for CyclicScheduler {
    fn name(&self) -> &'static str {
        "CS"
    }

    fn schedule(&self, n: usize, r: usize, _rng: &mut Rng) -> ToMatrix {
        let rows = (0..n)
            .map(|i| (0..r).map(|j| wrap((i + j) as i64, n)).collect())
            .collect();
        ToMatrix::new(n, rows)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    

    fn build(n: usize, r: usize) -> ToMatrix {
        let mut rng = Rng::seed_from_u64(0);
        CyclicScheduler.schedule(n, r, &mut rng)
    }

    #[test]
    fn matches_paper_example_2() {
        // Example 2 (n = 4, r = 3), paper's 1-based C_CS:
        //   [1 2 3; 2 3 4; 3 4 1; 4 1 2]
        let c = build(4, 3);
        assert_eq!(
            c.rows(),
            &[vec![0, 1, 2], vec![1, 2, 3], vec![2, 3, 0], vec![3, 0, 1]]
        );
    }

    #[test]
    fn rows_distinct_and_cyclic() {
        for n in 1..=12 {
            for r in 1..=n {
                let c = build(n, r);
                assert!(c.rows_distinct(), "n={n} r={r}");
                // cyclic structure: row i is row 0 shifted by i
                for i in 0..n {
                    for j in 0..r {
                        assert_eq!(c.task(i, j), (c.task(0, j) + i) % n);
                    }
                }
            }
        }
    }

    #[test]
    fn uniform_coverage_r_each() {
        // every task is held by exactly r workers
        for (n, r) in [(5, 1), (7, 3), (8, 8)] {
            let cov = build(n, r).coverage();
            assert!(cov.iter().all(|&c| c == r), "n={n} r={r}: {cov:?}");
        }
    }

    #[test]
    fn each_task_occupies_every_slot_once() {
        // the defining CS property: task t sits at slot j for exactly one
        // worker, for every j < r
        let c = build(6, 4);
        for t in 0..6 {
            let mut slots: Vec<usize> = c.placements(t).into_iter().map(|(_, j)| j).collect();
            slots.sort_unstable();
            assert_eq!(slots, vec![0, 1, 2, 3], "task {t}");
        }
    }

    #[test]
    fn full_load_rows_are_rotations() {
        let c = build(5, 5);
        for i in 0..5 {
            let mut expected: Vec<usize> = (0..5).map(|j| (i + j) % 5).collect();
            assert_eq!(c.row(i), &expected[..]);
            expected.rotate_left(1);
        }
    }
}
