//! Task-ordering (TO) matrices and schedulers (paper §II, §IV).
//!
//! A [`ToMatrix`] is the paper's `C ∈ [n]^{n×r}`: row `i` lists, in
//! execution order, the task indices worker `i` computes (0-based here;
//! the paper is 1-based).  A [`Scheduler`] builds one for given `(n, r)`.
//!
//! Provided schedulers:
//! * [`CyclicScheduler`] — CS, eq. (21)–(23);
//! * [`StaircaseScheduler`] — SS, eq. (29)–(30);
//! * [`RandomAssignment`] — RA baseline of [18] (r = n, random order);
//! * [`oracle`] — the genie schedule used by the §V lower bound.
//!
//! Schedulers build *assignments*; full **schemes** (assignment +
//! execution order + completion rule, with applicability and display
//! names) live in [`crate::scheme`] — its `SchemeRegistry` wraps these
//! schedulers for the uncoded schemes and is re-exported here as
//! [`SchemeId`] for backward compatibility.

pub mod cyclic;
pub mod oracle;
pub mod random_assignment;
pub mod search;
pub mod staircase;

pub use cyclic::CyclicScheduler;
pub use oracle::oracle_schedule;
pub use random_assignment::RandomAssignment;
pub use search::{search, SearchConfig, SearchOutcome};
pub use staircase::StaircaseScheduler;

// SchemeId moved into the unified scheme layer (PR 2); re-exported here
// because harness/config/tests historically import it from `scheduler`.
pub use crate::scheme::SchemeId;

use crate::util::rng::Rng;


/// Task-ordering matrix: `rows[i][j]` = index of the task worker `i`
/// executes as its `j`-th computation.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ToMatrix {
    n: usize,
    r: usize,
    rows: Vec<Vec<usize>>,
}

impl ToMatrix {
    /// Build from explicit rows, validating the TO-matrix invariants:
    /// `n` rows, each of length `r ≤ n`, entries in `[0, n)`.  Distinct
    /// entries per row are *recommended* (any repeat wastes a slot —
    /// paper §II notes optimal matrices have distinct rows) but not
    /// required; [`ToMatrix::rows_distinct`] reports it.
    pub fn new(n: usize, rows: Vec<Vec<usize>>) -> Self {
        assert_eq!(rows.len(), n, "need one row per worker");
        assert!(n > 0, "need at least one worker");
        let r = rows[0].len();
        assert!(r >= 1 && r <= n, "computation load must satisfy 1 ≤ r ≤ n");
        for (i, row) in rows.iter().enumerate() {
            assert_eq!(row.len(), r, "row {i} has wrong length");
            for &t in row {
                assert!(t < n, "row {i} references task {t} ≥ n = {n}");
            }
        }
        Self { n, r, rows }
    }

    pub fn n(&self) -> usize {
        self.n
    }

    pub fn r(&self) -> usize {
        self.r
    }

    /// Entry `C(i, j)` (0-based).
    #[inline]
    pub fn task(&self, worker: usize, slot: usize) -> usize {
        self.rows[worker][slot]
    }

    #[inline]
    pub fn row(&self, worker: usize) -> &[usize] {
        &self.rows[worker]
    }

    pub fn rows(&self) -> &[Vec<usize>] {
        &self.rows
    }

    /// Does every row consist of distinct tasks?
    pub fn rows_distinct(&self) -> bool {
        let mut seen = vec![false; self.n];
        for row in &self.rows {
            seen.iter_mut().for_each(|s| *s = false);
            for &t in row {
                if seen[t] {
                    return false;
                }
                seen[t] = true;
            }
        }
        true
    }

    /// How many workers are assigned each task (the task's replication).
    pub fn coverage(&self) -> Vec<usize> {
        let mut cov = vec![0usize; self.n];
        for row in &self.rows {
            for &t in row {
                cov[t] += 1;
            }
        }
        cov
    }

    /// Positions (slots) at which `task` appears across workers;
    /// `(worker, slot)` pairs.  Empty if the task is unassigned.
    pub fn placements(&self, task: usize) -> Vec<(usize, usize)> {
        let mut out = Vec::new();
        for (i, row) in self.rows.iter().enumerate() {
            for (j, &t) in row.iter().enumerate() {
                if t == task {
                    out.push((i, j));
                }
            }
        }
        out
    }

    /// Is every task assigned to at least one worker?  (Necessary for a
    /// computation target of k = n to ever complete.)
    pub fn covers_all_tasks(&self) -> bool {
        self.coverage().iter().all(|&c| c > 0)
    }

    /// Render with 1-based indices in the paper's bracket layout, e.g.
    /// the `C_CS` of Example 2.
    pub fn to_paper_string(&self) -> String {
        let mut s = String::new();
        for row in &self.rows {
            s.push_str("  [");
            for (j, &t) in row.iter().enumerate() {
                if j > 0 {
                    s.push(' ');
                }
                s.push_str(&(t + 1).to_string());
            }
            s.push_str("]\n");
        }
        s
    }
}

/// Builds TO matrices.  Stateless schedulers (CS/SS) ignore the RNG;
/// RA redraws a fresh random order every call — matching the paper,
/// where RA re-randomizes each DGD iteration while CS/SS are fixed.
pub trait Scheduler: Send + Sync {
    fn name(&self) -> &'static str;

    /// Construct the TO matrix for `n` workers with computation load `r`.
    fn schedule(&self, n: usize, r: usize, rng: &mut Rng) -> ToMatrix;

    /// True if `schedule` depends on the RNG (must be re-invoked per
    /// round in Monte-Carlo runs).
    fn is_randomized(&self) -> bool {
        false
    }
}

// Let borrowed trait objects act as schedulers, so engines holding
// `&[&dyn Scheduler]` can feed the generic scheme-layer adapters
// (`scheme::evaluator_for_scheduler`) without boxing or cloning.
impl Scheduler for &dyn Scheduler {
    fn name(&self) -> &'static str {
        (**self).name()
    }

    fn schedule(&self, n: usize, r: usize, rng: &mut Rng) -> ToMatrix {
        (**self).schedule(n, r, rng)
    }

    fn is_randomized(&self) -> bool {
        (**self).is_randomized()
    }
}

/// The paper's cyclic-shift index helper `g(m)` (eq. 22), expressed
/// 0-based: wraps any integer into `[0, n)`.
#[inline]
pub(crate) fn wrap(m: i64, n: usize) -> usize {
    let n = n as i64;
    (((m % n) + n) % n) as usize
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_example_1_matrix_is_valid() {
        // Example 1's C (1-based) converted to 0-based
        let c = ToMatrix::new(
            4,
            vec![vec![0, 1, 2], vec![2, 1, 0], vec![2, 3, 0], vec![3, 2, 0]],
        );
        assert_eq!(c.n(), 4);
        assert_eq!(c.r(), 3);
        assert!(c.rows_distinct());
        assert!(c.covers_all_tasks());
        // task 0 (paper's X_1) opens worker 0's row and closes the
        // other three workers' rows
        assert_eq!(
            c.placements(0),
            vec![(0, 0), (1, 2), (2, 2), (3, 2)]
        );
        // coverage: tasks 0 and 2 at all four workers, tasks 1 and 3
        // at two workers each
        assert_eq!(c.coverage(), vec![4, 2, 4, 2]);
    }

    #[test]
    fn wrap_matches_paper_g() {
        // paper g (1-based): g(m) = m for 1≤m≤n, m−n above, m+n below.
        // 0-based equivalence: wrap(m) = g(m+1) − 1 for m in −n..2n.
        let n = 4;
        assert_eq!(wrap(0, n), 0);
        assert_eq!(wrap(3, n), 3);
        assert_eq!(wrap(4, n), 0);
        assert_eq!(wrap(7, n), 3);
        assert_eq!(wrap(-1, n), 3);
        assert_eq!(wrap(-4, n), 0);
    }

    #[test]
    #[should_panic(expected = "computation load")]
    fn rejects_r_greater_than_n() {
        ToMatrix::new(2, vec![vec![0, 1, 0], vec![1, 0, 1]]);
    }

    #[test]
    #[should_panic(expected = "references task")]
    fn rejects_out_of_range_task() {
        ToMatrix::new(2, vec![vec![0, 2], vec![1, 0]]);
    }

    #[test]
    fn detects_non_distinct_rows() {
        let c = ToMatrix::new(2, vec![vec![0, 0], vec![1, 0]]);
        assert!(!c.rows_distinct());
    }

    #[test]
    fn paper_string_is_one_based() {
        let c = ToMatrix::new(2, vec![vec![0, 1], vec![1, 0]]);
        assert_eq!(c.to_paper_string(), "  [1 2]\n  [2 1]\n");
    }

    #[test]
    fn scheme_id_reexport_still_resolves() {
        // SchemeId moved to crate::scheme; the historical
        // `scheduler::SchemeId` path must keep working
        let id: SchemeId = SchemeId::Cs;
        assert_eq!(id.to_string(), "CS");
    }
}
