//! Genie (oracle) scheduling — the constructive half of the §V lower
//! bound.
//!
//! Given the *realization* of all per-slot delays (the paper's `T`,
//! eq. 42), one can pick a TO matrix whose completion time equals the
//! k-th smallest slot-arrival time `t̂_{T,(k)}`: order all `n·r` slots by
//! arrival (eq. 46) and make the first `k` of them carry `k` distinct
//! tasks.  The paper uses this argument to show
//! `t_LB(T, r, k) = t̂_{T,(k)}`; we implement the construction so a test
//! can verify, realization by realization, that simulating the returned
//! matrix really completes at the k-th order statistic.

use crate::delay::DelaySample;
use crate::scheduler::ToMatrix;

/// Build a genie TO matrix for one delay realization and target `k`.
///
/// The first `k` slots in global arrival order receive tasks `0..k` (all
/// distinct); remaining slots of each worker are filled with tasks not
/// yet present in that row (preserving the distinct-row invariant).
pub fn oracle_schedule(sample: &DelaySample, k: usize) -> ToMatrix {
    let (n, r) = (sample.n, sample.r);
    assert!(k >= 1 && k <= n, "target must satisfy 1 ≤ k ≤ n");
    assert!(k <= n * r, "not enough slots for k distinct tasks");

    // order all slots by arrival time (eq. 46)
    let mut slots: Vec<(f64, usize, usize)> = Vec::with_capacity(n * r);
    for i in 0..n {
        let mut prefix = 0.0;
        for j in 0..r {
            prefix += sample.comp(i, j);
            slots.push((prefix + sample.comm(i, j), i, j));
        }
    }
    slots.sort_by(|a, b| a.0.total_cmp(&b.0));

    // the first k slots carry k distinct tasks, in arrival order
    let mut rows: Vec<Vec<Option<usize>>> = vec![vec![None; r]; n];
    for (task, &(_, i, j)) in slots.iter().take(k).enumerate() {
        rows[i][j] = Some(task);
    }

    // fill remaining slots with tasks unused in that row
    let rows = rows
        .into_iter()
        .map(|row| {
            let mut used = vec![false; n];
            for t in row.iter().flatten() {
                used[*t] = true;
            }
            let mut free = (0..n).filter(|&t| !used[t]);
            row.into_iter()
                .map(|slot| slot.unwrap_or_else(|| free.next().expect("n ≥ r spare tasks")))
                .collect()
        })
        .collect();
    ToMatrix::new(n, rows)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::delay::{DelayModel, ShiftedExponential};
    use crate::util::rng::Rng;

    fn kth_slot_arrival(sample: &DelaySample, k: usize) -> f64 {
        let (n, r) = (sample.n, sample.r);
        let mut times: Vec<f64> = Vec::with_capacity(n * r);
        for i in 0..n {
            let mut prefix = 0.0;
            for j in 0..r {
                prefix += sample.comp(i, j);
                times.push(prefix + sample.comm(i, j));
            }
        }
        times.sort_by(f64::total_cmp);
        times[k - 1]
    }

    #[test]
    fn oracle_rows_are_valid() {
        let model = ShiftedExponential::new(0.1, 5.0, 0.2, 2.0);
        let mut rng = Rng::seed_from_u64(11);
        for (n, r, k) in [(4, 2, 3), (6, 6, 6), (5, 3, 1), (8, 4, 8)] {
            let s = model.sample(n, r, &mut rng);
            let c = oracle_schedule(&s, k);
            assert_eq!(c.n(), n);
            assert_eq!(c.r(), r);
            assert!(c.rows_distinct(), "n={n} r={r} k={k}");
        }
    }

    #[test]
    fn first_k_slots_carry_distinct_tasks() {
        let model = ShiftedExponential::new(0.0, 1.0, 0.0, 1.0);
        let mut rng = Rng::seed_from_u64(3);
        let (n, r, k) = (6, 3, 5);
        let s = model.sample(n, r, &mut rng);
        let c = oracle_schedule(&s, k);

        // recompute slot order, collect the tasks of the first k slots
        let mut slots: Vec<(f64, usize, usize)> = Vec::new();
        for i in 0..n {
            let mut prefix = 0.0;
            for j in 0..r {
                prefix += s.comp(i, j);
                slots.push((prefix + s.comm(i, j), i, j));
            }
        }
        slots.sort_by(|a, b| a.0.total_cmp(&b.0));
        let mut tasks: Vec<usize> = slots[..k].iter().map(|&(_, i, j)| c.task(i, j)).collect();
        tasks.sort_unstable();
        tasks.dedup();
        assert_eq!(tasks.len(), k, "first k slots must carry k distinct tasks");
    }

    #[test]
    fn completion_equals_kth_order_statistic() {
        // the constructive claim behind t_LB(T, r, k) = t̂_{T,(k)}
        let model = ShiftedExponential::new(0.05, 3.0, 0.1, 1.5);
        let mut rng = Rng::seed_from_u64(77);
        for trial in 0..200 {
            let (n, r) = (6, 4);
            let k = 1 + trial % n;
            let s = model.sample(n, r, &mut rng);
            let c = oracle_schedule(&s, k);
            let sim = crate::sim::simulate_round(&c, &s, k);
            let want = kth_slot_arrival(&s, k);
            assert!(
                (sim.completion_time - want).abs() < 1e-9,
                "trial {trial} k={k}: sim {} vs k-th stat {}",
                sim.completion_time,
                want
            );
        }
    }

    #[test]
    #[should_panic(expected = "target must satisfy")]
    fn rejects_zero_target(){
        let s = DelaySample::zeros(3, 2);
        oracle_schedule(&s, 0);
    }
}
