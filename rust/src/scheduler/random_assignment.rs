//! Random assignment (RA) — the uncoded baseline of [18] (paper §VI-B).
//!
//! Every worker holds the whole dataset (`r = n`) and picks tasks
//! without replacement, independently and uniformly at random: each row
//! of `C_RA` is an independent random permutation of `[n]` (Example 6).
//! Re-randomized every round, mirroring the per-iteration randomness of
//! the original scheme.  A generalized `r < n` variant (uniformly random
//! r-subset in random order) is provided for ablations.

use crate::util::rng::Rng;


use super::{Scheduler, ToMatrix};

#[derive(Debug, Clone, Copy, Default)]
pub struct RandomAssignment;

impl Scheduler for RandomAssignment {
    fn name(&self) -> &'static str {
        "RA"
    }

    fn schedule(&self, n: usize, r: usize, rng: &mut Rng) -> ToMatrix {
        let rows = (0..n)
            .map(|_| {
                let mut perm: Vec<usize> = (0..n).collect();
                rng.shuffle(&mut perm);
                perm.truncate(r);
                perm
            })
            .collect();
        ToMatrix::new(n, rows)
    }

    fn is_randomized(&self) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    

    #[test]
    fn rows_are_permutations_at_full_load() {
        let mut rng = Rng::seed_from_u64(42);
        let c = RandomAssignment.schedule(10, 10, &mut rng);
        for i in 0..10 {
            let mut row = c.row(i).to_vec();
            row.sort_unstable();
            assert_eq!(row, (0..10).collect::<Vec<_>>(), "worker {i}");
        }
    }

    #[test]
    fn truncated_load_keeps_distinct_rows() {
        let mut rng = Rng::seed_from_u64(7);
        let c = RandomAssignment.schedule(9, 4, &mut rng);
        assert_eq!(c.r(), 4);
        assert!(c.rows_distinct());
    }

    #[test]
    fn redraws_differ_across_calls() {
        let mut rng = Rng::seed_from_u64(1);
        let a = RandomAssignment.schedule(8, 8, &mut rng);
        let b = RandomAssignment.schedule(8, 8, &mut rng);
        assert_ne!(a, b, "consecutive draws should differ w.h.p.");
        assert!(RandomAssignment.is_randomized());
    }

    #[test]
    fn deterministic_under_same_seed() {
        let mut r1 = Rng::seed_from_u64(5);
        let mut r2 = Rng::seed_from_u64(5);
        assert_eq!(
            RandomAssignment.schedule(6, 6, &mut r1),
            RandomAssignment.schedule(6, 6, &mut r2)
        );
    }

    #[test]
    fn first_slots_roughly_uniform() {
        // over many draws, each task appears in slot 0 of worker 0 with
        // probability 1/n — a χ²-style sanity bound
        let mut rng = Rng::seed_from_u64(123);
        let n = 8;
        let trials = 8000;
        let mut counts = vec![0usize; n];
        for _ in 0..trials {
            let c = RandomAssignment.schedule(n, n, &mut rng);
            counts[c.task(0, 0)] += 1;
        }
        let expected = trials as f64 / n as f64;
        for (t, &c) in counts.iter().enumerate() {
            assert!(
                (c as f64 - expected).abs() < 5.0 * expected.sqrt(),
                "task {t}: {c} vs {expected}"
            );
        }
    }
}
