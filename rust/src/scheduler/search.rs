//! Local-search TO-matrix optimization — beyond the paper.
//!
//! The paper poses `t̄*(r,k) = min_C t̄_C(r,k)` (eq. 6) and calls a
//! general characterization "elusive", then hand-designs CS and SS.
//! This module attacks the minimization *numerically*: steepest-descent
//! local search over TO matrices, scoring candidates by Monte-Carlo
//! average completion time over a **fixed set of common random numbers**
//! (the same delay realizations for every candidate, so comparisons are
//! low-variance and the search is deterministic).
//!
//! Moves: swap two entries within a worker's row (order change) and
//! replace an entry with an unused task (assignment change) — together
//! they connect the whole space of distinct-row TO matrices.
//!
//! Used by the `straggler ablations` harness to quantify how much
//! headroom CS/SS leave on the table (answer in EXPERIMENTS.md: little —
//! supporting the paper's design).

use crate::delay::DelayModel;
use crate::scheduler::{CyclicScheduler, Scheduler, ToMatrix};
use crate::scheme::ToEvaluator;
use crate::sim::slot_arrivals_batch;
use crate::util::rng::Rng;

/// Configuration of the local search.
pub struct SearchConfig {
    /// delay realizations used as common random numbers
    pub crn_rounds: usize,
    /// maximum full neighbourhood sweeps
    pub max_sweeps: usize,
    /// random restarts (best of all kept)
    pub restarts: usize,
    pub seed: u64,
}

impl Default for SearchConfig {
    fn default() -> Self {
        Self {
            crn_rounds: 300,
            max_sweeps: 8,
            restarts: 2,
            seed: 0x5EA2C4,
        }
    }
}

/// Result of a search.
pub struct SearchOutcome {
    pub matrix: ToMatrix,
    /// CRN-estimated t̄ of the found matrix
    pub score: f64,
    /// CRN-estimated t̄ of the CS baseline (same realizations)
    pub cs_score: f64,
    pub evaluations: usize,
}

/// CRN scorer: the common random numbers live as **one** `DelayBatch`
/// whose slot-arrival times are precomputed a single time — candidate
/// TO matrices only change the slot→task mapping, never the arrivals,
/// so each of the search's hundreds of evaluations is a flat min-reduce
/// + selection over the cached arrival array instead of a fresh pass
/// over the delays.  Scoring dispatches through the scheme layer's
/// [`ToEvaluator`] (its `refill` + per-round kernel are exactly the old
/// `FlatTasks` + `completion_from_arrivals` pair), so search scores and
/// Monte-Carlo estimates share one completion kernel.
struct CrnScorer {
    rounds: usize,
    stride: usize,
    arrivals: Vec<f64>,
    eval: ToEvaluator,
}

impl CrnScorer {
    fn new(
        model: &dyn DelayModel,
        n: usize,
        r: usize,
        k: usize,
        rounds: usize,
        rng: &mut Rng,
    ) -> Self {
        let batch = model.sample_batch(rounds, n, r, rng);
        let mut arrivals = Vec::new();
        slot_arrivals_batch(&batch, &mut arrivals);
        Self {
            rounds,
            stride: n * r,
            arrivals,
            eval: ToEvaluator::new(&ToMatrix::new(n, vec![(0..r).collect(); n]), k),
        }
    }

    /// CRN-estimated `t̄` of one candidate (bit-identical to scoring it
    /// with `completion_time_fast` over the same realizations).
    fn score(&mut self, to: &ToMatrix) -> f64 {
        self.eval.refill(to);
        let mut total = 0.0;
        for b in 0..self.rounds {
            total += self
                .eval
                .completion_round(&self.arrivals[b * self.stride..(b + 1) * self.stride]);
        }
        total / self.rounds as f64
    }
}

/// Run the local search for `(n, r, k)` under `model`.
pub fn search(
    model: &dyn DelayModel,
    n: usize,
    r: usize,
    k: usize,
    cfg: &SearchConfig,
) -> SearchOutcome {
    assert!(k >= 1 && k <= n);
    let mut rng = Rng::seed_from_u64(cfg.seed);
    // common random numbers, sampled once as a batch (same RNG stream
    // as the old per-round sampling) and reduced to arrivals once
    let mut scorer = CrnScorer::new(model, n, r, k, cfg.crn_rounds, &mut rng);
    let mut evaluations = 0usize;

    let cs = CyclicScheduler.schedule(n, r, &mut rng);
    let cs_score = scorer.score(&cs);
    evaluations += 1;

    let mut best_rows = cs.rows().to_vec();
    let mut best_score = cs_score;

    for restart in 0..cfg.restarts.max(1) {
        // start from CS, or from a coverage-preserving randomization on
        // restarts: relabel tasks by a random permutation and shuffle
        // the worker<->row assignment (a random r-subset per row could
        // leave < k tasks covered and make the target unreachable)
        let mut rows: Vec<Vec<usize>> = if restart == 0 {
            cs.rows().to_vec()
        } else {
            let mut relabel: Vec<usize> = (0..n).collect();
            rng.shuffle(&mut relabel);
            let mut rows: Vec<Vec<usize>> = cs
                .rows()
                .iter()
                .map(|row| row.iter().map(|&t| relabel[t]).collect())
                .collect();
            rng.shuffle(&mut rows);
            rows
        };
        let mut cur = scorer.score(&ToMatrix::new(n, rows.clone()));
        evaluations += 1;

        for _sweep in 0..cfg.max_sweeps {
            let mut improved = false;
            for i in 0..n {
                // move 1: swap two slots in row i
                for a in 0..r {
                    for b in a + 1..r {
                        rows[i].swap(a, b);
                        let cand = scorer.score(&ToMatrix::new(n, rows.clone()));
                        evaluations += 1;
                        if cand + 1e-12 < cur {
                            cur = cand;
                            improved = true;
                        } else {
                            rows[i].swap(a, b); // revert
                        }
                    }
                }
                // move 2: replace a slot with a task unused in this row,
                // but never below k globally-covered tasks (the target
                // must stay reachable)
                for slot in 0..r {
                    let used: Vec<bool> = {
                        let mut u = vec![false; n];
                        for &t in &rows[i] {
                            u[t] = true;
                        }
                        u
                    };
                    let mut cov = vec![0usize; n];
                    for row in &rows {
                        for &t in row {
                            cov[t] += 1;
                        }
                    }
                    let covered = cov.iter().filter(|&&c| c > 0).count();
                    let original = rows[i][slot];
                    for t in 0..n {
                        if used[t] {
                            continue;
                        }
                        let new_covered =
                            covered - usize::from(cov[original] == 1) + usize::from(cov[t] == 0);
                        if new_covered < k {
                            continue;
                        }
                        rows[i][slot] = t;
                        let cand = scorer.score(&ToMatrix::new(n, rows.clone()));
                        evaluations += 1;
                        if cand + 1e-12 < cur {
                            cur = cand;
                            break;
                        }
                        rows[i][slot] = original;
                    }
                }
            }
            if !improved {
                break;
            }
        }
        if cur < best_score {
            best_score = cur;
            best_rows = rows;
        }
    }

    SearchOutcome {
        matrix: ToMatrix::new(n, best_rows),
        score: best_score,
        cs_score,
        evaluations,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::delay::{ShiftedExponential, TruncatedGaussianModel};
    use crate::sim::{simulate_round_with, SimScratch};
    use crate::scheduler::StaircaseScheduler;

    #[test]
    fn search_never_worse_than_cs_on_crn() {
        let model = TruncatedGaussianModel::scenario2(6, 3);
        let cfg = SearchConfig {
            crn_rounds: 120,
            max_sweeps: 3,
            restarts: 1,
            seed: 5,
        };
        let out = search(&model, 6, 3, 6, &cfg);
        assert!(out.score <= out.cs_score + 1e-12);
        assert!(out.matrix.rows_distinct());
        assert!(out.evaluations > 10);
    }

    #[test]
    fn search_finds_heterogeneity_aware_schedule() {
        // strongly heterogeneous workers: search should beat CS clearly
        // because CS ignores speed differences (it was designed for the
        // symmetric case — paper §IV-A)
        let model = TruncatedGaussianModel::scenario2(5, 11);
        let cfg = SearchConfig {
            crn_rounds: 200,
            max_sweeps: 4,
            restarts: 2,
            seed: 9,
        };
        let out = search(&model, 5, 2, 5, &cfg);
        assert!(
            out.score < out.cs_score,
            "search {} should beat CS {} under heterogeneity",
            out.score,
            out.cs_score
        );
    }

    #[test]
    fn searched_matrix_generalizes_off_crn() {
        // score on *fresh* realizations: searched C should remain at
        // least competitive with CS (no gross CRN overfit)
        let model = ShiftedExponential::new(0.05, 4.0, 0.2, 2.0);
        let cfg = SearchConfig {
            crn_rounds: 250,
            max_sweeps: 3,
            restarts: 1,
            seed: 31,
        };
        let (n, r, k) = (6, 3, 5);
        let out = search(&model, n, r, k, &cfg);
        let mut rng = Rng::seed_from_u64(777);
        let mut scratch = SimScratch::new();
        let cs = CyclicScheduler.schedule(n, r, &mut rng);
        let ss = StaircaseScheduler.schedule(n, r, &mut rng);
        let (mut t_found, mut t_cs, mut t_ss) = (0.0, 0.0, 0.0);
        for _ in 0..4000 {
            let s = model.sample(n, r, &mut rng);
            t_found += simulate_round_with(&out.matrix, &s, k, &mut scratch).completion_time;
            t_cs += simulate_round_with(&cs, &s, k, &mut scratch).completion_time;
            t_ss += simulate_round_with(&ss, &s, k, &mut scratch).completion_time;
        }
        assert!(
            t_found <= t_cs.min(t_ss) * 1.03,
            "searched {} vs CS {} / SS {}",
            t_found,
            t_cs,
            t_ss
        );
    }
}
