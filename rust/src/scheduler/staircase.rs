//! Staircase scheduling (SS) — paper §IV-B.
//!
//! `C_SS(i, j) = g(i + (−1)^{i−1}(j − 1))` (eq. 29): odd-indexed workers
//! (paper numbering) walk *forward* from their start task, even-indexed
//! workers walk *backward*.  Adjacent workers therefore sweep toward
//! each other — the "staircase" — which spreads early slots differently
//! from CS: a task that is late in one worker's queue is early in a
//! *neighbouring* worker's queue in the opposite direction.  Remark 5:
//! same step size as CS, alternating direction.

use crate::util::rng::Rng;

use super::{wrap, Scheduler, ToMatrix};

#[derive(Debug, Clone, Copy, Default)]
pub struct StaircaseScheduler;

impl Scheduler for StaircaseScheduler {
    fn name(&self) -> &'static str {
        "SS"
    }

    fn schedule(&self, n: usize, r: usize, _rng: &mut Rng) -> ToMatrix {
        let rows = (0..n)
            .map(|i| {
                // paper worker index is i+1; (−1)^{(i+1)−1} = +1 for even
                // 0-based i (ascending), −1 for odd (descending)
                let dir: i64 = if i % 2 == 0 { 1 } else { -1 };
                (0..r)
                    .map(|j| wrap(i as i64 + dir * j as i64, n))
                    .collect()
            })
            .collect();
        ToMatrix::new(n, rows)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    

    fn build(n: usize, r: usize) -> ToMatrix {
        let mut rng = Rng::seed_from_u64(0);
        StaircaseScheduler.schedule(n, r, &mut rng)
    }

    #[test]
    fn matches_paper_example_3() {
        // Example 3 (n = 4, r = 3), paper's 1-based C_SS:
        //   [1 2 3; 2 1 4; 3 4 1; 4 3 2]
        let c = build(4, 3);
        assert_eq!(
            c.rows(),
            &[vec![0, 1, 2], vec![1, 0, 3], vec![2, 3, 0], vec![3, 2, 1]]
        );
    }

    #[test]
    fn rows_distinct_for_all_loads() {
        for n in 1..=12 {
            for r in 1..=n {
                let c = build(n, r);
                assert!(c.rows_distinct(), "n={n} r={r}");
            }
        }
    }

    #[test]
    fn first_column_matches_cs() {
        // both schemes start worker i at task i (the diagonal)
        let c = build(9, 4);
        for i in 0..9 {
            assert_eq!(c.task(i, 0), i);
        }
    }

    #[test]
    fn alternating_directions() {
        let c = build(8, 3);
        for i in 0..8 {
            let step =
                (c.task(i, 1) as i64 - c.task(i, 0) as i64).rem_euclid(8);
            if i % 2 == 0 {
                assert_eq!(step, 1, "even worker {i} ascends");
            } else {
                assert_eq!(step, 7, "odd worker {i} descends");
            }
        }
    }

    #[test]
    fn even_n_uniform_coverage() {
        // for even n the ± directions tile tasks evenly: r per task
        let c = build(8, 5);
        assert!(c.covers_all_tasks());
        let cov = c.coverage();
        assert_eq!(cov.iter().sum::<usize>(), 8 * 5);
        assert!(cov.iter().all(|&x| x == 5), "{cov:?}");
    }

    #[test]
    fn odd_n_coverage_stays_within_one_of_r() {
        // odd n leaves a direction imbalance: coverage ∈ {r−1, r, r+1}
        for (n, r) in [(5usize, 3usize), (7, 4), (9, 2), (15, 6)] {
            let c = build(n, r);
            assert!(c.covers_all_tasks() || r == 1, "n={n} r={r}");
            let cov = c.coverage();
            assert_eq!(cov.iter().sum::<usize>(), n * r);
            for (t, &x) in cov.iter().enumerate() {
                assert!(
                    (x as i64 - r as i64).abs() <= 1,
                    "n={n} r={r} task {t} coverage {x}"
                );
            }
        }
    }

    #[test]
    fn differs_from_cs_when_r_ge_2() {
        use crate::scheduler::CyclicScheduler;
        let mut rng = Rng::seed_from_u64(0);
        for n in 3..=8 {
            for r in 2..=n {
                let ss = build(n, r);
                let cs = CyclicScheduler.schedule(n, r, &mut rng);
                assert_ne!(ss, cs, "n={n} r={r}");
            }
        }
    }
}
