//! Scheme evaluators for the paper's six baseline schemes, plus the
//! completion kernels they share.
//!
//! The kernels (`pc_completion`, `ingest_count`, `ingest_uncoded`)
//! moved here verbatim from the pre-refactor `harness/eval.rs` — every
//! floating-point operation, comparison and selection is unchanged, so
//! registry-dispatched estimates reproduce the old evaluator bit for
//! bit (`rust/tests/scheme_registry.rs`).

use crate::scheduler::{Scheduler, ToMatrix};
use crate::sim::{completion_from_arrivals, kth_arrival_from_arrivals, FlatTasks};
use crate::util::rng::Rng;

use super::{RoundView, SchemeEvaluator};

/// Evaluator for a **fixed** TO matrix (CS, SS, searched schedules):
/// rows flattened once, per round one min-reduce + selection over the
/// shared arrival array.  Also the CRN scorer of
/// [`crate::scheduler::search`] — `refill` swaps the candidate matrix
/// without touching the arrivals.
pub struct ToEvaluator {
    k: usize,
    flat: FlatTasks,
    task_times: Vec<f64>,
    pairs: Vec<(f64, usize)>,
    seen: Vec<bool>,
}

impl ToEvaluator {
    pub fn new(to: &ToMatrix, k: usize) -> Self {
        let flat = FlatTasks::new(to);
        let (n, r) = (flat.n(), flat.r());
        Self {
            k,
            flat,
            task_times: Vec::with_capacity(n),
            pairs: Vec::with_capacity(n * r),
            seen: Vec::with_capacity(n),
        }
    }

    /// Swap in a different matrix of the same shape (search hot path).
    pub fn refill(&mut self, to: &ToMatrix) {
        self.flat.refill(to);
    }

    /// Idealized completion of one round from its arrival slice.
    #[inline]
    pub fn completion_round(&mut self, arrivals: &[f64]) -> f64 {
        completion_from_arrivals(&self.flat, arrivals, self.k, &mut self.task_times)
    }

    /// Completion of one round under serialized master ingestion.
    pub fn completion_round_ingest(&mut self, arrivals: &[f64], ingest_ms: f64) -> f64 {
        ingest_uncoded(
            &self.flat,
            arrivals,
            self.k,
            ingest_ms,
            &mut self.pairs,
            &mut self.seen,
        )
    }
}

impl SchemeEvaluator for ToEvaluator {
    fn completion(&mut self, round: &RoundView<'_>, _rng_sched: &mut Rng) -> f64 {
        self.completion_round(round.arrivals)
    }

    fn completion_ingest(
        &mut self,
        round: &RoundView<'_>,
        ingest_ms: f64,
        _rng_sched: &mut Rng,
    ) -> f64 {
        self.completion_round_ingest(round.arrivals, ingest_ms)
    }
}

/// Evaluator for **randomized** schedulers (RA): a fresh TO matrix is
/// drawn from `rng_sched` every round (matching the paper, where RA
/// re-randomizes each DGD iteration) and refilled into an inner
/// [`ToEvaluator`], which supplies both completion kernels — one
/// implementation of the uncoded dynamics, not two.
pub struct RedrawEvaluator<S: Scheduler> {
    scheduler: S,
    n: usize,
    r: usize,
    k: usize,
    inner: Option<ToEvaluator>,
}

impl<S: Scheduler> RedrawEvaluator<S> {
    /// Draw this round's matrix into the reusable inner evaluator.
    fn redraw(&mut self, rng_sched: &mut Rng) -> &mut ToEvaluator {
        let to = self.scheduler.schedule(self.n, self.r, rng_sched);
        if let Some(ev) = self.inner.as_mut() {
            ev.refill(&to);
        } else {
            self.inner = Some(ToEvaluator::new(&to, self.k));
        }
        self.inner.as_mut().expect("filled above")
    }
}

impl<S: Scheduler> SchemeEvaluator for RedrawEvaluator<S> {
    fn completion(&mut self, round: &RoundView<'_>, rng_sched: &mut Rng) -> f64 {
        self.redraw(rng_sched).completion_round(round.arrivals)
    }

    fn completion_ingest(
        &mut self,
        round: &RoundView<'_>,
        ingest_ms: f64,
        rng_sched: &mut Rng,
    ) -> f64 {
        self.redraw(rng_sched)
            .completion_round_ingest(round.arrivals, ingest_ms)
    }
}

/// Build the right evaluator for any [`Scheduler`] — fixed schedules
/// are drawn from `rng_sched` once **here** (in caller order, exactly
/// like the pre-refactor engines), randomized ones redraw per round.
/// This is the adapter [`crate::sim::MonteCarlo`] drives its
/// `&dyn Scheduler` slices through.
pub fn evaluator_for_scheduler<'a, S: Scheduler + 'a>(
    scheduler: S,
    n: usize,
    r: usize,
    k: usize,
    rng_sched: &mut Rng,
) -> Box<dyn SchemeEvaluator + 'a> {
    if scheduler.is_randomized() {
        Box::new(RedrawEvaluator {
            scheduler,
            n,
            r,
            k,
            inner: None,
        })
    } else {
        Box::new(ToEvaluator::new(&scheduler.schedule(n, r, rng_sched), k))
    }
}

/// Evaluator for PC's single-message timing (eqs. 51–52): per worker
/// the comp-row sum plus the last slot's comm delay, completed at the
/// `2⌈n/r⌉ − 1`-th order statistic.
pub struct PcEvaluator {
    n: usize,
    r: usize,
    threshold: usize,
    scratch: Vec<f64>,
    pairs: Vec<(f64, usize)>,
}

impl PcEvaluator {
    pub fn new(n: usize, r: usize) -> Self {
        assert!(r >= 2, "PC requires computation load r ≥ 2 (paper Table I)");
        Self {
            n,
            r,
            threshold: 2 * n.div_ceil(r) - 1,
            scratch: Vec::with_capacity(n),
            pairs: Vec::with_capacity(n),
        }
    }
}

impl SchemeEvaluator for PcEvaluator {
    fn completion(&mut self, round: &RoundView<'_>, _rng_sched: &mut Rng) -> f64 {
        pc_completion(
            round.comp,
            round.comm,
            self.n,
            self.r,
            self.threshold,
            &mut self.scratch,
        )
    }

    fn completion_ingest(
        &mut self,
        round: &RoundView<'_>,
        ingest_ms: f64,
        _rng_sched: &mut Rng,
    ) -> f64 {
        let (n, r) = (self.n, self.r);
        self.pairs.clear();
        for i in 0..n {
            let comp_sum: f64 = round.comp[i * r..(i + 1) * r].iter().sum();
            self.pairs.push((comp_sum + round.comm[i * r + r - 1], 0));
        }
        ingest_count(&mut self.pairs, self.threshold, ingest_ms)
    }
}

/// Evaluator completing at the `threshold`-th smallest **slot arrival**
/// over all `n·r` slots — PCMM (`threshold = 2n − 1`, eqs. 56–57) and
/// the §V genie bound (`threshold = k`, eq. 46) are both this kernel.
pub struct SlotOrderStatEvaluator {
    threshold: usize,
    scratch: Vec<f64>,
    pairs: Vec<(f64, usize)>,
}

impl SlotOrderStatEvaluator {
    pub fn new(threshold: usize) -> Self {
        assert!(threshold >= 1, "order-statistic threshold must be ≥ 1");
        Self {
            threshold,
            scratch: Vec::new(),
            pairs: Vec::new(),
        }
    }
}

impl SchemeEvaluator for SlotOrderStatEvaluator {
    fn completion(&mut self, round: &RoundView<'_>, _rng_sched: &mut Rng) -> f64 {
        kth_arrival_from_arrivals(round.arrivals, self.threshold, &mut self.scratch)
    }

    fn completion_ingest(
        &mut self,
        round: &RoundView<'_>,
        ingest_ms: f64,
        _rng_sched: &mut Rng,
    ) -> f64 {
        self.pairs.clear();
        self.pairs.extend(round.arrivals.iter().map(|&t| (t, 0)));
        ingest_count(&mut self.pairs, self.threshold, ingest_ms)
    }
}

/// PC completion (eqs. 51–52) from one round's comp/comm rows: worker
/// `i` finishes at `Σ_{j<r} comp(i,j) + comm(i, r−1)` (all `r` tasks,
/// one message); the round completes at the threshold-th order
/// statistic across workers.  Mirrors `PcScheme::completion_time` on
/// the batch's flat storage.
pub fn pc_completion(
    comp: &[f64],
    comm: &[f64],
    n: usize,
    r: usize,
    threshold: usize,
    scratch: &mut Vec<f64>,
) -> f64 {
    scratch.clear();
    for i in 0..n {
        let comp_sum: f64 = comp[i * r..(i + 1) * r].iter().sum();
        scratch.push(comp_sum + comm[i * r + r - 1]);
    }
    let (_, kth, _) = scratch.select_nth_unstable_by(threshold - 1, |a, b| a.total_cmp(b));
    *kth
}

/// Completion under a serialized ingestion queue, stopping at the
/// `count`-th processed message.  For LB the queue only sees the useful
/// messages, so sort first and sweep the earliest `count`.
pub fn ingest_count(arrivals: &mut [(f64, usize)], count: usize, s: f64) -> f64 {
    arrivals.sort_unstable_by(|a, b| a.0.total_cmp(&b.0));
    let mut busy = 0.0f64;
    for (idx, &(t, _)) in arrivals.iter().enumerate() {
        busy = busy.max(t) + s;
        if idx + 1 == count {
            return busy;
        }
    }
    unreachable!("count exceeds message stream")
}

/// Uncoded completion with ingestion: the master processes *every*
/// arriving message (duplicates included) in arrival order; the round
/// ends when the k-th distinct task finishes ingestion.  Message
/// arrival times come from the shared per-round arrival array; the TO
/// matrix only supplies the task tags.  `pairs` and `seen` are
/// caller-owned scratch (this sits in the per-round ingestion loop —
/// no allocation here).
pub fn ingest_uncoded(
    tasks: &FlatTasks,
    round_arrivals: &[f64],
    k: usize,
    s: f64,
    pairs: &mut Vec<(f64, usize)>,
    seen: &mut Vec<bool>,
) -> f64 {
    let n = tasks.n();
    pairs.clear();
    pairs.extend(
        round_arrivals
            .iter()
            .zip(tasks.tasks())
            .map(|(&t, &task)| (t, task)),
    );
    pairs.sort_unstable_by(|a, b| a.0.total_cmp(&b.0));
    let mut busy = 0.0f64;
    seen.clear();
    seen.resize(n, false);
    let mut distinct = 0usize;
    for &(t, task) in pairs.iter() {
        busy = busy.max(t) + s;
        if !seen[task] {
            seen[task] = true;
            distinct += 1;
            if distinct == k {
                return busy;
            }
        }
    }
    panic!("TO matrix covers fewer than k distinct tasks");
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coded::PcScheme;
    use crate::delay::{DelayModel, TruncatedGaussianModel};

    #[test]
    fn pc_completion_matches_coded_module_kernel() {
        // the scheme layer's slice-based PC kernel must stay
        // bit-identical to PcScheme::completion_time, or figure PC
        // curves silently drift from the coded module's ground truth
        let (n, r) = (9usize, 4usize);
        let model = TruncatedGaussianModel::scenario2(n, 8);
        let mut rng = crate::util::rng::Rng::seed_from_u64(2);
        let pc = PcScheme::new(n, r);
        let mut coded_scratch: Vec<f64> = Vec::new();
        let mut flat_scratch: Vec<f64> = Vec::new();
        for _ in 0..64 {
            let sample = model.sample(n, r, &mut rng);
            let coded = pc.completion_time(&sample, &mut coded_scratch);
            let flat = pc_completion(
                sample.comp_flat(),
                sample.comm_flat(),
                n,
                r,
                pc.recovery_threshold(),
                &mut flat_scratch,
            );
            assert_eq!(coded.to_bits(), flat.to_bits());
        }
    }

    #[test]
    fn pc_evaluator_threshold_matches_coded_module() {
        for (n, r) in [(4usize, 2usize), (8, 4), (15, 15), (9, 3)] {
            let ev = PcEvaluator::new(n, r);
            assert_eq!(ev.threshold, PcScheme::new(n, r).recovery_threshold());
        }
    }

    #[test]
    fn ingest_count_serializes_queue() {
        // three messages at t = 1, 1, 5 with 2 ms ingestion: the second
        // queues behind the first (3 + 2 = 5), the third starts at its
        // own arrival
        let mut q = vec![(5.0, 0), (1.0, 0), (1.0, 0)];
        assert_eq!(ingest_count(&mut q.clone(), 1, 2.0), 3.0);
        assert_eq!(ingest_count(&mut q.clone(), 2, 2.0), 5.0);
        assert_eq!(ingest_count(&mut q, 3, 2.0), 7.0);
    }
}
