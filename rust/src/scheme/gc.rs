//! Grouped multi-message cyclic scheduling — `GC(s)`.
//!
//! The multi-message gradient-coding literature (Ozfatura, Ulukus &
//! Gündüz, arXiv:2004.04948) trades communication for computation by
//! letting each worker send a **partial sum every `s` completed tasks**
//! instead of one message per task.  `GC(s)` brings that family into
//! this codebase's uncoded-task framework:
//!
//! * assignment/order: the cyclic TO matrix (CS, eq. 21) — every task
//!   sits early in *some* worker's queue, so partial flushes stay
//!   useful;
//! * communication: worker `i` flushes one message after slots
//!   `s−1, 2s−1, …` (and a final flush at slot `r−1` for the ragged
//!   tail).  A flushed message delivers the **group** of tasks computed
//!   since the previous flush, and arrives at the flush slot's arrival
//!   time `Σ_{m ≤ j_f} T⁽¹⁾ + T⁽²⁾_{j_f}` (eq. 1 applied to the flush
//!   slot — the worker holds finished results until the flush, and the
//!   message rides the flush slot's communication delay);
//! * completion: unchanged §II rule — earliest time `k` distinct tasks
//!   have been delivered.
//!
//! `GC(1)` flushes every slot and is **bit-identical** to CS (pinned by
//! `rust/tests/scheme_registry.rs` and a proptest).  Larger `s` delays
//! deliveries (stochastically — a group's tasks all ride the flush
//! slot's comm draw) but cuts the master's message load by `s×`, which
//! pays off under the serialized-ingestion testbed model
//! ([`crate::harness::EC2_INGEST_MS`]): fewer queue slots per round.
//! `straggler fig8` sweeps the tradeoff.

use crate::scheduler::{CyclicScheduler, Scheduler, ToMatrix};
use crate::sim::FlatTasks;
use crate::util::rng::Rng;

use super::{RoundView, Scheme, SchemeEvaluator, SchemeId};

/// The `GC(s)` scheme descriptor: cyclic assignment, one message per
/// `s` completed tasks.
#[derive(Debug, Clone, Copy)]
pub struct GcScheme {
    /// Group size `s ≥ 1`; `s = 1` degenerates to CS.
    pub s: usize,
}

impl GcScheme {
    /// `s = 0` is constructible (so `applicable` can report it invalid
    /// instead of panicking) but rejected by `applicable`/`prepare`.
    pub fn new(s: usize) -> Self {
        Self { s }
    }
}

impl Scheme for GcScheme {
    fn id(&self) -> SchemeId {
        SchemeId::Gc(self.s as u32)
    }

    fn applicable(&self, _n: usize, r: usize, _k: usize) -> bool {
        // a group larger than the row never flushes mid-row and is just
        // a mislabeled GC(r); reject it so sweeps stay unambiguous
        self.s >= 1 && self.s <= r
    }

    fn prepare(
        &self,
        n: usize,
        r: usize,
        k: usize,
        rng_sched: &mut Rng,
    ) -> Box<dyn SchemeEvaluator> {
        let to = CyclicScheduler.schedule(n, r, rng_sched);
        Box::new(GcEvaluator::new(&to, self.s, k))
    }
}

/// Prepared `GC(s)` evaluator: the cyclic rows flattened once, plus a
/// per-slot map to the (global index of the) flush slot that delivers
/// each slot's result.  Per round this is the same min-reduce +
/// selection as the CS kernel, just reading each slot's arrival through
/// the flush map — at `s = 1` the map is the identity and the kernel
/// reproduces [`crate::sim::completion_from_arrivals`] bit for bit.
pub struct GcEvaluator {
    n: usize,
    k: usize,
    tasks: FlatTasks,
    /// global slot index of the message delivering each slot's result
    flush_of: Vec<usize>,
    /// start slot (global) of each flush group, in flush-arrival layout
    groups: Vec<usize>,
    task_times: Vec<f64>,
    pairs: Vec<(f64, usize)>,
    seen: Vec<bool>,
}

impl GcEvaluator {
    pub fn new(to: &ToMatrix, s: usize, k: usize) -> Self {
        Self::with_sizes(to, &vec![s; to.n()], k)
    }

    /// Per-worker flush sizes `sizes[i]` — the heterogeneity-aware
    /// generalization ([`super::gc_het::GcHetScheme`]); uniform sizes
    /// reproduce [`GcEvaluator::new`] exactly.
    pub fn with_sizes(to: &ToMatrix, sizes: &[usize], k: usize) -> Self {
        let (n, r) = (to.n(), to.r());
        assert_eq!(sizes.len(), n, "need one flush size per worker");
        assert!(
            sizes.iter().all(|&s| s >= 1 && s <= r),
            "GC group size must satisfy 1 ≤ s ≤ r"
        );
        assert!(k >= 1 && k <= n, "computation target must satisfy 1 ≤ k ≤ n");
        let tasks = FlatTasks::new(to);
        let mut flush_of = Vec::with_capacity(n * r);
        let mut groups = Vec::new();
        for (i, &s) in sizes.iter().enumerate() {
            let base = i * r;
            let mut start = 0usize;
            while start < r {
                let end = (start + s).min(r);
                groups.push(base + start);
                for _ in start..end {
                    flush_of.push(base + end - 1);
                }
                start = end;
            }
        }
        debug_assert_eq!(flush_of.len(), n * r);
        Self {
            n,
            k,
            tasks,
            flush_of,
            groups,
            task_times: Vec::with_capacity(n),
            pairs: Vec::with_capacity(n * r),
            seen: Vec::with_capacity(n),
        }
    }

    /// Messages per round (`n · ⌈r/s⌉` — the `s×` communication saving).
    pub fn messages_per_round(&self) -> usize {
        self.groups.len()
    }
}

impl SchemeEvaluator for GcEvaluator {
    fn completion(&mut self, round: &RoundView<'_>, _rng_sched: &mut Rng) -> f64 {
        // identical loop shape to `completion_from_arrivals`, with each
        // slot's arrival read through the flush map
        let (n, k) = (self.n, self.k);
        let arrivals = round.arrivals;
        debug_assert_eq!(arrivals.len(), self.flush_of.len());
        self.task_times.clear();
        self.task_times.resize(n, f64::INFINITY);
        for (slot, &task) in self.tasks.tasks().iter().enumerate() {
            let arrival = arrivals[self.flush_of[slot]];
            if arrival < self.task_times[task] {
                self.task_times[task] = arrival;
            }
        }
        let (_, kth, _) = self
            .task_times
            .select_nth_unstable_by(k - 1, |a, b| a.total_cmp(b));
        let t = *kth;
        assert!(
            t.is_finite(),
            "TO matrix covers fewer than k = {k} distinct tasks"
        );
        t
    }

    fn completion_ingest(
        &mut self,
        round: &RoundView<'_>,
        ingest_ms: f64,
        _rng_sched: &mut Rng,
    ) -> f64 {
        // the master's queue sees one entry per *message*; each message
        // delivers its whole group when processed.  The group layout is
        // read back from the precomputed flush map: a group spans
        // `start ..= flush_of[start]`.
        let (n, k) = (self.n, self.k);
        let arrivals = round.arrivals;
        self.pairs.clear();
        for &start in &self.groups {
            self.pairs.push((arrivals[self.flush_of[start]], start));
        }
        self.pairs.sort_unstable_by(|a, b| a.0.total_cmp(&b.0));
        let mut busy = 0.0f64;
        self.seen.clear();
        self.seen.resize(n, false);
        let mut distinct = 0usize;
        for &(t, start) in self.pairs.iter() {
            busy = busy.max(t) + ingest_ms;
            for slot in start..=self.flush_of[start] {
                let task = self.tasks.tasks()[slot];
                if !self.seen[task] {
                    self.seen[task] = true;
                    distinct += 1;
                    if distinct == k {
                        return busy;
                    }
                }
            }
        }
        panic!("TO matrix covers fewer than k distinct tasks");
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::delay::{DelayModel, TruncatedGaussianModel};
    use crate::scheme::exec::ToEvaluator;
    use crate::sim::slot_arrivals_batch;

    fn round_views(
        batch: &crate::delay::DelayBatch,
        arrivals: &[f64],
        b: usize,
    ) -> (Vec<f64>, Vec<f64>, Vec<f64>) {
        let stride = batch.stride();
        (
            arrivals[b * stride..(b + 1) * stride].to_vec(),
            batch.comp_round(b).to_vec(),
            batch.comm_round(b).to_vec(),
        )
    }

    #[test]
    fn flush_map_layout() {
        let mut rng = Rng::seed_from_u64(0);
        let to = CyclicScheduler.schedule(5, 5, &mut rng);
        let ev = GcEvaluator::new(&to, 2, 3);
        // r = 5, s = 2: groups [0,1], [2,3], [4]; flush slots 1, 3, 4
        assert_eq!(&ev.flush_of[0..5], &[1, 1, 3, 3, 4]);
        assert_eq!(&ev.flush_of[5..10], &[6, 6, 8, 8, 9]);
        assert_eq!(ev.messages_per_round(), 5 * 3);
        let ev1 = GcEvaluator::new(&to, 1, 3);
        assert_eq!(ev1.flush_of, (0..25).collect::<Vec<_>>());
        assert_eq!(ev1.messages_per_round(), 25);
    }

    #[test]
    fn gc1_bit_identical_to_cs_kernel_both_modes() {
        let (n, r, k) = (7usize, 5usize, 6usize);
        let model = TruncatedGaussianModel::scenario2(n, 4);
        let mut rng = Rng::seed_from_u64(11);
        let batch = model.sample_batch(24, n, r, &mut rng);
        let mut arrivals = Vec::new();
        slot_arrivals_batch(&batch, &mut arrivals);
        let mut rng_sched = Rng::seed_from_u64(0);
        let to = CyclicScheduler.schedule(n, r, &mut rng_sched);
        let mut cs = ToEvaluator::new(&to, k);
        let mut gc = GcEvaluator::new(&to, 1, k);
        let mut dummy = Rng::seed_from_u64(0);
        for b in 0..batch.rounds {
            let (arr, comp, comm) = round_views(&batch, &arrivals, b);
            let view = RoundView {
                arrivals: &arr,
                comp: &comp,
                comm: &comm,
            };
            let a = cs.completion(&view, &mut dummy);
            let g = gc.completion(&view, &mut dummy);
            assert_eq!(a.to_bits(), g.to_bits(), "round {b}");
            let ai = cs.completion_ingest(&view, 0.15, &mut dummy);
            let gi = gc.completion_ingest(&view, 0.15, &mut dummy);
            assert_eq!(ai.to_bits(), gi.to_bits(), "ingest round {b}");
        }
    }

    /// n = 4, r = 4, cyclic rows; worker 0 is fast (comp 1, comm 0.5
    /// → arrivals 1.5, 2.5, 3.5, 4.5), workers 1–3 are very slow, so
    /// with k = 1 only worker 0's flush schedule matters.
    fn fast_worker_fixture() -> (ToMatrix, Vec<f64>, Vec<f64>, Vec<f64>) {
        let mut rng = Rng::seed_from_u64(0);
        let to = CyclicScheduler.schedule(4, 4, &mut rng);
        let mut comp = vec![100.0; 16];
        let mut comm = vec![0.5; 16];
        comp[0..4].copy_from_slice(&[1.0; 4]);
        comm[0..4].copy_from_slice(&[0.5; 4]);
        let mut arrivals = Vec::with_capacity(16);
        for i in 0..4 {
            let mut prefix = 0.0;
            for j in 0..4 {
                prefix += comp[i * 4 + j];
                arrivals.push(prefix + comm[i * 4 + j]);
            }
        }
        (to, arrivals, comp, comm)
    }

    #[test]
    fn grouping_defers_deliveries_on_fixture() {
        // CS delivers worker 0's first task at 1.5; GC(2) holds it
        // until slot 1 flushes at 2 + 0.5 = 2.5; GC(4) until slot 3
        // flushes at 4.5.
        let (to, arrivals, comp, comm) = fast_worker_fixture();
        let view = RoundView {
            arrivals: &arrivals,
            comp: &comp,
            comm: &comm,
        };
        let mut dummy = Rng::seed_from_u64(0);
        for (s, want) in [(1usize, 1.5f64), (2, 2.5), (3, 3.5), (4, 4.5)] {
            let mut ev = GcEvaluator::new(&to, s, 1);
            assert_eq!(ev.completion(&view, &mut dummy), want, "s = {s}");
        }
    }

    #[test]
    fn ingest_charges_per_message_not_per_task() {
        // ingest 10 ms dominates; with k = 1 the first processed
        // message decides.  GC(1): worker 0's slot-0 message at 1.5 →
        // 11.5.  GC(4): worker 0's single 4-task message at 4.5 → 14.5.
        let (to, arrivals, comp, comm) = fast_worker_fixture();
        let view = RoundView {
            arrivals: &arrivals,
            comp: &comp,
            comm: &comm,
        };
        let mut dummy = Rng::seed_from_u64(0);
        let mut gc1 = GcEvaluator::new(&to, 1, 1);
        let mut gc4 = GcEvaluator::new(&to, 4, 1);
        assert_eq!(gc1.completion_ingest(&view, 10.0, &mut dummy), 11.5);
        assert_eq!(gc4.completion_ingest(&view, 10.0, &mut dummy), 14.5);
    }

    #[test]
    fn per_worker_sizes_generalize_uniform() {
        let mut rng = Rng::seed_from_u64(0);
        let to = CyclicScheduler.schedule(4, 4, &mut rng);
        let het = GcEvaluator::with_sizes(&to, &[1, 2, 2, 4], 2);
        // worker 0 flushes every slot; worker 3 once at the row end
        assert_eq!(&het.flush_of[0..4], &[0, 1, 2, 3]);
        assert_eq!(&het.flush_of[4..8], &[5, 5, 7, 7]);
        assert_eq!(&het.flush_of[12..16], &[15, 15, 15, 15]);
        assert_eq!(het.messages_per_round(), 4 + 2 + 2 + 1);
        let uni = GcEvaluator::with_sizes(&to, &[2; 4], 3);
        let direct = GcEvaluator::new(&to, 2, 3);
        assert_eq!(uni.flush_of, direct.flush_of);
        assert_eq!(uni.groups, direct.groups);
    }

    #[test]
    fn applicability_bounds_group_by_row_length() {
        assert!(GcScheme::new(1).applicable(8, 1, 8));
        assert!(GcScheme::new(4).applicable(8, 4, 8));
        assert!(!GcScheme::new(5).applicable(8, 4, 8));
    }
}
