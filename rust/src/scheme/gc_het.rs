//! Heterogeneity-aware grouped cyclic scheduling — `GCH(s_fast,
//! s_slow)`.
//!
//! The uniform `GC(s)` family flushes every worker at the same cadence,
//! but on a heterogeneous cluster that is the wrong trade at both ends:
//! a fast worker's groups fill quickly, so batching them further
//! (larger `s`) cuts the master's ingestion load at almost no added
//! latency, while a straggler's half-filled group strands the few
//! results it *did* finish behind a flush that may never come — it
//! should stream eagerly (smaller `s`).  This mirrors the
//! service-rate-proportional task-allocation intuition of Behrouzi-Far
//! & Soljanin (arXiv:1808.02838): match each worker's communication
//! pattern to its speed rather than treating the fleet as exchangeable.
//!
//! `GCH(s_fast, s_slow)` assigns worker `i` the flush size linearly
//! interpolated from `s_fast` at worker 0 down (or up) to `s_slow` at
//! worker `n − 1`, under the convention that **lower worker indices
//! are faster** — sort workers by measured service rate before mapping
//! them onto indices (the delay models here are exchangeable-per-index,
//! so the convention is a labeling, not a constraint).  Assignment and
//! completion are unchanged cyclic / `k`-distinct; only the per-worker
//! flush cadence varies, so the scheme rides the same
//! [`GcEvaluator`](super::gc::GcEvaluator) kernel via
//! [`GcEvaluator::with_sizes`](super::gc::GcEvaluator::with_sizes).
//!
//! `GCH(s, s)` is exactly `GC(s)`; `straggler sim --schemes
//! "GCH(4,1)"` sweeps it against the uniform family.

use crate::scheduler::{CyclicScheduler, Scheduler};
use crate::util::rng::Rng;

use super::gc::GcEvaluator;
use super::{Scheme, SchemeEvaluator, SchemeId};

/// The `GCH(s_fast, s_slow)` scheme descriptor.
#[derive(Debug, Clone, Copy)]
pub struct GcHetScheme {
    /// Flush size of worker 0 (the fastest, by convention).
    pub s_fast: usize,
    /// Flush size of worker `n − 1` (the slowest).
    pub s_slow: usize,
}

impl GcHetScheme {
    /// Like `GC(s)`, out-of-range sizes are constructible so
    /// `applicable` can report them invalid instead of panicking.
    pub fn new(s_fast: usize, s_slow: usize) -> Self {
        Self { s_fast, s_slow }
    }

    /// Per-worker flush sizes: the rounded linear ramp from `s_fast`
    /// (worker 0) to `s_slow` (worker `n − 1`).
    pub fn sizes(&self, n: usize) -> Vec<usize> {
        assert!(n >= 1);
        if n == 1 {
            return vec![self.s_fast];
        }
        let (a, b) = (self.s_fast as f64, self.s_slow as f64);
        (0..n)
            .map(|i| {
                let t = i as f64 / (n - 1) as f64;
                (a + (b - a) * t).round() as usize
            })
            .collect()
    }

    /// Live-cluster flush layout: `(canonical block, per-worker
    /// sizes)`.  The canonical block is `max(s_fast, s_slow)` — the
    /// block size of the master's duplicate-safe range merge — and
    /// every ramp size is snapped **down** to its largest divisor
    /// ([`crate::adaptive::snap_divisor`]), so each worker's aligned
    /// flush ranges nest inside one canonical block and
    /// [`crate::coordinator::aggregate::RoundAggregator`] can merge
    /// them across workers.  The Monte-Carlo engines keep the exact
    /// (unsnapped) ramp; the restriction is the price of mergeable
    /// partial sums on the wire and is documented in EXPERIMENTS.md
    /// §Adaptive.
    pub fn cluster_sizes(&self, n: usize) -> (usize, Vec<usize>) {
        let canonical = self.s_fast.max(self.s_slow).max(1);
        let sizes = self
            .sizes(n)
            .into_iter()
            .map(|s| crate::adaptive::snap_divisor(canonical, s))
            .collect();
        (canonical, sizes)
    }
}

impl Scheme for GcHetScheme {
    fn id(&self) -> SchemeId {
        SchemeId::GcHet(self.s_fast as u32, self.s_slow as u32)
    }

    fn applicable(&self, _n: usize, r: usize, _k: usize) -> bool {
        // both endpoints in [1, r] keeps every interpolated size in
        // range (the ramp is monotone between its endpoints)
        self.s_fast >= 1 && self.s_slow >= 1 && self.s_fast <= r && self.s_slow <= r
    }

    fn prepare(
        &self,
        n: usize,
        r: usize,
        k: usize,
        rng_sched: &mut Rng,
    ) -> Box<dyn SchemeEvaluator> {
        let to = CyclicScheduler.schedule(n, r, rng_sched);
        Box::new(GcEvaluator::with_sizes(&to, &self.sizes(n), k))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ramp_interpolates_inclusive_endpoints() {
        let s = GcHetScheme::new(4, 1);
        assert_eq!(s.sizes(4), vec![4, 3, 2, 1]);
        assert_eq!(s.sizes(2), vec![4, 1]);
        assert_eq!(s.sizes(1), vec![4]);
        // ascending ramps work too (slow workers batching more);
        // f64::round ties go away from zero: 1.5 → 2, 2.5 → 3
        assert_eq!(GcHetScheme::new(1, 3).sizes(5), vec![1, 2, 2, 3, 3]);
        // degenerate ramp = uniform GC(s)
        assert_eq!(GcHetScheme::new(2, 2).sizes(6), vec![2; 6]);
    }

    #[test]
    fn cluster_sizes_are_divisors_of_the_canonical_block() {
        // GCH(4,1) at n = 4: exact ramp [4, 3, 2, 1]; 3 ∤ 4 snaps to 2
        let (canonical, sizes) = GcHetScheme::new(4, 1).cluster_sizes(4);
        assert_eq!(canonical, 4);
        assert_eq!(sizes, vec![4, 2, 2, 1]);
        // ascending ramps snap too, canonical is the larger endpoint
        let (canonical, sizes) = GcHetScheme::new(1, 6).cluster_sizes(4);
        assert_eq!(canonical, 6);
        assert!(sizes.iter().all(|&s| 6 % s == 0), "{sizes:?}");
        assert_eq!(*sizes.first().unwrap(), 1);
        assert_eq!(*sizes.last().unwrap(), 6);
        // a flat ramp is untouched
        let (canonical, sizes) = GcHetScheme::new(3, 3).cluster_sizes(5);
        assert_eq!((canonical, sizes), (3, vec![3; 5]));
    }

    #[test]
    fn applicability_bounds_both_endpoints() {
        assert!(GcHetScheme::new(4, 1).applicable(8, 4, 8));
        assert!(GcHetScheme::new(1, 1).applicable(8, 1, 8));
        assert!(!GcHetScheme::new(5, 1).applicable(8, 4, 8));
        assert!(!GcHetScheme::new(1, 5).applicable(8, 4, 8));
        assert!(!GcHetScheme::new(0, 2).applicable(8, 4, 8));
    }

    #[test]
    fn degenerate_ramp_matches_uniform_gc_kernel() {
        use super::super::gc::GcScheme;
        use super::super::RoundView;
        use crate::delay::{DelayModel, TruncatedGaussianModel};
        use crate::sim::slot_arrivals_batch;

        let (n, r, k) = (6usize, 4usize, 5usize);
        let model = TruncatedGaussianModel::scenario2(n, 9);
        let mut rng = Rng::seed_from_u64(17);
        let batch = model.sample_batch(16, n, r, &mut rng);
        let mut arrivals = Vec::new();
        slot_arrivals_batch(&batch, &mut arrivals);
        let mut rng_a = Rng::seed_from_u64(1);
        let mut rng_b = Rng::seed_from_u64(1);
        let mut het = GcHetScheme::new(2, 2).prepare(n, r, k, &mut rng_a);
        let mut uni = GcScheme::new(2).prepare(n, r, k, &mut rng_b);
        let stride = n * r;
        let mut dummy = Rng::seed_from_u64(0);
        for b in 0..batch.rounds {
            let view = RoundView {
                arrivals: &arrivals[b * stride..(b + 1) * stride],
                comp: batch.comp_round(b),
                comm: batch.comm_round(b),
            };
            assert_eq!(
                het.completion(&view, &mut dummy).to_bits(),
                uni.completion(&view, &mut dummy).to_bits(),
                "round {b}"
            );
            assert_eq!(
                het.completion_ingest(&view, 0.15, &mut dummy).to_bits(),
                uni.completion_ingest(&view, 0.15, &mut dummy).to_bits(),
                "ingest round {b}"
            );
        }
    }
}
