//! Unified scheme-execution layer — the paper's "scheme" as a
//! first-class, pluggable object.
//!
//! A *scheme* is an assignment + execution order + completion rule
//! (paper Table I).  Before this layer existed, each scheme was a set
//! of hardcoded `SchemeId` match arms scattered across the harness, the
//! Monte-Carlo engine, the search, the lower bound and the coordinator,
//! with completion semantics re-implemented per call site.  This module
//! collapses all of that into one contract:
//!
//! * [`Scheme`] — constructor + paper-Table-I applicability; its
//!   [`Scheme::prepare`] returns a reusable per-chunk evaluator, so all
//!   setup (TO-matrix construction, `FlatTasks` flattening, coded
//!   order-statistic thresholds, group layouts) happens **once**, never
//!   in the per-round hot loop;
//! * [`SchemeEvaluator`] — "given the precomputed `slot_arrivals` of a
//!   [`DelayBatch`] chunk, produce per-round completion times",
//!   preserving the bit-identity contract of [`crate::sim::batch`]
//!   (same prefix-sum order, same min comparisons, same
//!   `select_nth_unstable_by`);
//! * [`run_rounds`] — the single chunked shard loop every batched
//!   engine drives (harness evaluator, `MonteCarlo`, the §V lower
//!   bound), so the delay-stream layout can never drift between them;
//!   its sequential re-planning counterpart is
//!   [`crate::adaptive::run_policy_rounds`], which keeps the same
//!   sampling layout and kernels but re-plans between rounds;
//! * [`registry::SchemeRegistry`] — construction, applicability rules,
//!   display names, CLI parsing, and the live-cluster execution plan
//!   ([`ClusterPlan`]) consumed by [`crate::coordinator`].
//!
//! Adding a scheme is now one `impl Scheme` (see `EXPERIMENTS.md`
//! §Schemes for the walkthrough); the grouped multi-message family
//! [`gc::GcScheme`] is the reference example.

pub mod exec;
pub mod gc;
pub mod gc_het;
pub mod registry;

pub use exec::{
    evaluator_for_scheduler, PcEvaluator, RedrawEvaluator, SlotOrderStatEvaluator, ToEvaluator,
};
pub use gc::GcScheme;
pub use gc_het::GcHetScheme;
pub use registry::SchemeRegistry;

use crate::delay::{DelayBatch, DelayModel};
use crate::scheduler::Scheduler;
use crate::sim::{chunk_rounds, slot_arrivals_batch};
use crate::util::rng::Rng;

/// Scheme identifier used across harness, reports, configs and CLI — a
/// thin name/ordering type; all behavior lives behind
/// [`SchemeRegistry::build`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum SchemeId {
    /// Cyclic scheduling (paper §IV-A).
    Cs,
    /// Staircase scheduling (paper §IV-B).
    Ss,
    /// Random assignment baseline of [18] (r = n).
    Ra,
    /// Polynomially coded regression timing (Li et al. [13]).
    Pc,
    /// Polynomially coded multi-message timing (Ozfatura et al. [17]).
    Pcmm,
    /// The §V genie lower bound.
    Lb,
    /// Grouped multi-message cyclic: one partial-sum message every `s`
    /// completed tasks (arXiv:2004.04948-style communication–
    /// computation tradeoff); degenerates to CS at `s = 1`.
    Gc(u32),
    /// Heterogeneity-aware grouped cyclic: per-worker flush sizes
    /// ramping from `s_fast` (worker 0) to `s_slow` (worker n−1) —
    /// see [`gc_het::GcHetScheme`].
    GcHet(u32, u32),
}

impl std::fmt::Display for SchemeId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SchemeId::Cs => f.write_str("CS"),
            SchemeId::Ss => f.write_str("SS"),
            SchemeId::Ra => f.write_str("RA"),
            SchemeId::Pc => f.write_str("PC"),
            SchemeId::Pcmm => f.write_str("PCMM"),
            SchemeId::Lb => f.write_str("LB"),
            SchemeId::Gc(s) => write!(f, "GC({s})"),
            SchemeId::GcHet(a, b) => write!(f, "GCH({a},{b})"),
        }
    }
}

/// One round's view of a sampled [`DelayBatch`] chunk: the precomputed
/// slot-arrival times (`n·r` values — [`slot_arrivals_batch`]) plus the
/// raw per-slot delay rows the arrivals were derived from (PC's
/// single-message timing needs the comp sums directly).
pub struct RoundView<'a> {
    /// Arrival time of every slot, `i·r + j` layout (eq. 1).
    pub arrivals: &'a [f64],
    /// Computation delays of every slot, same layout.
    pub comp: &'a [f64],
    /// Communication delays of every slot, same layout.
    pub comm: &'a [f64],
}

/// A scheme constructor + its paper-Table-I applicability rules.
///
/// Implementations are cheap, stateless descriptors; all per-run state
/// lives in the evaluator returned by [`Scheme::prepare`].
pub trait Scheme: Send + Sync {
    /// The thin identifier (also the display name via `Display`).
    fn id(&self) -> SchemeId;

    /// Paper-Table-I applicability at an `(n, r, k)` point — e.g.
    /// `PC ⇒ r ≥ 2, k = n`; `RA ⇒ r = n`; `GC(s) ⇒ s ≤ r`.
    fn applicable(&self, n: usize, r: usize, k: usize) -> bool;

    /// Build a reusable per-chunk evaluator for this `(n, r, k)` point.
    ///
    /// All construction-time randomness (fixed schedules) must be drawn
    /// from `rng_sched` **here**, in the order schemes are prepared —
    /// that is what keeps registry-dispatched runs bit-identical to the
    /// pre-refactor engines (randomized schemes draw per round inside
    /// the evaluator instead).
    fn prepare(&self, n: usize, r: usize, k: usize, rng_sched: &mut Rng)
        -> Box<dyn SchemeEvaluator>;
}

/// The per-round completion kernel of a prepared scheme.
///
/// Contract: given one round's [`RoundView`] over the shared arrival
/// array, produce the round's completion time with **exactly** the
/// floating-point operations of the pre-refactor kernels (bit-identity
/// is pinned by `rust/tests/scheme_registry.rs` and
/// `rust/tests/batch_engine.rs`).  Dispatch cost is one virtual call
/// per round per scheme; everything else was hoisted into `prepare`.
pub trait SchemeEvaluator {
    /// Idealized eq. (1)–(2) completion from the shared arrival array.
    fn completion(&mut self, round: &RoundView<'_>, rng_sched: &mut Rng) -> f64;

    /// Completion under the serialized master-ingestion queue
    /// (`ingest_ms` per processed message — the testbed model of
    /// [`crate::harness::EC2_INGEST_MS`]).
    fn completion_ingest(
        &mut self,
        round: &RoundView<'_>,
        ingest_ms: f64,
        rng_sched: &mut Rng,
    ) -> f64;
}

/// The shared chunked shard loop of every batched engine: sample delays
/// in [`DelayBatch`] chunks, compute every slot's arrival **once** per
/// chunk, evaluate all prepared schemes against that shared array, and
/// emit `(scheme_idx, t)` per round per scheme in scheme order.
///
/// `rng` drives delay sampling; `rng_sched` drives per-round scheduling
/// randomness (RA redraws).  Chunking, reallocation and RNG consumption
/// mirror the pre-refactor loops exactly, so the delay stream seen for
/// a fixed `(rounds, seed)` is unchanged.
#[allow(clippy::too_many_arguments)]
pub fn run_rounds<'a>(
    evaluators: &mut [Box<dyn SchemeEvaluator + 'a>],
    model: &dyn DelayModel,
    n: usize,
    r: usize,
    rounds: usize,
    ingest_ms: f64,
    rng: &mut Rng,
    rng_sched: &mut Rng,
    emit: &mut dyn FnMut(usize, f64),
) {
    let stride = n * r;
    // fleet-aware chunking: same round-sequential delay stream for any
    // chunk size, but bounded per-shard memory at n = 10_000 scale
    let cap = chunk_rounds(n, r);
    let mut batch = DelayBatch::zeros(cap.min(rounds.max(1)), n, r);
    let mut arrivals: Vec<f64> = Vec::new();
    let mut done = 0usize;
    while done < rounds {
        let chunk = cap.min(rounds - done);
        if batch.rounds != chunk {
            batch = DelayBatch::zeros(chunk, n, r);
        }
        model.sample_batch_into(&mut batch, rng);
        slot_arrivals_batch(&batch, &mut arrivals);
        for b in 0..chunk {
            let view = RoundView {
                arrivals: &arrivals[b * stride..(b + 1) * stride],
                comp: batch.comp_round(b),
                comm: batch.comm_round(b),
            };
            for (idx, ev) in evaluators.iter_mut().enumerate() {
                let t = if ingest_ms == 0.0 {
                    ev.completion(&view, rng_sched)
                } else {
                    ev.completion_ingest(&view, ingest_ms, rng_sched)
                };
                emit(idx, t);
            }
        }
        done += chunk;
    }
}

/// How the live cluster master decides a round is complete.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CompletionRule {
    /// Stop at `k` distinct task results (uncoded §II rule; `k` is the
    /// cluster config's computation target).
    DistinctTasks,
    /// Stop after `threshold` received messages (the coded
    /// order-statistic rule — PC's `2⌈n/r⌉ − 1`, PCMM's `2n − 1`).
    Messages { threshold: usize },
}

/// What travels on the wire and how the master consumes it — the
/// scheme-native data-plane half of a [`ClusterPlan`] (protocol v3).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WirePlan {
    /// Plain partitions; each flushed message carries the aggregated
    /// partial sum of its task range, merged duplicate-safe by
    /// [`crate::coordinator::aggregate::RoundAggregator`].  `align`
    /// moves worker flush points to canonical task-space boundaries so
    /// ranges from different workers can tile (required whenever
    /// `group > 1`).
    Uncoded { align: bool },
    /// Master-encoded PC matrices (Li et al. [13]): one aggregated
    /// message per worker is the polynomial evaluation `φ(x_i)`; the
    /// master interpolates at the recovery threshold with
    /// [`crate::coded::PcScheme::decode`] and applies the full-gradient
    /// update.
    Pc,
    /// Master-encoded PCMM matrices (Ozfatura et al. [17]): each
    /// streamed message is one evaluation `ψ(β_{i,j})`; decoded at
    /// `2n − 1` with [`crate::coded::PcmmScheme::decode`].
    Pcmm,
}

/// How the live cluster executes a scheme — the coordinator-side
/// counterpart of [`Scheme::prepare`], built by
/// [`SchemeRegistry::cluster_plan`] so the socketed master/worker and
/// the simulator consume one source of truth.
pub struct ClusterPlan {
    /// TO-matrix builder for per-round assignments (uncoded wire; the
    /// coded wires fix their own slot assignment).
    pub scheduler: Box<dyn Scheduler>,
    /// Canonical flush block: workers flush one result message per
    /// `group` completed tasks (1 = the paper's immediate streaming;
    /// `s` for GC(s); `r` for PC's single message per worker).  This is
    /// also the canonical block size of the master's duplicate-safe
    /// range merge ([`crate::coordinator::aggregate`]).
    pub group: usize,
    /// Per-worker flush sizes (heterogeneous cadence — GCH and the
    /// `load` policy); `None` = every worker uses `group`.  Every entry
    /// must divide `group`, so each worker's aligned flush ranges nest
    /// inside one canonical block and cross-worker merging stays
    /// duplicate-safe.
    pub groups: Option<Vec<usize>>,
    /// Round-completion rule the master enforces.
    pub rule: CompletionRule,
    /// Payload semantics of the result stream.
    pub wire: WirePlan,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scheme_id_display() {
        assert_eq!(SchemeId::Cs.to_string(), "CS");
        assert_eq!(SchemeId::Pcmm.to_string(), "PCMM");
        assert_eq!(SchemeId::Gc(1).to_string(), "GC(1)");
        assert_eq!(SchemeId::Gc(12).to_string(), "GC(12)");
    }

    #[test]
    fn gc_ids_compare_by_group() {
        assert_eq!(SchemeId::Gc(2), SchemeId::Gc(2));
        assert_ne!(SchemeId::Gc(2), SchemeId::Gc(3));
        assert_ne!(SchemeId::Gc(1), SchemeId::Cs, "GC(1) ≡ CS in law, not in name");
    }
}
