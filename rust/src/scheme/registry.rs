//! The scheme registry: one place that owns scheme construction,
//! paper-Table-I applicability, display names, CLI/config parsing, and
//! the live-cluster execution plan.
//!
//! Everything that used to be a `SchemeId` match arm scattered across
//! `harness/`, `config/`, `main.rs` and `coordinator/` now dispatches
//! through here, so adding a scheme is: implement [`Scheme`] in one
//! file, add one `build` arm (and a `parse` spelling), done — the
//! Monte-Carlo engines, figures, CLI, configs and cluster pick it up.

use anyhow::{anyhow, bail, Result};

use crate::adaptive::PolicyKind;
use crate::scheduler::{CyclicScheduler, RandomAssignment, Scheduler, StaircaseScheduler};
use crate::util::rng::Rng;

use super::exec::{evaluator_for_scheduler, PcEvaluator, SlotOrderStatEvaluator};
use super::gc::GcScheme;
use super::gc_het::GcHetScheme;
use super::{ClusterPlan, CompletionRule, Scheme, SchemeEvaluator, SchemeId, WirePlan};

/// Namespace for scheme construction and lookup (stateless — schemes
/// are cheap descriptors built on demand from their [`SchemeId`]).
pub struct SchemeRegistry;

impl SchemeRegistry {
    /// Construct the scheme behind an id.
    pub fn build(id: SchemeId) -> Box<dyn Scheme> {
        match id {
            SchemeId::Cs => Box::new(CsScheme),
            SchemeId::Ss => Box::new(SsScheme),
            SchemeId::Ra => Box::new(RaScheme),
            SchemeId::Pc => Box::new(PcTimingScheme),
            SchemeId::Pcmm => Box::new(PcmmTimingScheme),
            SchemeId::Lb => Box::new(GenieScheme),
            SchemeId::Gc(s) => Box::new(GcScheme::new(s as usize)),
            SchemeId::GcHet(a, b) => Box::new(GcHetScheme::new(a as usize, b as usize)),
        }
    }

    /// Paper-Table-I applicability of `id` at an `(n, r, k)` point.
    pub fn applicable(id: SchemeId, n: usize, r: usize, k: usize) -> bool {
        Self::build(id).applicable(n, r, k)
    }

    /// The paper's six baseline schemes, in figure order.
    pub fn default_schemes() -> Vec<SchemeId> {
        vec![
            SchemeId::Cs,
            SchemeId::Ss,
            SchemeId::Ra,
            SchemeId::Pc,
            SchemeId::Pcmm,
            SchemeId::Lb,
        ]
    }

    /// Parse a scheme name as spelled in configs and on the CLI:
    /// `CS | SS | RA | PC | PCMM | LB | GC(s) | GCs | GCH(a,b)`
    /// (case-insensitive).
    pub fn parse(name: &str) -> Result<SchemeId> {
        let upper = name.trim().to_uppercase();
        Ok(match upper.as_str() {
            "CS" => SchemeId::Cs,
            "SS" => SchemeId::Ss,
            "RA" => SchemeId::Ra,
            "PC" => SchemeId::Pc,
            "PCMM" => SchemeId::Pcmm,
            "LB" => SchemeId::Lb,
            other => {
                // GCH before GC — "GCH(…)" also starts with "GC"
                if let Some(rest) = other.strip_prefix("GCH") {
                    let inner = rest
                        .strip_prefix('(')
                        .and_then(|s| s.strip_suffix(')'))
                        .filter(|s| !s.contains('(') && !s.contains(')'))
                        .ok_or_else(|| {
                            anyhow!("malformed GCH spelling {name:?}; want GCH(s_fast,s_slow)")
                        })?;
                    let (a, b) = inner.split_once(',').ok_or_else(|| {
                        anyhow!("GCH needs two sizes, GCH(s_fast,s_slow); got {name:?}")
                    })?;
                    let parse_size = |d: &str| -> Result<u32> {
                        let s: u32 = d.trim().parse().map_err(|_| {
                            anyhow!("bad GCH group size in {name:?}; want GCH(a,b), a,b ≥ 1")
                        })?;
                        if s == 0 {
                            bail!("GCH group sizes must be ≥ 1, got {name:?}");
                        }
                        Ok(s)
                    };
                    return Ok(SchemeId::GcHet(parse_size(a)?, parse_size(b)?));
                }
                let Some(rest) = other.strip_prefix("GC") else {
                    bail!("unknown scheme {name:?} (CS|SS|RA|PC|PCMM|LB|GC(s)|GCH(a,b))");
                };
                // exactly `GCs` or `GC(s)` — unbalanced/doubled parens
                // are user errors, not group sizes
                let digits = match rest.strip_prefix('(') {
                    Some(inner) => inner
                        .strip_suffix(')')
                        .filter(|d| !d.contains('(') && !d.contains(')'))
                        .ok_or_else(|| anyhow!("malformed GC spelling {name:?}; want GC(s)"))?,
                    None => rest,
                };
                let s: u32 = digits
                    .parse()
                    .map_err(|_| anyhow!("bad GC group size in {name:?}; want GC(s), s ≥ 1"))?;
                if s == 0 {
                    bail!("GC group size must be ≥ 1, got {name:?}");
                }
                SchemeId::Gc(s)
            }
        })
    }

    /// Parse a comma-separated scheme list (the CLI's `--schemes`
    /// grammar), keeping commas *inside parentheses* intact so
    /// `CS,GCH(4,1),LB` splits into three schemes, not four fragments.
    pub fn parse_list(list: &str) -> Result<Vec<SchemeId>> {
        let mut segments: Vec<String> = vec![String::new()];
        let mut depth = 0usize;
        for ch in list.chars() {
            match ch {
                ',' if depth == 0 => segments.push(String::new()),
                _ => {
                    match ch {
                        '(' => depth += 1,
                        ')' => depth = depth.saturating_sub(1),
                        _ => {}
                    }
                    segments.last_mut().expect("nonempty").push(ch);
                }
            }
        }
        segments.iter().map(|s| Self::parse(s)).collect()
    }

    /// Build the live-cluster execution plan for a scheme at `(n, r, k)`
    /// — the coordinator-side counterpart of [`Scheme::prepare`].
    ///
    /// Since protocol v3 the plan is fully scheme-native: uncoded
    /// schemes aggregate partial sums on the wire (GC(s) additionally
    /// aligns flushes to canonical blocks so the master can merge
    /// ranges across workers), and the coded schemes (PC/PCMM) ship
    /// master-encoded polynomial evaluations that the master *decodes*
    /// with [`crate::coded`] at the recovery threshold, updating θ —
    /// no more timing-only rounds (see EXPERIMENTS.md §Schemes).  The
    /// genie bound has no constructive live execution.
    pub fn cluster_plan(id: SchemeId, n: usize, r: usize, k: usize) -> Result<ClusterPlan> {
        if !Self::applicable(id, n, r, k) {
            bail!("{id} is not applicable at (n = {n}, r = {r}, k = {k}) — paper Table I");
        }
        Ok(match id {
            SchemeId::Cs => uncoded_plan(Box::new(CyclicScheduler), 1),
            SchemeId::Ss => uncoded_plan(Box::new(StaircaseScheduler), 1),
            SchemeId::Ra => uncoded_plan(Box::new(RandomAssignment), 1),
            SchemeId::Gc(s) => uncoded_plan(Box::new(CyclicScheduler), s as usize),
            SchemeId::GcHet(a, b) => {
                // per-worker flush sizes, snapped to divisors of the
                // canonical block so every aligned flush range nests
                // inside one block and the master's duplicate-safe
                // range merge works across cadences (the restriction
                // that unlocked GCH on the live cluster)
                let (canonical, sizes) =
                    GcHetScheme::new(a as usize, b as usize).cluster_sizes(n);
                ClusterPlan {
                    scheduler: Box::new(CyclicScheduler),
                    group: canonical,
                    groups: Some(sizes),
                    rule: CompletionRule::DistinctTasks,
                    wire: WirePlan::Uncoded {
                        align: canonical > 1,
                    },
                }
            }
            SchemeId::Pc => ClusterPlan {
                scheduler: Box::new(CyclicScheduler),
                group: r,
                groups: None,
                rule: CompletionRule::Messages {
                    threshold: 2 * n.div_ceil(r) - 1,
                },
                wire: WirePlan::Pc,
            },
            SchemeId::Pcmm => ClusterPlan {
                scheduler: Box::new(CyclicScheduler),
                group: 1,
                groups: None,
                rule: CompletionRule::Messages { threshold: 2 * n - 1 },
                wire: WirePlan::Pcmm,
            },
            SchemeId::Lb => bail!(
                "LB is a genie bound with no live execution; replay \
                 scheduler::oracle_schedule offline instead"
            ),
        })
    }

    /// Build the live-cluster plan for `(scheme, policy)` — the entry
    /// point of the adaptive subsystem's cluster side
    /// ([`crate::adaptive`]).  `static` defers to
    /// [`SchemeRegistry::cluster_plan`] unchanged; the re-planning
    /// policies are restricted to the uncoded data plane (the coded
    /// wires fix their own assignment and decode threshold) and to
    /// schemes with a fixed base plan the policy can permute.
    pub fn adaptive_plan(
        id: SchemeId,
        policy: PolicyKind,
        n: usize,
        r: usize,
        k: usize,
    ) -> Result<ClusterPlan> {
        let plan = Self::cluster_plan(id, n, r, k)?;
        // one shared gate with the Monte-Carlo arm: uncoded fixed base,
        // alloc-group r | n, alloc-random r = n
        policy.validate_base(id, n, r)?;
        Ok(plan)
    }
}

fn uncoded_plan(scheduler: Box<dyn Scheduler>, group: usize) -> ClusterPlan {
    ClusterPlan {
        scheduler,
        group,
        groups: None,
        rule: CompletionRule::DistinctTasks,
        // flushes larger than one task must align to canonical blocks
        // for the master's duplicate-safe range merge
        wire: WirePlan::Uncoded { align: group > 1 },
    }
}

/// Cyclic scheduling, any `1 ≤ r, k ≤ n` (paper Table I row 1).
struct CsScheme;

impl Scheme for CsScheme {
    fn id(&self) -> SchemeId {
        SchemeId::Cs
    }

    fn applicable(&self, _n: usize, _r: usize, _k: usize) -> bool {
        true
    }

    fn prepare(
        &self,
        n: usize,
        r: usize,
        k: usize,
        rng_sched: &mut Rng,
    ) -> Box<dyn SchemeEvaluator> {
        evaluator_for_scheduler(CyclicScheduler, n, r, k, rng_sched)
    }
}

/// Staircase scheduling, any `1 ≤ r, k ≤ n`.
struct SsScheme;

impl Scheme for SsScheme {
    fn id(&self) -> SchemeId {
        SchemeId::Ss
    }

    fn applicable(&self, _n: usize, _r: usize, _k: usize) -> bool {
        true
    }

    fn prepare(
        &self,
        n: usize,
        r: usize,
        k: usize,
        rng_sched: &mut Rng,
    ) -> Box<dyn SchemeEvaluator> {
        evaluator_for_scheduler(StaircaseScheduler, n, r, k, rng_sched)
    }
}

/// Random assignment — the [18] baseline requires the full dataset at
/// every worker (`r = n`).
struct RaScheme;

impl Scheme for RaScheme {
    fn id(&self) -> SchemeId {
        SchemeId::Ra
    }

    fn applicable(&self, n: usize, r: usize, _k: usize) -> bool {
        r == n
    }

    fn prepare(
        &self,
        n: usize,
        r: usize,
        k: usize,
        rng_sched: &mut Rng,
    ) -> Box<dyn SchemeEvaluator> {
        evaluator_for_scheduler(RandomAssignment, n, r, k, rng_sched)
    }
}

/// PC timing — `r ≥ 2`, full-gradient only (`k = n`), paper Table I.
struct PcTimingScheme;

impl Scheme for PcTimingScheme {
    fn id(&self) -> SchemeId {
        SchemeId::Pc
    }

    fn applicable(&self, n: usize, r: usize, k: usize) -> bool {
        r >= 2 && k == n
    }

    fn prepare(
        &self,
        n: usize,
        r: usize,
        _k: usize,
        _rng_sched: &mut Rng,
    ) -> Box<dyn SchemeEvaluator> {
        Box::new(PcEvaluator::new(n, r))
    }
}

/// PCMM timing — `r ≥ 2`, `k = n`; completes at the `(2n − 1)`-th slot
/// arrival.
struct PcmmTimingScheme;

impl Scheme for PcmmTimingScheme {
    fn id(&self) -> SchemeId {
        SchemeId::Pcmm
    }

    fn applicable(&self, n: usize, r: usize, k: usize) -> bool {
        // n·r ≥ 2n − 1 evaluation slots are needed to ever decode;
        // implied by r ≥ 2 for n ≥ 1
        r >= 2 && k == n
    }

    fn prepare(
        &self,
        n: usize,
        _r: usize,
        _k: usize,
        _rng_sched: &mut Rng,
    ) -> Box<dyn SchemeEvaluator> {
        Box::new(SlotOrderStatEvaluator::new(2 * n - 1))
    }
}

/// The §V genie lower bound: the k-th smallest slot arrival.
struct GenieScheme;

impl Scheme for GenieScheme {
    fn id(&self) -> SchemeId {
        SchemeId::Lb
    }

    fn applicable(&self, _n: usize, _r: usize, _k: usize) -> bool {
        true
    }

    fn prepare(
        &self,
        _n: usize,
        _r: usize,
        k: usize,
        _rng_sched: &mut Rng,
    ) -> Box<dyn SchemeEvaluator> {
        Box::new(SlotOrderStatEvaluator::new(k))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_accepts_all_spellings() {
        assert_eq!(SchemeRegistry::parse("cs").unwrap(), SchemeId::Cs);
        assert_eq!(SchemeRegistry::parse("PCMM").unwrap(), SchemeId::Pcmm);
        assert_eq!(SchemeRegistry::parse(" lb ").unwrap(), SchemeId::Lb);
        assert_eq!(SchemeRegistry::parse("GC(3)").unwrap(), SchemeId::Gc(3));
        assert_eq!(SchemeRegistry::parse("gc4").unwrap(), SchemeId::Gc(4));
        assert_eq!(SchemeRegistry::parse("GCH(4,1)").unwrap(), SchemeId::GcHet(4, 1));
        assert_eq!(SchemeRegistry::parse("gch(2, 3)").unwrap(), SchemeId::GcHet(2, 3));
    }

    #[test]
    fn parse_rejects_junk() {
        for bad in [
            "", "XX", "GC", "GC(0)", "GC(-1)", "GC(two)", "GC(2", "GC2)", "GC((2))", "GC()",
            "GCH", "GCH2", "GCH(2)", "GCH(2,)", "GCH(,2)", "GCH(0,2)", "GCH(2,0)",
            "GCH(2,3", "GCH((2,3))", "GCH(2;3)",
        ] {
            assert!(SchemeRegistry::parse(bad).is_err(), "{bad:?}");
        }
    }

    #[test]
    fn parse_list_is_paren_aware() {
        assert_eq!(
            SchemeRegistry::parse_list("CS,GCH(4,1),GC(2),LB").unwrap(),
            vec![
                SchemeId::Cs,
                SchemeId::GcHet(4, 1),
                SchemeId::Gc(2),
                SchemeId::Lb
            ]
        );
        assert_eq!(
            SchemeRegistry::parse_list("pcmm").unwrap(),
            vec![SchemeId::Pcmm]
        );
        for bad in ["", "CS,,LB", "CS,GCH(4,1", "GCH(4,1),"] {
            assert!(SchemeRegistry::parse_list(bad).is_err(), "{bad:?}");
        }
    }

    #[test]
    fn display_roundtrips_through_parse() {
        let mut ids = SchemeRegistry::default_schemes();
        ids.push(SchemeId::Gc(1));
        ids.push(SchemeId::Gc(7));
        ids.push(SchemeId::GcHet(4, 1));
        ids.push(SchemeId::GcHet(2, 2));
        for id in ids {
            assert_eq!(SchemeRegistry::parse(&id.to_string()).unwrap(), id);
        }
    }

    #[test]
    fn cluster_plan_rules_match_table1() {
        let p = SchemeRegistry::cluster_plan(SchemeId::Gc(2), 4, 4, 4).unwrap();
        assert_eq!(p.group, 2);
        assert_eq!(p.rule, CompletionRule::DistinctTasks);
        assert_eq!(p.wire, WirePlan::Uncoded { align: true });

        let p = SchemeRegistry::cluster_plan(SchemeId::Gc(1), 4, 4, 4).unwrap();
        assert_eq!(
            p.wire,
            WirePlan::Uncoded { align: false },
            "single-task flushes need no alignment"
        );

        let p = SchemeRegistry::cluster_plan(SchemeId::Ss, 4, 2, 3).unwrap();
        assert_eq!(p.group, 1);
        assert_eq!(p.wire, WirePlan::Uncoded { align: false });

        let p = SchemeRegistry::cluster_plan(SchemeId::Pcmm, 4, 2, 4).unwrap();
        assert_eq!(p.group, 1);
        assert_eq!(p.rule, CompletionRule::Messages { threshold: 7 });
        assert_eq!(p.wire, WirePlan::Pcmm);

        let p = SchemeRegistry::cluster_plan(SchemeId::Pc, 8, 4, 8).unwrap();
        assert_eq!(p.group, 4, "PC sends one message per worker");
        assert_eq!(p.rule, CompletionRule::Messages { threshold: 3 });
        assert_eq!(p.wire, WirePlan::Pc);

        assert!(SchemeRegistry::cluster_plan(SchemeId::Lb, 4, 2, 4).is_err());
        assert!(
            SchemeRegistry::cluster_plan(SchemeId::Ra, 4, 3, 4).is_err(),
            "RA needs r = n"
        );
        assert!(
            SchemeRegistry::cluster_plan(SchemeId::Pc, 4, 4, 2).is_err(),
            "coded schemes are k = n only"
        );
    }

    #[test]
    fn gch_cluster_plan_is_unlocked_with_divisor_sizes() {
        let p = SchemeRegistry::cluster_plan(SchemeId::GcHet(4, 1), 4, 4, 4).unwrap();
        assert_eq!(p.group, 4, "canonical block is the larger endpoint");
        assert_eq!(p.rule, CompletionRule::DistinctTasks);
        assert_eq!(p.wire, WirePlan::Uncoded { align: true });
        let sizes = p.groups.expect("per-worker sizes");
        assert_eq!(sizes, vec![4, 2, 2, 1], "ramp snapped to divisors of 4");

        // degenerate flat ramp = uniform GC(s): same canonical block
        let p = SchemeRegistry::cluster_plan(SchemeId::GcHet(2, 2), 6, 4, 6).unwrap();
        assert_eq!(p.group, 2);
        assert_eq!(p.groups, Some(vec![2; 6]));

        // applicability unchanged: endpoints must fit the row
        assert!(SchemeRegistry::cluster_plan(SchemeId::GcHet(5, 1), 4, 4, 4).is_err());
    }

    #[test]
    fn adaptive_plan_gates_policies_by_wire_and_base() {
        use crate::adaptive::PolicyKind;
        // static defers to cluster_plan for every scheme
        let p = SchemeRegistry::adaptive_plan(SchemeId::Pcmm, PolicyKind::Static, 4, 2, 4);
        assert!(p.is_ok());
        // re-planning policies: uncoded fixed-base schemes only
        for policy in [PolicyKind::AdaptiveOrder, PolicyKind::AdaptiveLoad] {
            assert!(SchemeRegistry::adaptive_plan(SchemeId::Gc(2), policy, 6, 6, 6).is_ok());
            assert!(SchemeRegistry::adaptive_plan(SchemeId::Ss, policy, 6, 3, 6).is_ok());
            assert!(
                SchemeRegistry::adaptive_plan(SchemeId::Pc, policy, 6, 3, 6).is_err(),
                "coded wire rejects {policy}"
            );
            assert!(
                SchemeRegistry::adaptive_plan(SchemeId::Ra, policy, 6, 6, 6).is_err(),
                "randomized base rejects {policy}"
            );
            assert!(
                SchemeRegistry::adaptive_plan(SchemeId::GcHet(2, 1), policy, 6, 6, 6).is_err(),
                "GCH is a static load layout"
            );
        }
        assert!(
            SchemeRegistry::adaptive_plan(SchemeId::Cs, PolicyKind::AllocGroup, 6, 4, 6).is_err(),
            "alloc-group needs r | n"
        );
        let ok = SchemeRegistry::adaptive_plan(SchemeId::Cs, PolicyKind::AllocGroup, 6, 3, 6);
        assert!(ok.is_ok());
        let ok = SchemeRegistry::adaptive_plan(SchemeId::Cs, PolicyKind::AllocRandom, 6, 6, 6);
        assert!(ok.is_ok());
        assert!(
            SchemeRegistry::adaptive_plan(SchemeId::Cs, PolicyKind::AllocRandom, 6, 3, 6).is_err(),
            "alloc-random needs r = n"
        );
    }
}
