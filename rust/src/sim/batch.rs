//! Batched structure-of-arrays completion kernels — the inner loops of
//! the batched Monte-Carlo engine.
//!
//! The scalar hot path ([`super::completion_time_fast`]) recomputes the
//! per-slot arrival times `Σ_{m≤j} comp(i,m) + comm(i,j)` (eq. 1) for
//! **every scheme** it evaluates, and chases `Vec<Vec<usize>>` rows of
//! the TO matrix in its inner loop.  The batched kernels here fix both:
//!
//! * [`slot_arrivals_batch`] computes the arrival times of **all**
//!   `B × n × r` slots of a [`DelayBatch`] once — arrivals depend only
//!   on the delays, not on the schedule, so every coupled scheme (and
//!   the §V lower bound) reuses the same array without re-reading the
//!   delay stream;
//! * [`FlatTasks`] hoists a TO matrix's row indices into one contiguous
//!   `n·r` array once per batch, turning the per-round task lookup into
//!   a linear walk of a flat slice;
//! * [`completion_from_arrivals`] is the per-round min-reduce + k-th
//!   order-statistic selection over one precomputed arrival slice.
//!
//! **Bit-identity contract** (tested in `rust/tests/batch_engine.rs`):
//! for any TO matrix, delays and `k`, [`completion_times_batch`]
//! produces exactly the bits of [`super::completion_time_fast`] on the
//! per-round samples — same prefix-sum order, same min comparisons,
//! same `select_nth_unstable_by` — so the batched engine reproduces the
//! scalar engine's estimates exactly.

use crate::delay::DelayBatch;
use crate::scheduler::ToMatrix;

/// A TO matrix's row indices flattened into one contiguous array:
/// slot `(i, j)` at `i·r + j`.  Built once per batch (or per search)
/// so the completion kernel never touches the nested `Vec`s.
#[derive(Debug, Clone)]
pub struct FlatTasks {
    n: usize,
    r: usize,
    tasks: Vec<usize>,
}

impl FlatTasks {
    pub fn new(to: &ToMatrix) -> Self {
        let (n, r) = (to.n(), to.r());
        let mut tasks = Vec::with_capacity(n * r);
        for i in 0..n {
            tasks.extend_from_slice(to.row(i));
        }
        Self { n, r, tasks }
    }

    /// Rebuild in place from a (possibly different) matrix of the same
    /// shape — the local-search hot path mutates candidates per move.
    pub fn refill(&mut self, to: &ToMatrix) {
        assert_eq!(to.n(), self.n, "shape change requires FlatTasks::new");
        assert_eq!(to.r(), self.r, "shape change requires FlatTasks::new");
        self.tasks.clear();
        for i in 0..self.n {
            self.tasks.extend_from_slice(to.row(i));
        }
    }

    /// Refill a reusable scratch slot in place (creating it on first
    /// use) — the per-draw pattern of the randomized-scheme hot loops,
    /// which would otherwise allocate a fresh `FlatTasks` every round.
    pub fn refill_or_init<'a>(slot: &'a mut Option<FlatTasks>, to: &ToMatrix) -> &'a FlatTasks {
        if let Some(flat) = slot.as_mut() {
            flat.refill(to);
        } else {
            *slot = Some(FlatTasks::new(to));
        }
        slot.as_ref().expect("filled above")
    }

    #[inline]
    pub fn n(&self) -> usize {
        self.n
    }

    #[inline]
    pub fn r(&self) -> usize {
        self.r
    }

    #[inline]
    pub fn tasks(&self) -> &[usize] {
        &self.tasks
    }
}

/// Arrival time of every slot of every round of `batch`, written as one
/// flat round-major array (`out[b·n·r + i·r + j]`).  Identical
/// arithmetic to the scalar path: running prefix over a worker's
/// computation delays plus that slot's communication delay.
pub fn slot_arrivals_batch(batch: &DelayBatch, out: &mut Vec<f64>) {
    let (n, r) = (batch.n, batch.r);
    let stride = batch.stride();
    // every element is unconditionally written below, so only touch the
    // length when it changes — no per-chunk zero-fill on the hot path
    if out.len() != batch.rounds * stride {
        out.clear();
        out.resize(batch.rounds * stride, 0.0);
    }
    for b in 0..batch.rounds {
        let comp = batch.comp_round(b);
        let comm = batch.comm_round(b);
        let dst = &mut out[b * stride..(b + 1) * stride];
        for i in 0..n {
            let base = i * r;
            let mut prefix = 0.0;
            for j in 0..r {
                prefix += comp[base + j];
                dst[base + j] = prefix + comm[base + j];
            }
        }
    }
}

/// Shift one round's *local* arrival slice (`n·r` values, worker-major)
/// onto the absolute clock of the bounded-staleness pipeline:
/// `out[i·r + j] = local[i·r + j] + starts[i]`, where `starts[i]` is
/// worker `i`'s start time for the round (max of the round's issue
/// instant and the worker's previous free time).
///
/// With `starts ≡ 0` this is a bit-exact pass-through (a `+ 0.0` leaves
/// every finite f64 unchanged), which is why the synchronous `S = 1`
/// engines never need it — pinned by `offsets_of_zero_are_bit_exact`.
#[inline]
pub fn offset_arrivals(local: &[f64], starts: &[f64], r: usize, out: &mut Vec<f64>) {
    debug_assert_eq!(local.len(), starts.len() * r);
    if out.len() != local.len() {
        out.clear();
        out.resize(local.len(), 0.0);
    }
    for (i, &start) in starts.iter().enumerate() {
        let base = i * r;
        for j in 0..r {
            out[base + j] = local[base + j] + start;
        }
    }
}

/// Completion time of one round from its precomputed arrival slice
/// (`n·r` values): per-task first arrival (min-reduce over the flat
/// task indices), then the k-th order statistic.
///
/// Bit-identical to [`super::completion_time_fast`] on the same round.
#[inline]
pub fn completion_from_arrivals(
    tasks: &FlatTasks,
    arrivals: &[f64],
    k: usize,
    task_times: &mut Vec<f64>,
) -> f64 {
    let n = tasks.n;
    debug_assert_eq!(arrivals.len(), tasks.tasks.len());
    assert!(k >= 1 && k <= n, "computation target must satisfy 1 ≤ k ≤ n");
    // steady state (same n every round) is a straight `fill` — one
    // memset-shaped pass instead of clear + resize's len/capacity
    // bookkeeping; at n = 10_000 this is the kernel's only O(n) write
    // besides the min-reduce itself
    if task_times.len() == n {
        task_times.fill(f64::INFINITY);
    } else {
        task_times.clear();
        task_times.resize(n, f64::INFINITY);
    }
    for (slot, &task) in tasks.tasks.iter().enumerate() {
        let arrival = arrivals[slot];
        if arrival < task_times[task] {
            task_times[task] = arrival;
        }
    }
    let (_, kth, _) = task_times.select_nth_unstable_by(k - 1, |a, b| a.total_cmp(b));
    let t = *kth;
    assert!(
        t.is_finite(),
        "TO matrix covers fewer than k = {k} distinct tasks"
    );
    t
}

/// k-th smallest slot arrival of one round from its precomputed arrival
/// slice — the §V lower bound (`t̂_{T,(k)}`), sharing the arrival array
/// with the uncoded schemes instead of re-deriving it from the delays.
///
/// Bit-identical to [`crate::lb::kth_slot_arrival`] on the same round.
#[inline]
pub fn kth_arrival_from_arrivals(arrivals: &[f64], k: usize, scratch: &mut Vec<f64>) -> f64 {
    assert!(k >= 1 && k <= arrivals.len(), "need 1 ≤ k ≤ n·r slots");
    scratch.clear();
    scratch.extend_from_slice(arrivals);
    let (_, kth, _) = scratch.select_nth_unstable_by(k - 1, |a, b| a.total_cmp(b));
    *kth
}

/// Completion times of every round of `batch` for one TO matrix —
/// the public one-scheme batched kernel.  For coupled multi-scheme
/// evaluation, precompute [`slot_arrivals_batch`] once and call
/// [`completion_from_arrivals`] per scheme instead (what the
/// Monte-Carlo engine does).
pub fn completion_times_batch(to: &ToMatrix, batch: &DelayBatch, k: usize, out: &mut Vec<f64>) {
    assert_eq!(batch.n, to.n(), "delay batch shaped for different n");
    assert_eq!(batch.r, to.r(), "delay batch shaped for different r");
    let tasks = FlatTasks::new(to);
    let stride = batch.stride();
    let mut arrivals = Vec::new();
    slot_arrivals_batch(batch, &mut arrivals);
    let mut task_times: Vec<f64> = Vec::with_capacity(to.n());
    out.clear();
    out.reserve(batch.rounds);
    for b in 0..batch.rounds {
        out.push(completion_from_arrivals(
            &tasks,
            &arrivals[b * stride..(b + 1) * stride],
            k,
            &mut task_times,
        ));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::delay::{DelayModel, TruncatedGaussianModel};
    use crate::scheduler::{CyclicScheduler, Scheduler, StaircaseScheduler};
    use crate::sim::completion_time_fast;
    use crate::util::rng::Rng;

    #[test]
    fn flat_tasks_mirror_matrix_rows() {
        let mut rng = Rng::seed_from_u64(1);
        let to = CyclicScheduler.schedule(5, 3, &mut rng);
        let flat = FlatTasks::new(&to);
        assert_eq!(flat.n(), 5);
        assert_eq!(flat.r(), 3);
        for i in 0..5 {
            for j in 0..3 {
                assert_eq!(flat.tasks()[i * 3 + j], to.task(i, j));
            }
        }
    }

    #[test]
    fn batch_kernel_bit_identical_to_scalar_fast_path() {
        let (n, r) = (8usize, 5usize);
        let model = TruncatedGaussianModel::scenario2(n, 3);
        let mut rng = Rng::seed_from_u64(77);
        let batch = model.sample_batch(32, n, r, &mut rng);
        for sched in [
            &CyclicScheduler as &dyn Scheduler,
            &StaircaseScheduler,
        ] {
            let mut rng2 = Rng::seed_from_u64(0);
            let to = sched.schedule(n, r, &mut rng2);
            for k in [1usize, 3, n] {
                let mut batched = Vec::new();
                completion_times_batch(&to, &batch, k, &mut batched);
                let mut scratch: Vec<f64> = Vec::new();
                for b in 0..batch.rounds {
                    let sample = batch.round_sample(b);
                    let scalar = completion_time_fast(&to, &sample, k, &mut scratch);
                    assert_eq!(
                        batched[b].to_bits(),
                        scalar.to_bits(),
                        "{} k={k} round {b}",
                        sched.name()
                    );
                }
            }
        }
    }

    #[test]
    fn kth_arrival_matches_lb_kernel() {
        let (n, r) = (6usize, 4usize);
        let model = TruncatedGaussianModel::scenario1(n);
        let mut rng = Rng::seed_from_u64(5);
        let batch = model.sample_batch(16, n, r, &mut rng);
        let mut arrivals = Vec::new();
        slot_arrivals_batch(&batch, &mut arrivals);
        let stride = batch.stride();
        let mut scratch = Vec::new();
        let mut lb_scratch = Vec::new();
        for b in 0..batch.rounds {
            let sample = batch.round_sample(b);
            for k in [1usize, n, n * r] {
                let batched = kth_arrival_from_arrivals(
                    &arrivals[b * stride..(b + 1) * stride],
                    k,
                    &mut scratch,
                );
                let scalar = crate::lb::kth_slot_arrival(&sample, k, &mut lb_scratch);
                assert_eq!(batched.to_bits(), scalar.to_bits(), "k={k} round {b}");
            }
        }
    }

    #[test]
    fn offsets_of_zero_are_bit_exact() {
        let (n, r) = (4usize, 3usize);
        let model = TruncatedGaussianModel::scenario1(n);
        let mut rng = Rng::seed_from_u64(11);
        let batch = model.sample_batch(2, n, r, &mut rng);
        let mut arrivals = Vec::new();
        slot_arrivals_batch(&batch, &mut arrivals);
        let local = &arrivals[..n * r];
        let zeros = vec![0.0f64; n];
        let mut out = Vec::new();
        offset_arrivals(local, &zeros, r, &mut out);
        for (a, b) in local.iter().zip(&out) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        // and a real shift lands per worker, not globally
        let starts = vec![0.0, 10.0, 20.0, 30.0];
        offset_arrivals(local, &starts, r, &mut out);
        for i in 0..n {
            for j in 0..r {
                assert_eq!(out[i * r + j], local[i * r + j] + starts[i]);
            }
        }
    }

    #[test]
    #[should_panic(expected = "fewer than k")]
    fn uncoverable_target_panics() {
        let to = ToMatrix::new(2, vec![vec![0, 0], vec![0, 0]]);
        let mut batch = DelayBatch::zeros(1, 2, 2);
        batch.comp_flat_mut().fill(1.0);
        batch.comm_flat_mut().fill(1.0);
        let mut out = Vec::new();
        completion_times_batch(&to, &batch, 2, &mut out);
    }
}
