//! Monte-Carlo completion-time engine (paper §II dynamics, eq. 1–2, 5).
//!
//! [`simulate_round`] plays out one round: given a TO matrix and one
//! delay realization it computes, per worker, the arrival time of every
//! slot's result at the master (prefix-summed computation delays plus
//! that slot's communication delay — eq. 1), then finds the earliest
//! time at which `k` *distinct* tasks have arrived (eq. 2 + the
//! computation-target stopping rule).
//!
//! [`montecarlo`] wraps this in a seeded, optionally multi-threaded
//! estimator producing the paper's `t̄_C(r, k)` (eq. 5) with standard
//! errors, and supports *coupled* evaluation of several schemes on the
//! identical delay stream (variance-reduced comparisons, and the
//! stochastic-dominance property tests).
//!
//! [`batch`] holds the structure-of-arrays kernels the estimator runs
//! on (shared per-batch arrival pass, flat TO-row completion reduce),
//! and [`pool`] the persistent worker pool the shards execute on; both
//! are public so the scheduler search, the lower bound and the figure
//! harness drive the same hot loops.

pub mod batch;
pub mod montecarlo;
pub mod pool;

pub use batch::{
    completion_from_arrivals, completion_times_batch, kth_arrival_from_arrivals, offset_arrivals,
    slot_arrivals_batch, FlatTasks,
};
pub use montecarlo::{
    chunk_rounds, shard_layout, shard_rngs, CompletionEstimate, Engine, MonteCarlo, BATCH_ROUNDS,
    MAX_CHUNK_SLOTS,
};
pub use pool::WorkerPool;

use crate::delay::DelaySample;
use crate::scheduler::ToMatrix;

/// Result of one simulated round.
#[derive(Debug, Clone)]
pub struct RoundResult {
    /// `t_C(r, k)` for this realization.
    pub completion_time: f64,
    /// The `k` distinct tasks the master held at completion, in arrival
    /// order (the `p_1 … p_k` of update rule eq. 61).
    pub winners: Vec<usize>,
}

/// Reusable scratch for the hot loop — avoids per-round allocation.
/// One per thread; `simulate_round_with` writes into it.
#[derive(Debug, Default)]
pub struct SimScratch {
    /// (arrival time, task) for every slot, filled per round.
    arrivals: Vec<(f64, usize)>,
    /// first-arrival marker per task.
    seen: Vec<bool>,
}

impl SimScratch {
    pub fn new() -> Self {
        Self::default()
    }

    fn reset(&mut self, n: usize, cap: usize) {
        self.arrivals.clear();
        self.arrivals.reserve(cap);
        self.seen.clear();
        self.seen.resize(n, false);
    }
}

/// Simulate one round, allocating scratch internally (tests/one-offs).
pub fn simulate_round(to: &ToMatrix, sample: &DelaySample, k: usize) -> RoundResult {
    let mut scratch = SimScratch::new();
    simulate_round_with(to, sample, k, &mut scratch)
}

/// Simulate one round into caller-provided scratch (the hot path).
///
/// Complexity `O(n·r log(n·r))` from the arrival sort.  Early-exit
/// optimizations (partial selection) are benchmarked in
/// `rust/benches/hot_paths.rs`; the sort variant wins for the paper's
/// `n ≤ 16` sizes.
pub fn simulate_round_with(
    to: &ToMatrix,
    sample: &DelaySample,
    k: usize,
    scratch: &mut SimScratch,
) -> RoundResult {
    let (n, r) = (to.n(), to.r());
    assert_eq!(sample.n, n, "delay sample shaped for different n");
    assert_eq!(sample.r, r, "delay sample shaped for different r");
    assert!(k >= 1 && k <= n, "computation target must satisfy 1 ≤ k ≤ n");

    scratch.reset(n, n * r);

    // eq. (1): worker i's j-th result arrives at
    //   Σ_{m ≤ j} comp(i, m) + comm(i, j)
    for i in 0..n {
        let comp = sample.comp_row(i);
        let comm = sample.comm_row(i);
        let row = to.row(i);
        let mut prefix = 0.0;
        for j in 0..r {
            prefix += comp[j];
            scratch.arrivals.push((prefix + comm[j], row[j]));
        }
    }

    // stopping rule: earliest t with k distinct tasks received
    scratch
        .arrivals
        .sort_unstable_by(|a, b| a.0.total_cmp(&b.0));

    let mut winners = Vec::with_capacity(k);
    for &(t, task) in scratch.arrivals.iter() {
        if !scratch.seen[task] {
            scratch.seen[task] = true;
            winners.push(task);
            if winners.len() == k {
                return RoundResult {
                    completion_time: t,
                    winners,
                };
            }
        }
    }

    // unreachable when the TO matrix covers ≥ k distinct tasks; surface
    // the configuration error loudly otherwise.
    panic!(
        "TO matrix covers only {} distinct tasks; target k = {k} unreachable",
        scratch.seen.iter().filter(|&&s| s).count()
    );
}

/// Completion time only — the Monte-Carlo hot path.
///
/// Identity: the round completes at the k-th smallest *per-task first
/// arrival* `t_(k)` (each task's first arrival is `t_j` of eq. 2, and
/// the k-th distinct arrival is exactly the k-th order statistic of the
/// `t_j`).  That replaces the `O(n·r log(n·r))` arrival sort of
/// [`simulate_round_with`] with an `O(n·r)` min-reduction plus an
/// `O(n)` selection — ~7× faster at n = r = 16 (EXPERIMENTS.md §Perf).
/// Use [`simulate_round_with`] when the *winner order* matters (the
/// training path of eq. 61).
pub fn completion_time_fast(
    to: &ToMatrix,
    sample: &DelaySample,
    k: usize,
    task_times: &mut Vec<f64>,
) -> f64 {
    let (n, r) = (to.n(), to.r());
    debug_assert_eq!(sample.n, n);
    debug_assert_eq!(sample.r, r);
    assert!(k >= 1 && k <= n, "computation target must satisfy 1 ≤ k ≤ n");
    task_times.clear();
    task_times.resize(n, f64::INFINITY);
    for i in 0..n {
        let comp = sample.comp_row(i);
        let comm = sample.comm_row(i);
        let row = to.row(i);
        let mut prefix = 0.0;
        for j in 0..r {
            prefix += comp[j];
            let arrival = prefix + comm[j];
            let task = row[j];
            if arrival < task_times[task] {
                task_times[task] = arrival;
            }
        }
    }
    let (_, kth, _) = task_times.select_nth_unstable_by(k - 1, |a, b| a.total_cmp(b));
    let t = *kth;
    assert!(
        t.is_finite(),
        "TO matrix covers fewer than k = {k} distinct tasks"
    );
    t
}

/// First-arrival time of every task (`t_j` of eq. 2), ∞ for unassigned
/// tasks.  Used by the Theorem-1 analytic evaluator.
pub fn task_arrival_times(to: &ToMatrix, sample: &DelaySample) -> Vec<f64> {
    let (n, r) = (to.n(), to.r());
    let mut t = vec![f64::INFINITY; n];
    for i in 0..n {
        let comp = sample.comp_row(i);
        let comm = sample.comm_row(i);
        let row = to.row(i);
        let mut prefix = 0.0;
        for j in 0..r {
            prefix += comp[j];
            let arrival = prefix + comm[j];
            let task = row[j];
            if arrival < t[task] {
                t[task] = arrival;
            }
        }
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::delay::DelaySample;
    use crate::scheduler::{CyclicScheduler, Scheduler, ToMatrix};
    use crate::util::rng::Rng;

    /// deterministic 2-worker fixture:
    ///   C = [[0, 1], [1, 0]]
    ///   worker 0: comp [1, 2], comm [10, 1]
    ///   worker 1: comp [4, 1], comm [1, 1]
    /// arrivals: w0 slot0 (task0) @ 1+10=11; w0 slot1 (task1) @ 3+1=4
    ///           w1 slot0 (task1) @ 4+1=5;   w1 slot1 (task0) @ 5+1=6
    fn fixture() -> (ToMatrix, DelaySample) {
        let to = ToMatrix::new(2, vec![vec![0, 1], vec![1, 0]]);
        let s = DelaySample::from_rows(
            vec![vec![1.0, 2.0], vec![4.0, 1.0]],
            vec![vec![10.0, 1.0], vec![1.0, 1.0]],
        );
        (to, s)
    }

    #[test]
    fn arrival_times_follow_eq_1_and_2() {
        let (to, s) = fixture();
        let t = task_arrival_times(&to, &s);
        // t_1 = min(11, 6) = 6; t_2 = min(4, 5) = 4
        assert_eq!(t, vec![6.0, 4.0]);
    }

    #[test]
    fn completion_k1_is_first_distinct() {
        let (to, s) = fixture();
        let r = simulate_round(&to, &s, 1);
        assert_eq!(r.completion_time, 4.0);
        assert_eq!(r.winners, vec![1]);
    }

    #[test]
    fn completion_k2_needs_both_tasks() {
        let (to, s) = fixture();
        let r = simulate_round(&to, &s, 2);
        assert_eq!(r.completion_time, 6.0);
        assert_eq!(r.winners, vec![1, 0]);
    }

    #[test]
    fn duplicate_arrivals_do_not_count_twice() {
        // worker 1 re-delivers task 1 before anyone delivers task 0
        let to = ToMatrix::new(2, vec![vec![1, 1], vec![1, 0]]);
        let s = DelaySample::from_rows(
            vec![vec![1.0, 1.0], vec![1.0, 5.0]],
            vec![vec![0.5, 0.5], vec![0.5, 0.5]],
        );
        let r = simulate_round(&to, &s, 2);
        // task1 @1.5 (w0) and @1.5 (w1), dup @2.5; task0 @6.5
        assert_eq!(r.completion_time, 6.5);
        assert_eq!(r.winners, vec![1, 0]);
    }

    #[test]
    fn completion_monotone_in_k() {
        let model = crate::delay::ShiftedExponential::new(0.1, 2.0, 0.1, 2.0);
        use crate::delay::DelayModel;
        let mut rng = Rng::seed_from_u64(9);
        let to = CyclicScheduler.schedule(8, 4, &mut rng);
        for _ in 0..100 {
            let s = model.sample(8, 4, &mut rng);
            let mut last = 0.0;
            for k in 1..=8 {
                let r = simulate_round(&to, &s, k);
                assert!(r.completion_time >= last, "k={k}");
                last = r.completion_time;
            }
        }
    }

    #[test]
    fn completion_non_increasing_in_r_under_coupling() {
        // adding a column to the TO matrix (same delays for shared
        // prefix slots) can only help
        use crate::delay::DelayModel;
        let model = crate::delay::ShiftedExponential::new(0.1, 2.0, 0.1, 2.0);
        let mut rng = Rng::seed_from_u64(33);
        let n = 6;
        for _ in 0..50 {
            let big = model.sample(n, n, &mut rng);
            let mut last = f64::INFINITY;
            for r in 1..=n {
                // truncate both schedule and delays to r slots
                let to = {
                    let mut rng2 = Rng::seed_from_u64(0);
                    CyclicScheduler.schedule(n, r, &mut rng2)
                };
                let s = DelaySample::from_rows(
                    (0..n).map(|i| big.comp_row(i)[..r].to_vec()).collect(),
                    (0..n).map(|i| big.comm_row(i)[..r].to_vec()).collect(),
                );
                let res = simulate_round(&to, &s, n.min(2 * r));
                if r > 1 {
                    // completion for smaller target on more slots is
                    // not directly comparable; instead fix k = 2
                    let res2 = simulate_round(&to, &s, 2.min(n));
                    assert!(res2.completion_time <= last + 1e-12, "r={r}");
                    last = res2.completion_time;
                } else {
                    last = simulate_round(&to, &s, 2.min(n)).completion_time;
                }
                let _ = res;
            }
        }
    }

    #[test]
    #[should_panic(expected = "unreachable")]
    fn panics_when_target_uncoverable() {
        // both workers only ever compute task 0 → k = 2 impossible
        let to = ToMatrix::new(2, vec![vec![0, 0], vec![0, 0]]);
        let s = DelaySample::from_rows(
            vec![vec![1.0, 1.0], vec![1.0, 1.0]],
            vec![vec![1.0, 1.0], vec![1.0, 1.0]],
        );
        simulate_round(&to, &s, 2);
    }

    #[test]
    fn winners_match_task_arrival_order() {
        use crate::delay::DelayModel;
        let model = crate::delay::ShiftedExponential::new(0.0, 1.0, 0.0, 1.0);
        let mut rng = Rng::seed_from_u64(4);
        let to = CyclicScheduler.schedule(5, 3, &mut rng);
        let s = model.sample(5, 3, &mut rng);
        let res = simulate_round(&to, &s, 5.min(to.r() * 5));
        let t = task_arrival_times(&to, &s);
        // winners must be sorted by their first-arrival times
        for w in res.winners.windows(2) {
            assert!(t[w[0]] <= t[w[1]]);
        }
    }
}
