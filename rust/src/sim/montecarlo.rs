//! Seeded, multi-threaded Monte-Carlo estimation of `t̄_C(r, k)` on the
//! batched structure-of-arrays engine.
//!
//! Rounds are split into `threads` deterministic **shards**; each shard
//! owns an RNG pair seeded purely from `(seed, shard)` (see
//! [`shard_rngs`]) so results are reproducible for a fixed
//! `(trials, threads, seed)` triple regardless of scheduling.  Shards
//! execute on the process-wide persistent [`WorkerPool`] instead of
//! freshly-spawned threads, and OS-level concurrency is therefore
//! always clamped to `available_parallelism` even when `threads` is set
//! higher explicitly — `threads` only controls the (deterministic)
//! shard/RNG-stream layout, never oversubscription.
//!
//! Per shard the engine samples delays in [`crate::delay::DelayBatch`]
//! chunks, computes every slot's arrival time **once** per chunk
//! ([`super::batch::slot_arrivals_batch`]), and evaluates all coupled
//! schemes against that shared arrival array — the coupled estimator's
//! "same delay stream for every scheme" fairness discipline, now also
//! meaning the delays are *read* once per round instead of once per
//! round × scheme.  Since PR 2 the batched arm is literally the figure
//! harness's loop: schedulers are wrapped into prepared scheme-layer
//! evaluators ([`crate::scheme::evaluator_for_scheduler`]) and driven
//! by [`crate::scheme::run_rounds`].
//! Trial statistics stream into `RunningStats` + `StreamingQuantiles`
//! accumulators, so memory is O(schemes), not O(schemes × trials); the
//! raw per-round values remain available through the opt-in
//! [`MonteCarlo::run_coupled`] (used by the stochastic-dominance
//! property tests).
//!
//! ## Shard-seeding invariant
//!
//! Delay sampling uses the shard's **delay RNG**; scheduling randomness
//! (RA redraws) uses a **separate** RNG derived from the same base.
//! Consequently the delay stream seen by a scheme depends only on
//! `(seed, threads, trials)` — never on *which other schemes* are being
//! evaluated — so `estimate(CS)` and `estimate_coupled([CS, RA])` see
//! bit-identical delays for CS.  This is asserted by the
//! `coupling_invariant_single_vs_coupled` test below; both engines and
//! the harness evaluator derive their streams through [`shard_rngs`] so
//! the invariant cannot drift silently between code paths.

use crate::delay::{DelayModel, DelaySample};
use crate::scheduler::{Scheduler, ToMatrix};
use crate::scheme::{evaluator_for_scheduler, run_rounds, SchemeEvaluator};
use crate::util::rng::Rng;
use crate::util::stats::{RunningStats, StreamingQuantiles};

use super::completion_time_fast;
use super::pool::WorkerPool;

/// Rounds sampled per [`DelayBatch`] chunk.  Large enough to amortize
/// dispatch and keep the arrival array streaming through cache, small
/// enough that a 16×16 batch stays ~1 MB.
pub const BATCH_ROUNDS: usize = 256;

/// Per-chunk slot budget for fleet-scale shapes: comp + comm + arrivals
/// at `f64` cost 24 bytes/slot, so 2²¹ slots ≈ 50 MB per shard — the
/// ceiling a fixed 256-round chunk would blow through at `n = 10_000`
/// (256 rounds × 40 000 slots ≈ 245 MB per shard).
pub const MAX_CHUNK_SLOTS: usize = 1 << 21;

/// Rounds per [`DelayBatch`] chunk adapted to the fleet size: the full
/// [`BATCH_ROUNDS`] for every paper-scale shape (`n·r ≤ 8192`), scaled
/// down to hold [`MAX_CHUNK_SLOTS`] for big fleets.  Chunking never
/// affects results — delays are sampled round-sequentially, so any
/// chunk split concatenates to the identical stream (pinned by the
/// batched-vs-scalar bit-identity tests and `tests/fleet.rs`).
pub fn chunk_rounds(n: usize, r: usize) -> usize {
    (MAX_CHUNK_SLOTS / (n * r).max(1)).clamp(1, BATCH_ROUNDS)
}

/// Derive a shard's `(delay RNG, scheduling RNG)` pair — the single
/// source of the shard-seeding invariant (see module docs).  Everything
/// that shards Monte-Carlo rounds (this engine, the harness evaluator)
/// must obtain its streams here.
pub fn shard_rngs(seed: u64, shard: u64) -> (Rng, Rng) {
    let base = seed ^ 0x9E3779B97F4A7C15u64.wrapping_mul(shard + 1);
    (
        Rng::seed_from_u64(base),
        Rng::seed_from_u64(base ^ 0x5C4ED),
    )
}

/// Deterministic shard layout shared by every sharded engine:
/// `threads` shards clamped into `[1, trials]`, remainder spread over
/// the leading shards.  Lives next to [`shard_rngs`] for the same
/// reason — round counts feed the RNG streams' consumption, so a
/// private copy of this formula could silently decouple the harness
/// evaluator from `MonteCarlo`.
pub fn shard_layout(trials: usize, threads: usize) -> Vec<usize> {
    let shards = threads.clamp(1, trials.max(1));
    (0..shards)
        .map(|t| trials / shards + usize::from(t < trials % shards))
        .collect()
}

/// Point estimate of the average completion time plus dispersion.
#[derive(Debug, Clone)]
pub struct CompletionEstimate {
    pub scheme: String,
    pub n: usize,
    pub r: usize,
    pub k: usize,
    pub trials: usize,
    pub mean: f64,
    pub std_err: f64,
    pub std_dev: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p95: f64,
}

impl CompletionEstimate {
    /// Build from streaming accumulators (the engine's native path).
    pub fn from_streams(
        scheme: String,
        n: usize,
        r: usize,
        k: usize,
        stats: &RunningStats,
        quantiles: &StreamingQuantiles,
    ) -> Self {
        debug_assert_eq!(stats.count(), quantiles.count());
        let qs = quantiles.quantiles(&[0.5, 0.95]);
        Self {
            scheme,
            n,
            r,
            k,
            trials: stats.count() as usize,
            mean: stats.mean(),
            std_err: stats.std_err(),
            std_dev: stats.std_dev(),
            min: stats.min(),
            max: stats.max(),
            p50: qs[0],
            p95: qs[1],
        }
    }

    /// Build from raw values by streaming them through the same
    /// accumulators (convenience for custom/raw-mode callers).
    pub fn from_values(scheme: String, n: usize, r: usize, k: usize, values: &[f64]) -> Self {
        let mut stats = RunningStats::new();
        let mut quantiles = StreamingQuantiles::new();
        for &v in values {
            stats.push(v);
            quantiles.push(v);
        }
        Self::from_streams(scheme, n, r, k, &stats, &quantiles)
    }
}

/// Which completion kernel drives the shards.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Engine {
    /// Per-round sampling + [`completion_time_fast`] — the reference
    /// path the batched engine must reproduce bit-for-bit.
    Scalar,
    /// [`DelayBatch`] chunks with one shared arrival pass per chunk.
    Batched,
}

/// Monte-Carlo driver configuration.
///
/// `threads` is the number of deterministic shards (RNG streams); the
/// persistent pool clamps actual OS parallelism to
/// `available_parallelism` regardless of its value.
#[derive(Debug, Clone, Copy)]
pub struct MonteCarlo {
    pub trials: usize,
    pub seed: u64,
    pub threads: usize,
}

impl Default for MonteCarlo {
    fn default() -> Self {
        Self {
            trials: 10_000,
            seed: 0x5EED,
            threads: std::thread::available_parallelism()
                .map(|p| p.get())
                .unwrap_or(1),
        }
    }
}

impl MonteCarlo {
    pub fn new(trials: usize, seed: u64) -> Self {
        Self {
            trials,
            seed,
            ..Self::default()
        }
    }

    pub fn single_threaded(mut self) -> Self {
        self.threads = 1;
        self
    }

    fn shard_sizes(&self) -> Vec<usize> {
        shard_layout(self.trials, self.threads)
    }

    /// Estimate `t̄` for one scheme (batched engine).
    pub fn estimate(
        &self,
        scheduler: &dyn Scheduler,
        model: &dyn DelayModel,
        n: usize,
        r: usize,
        k: usize,
    ) -> CompletionEstimate {
        self.estimate_coupled(&[scheduler], model, n, r, k)
            .pop()
            .expect("one scheme in, one estimate out")
    }

    /// Estimate several schemes against the identical delay stream
    /// (batched engine — the default hot path).
    pub fn estimate_coupled(
        &self,
        schedulers: &[&dyn Scheduler],
        model: &dyn DelayModel,
        n: usize,
        r: usize,
        k: usize,
    ) -> Vec<CompletionEstimate> {
        self.estimate_coupled_with(schedulers, model, n, r, k, Engine::Batched)
    }

    /// Same estimator on the scalar reference kernel.  Exists so the
    /// bit-identity of the batched engine stays testable and
    /// benchmarkable forever (`cargo bench --bench hot_paths`).
    pub fn estimate_coupled_scalar(
        &self,
        schedulers: &[&dyn Scheduler],
        model: &dyn DelayModel,
        n: usize,
        r: usize,
        k: usize,
    ) -> Vec<CompletionEstimate> {
        self.estimate_coupled_with(schedulers, model, n, r, k, Engine::Scalar)
    }

    /// Shared driver: shard on the persistent pool, stream per-shard
    /// accumulators, merge in shard-index order (deterministic).
    pub fn estimate_coupled_with(
        &self,
        schedulers: &[&dyn Scheduler],
        model: &dyn DelayModel,
        n: usize,
        r: usize,
        k: usize,
        engine: Engine,
    ) -> Vec<CompletionEstimate> {
        assert!(!schedulers.is_empty());
        assert!(self.trials > 0, "need at least one trial");
        let seed = self.seed;
        let jobs: Vec<_> = self
            .shard_sizes()
            .into_iter()
            .enumerate()
            .map(|(shard, rounds)| {
                move || {
                    let mut acc: Vec<(RunningStats, StreamingQuantiles)> =
                        vec![(RunningStats::new(), StreamingQuantiles::new()); schedulers.len()];
                    run_shard(
                        schedulers,
                        model,
                        n,
                        r,
                        k,
                        rounds,
                        seed,
                        shard as u64,
                        engine,
                        &mut |idx, t| {
                            acc[idx].0.push(t);
                            acc[idx].1.push(t);
                        },
                    );
                    acc
                }
            })
            .collect();
        let per_shard = WorkerPool::global().scope_run(jobs);

        let mut merged: Vec<(RunningStats, StreamingQuantiles)> =
            vec![(RunningStats::new(), StreamingQuantiles::new()); schedulers.len()];
        for shard_acc in per_shard {
            for (dst, src) in merged.iter_mut().zip(shard_acc) {
                dst.0.merge(&src.0);
                dst.1.merge(&src.1);
            }
        }
        schedulers
            .iter()
            .zip(merged)
            .map(|(s, (stats, quantiles))| {
                CompletionEstimate::from_streams(
                    s.name().to_string(),
                    n,
                    r,
                    k,
                    &stats,
                    &quantiles,
                )
            })
            .collect()
    }

    /// Raw per-round completion times, one vec per scheme, coupled on
    /// the delay stream — the opt-in O(schemes × trials) mode kept for
    /// dominance tests and custom statistics.  Values are bit-identical
    /// to what the streaming estimator folds in, in the same order
    /// (shards concatenated in index order).
    pub fn run_coupled(
        &self,
        schedulers: &[&dyn Scheduler],
        model: &dyn DelayModel,
        n: usize,
        r: usize,
        k: usize,
    ) -> Vec<Vec<f64>> {
        assert!(!schedulers.is_empty());
        assert!(self.trials > 0, "need at least one trial");
        let seed = self.seed;
        let jobs: Vec<_> = self
            .shard_sizes()
            .into_iter()
            .enumerate()
            .map(|(shard, rounds)| {
                move || {
                    let mut out: Vec<Vec<f64>> =
                        vec![Vec::with_capacity(rounds); schedulers.len()];
                    run_shard(
                        schedulers,
                        model,
                        n,
                        r,
                        k,
                        rounds,
                        seed,
                        shard as u64,
                        Engine::Batched,
                        &mut |idx, t| out[idx].push(t),
                    );
                    out
                }
            })
            .collect();
        let per_shard = WorkerPool::global().scope_run(jobs);

        let mut merged: Vec<Vec<f64>> = vec![Vec::with_capacity(self.trials); schedulers.len()];
        for shard_out in per_shard {
            for (dst, src) in merged.iter_mut().zip(shard_out) {
                dst.extend(src);
            }
        }
        merged
    }
}

/// One shard's worth of coupled rounds, emitting `(scheme_idx, t)` per
/// round per scheme.  Fixed schedules are built once (consuming the
/// scheduling RNG identically under both engines); randomized schemes
/// redraw per round in round-major scheme order.
///
/// The batched arm dispatches through the unified scheme layer
/// ([`crate::scheme`]): each scheduler is wrapped in a prepared
/// evaluator and the shared [`run_rounds`] chunk loop does the rest —
/// the same code path the figure harness runs, so the two engines
/// cannot drift.  The scalar arm stays a hand-rolled per-round loop on
/// purpose: it is the independent reference the bit-identity tests
/// compare against.
#[allow(clippy::too_many_arguments)]
fn run_shard(
    schedulers: &[&dyn Scheduler],
    model: &dyn DelayModel,
    n: usize,
    r: usize,
    k: usize,
    rounds: usize,
    seed: u64,
    shard: u64,
    engine: Engine,
    emit: &mut dyn FnMut(usize, f64),
) {
    let (mut rng, mut rng_sched) = shard_rngs(seed, shard);

    match engine {
        Engine::Scalar => {
            // fixed schedules built once; randomized ones rebuilt per round
            let fixed: Vec<Option<ToMatrix>> = schedulers
                .iter()
                .map(|s| {
                    if s.is_randomized() {
                        None
                    } else {
                        Some(s.schedule(n, r, &mut rng_sched))
                    }
                })
                .collect();
            let mut sample = DelaySample::zeros(n, r);
            let mut scratch: Vec<f64> = Vec::with_capacity(n);
            for _ in 0..rounds {
                model.sample_into(&mut sample, &mut rng);
                for (idx, sched) in schedulers.iter().enumerate() {
                    let t = match &fixed[idx] {
                        Some(to) => completion_time_fast(to, &sample, k, &mut scratch),
                        None => {
                            let to = sched.schedule(n, r, &mut rng_sched);
                            completion_time_fast(&to, &sample, k, &mut scratch)
                        }
                    };
                    emit(idx, t);
                }
            }
        }
        Engine::Batched => {
            // prepare consumes rng_sched in scheduler order, exactly
            // like the scalar arm's fixed-schedule pass
            let mut evaluators: Vec<Box<dyn SchemeEvaluator + '_>> = schedulers
                .iter()
                .map(|s| evaluator_for_scheduler(*s, n, r, k, &mut rng_sched))
                .collect();
            run_rounds(
                &mut evaluators,
                model,
                n,
                r,
                rounds,
                0.0,
                &mut rng,
                &mut rng_sched,
                emit,
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::delay::{ShiftedExponential, TruncatedGaussianModel};
    use crate::scheduler::{CyclicScheduler, RandomAssignment, StaircaseScheduler};

    #[test]
    fn deterministic_given_seed_and_threads() {
        let model = ShiftedExponential::new(0.1, 3.0, 0.2, 2.0);
        let mc = MonteCarlo {
            trials: 2000,
            seed: 42,
            threads: 4,
        };
        let a = mc.estimate(&CyclicScheduler, &model, 6, 3, 6);
        let b = mc.estimate(&CyclicScheduler, &model, 6, 3, 6);
        assert_eq!(a.mean, b.mean);
        assert_eq!(a.p95, b.p95);
    }

    #[test]
    fn thread_split_covers_all_trials() {
        let model = ShiftedExponential::new(0.1, 3.0, 0.2, 2.0);
        for threads in [1, 2, 3, 7] {
            let mc = MonteCarlo {
                trials: 100,
                seed: 1,
                threads,
            };
            let e = mc.estimate(&CyclicScheduler, &model, 4, 2, 3);
            assert_eq!(e.trials, 100, "threads={threads}");
        }
    }

    #[test]
    fn oversubscribed_shard_count_still_deterministic_and_complete() {
        // `threads` above the core count only changes the shard/RNG
        // layout; OS concurrency is clamped by the persistent pool
        let model = ShiftedExponential::new(0.1, 3.0, 0.2, 2.0);
        let mc = MonteCarlo {
            trials: 1000,
            seed: 9,
            threads: 64,
        };
        let a = mc.estimate(&CyclicScheduler, &model, 5, 2, 4);
        let b = mc.estimate(&CyclicScheduler, &model, 5, 2, 4);
        assert_eq!(a.trials, 1000);
        assert_eq!(a.mean, b.mean);
    }

    #[test]
    fn batched_estimates_bit_identical_to_scalar_engine() {
        // the acceptance bar: fixed (trials, threads, seed) triple →
        // mean, p50 and p95 agree to the last bit across engines
        let model = TruncatedGaussianModel::scenario2(8, 11);
        let mc = MonteCarlo {
            trials: 3000,
            seed: 1234,
            threads: 3,
        };
        let schemes: Vec<&dyn crate::scheduler::Scheduler> =
            vec![&CyclicScheduler, &StaircaseScheduler, &RandomAssignment];
        let batched = mc.estimate_coupled(&schemes, &model, 8, 8, 8);
        let scalar = mc.estimate_coupled_scalar(&schemes, &model, 8, 8, 8);
        for (a, b) in batched.iter().zip(&scalar) {
            assert_eq!(a.mean.to_bits(), b.mean.to_bits(), "{} mean", a.scheme);
            assert_eq!(a.p50.to_bits(), b.p50.to_bits(), "{} p50", a.scheme);
            assert_eq!(a.p95.to_bits(), b.p95.to_bits(), "{} p95", a.scheme);
            assert_eq!(a.min.to_bits(), b.min.to_bits(), "{} min", a.scheme);
            assert_eq!(a.max.to_bits(), b.max.to_bits(), "{} max", a.scheme);
        }
    }

    #[test]
    fn coupling_invariant_single_vs_coupled() {
        // shard-seeding invariant: the delay stream a scheme sees must
        // not depend on which other schemes ride along
        let model = TruncatedGaussianModel::scenario1(6);
        let mc = MonteCarlo {
            trials: 1500,
            seed: 77,
            threads: 4,
        };
        let alone = mc.estimate(&CyclicScheduler, &model, 6, 3, 6);
        let coupled = mc.estimate_coupled(
            &[&CyclicScheduler, &RandomAssignment],
            &model,
            6,
            3,
            6,
        );
        assert_eq!(alone.mean.to_bits(), coupled[0].mean.to_bits());
        assert_eq!(alone.p95.to_bits(), coupled[0].p95.to_bits());
    }

    #[test]
    fn streaming_matches_raw_values_pipeline() {
        // run_coupled (raw mode) feeds the same values in the same
        // order; re-streaming them per shard must reproduce the
        // estimator exactly
        let model = ShiftedExponential::new(0.05, 5.0, 0.3, 2.0);
        let mc = MonteCarlo {
            trials: 900,
            seed: 5,
            threads: 1, // single shard → single accumulator stream
        };
        let raw = mc.run_coupled(&[&CyclicScheduler], &model, 5, 2, 5);
        let est = mc.estimate(&CyclicScheduler, &model, 5, 2, 5);
        let rebuilt =
            CompletionEstimate::from_values("CS".into(), 5, 2, 5, &raw[0]);
        assert_eq!(est.mean.to_bits(), rebuilt.mean.to_bits());
        assert_eq!(est.p50.to_bits(), rebuilt.p50.to_bits());
        assert_eq!(est.p95.to_bits(), rebuilt.p95.to_bits());
    }

    #[test]
    fn r1_k1_mean_matches_analytic_minimum() {
        // r = 1, k = 1, n = 1: completion = comp + comm of the single
        // worker; mean must equal the sum of means.
        let model = ShiftedExponential::new(0.1, 4.0, 0.3, 5.0);
        let mc = MonteCarlo::new(200_000, 7);
        let e = mc.estimate(&CyclicScheduler, &model, 1, 1, 1);
        let want = 0.1 + 0.25 + 0.3 + 0.2;
        assert!(
            (e.mean - want).abs() < 5.0 * e.std_err,
            "{} vs {want} (se {})",
            e.mean,
            e.std_err
        );
    }

    #[test]
    fn more_redundancy_helps_on_average() {
        // at fixed k, larger computation load can only reduce t̄ (more
        // slots per task) — checked on scenario-1 gaussians
        let model = TruncatedGaussianModel::scenario1(8);
        let mc = MonteCarlo::new(4000, 11);
        let t_r1 = mc.estimate(&CyclicScheduler, &model, 8, 1, 6).mean;
        let t_r4 = mc.estimate(&CyclicScheduler, &model, 8, 4, 6).mean;
        let t_r8 = mc.estimate(&CyclicScheduler, &model, 8, 8, 6).mean;
        assert!(t_r4 < t_r1, "{t_r4} !< {t_r1}");
        assert!(t_r8 <= t_r4 + 2e-3, "{t_r8} !<= {t_r4}");
    }

    #[test]
    fn coupled_schemes_share_delay_stream() {
        // CS vs CS coupled must be *identical*, not just close
        let model = ShiftedExponential::new(0.1, 3.0, 0.2, 2.0);
        let mc = MonteCarlo::new(500, 3);
        let out = mc.run_coupled(
            &[&CyclicScheduler, &CyclicScheduler],
            &model,
            5,
            2,
            4,
        );
        assert_eq!(out[0], out[1]);
    }

    #[test]
    fn scheduled_schemes_beat_ra_at_full_load() {
        // the paper's headline uncoded comparison (Figs. 5–7): CS and SS
        // dominate RA when r = n
        let model = TruncatedGaussianModel::scenario1(10);
        let mc = MonteCarlo::new(6000, 19);
        let est = mc.estimate_coupled(
            &[&CyclicScheduler, &StaircaseScheduler, &RandomAssignment],
            &model,
            10,
            10,
            10,
        );
        let (cs, ss, ra) = (&est[0], &est[1], &est[2]);
        assert!(cs.mean < ra.mean, "CS {} !< RA {}", cs.mean, ra.mean);
        assert!(ss.mean < ra.mean, "SS {} !< RA {}", ss.mean, ra.mean);
    }

    #[test]
    fn estimate_quantiles_ordered() {
        let model = ShiftedExponential::new(0.1, 3.0, 0.2, 2.0);
        let mc = MonteCarlo::new(3000, 5);
        let e = mc.estimate(&StaircaseScheduler, &model, 6, 2, 5);
        assert!(e.min <= e.p50 && e.p50 <= e.p95 && e.p95 <= e.max);
        assert!(e.std_dev > 0.0);
    }
}
