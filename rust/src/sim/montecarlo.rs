//! Seeded, multi-threaded Monte-Carlo estimation of `t̄_C(r, k)`.
//!
//! Rounds are sharded across OS threads; each shard owns an RNG seeded
//! from `(seed, shard)` so results are reproducible for a fixed
//! `(trials, threads, seed)` triple regardless of scheduling.  The
//! coupled estimator evaluates several schemes against the *same* delay
//! stream, eliminating between-scheme sampling noise — that is what the
//! figure harnesses use, mirroring the paper's "same dataset for all
//! schemes" fairness note.

use crate::util::rng::Rng;


use crate::delay::{DelayModel, DelaySample};
use crate::scheduler::Scheduler;
use crate::util::stats::{quantile_sorted, RunningStats};

use super::completion_time_fast;

/// Point estimate of the average completion time plus dispersion.
#[derive(Debug, Clone)]
pub struct CompletionEstimate {
    pub scheme: String,
    pub n: usize,
    pub r: usize,
    pub k: usize,
    pub trials: usize,
    pub mean: f64,
    pub std_err: f64,
    pub std_dev: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p95: f64,
}

impl CompletionEstimate {
    fn from_values(
        scheme: String,
        n: usize,
        r: usize,
        k: usize,
        mut values: Vec<f64>,
    ) -> Self {
        let mut acc = RunningStats::new();
        for &v in &values {
            acc.push(v);
        }
        values.sort_unstable_by(f64::total_cmp);
        Self {
            scheme,
            n,
            r,
            k,
            trials: values.len(),
            mean: acc.mean(),
            std_err: acc.std_err(),
            std_dev: acc.std_dev(),
            min: acc.min(),
            max: acc.max(),
            p50: quantile_sorted(&values, 0.5),
            p95: quantile_sorted(&values, 0.95),
        }
    }
}

/// Monte-Carlo driver configuration.
#[derive(Debug, Clone, Copy)]
pub struct MonteCarlo {
    pub trials: usize,
    pub seed: u64,
    pub threads: usize,
}

impl Default for MonteCarlo {
    fn default() -> Self {
        Self {
            trials: 10_000,
            seed: 0x5EED,
            threads: std::thread::available_parallelism()
                .map(|p| p.get())
                .unwrap_or(1),
        }
    }
}

impl MonteCarlo {
    pub fn new(trials: usize, seed: u64) -> Self {
        Self {
            trials,
            seed,
            ..Self::default()
        }
    }

    pub fn single_threaded(mut self) -> Self {
        self.threads = 1;
        self
    }

    /// Estimate `t̄` for one scheme.
    pub fn estimate(
        &self,
        scheduler: &dyn Scheduler,
        model: &dyn DelayModel,
        n: usize,
        r: usize,
        k: usize,
    ) -> CompletionEstimate {
        let values = self.run_coupled(&[scheduler], model, n, r, k).pop().unwrap();
        CompletionEstimate::from_values(scheduler.name().to_string(), n, r, k, values)
    }

    /// Estimate several schemes against the identical delay stream.
    pub fn estimate_coupled(
        &self,
        schedulers: &[&dyn Scheduler],
        model: &dyn DelayModel,
        n: usize,
        r: usize,
        k: usize,
    ) -> Vec<CompletionEstimate> {
        let all = self.run_coupled(schedulers, model, n, r, k);
        schedulers
            .iter()
            .zip(all)
            .map(|(s, values)| {
                CompletionEstimate::from_values(s.name().to_string(), n, r, k, values)
            })
            .collect()
    }

    /// Raw per-round completion times, one vec per scheme, coupled on
    /// the delay stream.  Exposed for dominance tests and custom stats.
    pub fn run_coupled(
        &self,
        schedulers: &[&dyn Scheduler],
        model: &dyn DelayModel,
        n: usize,
        r: usize,
        k: usize,
    ) -> Vec<Vec<f64>> {
        assert!(!schedulers.is_empty());
        assert!(self.trials > 0, "need at least one trial");
        let threads = self.threads.clamp(1, self.trials);
        let shard_sizes: Vec<usize> = (0..threads)
            .map(|t| self.trials / threads + usize::from(t < self.trials % threads))
            .collect();

        let mut per_scheme: Vec<Vec<f64>> = vec![Vec::with_capacity(self.trials); schedulers.len()];
        std::thread::scope(|scope| {
            let handles: Vec<_> = shard_sizes
                .iter()
                .enumerate()
                .map(|(shard, &rounds)| {
                    let schedulers = &schedulers;
                    let seed = self.seed;
                    scope.spawn(move || {
                        shard_worker(*schedulers, model, n, r, k, rounds, seed, shard as u64)
                    })
                })
                .collect();
            for h in handles {
                let shard_result = h.join().expect("MC shard panicked");
                for (dst, src) in per_scheme.iter_mut().zip(shard_result) {
                    dst.extend(src);
                }
            }
        });
        per_scheme
    }
}

fn shard_worker(
    schedulers: &[&dyn Scheduler],
    model: &dyn DelayModel,
    n: usize,
    r: usize,
    k: usize,
    rounds: usize,
    seed: u64,
    shard: u64,
) -> Vec<Vec<f64>> {
    // distinct, deterministic streams per shard; scheduling randomness
    // (RA redraws) is kept on a *separate* RNG so the delay stream is
    // identical no matter which scheduler set is being evaluated —
    // `estimate(CS)` and `estimate_coupled([CS, RA])` see the same
    // delays for CS.
    let base = seed ^ 0x9E3779B97F4A7C15u64.wrapping_mul(shard + 1);
    let mut rng = Rng::seed_from_u64(base);
    let mut rng_sched = Rng::seed_from_u64(base ^ 0x5C4ED);
    let mut sample = DelaySample::zeros(n, r);
    let mut scratch: Vec<f64> = Vec::with_capacity(n);

    // fixed schedules built once; randomized ones rebuilt per round
    let fixed: Vec<Option<crate::scheduler::ToMatrix>> = schedulers
        .iter()
        .map(|s| {
            if s.is_randomized() {
                None
            } else {
                Some(s.schedule(n, r, &mut rng_sched))
            }
        })
        .collect();

    let mut out: Vec<Vec<f64>> = vec![Vec::with_capacity(rounds); schedulers.len()];
    for _ in 0..rounds {
        model.sample_into(&mut sample, &mut rng);
        for (idx, sched) in schedulers.iter().enumerate() {
            let t = match &fixed[idx] {
                Some(to) => completion_time_fast(to, &sample, k, &mut scratch),
                None => {
                    let to = sched.schedule(n, r, &mut rng_sched);
                    completion_time_fast(&to, &sample, k, &mut scratch)
                }
            };
            out[idx].push(t);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::delay::{ShiftedExponential, TruncatedGaussianModel};
    use crate::scheduler::{CyclicScheduler, RandomAssignment, StaircaseScheduler};

    #[test]
    fn deterministic_given_seed_and_threads() {
        let model = ShiftedExponential::new(0.1, 3.0, 0.2, 2.0);
        let mc = MonteCarlo {
            trials: 2000,
            seed: 42,
            threads: 4,
        };
        let a = mc.estimate(&CyclicScheduler, &model, 6, 3, 6);
        let b = mc.estimate(&CyclicScheduler, &model, 6, 3, 6);
        assert_eq!(a.mean, b.mean);
        assert_eq!(a.p95, b.p95);
    }

    #[test]
    fn thread_split_covers_all_trials() {
        let model = ShiftedExponential::new(0.1, 3.0, 0.2, 2.0);
        for threads in [1, 2, 3, 7] {
            let mc = MonteCarlo {
                trials: 100,
                seed: 1,
                threads,
            };
            let e = mc.estimate(&CyclicScheduler, &model, 4, 2, 3);
            assert_eq!(e.trials, 100, "threads={threads}");
        }
    }

    #[test]
    fn r1_k1_mean_matches_analytic_minimum() {
        // r = 1, k = 1, n = 1: completion = comp + comm of the single
        // worker; mean must equal the sum of means.
        let model = ShiftedExponential::new(0.1, 4.0, 0.3, 5.0);
        let mc = MonteCarlo::new(200_000, 7);
        let e = mc.estimate(&CyclicScheduler, &model, 1, 1, 1);
        let want = 0.1 + 0.25 + 0.3 + 0.2;
        assert!(
            (e.mean - want).abs() < 5.0 * e.std_err,
            "{} vs {want} (se {})",
            e.mean,
            e.std_err
        );
    }

    #[test]
    fn more_redundancy_helps_on_average() {
        // at fixed k, larger computation load can only reduce t̄ (more
        // slots per task) — checked on scenario-1 gaussians
        let model = TruncatedGaussianModel::scenario1(8);
        let mc = MonteCarlo::new(4000, 11);
        let t_r1 = mc.estimate(&CyclicScheduler, &model, 8, 1, 6).mean;
        let t_r4 = mc.estimate(&CyclicScheduler, &model, 8, 4, 6).mean;
        let t_r8 = mc.estimate(&CyclicScheduler, &model, 8, 8, 6).mean;
        assert!(t_r4 < t_r1, "{t_r4} !< {t_r1}");
        assert!(t_r8 <= t_r4 + 2e-3, "{t_r8} !<= {t_r4}");
    }

    #[test]
    fn coupled_schemes_share_delay_stream() {
        // CS vs CS coupled must be *identical*, not just close
        let model = ShiftedExponential::new(0.1, 3.0, 0.2, 2.0);
        let mc = MonteCarlo::new(500, 3);
        let out = mc.run_coupled(
            &[&CyclicScheduler, &CyclicScheduler],
            &model,
            5,
            2,
            4,
        );
        assert_eq!(out[0], out[1]);
    }

    #[test]
    fn scheduled_schemes_beat_ra_at_full_load() {
        // the paper's headline uncoded comparison (Figs. 5–7): CS and SS
        // dominate RA when r = n
        let model = TruncatedGaussianModel::scenario1(10);
        let mc = MonteCarlo::new(6000, 19);
        let est = mc.estimate_coupled(
            &[&CyclicScheduler, &StaircaseScheduler, &RandomAssignment],
            &model,
            10,
            10,
            10,
        );
        let (cs, ss, ra) = (&est[0], &est[1], &est[2]);
        assert!(cs.mean < ra.mean, "CS {} !< RA {}", cs.mean, ra.mean);
        assert!(ss.mean < ra.mean, "SS {} !< RA {}", ss.mean, ra.mean);
    }

    #[test]
    fn estimate_quantiles_ordered() {
        let model = ShiftedExponential::new(0.1, 3.0, 0.2, 2.0);
        let mc = MonteCarlo::new(3000, 5);
        let e = mc.estimate(&StaircaseScheduler, &model, 6, 2, 5);
        assert!(e.min <= e.p50 && e.p50 <= e.p95 && e.p95 <= e.max);
        assert!(e.std_dev > 0.0);
    }
}
