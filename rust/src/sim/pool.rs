//! Persistent worker pool for Monte-Carlo sharding.
//!
//! The scalar engine spawned fresh OS threads (`thread::scope`) for
//! **every** `estimate`/`estimate_coupled` call; a figure sweep makes
//! hundreds of such calls, so thread creation and teardown sat on the
//! hot path.  This pool spawns `available_parallelism` threads once per
//! process ([`WorkerPool::global`]) and feeds them shard closures over a
//! channel; sweeps reuse the same threads for every point.
//!
//! Determinism: results are returned **indexed by shard**, so the
//! caller's output order never depends on which worker thread ran which
//! shard or in what order shards finished.  Seeding stays a pure
//! function of `(seed, shard)` (see `montecarlo::shard_rngs`), so the
//! estimates are identical to the old per-call-spawn engine.
//!
//! Safety: [`WorkerPool::scope_run`] erases the closure lifetimes to
//! queue borrowed work on `'static` threads (the standard scoped-pool
//! construction).  Soundness rests on the completion barrier: the call
//! does not return until every queued job has finished (or panicked —
//! panics are caught per job and re-raised in the caller), so borrowed
//! data outlives every access.

use std::cell::Cell;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Mutex, OnceLock};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

thread_local! {
    /// True on pool worker threads — lets [`WorkerPool::scope_run`]
    /// detect re-entrant use (a job that itself fans out on the pool)
    /// and fall back to inline execution instead of deadlocking: with
    /// every worker blocked in a nested `scope_run`, no thread would
    /// remain to drain the nested jobs.
    static IS_POOL_WORKER: Cell<bool> = const { Cell::new(false) };
}

/// A fixed set of worker threads consuming jobs from a shared queue.
pub struct WorkerPool {
    sender: Sender<Job>,
    size: usize,
}

impl WorkerPool {
    /// Spawn a pool with `size` threads (clamped to ≥ 1).
    pub fn new(size: usize) -> Self {
        let size = size.max(1);
        let (sender, receiver) = channel::<Job>();
        let receiver = std::sync::Arc::new(Mutex::new(receiver));
        for idx in 0..size {
            let receiver = std::sync::Arc::clone(&receiver);
            thread::Builder::new()
                .name(format!("mc-pool-{idx}"))
                .spawn(move || worker_loop(&receiver))
                .expect("spawning Monte-Carlo pool thread");
        }
        Self { sender, size }
    }

    /// The process-wide pool, created on first use with
    /// `available_parallelism` threads.  All Monte-Carlo engines share
    /// it, which also acts as the global concurrency clamp: an engine
    /// may be configured with more *shards* than the machine has cores
    /// (shard count controls RNG streams, hence reproducibility), but
    /// at most `size()` of them ever run at once.
    pub fn global() -> &'static WorkerPool {
        static POOL: OnceLock<WorkerPool> = OnceLock::new();
        POOL.get_or_init(|| {
            WorkerPool::new(
                thread::available_parallelism()
                    .map(|p| p.get())
                    .unwrap_or(1),
            )
        })
    }

    /// Number of worker threads.
    pub fn size(&self) -> usize {
        self.size
    }

    /// Run `jobs` on the pool and return their results **in job order**,
    /// blocking until all complete.  A panicking job does not kill the
    /// pool; the panic is re-raised here after every other job has
    /// drained, so borrowed data is never freed under a running job.
    ///
    /// Re-entrant calls (a job fanning out on the pool it runs on) are
    /// detected and executed inline on the calling thread — results and
    /// determinism are unchanged, only the extra parallelism is lost.
    pub fn scope_run<'scope, R, F>(&self, jobs: Vec<F>) -> Vec<R>
    where
        R: Send + 'scope,
        F: FnOnce() -> R + Send + 'scope,
    {
        if IS_POOL_WORKER.with(Cell::get) {
            // nested use: every worker may already be occupied by an
            // outer job, so queueing would deadlock — run inline
            return jobs.into_iter().map(|job| job()).collect();
        }
        let n_jobs = jobs.len();
        let (tx, rx) = channel::<(usize, thread::Result<R>)>();
        for (idx, job) in jobs.into_iter().enumerate() {
            let tx = tx.clone();
            let boxed: Box<dyn FnOnce() + Send + 'scope> = Box::new(move || {
                let result = catch_unwind(AssertUnwindSafe(job));
                // receiver alive until all results collected; a send
                // failure is unreachable while the barrier below holds
                let _ = tx.send((idx, result));
            });
            // SAFETY: the job only borrows data live for 'scope, and the
            // barrier below blocks until every job has signalled
            // completion, so no borrow escapes this call.  Box<dyn
            // FnOnce> has the same layout regardless of its lifetime
            // bound.
            let boxed: Job = unsafe {
                std::mem::transmute::<Box<dyn FnOnce() + Send + 'scope>, Job>(boxed)
            };
            self.sender.send(boxed).expect("worker pool shut down");
        }
        drop(tx);

        let mut results: Vec<Option<thread::Result<R>>> = Vec::new();
        results.resize_with(n_jobs, || None);
        for _ in 0..n_jobs {
            let (idx, result) = rx
                .recv()
                .expect("pool worker vanished with jobs in flight");
            results[idx] = Some(result);
        }
        // completion barrier passed: every job has run to completion
        results
            .into_iter()
            .map(|slot| match slot.expect("every index filled") {
                Ok(value) => value,
                Err(panic) => resume_unwind(panic),
            })
            .collect()
    }
}

fn worker_loop(receiver: &Mutex<Receiver<Job>>) {
    IS_POOL_WORKER.with(|flag| flag.set(true));
    loop {
        // hold the lock only while dequeuing so workers drain in parallel
        let job = match receiver.lock() {
            Ok(guard) => guard.recv(),
            Err(_) => return,
        };
        match job {
            Ok(job) => job(),
            Err(_) => return, // pool dropped
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn results_come_back_in_job_order() {
        let pool = WorkerPool::new(4);
        let jobs: Vec<_> = (0..64)
            .map(|i| {
                move || {
                    // stagger so completion order differs from job order
                    if i % 7 == 0 {
                        std::thread::sleep(std::time::Duration::from_millis(2));
                    }
                    i * i
                }
            })
            .collect();
        let out = pool.scope_run(jobs);
        assert_eq!(out, (0..64).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn jobs_can_borrow_stack_data() {
        let pool = WorkerPool::new(3);
        let data: Vec<u64> = (0..1000).collect();
        let chunks: Vec<&[u64]> = data.chunks(100).collect();
        let jobs: Vec<_> = chunks
            .iter()
            .map(|chunk| move || chunk.iter().sum::<u64>())
            .collect();
        let sums = pool.scope_run(jobs);
        assert_eq!(sums.iter().sum::<u64>(), data.iter().sum::<u64>());
    }

    #[test]
    fn pool_survives_a_panicking_job() {
        let pool = WorkerPool::new(2);
        let caught = std::panic::catch_unwind(AssertUnwindSafe(|| {
            let jobs: Vec<Box<dyn FnOnce() -> usize + Send>> = vec![
                Box::new(|| 1),
                Box::new(|| panic!("job boom")),
                Box::new(|| 3),
            ];
            pool.scope_run(jobs)
        }));
        assert!(caught.is_err(), "panic must propagate to the caller");
        // the pool must still work afterwards
        let out = pool.scope_run(vec![
            (|| 5usize) as fn() -> usize,
            (|| 6usize) as fn() -> usize,
        ]);
        assert_eq!(out, vec![5, 6]);
    }

    #[test]
    fn nested_scope_run_executes_inline_instead_of_deadlocking() {
        // size-1 pool: a single level of nesting would deadlock without
        // the re-entrancy fallback
        let pool = WorkerPool::new(1);
        let outer: Vec<_> = (0..3)
            .map(|i| {
                let pool = &pool;
                move || {
                    let inner = pool.scope_run(vec![
                        Box::new(move || i * 10) as Box<dyn FnOnce() -> usize + Send>,
                        Box::new(move || i * 10 + 1),
                    ]);
                    inner.iter().sum::<usize>()
                }
            })
            .collect();
        let sums = pool.scope_run(outer);
        assert_eq!(sums, vec![1, 21, 41]);
    }

    #[test]
    fn global_pool_is_shared_and_reused() {
        let a = WorkerPool::global() as *const _;
        let b = WorkerPool::global() as *const _;
        assert_eq!(a, b);
        let counter = AtomicUsize::new(0);
        let jobs: Vec<_> = (0..10)
            .map(|_| {
                let c = &counter;
                move || c.fetch_add(1, Ordering::SeqCst)
            })
            .collect();
        WorkerPool::global().scope_run(jobs);
        assert_eq!(counter.load(Ordering::SeqCst), 10);
    }
}
