//! Per-worker clock-offset estimation (protocol v5 latency anatomy).
//!
//! Worker clocks are unsynchronized: every worker process stamps its
//! v5 `Result` timing fields (`comp_start_us`/`comp_end_us`/
//! `enqueue_us`/`send_ts_us`) with *its own* monotonic clock, so the
//! master cannot subtract them from its arrival stamps directly.  This
//! module is the NTP-style fix: each Assign→Result exchange is a
//! four-timestamp ping
//!
//! ```text
//!   t0  master clock   Assign issue stamp  (carried on the wire)
//!   t1  worker clock   first task compute start
//!   t2  worker clock   delivery-thread send stamp
//!   t3  master clock   frame arrival (FrameBuf fill mark)
//! ```
//!
//! from which the classic midpoint estimate of the worker−master
//! offset is `θ = ((t1−t0) + (t2−t3)) / 2` with round-trip time
//! `ρ = (t3−t0) − (t2−t1)`; the estimate's error is bounded by `ρ/2`
//! regardless of how the one-way delays split (the asymmetry can move
//! the true offset anywhere inside `θ ± ρ/2`, but no further).  The
//! estimator therefore keeps the exchange with the **smallest RTT**
//! seen so far — a running min-RTT midpoint filter — because the
//! tightest ping gives the tightest bound.  To track *drift* (worker
//! clocks ticking at slightly different rates), the retained min-RTT
//! inflates by a small factor per exchange so a long-running worker
//! keeps refreshing its offset from recent traffic, and the slope
//! between consecutive accepted midpoints feeds an EWMA drift rate
//! used to extrapolate the offset when mapping stamps.
//!
//! The `Welcome→Hello` handshake ping seeds the estimate before any
//! round traffic flows (`seed_handshake`), so even round 0's phase
//! decomposition has a bounded-error mapping.  In-process fleets share
//! `coordinator::now_us`'s single process clock, so there the
//! estimator must (and tests assert it does) recover an offset ≈ 0.

/// Multiplicative inflation of the retained min-RTT per observed
/// exchange: after ~35 exchanges a previously accepted ping has
/// doubled its effective RTT, so fresher (drift-current) exchanges
/// displace it even if the wire got slightly slower.
const MIN_RTT_INFLATE: f64 = 1.02;

/// EWMA weight of the drift-rate update on each accepted exchange.
const DRIFT_ALPHA: f64 = 0.3;

/// Minimum spacing between accepted exchanges for a drift update: the
/// slope noise is `(err₁+err₂)/Δt`, so sub-second pairs would swamp
/// any real oscillator error (tens of ppm) with jitter.
const DRIFT_MIN_DT_S: f64 = 2.0;

/// Sanity clamp on the drift estimate (µs/s ≈ ppm) — real clocks are
/// within ±100 ppm; 10× that headroom, and a single corrupt exchange
/// cannot poison the mapping.
const DRIFT_CLAMP: f64 = 1_000.0;

/// Offset/drift estimate for one worker's clock against the master's.
#[derive(Debug, Clone)]
pub struct ClockSync {
    /// worker − master offset (µs) at `ref_us` on the worker clock
    offset_us: f64,
    /// worker-clock instant of the last accepted exchange
    ref_us: f64,
    /// drift of the offset, µs per worker-clock second (≈ ppm)
    drift_us_per_s: f64,
    /// effective RTT of the retained exchange (inflated over time)
    min_rtt_us: f64,
    exchanges: u64,
    accepted: u64,
}

impl Default for ClockSync {
    fn default() -> Self {
        Self::new()
    }
}

impl ClockSync {
    pub fn new() -> Self {
        Self {
            offset_us: 0.0,
            ref_us: 0.0,
            drift_us_per_s: 0.0,
            min_rtt_us: f64::INFINITY,
            exchanges: 0,
            accepted: 0,
        }
    }

    /// Feed one four-stamp exchange (`t0`/`t3` master clock, `t1`/`t2`
    /// worker clock, µs).  Returns `true` if this exchange displaced
    /// the retained minimum and updated the offset.
    pub fn observe(&mut self, t0: u64, t1: u64, t2: u64, t3: u64) -> bool {
        self.exchanges += 1;
        let rtt = ((t3 as f64 - t0 as f64) - (t2 as f64 - t1 as f64)).max(0.0);
        let midpoint = ((t1 as f64 - t0 as f64) + (t2 as f64 - t3 as f64)) / 2.0;
        // let drift-stale retained pings age out
        if self.min_rtt_us.is_finite() {
            self.min_rtt_us *= MIN_RTT_INFLATE;
        }
        if rtt > self.min_rtt_us {
            return false;
        }
        if self.accepted > 0 {
            let dt_s = (t1 as f64 - self.ref_us) / 1e6;
            if dt_s >= DRIFT_MIN_DT_S {
                let slope =
                    ((midpoint - self.offset_us) / dt_s).clamp(-DRIFT_CLAMP, DRIFT_CLAMP);
                self.drift_us_per_s = if self.drift_us_per_s == 0.0 {
                    slope
                } else {
                    (1.0 - DRIFT_ALPHA) * self.drift_us_per_s + DRIFT_ALPHA * slope
                };
            }
        }
        self.offset_us = midpoint;
        self.ref_us = t1 as f64;
        self.min_rtt_us = rtt;
        self.accepted += 1;
        true
    }

    /// Seed from the `Welcome→Hello` handshake: the worker stamps
    /// `ts_us` somewhere between the master's write (`t0`) and read
    /// (`t3`) — a degenerate exchange with zero worker-side hold.
    pub fn seed_handshake(&mut self, t0_master: u64, ts_worker: u64, t3_master: u64) {
        self.observe(t0_master, ts_worker, ts_worker, t3_master);
    }

    /// Map a worker-clock stamp onto the master clock, extrapolating
    /// the drift since the last accepted exchange.  Saturates at 0
    /// (the shared process clock starts there).
    pub fn map_to_master(&self, worker_us: u64) -> u64 {
        let off = self.offset_at(worker_us as f64);
        let mapped = worker_us as f64 - off;
        if mapped <= 0.0 {
            0
        } else {
            mapped as u64
        }
    }

    fn offset_at(&self, worker_us: f64) -> f64 {
        self.offset_us + self.drift_us_per_s * (worker_us - self.ref_us) / 1e6
    }

    /// Current worker − master offset estimate (µs), at the last
    /// accepted exchange's reference point.
    pub fn offset_us(&self) -> f64 {
        self.offset_us
    }

    /// Estimated drift (µs of offset per second ≈ ppm).
    pub fn drift_us_per_s(&self) -> f64 {
        self.drift_us_per_s
    }

    /// Hard bound on the offset error: half the retained exchange's
    /// RTT.  Infinite until the first exchange is accepted.
    pub fn error_bound_us(&self) -> f64 {
        if self.accepted == 0 {
            f64::INFINITY
        } else {
            (self.min_rtt_us / 2.0).max(1.0)
        }
    }

    pub fn synced(&self) -> bool {
        self.accepted > 0
    }

    pub fn exchanges(&self) -> u64 {
        self.exchanges
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A synthetic worker clock: `worker(t) = t + offset + drift·t`.
    struct FakeClock {
        offset_us: f64,
        drift_ppm: f64,
    }

    impl FakeClock {
        fn worker(&self, master_us: u64) -> u64 {
            let t = master_us as f64;
            (t + self.offset_us + self.drift_ppm * t / 1e6).round() as u64
        }
    }

    /// Run `k` exchanges with deterministic pseudo-random one-way
    /// delays and return the estimator.
    fn run_exchanges(clk: &FakeClock, sync: &mut ClockSync, k: u32) {
        let mut master_t: u64 = 1_000_000;
        for i in 0..k {
            // deterministic jitter in [100, 1700) µs, different per leg
            let up = 100 + (i as u64 * 7919) % 1600;
            let down = 100 + (i as u64 * 104_729) % 1600;
            let hold = 500 + (i as u64 * 31) % 2000;
            let t0 = master_t;
            let t1 = clk.worker(t0 + up);
            let t2 = t1 + hold;
            let t3 = clk.worker_inverse(t2) + down;
            sync.observe(t0, t1, t2, t3);
            master_t += 50_000 + (i as u64 * 13) % 10_000;
        }
    }

    impl FakeClock {
        /// master instant at which the worker clock reads `w`
        fn worker_inverse(&self, worker_us: u64) -> u64 {
            let w = worker_us as f64;
            ((w - self.offset_us) / (1.0 + self.drift_ppm / 1e6)).round() as u64
        }
    }

    #[test]
    fn recovers_static_offset_within_error_bound() {
        for offset in [-3_000_000.0f64, 0.0, 250_000.0, 7_500_000.0] {
            let clk = FakeClock {
                offset_us: offset,
                drift_ppm: 0.0,
            };
            let mut sync = ClockSync::new();
            run_exchanges(&clk, &mut sync, 64);
            assert!(sync.synced());
            let bound = sync.error_bound_us();
            assert!(bound.is_finite() && bound > 0.0);
            let err = (sync.offset_us() - offset).abs();
            assert!(
                err <= bound,
                "offset {offset}: err {err} exceeds bound {bound}"
            );
            // best ping had ≤ ~200+200 µs of asymmetric jitter floor
            assert!(bound <= 2_000.0, "bound {bound} too loose");
        }
    }

    #[test]
    fn tracks_drift_across_a_long_run() {
        // 200 ppm is an absurdly bad oscillator — a worst case
        let clk = FakeClock {
            offset_us: 1_000_000.0,
            drift_ppm: 200.0,
        };
        let mut sync = ClockSync::new();
        run_exchanges(&clk, &mut sync, 256);
        // after ~256 rounds at ~55 ms apart, ~14 s elapsed: the raw
        // seed offset is stale by ~2.8 ms, the tracker must do better
        let now_master: u64 = 16_000_000;
        let now_worker = clk.worker(now_master);
        let mapped = sync.map_to_master(now_worker);
        let err = (mapped as f64 - now_master as f64).abs();
        assert!(err <= 3_000.0, "drift-mapped error {err} µs");
        assert!(
            sync.drift_us_per_s() != 0.0,
            "drift went undetected over a 15 s run at 200 ppm"
        );
    }

    #[test]
    fn handshake_seed_gives_immediate_bounded_mapping() {
        let clk = FakeClock {
            offset_us: -500_000.0,
            drift_ppm: 0.0,
        };
        let mut sync = ClockSync::new();
        assert!(!sync.synced());
        assert!(sync.error_bound_us().is_infinite());
        // master writes Welcome at t0, worker stamps mid-flight,
        // master reads Hello at t3 — 400 µs round trip
        let t0: u64 = 2_000_000;
        let ts = clk.worker(t0 + 180);
        let t3 = t0 + 400;
        sync.seed_handshake(t0, ts, t3);
        assert!(sync.synced());
        assert!(sync.error_bound_us() <= 200.0 + 1.0);
        let err = (sync.offset_us() - (-500_000.0)).abs();
        assert!(err <= sync.error_bound_us(), "seed err {err}");
    }

    #[test]
    fn shared_process_clock_maps_to_identity() {
        // in-process fleets: worker stamps ARE master stamps
        let clk = FakeClock {
            offset_us: 0.0,
            drift_ppm: 0.0,
        };
        let mut sync = ClockSync::new();
        run_exchanges(&clk, &mut sync, 32);
        assert!(sync.offset_us().abs() <= sync.error_bound_us());
        let w: u64 = 9_999_999;
        let mapped = sync.map_to_master(w);
        assert!(
            (mapped as f64 - w as f64).abs() <= sync.error_bound_us() + 1.0,
            "identity mapping off by {}",
            mapped as f64 - w as f64
        );
    }

    #[test]
    fn min_rtt_filter_prefers_the_tight_ping() {
        let mut sync = ClockSync::new();
        // sloppy ping: 10 ms RTT, asymmetric → midpoint off by ~4 ms
        sync.observe(0, 9_000, 9_500, 10_500);
        let sloppy = sync.offset_us();
        // tight ping: true offset 1000, up 100 µs / hold 50 / down 50
        assert!(sync.observe(100_000, 101_100, 101_150, 100_200));
        assert!((sync.offset_us() - 1_000.0).abs() <= sync.error_bound_us());
        assert!((sync.offset_us() - sloppy).abs() > 1_000.0);
        // a later sloppy ping must NOT displace the tight one
        assert!(!sync.observe(200_000, 209_000, 209_500, 210_500));
        assert!((sync.offset_us() - 1_000.0).abs() <= sync.error_bound_us());
    }
}
