//! Telemetry exporters: the Prometheus text-format encoder, the JSONL
//! metrics log, and the minimal HTTP/1.1 scrape listener that rides the
//! master's existing `poll(2)` loop as a [`PollHook`] — no extra
//! threads on the reactor plane, no dependencies.
//!
//! The encoder writes into a caller-owned `String` (warm capacity →
//! allocation-free re-encode), emitting counters and gauges verbatim
//! and histograms in the Prometheus `summary` convention
//! (`name{quantile="0.5"} v` … plus `name_sum`/`name_count`).  The
//! scrape server answers `GET /metrics` with
//! `Content-Type: text/plain; version=0.0.4` and closes the connection
//! per response — exactly what a Prometheus scraper or a plain `curl`
//! expects — and degrades politely on junk input (400/404/405, bounded
//! request buffer).  Three JSON sidecar endpoints ride the same
//! listener: `/healthz` (liveness + uptime + applied-round count, so
//! probes can tell "up" from "wrong path"), `/catalog` (the full
//! metric catalog as `[{name, kind, help}]`), and `/debug/flight`
//! (the flight-recorder ring attached via
//! [`MetricsServer::set_flight`]).

use std::cell::RefCell;
use std::fmt::Write as _;
use std::fs::File;
use std::io::{self, BufWriter, ErrorKind, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::os::unix::io::AsRawFd;
use std::path::Path;
use std::rc::Rc;

use anyhow::{Context, Result};

use super::flight::FlightRecorder;
use super::metrics as tm;
use super::registry::Snapshot;
use super::Metric;
use crate::coordinator::now_us;
use crate::util::json::Json;
use crate::util::poll::{poll_fds, PollFd, PollHook, POLLIN, POLLOUT};

/// Largest request we are willing to buffer before answering 400 —
/// a real scrape's request line + headers is a few hundred bytes.
const MAX_REQUEST_BYTES: usize = 8 * 1024;

/// Encode `snap` into the Prometheus text exposition format (v0.0.4).
/// Appends nothing but the metric families themselves; the caller owns
/// (and typically reuses) `out`, which is cleared first.
pub fn encode_prometheus_into(out: &mut String, snap: &Snapshot) {
    out.clear();
    for &(name, help, v) in &snap.counters {
        let _ = writeln!(out, "# HELP {name} {help}");
        let _ = writeln!(out, "# TYPE {name} counter");
        let _ = writeln!(out, "{name} {v}");
    }
    for &(name, help, v) in &snap.gauges {
        let _ = writeln!(out, "# HELP {name} {help}");
        let _ = writeln!(out, "# TYPE {name} gauge");
        let _ = writeln!(out, "{name} {v}");
    }
    for &(name, help, h) in &snap.hists {
        let _ = writeln!(out, "# HELP {name} {help}");
        let _ = writeln!(out, "# TYPE {name} summary");
        let _ = writeln!(out, "{name}{{quantile=\"0.5\"}} {}", h.p50);
        let _ = writeln!(out, "{name}{{quantile=\"0.9\"}} {}", h.p90);
        let _ = writeln!(out, "{name}{{quantile=\"0.99\"}} {}", h.p99);
        let _ = writeln!(out, "{name}_sum {}", h.mean * h.count as f64);
        let _ = writeln!(out, "{name}_count {}", h.count);
    }
}

/// Append-only JSONL metrics log: one compact-JSON snapshot per line,
/// flushed per append so a killed run still leaves every completed
/// round's record on disk.
pub struct MetricsLog {
    w: BufWriter<File>,
}

impl MetricsLog {
    pub fn create(path: impl AsRef<Path>) -> Result<Self> {
        let path = path.as_ref();
        let f = File::create(path)
            .with_context(|| format!("creating metrics log {}", path.display()))?;
        Ok(Self {
            w: BufWriter::new(f),
        })
    }

    /// Write one `{ts_us, counters, gauges, histograms}` line.
    pub fn append(&mut self, snap: &Snapshot, ts_us: u64) -> Result<()> {
        let line = Json::obj(vec![
            ("ts_us", Json::Num(ts_us as f64)),
            (
                "counters",
                Json::Obj(
                    snap.counters
                        .iter()
                        .map(|&(name, _, v)| (name.to_string(), Json::Num(v as f64)))
                        .collect(),
                ),
            ),
            (
                "gauges",
                Json::Obj(
                    snap.gauges
                        .iter()
                        .map(|&(name, _, v)| (name.to_string(), Json::Num(v)))
                        .collect(),
                ),
            ),
            (
                "histograms",
                Json::Obj(
                    snap.hists
                        .iter()
                        .map(|&(name, _, h)| {
                            (
                                name.to_string(),
                                Json::obj(vec![
                                    ("count", Json::Num(h.count as f64)),
                                    ("mean", Json::Num(h.mean)),
                                    ("p50", Json::Num(h.p50)),
                                    ("p90", Json::Num(h.p90)),
                                    ("p99", Json::Num(h.p99)),
                                    ("max", Json::Num(h.max)),
                                ]),
                            )
                        })
                        .collect(),
                ),
            ),
        ]);
        writeln!(self.w, "{}", line.to_string_compact()).context("writing metrics log line")?;
        self.w.flush().context("flushing metrics log")
    }

    /// Graceful-shutdown path (master stop and Ctrl-C): append one
    /// last snapshot, then flush *and fsync* so the final applied
    /// rounds survive even if the process dies right after.
    pub fn finalize(&mut self, snap: &Snapshot, ts_us: u64) -> Result<()> {
        self.append(snap, ts_us)?;
        self.w.flush().context("flushing metrics log")?;
        self.w
            .get_ref()
            .sync_all()
            .context("syncing metrics log to disk")
    }
}

/// One in-flight scrape connection.
struct ScrapeConn {
    stream: TcpStream,
    req: Vec<u8>,
    resp: Vec<u8>,
    sent: usize,
    /// Request fully read (or rejected) — now draining `resp`.
    responding: bool,
}

/// The scrape listener: a non-blocking `TcpListener` plus its in-flight
/// connections, pumped either by the reactor's poll loop (via
/// [`PollHook`]) or by [`MetricsServer::pump`] on the threads plane.
/// Every poll iteration does bounded, non-blocking work only.
pub struct MetricsServer {
    listener: TcpListener,
    addr: SocketAddr,
    conns: Vec<ScrapeConn>,
    snap: Snapshot,
    body: String,
    /// Scratch poll set for the standalone `pump` path.
    fds: Vec<PollFd>,
    /// Process-clock bind time, for `/healthz` uptime.
    start_us: u64,
    /// `/debug/flight` source, shared with the master loop (the
    /// server is only ever pumped from the master's own thread).
    flight: Option<Rc<RefCell<FlightRecorder>>>,
}

impl MetricsServer {
    pub fn bind(addr: &str) -> Result<Self> {
        let listener = TcpListener::bind(addr)
            .with_context(|| format!("binding metrics listener on {addr}"))?;
        listener
            .set_nonblocking(true)
            .context("metrics listener nonblocking")?;
        let addr = listener.local_addr().context("metrics listener addr")?;
        Ok(Self {
            listener,
            addr,
            conns: Vec::new(),
            snap: Snapshot::default(),
            body: String::new(),
            fds: Vec::new(),
            start_us: now_us(),
            flight: None,
        })
    }

    /// The bound address (resolves `:0` requests to the real port).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Attach the flight recorder `/debug/flight` dumps read from.
    pub fn set_flight(&mut self, flight: Rc<RefCell<FlightRecorder>>) {
        self.flight = Some(flight);
    }

    /// Drive accept/read/write readiness once without an external poll
    /// loop — the threads-plane pump, also handy in tests.  Bounded
    /// non-blocking work; `timeout_ms` caps the poll wait.
    pub fn pump(&mut self, timeout_ms: i32) {
        let mut fds = std::mem::take(&mut self.fds);
        fds.clear();
        self.register(&mut fds);
        if poll_fds(&mut fds, timeout_ms).is_ok() {
            self.service(&fds);
        }
        self.fds = fds;
    }

    fn accept_new(&mut self) {
        loop {
            match self.listener.accept() {
                Ok((stream, _)) => {
                    if stream.set_nonblocking(true).is_err() {
                        continue;
                    }
                    self.conns.push(ScrapeConn {
                        stream,
                        req: Vec::new(),
                        resp: Vec::new(),
                        sent: 0,
                        responding: false,
                    });
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => return,
                Err(_) => return,
            }
        }
    }

    /// Refresh the cached snapshot + body and build `conn`'s response.
    fn respond(
        conn: &mut ScrapeConn,
        snap: &mut Snapshot,
        body: &mut String,
        flight: Option<&Rc<RefCell<FlightRecorder>>>,
        start_us: u64,
    ) {
        let verdict = parse_request(&conn.req);
        let (status, ctype) = match verdict {
            RequestVerdict::Metrics => ("200 OK", "text/plain; version=0.0.4"),
            RequestVerdict::Healthz | RequestVerdict::Catalog | RequestVerdict::Flight => {
                ("200 OK", "application/json")
            }
            RequestVerdict::NotFound => ("404 Not Found", "text/plain"),
            RequestVerdict::BadMethod => ("405 Method Not Allowed", "text/plain"),
            RequestVerdict::Malformed => ("400 Bad Request", "text/plain"),
        };
        match verdict {
            RequestVerdict::Metrics => {
                tm::TELEMETRY_SCRAPES_TOTAL.inc();
                super::snapshot_into(snap);
                encode_prometheus_into(body, snap);
            }
            RequestVerdict::Healthz => {
                tm::TELEMETRY_SCRAPES_TOTAL.inc();
                let doc = Json::obj(vec![
                    ("status", Json::Str("ok".into())),
                    ("uptime_us", Json::Num(now_us().saturating_sub(start_us) as f64)),
                    (
                        "rounds_applied",
                        Json::Num(tm::MASTER_ROUNDS_TOTAL.get() as f64),
                    ),
                ]);
                body.clear();
                body.push_str(&doc.to_string_compact());
                body.push('\n');
            }
            RequestVerdict::Catalog => {
                tm::TELEMETRY_SCRAPES_TOTAL.inc();
                let entries: Vec<Json> = super::catalog()
                    .iter()
                    .map(|m| {
                        let (kind, name, help) = match m {
                            Metric::Counter(c) => ("counter", c.name(), c.help()),
                            Metric::Gauge(g) => ("gauge", g.name(), g.help()),
                            Metric::Histogram(h) => ("histogram", h.name(), h.help()),
                        };
                        Json::obj(vec![
                            ("name", Json::Str(name.into())),
                            ("kind", Json::Str(kind.into())),
                            ("help", Json::Str(help.into())),
                        ])
                    })
                    .collect();
                body.clear();
                body.push_str(&Json::Arr(entries).to_string_compact());
                body.push('\n');
            }
            RequestVerdict::Flight => {
                tm::TELEMETRY_SCRAPES_TOTAL.inc();
                let doc = match flight {
                    Some(fr) => fr.borrow().to_json(),
                    None => Json::obj(vec![
                        ("depth", Json::Num(0.0)),
                        ("recorded", Json::Num(0.0)),
                        ("dropped", Json::Num(0.0)),
                        ("events", Json::Arr(Vec::new())),
                    ]),
                };
                body.clear();
                body.push_str(&doc.to_string_compact());
                body.push('\n');
            }
            RequestVerdict::NotFound | RequestVerdict::BadMethod | RequestVerdict::Malformed => {
                tm::TELEMETRY_SCRAPE_ERRORS_TOTAL.inc();
                body.clear();
                body.push_str(status);
                body.push('\n');
            }
        }
        conn.resp.clear();
        let _ = write!(
            conn.resp,
            "HTTP/1.1 {status}\r\nContent-Type: {ctype}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
            body.len()
        );
        conn.resp.extend_from_slice(body.as_bytes());
        conn.sent = 0;
        conn.responding = true;
    }

    /// Non-blocking read step; returns `false` when the connection
    /// should be dropped.
    fn read_step(
        conn: &mut ScrapeConn,
        snap: &mut Snapshot,
        body: &mut String,
        flight: Option<&Rc<RefCell<FlightRecorder>>>,
        start_us: u64,
    ) -> bool {
        let mut buf = [0u8; 1024];
        loop {
            match conn.stream.read(&mut buf) {
                Ok(0) => {
                    // peer closed before completing a request
                    return false;
                }
                Ok(k) => {
                    conn.req.extend_from_slice(&buf[..k]);
                    if request_complete(&conn.req) || conn.req.len() > MAX_REQUEST_BYTES {
                        Self::respond(conn, snap, body, flight, start_us);
                        return true;
                    }
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => return true,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(_) => return false,
            }
        }
    }

    /// Non-blocking write step; returns `false` once drained or failed.
    fn write_step(conn: &mut ScrapeConn) -> bool {
        loop {
            if conn.sent >= conn.resp.len() {
                let _ = conn.stream.flush();
                return false; // response fully sent → close
            }
            match conn.stream.write(&conn.resp[conn.sent..]) {
                Ok(0) => return false,
                Ok(k) => conn.sent += k,
                Err(e) if e.kind() == ErrorKind::WouldBlock => return true,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(_) => return false,
            }
        }
    }
}

impl PollHook for MetricsServer {
    fn register(&mut self, fds: &mut Vec<PollFd>) {
        fds.push(PollFd::new(self.listener.as_raw_fd(), POLLIN));
        for c in &self.conns {
            let ev = if c.responding { POLLOUT } else { POLLIN };
            fds.push(PollFd::new(c.stream.as_raw_fd(), ev));
        }
    }

    fn service(&mut self, fds: &[PollFd]) {
        if fds.is_empty() {
            return;
        }
        if fds[0].readable() || fds[0].failed() {
            self.accept_new();
        }
        // conn fds follow the listener in registration order; conns
        // accepted *this* iteration have no fd entry yet and are
        // simply picked up next round
        let mut snap = std::mem::take(&mut self.snap);
        let mut body = std::mem::take(&mut self.body);
        let flight = self.flight.clone();
        let start_us = self.start_us;
        let n_polled = fds.len() - 1;
        let mut i = 0usize;
        self.conns.retain_mut(|c| {
            let idx = i;
            i += 1;
            if idx >= n_polled {
                return true; // not in this poll set yet
            }
            let fd = &fds[idx + 1];
            if fd.failed() {
                return false;
            }
            if !c.responding
                && fd.readable()
                && !Self::read_step(c, &mut snap, &mut body, flight.as_ref(), start_us)
            {
                return false;
            }
            if c.responding && (fd.writable() || fd.readable()) {
                return Self::write_step(c);
            }
            true
        });
        self.snap = snap;
        self.body = body;
    }
}

enum RequestVerdict {
    Metrics,
    Healthz,
    Catalog,
    Flight,
    NotFound,
    BadMethod,
    Malformed,
}

fn request_complete(req: &[u8]) -> bool {
    req.windows(4).any(|w| w == b"\r\n\r\n") || req.windows(2).any(|w| w == b"\n\n")
}

/// Classify the request line: `GET /metrics` (or `GET /`) is a scrape,
/// `/healthz`, `/catalog`, and `/debug/flight` are the JSON sidecars;
/// anything else is answered with the matching error status.
fn parse_request(req: &[u8]) -> RequestVerdict {
    let Ok(text) = std::str::from_utf8(req) else {
        return RequestVerdict::Malformed;
    };
    let Some(line) = text.lines().next() else {
        return RequestVerdict::Malformed;
    };
    let mut parts = line.split_whitespace();
    let (Some(method), Some(path), Some(version)) = (parts.next(), parts.next(), parts.next())
    else {
        return RequestVerdict::Malformed;
    };
    if !version.starts_with("HTTP/1.") {
        return RequestVerdict::Malformed;
    }
    if method != "GET" {
        return RequestVerdict::BadMethod;
    }
    match path {
        "/metrics" | "/" => RequestVerdict::Metrics,
        "/healthz" => RequestVerdict::Healthz,
        "/catalog" => RequestVerdict::Catalog,
        "/debug/flight" => RequestVerdict::Flight,
        _ => RequestVerdict::NotFound,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::telemetry::registry::HistSnapshot;

    fn sample_snapshot() -> Snapshot {
        Snapshot {
            counters: vec![("t_frames_total", "frames seen", 42)],
            gauges: vec![("t_in_flight", "rounds in flight", 2.0)],
            hists: vec![(
                "t_dwell_us",
                "dwell",
                HistSnapshot {
                    count: 10,
                    mean: 5.0,
                    p50: 4.0,
                    p90: 9.0,
                    p99: 9.9,
                    max: 10.0,
                },
            )],
        }
    }

    #[test]
    fn prometheus_encoding_is_exact() {
        let mut out = String::new();
        encode_prometheus_into(&mut out, &sample_snapshot());
        let expect = "\
# HELP t_frames_total frames seen
# TYPE t_frames_total counter
t_frames_total 42
# HELP t_in_flight rounds in flight
# TYPE t_in_flight gauge
t_in_flight 2
# HELP t_dwell_us dwell
# TYPE t_dwell_us summary
t_dwell_us{quantile=\"0.5\"} 4
t_dwell_us{quantile=\"0.9\"} 9
t_dwell_us{quantile=\"0.99\"} 9.9
t_dwell_us_sum 50
t_dwell_us_count 10
";
        assert_eq!(out, expect);
    }

    #[test]
    fn request_parser_classifies() {
        assert!(matches!(
            parse_request(b"GET /metrics HTTP/1.1\r\n\r\n"),
            RequestVerdict::Metrics
        ));
        assert!(matches!(
            parse_request(b"GET / HTTP/1.0\r\n\r\n"),
            RequestVerdict::Metrics
        ));
        assert!(matches!(
            parse_request(b"GET /healthz HTTP/1.1\r\n\r\n"),
            RequestVerdict::Healthz
        ));
        assert!(matches!(
            parse_request(b"GET /catalog HTTP/1.1\r\n\r\n"),
            RequestVerdict::Catalog
        ));
        assert!(matches!(
            parse_request(b"GET /debug/flight HTTP/1.1\r\n\r\n"),
            RequestVerdict::Flight
        ));
        assert!(matches!(
            parse_request(b"GET /nope HTTP/1.1\r\n\r\n"),
            RequestVerdict::NotFound
        ));
        assert!(matches!(
            parse_request(b"POST /metrics HTTP/1.1\r\n\r\n"),
            RequestVerdict::BadMethod
        ));
        assert!(matches!(parse_request(b"\xff\xfe"), RequestVerdict::Malformed));
        assert!(matches!(parse_request(b"GARBAGE"), RequestVerdict::Malformed));
    }
}
