//! Flight recorder + online phase-anomaly detector (protocol v5).
//!
//! A production master cannot keep (or ship) every frame's latency
//! breakdown, but when a straggler investigation starts, the *recent
//! past* is exactly what's needed.  [`FlightRecorder`] is a bounded
//! ring of structured events — frame phase breakdowns, replans, ring
//! drops, anomalies — recorded allocation-free on the hot path
//! (fixed-size numeric events, `&'static str` kinds, ring
//! preallocated at construction) and dumped as JSON from the
//! `MetricsServer`'s `/debug/flight` endpoint on demand.
//!
//! [`AnomalyDetector`] watches the per-worker phase EWMAs against the
//! fleet median: a worker whose smoothed compute/queue/network/dwell
//! phase exceeds `factor ×` the median of all workers' smoothed
//! phases is flagged once (hysteresis re-arms after it recovers to
//! half the firing threshold), bumping `straggler_anomaly_total` and
//! dropping an `anomaly` event into the ring — the automatic flight
//! dump the tentpole asks for.  Detection is pure observation: it
//! reads frame timings already on the wire, consumes no RNG, and
//! never touches the data path (inertness pinned by
//! `tests/reactor_parity.rs`).

use crate::util::json::Json;
use crate::util::stats::Ewma;

/// Default `/debug/flight` ring depth (`train --flight-depth`).
pub const DEFAULT_FLIGHT_DEPTH: usize = 256;

/// Default anomaly factor (`train --anomaly-factor`).
pub const DEFAULT_ANOMALY_FACTOR: f64 = 4.0;

/// EWMA weight for the per-worker per-phase smoothers.
const PHASE_EWMA_ALPHA: f64 = 0.25;

/// Observations a worker's phase needs before it can be flagged —
/// one slow first frame (cold caches, page faults) is not an anomaly.
const MIN_SAMPLES: u64 = 4;

/// Fleet medians below this (ms) never flag: with everything
/// effectively instant, ratios are noise.
const MEDIAN_FLOOR_MS: f64 = 0.01;

/// The four v5 latency phases, in wire order.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    Compute = 0,
    Queue = 1,
    Network = 2,
    Dwell = 3,
}

impl Phase {
    pub const ALL: [Phase; 4] = [Phase::Compute, Phase::Queue, Phase::Network, Phase::Dwell];

    pub fn name(self) -> &'static str {
        match self {
            Phase::Compute => "compute",
            Phase::Queue => "queue",
            Phase::Network => "network",
            Phase::Dwell => "dwell",
        }
    }
}

/// One recorded event.  `vals` is kind-specific:
///
/// * `"phase"`  — `[compute_ms, queue_ms, network_ms, dwell_ms]`
/// * `"anomaly"` — `[phase_idx, observed_ms, fleet_median_ms, factor]`
/// * `"replan"` / `"ring_drop"` / anything else — free numeric slots
#[derive(Debug, Clone, Default)]
pub struct FlightEvent {
    pub seq: u64,
    pub ts_us: u64,
    pub kind: &'static str,
    pub round: i64,
    pub worker: i64,
    pub vals: [f64; 4],
}

impl FlightEvent {
    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("seq", Json::Num(self.seq as f64)),
            ("ts_us", Json::Num(self.ts_us as f64)),
            ("kind", Json::Str(self.kind.to_string())),
            ("round", Json::Num(self.round as f64)),
            ("worker", Json::Num(self.worker as f64)),
            (
                "vals",
                Json::Arr(self.vals.iter().map(|&v| Json::Num(v)).collect()),
            ),
        ])
    }
}

/// Bounded ring of recent [`FlightEvent`]s.  `record` is the hot path
/// and allocation-free; `to_json` (the `/debug/flight` dump) allocates
/// and is strictly cold.
pub struct FlightRecorder {
    ring: Vec<FlightEvent>,
    head: usize,
    len: usize,
    seq: u64,
    dropped: u64,
}

impl FlightRecorder {
    pub fn new(depth: usize) -> Self {
        let depth = depth.max(1);
        Self {
            ring: vec![FlightEvent::default(); depth],
            head: 0,
            len: 0,
            seq: 0,
            dropped: 0,
        }
    }

    pub fn depth(&self) -> usize {
        self.ring.len()
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Events evicted by the ring wrapping (total recorded − retained).
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    /// Record one event in place — no allocation, no branch on any
    /// data-path state.
    pub fn record(
        &mut self,
        ts_us: u64,
        kind: &'static str,
        round: i64,
        worker: i64,
        vals: [f64; 4],
    ) {
        let depth = self.ring.len();
        let at = (self.head + self.len) % depth;
        let slot = &mut self.ring[at];
        slot.seq = self.seq;
        slot.ts_us = ts_us;
        slot.kind = kind;
        slot.round = round;
        slot.worker = worker;
        slot.vals = vals;
        self.seq += 1;
        if self.len < depth {
            self.len += 1;
        } else {
            self.head = (self.head + 1) % depth;
            self.dropped += 1;
        }
    }

    /// Oldest→newest iteration over the retained window.
    pub fn iter(&self) -> impl Iterator<Item = &FlightEvent> {
        let depth = self.ring.len();
        (0..self.len).map(move |i| &self.ring[(self.head + i) % depth])
    }

    /// The `/debug/flight` document.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("depth", Json::Num(self.depth() as f64)),
            ("recorded", Json::Num(self.seq as f64)),
            ("dropped", Json::Num(self.dropped as f64)),
            (
                "events",
                Json::Arr(self.iter().map(|e| e.to_json()).collect()),
            ),
        ])
    }
}

/// A fired anomaly: which worker/phase, and the evidence.
#[derive(Debug, Clone, Copy)]
pub struct Anomaly {
    pub worker: usize,
    pub phase: Phase,
    pub observed_ms: f64,
    pub fleet_median_ms: f64,
}

/// Per-worker per-phase EWMA vs fleet-median watchdog.
pub struct AnomalyDetector {
    factor: f64,
    /// `ewma[worker][phase]`
    ewma: Vec<[Ewma; 4]>,
    /// latched worker×phase pairs (hysteresis)
    latched: Vec<[bool; 4]>,
    /// scratch for the median scan — preallocated, hot-path alloc-free
    scratch: Vec<f64>,
    fired: u64,
}

impl AnomalyDetector {
    pub fn new(n_workers: usize, factor: f64) -> Self {
        assert!(factor > 1.0, "anomaly factor must exceed 1");
        Self {
            factor,
            ewma: (0..n_workers)
                .map(|_| std::array::from_fn(|_| Ewma::new(PHASE_EWMA_ALPHA)))
                .collect(),
            latched: vec![[false; 4]; n_workers],
            scratch: Vec::with_capacity(n_workers),
            fired: 0,
        }
    }

    pub fn fired(&self) -> u64 {
        self.fired
    }

    /// The configured firing threshold (× fleet median).
    pub fn factor(&self) -> f64 {
        self.factor
    }

    /// Feed one frame's phase reading; returns the anomaly if this
    /// observation pushed the worker's smoothed phase over the
    /// threshold (rising edge only — the latch holds until the worker
    /// recovers below half the firing threshold).
    pub fn observe(&mut self, worker: usize, phase: Phase, ms: f64) -> Option<Anomaly> {
        if worker >= self.ewma.len() || !ms.is_finite() || ms < 0.0 {
            return None;
        }
        let p = phase as usize;
        self.ewma[worker][p].push(ms);
        if self.ewma[worker][p].count() < MIN_SAMPLES {
            return None;
        }
        // fleet median of the *other* workers' smoothed phase — the
        // suspect must not drag its own median up in a small fleet
        self.scratch.clear();
        for (w, e) in self.ewma.iter().enumerate() {
            if w != worker && e[p].count() > 0 {
                self.scratch.push(e[p].mean());
            }
        }
        if self.scratch.is_empty() {
            return None;
        }
        self.scratch
            .sort_unstable_by(|a, b| a.partial_cmp(b).unwrap());
        let median = self.scratch[self.scratch.len() / 2].max(MEDIAN_FLOOR_MS);
        let smoothed = self.ewma[worker][p].mean();
        if smoothed > self.factor * median {
            if self.latched[worker][p] {
                return None;
            }
            self.latched[worker][p] = true;
            self.fired += 1;
            Some(Anomaly {
                worker,
                phase,
                observed_ms: smoothed,
                fleet_median_ms: median,
            })
        } else {
            if smoothed < self.factor * median / 2.0 {
                self.latched[worker][p] = false;
            }
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_retains_the_newest_depth_events() {
        let mut fr = FlightRecorder::new(4);
        assert!(fr.is_empty());
        for i in 0..10u64 {
            fr.record(i * 100, "phase", i as i64, 0, [i as f64, 0.0, 0.0, 0.0]);
        }
        assert_eq!((fr.len(), fr.depth(), fr.dropped()), (4, 4, 6));
        let seqs: Vec<u64> = fr.iter().map(|e| e.seq).collect();
        assert_eq!(seqs, vec![6, 7, 8, 9]);
        let j = fr.to_json().to_string_compact();
        assert!(j.contains("\"dropped\":6") && j.contains("\"kind\":\"phase\""));
        // the dump must parse back
        let parsed = Json::parse(&j).unwrap();
        assert_eq!(parsed.get("depth").and_then(Json::as_f64), Some(4.0));
    }

    #[test]
    fn record_path_does_not_allocate_after_construction() {
        // the ring is fully preallocated; recording past wrap reuses
        // slots.  (The allocation pin itself lives in
        // tests/telemetry.rs with the counting allocator.)
        let mut fr = FlightRecorder::new(2);
        for i in 0..100 {
            fr.record(i, "ring_drop", -1, -1, [0.0; 4]);
        }
        assert_eq!(fr.len(), 2);
        assert_eq!(fr.dropped(), 98);
    }

    #[test]
    fn detector_fires_once_on_the_straggler_only() {
        let mut det = AnomalyDetector::new(4, 4.0);
        let mut fired = Vec::new();
        for _round in 0..20 {
            for w in 0..4usize {
                let ms = if w == 2 { 50.0 } else { 1.0 };
                if let Some(a) = det.observe(w, Phase::Compute, ms) {
                    fired.push(a);
                }
            }
        }
        assert_eq!(fired.len(), 1, "latched: fires on the rising edge only");
        assert_eq!(fired[0].worker, 2);
        assert_eq!(fired[0].phase, Phase::Compute);
        assert!(fired[0].observed_ms > 4.0 * fired[0].fleet_median_ms);
        assert_eq!(det.fired(), 1);
    }

    #[test]
    fn detector_rearms_after_recovery() {
        let mut det = AnomalyDetector::new(3, 3.0);
        let feed = |det: &mut AnomalyDetector, ms: f64, rounds: usize| {
            let mut n = 0;
            for _ in 0..rounds {
                for w in 0..3usize {
                    let v = if w == 0 { ms } else { 1.0 };
                    if det.observe(w, Phase::Network, v).is_some() {
                        n += 1;
                    }
                }
            }
            n
        };
        assert_eq!(feed(&mut det, 30.0, 15), 1, "first excursion fires once");
        // recovery: EWMA decays below half the threshold → re-arm
        assert_eq!(feed(&mut det, 1.0, 40), 0);
        assert_eq!(feed(&mut det, 30.0, 15), 1, "second excursion re-fires");
        assert_eq!(det.fired(), 2);
    }

    #[test]
    fn detector_needs_min_samples_and_a_fleet() {
        let mut det = AnomalyDetector::new(2, 2.0);
        // fewer than MIN_SAMPLES observations never fire
        for _ in 0..(MIN_SAMPLES - 1) {
            assert!(det.observe(0, Phase::Dwell, 100.0).is_none());
        }
        // still nothing: worker 1 has no samples → no fleet baseline
        assert!(det.observe(0, Phase::Dwell, 100.0).is_none());
        for _ in 0..MIN_SAMPLES {
            det.observe(1, Phase::Dwell, 1.0);
        }
        assert!(det.observe(0, Phase::Dwell, 100.0).is_some());
    }
}
